"""Shared benchmark helpers.

CPU-budget policy: every benchmark runs a scaled-down version of the
paper's experiment by default (`quick=True`) — same axes being varied, same
comparisons, smaller models/datasets — and scales up with --full.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List

import numpy as np

from repro.configs import get_config
from repro.data import make_dataset

ROWS: List[Dict] = []


def record(name: str, us_per_call: float, derived: str = "") -> None:
    ROWS.append({"name": name, "us_per_call": us_per_call, "derived": derived})
    print(f"{name},{us_per_call:.1f},{derived}")


def csv_header():
    print("name,us_per_call,derived")


def small_mnist(size=512, hw=12):
    return make_dataset("mnist", size=size, image_hw=hw, channels=1)


def small_cifar(size=512, hw=12):
    return make_dataset("cifar", size=size, image_hw=hw, channels=3)


def timed(fn: Callable, *args, repeats: int = 1, **kw):
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeats
    return out, dt
