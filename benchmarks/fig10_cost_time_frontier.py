"""Fig. 10 (the paper's headline, reproduced end-to-end) — the cost–time
frontier of serverless vs instance-based P2P training.

The paper's central claim is a comparison: serverless parallel gradient
computation is up to 97.34% faster than conventional instance-based P2P
training, at up to 5.4x the cost, with the gap widest in the
resource-constrained scenario (a weak instance computing m batches
sequentially, splitting mini-batches that don't fit its memory). Until the
InstanceRuntime existed only the serverless half ran on the discrete-event
engine; this benchmark prices BOTH sides on it and sweeps

  * model size — small CNN vs VGG11-scale (the paper's model);
  * EC2 memory tier — t2.small / t2.medium / t2.large: memory bounds the
    resident working set (mini-batch splitting below the fit line, "does
    not fit" below the model line) and vCPUs scale sequential compute;
  * P (peer count) — degree-aware exchange wire charging on the overlay.

Engine-only accounting on a fixed synthetic workload (deterministic
per-batch times measured on a 1-vCPU reference — no gradient math, so the
sweep is fast and bit-reproducible). The exchange wire (one upload +
degree downloads on the overlay, through the shared LinkModel) is charged
symmetrically on BOTH walls — the backends move identical bytes — so the
speedup is never an artifact of scoping. Every scenario contributes two
CostReports; the JSON carries all rows, the Pareto frontier over them, and
the headline speedup-vs-cost-multiple curve (the 97.34% / 5.4x shape).

Safety rail: the ideal-config instance run (zero boot, zero churn,
unconstrained memory, no wire) must reproduce the analytic Formula-(2)
InstanceCost wall-clock and USD to <= 1e-6 — same contract as the PR-2
serverless ideal-equivalence test.

Emits BENCH_fig10_cost_time_frontier.json.
"""
from __future__ import annotations

import json
import os

import numpy as np

from repro.core.cost import (
    CostReport,
    EC2_VCPUS,
    InstanceCost,
    compare_backends,
    ec2_cost_per_second,
    pareto_frontier,
)
from repro.core.events import InstanceConfig, LinkModel, RuntimeConfig
from repro.core.graph import get_graph
from repro.core.serverless import ServerlessExecutor

from benchmarks.common import record

BENCH_JSON = os.path.join(
    os.path.dirname(__file__), "..", "BENCH_fig10_cost_time_frontier.json"
)


def run(quick: bool = True, seed: int = 0):
    m = 64 if quick else 235  # batches per peer (paper batch-64 rows: 235)
    rng = np.random.default_rng(0)
    # instance-side seconds on a 1-vCPU reference machine
    per_batch = (3.0 + 0.5 * rng.random(m)).tolist()
    batch_bytes = int(160e6)  # a large image batch: memory pressure source
    models = {"cnn-50MB": int(50e6), "vgg11-531MB": int(531e6)}
    if not quick:
        models["resnet-150MB"] = int(150e6)
    tiers = ("t2.small", "t2.medium", "t2.large")
    peer_counts = (4,) if quick else (4, 8)
    link = LinkModel(bandwidth_bps=1e9)

    rows, reports = [], []
    for model_name, model_bytes in models.items():
        for P in peer_counts:
            graph = get_graph("full", P)
            degree = int(round(graph.mean_degree))
            payload = model_bytes  # dense fp32 gradient per overlay edge
            # Exchange wire: one upload + degree downloads — IDENTICAL on
            # both backends (same overlay, same payloads, same link), so
            # the comparison stays apples-to-apples. It is charged in
            # *time* on both sides (the serverless peer's orchestrator
            # EC2 is up — and billed per second — while the mailbox
            # exchange runs); per-GB egress dollars stay 0 because the
            # paper's Formulas (1)/(2) price no data transfer (that
            # accounting lives in ServerlessCost.egress_usd / fig8).
            wire_s = link.transfer_s(payload) * (1 + degree)
            # serverless: one fan-out of m Lambdas, shared orchestration
            sex = ServerlessExecutor(
                runtime=RuntimeConfig(seed=seed), instance="t2.small",
                instance_vcpus=1.0,
            )
            srep = sex.simulate(
                per_batch, model_bytes=model_bytes, batch_bytes=batch_bytes,
            )
            scr = CostReport(
                backend="serverless",
                wall_time_s=srep.wall_time_s + wire_s,
                cost_usd=srep.cost_usd
                + ec2_cost_per_second("t2.small") * wire_s,
                instance="t2.small",
                lambda_memory_mb=srep.lambda_memory_mb,
                num_peers=P,
                label=f"serverless/{model_name}/P{P}",
            )
            reports.append(scr)
            for tier in tiers:
                iex = ServerlessExecutor(
                    backend="instance", instance=tier,
                    instance_config=InstanceConfig(boot_s=40.0, seed=seed),
                )
                try:
                    irep = iex.simulate_instance(
                        per_batch, model_bytes=model_bytes,
                        batch_bytes=batch_bytes, reference_vcpus=1.0,
                        upload_bytes=payload,
                        download_bytes=[payload] * degree,
                        link=link,
                    )
                except ValueError:  # model overflows the tier outright
                    rows.append({
                        "model": model_name, "tier": tier, "peers": P,
                        "fits": False,
                    })
                    record(
                        f"fig10/{model_name}/{tier}/P{P}", 0.0,
                        "fits=False (model overflows the tier)",
                    )
                    continue
                icr = irep.cost_report(
                    num_peers=P, label=f"{tier}/{model_name}/P{P}"
                )
                reports.append(icr)
                cmp = compare_backends(scr, icr)
                constrained = irep.num_splits > 1
                rows.append({
                    "model": model_name, "tier": tier, "peers": P,
                    "fits": True,
                    "tier_vcpus": EC2_VCPUS[tier],
                    "num_splits": irep.num_splits,
                    "resource_constrained": constrained,
                    "wire_s": wire_s,  # same exchange wire on BOTH walls
                    "instance_boot_s": irep.boot_s,
                    "instance_wire_s": irep.wire_s,
                    "instance_billed_s": irep.instance_billed_s,
                    "lambda_memory_mb": srep.lambda_memory_mb,
                    **cmp,
                })
                record(
                    f"fig10/{model_name}/{tier}/P{P}",
                    irep.wall_time_s * 1e6,
                    f"speedup_pct={cmp['speedup_pct']:.2f};"
                    f"cost_multiple={cmp['cost_multiple']:.2f};"
                    f"splits={irep.num_splits};"
                    f"serverless_wall_s={cmp['serverless_wall_s']:.2f}",
                )

    fit_rows = [r for r in rows if r["fits"]]
    headline = max(fit_rows, key=lambda r: r["speedup_pct"])
    frontier = pareto_frontier(reports)

    # Safety rail: ideal instance config == analytic Formula (2), <= 1e-6.
    ideal = ServerlessExecutor(
        backend="instance", instance="t2.large",
        instance_config=InstanceConfig(),
    ).simulate_instance(per_batch)
    analytic = InstanceCost(float(sum(per_batch)), "t2.large")
    wall_err = abs(ideal.wall_time_s - float(sum(per_batch)))
    usd_err = abs(ideal.cost_usd - analytic.cost_per_peer)

    claims = {
        # the paper's trade-off shape, in at least one memory-constrained
        # configuration: serverless >= 90% faster, instance cheaper
        "resource_constrained_speedup_ge_90": any(
            r["resource_constrained"] and r["speedup_pct"] >= 90.0
            and r["cost_multiple"] > 1.0
            for r in fit_rows
        ),
        "headline_speedup_ge_90": headline["speedup_pct"] >= 90.0,
        "serverless_costs_more_somewhere": any(
            r["cost_multiple"] > 1.0 for r in fit_rows
        ),
        # serverless wins on wall-clock, instance on dollars, so the Pareto
        # frontier must genuinely contain points from BOTH backends
        "frontier_has_both_backends": len({p.backend for p in frontier}) == 2,
        "ideal_instance_matches_analytic_1e6": (
            wall_err <= 1e-6 and usd_err <= 1e-6
        ),
    }
    record(
        "fig10/claim:cost_time_frontier",
        0.0,
        ";".join(f"{k}={v}" for k, v in claims.items())
        + f";holds={all(claims.values())}",
    )
    record(
        "fig10/headline",
        0.0,
        f"speedup_pct={headline['speedup_pct']:.2f};"
        f"cost_multiple={headline['cost_multiple']:.2f};"
        f"model={headline['model']};tier={headline['tier']};"
        f"paper_claims=97.34pct_at_5.4x",
    )

    with open(BENCH_JSON, "w") as f:
        json.dump(
            {
                "bench": "fig10_cost_time_frontier",
                "quick": quick,
                "seed": seed,
                "num_batches": m,
                "batch_bytes": batch_bytes,
                "models": models,
                "tiers": list(tiers),
                "peer_counts": list(peer_counts),
                "rows": rows,
                "headline": {
                    "speedup_pct": headline["speedup_pct"],
                    "cost_multiple": headline["cost_multiple"],
                    "model": headline["model"],
                    "tier": headline["tier"],
                    "paper": {"speedup_pct": 97.34, "cost_multiple": 5.4},
                },
                "frontier": [
                    {
                        "backend": p.backend,
                        "label": p.label,
                        "wall_time_s": p.wall_time_s,
                        "cost_usd": p.cost_usd,
                    }
                    for p in frontier
                ],
                "ideal_equivalence": {
                    "wall_err_s": wall_err,
                    "usd_err": usd_err,
                },
                "claims": claims,
            },
            f,
            indent=2,
        )
    record("fig10/json", 0.0, f"path={os.path.relpath(BENCH_JSON)}")
    return claims


if __name__ == "__main__":
    run()
