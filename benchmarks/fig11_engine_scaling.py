"""Fig. 11 (beyond-paper) — engine-throughput scaling to 10k–100k peers.

The paper measures its headline numbers at small P; the scaling question
(SPIRT / LambdaML's per-peer coordination bottleneck) is whether the
simulation stack itself survives large fleets. This benchmark sweeps
P ∈ {1e2, 1e3, 1e4, 1e5} x {full, ring, gossip, hierarchical, tree} and
reports, per (P, mode):

  * overlay construction time and power-iteration spectral gap on the
    CSR sparse surface (no P x P materialization);
  * one simulated serverless epoch: a batched ``ServerlessRuntime.fanout``
    wave of P invocations (cold starts + failures + stragglers) plus the
    mode's mailbox exchange traffic (degree-bounded consumes for sparse
    overlays, up/down register sweeps for ``tree``) — events/sec and
    wall seconds;
  * tracemalloc peak bytes per P (the sub-quadratic memory claim) and
    degree-aware wire accounting from the exchange registry.

Dense full-mesh consume traffic is O(P^2) and is only simulated where
that is affordable (``consume_simulated`` flags each row honestly) — at
scale the point IS that you use a sparse overlay or the tree.

Claims checked (acceptance criteria for the scaling PR):
  * a full epoch at P=10,000 simulates in <= 10 s wall on every
    fully-simulated mode;
  * peak memory grows sub-quadratically in P;
  * same-seed batched engine == legacy scalar engine (<= 1e-6, every
    per-invocation record field) at small P;
  * sparse ``mixing_row`` == dense ``mixing_matrix()`` row for every
    registered overlay.

Emits BENCH_fig11_engine_scaling.json (rows + claims).
"""
from __future__ import annotations

import argparse
import json
import os
import resource
import time
import tracemalloc

import jax
import numpy as np

from repro.core.events import LinkModel, RuntimeConfig, ServerlessRuntime
from repro.core.exchange import ExchangeContext, get_exchange
from repro.core.graph import get_graph
from repro.core.mailbox import HostMailbox
from repro.core.tree import TreePlan

from benchmarks.common import record

BENCH_JSON = os.path.join(
    os.path.dirname(__file__), "..", "BENCH_fig11_engine_scaling.json"
)

# (mode, graph spec, exchange spec): the five scaling columns of fig11
MODES = (
    ("full", "full", "allgather_mean"),
    ("ring", "ring", "allgather_mean"),
    ("gossip", "gossip:3", "allgather_mean"),
    ("hierarchical", "hierarchical:32", "allgather_mean"),
    ("tree", "full", "tree"),
)
MEMORY_MB = 1792
PAYLOAD_BYTES = 1 << 20  # nominal per-register publish size (accounting only)
CONSUME_CAP = 3_000_000  # max mailbox downloads simulated per row


def _grads_like():
    """~1M-param model as ShapeDtypeStructs — byte accounting without
    allocating anything (the sweep's memory claim must measure the
    engine, not the reference gradients)."""
    return {
        "w": jax.ShapeDtypeStruct((1024, 1024), np.float32),
        "b": jax.ShapeDtypeStruct((4096,), np.float32),
    }


def _engine_epoch(P: int, seed: int):
    """One batched fan-out wave of P invocations under a faulty runtime."""
    rt = ServerlessRuntime(
        RuntimeConfig(
            cold_start_s=2.5,
            failure_rate=0.02,
            straggler_prob=0.1,
            seed=seed,
        )
    )
    times = np.random.default_rng(seed).uniform(0.8, 1.2, P)
    t0 = time.perf_counter()
    res = rt.fanout(times, memory_mb=MEMORY_MB)
    dt = time.perf_counter() - t0
    attempts = sum(r.attempts for r in res.invocations)
    return {
        "fanout_wall_s": dt,
        "events_per_s": attempts / dt if dt > 0 else float("inf"),
        "attempts": attempts,
        "cold_starts": res.num_cold_starts,
        "retries": res.num_retries,
        "makespan_s": res.makespan_s,
    }


def _mailbox_epoch(P: int, mode: str, graph, fanout: int = 2):
    """The mode's mailbox register traffic for one epoch.

    Dense modes publish P registers and download along every edge
    (skipped above CONSUME_CAP — flagged, never silently truncated);
    ``tree`` runs the real up/down sweep over a :class:`TreePlan`.
    """
    mb = HostMailbox(P, graph=graph)
    t0 = time.perf_counter()
    if mode == "tree":
        tp = TreePlan(P, fanout)
        for r in range(P - 1, 0, -1):  # up-sweep, leaves first
            mb.publish(r, None, nbytes=PAYLOAD_BYTES, time=0.0, epoch=0,
                       shard=("up",))
            mb.consume(r, consumer=tp.parent(r), shard=("up",))
        for r in range(P):  # down-sweep: hubs publish, children consume
            if len(tp.children(r)):
                mb.publish(r, None, nbytes=PAYLOAD_BYTES, time=0.0, epoch=0,
                           shard=("down",))
            if r:
                mb.consume(tp.parent(r), consumer=r, shard=("down",))
        simulated = True
    else:
        for r in range(P):
            mb.publish(r, None, nbytes=PAYLOAD_BYTES, time=0.0, epoch=0)
        total_consumes = int(round(graph.mean_degree * P))
        simulated = total_consumes <= CONSUME_CAP
        if simulated:
            for r in range(P):
                for nbr in graph.neighbors_array(r):
                    mb.consume(int(nbr), consumer=r)
    dt = time.perf_counter() - t0
    ops = mb.stats["publishes"] + mb.stats["consumes"]
    return {
        "mailbox_wall_s": dt,
        "mailbox_ops": ops,
        "mailbox_ops_per_s": ops / dt if dt > 0 else float("inf"),
        "consume_simulated": simulated,
        "live_messages": mb.live_messages,
    }


def _sweep_rows(peer_counts, seed: int):
    grads_like = _grads_like()
    rows, peak_mem = [], {}
    for P in peer_counts:
        for mode, gspec, xspec in MODES:
            tracemalloc.start()
            t0 = time.perf_counter()
            g = get_graph(gspec, P, seed=seed)
            build_s = time.perf_counter() - t0
            t0 = time.perf_counter()
            gap = g.spectral_gap()
            gap_s = time.perf_counter() - t0
            proto = get_exchange(xspec)
            ctx = ExchangeContext(num_peers=P, graph=g)
            engine = _engine_epoch(P, seed)
            mbx = _mailbox_epoch(P, mode, g)
            epoch_wall = engine["fanout_wall_s"] + mbx["mailbox_wall_s"]
            row = {
                "num_peers": P,
                "mode": mode,
                "graph": gspec,
                "exchange": xspec,
                "graph_build_s": build_s,
                "spectral_gap": gap,
                "spectral_gap_s": gap_s,
                "degree": ctx.degree,
                "num_edges": g.num_edges,
                "bytes_per_edge": proto.wire_bytes_per_edge(grads_like, ctx),
                "wire_bytes_per_step": proto.wire_bytes(grads_like, ctx),
                "host_publish_bytes": proto.host_wire_bytes(grads_like, ctx),
                "epoch_wall_s": epoch_wall,
                **engine,
                **mbx,
            }
            row["peak_mem_bytes"] = tracemalloc.get_traced_memory()[1]
            tracemalloc.stop()
            rows.append(row)
            record(
                f"fig11/P{P}/{mode}",
                epoch_wall * 1e6,
                f"events_per_s={engine['events_per_s']:.0f};"
                f"gap={gap:.3f};consume={mbx['consume_simulated']};"
                f"peak_mem={row['peak_mem_bytes']}",
            )
        # scalable-path peak: the dense full-mesh consume wave is the
        # known-quadratic baseline fig11 argues AGAINST, so the memory
        # claim tracks the sparse/tree modes (full stays in the rows as
        # the contrast column)
        peak_mem[P] = max(
            r["peak_mem_bytes"] for r in rows
            if r["num_peers"] == P and r["mode"] != "full"
        )
        record(f"fig11/P{P}/peak_mem", 0.0, f"bytes={peak_mem[P]}")
    return rows, peak_mem


def _batched_matches_scalar(seed: int, P: int = 256) -> float:
    """Same-seed batched engine vs legacy scalar engine: max abs diff over
    every per-invocation record field (and the makespan)."""
    cfg = dict(
        concurrency_limit=64,
        cold_start_s=2.0,
        failure_rate=0.05,
        straggler_prob=0.2,
        seed=seed,
    )
    times = np.random.default_rng(seed + 1).uniform(0.5, 1.5, P)
    results = {}
    for batched in (False, True):
        rt = ServerlessRuntime(RuntimeConfig(**cfg))
        results[batched] = rt.fanout(
            times, memory_mb=MEMORY_MB, batched=batched
        )
    fields = (
        "submit_s", "start_s", "end_s", "exec_s", "download_s",
        "queue_wait_s", "cold_start_s", "cold_starts", "straggler_factor",
        "attempts", "retries", "backoff_s", "failed_s", "billed_s",
    )
    err = abs(results[True].makespan_s - results[False].makespan_s)
    for a, b in zip(results[False].invocations, results[True].invocations):
        assert a.index == b.index
        for f in fields:
            err = max(err, abs(float(getattr(a, f)) - float(getattr(b, f))))
    return err


def _mixing_row_matches_dense(seed: int, P: int = 64) -> float:
    """Sparse per-row mixing weights vs the dense matrix, every overlay."""
    err = 0.0
    for spec in ("full", "ring", "gossip:3", "hierarchical:8"):
        g = get_graph(spec, P, seed=seed)
        W = g.mixing_matrix()
        for r in range(P):
            err = max(err, float(np.abs(g.mixing_row(r) - W[r]).max()))
    return err


def run(quick: bool = True, seed: int = 0, smoke: bool = False):
    if smoke:
        peer_counts = (100, 1000)
    elif quick:
        peer_counts = (100, 1000, 10_000)
    else:
        peer_counts = (100, 1000, 10_000, 100_000)
    rows, peak_mem = _sweep_rows(peer_counts, seed)
    engine_err = _batched_matches_scalar(seed)
    mixing_err = _mixing_row_matches_dense(seed)
    record("fig11/batched_vs_scalar", 0.0, f"max_abs_err={engine_err:.2e}")
    record("fig11/mixing_row_vs_dense", 0.0, f"max_abs_err={mixing_err:.2e}")

    target_P = 10_000 if 10_000 in peer_counts else max(peer_counts)
    sim_rows = [
        r for r in rows
        if r["num_peers"] == target_P and r["consume_simulated"]
    ]
    # memory exponent between the two largest P: sub-quadratic means the
    # log-log slope stays well under 2 (dense adjacency would be exactly 2)
    ps = sorted(peak_mem)
    p_lo, p_hi = ps[-2], ps[-1]
    mem_exponent = (
        np.log(peak_mem[p_hi] / peak_mem[p_lo]) / np.log(p_hi / p_lo)
    )
    tree_hi = next(
        r for r in rows if r["num_peers"] == ps[-1] and r["mode"] == "tree"
    )
    full_hi = next(
        r for r in rows if r["num_peers"] == ps[-1] and r["mode"] == "full"
    )
    claims = {
        # every fully-simulated mode clears a P=10k epoch in seconds
        "epoch_10k_under_10s": bool(
            sim_rows and max(r["epoch_wall_s"] for r in sim_rows) <= 10.0
        ),
        "engine_over_10k_events_per_s": bool(
            min(r["events_per_s"] for r in rows) >= 10_000
        ),
        "memory_subquadratic": bool(mem_exponent < 1.7),
        "batched_matches_scalar": bool(engine_err <= 1e-6),
        "mixing_row_matches_dense": bool(mixing_err <= 1e-12),
        # a tree hub uploads <= 2 buffers regardless of P; a full-mesh
        # peer's per-step wire grows O(P)
        "tree_bounded_publish_vs_full_mesh": bool(
            tree_hi["host_publish_bytes"]
            < 0.1 * full_hi["wire_bytes_per_step"]
        ),
    }
    record(
        "fig11/claim:engine_scaling",
        0.0,
        ";".join(f"{k}={v}" for k, v in claims.items())
        + f";holds={all(claims.values())}",
    )
    with open(BENCH_JSON, "w") as f:
        json.dump(
            {
                "bench": "fig11_engine_scaling",
                "quick": quick,
                "smoke": smoke,
                "seed": seed,
                "peer_counts": list(peer_counts),
                "modes": [m[0] for m in MODES],
                "rows": rows,
                "peak_mem_bytes": {str(k): v for k, v in peak_mem.items()},
                "mem_exponent": float(mem_exponent),
                "batched_vs_scalar_max_err": engine_err,
                "mixing_row_vs_dense_max_err": mixing_err,
                "ru_maxrss_kb": resource.getrusage(resource.RUSAGE_SELF).ru_maxrss,
                "claims": claims,
            },
            f,
            indent=2,
        )
    record("fig11/json", 0.0, f"path={os.path.relpath(BENCH_JSON)}")
    return claims


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="sweep up to P=1e5")
    ap.add_argument("--smoke", action="store_true",
                    help="P<=1000 CI smoke (fastest path through every mode)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    claims = run(quick=not args.full, seed=args.seed, smoke=args.smoke)
    if not all(claims.values()):
        raise SystemExit(f"fig11 claims failed: {claims}")
