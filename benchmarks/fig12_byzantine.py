"""Fig. 12 (beyond-paper) — Byzantine-robust aggregation under attack.

The paper's P2P design trusts every peer: the RabbitMQ mailbox delivers
whatever a peer publishes, and the consumer averages it in. On public
serverless deployments that trust is the attack surface, so this benchmark
plants a seeded Byzantine minority (``repro.core.robust.AdversarySpec``)
into ``LocalP2PCluster`` and sweeps

    attacker fraction x exchange protocol x overlay graph

measuring what each aggregation rule retains of its OWN clean accuracy
(attacked val-acc / clean val-acc, both evaluated on a non-attacker rank):

  * ``allgather_mean`` — the paper's protocol, breakdown point 0 (one
    attacker already owns the average);
  * ``trimmed_mean:f`` — coordinate-wise trimmed mean, survives < f;
  * ``median`` — coordinate-wise median, survives < 1/2;
  * ``krum`` — distance-scored selection (Blanchard et al., 2017),
    survives f <= (P - 3) / 2, full graph only.

The training recipe is the repo's known-to-learn CNN setting (MobileNetV3-
Small on the procedural MNIST, the same recipe the tier-1 convergence test
uses), so the clean baselines genuinely converge and degradation is
attributable to the attack, not to an unlearnable task. Attackers publish
poison but keep their own local update honest — the victim is an honest
consumer.

The robustness tax is reported as wire bytes: the robust family needs
every neighbor's dense gradient (order statistics don't fuse), so it pays
``allgather_mean`` byte counts where ``psum_mean`` / ``reduce_scatter``
pay ~2/P of that.

Also rails the zero-attacker equivalence: ``trimmed_mean:0`` must match
``allgather_mean`` parameter-for-parameter (<= 1e-6) on the host path.

Runtime: the accuracy sweep trains ~10 eight-peer clusters to convergence
(~20 min quick on a laptop CPU). ``run(smoke=True)`` — what
``scripts/check.sh --fast`` calls — skips the sweep and checks only the
fast rails (equivalence, wire accounting, adversary bookkeeping) without
touching BENCH_fig12_byzantine.json.

Emits BENCH_fig12_byzantine.json (rows + claims).
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import AdversarySpec, LocalP2PCluster
from repro.core.exchange import ExchangeContext, get_exchange
from repro.data import make_dataset
from repro.optim import sgd

from benchmarks.common import record, small_mnist

BENCH_JSON = os.path.join(
    os.path.dirname(__file__), "..", "BENCH_fig12_byzantine.json"
)

NUM_PEERS = 8
ATTACK = "sign_flip"
ATTACK_SCALE = 10.0
# (protocol spec, graph spec) — krum refuses sparse graphs, so its gossip
# cell is structurally absent, not skipped
CELLS = (
    ("allgather_mean", "full"),
    ("trimmed_mean:0.25", "full"),
    ("median", "full"),
    ("krum", "full"),
    ("median", "gossip:4"),
)
ROBUST_FULL = ("trimmed_mean:0.25", "median", "krum")


def _sweep_cluster(exchange, graph, adversary, seed, batches_per_epoch):
    """The tier-1 convergence recipe (test_system), widened to 8 peers."""
    return LocalP2PCluster(
        get_config("mobilenet-v3-small"),
        make_dataset("mnist", size=640, image_hw=12, channels=1),
        num_peers=NUM_PEERS,
        batch_size=16,
        batches_per_epoch=batches_per_epoch,
        optimizer=sgd(momentum=0.9),
        lr=0.05,
        sync=True,
        exchange=exchange,
        graph=graph,
        adversary=adversary,
        seed=seed,
    )


def _rail_cluster(exchange, adversary=None, *, seed=0, reject_nonfinite=False):
    """Tiny squeezenet cluster for the fast (non-accuracy) rails."""
    return LocalP2PCluster(
        get_config("squeezenet1.1"),
        small_mnist(size=128, hw=8),
        num_peers=4,
        batch_size=8,
        batches_per_epoch=2,
        optimizer=sgd(momentum=0.9),
        lr=0.05,
        sync=True,
        exchange=exchange,
        adversary=adversary,
        reject_nonfinite=reject_nonfinite,
        seed=seed,
    )


def _honest_rank(adversary, num_peers: int) -> int:
    bad = set(adversary.attackers(num_peers)) if adversary else set()
    return min(r for r in range(num_peers) if r not in bad)


def _sweep_rows(fractions, seed, *, epochs, batches_per_epoch):
    rows = []
    for exchange, graph in CELLS:
        for frac in fractions:
            adv = (
                AdversarySpec(
                    fraction=frac, attack=ATTACK, scale=ATTACK_SCALE, seed=seed
                )
                if frac > 0 else None
            )
            cluster = _sweep_cluster(exchange, graph, adv, seed,
                                     batches_per_epoch)
            cluster.run(epochs=epochs)
            rank = _honest_rank(adv, NUM_PEERS)
            val_loss, val_acc = cluster.evaluate(rank, num_batches=4)
            cc = cluster.comm_cost()
            rows.append(
                {
                    "exchange": exchange,
                    "graph": graph,
                    "attack": ATTACK if frac > 0 else "none",
                    "attacker_frac": frac,
                    "num_attackers": (
                        adv.num_attackers(NUM_PEERS) if adv else 0
                    ),
                    "eval_rank": rank,
                    "val_loss": val_loss,
                    "val_acc": val_acc,
                    "wire_bytes_per_peer_step": cc.wire_bytes_per_step,
                    "poisoned_publishes": cluster.mailbox.stats[
                        "poisoned_publishes"
                    ],
                }
            )
            record(
                f"fig12/{exchange}/{graph}/frac{frac:g}",
                0.0,
                f"val_acc={val_acc:.3f};val_loss={val_loss:.4f};"
                f"wire_bytes={cc.wire_bytes_per_step}",
            )
    return rows


def _equivalence_err(seed: int) -> float:
    """max |param delta| between trimmed_mean:0 and allgather_mean."""
    a = _rail_cluster("allgather_mean", seed=seed)
    b = _rail_cluster("trimmed_mean:0", seed=seed)
    a.run(epochs=2)
    b.run(epochs=2)
    return max(
        float(jnp.max(jnp.abs(x - y)))
        for x, y in zip(
            jax.tree.leaves(a.peers[0].params),
            jax.tree.leaves(b.peers[0].params),
        )
    )


def _wire_overhead_rows():
    """The robustness tax vs the fused collectives, dense model bytes."""
    grads_like = {
        "w": jnp.zeros((256, 256), jnp.float32),
        "b": jnp.zeros((4096,), jnp.float32),
    }
    ctx = ExchangeContext(num_peers=NUM_PEERS)
    rows = []
    for spec in ("psum_mean", "reduce_scatter", "allgather_mean",
                 "trimmed_mean:0.25", "median", "krum"):
        proto = get_exchange(spec)
        wb = proto.wire_bytes(grads_like, ctx)
        rows.append({"exchange": spec, "wire_bytes_per_peer_step": wb})
        record(f"fig12/wire/{spec}", 0.0, f"wire_bytes={wb}")
    return rows


def _smoke(seed: int) -> dict:
    """The fast rails only (for check.sh --fast / CI): equivalence, wire
    accounting, adversary + nonfinite-guard bookkeeping. No training
    sweep, no BENCH json."""
    equiv_err = _equivalence_err(seed)
    wire = _wire_overhead_rows()
    adv = AdversarySpec(fraction=0.25, attack=ATTACK, scale=ATTACK_SCALE,
                        seed=seed)
    c = _rail_cluster("median", adv, seed=seed, reject_nonfinite=True)
    c.run(epochs=2)
    wb = {r["exchange"]: r["wire_bytes_per_peer_step"] for r in wire}
    claims = {
        "zero_trim_equiv_mean": equiv_err <= 1e-6,
        "adversary_publishes_counted": (
            c.mailbox.stats["poisoned_publishes"]
            == adv.num_attackers(4) * 2
        ),
        "robust_pay_dense_bytes": all(
            wb[p] == wb["allgather_mean"] for p in ROBUST_FULL
        )
        and wb["allgather_mean"] > 2 * wb["psum_mean"],
    }
    record(
        "fig12/claim:byzantine_smoke",
        0.0,
        ";".join(f"{k}={v}" for k, v in claims.items())
        + f";equiv_err={equiv_err:.2e};holds={all(claims.values())}",
    )
    assert all(claims.values()), claims
    return claims


def run(quick: bool = True, seed: int = 0, smoke: bool = False):
    if smoke:
        return _smoke(seed)
    fractions = (0.0, 0.25) if quick else (0.0, 0.25, 0.375)
    epochs = 6 if quick else 10
    batches_per_epoch = 4 if quick else 5
    rows = _sweep_rows(fractions, seed, epochs=epochs,
                       batches_per_epoch=batches_per_epoch)
    equiv_err = _equivalence_err(seed)
    wire = _wire_overhead_rows()

    def acc(exchange, graph, frac):
        return next(
            r["val_acc"] for r in rows
            if r["exchange"] == exchange and r["graph"] == graph
            and r["attacker_frac"] == frac
        )

    def retention(exchange, graph, frac=0.25):
        return acc(exchange, graph, frac) / max(acc(exchange, graph, 0.0),
                                                1e-9)

    mean_ret = retention("allgather_mean", "full")
    robust_rets = {p: retention(p, "full") for p in ROBUST_FULL}
    wb = {r["exchange"]: r["wire_bytes_per_peer_step"] for r in wire}
    claims = {
        # zero attackers: trimmed_mean:0 IS allgather_mean (<= 1e-6)
        "zero_trim_equiv_mean": equiv_err <= 1e-6,
        # the paper's plain mean collapses under a 25% sign-flip minority
        "mean_degrades_under_attack": mean_ret < 0.5,
        # every robust protocol retains most of its clean accuracy...
        "robust_retain_under_attack": all(
            v >= 0.55 for v in robust_rets.values()
        ),
        # ...and beats the attacked mean outright
        "robust_beat_mean_under_attack": all(
            acc(p, "full", 0.25) > acc("allgather_mean", "full", 0.25) + 0.1
            for p in ROBUST_FULL
        ),
        # sparse overlay: the closed-neighborhood median survives too
        "gossip_median_retains": retention("median", "gossip:4") >= 0.5,
        # honest wire accounting: robustness costs dense allgather bytes
        "robust_pay_dense_bytes": all(
            wb[p] == wb["allgather_mean"] for p in ROBUST_FULL
        )
        and wb["allgather_mean"] > 2 * wb["psum_mean"],
    }
    record(
        "fig12/claim:byzantine_robustness",
        0.0,
        ";".join(f"{k}={v}" for k, v in claims.items())
        + f";holds={all(claims.values())}",
    )
    with open(BENCH_JSON, "w") as fp:
        json.dump(
            {
                "bench": "fig12_byzantine",
                "quick": quick,
                "seed": seed,
                "num_peers": NUM_PEERS,
                "attack": ATTACK,
                "attack_scale": ATTACK_SCALE,
                "fractions": list(fractions),
                "epochs": epochs,
                "batches_per_epoch": batches_per_epoch,
                "zero_trim_equivalence_max_err": equiv_err,
                "sweep_rows": rows,
                "wire_rows": wire,
                "retention_at_25pct": {
                    "allgather_mean": mean_ret,
                    **robust_rets,
                },
                "claims": claims,
            },
            fp,
            indent=2,
        )
    record("fig12/json", 0.0, f"path={os.path.relpath(BENCH_JSON)}")
    return claims


if __name__ == "__main__":
    import sys

    run(smoke="--smoke" in sys.argv)
