"""Fig. 13 (beyond-paper) — Pallas-fused compressed exchange + EF-SGD.

The paper compresses gradients (§III-B.4) to survive serverless egress
pricing; this benchmark measures the fused device hot path added on top:

  * **bytes moved** — wire bytes per edge for the packed qsgd / topk
    formats vs the dense fp32 payload (claim: <= 30% of uncompressed at
    the aggressive settings levels=3 / topk_frac=1e-3), plus the analytic
    HBM traffic of the fused decode-dequantize-reduce kernel vs the
    unfused vmap-dequantize-then-reduce formulation (the fused pass never
    materialises the P dense fp32 intermediates);
  * **codec wall-time** — jitted decode wall-times for the jnp reference
    vs the Pallas kernel. On this CPU host the kernel runs in *interpret
    mode* (an emulator), so its absolute time is NOT TPU performance and
    no speed claim is made — both numbers are recorded honestly and the
    bytes-moved ratio carries the perf argument;
  * **EF retention** — error feedback (``Topology(ef=True)`` /
    ``LocalP2PCluster(ef=True)``) must retain convergence where the bare
    codec stalls. The retention cell is the *device-path* exchange
    (``combine``/``combine_ef`` under a peer axis — every contribution
    compressed, exactly what ``build_p2p_train_step`` runs on the mesh)
    on a seeded least-squares problem: top-k at ``frac=1e-3`` without EF
    stalls orders of magnitude above the dense floor, with EF it reaches
    it. QSGD is *unbiased*, so levels=3 converges without EF (its own
    rail here) — and because aggressive QSGD is not a contractive
    compressor (quantization-noise norm ``~sqrt(bucket)/levels`` of the
    input), EF theory does not apply to it; the host-path EF rows are
    recorded for finiteness, not ranked;
  * **equivalence rails** — host-cluster final params, ``impl="kernel"``
    vs ``impl="jnp"``, <= 1e-6 for both codecs (the same rail the tier-1
    suite checks on the 4-device mesh).

``run(smoke=True)`` — what ``scripts/check.sh --fast`` calls — runs only
the fast rails (equivalence, wire accounting, a short finite-loss EF run)
and does not touch BENCH_fig13_fused_compression.json.

Emits BENCH_fig13_fused_compression.json (rows + claims + seed).
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import LocalP2PCluster
from repro.core.compression import QSGDConfig
from repro.core.exchange import ExchangeContext, get_exchange
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.optim import sgd

from benchmarks.common import record, small_mnist, timed

BENCH_JSON = os.path.join(
    os.path.dirname(__file__), "..", "BENCH_fig13_fused_compression.json"
)

NUM_PEERS = 4
QSGD_AGGRESSIVE = QSGDConfig(levels=3, bucket=512)
TOPK_AGGRESSIVE = 1e-3

# dense stand-in for a model's gradient pytree (same shapes as fig12's
# wire-overhead rows, plus a ragged tail that exercises bucket padding)
GRADS_LIKE = {
    "w": jnp.zeros((256, 256), jnp.float32),
    "b": jnp.zeros((4096,), jnp.float32),
    "tail": jnp.zeros((1000,), jnp.float32),
}


def _rail_cluster(seed: int, *, ef: bool, batches_per_epoch: int = 2, **kw):
    """The repo's smoke recipe (squeezenet on procedural MNIST)."""
    return LocalP2PCluster(
        get_config("squeezenet1.1"),
        small_mnist(size=128, hw=8),
        num_peers=NUM_PEERS,
        batch_size=8,
        batches_per_epoch=batches_per_epoch,
        optimizer=sgd(momentum=0.9),
        lr=0.05,
        sync=True,
        ef=ef,
        seed=seed,
        **kw,
    )


# ---------------------------------------------------------------------------
# bytes moved
# ---------------------------------------------------------------------------


def _wire_rows():
    raw = sum(x.size * 4 for x in jax.tree.leaves(GRADS_LIKE))
    rows = [{"codec": "dense_fp32", "wire_bytes_per_edge": raw, "ratio": 1.0}]
    cells = (
        ("qsgd_s3", "qsgd", {"qsgd": QSGD_AGGRESSIVE}),
        ("qsgd_s127", "qsgd", {"qsgd": QSGDConfig(levels=127, bucket=512)}),
        ("topk_1e-3", "topk", {"topk_frac": TOPK_AGGRESSIVE}),
        ("topk_1e-2", "topk", {"topk_frac": 1e-2}),
    )
    for name, proto_name, ctx_kw in cells:
        ctx = ExchangeContext(num_peers=NUM_PEERS, **ctx_kw)
        wb = get_exchange(proto_name).wire_bytes_per_edge(GRADS_LIKE, ctx)
        rows.append(
            {"codec": name, "wire_bytes_per_edge": wb, "ratio": wb / raw}
        )
        record(f"fig13/wire/{name}", 0.0,
               f"bytes={wb};ratio={wb / raw:.4f}")
    return rows


def _fused_traffic_row(P: int, nb: int, bucket: int):
    """Analytic HBM bytes for the decode side of one leaf.

    Unfused (vmap dequantize, then reduce): reads the int8 banks + norms,
    WRITES P dense fp32 intermediates, then reads them back for the mean.
    Fused (single pass): reads the same banks, writes the fp32 output once.
    """
    banks = P * nb * bucket * 1 + P * nb * 4  # int8 levels + fp32 norms
    dense = nb * bucket * 4
    unfused = banks + 2 * P * dense + dense  # write + re-read intermediates
    fused = banks + dense
    row = {
        "P": P, "nb": nb, "bucket": bucket,
        "unfused_bytes": unfused, "fused_bytes": fused,
        "traffic_ratio": fused / unfused,
    }
    record(
        f"fig13/traffic/P{P}", 0.0,
        f"fused={fused};unfused={unfused};ratio={fused / unfused:.3f}",
    )
    return row


# ---------------------------------------------------------------------------
# codec wall-time (recorded, not claimed: CPU interpret mode != TPU perf)
# ---------------------------------------------------------------------------


def _timing_rows(seed: int):
    P, nb, bucket, s = NUM_PEERS, 32, 512, 3
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    lev = jax.random.randint(k1, (P, nb, bucket), -s, s + 1, jnp.int8)
    nrm = jax.random.uniform(k2, (P, nb), jnp.float32, 0.1, 1.0)
    w = jnp.full((P,), 1.0 / P, jnp.float32)

    jnp_fn = jax.jit(lambda l, n: kref.qsgd_dequant_reduce_ref(l, n, w, s))
    ker_fn = jax.jit(lambda l, n: kops.qsgd_dequant_reduce(l, n, w, s))
    jax.block_until_ready(jnp_fn(lev, nrm))  # warm both caches
    jax.block_until_ready(ker_fn(lev, nrm))
    _, t_jnp = timed(lambda: jax.block_until_ready(jnp_fn(lev, nrm)),
                     repeats=20)
    _, t_ker = timed(lambda: jax.block_until_ready(ker_fn(lev, nrm)),
                     repeats=5)

    n, k = 65536, 64
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (n,), jnp.float32)
    sel_jnp = jax.jit(lambda v: kref.topk_select_ref(v, k))
    sel_ker = jax.jit(lambda v: kops.topk_select_pack(v, k))
    jax.block_until_ready(sel_jnp(x))
    jax.block_until_ready(sel_ker(x))
    _, ts_jnp = timed(lambda: jax.block_until_ready(sel_jnp(x)), repeats=20)
    _, ts_ker = timed(lambda: jax.block_until_ready(sel_ker(x)), repeats=5)

    interp = jax.default_backend() != "tpu"
    rows = [
        {"op": "qsgd_dequant_reduce", "impl": "jnp", "us": t_jnp * 1e6},
        {"op": "qsgd_dequant_reduce", "impl": "kernel", "us": t_ker * 1e6,
         "interpret_mode": interp},
        {"op": "topk_select_pack", "impl": "jnp", "us": ts_jnp * 1e6},
        {"op": "topk_select_pack", "impl": "kernel", "us": ts_ker * 1e6,
         "interpret_mode": interp},
    ]
    for r in rows:
        record(
            f"fig13/time/{r['op']}/{r['impl']}", r["us"],
            "interpret-emulated" if r.get("interpret_mode") else "",
        )
    return rows


# ---------------------------------------------------------------------------
# EF retention + equivalence rails
# ---------------------------------------------------------------------------

EF_CELLS = (
    ("qsgd_s3", {"exchange": "qsgd", "qsgd": QSGD_AGGRESSIVE}),
    ("topk_1e-3", {"exchange": "topk", "topk_frac": TOPK_AGGRESSIVE}),
)


def _quadratic_ef_rows(seed: int, *, steps: int):
    """Device-path EF retention on a seeded least-squares problem.

    Runs the actual registered protocols' ``combine``/``combine_ef``
    under a vmapped peer axis — the identical collective math
    ``build_p2p_train_step`` traces inside ``shard_map`` — so every
    contribution (own included) is compressed, unlike the host mailbox
    path whose legacy own-contribution stays dense.
    """
    P, B, D = NUM_PEERS, 64, 512
    key = jax.random.PRNGKey(seed)
    w_true = jax.random.normal(key, (D,)) / jnp.sqrt(D)
    X = jax.random.normal(jax.random.fold_in(key, 1), (P, B, D))
    y = jnp.einsum("pbd,d->pb", X, w_true) + 0.01 * jax.random.normal(
        jax.random.fold_in(key, 2), (P, B)
    )

    def gradf(w, Xr, yr):
        return Xr.T @ (Xr @ w - yr) / B

    def lossf(w):
        return float(jnp.mean((jnp.einsum("pbd,d->pb", X, w) - y) ** 2))

    def train(proto_name, ef, lr, n, **ctx_kw):
        proto = get_exchange(proto_name) if proto_name else None
        ctx = ExchangeContext(axis="data", num_peers=P, **ctx_kw)

        def step(w, e, Xr, yr, k):
            g = gradf(w, Xr, yr)
            if proto is None:
                return w - lr * jax.lax.pmean(g, "data"), e
            if ef:
                c = g + e
                avg, local, _ = proto.combine_ef(c, ctx, key=k)
                return w - lr * avg, c - local
            avg, _ = proto.combine(g, ctx, key=k)
            return w - lr * avg, e

        vstep = jax.jit(
            jax.vmap(step, in_axes=(0, 0, 0, 0, None), axis_name="data")
        )
        w = jnp.zeros((P, D))
        e = jnp.zeros((P, D))
        for t in range(n):
            w, e = vstep(w, e, X, y, jax.random.fold_in(key, 100 + t))
        return lossf(w[0])

    # EF ships the ACCUMULATED residual when a coordinate finally wins
    # the top-k race, so the stable lr scales with ~k/d — same lr for
    # both arms keeps the comparison fair.
    cells = (
        ("dense_fp32", None, False, 0.02, steps, {}),
        ("topk_1e-3", "topk", False, 0.02, steps,
         {"topk_frac": TOPK_AGGRESSIVE}),
        ("topk_1e-3", "topk", True, 0.02, steps,
         {"topk_frac": TOPK_AGGRESSIVE}),
        # unbiased rail: aggressive qsgd needs NO error feedback
        ("qsgd_s3", "qsgd", False, 0.1, min(steps, 300),
         {"qsgd": QSGD_AGGRESSIVE}),
    )
    rows = []
    for name, proto_name, ef, lr, n, ctx_kw in cells:
        loss = train(proto_name, ef, lr, n, **ctx_kw)
        rows.append({"codec": name, "ef": ef, "lr": lr, "steps": n,
                     "final_loss": loss})
        record(f"fig13/ef_device/{name}/{'ef' if ef else 'no_ef'}", 0.0,
               f"final_loss={loss:.6f};lr={lr};steps={n}")
    return rows


def _host_ef_rows(seed: int, *, epochs: int, batches_per_epoch: int):
    """Host-path EF rows (recorded for finiteness; the host mailbox keeps
    the legacy dense own-contribution, so EF-vs-no-EF final losses are
    not directly comparable there)."""
    rows = []
    for name, kw in EF_CELLS:
        for ef in (False, True):
            cl = _rail_cluster(seed, ef=ef,
                               batches_per_epoch=batches_per_epoch, **kw)
            hist = cl.run(epochs=epochs)
            rows.append({"codec": name, "ef": ef,
                         "final_loss": hist[-1]["loss"]})
            record(f"fig13/ef_host/{name}/{'ef' if ef else 'no_ef'}", 0.0,
                   f"final_loss={hist[-1]['loss']:.4f}")
    return rows


def _equivalence_errs(seed: int) -> dict:
    """Host-cluster final params: impl='kernel' vs impl='jnp', per codec."""

    def final_params(**kw):
        cl = _rail_cluster(seed, ef=False, batches_per_epoch=1, **kw)
        cl.run_epoch_sync(0)
        return cl.peers[0].params

    def maxerr(a, b):
        return max(
            float(jnp.max(jnp.abs(x - y)))
            for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b))
        )

    errs = {
        "qsgd": maxerr(
            final_params(exchange="qsgd",
                         qsgd=QSGDConfig(levels=7, bucket=256, impl="jnp")),
            final_params(exchange="qsgd",
                         qsgd=QSGDConfig(levels=7, bucket=256, impl="kernel")),
        ),
        "topk": maxerr(
            final_params(exchange="topk", topk_frac=0.01, topk_impl="jnp"),
            final_params(exchange="topk", topk_frac=0.01, topk_impl="kernel"),
        ),
    }
    for name, err in errs.items():
        record(f"fig13/equiv/{name}", 0.0, f"max_err={err:.2e}")
    return errs


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------


def _wire_claims(wire_rows) -> dict:
    ratio = {r["codec"]: r["ratio"] for r in wire_rows}
    return {
        "qsgd_wire_le_30pct": ratio["qsgd_s3"] <= 0.30,
        "topk_wire_le_30pct": ratio["topk_1e-3"] <= 0.30,
    }


def _smoke(seed: int) -> dict:
    """Fast rails only (check.sh --fast / CI): no BENCH json."""
    wire = _wire_rows()
    traffic = _fused_traffic_row(NUM_PEERS, 32, 512)
    errs = _equivalence_errs(seed)
    # EF at levels=3 (kernel impl) trains and stays finite — the full
    # retention comparison is the non-smoke run
    cl = _rail_cluster(
        seed, ef=True, exchange="qsgd",
        qsgd=QSGDConfig(levels=3, bucket=256, impl="kernel"),
    )
    hist = cl.run(epochs=2)
    claims = {
        **_wire_claims(wire),
        "fused_moves_fewer_bytes": traffic["traffic_ratio"] < 0.5,
        "qsgd_kernel_equiv": errs["qsgd"] <= 1e-6,
        "topk_kernel_equiv": errs["topk"] <= 1e-6,
        "ef_kernel_path_finite": bool(np.isfinite(hist[-1]["loss"])),
    }
    record(
        "fig13/claim:fused_compression_smoke", 0.0,
        ";".join(f"{k}={v}" for k, v in claims.items())
        + f";holds={all(claims.values())}",
    )
    assert all(claims.values()), claims
    return claims


def run(quick: bool = True, seed: int = 0, smoke: bool = False):
    if smoke:
        return _smoke(seed)
    epochs = 4 if quick else 8
    batches_per_epoch = 2 if quick else 4
    steps = 2000 if quick else 4000
    wire = _wire_rows()
    traffic = _fused_traffic_row(NUM_PEERS, 32, 512)
    timing = _timing_rows(seed)
    errs = _equivalence_errs(seed)
    ef_rows = _quadratic_ef_rows(seed, steps=steps)
    host_rows = _host_ef_rows(seed, epochs=epochs,
                              batches_per_epoch=batches_per_epoch)

    def loss(codec, ef):
        return next(r["final_loss"] for r in ef_rows
                    if r["codec"] == codec and r["ef"] == ef)

    claims = {
        **_wire_claims(wire),
        # the fused pass skips the P dense fp32 intermediates entirely
        "fused_moves_fewer_bytes": traffic["traffic_ratio"] < 0.5,
        # kernel impl == jnp impl on the host training path
        "qsgd_kernel_equiv": errs["qsgd"] <= 1e-6,
        "topk_kernel_equiv": errs["topk"] <= 1e-6,
        # the biased sparsifier stalls without EF ...
        "topk_no_ef_stalls": loss("topk_1e-3", False) >= 0.1,
        # ... and EF restores convergence (>= 100x lower final loss)
        "ef_topk_retains": (
            loss("topk_1e-3", True) <= 1e-2 * loss("topk_1e-3", False)
        ),
        # the unbiased quantizer converges WITHOUT error feedback
        "qsgd_unbiased_converges": loss("qsgd_s3", False) <= 1e-3,
        # host-path EF runs stay finite (the host mailbox's legacy dense
        # own-contribution makes its EF/no-EF losses incomparable)
        "host_ef_finite": all(
            np.isfinite(r["final_loss"]) for r in host_rows
        ),
    }
    record(
        "fig13/claim:fused_compression", 0.0,
        ";".join(f"{k}={v}" for k, v in claims.items())
        + f";holds={all(claims.values())}",
    )
    with open(BENCH_JSON, "w") as fp:
        json.dump(
            {
                "bench": "fig13_fused_compression",
                "quick": quick,
                "seed": seed,
                "num_peers": NUM_PEERS,
                "qsgd_aggressive": {"levels": QSGD_AGGRESSIVE.levels,
                                    "bucket": QSGD_AGGRESSIVE.bucket},
                "topk_aggressive_frac": TOPK_AGGRESSIVE,
                "epochs": epochs,
                "batches_per_epoch": batches_per_epoch,
                "quadratic_steps": steps,
                "wire_rows": wire,
                "fused_traffic": traffic,
                "timing_rows": timing,
                "timing_note": (
                    "kernel timings are CPU interpret-mode emulation, not "
                    "TPU performance; no speed claim is made from them"
                ),
                "kernel_equivalence_max_err": errs,
                "ef_device_rows": ef_rows,
                "ef_host_rows": host_rows,
                "ef_note": (
                    "device-path retention: every contribution compressed "
                    "(what build_p2p_train_step runs); EF applies to the "
                    "contractive top-k sparsifier. Aggressive qsgd is "
                    "unbiased (converges without EF) and non-contractive "
                    "(EF theory does not cover it); host rows record "
                    "finiteness only"
                ),
                "claims": claims,
            },
            fp,
            indent=2,
        )
    record("fig13/json", 0.0, f"path={os.path.relpath(BENCH_JSON)}")
    return claims


if __name__ == "__main__":
    import sys

    run(smoke="--smoke" in sys.argv)
