"""Fig. 14 (beyond-paper) — the cost-aware auto-scheduler on a
heterogeneous fleet: CPU serverless vs GPU instances vs mixed.

PR 5 (fig10) *plots* the cost-time frontier; this benchmark *navigates*
it. The decision space follows the 2025 follow-up ("Cost-Performance
Analysis: CPU-Based Serverless vs GPU-Based Training Architectures"):
candidate plans span pure serverless at several Lambda tiers, pure CPU
and GPU instance fleets, and a mixed fleet that pairs the heavy peers
with GPUs and the light peers with Lambdas.

Workload: a deliberately heterogeneous data-parallel epoch. Heavy peers
run a few huge batches — on Lambda those serialize against the ~5.8-vCPU
memory-cap ceiling, while a GPU runs them at its measured epoch speedup;
light peers run many small batches — embarrassingly parallel, so the
cheapest serverless tier wins. That asymmetry is exactly what makes the
mixed fleet strictly dominate at least one pure-serverless AND one
pure-instance config (a claim below): the GPU finishes the heavy work at
the same wall as pure-GPU, while the light peers stop paying for idle
accelerators.

Every candidate is measured in the warm steady state (second epoch: VM
boots paid, containers warm — the regime a multi-epoch run lives in),
then a (deadline x budget) grid is swept:

  * ``cheapest_under_deadline`` must pick the exhaustive-search cost
    optimum among deadline-feasible plans (<= 5% gap claimed; measured
    0%, the candidate set IS the search space) and must NEVER violate
    the deadline — infeasible cells must raise, exactly when exhaustive
    search also finds nothing feasible.
  * ``fastest_under_budget`` symmetric, on wall-clock under the budget.
  * ``pareto_walk`` must always land ON the measured Pareto frontier.

Safety rail: a single-backend ``FleetPlan`` reproduces PR 5's pure
accounting — pure-serverless and pure-instance fleets match the
``ServerlessExecutor`` reports to <= 1e-6 on wall and USD.

Emits BENCH_fig14_auto_scheduler.json.
"""
from __future__ import annotations

import argparse
import json
import os

from repro.core.cost import CostReport, dominates
from repro.core.events import InstanceConfig, RuntimeConfig
from repro.core.scheduler import (
    FleetExecutor,
    FleetPlan,
    PeerAssignment,
    evaluate_candidates,
    get_scheduler,
)
from repro.core.serverless import LAMBDA_MAX_MEMORY_MB, ServerlessExecutor

from benchmarks.common import record

BENCH_JSON = os.path.join(
    os.path.dirname(__file__), "..", "BENCH_fig14_auto_scheduler.json"
)

MODEL_BYTES = int(531e6)  # VGG11-scale, the paper's model
BATCH_BYTES = int(8e6)


def _workload(smoke: bool):
    """Per-peer reference-machine batch times: 2 heavy + 2 light peers.

    The heavy batch must stay large even in smoke mode: the mixed fleet
    only avoids billing GPU barrier idle when the GPU's heavy-batch time
    (heavy / GPU speedup) covers the serverless light peers' wall-clock
    (~0.75 s of invoke + orchestration overhead)."""
    if smoke:
        heavy, light = [24.0], [0.3] * 24
    else:
        heavy, light = [24.0, 24.0], [0.3] * 24
    return [heavy, heavy, light, light]


def _candidates(quick: bool) -> list:
    gpu = PeerAssignment("instance", instance="p3.2xlarge")
    sls = PeerAssignment("serverless")
    cands = [
        FleetPlan.pure("serverless", 4, name="serverless-auto"),
        FleetPlan.pure(
            "serverless", 4, memory_mb=4400, name="serverless-4400"
        ),
        FleetPlan.pure(
            "serverless",
            4,
            memory_mb=LAMBDA_MAX_MEMORY_MB,
            name="serverless-10240",
        ),
        FleetPlan.pure("instance", 4, instance="t2.xlarge", name="cpu-t2.xlarge"),
        FleetPlan.pure(
            "instance", 4, instance="p3.2xlarge", name="gpu-p3.2xlarge"
        ),
        FleetPlan((gpu, gpu, sls, sls), name="mixed-2gpu-2sls"),
    ]
    if not quick:
        cands.insert(
            4,
            FleetPlan.pure(
                "instance", 4, instance="t2.large", name="cpu-t2.large"
            ),
        )
        cands.insert(
            5,
            FleetPlan.pure(
                "instance", 4, instance="g4dn.xlarge", name="gpu-g4dn.xlarge"
            ),
        )
    return cands


def _grid(reports):
    """(deadline, budget) cells spanning infeasible -> unconstrained."""
    walls = sorted(r.wall_time_s for r in reports)
    costs = sorted(r.total_usd for r in reports)
    deadlines = [walls[0] * 0.5] + [w * 1.001 for w in walls] + [None]
    budgets = [costs[0] * 0.5] + [c * 1.001 for c in costs] + [None]
    return deadlines, budgets


def run(quick: bool = True, seed: int = 0, smoke: bool = False):
    runtime = RuntimeConfig(seed=seed)
    candidates = _candidates(quick or smoke)
    workload = _workload(smoke)
    reports = evaluate_candidates(
        candidates,
        workload,
        model_bytes=MODEL_BYTES,
        batch_bytes=BATCH_BYTES,
        warm=True,
        runtime=runtime,
    )
    by_name = {c.name: r for c, r in zip(candidates, reports)}
    for c, r in zip(candidates, reports):
        record(
            f"fig14/candidate/{c.name}",
            r.wall_time_s * 1e6,
            f"wall_s={r.wall_time_s:.3f};total_usd={r.total_usd:.6f};"
            f"backend={r.backend}",
        )

    cheapest = get_scheduler("cheapest_under_deadline")
    fastest = get_scheduler("fastest_under_budget")
    walker = get_scheduler("pareto_walk")
    deadlines, budgets = _grid(reports)

    cells = []
    max_cost_gap_pct = 0.0
    max_wall_gap_pct = 0.0
    deadline_violations = 0
    infeasible_mismatches = 0
    walk_off_frontier = 0
    from repro.core.cost import pareto_frontier

    frontier = pareto_frontier(reports)
    frontier_keys = {(p.wall_time_s, p.cost_usd) for p in frontier}

    for dl in deadlines:
        for bg in budgets:
            cell = {"deadline_s": dl, "budget_usd": bg}
            # exhaustive search over the same candidate space
            dl_feasible = [
                r for r in reports if dl is None or r.wall_time_s <= dl
            ]
            bg_feasible = [
                r for r in reports if bg is None or r.total_usd <= bg
            ]
            # cheapest_under_deadline vs exhaustive cost optimum
            try:
                pick = reports[cheapest.choose(reports, deadline_s=dl)]
                if dl is not None and pick.wall_time_s > dl:
                    deadline_violations += 1
                best = min(r.total_usd for r in dl_feasible)
                gap = (
                    0.0
                    if best <= 0
                    else 100.0 * (pick.total_usd - best) / best
                )
                max_cost_gap_pct = max(max_cost_gap_pct, gap)
                cell["cheapest"] = {
                    "plan": pick.label,
                    "wall_s": pick.wall_time_s,
                    "total_usd": pick.total_usd,
                    "gap_pct": gap,
                }
            except ValueError:
                if dl_feasible:
                    infeasible_mismatches += 1
                cell["cheapest"] = {"infeasible": True}
            # fastest_under_budget vs exhaustive wall optimum
            try:
                pick = reports[fastest.choose(reports, budget_usd=bg)]
                best = min(r.wall_time_s for r in bg_feasible)
                gap = (
                    0.0
                    if best <= 0
                    else 100.0 * (pick.wall_time_s - best) / best
                )
                max_wall_gap_pct = max(max_wall_gap_pct, gap)
                cell["fastest"] = {
                    "plan": pick.label,
                    "wall_s": pick.wall_time_s,
                    "total_usd": pick.total_usd,
                    "gap_pct": gap,
                }
            except ValueError:
                if bg_feasible:
                    infeasible_mismatches += 1
                cell["fastest"] = {"infeasible": True}
            # pareto_walk: best-effort, never raises, never off-frontier
            pick = reports[walker.choose(reports, deadline_s=dl, budget_usd=bg)]
            if (pick.wall_time_s, pick.cost_usd) not in frontier_keys:
                walk_off_frontier += 1
            cell["pareto_walk"] = {
                "plan": pick.label,
                "wall_s": pick.wall_time_s,
                "total_usd": pick.total_usd,
            }
            cells.append(cell)

    # -- mixed-fleet dominance over pure configs ---------------------------
    mixed = by_name["mixed-2gpu-2sls"]
    pure_sls = [r for n, r in by_name.items() if n.startswith("serverless-")]
    pure_inst = [
        r
        for n, r in by_name.items()
        if n.startswith("cpu-") or n.startswith("gpu-")
    ]
    mixed_dominates_sls = [r.label for r in pure_sls if dominates(mixed, r)]
    mixed_dominates_inst = [r.label for r in pure_inst if dominates(mixed, r)]

    # -- PR 5 pure-backend equivalence rail (<= 1e-6) ----------------------
    light = workload[2]
    fx = FleetExecutor(runtime=RuntimeConfig(seed=seed))
    fleet_sls = fx.run_epoch(
        FleetPlan.pure("serverless", 4),
        [light] * 4,
        model_bytes=MODEL_BYTES,
        batch_bytes=BATCH_BYTES,
    ).cost_report()
    pr5_sls = (
        ServerlessExecutor(runtime=RuntimeConfig(seed=seed))
        .simulate(light, model_bytes=MODEL_BYTES, batch_bytes=BATCH_BYTES)
        .cost_report(num_peers=4)
    )
    fx2 = FleetExecutor(
        runtime=RuntimeConfig(seed=seed), instance_config=InstanceConfig()
    )
    fleet_inst = fx2.run_epoch(
        FleetPlan.pure("instance", 4, instance="t2.xlarge"),
        [light] * 4,
        model_bytes=MODEL_BYTES,
        batch_bytes=BATCH_BYTES,
    ).cost_report()
    pr5_inst = (
        ServerlessExecutor(
            backend="instance",
            instance="t2.xlarge",
            instance_config=InstanceConfig(),
        )
        .simulate_instance(
            light,
            model_bytes=MODEL_BYTES,
            batch_bytes=BATCH_BYTES,
            reference_vcpus=1.0,
        )
        .cost_report(num_peers=4)
    )
    equiv = {
        "serverless_wall_err_s": abs(
            fleet_sls.wall_time_s - pr5_sls.wall_time_s
        ),
        "serverless_usd_err": abs(fleet_sls.cost_usd - pr5_sls.cost_usd),
        "instance_wall_err_s": abs(
            fleet_inst.wall_time_s - pr5_inst.wall_time_s
        ),
        "instance_usd_err": abs(fleet_inst.cost_usd - pr5_inst.cost_usd),
    }

    claims = {
        "scheduler_within_5pct_of_exhaustive": (
            max_cost_gap_pct <= 5.0 and max_wall_gap_pct <= 5.0
        ),
        "cheapest_never_violates_deadline": deadline_violations == 0,
        "infeasible_iff_exhaustive_infeasible": infeasible_mismatches == 0,
        "pareto_walk_stays_on_frontier": walk_off_frontier == 0,
        "mixed_dominates_a_pure_serverless": len(mixed_dominates_sls) > 0,
        "mixed_dominates_a_pure_instance": len(mixed_dominates_inst) > 0,
        "pure_fleet_matches_pr5_1e6": all(v <= 1e-6 for v in equiv.values()),
    }
    record(
        "fig14/claim:auto_scheduler",
        0.0,
        ";".join(f"{k}={v}" for k, v in claims.items())
        + f";holds={all(claims.values())}",
    )
    record(
        "fig14/gaps",
        0.0,
        f"max_cost_gap_pct={max_cost_gap_pct:.3f};"
        f"max_wall_gap_pct={max_wall_gap_pct:.3f};"
        f"cells={len(cells)}",
    )

    with open(BENCH_JSON, "w") as f:
        json.dump(
            {
                "bench": "fig14_auto_scheduler",
                "quick": quick,
                "smoke": smoke,
                "seed": seed,
                "model_bytes": MODEL_BYTES,
                "batch_bytes": BATCH_BYTES,
                "workload": {
                    "heavy_peers": 2,
                    "light_peers": 2,
                    "heavy_batch_s": workload[0],
                    "light_batch_s": workload[2],
                },
                "candidates": [
                    {
                        "name": c.name,
                        "plan": c.describe(),
                        "backend": r.backend,
                        "wall_s": r.wall_time_s,
                        "cost_usd_per_peer": r.cost_usd,
                        "total_usd": r.total_usd,
                    }
                    for c, r in zip(candidates, reports)
                ],
                "frontier": [
                    {
                        "label": p.label,
                        "backend": p.backend,
                        "wall_s": p.wall_time_s,
                        "total_usd": p.total_usd,
                    }
                    for p in frontier
                ],
                "sweep": cells,
                "max_cost_gap_pct": max_cost_gap_pct,
                "max_wall_gap_pct": max_wall_gap_pct,
                "mixed_dominates": {
                    "serverless": mixed_dominates_sls,
                    "instance": mixed_dominates_inst,
                },
                "pure_fleet_equivalence": equiv,
                "claims": claims,
            },
            f,
            indent=2,
        )
    record("fig14/json", 0.0, f"path={os.path.relpath(BENCH_JSON)}")
    return claims


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="more tiers in the candidate set")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: tiny workload, core candidate set")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    claims = run(quick=not args.full, seed=args.seed, smoke=args.smoke)
    if not all(claims.values()):
        raise SystemExit(f"fig14 claims failed: {claims}")
