"""Fig. 3 — gradient-computation time with vs without serverless, across
peer counts and batch counts.

The paper's setting: VGG11/MNIST; the instance-based baseline processes a
peer's m batches *sequentially* on a weak instance; the serverless variant
fans them out over m Lambda functions. Our executor runs the same real
gradient computations and accounts wall-clock per backend (per-vCPU memory
scaling + invocation/orchestration overheads, AWS constants).

The improvement is governed by m (batches per peer): paper batch-64 rows
have m=235 and reach 97.34%. Quick mode keeps per-batch compute in the
realistic (>10 ms) regime and sweeps m up to 128; --full sweeps the paper's
batch sizes on VGG11.

Validated claim: serverless cuts gradient-computation time by >90% at high
m, and the gain shrinks as m falls (larger batch sizes).
"""
from __future__ import annotations

import numpy as np

from repro.configs import get_config
from repro.core import LocalP2PCluster, ServerlessExecutor
from repro.data import make_dataset
from repro.optim import sgd

from benchmarks.common import record, small_mnist


def run(quick: bool = True, seed: int = 0):
    ds = small_mnist(size=4096, hw=16 if quick else 28)
    peer_counts = [2, 4] if quick else [4, 8, 12]
    m_values = [8, 32, 96] if quick else [15, 30, 118, 235]  # paper's batch counts
    B = 16 if quick else 64
    model = get_config("squeezenet1.1" if quick else "vgg11")

    improvements = {}
    for P in peer_counts:
        for m in m_values:
            walls = {}
            for backend in ("instance", "serverless"):
                ex = ServerlessExecutor(backend=backend, instance_vcpus=1.0)
                cl = LocalP2PCluster(
                    model, ds, num_peers=P, batch_size=B,
                    batches_per_epoch=m, optimizer=sgd(momentum=0.9),
                    lr=0.01, executor=ex, seed=seed,
                )
                cl.run_epoch_sync(0)
                walls[backend] = float(
                    np.mean([r.wall_time_s for r in cl.peers[0].reports])
                )
            imp = 100.0 * (1 - walls["serverless"] / walls["instance"])
            improvements[(P, m)] = imp
            record(
                f"fig3/peers{P}/m{m}",
                walls["serverless"] * 1e6,
                f"instance_us={walls['instance']*1e6:.0f};improvement_pct={imp:.2f}",
            )
    best = max(improvements.values())
    record(
        "fig3/claim:serverless_speedup", 0.0,
        f"best_improvement_pct={best:.2f};paper_claims=97.34;holds={best > 85}",
    )
    return improvements


if __name__ == "__main__":
    run()
