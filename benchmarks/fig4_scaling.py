"""Fig. 4 — computation vs communication time as the peer count grows.

Paper setting: VGG11 and MobileNetV3-Small, batch 1024, peers 2..12. With
more peers each partition shrinks (compute drops) while every peer sends
its full gradient to all others (communication grows linearly in P).

Validated claims: compute decreases / communication increases with P, and
the effect is much larger for the bigger model (more gradient bytes).
"""
from __future__ import annotations

import numpy as np

from repro.configs import get_config
from repro.core import LocalP2PCluster
from repro.core.compression import raw_bytes
from repro.data import make_dataset
from repro.optim import sgd

from benchmarks.common import record, small_mnist


def run(quick: bool = True, seed: int = 0):
    ds = small_mnist(size=1024, hw=12 if quick else 28)
    peer_counts = [2, 4] if quick else [2, 4, 8, 12]
    models_ = ["squeezenet1.1", "mobilenet-v3-small"] if quick else [
        "mobilenet-v3-small", "vgg11"
    ]
    partition = 256 if quick else 12288
    B = 32 if quick else 1024
    bandwidth = 1e9  # 1 Gb/s inter-peer links

    results = {}
    for mname in models_:
        for P in peer_counts:
            m = max(partition // (P * B), 1)
            cl = LocalP2PCluster(
                get_config(mname), ds, num_peers=P, batch_size=B,
                batches_per_epoch=m, optimizer=sgd(momentum=0.9), lr=0.01,
                network_bandwidth_bps=bandwidth, seed=seed,
            )
            cl.run_epoch_sync(0)
            peer = cl.peers[0]
            # communication: wire time for sending to own queue + receiving P-1
            send_s = peer.send_time_s
            recv_s = (P - 1) * (peer.comm_bytes_sent * 8 / bandwidth)
            comm = send_s + recv_s
            comp = peer.compute_time_s
            results[(mname, P)] = (comp, comm)
            record(
                f"fig4/{mname}/peers{P}",
                comp * 1e6,
                f"comm_us={comm*1e6:.0f};grad_bytes={peer.comm_bytes_sent}",
            )
    ok = True
    for mname in models_:
        ps = sorted(p for (m2, p) in results if m2 == mname)
        comps = [results[(mname, p)][0] for p in ps]
        comms = [results[(mname, p)][1] for p in ps]
        ok &= comps[-1] <= comps[0] * 1.1  # compute shrinks (or flat)
        ok &= comms[-1] > comms[0]  # comm grows
    record("fig4/claim:comm_grows_compute_shrinks", 0.0, f"holds={ok}")
    return results


if __name__ == "__main__":
    run()
