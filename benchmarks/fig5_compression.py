"""Fig. 5 — QSGD's impact on gradient send/receive time.

The paper's Fig. 5 measures the *send and receive* times of one peer's
gradient exchange (4 peers, VGG11): QSGD cuts them across batch sizes. We
measure the same: wire time = send (1 publish) + receive (P-1 consumes) at
a 1 Gb/s inter-peer link, with and without QSGD — plus, separately, the
quantize/dequantize compute cost on THIS host and the link bandwidth below
which compression also wins on total wall-clock (on AWS the paper's
RabbitMQ links are far below it; on TPU ICI they are far above — which is
why EXPERIMENTS.md §Perf found psum > qsgd there).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core import QSGDConfig, quantize_tree, dequantize_tree
from repro.core.compression import payload_bytes, raw_bytes
from repro.core.cost import CommCost
from repro.core.exchange import ExchangeContext, available_exchanges, get_exchange
from repro import models

from benchmarks.common import record

PEERS = 4
BANDWIDTH = 1e9  # 1 Gb/s


def run(quick: bool = True, seed: int = 0):
    cfg = get_config("squeezenet1.1" if quick else "vgg11")
    params = models.init_model(jax.random.PRNGKey(seed + 0), cfg)
    grads = jax.tree.map(
        lambda p: jax.random.normal(jax.random.PRNGKey(seed + 1), p.shape), params
    )
    qcfg = QSGDConfig(levels=127, bucket=2048)

    # warm the jits
    payload, _ = quantize_tree(grads, jax.random.PRNGKey(seed + 2), qcfg)
    jax.block_until_ready(jax.tree.leaves(dequantize_tree(payload, qcfg)))

    raw = raw_bytes(grads)
    t0 = time.perf_counter()
    payload, _ = quantize_tree(grads, jax.random.PRNGKey(seed + 3), qcfg)
    jax.block_until_ready(jax.tree.leaves(payload))
    t_q = time.perf_counter() - t0
    comp = payload_bytes(payload)
    t0 = time.perf_counter()
    back = dequantize_tree(payload, qcfg)
    jax.block_until_ready(jax.tree.leaves(back))
    t_dq = time.perf_counter() - t0

    # the paper's measured quantity: send (1) + receive (P-1) wire time
    comm_raw = PEERS * raw * 8 / BANDWIDTH
    comm_qsgd = PEERS * comp * 8 / BANDWIDTH
    record("fig5/uncompressed_comm", comm_raw * 1e6, f"bytes={raw};peers={PEERS}")
    record(
        "fig5/qsgd_comm", comm_qsgd * 1e6,
        f"bytes={comp};ratio={raw/comp:.2f};quant_us={t_q*1e6:.0f};dequant_us={t_dq*1e6:.0f}",
    )
    # total incl. codec compute on this host, and the breakeven bandwidth
    total_qsgd = comm_qsgd + t_q + (PEERS - 1) * t_dq
    saved_bits = PEERS * (raw - comp) * 8
    breakeven_bps = saved_bits / max(t_q + (PEERS - 1) * t_dq, 1e-9)
    record(
        "fig5/qsgd_total_incl_codec", total_qsgd * 1e6,
        f"breakeven_link_bps={breakeven_bps:.3e}",
    )
    comm_speedup = comm_raw / comm_qsgd
    record(
        "fig5/claim:compression_reduces_comm", 0.0,
        f"comm_speedup={comm_speedup:.2f}x;paper=Fig5_reduction;holds={comm_speedup > 2}",
    )
    # Registry sweep: every registered protocol's wire bytes — per-peer
    # totals feed CommCost (degree-aware: full mesh, so degree = P-1); the
    # compression ratio compares per-edge payloads so it stays a codec
    # property, independent of the overlay.
    ctx = ExchangeContext(num_peers=PEERS, qsgd=qcfg, topk_frac=0.01)
    for name in available_exchanges():
        proto = get_exchange(name)
        wb = proto.wire_bytes(grads, ctx)
        per_edge = proto.wire_bytes_per_edge(grads, ctx)
        cc = CommCost(wire_bytes_per_step=wb, bandwidth_bps=BANDWIDTH)
        record(
            f"fig5/wire/{name}", cc.seconds_per_step * 1e6,
            f"bytes={wb};bytes_per_edge={per_edge};"
            f"ratio_vs_raw={raw/max(per_edge,1):.2f}",
        )
    return comm_raw, comm_qsgd


if __name__ == "__main__":
    run()
