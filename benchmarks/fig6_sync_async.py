"""Fig. 6 — synchronous vs asynchronous P2P convergence.

Paper setting: MobileNetV3-Small, batch 64, SGD lr=0.001, four peers;
synchronous P2P converges faster and more stably (async consumes stale
gradients). We run both modes with heterogeneous peer speeds (staleness
source) and compare validation-accuracy trajectories.

Validated claim: sync reaches a higher accuracy in the same number of
epochs and its trajectory is less erratic than async.
"""
from __future__ import annotations

import numpy as np

from repro.configs import get_config
from repro.core import LocalP2PCluster
from repro.data import make_dataset
from repro.optim import sgd

from benchmarks.common import record, small_mnist


def run(quick: bool = True, seed: int = 0):
    ds = small_mnist(size=768, hw=12)
    epochs = 6 if quick else 30
    histories = {}
    for mode in ("sync", "async"):
        cl = LocalP2PCluster(
            get_config("mobilenet-v3-small"),
            ds,
            num_peers=4, batch_size=16 if quick else 64,
            batches_per_epoch=3,
            optimizer=sgd(momentum=0.9), lr=0.02,
            sync=(mode == "sync"),
            exchange="allgather_mean",  # Algorithm 1 wire format, via registry
            peer_speeds=None if mode == "sync" else [1.0, 1.0, 4.0, 8.0],
            seed=seed,
        )
        hist = cl.run(epochs)
        accs = [h.get("val_acc", np.nan) for h in hist]
        histories[mode] = accs
        record(
            f"fig6/{mode}",
            0.0,
            "acc_curve=" + "|".join(f"{a:.3f}" for a in accs),
        )
    best_sync = np.nanmax(histories["sync"])
    best_async = np.nanmax(histories["async"])
    # stability: std of first differences
    var_sync = np.nanstd(np.diff(histories["sync"]))
    var_async = np.nanstd(np.diff(histories["async"]))
    record(
        "fig6/claim:sync_converges_better", 0.0,
        f"best_sync={best_sync:.3f};best_async={best_async:.3f};"
        f"jitter_sync={var_sync:.3f};jitter_async={var_async:.3f};"
        f"holds={best_sync >= best_async}",
    )
    return histories


if __name__ == "__main__":
    run()
