"""Fig. 7 (beyond-paper) — serverless speedup under faults, cold starts and
allocation policies.

The paper's Fig. 3 speedup assumes a frictionless Lambda: every invocation
warm, none throttled, none failing. This benchmark sweeps the
ServerlessRuntime's fault axes on a fixed synthetic workload (deterministic
per-batch times, engine-only accounting — no gradient math, so the sweep is
fast and bit-reproducible) and reports how much of the headline
gradient-time improvement survives:

  * failure rate in {0, 5%, 20%} — retries burn dead work + backoff;
  * cold starts in {0 s, 2.5 s} — first epoch pays container init, later
    epochs are warm unless the allocation policy re-sizes the tier;
  * allocation policy in {static, latency} — dynamic memory sizing buys
    wall-time back at a dollar premium (the paper's §IV-D "dynamic
    resource allocation", priced).

Emits one BENCH_fig7_faults_coldstart.json record (all scenario rows +
claims) so the perf trajectory accumulates across PRs.
"""
from __future__ import annotations

import json
import os

import numpy as np

from repro.core.events import RuntimeConfig, get_allocation
from repro.core.serverless import ServerlessExecutor

from benchmarks.common import record

BENCH_JSON = os.path.join(
    os.path.dirname(__file__), "..", "BENCH_fig7_faults_coldstart.json"
)


def run(quick: bool = True, seed: int = 0):
    m = 32 if quick else 235  # batches per peer (paper batch-64 rows: 235)
    epochs = 3 if quick else 6
    rng = np.random.default_rng(0)
    per_batch = (0.8 + 0.4 * rng.random(m)).tolist()  # instance-side seconds
    instance_wall = float(sum(per_batch))
    kw = dict(model_bytes=int(50e6), batch_bytes=int(4e6))

    rows = []
    for failure_rate in (0.0, 0.05, 0.2):
        for cold_start_s in (0.0, 2.5):
            for alloc in ("static", "latency"):
                ex = ServerlessExecutor(
                    runtime=RuntimeConfig(
                        failure_rate=failure_rate,
                        cold_start_s=cold_start_s,
                        concurrency_limit=64,
                        seed=seed,
                    ),
                    allocation=(
                        "static" if alloc == "static"
                        else get_allocation("latency", target_batch_s=0.5)
                    ),
                )
                reps = [
                    ex.simulate(per_batch, epoch=e, **kw) for e in range(epochs)
                ]
                last = reps[-1]
                imp = 100.0 * (1.0 - last.wall_time_s / instance_wall)
                row = {
                    "failure_rate": failure_rate,
                    "cold_start_s": cold_start_s,
                    "allocation": alloc,
                    "wall_s_last_epoch": last.wall_time_s,
                    "wall_s_first_epoch": reps[0].wall_time_s,
                    "improvement_pct": imp,
                    "lambda_memory_mb": last.lambda_memory_mb,
                    "cold_starts": sum(r.num_cold_starts for r in reps),
                    "retries": sum(r.num_retries for r in reps),
                    "cost_usd_per_epoch": last.cost_usd,
                }
                rows.append(row)
                record(
                    f"fig7/fail{failure_rate}/cold{cold_start_s}/{alloc}",
                    last.wall_time_s * 1e6,
                    f"improvement_pct={imp:.2f};mem_mb={last.lambda_memory_mb};"
                    f"retries={row['retries']};cold_starts={row['cold_starts']};"
                    f"cost_usd={last.cost_usd:.6f}",
                )

    def pick(fr, cs, al):
        return next(
            r for r in rows
            if r["failure_rate"] == fr and r["cold_start_s"] == cs
            and r["allocation"] == al
        )

    ideal = pick(0.0, 0.0, "static")
    faulty = pick(0.2, 2.5, "static")
    dyn = pick(0.2, 2.5, "latency")
    claims = {
        # faults erode but don't erase the paper's speedup claim
        "speedup_degrades_with_faults": faulty["improvement_pct"]
        < ideal["improvement_pct"],
        "speedup_survives_faults": faulty["improvement_pct"] > 50.0,
        # dynamic allocation measurably changes accounted wall-time vs static
        "dynamic_allocation_faster_than_static": dyn["wall_s_last_epoch"]
        < 0.9 * faulty["wall_s_last_epoch"],
        "dynamic_allocation_costs_more": dyn["cost_usd_per_epoch"]
        > faulty["cost_usd_per_epoch"],
        # warm pools amortize cold starts after epoch 0
        "warm_epochs_faster_than_cold": pick(0.0, 2.5, "static")[
            "wall_s_last_epoch"
        ]
        < pick(0.0, 2.5, "static")["wall_s_first_epoch"],
    }
    record(
        "fig7/claim:faults_coldstart",
        0.0,
        ";".join(f"{k}={v}" for k, v in claims.items())
        + f";holds={all(claims.values())}",
    )

    with open(BENCH_JSON, "w") as f:
        json.dump(
            {
                "bench": "fig7_faults_coldstart",
                "quick": quick,
                "seed": seed,
                "num_batches": m,
                "epochs": epochs,
                "instance_wall_s": instance_wall,
                "rows": rows,
                "claims": claims,
            },
            f,
            indent=2,
        )
    record("fig7/json", 0.0, f"path={os.path.relpath(BENCH_JSON)}")
    return claims


if __name__ == "__main__":
    run()
