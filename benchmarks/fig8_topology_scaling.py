"""Fig. 8 (beyond-paper) — overlay-topology scaling: wire bytes, simulated
exchange wall-time, and sync convergence across peer graphs.

The paper's scalability concern is communication overhead as the peer
count grows; the seed repo hard-coded the worst case (full mesh: every
peer moves ``(P-1) x payload`` per step). With the PeerGraph registry the
overlay is a knob, so this benchmark sweeps P x {full, ring, gossip:3}
and reports:

  * per-peer wire bytes per step (per-edge payload x degree) — full mesh
    grows O(P), ring stays O(1), gossip stays O(k);
  * simulated per-step exchange wall-time on a 1 Gb/s link (publish +
    degree-many downloads, the same charging ``LocalP2PCluster`` applies);
  * overlay diagnostics (degree, spectral gap — the decentralized-SGD
    consensus rate);
  * sync-convergence loss at small P: a real ``LocalP2PCluster`` run per
    graph, Metropolis–Hastings mixing against the full-mesh mean.

Emits one BENCH_fig8_topology_scaling.json record (rows + claims) so the
perf trajectory accumulates across PRs.
"""
from __future__ import annotations

import json
import os

import jax.numpy as jnp

from repro.core.events import LinkModel
from repro.core.exchange import ExchangeContext, get_exchange
from repro.core.graph import get_graph

from benchmarks.common import record, small_mnist

BENCH_JSON = os.path.join(
    os.path.dirname(__file__), "..", "BENCH_fig8_topology_scaling.json"
)

GRAPHS = ("full", "ring", "gossip:3")
BANDWIDTH = 1e9


def _wire_rows(peer_counts, grads_like, seed: int = 0):
    proto = get_exchange("allgather_mean")
    link = LinkModel(bandwidth_bps=BANDWIDTH)
    rows = []
    for P in peer_counts:
        for spec in GRAPHS:
            g = get_graph(spec, P, seed=seed)
            ctx = ExchangeContext(
                num_peers=P,
                graph=g,
                mixing=None if g.is_full else g.mixing_matrix(),
            )
            per_edge = proto.wire_bytes_per_edge(grads_like, ctx)
            total = proto.wire_bytes(grads_like, ctx)
            # same per_edge x degree convention as the byte column, so
            # sim_exchange_wall_s == wire_bytes_per_peer_step * 8 / bw
            sim_wall = link.transfer_s(per_edge) * ctx.degree
            rows.append(
                {
                    "num_peers": P,
                    "graph": spec,
                    "degree": ctx.degree,
                    "spectral_gap": g.spectral_gap(),
                    "bytes_per_edge": per_edge,
                    "wire_bytes_per_peer_step": total,
                    "sim_exchange_wall_s": sim_wall,
                }
            )
            record(
                f"fig8/P{P}/{spec}",
                sim_wall * 1e6,
                f"wire_bytes={total};degree={ctx.degree:g};"
                f"spectral_gap={g.spectral_gap():.3f}",
            )
    return rows


def _convergence_rows(num_peers: int, epochs: int, seed: int = 0):
    from repro.configs import get_config
    from repro.core import LocalP2PCluster
    from repro.optim import sgd

    cfg = get_config("squeezenet1.1")
    rows = []
    for spec in GRAPHS:
        cluster = LocalP2PCluster(
            cfg,
            small_mnist(size=256, hw=8),
            num_peers=num_peers,
            batch_size=8,
            batches_per_epoch=1,
            optimizer=sgd(momentum=0.9),
            lr=0.05,
            sync=True,
            graph=spec,
            seed=seed,
        )
        history = cluster.run(epochs=epochs)
        last = history[-1]
        rows.append(
            {
                "graph": spec,
                "num_peers": num_peers,
                "epochs": len(history),
                "final_loss": last["loss"],
                "final_val_acc": last.get("val_acc", float("nan")),
                "comm_bytes_sent_peer0": cluster.peers[0].comm_bytes_sent,
            }
        )
        record(
            f"fig8/converge/{spec}",
            0.0,
            f"loss={last['loss']:.4f};val_acc={last.get('val_acc', 0.0):.3f}",
        )
    return rows


def run(quick: bool = True, seed: int = 0):
    peer_counts = (4, 8, 16, 32) if quick else (4, 8, 16, 32, 64, 128)
    grads_like = {
        "w": jnp.zeros((256, 256), jnp.float32),
        "b": jnp.zeros((4096,), jnp.float32),
    }
    wire = _wire_rows(peer_counts, grads_like, seed=seed)
    # P=6 is the smallest count where gossip:3 is genuinely sparse (at
    # P=4 it degenerates to the complete graph and would test nothing)
    conv = _convergence_rows(num_peers=6, epochs=2 if quick else 6, seed=seed)

    def pick(P, spec):
        return next(
            r for r in wire if r["num_peers"] == P and r["graph"] == spec
        )

    lo, hi = peer_counts[0], peer_counts[-1]
    full_growth = (
        pick(hi, "full")["wire_bytes_per_peer_step"]
        / pick(lo, "full")["wire_bytes_per_peer_step"]
    )
    ring_growth = (
        pick(hi, "ring")["wire_bytes_per_peer_step"]
        / pick(lo, "ring")["wire_bytes_per_peer_step"]
    )
    gossip_growth = (
        pick(hi, "gossip:3")["wire_bytes_per_peer_step"]
        / pick(lo, "gossip:3")["wire_bytes_per_peer_step"]
    )
    loss = {r["graph"]: r["final_loss"] for r in conv}
    claims = {
        # full mesh per-peer traffic grows ~linearly in P...
        "full_mesh_grows_with_P": full_growth > (hi - 1) / (lo - 1) * 0.9,
        # ...while sparse overlays stay O(degree), independent of P
        "ring_bytes_flat_in_P": ring_growth < 1.5,
        "gossip_bytes_flat_in_P": gossip_growth < 2.0,
        "sparse_cheaper_than_full_at_scale": (
            pick(hi, "ring")["wire_bytes_per_peer_step"]
            < 0.2 * pick(hi, "full")["wire_bytes_per_peer_step"]
        ),
        # denser graphs mix faster: full's one-shot consensus tops the gap
        "full_has_best_spectral_gap": pick(hi, "full")["spectral_gap"]
        >= max(pick(hi, s)["spectral_gap"] for s in GRAPHS),
        # MH mixing still trains: sparse-graph loss lands near the full mean
        "sync_convergence_comparable": all(
            v == v and v < loss["full"] * 1.5 + 0.5 for v in loss.values()
        ),
    }
    record(
        "fig8/claim:topology_scaling",
        0.0,
        ";".join(f"{k}={v}" for k, v in claims.items())
        + f";holds={all(claims.values())}",
    )
    with open(BENCH_JSON, "w") as f:
        json.dump(
            {
                "bench": "fig8_topology_scaling",
                "quick": quick,
                "seed": seed,
                "peer_counts": list(peer_counts),
                "graphs": list(GRAPHS),
                "bandwidth_bps": BANDWIDTH,
                "wire_rows": wire,
                "convergence_rows": conv,
                "claims": claims,
            },
            f,
            indent=2,
        )
    record("fig8/json", 0.0, f"path={os.path.relpath(BENCH_JSON)}")
    return claims


if __name__ == "__main__":
    run()
