"""Fig. 9 (beyond-paper) — sharded gradient aggregation: ShardPlan +
reduce_scatter vs the legacy whole-pytree exchange.

Every dense protocol ships the ENTIRE gradient across each edge and
reduces it monolithically on every peer: per-peer aggregation work and
per-edge payload are O(model) regardless of peer count. The sharded
exchange (``reduce_scatter``, SPIRT / LambdaML style) makes shards the
unit of exchange and aggregation: the per-edge payload is one shard
(``model / P``) and the aggregation stage becomes P parallel serverless
aggregator invocations, each reducing only its shard, with Lambda memory
sized from SHARD bytes.

This benchmark sweeps P x {allgather_mean, reduce_scatter} and reports:

  * per-edge wire bytes — sharded shrinks ~1/P, legacy stays flat;
  * per-peer per-step totals (scatter+gather for sharded, degree-scaled
    for legacy) — sharded stays ~2x model while legacy grows O(P);
  * the aggregation stage priced on the ServerlessRuntime event engine
    (``ServerlessExecutor.simulate_aggregation``): a fixed count of m
    contributed gradients reduced by 1 monolithic aggregator (legacy) vs
    P parallel shard aggregators (sharded) — wall-time ~1/P vs flat —
    plus the aggregator memory tier, sized from shard bytes;
  * a real LocalP2PCluster equivalence run: reduce_scatter final params
    match allgather_mean to <= 1e-6 on the full graph.

Emits one BENCH_fig9_sharded_aggregation.json record (rows + claims) so
the perf trajectory accumulates across PRs.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp

from repro.core.exchange import ExchangeContext, get_exchange
from repro.core.serverless import ServerlessExecutor
from repro.core.shard import ShardPlan

from benchmarks.common import record, small_mnist

BENCH_JSON = os.path.join(
    os.path.dirname(__file__), "..", "BENCH_fig9_sharded_aggregation.json"
)

PROTOCOLS = ("allgather_mean", "reduce_scatter")
CONTRIBUTIONS = 8  # m gradients reduced per aggregation, fixed across P
REDUCE_BPS = 2e9  # instance-side reduce throughput (bytes/s), synthetic


def _grads_like():
    # ~16 MB fp32 so the aggregation exec time dominates simulated overheads
    return {
        "w": jnp.zeros((2048, 2048), jnp.float32),
        "b": jnp.zeros((16384,), jnp.float32),
    }


def _agg_executor() -> ServerlessExecutor:
    # ideal runtime, zero fixed overheads: isolates the scaling law
    return ServerlessExecutor(
        backend="serverless", invoke_overhead_s=0.0, orchestration_overhead_s=0.0
    )


def _rows(peer_counts, grads_like):
    model_bytes = sum(
        x.size * x.dtype.itemsize for x in jax.tree.leaves(grads_like)
    )
    rows = []
    for P in peer_counts:
        plan = ShardPlan.for_tree(grads_like, P)
        for name in PROTOCOLS:
            proto = get_exchange(name)
            ctx = ExchangeContext(num_peers=P)
            per_edge = proto.wire_bytes_per_edge(grads_like, ctx)
            total = proto.wire_bytes(grads_like, ctx)
            # aggregation stage on the event engine: m contributed
            # gradients, reduced by P parallel shard aggregators (sharded)
            # or 1 monolithic aggregator (legacy)
            unit = plan.shard_bytes() if proto.sharded else model_bytes
            n_agg = plan.num_shards if proto.sharded else 1
            t_reduce = CONTRIBUTIONS * unit / REDUCE_BPS
            rep = _agg_executor().simulate_aggregation(
                [t_reduce] * n_agg,
                shard_bytes=unit,
                num_contributions=CONTRIBUTIONS,
                epoch=0,
                peer=f"fig9-{name}-P{P}",
            )
            rows.append(
                {
                    "num_peers": P,
                    "protocol": name,
                    "bytes_per_edge": per_edge,
                    "wire_bytes_per_peer_step": total,
                    "num_aggregators": n_agg,
                    "aggregator_memory_mb": rep.lambda_memory_mb,
                    "agg_wall_s": rep.wall_time_s,
                    "agg_measured_s": rep.measured_compute_s,
                    "agg_cost_usd": rep.cost_usd,
                }
            )
            record(
                f"fig9/P{P}/{name}",
                rep.wall_time_s * 1e6,
                f"bytes_per_edge={per_edge};aggregators={n_agg};"
                f"mem_mb={rep.lambda_memory_mb}",
            )
    return rows


def _equivalence_err(num_peers: int, seed: int = 0) -> float:
    """reduce_scatter vs allgather_mean on a real host cluster (full graph)."""
    from repro.configs import get_config
    from repro.core import LocalP2PCluster
    from repro.optim import sgd

    cfg = get_config("squeezenet1.1")

    def run(exchange):
        cluster = LocalP2PCluster(
            cfg,
            small_mnist(size=128, hw=8),
            num_peers=num_peers,
            batch_size=8,
            batches_per_epoch=1,
            optimizer=sgd(momentum=0.9),
            lr=0.05,
            sync=True,
            exchange=exchange,
            seed=seed,
        )
        cluster.run_epoch_sync(0)
        return cluster.peers[0].params

    ref, shd = run("allgather_mean"), run("reduce_scatter")
    return max(
        float(jnp.abs(a - b).max())
        for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(shd))
    )


def run(quick: bool = True, seed: int = 0):
    peer_counts = (4, 8, 16, 32) if quick else (4, 8, 16, 32, 64, 128)
    grads_like = _grads_like()
    rows = _rows(peer_counts, grads_like)

    def pick(P, name):
        return next(
            r for r in rows if r["num_peers"] == P and r["protocol"] == name
        )

    lo, hi = peer_counts[0], peer_counts[-1]
    ideal = lo / hi  # the ~1/P scaling target between the sweep endpoints
    sh_edge = pick(hi, "reduce_scatter")["bytes_per_edge"] / pick(lo, "reduce_scatter")["bytes_per_edge"]
    lg_edge = pick(hi, "allgather_mean")["bytes_per_edge"] / pick(lo, "allgather_mean")["bytes_per_edge"]
    sh_agg = pick(hi, "reduce_scatter")["agg_wall_s"] / pick(lo, "reduce_scatter")["agg_wall_s"]
    lg_agg = pick(hi, "allgather_mean")["agg_wall_s"] / pick(lo, "allgather_mean")["agg_wall_s"]
    err = _equivalence_err(num_peers=4, seed=seed)
    claims = {
        # shards shrink the per-edge payload as ~1/P (padding adds slack)...
        "sharded_edge_bytes_inverse_P": sh_edge < 2.0 * ideal,
        # ...and the parallel aggregators cut wall-time as ~1/P (memory-
        # proportional Lambda vCPU adds slack: smaller shards -> smaller
        # tier -> slightly slower per element)
        "sharded_agg_wall_inverse_P": sh_agg < 3.0 * ideal,
        # while the legacy whole-pytree protocol stays flat on both axes
        "legacy_edge_bytes_flat": 0.99 <= lg_edge <= 1.01,
        "legacy_agg_wall_flat": 0.8 <= lg_agg <= 1.25,
        # total per-peer traffic: ~2x model (sharded) vs (P-1)x model
        "sharded_total_wire_cheaper_at_scale": (
            pick(hi, "reduce_scatter")["wire_bytes_per_peer_step"]
            < 0.2 * pick(hi, "allgather_mean")["wire_bytes_per_peer_step"]
        ),
        # aggregator memory is sized from shard bytes, not model bytes
        "aggregator_memory_shrinks_with_shards": (
            pick(hi, "reduce_scatter")["aggregator_memory_mb"]
            <= pick(lo, "reduce_scatter")["aggregator_memory_mb"]
        ),
        # the safety rail: sharded mean == legacy mean on the full graph
        "sharded_equivalent_to_mean": err <= 1e-6,
    }
    record(
        "fig9/claim:sharded_aggregation",
        0.0,
        ";".join(f"{k}={v}" for k, v in claims.items())
        + f";equiv_err={err:.2e};holds={all(claims.values())}",
    )
    with open(BENCH_JSON, "w") as f:
        json.dump(
            {
                "bench": "fig9_sharded_aggregation",
                "quick": quick,
                "seed": seed,
                "peer_counts": list(peer_counts),
                "protocols": list(PROTOCOLS),
                "contributions": CONTRIBUTIONS,
                "reduce_bps": REDUCE_BPS,
                "rows": rows,
                "equivalence_max_abs_err": err,
                "claims": claims,
            },
            f,
            indent=2,
        )
    record("fig9/json", 0.0, f"path={os.path.relpath(BENCH_JSON)}")
    return claims


if __name__ == "__main__":
    run()
