"""Roofline report — formats the dry-run JSON (launch/dryrun.py --json)
into the EXPERIMENTS.md §Roofline table. Does not require 512 devices:
reads the recorded artifacts.
"""
from __future__ import annotations

import json
import os

from benchmarks.common import record

DEFAULT_JSON = os.path.join(os.path.dirname(__file__), "..", "dryrun_singlepod.json")


def run(quick: bool = True, path: str = DEFAULT_JSON, seed: int = 0):
    if not os.path.exists(path):
        record("roofline/missing", 0.0, f"run launch/dryrun.py --all --json {path}")
        return []
    with open(path) as f:
        recs = json.load(f)
    for r in recs:
        if "skipped" in r:
            record(f"roofline/{r['arch']}/{r['shape']}", 0.0, f"skipped:{r['skipped']}")
            continue
        terms = r["terms_s"]
        record(
            f"roofline/{r['arch']}/{r['shape']}",
            terms[r["dominant"]] * 1e6,
            f"compute_s={terms['compute']:.3e};memory_s={terms['memory']:.3e};"
            f"collective_s={terms['collective']:.3e};dominant={r['dominant']};"
            f"useful={r['useful_flops_ratio']:.2f}",
        )
    return recs


if __name__ == "__main__":
    run()
