"""Benchmark runner — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only fig3,...]

Prints ``name,us_per_call,derived`` CSV rows (benchmarks.common.record).
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale settings")
    ap.add_argument("--only", default=None, help="comma-separated module keys")
    ap.add_argument("--seed", type=int, default=0,
                    help="base seed, recorded in every BENCH_*.json")
    args = ap.parse_args()

    from benchmarks import (
        fig3_serverless_speedup,
        fig4_scaling,
        fig5_compression,
        fig6_sync_async,
        fig7_faults_coldstart,
        fig8_topology_scaling,
        fig9_sharded_aggregation,
        fig10_cost_time_frontier,
        fig11_engine_scaling,
        fig12_byzantine,
        fig13_fused_compression,
        fig14_auto_scheduler,
        roofline,
        table1_resource_stages,
        table2_3_cost,
    )
    from benchmarks.common import csv_header, record

    suites = {
        "table1": table1_resource_stages,
        "fig3": fig3_serverless_speedup,
        "table2_3": table2_3_cost,
        "fig4": fig4_scaling,
        "fig5": fig5_compression,
        "fig6": fig6_sync_async,
        "fig7": fig7_faults_coldstart,
        "fig8": fig8_topology_scaling,
        "fig9": fig9_sharded_aggregation,
        "fig10": fig10_cost_time_frontier,
        "fig11": fig11_engine_scaling,
        "fig12": fig12_byzantine,
        "fig13": fig13_fused_compression,
        "fig14": fig14_auto_scheduler,
        "roofline": roofline,
    }
    if args.only:
        keys = args.only.split(",")
        suites = {k: v for k, v in suites.items() if k in keys}

    csv_header()
    failures = []
    for name, mod in suites.items():
        t0 = time.time()
        try:
            mod.run(quick=not args.full, seed=args.seed)
            record(f"suite/{name}", (time.time() - t0) * 1e6, "status=ok")
        except Exception as e:  # pragma: no cover
            failures.append(name)
            traceback.print_exc()
            record(f"suite/{name}", (time.time() - t0) * 1e6, f"status=FAILED:{e!r}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
