"""Table I — per-stage resource usage in P2P training with 4 workers.

Reproduces the experiment: 4 peers train SqueezeNet1.1 / MobileNetV3-Small /
VGG-11 on MNIST- and CIFAR-shaped data; CPU %, memory and processing time
are recorded per stage (compute gradients / send / receive / model update /
convergence detection) and averaged over epochs.

Validated claim: *compute gradients dominates processing time* (the paper's
basis for offloading exactly that stage to Lambda).
"""
from __future__ import annotations

from repro.configs import get_config
from repro.core import LocalP2PCluster
from repro.data import make_dataset
from repro.optim import sgd

from benchmarks.common import record


def run(quick: bool = True, seed: int = 0):
    models_ = ["squeezenet1.1", "mobilenet-v3-small"] + ([] if quick else ["vgg11"])
    datasets = {
        "mnist": make_dataset("mnist", size=256, image_hw=12 if quick else 28, channels=1),
        "cifar": make_dataset("cifar", size=256, image_hw=12 if quick else 32, channels=3),
    }
    epochs = 2 if quick else 4
    ok = True
    for mname in models_:
        for dname, ds in datasets.items():
            cl = LocalP2PCluster(
                get_config(mname), ds,
                num_peers=2 if quick else 4,
                batch_size=16,
                batches_per_epoch=2 if quick else 30,
                optimizer=sgd(momentum=0.9), lr=0.01, sync=True, seed=seed,
            )
            cl.run(epochs, eval_every=1)
            t = cl.peers[0].metrics.table()
            for stage, row in t.items():
                record(
                    f"table1/{mname}/{dname}/{stage}",
                    row["time_s"] * 1e6,
                    f"cpu%={row['cpu_percent']};mem_mb={row['memory_mb']}",
                )
            times = {s: r["time_s"] for s, r in t.items()}
            dominant = max(times, key=times.get)
            ok &= dominant == "compute_gradients"
    record("table1/claim:compute_gradients_dominates", 0.0, f"holds={ok}")
    return ok


if __name__ == "__main__":
    run()
