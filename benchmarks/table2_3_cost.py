"""Tables II & III — cost of gradient computation: serverless vs instances.

Two parts:
1. *Paper validation*: plug the paper's measured inputs (batch counts,
   Lambda memory sizes, compute times) into cost formulas (1) and (2) and
   check we reproduce their dollar figures, including the 5.34x headline.
2. *Our workload*: cost the CNN gradient epoch measured by the executor on
   this container under both backends.
"""
from __future__ import annotations

from repro.configs import get_config
from repro.core import LocalP2PCluster, ServerlessExecutor
from repro.core.cost import (
    InstanceCost,
    ServerlessCost,
    paper_table2_row,
    paper_table3_row,
)
from repro.data import make_dataset
from repro.optim import sgd

from benchmarks.common import record, small_mnist

PAPER_TABLE2_TOTALS = {1024: 0.03567, 512: 0.03069, 128: 0.03451, 64: 0.05435}
PAPER_TABLE3_TOTALS = {1024: 0.00665, 512: 0.00717, 128: 0.00851, 64: 0.01017}


def run(quick: bool = True, seed: int = 0):
    max_rel_err = 0.0
    for batch in (1024, 512, 128, 64):
        r2 = paper_table2_row(batch)
        ours_s = ServerlessCost(
            compute_time_s=r2["compute_time_s"],
            num_batches=r2["num_batches"],
            lambda_memory_mb=r2["lambda_memory_mb"],
            instance="t2.small",
        ).cost_per_peer
        r3 = paper_table3_row(batch)
        ours_i = InstanceCost(r3["compute_time_s"], "t2.large").cost_per_peer
        e2 = abs(ours_s - PAPER_TABLE2_TOTALS[batch]) / PAPER_TABLE2_TOTALS[batch]
        e3 = abs(ours_i - PAPER_TABLE3_TOTALS[batch]) / PAPER_TABLE3_TOTALS[batch]
        max_rel_err = max(max_rel_err, e2, e3)
        record(
            f"table2_3/paper_batch{batch}",
            r2["compute_time_s"] * 1e6,
            f"serverless_usd={ours_s:.5f};instance_usd={ours_i:.5f};"
            f"ratio={ours_s/ours_i:.2f};rel_err={max(e2,e3)*100:.1f}%",
        )
    ratio_1024 = (
        ServerlessCost(41.2, 15, 4400, "t2.small").cost_per_peer
        / InstanceCost(258.0, "t2.large").cost_per_peer
    )
    record(
        "table2_3/claim:cost_ratio", 0.0,
        f"ratio={ratio_1024:.2f};paper=5.34;max_rel_err={max_rel_err*100:.1f}%",
    )

    # our measured workload
    ds = small_mnist(size=256)
    for backend in ("instance", "serverless"):
        cl = LocalP2PCluster(
            get_config("squeezenet1.1"), ds, num_peers=2, batch_size=16,
            batches_per_epoch=2 if quick else 8,
            optimizer=sgd(momentum=0.9), lr=0.01,
            executor=ServerlessExecutor(backend=backend),
        )
        cl.run_epoch_sync(0)
        r = cl.peers[0].reports[0]
        record(
            f"table2_3/measured_{backend}",
            r.wall_time_s * 1e6,
            f"cost_usd={r.cost_usd:.6f};lambda_mb={r.lambda_memory_mb}",
        )
    return max_rel_err


if __name__ == "__main__":
    run()
