"""Cost explorer: when is serverless P2P worth it?

Sweeps the paper's trade-off space — batch size, number of Lambda
invocations, memory sizing — and prints the serverless-vs-instance cost and
time Pareto, using the unified CostReport frontier API throughout: the
paper's own Table II/III points, the engine-priced instance baseline
(boot, idle billing, memory-constrained splitting), and the TPU
chip-second equivalent of the same trade-off.

    PYTHONPATH=src python examples/cost_explorer.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.cost import (
    CommCost,
    CostReport,
    InstanceCost,
    ServerlessCost,
    TPUCost,
    compare_backends,
    pareto_frontier,
    paper_table2_row,
    paper_table3_row,
)
from repro.core.events import InstanceConfig, RuntimeConfig, available_allocations
from repro.core.exchange import ExchangeContext, available_exchanges, get_exchange
from repro.core.serverless import ServerlessExecutor, ServerlessPlanner


def main():
    print("=== Paper Tables II/III (VGG11 / MNIST / 4 peers), via CostReport ===")
    print(f"{'batch':>6} {'serverless $':>13} {'instance $':>11} {'multiple':>8} "
          f"{'t_serverless':>12} {'t_instance':>11} {'speedup':>8}")
    for b in (1024, 512, 128, 64):
        r2, r3 = paper_table2_row(b), paper_table3_row(b)
        s = CostReport(
            "serverless", r2["compute_time_s"],
            ServerlessCost(r2["compute_time_s"], r2["num_batches"],
                           r2["lambda_memory_mb"], "t2.small").cost_per_peer,
            label=f"batch{b}",
        )
        i = CostReport(
            "instance", r3["compute_time_s"],
            InstanceCost(r3["compute_time_s"], "t2.large").cost_per_peer,
            instance="t2.large", label=f"batch{b}",
        )
        cmp = compare_backends(s, i)
        print(f"{b:>6} {s.cost_usd:>13.5f} {i.cost_usd:>11.5f} "
              f"{cmp['cost_multiple']:>7.2f}x "
              f"{s.wall_time_s:>11.1f}s {i.wall_time_s:>10.1f}s "
              f"{cmp['speedup_pct']:>7.1f}%")

    print("\n=== Planner: Lambda sizing vs model size (batch 4 MB) ===")
    planner = ServerlessPlanner()
    for mb in (5, 50, 500, 2000, 4000):
        mem = planner.lambda_memory_mb(int(mb * 1e6), int(4e6))
        print(f"model {mb:>5} MB  ->  lambda {mem:>6} MB "
              f"({mem/1769:.2f} vCPU)")

    print("\n=== Exchange wire cost: VGG11-sized gradient, 4 peers, 1 Gb/s ===")
    import jax
    import jax.numpy as jnp

    # shapes only — byte accounting never materializes the gradient
    grads_like = {"vgg11": jax.ShapeDtypeStruct((132_863_336,), jnp.float32)}
    ctx = ExchangeContext(num_peers=4, topk_frac=0.01)
    for name in available_exchanges():
        cc = CommCost(
            wire_bytes_per_step=get_exchange(name).wire_bytes(grads_like, ctx),
            bandwidth_bps=1e9, usd_per_gb_egress=0.09,  # AWS inter-AZ-ish
        )
        print(f"{name:16s} {cc.wire_bytes_per_step/1e6:>8.1f} MB/step "
              f"{cc.seconds_per_step:>7.2f} s/step  ${cc.usd_per_step:.4f}/step egress")

    print("\n=== Runtime engine: faults, cold starts, allocation policies ===")
    # 30 one-second batches on a 50 MB model, 4 epochs per scenario
    per_batch = [1.0 + 0.02 * i for i in range(30)]
    for label, runtime, alloc in (
        ("ideal / static", RuntimeConfig(), "static"),
        ("aws / static", RuntimeConfig.aws_default(), "static"),
        ("aws / latency", RuntimeConfig.aws_default(), "latency"),
    ):
        ex = ServerlessExecutor(runtime=runtime, allocation=alloc)
        rep = None
        for epoch in range(4):
            rep = ex.simulate(per_batch, model_bytes=int(50e6),
                              batch_bytes=int(4e6), epoch=epoch)
        print(f"{label:16s} epoch3: {rep.lambda_memory_mb:>5}MB "
              f"wall={rep.wall_time_s:6.2f}s cold={rep.num_cold_starts} "
              f"retries={rep.num_retries} ${rep.cost_usd:.6f}/peer/epoch")
    print(f"(allocation policies registered: {', '.join(available_allocations())})")

    print("\n=== Engine-priced instance baseline + the cost-time frontier ===")
    # the same 30 batches, sequentially, across EC2 tiers (boot 40 s billed;
    # a VGG11-scale model + large batch splits on the small tier)
    model_bytes, batch_bytes = int(531e6), int(160e6)
    sex = ServerlessExecutor(instance="t2.small", instance_vcpus=1.0)
    srep = sex.simulate(per_batch, model_bytes=model_bytes,
                        batch_bytes=batch_bytes)
    points = [srep.cost_report(label="serverless")]
    for tier in ("t2.small", "t2.medium", "t2.large"):
        iex = ServerlessExecutor(
            backend="instance", instance=tier,
            instance_config=InstanceConfig(boot_s=40.0),
        )
        irep = iex.simulate_instance(
            per_batch, model_bytes=model_bytes, batch_bytes=batch_bytes,
            reference_vcpus=1.0,
        )
        points.append(irep.cost_report(label=tier))
        cmp = compare_backends(points[0], points[-1])
        print(f"{tier:10s} wall={irep.wall_time_s:7.2f}s "
              f"(boot={irep.boot_s:.0f}s splits={irep.num_splits}) "
              f"${irep.cost_usd:.6f}  ->  serverless "
              f"{cmp['speedup_pct']:.2f}% faster at "
              f"{cmp['cost_multiple']:.2f}x the cost")
    print("frontier (non-dominated wall/cost points):")
    for p in pareto_frontier(points):
        print(f"  {p.label:12s} {p.summary()}")

    print("\n=== TPU equivalent: cost/step of the serverless-P2P train step ===")
    # Using the roofline collective-bound estimate for qwen2.5-3b train_4k:
    # paper-faithful exchange ~8.4 s/step vs psum exchange ~1.1 s/step.
    for name, t in (("allgather_mean (paper-faithful)", 8.4),
                    ("psum/reduce-scatter (optimized)", 1.1)):
        c = TPUCost(step_time_s=t, chips=256)
        print(f"{name:36s} {t:>5.1f} s/step  ${c.cost_per_step:.3f}/step "
              f"(${c.cost_per_step*1000:.0f}/1k steps)")


if __name__ == "__main__":
    main()
