"""Cost explorer: when is serverless P2P worth it?

Sweeps the paper's trade-off space — batch size, number of Lambda
invocations, memory sizing — and prints the serverless-vs-instance cost and
time Pareto, including the paper's own Table II/III points and the TPU
chip-second equivalent of the same trade-off.

    PYTHONPATH=src python examples/cost_explorer.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.cost import (
    CommCost,
    InstanceCost,
    ServerlessCost,
    TPUCost,
    paper_table2_row,
    paper_table3_row,
)
from repro.core.events import RuntimeConfig, available_allocations
from repro.core.exchange import ExchangeContext, available_exchanges, get_exchange
from repro.core.serverless import ServerlessExecutor, ServerlessPlanner


def main():
    print("=== Paper Tables II/III (VGG11 / MNIST / 4 peers) ===")
    print(f"{'batch':>6} {'serverless $':>13} {'instance $':>11} {'ratio':>6} "
          f"{'t_serverless':>12} {'t_instance':>11} {'speedup':>8}")
    for b in (1024, 512, 128, 64):
        r2, r3 = paper_table2_row(b), paper_table3_row(b)
        s = ServerlessCost(r2["compute_time_s"], r2["num_batches"],
                           r2["lambda_memory_mb"], "t2.small")
        i = InstanceCost(r3["compute_time_s"], "t2.large")
        print(f"{b:>6} {s.cost_per_peer:>13.5f} {i.cost_per_peer:>11.5f} "
              f"{s.cost_per_peer/i.cost_per_peer:>6.2f} "
              f"{r2['compute_time_s']:>11.1f}s {r3['compute_time_s']:>10.1f}s "
              f"{r3['compute_time_s']/r2['compute_time_s']:>7.1f}x")

    print("\n=== Planner: Lambda sizing vs model size (batch 4 MB) ===")
    planner = ServerlessPlanner()
    for mb in (5, 50, 500, 2000, 4000):
        mem = planner.lambda_memory_mb(int(mb * 1e6), int(4e6))
        print(f"model {mb:>5} MB  ->  lambda {mem:>6} MB "
              f"({mem/1769:.2f} vCPU)")

    print("\n=== Exchange wire cost: VGG11-sized gradient, 4 peers, 1 Gb/s ===")
    import jax
    import jax.numpy as jnp

    # shapes only — byte accounting never materializes the gradient
    grads_like = {"vgg11": jax.ShapeDtypeStruct((132_863_336,), jnp.float32)}
    ctx = ExchangeContext(num_peers=4, topk_frac=0.01)
    for name in available_exchanges():
        cc = CommCost(
            wire_bytes_per_step=get_exchange(name).wire_bytes(grads_like, ctx),
            bandwidth_bps=1e9, usd_per_gb_egress=0.09,  # AWS inter-AZ-ish
        )
        print(f"{name:16s} {cc.wire_bytes_per_step/1e6:>8.1f} MB/step "
              f"{cc.seconds_per_step:>7.2f} s/step  ${cc.usd_per_step:.4f}/step egress")

    print("\n=== Runtime engine: faults, cold starts, allocation policies ===")
    # 30 one-second batches on a 50 MB model, 4 epochs per scenario
    per_batch = [1.0 + 0.02 * i for i in range(30)]
    for label, runtime, alloc in (
        ("ideal / static", RuntimeConfig(), "static"),
        ("aws / static", RuntimeConfig.aws_default(), "static"),
        ("aws / latency", RuntimeConfig.aws_default(), "latency"),
    ):
        ex = ServerlessExecutor(runtime=runtime, allocation=alloc)
        rep = None
        for epoch in range(4):
            rep = ex.simulate(per_batch, model_bytes=int(50e6),
                              batch_bytes=int(4e6), epoch=epoch)
        print(f"{label:16s} epoch3: {rep.lambda_memory_mb:>5}MB "
              f"wall={rep.wall_time_s:6.2f}s cold={rep.num_cold_starts} "
              f"retries={rep.num_retries} ${rep.cost_usd:.6f}/peer/epoch")
    print(f"(allocation policies registered: {', '.join(available_allocations())})")

    print("\n=== TPU equivalent: cost/step of the serverless-P2P train step ===")
    # Using the roofline collective-bound estimate for qwen2.5-3b train_4k:
    # paper-faithful exchange ~8.4 s/step vs psum exchange ~1.1 s/step.
    for name, t in (("allgather_mean (paper-faithful)", 8.4),
                    ("psum/reduce-scatter (optimized)", 1.1)):
        c = TPUCost(step_time_s=t, chips=256)
        print(f"{name:36s} {t:>5.1f} s/step  ${c.cost_per_step:.3f}/step "
              f"(${c.cost_per_step*1000:.0f}/1k steps)")


if __name__ == "__main__":
    main()
