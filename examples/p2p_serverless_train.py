"""End-to-end driver: distributed P2P training of a ~100M-parameter LM for a
few hundred steps with the TPU-native serverless-P2P train step.

Peers = the `data` mesh axis (each holds a disjoint partition); the `model`
axis is the serverless lambda pool (micro-batch fan-out). On this CPU
container the mesh is 1x1 and the arch is a ~100M-param variant; on a TPU
slice the same code runs the full configs on the production mesh.

    PYTHONPATH=src python examples/p2p_serverless_train.py --steps 200
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs import get_config
from repro.core.compression import QSGDConfig
from repro.core.convergence import ConvergenceDetector
from repro.core.exchange import available_exchanges
from repro.core.p2p import Topology
from repro.data import BatchKey, DataLoader, Partitioner, make_dataset
from repro.launch.mesh import make_host_mesh
from repro.launch.sharding import activation_rules
from repro.configs.base import ShapeConfig
from repro.models.layers import axis_rules
from repro.optim import adam
from repro.optim.schedules import warmup_cosine
from repro.train import P2PTrainer


def hundred_m_config():
    """~100M-param decoder LM in the qwen2.5 family (107M params)."""
    base = get_config("qwen2.5-3b")
    return dataclasses.replace(
        base, name="qwen-100m", num_layers=10, d_model=640, num_heads=10,
        num_kv_heads=2, d_ff=2560, vocab_size=32_768, head_dim=64, remat=False,
        serve_window=0,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--exchange", default="qsgd",
                    choices=list(available_exchanges()))
    ap.add_argument("--checkpoint", default="/tmp/p2p_lm_ckpt")
    args = ap.parse_args()

    cfg = hundred_m_config()
    mesh = make_host_mesh()
    npeers = mesh.shape["data"]
    topo = Topology(
        peer_axes=("data",) if npeers > 1 else (),
        lambda_axis="model" if mesh.shape["model"] > 1 else None,
        exchange=args.exchange,
        qsgd=QSGDConfig(levels=127, bucket=2048),
        serverless=mesh.shape["model"] > 1,
        grad_clip=1.0,
    )
    opt = adam()
    sched = warmup_cosine(1e-3, 20, args.steps)
    trainer = P2PTrainer(cfg, opt, topo, mesh, sched)
    state = trainer.init_state(jax.random.PRNGKey(0))
    nparams = sum(x.size for x in jax.tree.leaves(state.params))
    print(f"model: {cfg.name} ({nparams/1e6:.1f}M params), "
          f"peers={npeers}, exchange={args.exchange}")
    if topo.peer_axes:
        print(f"wire: {trainer.comm_cost(state.params).summary()}")

    ds = make_dataset("lm", size=100_000, vocab_size=cfg.vocab_size, seq_len=args.seq)
    loader = DataLoader(Partitioner(ds, 1), 0, args.batch)
    detector = ConvergenceDetector(1e-3, mode="min", plateau_patience=5,
                                   stop_patience=20, max_epochs=10**6)

    rules = activation_rules(cfg, ShapeConfig("ex", args.seq, args.batch, "train"), mesh)
    t0 = time.time()
    with compat.set_mesh(mesh):
        with axis_rules(rules):
            for i in range(args.steps):
                b = loader.load(BatchKey(0, i // loader.num_batches, i % loader.num_batches))
                batch = {"tokens": jnp.asarray(b["tokens"]), "labels": jnp.asarray(b["labels"])}
                state, m = trainer.step(state, batch)
                if (i + 1) % 20 == 0 or i == 0:
                    ce = float(m["aux"])
                    dt = (time.time() - t0) / (i + 1)
                    toks = args.batch * args.seq / dt
                    print(f"step {i+1:4d}  ce={ce:.4f}  {dt*1e3:.0f} ms/step "
                          f"({toks:,.0f} tok/s)")
                    if detector.step(ce):
                        print("converged — early stop")
                        break
    trainer.save(args.checkpoint, state)
    print(f"checkpoint saved: {args.checkpoint}.npz")


if __name__ == "__main__":
    main()
