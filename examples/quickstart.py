"""Quickstart: the paper's system in ~60 lines.

Four peers train SqueezeNet on MNIST-shaped data with Algorithm 1 —
per-peer partitions, per-batch gradients offloaded to the serverless
executor, RabbitMQ-style mailbox exchange, convergence detection — then we
print the Table-I-style stage breakdown and the cost of both backends.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_config
from repro.core import (
    InstanceConfig,
    LocalP2PCluster,
    RuntimeConfig,
    ServerlessExecutor,
    compare_backends,
)
from repro.data import make_dataset
from repro.optim import sgd


def main():
    dataset = make_dataset("mnist", size=512, image_hw=12, channels=1)
    cluster = LocalP2PCluster(
        get_config("mobilenet-v3-small"),
        dataset,
        num_peers=4,
        batch_size=16,
        batches_per_epoch=2,
        optimizer=sgd(momentum=0.9),
        lr=0.05,
        sync=True,  # RabbitMQ barrier semantics
        exchange="allgather_mean",  # any name in repro.core.available_exchanges()
        graph="ring",  # peer overlay: full | ring | gossip:K | hierarchical
        executor=ServerlessExecutor(  # Lambda fan-out on the event engine
            backend="serverless",
            runtime=RuntimeConfig.aws_default(),  # cold starts, rare faults
            allocation="latency",  # dynamic per-epoch memory sizing
        ),
    )
    print(f"overlay: {cluster.graph.describe()}")
    print(f"exchange={cluster.protocol.name}: {cluster.comm_cost().summary()}")
    history = cluster.run(epochs=3)

    print("\n=== training history ===")
    for h in history:
        print(
            f"epoch {h['epoch']}: loss={h['loss']:.3f} "
            f"val_acc={h.get('val_acc', float('nan')):.3f}"
        )

    print("\n=== Table-I-style stage breakdown (peer 0) ===")
    for stage, row in cluster.peers[0].metrics.table().items():
        print(f"{stage:24s} time={row['time_s']:.3f}s cpu={row['cpu_percent']:.0f}% "
              f"mem={row['memory_mb']:.0f}MB")

    for rep in cluster.peers[0].reports:
        print(
            f"\nepoch {rep.epoch} serverless execution: {rep.num_batches} lambdas x "
            f"{rep.lambda_memory_mb}MB, wall {rep.wall_time_s:.2f}s "
            f"(sequential compute was {rep.measured_compute_s:.2f}s), "
            f"cold_starts={rep.num_cold_starts} retries={rep.num_retries} "
            f"cost ${rep.cost_usd:.6f}/peer/epoch"
        )

    # The paper's headline, for THIS workload: price the last measured epoch
    # under the instance baseline too (t2.large; ideal config — a steady-state
    # VM with its one-off boot long amortized) and compare.
    srep = cluster.peers[0].reports[-1]
    irep = ServerlessExecutor(
        backend="instance", instance="t2.large",
        instance_config=InstanceConfig.ideal(),
    ).simulate_instance(srep.per_batch_s)
    cmp = compare_backends(srep.cost_report(), irep.cost_report())
    rel = "faster" if cmp["speedup_pct"] >= 0 else "slower"
    print(
        f"\nserverless vs instance (t2.large): {abs(cmp['speedup_pct']):.1f}% "
        f"{rel} at {cmp['cost_multiple']:.2f}x the cost "
        f"(${cmp['serverless_usd']:.6f} vs ${cmp['instance_usd']:.6f} "
        f"per peer-epoch) — the fan-out wins as batches/peer grow "
        f"(paper, 235 batches: 97.34% faster at up to 5.4x)"
    )


if __name__ == "__main__":
    main()
