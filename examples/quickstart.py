"""Quickstart: the paper's system in ~60 lines.

Four peers train SqueezeNet on MNIST-shaped data with Algorithm 1 —
per-peer partitions, per-batch gradients offloaded to the serverless
executor, RabbitMQ-style mailbox exchange, convergence detection — then we
print the Table-I-style stage breakdown and the cost of both backends.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_config
from repro.core import LocalP2PCluster, RuntimeConfig, ServerlessExecutor
from repro.data import make_dataset
from repro.optim import sgd


def main():
    dataset = make_dataset("mnist", size=512, image_hw=12, channels=1)
    cluster = LocalP2PCluster(
        get_config("mobilenet-v3-small"),
        dataset,
        num_peers=4,
        batch_size=16,
        batches_per_epoch=2,
        optimizer=sgd(momentum=0.9),
        lr=0.05,
        sync=True,  # RabbitMQ barrier semantics
        exchange="allgather_mean",  # any name in repro.core.available_exchanges()
        graph="ring",  # peer overlay: full | ring | gossip:K | hierarchical
        executor=ServerlessExecutor(  # Lambda fan-out on the event engine
            backend="serverless",
            runtime=RuntimeConfig.aws_default(),  # cold starts, rare faults
            allocation="latency",  # dynamic per-epoch memory sizing
        ),
    )
    print(f"overlay: {cluster.graph.describe()}")
    print(f"exchange={cluster.protocol.name}: {cluster.comm_cost().summary()}")
    history = cluster.run(epochs=3)

    print("\n=== training history ===")
    for h in history:
        print(
            f"epoch {h['epoch']}: loss={h['loss']:.3f} "
            f"val_acc={h.get('val_acc', float('nan')):.3f}"
        )

    print("\n=== Table-I-style stage breakdown (peer 0) ===")
    for stage, row in cluster.peers[0].metrics.table().items():
        print(f"{stage:24s} time={row['time_s']:.3f}s cpu={row['cpu_percent']:.0f}% "
              f"mem={row['memory_mb']:.0f}MB")

    for rep in cluster.peers[0].reports:
        print(
            f"\nepoch {rep.epoch} serverless execution: {rep.num_batches} lambdas x "
            f"{rep.lambda_memory_mb}MB, wall {rep.wall_time_s:.2f}s "
            f"(sequential compute was {rep.measured_compute_s:.2f}s), "
            f"cold_starts={rep.num_cold_starts} retries={rep.num_retries} "
            f"cost ${rep.cost_usd:.6f}/peer/epoch"
        )


if __name__ == "__main__":
    main()
