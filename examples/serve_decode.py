"""Batched serving example: greedy decode with KV caches across families.

Serves three different architecture families (dense+SWA, SSM, hybrid) with
batched requests and reports per-family tokens/s — demonstrating that
`serve_step` covers attention caches, rolling windows and SSM states.

    PYTHONPATH=src python examples/serve_decode.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import models
from repro.configs import get_config, reduced


def serve(arch: str, batch: int = 4, gen: int = 48):
    cfg = reduced(get_config(arch), vocab_size=512)
    params = models.init_model(jax.random.PRNGKey(0), cfg)
    state = models.init_decode_state(cfg, batch, gen + 8)

    @jax.jit
    def step(params, state, tok):
        logits, state = models.decode_step(params, state, tok, cfg)
        return logits.argmax(-1)[:, None].astype(jnp.int32), state

    tok = jnp.ones((batch, 1), jnp.int32)
    tok, state = step(params, state, tok)  # compile
    t0 = time.time()
    outs = []
    for _ in range(gen):
        tok, state = step(params, state, tok)
        outs.append(np.asarray(tok)[:, 0])
    dt = time.time() - t0
    seqs = np.stack(outs, 1)
    print(f"{arch:15s} [{cfg.family:6s}] {batch} reqs x {gen} tokens: "
          f"{batch*gen/dt:7.1f} tok/s   sample: {seqs[0][:10].tolist()}")


def main():
    for arch in ("gemma2-2b", "mamba2-370m", "zamba2-1.2b"):
        serve(arch)


if __name__ == "__main__":
    main()
