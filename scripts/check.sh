#!/usr/bin/env bash
# Tier-1 verify + fast smoke subset.
#
#   bash scripts/check.sh          # full tier-1 suite, then smoke
#   bash scripts/check.sh --fast   # smoke only (registry + cost math, <1 min)
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

smoke() {
  echo "== smoke: exchange registry =="
  python -c "
from repro.core.exchange import available_exchanges, get_exchange, ExchangeContext
import jax.numpy as jnp
g = {'w': jnp.zeros((64, 64))}
for n in available_exchanges():
    print(f'  {n}: {get_exchange(n).wire_bytes(g, ExchangeContext(num_peers=4))} B/peer/step')
"
  echo "== smoke: peer graph registry =="
  python -c "
from repro.core.graph import available_graphs, get_graph
for n in available_graphs():
    if n == 'static':
        continue  # programmatic-only (needs an explicit adjacency)
    print(f'  {get_graph(n, 8, seed=0).describe()}')
"
  echo "== smoke: paper cost tables (Tables II/III) =="
  python -m benchmarks.run --only table2_3
  echo "== smoke: serverless runtime fault sweep (Fig. 7) =="
  python -m benchmarks.run --only fig7
  echo "== smoke: overlay topology scaling (Fig. 8) =="
  python -m benchmarks.run --only fig8
  echo "== smoke: sharded aggregation (Fig. 9) =="
  python -m benchmarks.run --only fig9
  echo "== smoke: cost-time frontier, serverless vs instance (Fig. 10) =="
  python -m benchmarks.run --only fig10
  echo "== smoke: engine scaling rails (Fig. 11) =="
  # fastest path through every mode (P<=1000) + the batched==scalar and
  # mixing_row==dense rails; the 1e5-peer sweep is
  # `python -m benchmarks.fig11_engine_scaling --full`
  python -m benchmarks.fig11_engine_scaling --smoke
  echo "== smoke: byzantine-robust aggregation rails (Fig. 12) =="
  # fast rails only (equivalence, wire accounting, adversary bookkeeping);
  # the full attack sweep is `python -m benchmarks.run --only fig12`
  python -m benchmarks.fig12_byzantine --smoke
  echo "== smoke: fused compressed exchange + EF rails (Fig. 13) =="
  # fast rails only (kernel==jnp equivalence, wire accounting, EF finite);
  # the full retention/timing run is `python -m benchmarks.run --only fig13`
  python -m benchmarks.fig13_fused_compression --smoke
  echo "== smoke: heterogeneous-fleet auto-scheduler rails (Fig. 14) =="
  # tiny workload, core candidate set: scheduler==exhaustive, deadline
  # never violated, mixed-fleet dominance, pure-fleet==PR5 <=1e-6
  python -m benchmarks.fig14_auto_scheduler --smoke
  echo "== smoke: analysis suite (lint + contracts + trace + links) =="
  # full four-pass suite, JSON report artifact for CI; the trace pass
  # double-runs the seeded simulators and asserts identical digests
  python -m repro.analysis --fail-on=error --json ANALYSIS_REPORT.json
}

if [[ "${1:-}" == "--fast" ]]; then
  smoke
  exit 0
fi

echo "== tier-1: pytest =="
python -m pytest -x -q
smoke
echo "ALL CHECKS PASSED"
