#!/usr/bin/env python3
"""Thin shim over the analysis suite's links pass.

The docs link checker now lives in ``repro.analysis.links`` as pass 4 of
``python -m repro.analysis`` (which `scripts/check.sh --fast` and CI run
with all passes). This entry point is kept for muscle memory:

    python scripts/check_links.py
"""
from __future__ import annotations

import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))


def main() -> int:
    from repro.analysis.links import links_pass

    findings, checked = links_pass(ROOT)
    if findings:
        for f in findings:
            print(f"BROKEN LINK: {f.path}:{f.line}: {f.message}")
        return 1
    print(f"link-check: {checked} markdown files, all relative links resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
