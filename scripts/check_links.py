#!/usr/bin/env python3
"""Docs link checker: verify every relative link in README.md and
docs/*.md resolves to an existing file.

    python scripts/check_links.py

External links (http/https/mailto) and pure in-page anchors (#...) are
skipped; a relative link's optional #fragment is stripped before the
existence check. Exits non-zero listing every broken link — wired into
`scripts/check.sh --fast` and CI so docs can't rot silently.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
# [text](target) — target up to the first closing paren / whitespace
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def check_file(md: Path) -> list:
    broken = []
    for m in LINK_RE.finditer(md.read_text()):
        target = m.group(1)
        if target.startswith(SKIP_PREFIXES):
            continue
        path = target.split("#", 1)[0]
        if not path:
            continue
        if not (md.parent / path).exists():
            broken.append((md.relative_to(ROOT), target))
    return broken


def main() -> int:
    files = [ROOT / "README.md", *sorted((ROOT / "docs").glob("*.md"))]
    broken, checked = [], 0
    for f in files:
        if not f.exists():
            broken.append((f.relative_to(ROOT), "<file missing>"))
            continue
        checked += 1
        broken.extend(check_file(f))
    if broken:
        for f, target in broken:
            print(f"BROKEN LINK: {f}: {target}")
        return 1
    print(f"link-check: {checked} markdown files, all relative links resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
