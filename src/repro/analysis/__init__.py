"""Static + dynamic analysis suite for the repro codebase.

Four passes behind one CLI (``python -m repro.analysis``):

* **lint** — AST rules over reproducibility/correctness hazards (PRNG key
  reuse, traced-value branching, unseeded RNG, mutable defaults,
  unordered iteration in order-sensitive modules, float equality on
  cost/time quantities, un-ClassVar'd registry attributes, control-flow
  asserts, wall-clock reads in the simulator core). See
  :mod:`repro.analysis.lint`.
* **contracts** — executes every registered ExchangeProtocol / PeerGraph /
  AllocationPolicy against its declared ClassVar contract. See
  :mod:`repro.analysis.contracts`.
* **trace** — double-runs the seeded simulators with a
  :class:`~repro.analysis.trace.TraceRecorder` attached and asserts
  identical trace digests plus race/ordering invariants. See
  :mod:`repro.analysis.trace`.
* **links** — README/docs relative-link integrity (absorbed
  ``scripts/check_links.py``). See :mod:`repro.analysis.links`.

``scripts/check.sh --fast`` and CI run the full suite with
``--fail-on=error``; findings render human-readably and serialize to a
JSON report artifact (``--json``). Per-line suppression: ``# noqa: RULE``
or ``# analysis: ignore[RULE]``. Rule catalog: ``docs/ANALYSIS.md``.
"""
from __future__ import annotations

from pathlib import Path
from typing import Optional, Sequence

from repro.analysis.common import (
    Finding, Report, SEVERITIES, filter_suppressed, severity_rank,
    sorted_findings, suppressed_rules,
)

ALL_PASSES = ("lint", "contracts", "trace", "links")


def run_analysis(
    paths: Optional[Sequence[Path]] = None,
    *,
    root: Optional[Path] = None,
    passes: Sequence[str] = ALL_PASSES,
    deep: bool = True,
) -> Report:
    """Run the selected passes and return one merged :class:`Report`.

    ``paths`` scopes the lint pass (default: ``<root>/src``); contracts,
    trace and links are whole-project passes and ignore it. ``deep=False``
    skips the JAX-compiling cluster scenario in the trace pass.
    """
    root = Path(root) if root is not None else find_root()
    report = Report()
    unknown = set(passes) - set(ALL_PASSES)
    if unknown:
        raise ValueError(
            f"unknown analysis pass(es): {', '.join(sorted(unknown))}; "
            f"available: {', '.join(ALL_PASSES)}"
        )
    if "lint" in passes:
        from repro.analysis.lint import lint_paths

        targets = [Path(p) for p in paths] if paths else [root / "src"]
        findings, files = lint_paths(targets, root)
        report.extend(findings)
        report.files_scanned += files
        report.passes_run.append("lint")
    if "contracts" in passes:
        from repro.analysis.contracts import contracts_pass

        findings, _checks = contracts_pass()
        report.extend(findings)
        report.passes_run.append("contracts")
    if "trace" in passes:
        from repro.analysis.trace import trace_pass

        findings, _scenarios = trace_pass(deep=deep)
        report.extend(findings)
        report.passes_run.append("trace")
    if "links" in passes:
        from repro.analysis.links import links_pass

        findings, files = links_pass(root)
        report.extend(findings)
        report.files_scanned += files
        report.passes_run.append("links")
    return report


def find_root(start: Optional[Path] = None) -> Path:
    """Locate the repo root: the nearest ancestor holding ``pytest.ini``
    (or ``.git``), falling back to the current directory."""
    p = Path(start) if start is not None else Path.cwd()
    p = p.resolve()
    for candidate in (p, *p.parents):
        if (candidate / "pytest.ini").exists() or (candidate / ".git").exists():
            return candidate
    return p


__all__ = [
    "ALL_PASSES",
    "Finding",
    "Report",
    "SEVERITIES",
    "filter_suppressed",
    "find_root",
    "run_analysis",
    "severity_rank",
    "sorted_findings",
    "suppressed_rules",
]
