"""CLI for the analysis suite.

    python -m repro.analysis [paths ...] [--fail-on SEV] [--json FILE]
                             [--passes lint,contracts,trace,links] [--fast]

Exit status is 1 when any finding is at or above ``--fail-on`` (default
``error``; ``never`` always exits 0). ``paths`` scope the lint pass only;
the other passes are whole-project. ``--fast`` skips the JAX-compiling
cluster scenario of the trace pass (CI runs the full suite).
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from repro.analysis import ALL_PASSES, SEVERITIES, find_root, run_analysis


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Run the repro static/dynamic analysis suite.",
    )
    ap.add_argument(
        "paths", nargs="*", type=Path,
        help="files/directories for the lint pass (default: <root>/src)",
    )
    ap.add_argument(
        "--fail-on", default="error", choices=(*SEVERITIES, "never"),
        help="exit 1 when any finding is at/above this severity "
             "(default: error)",
    )
    ap.add_argument(
        "--json", type=Path, default=None, metavar="FILE",
        help="also write the full report as JSON",
    )
    ap.add_argument(
        "--passes", default=",".join(ALL_PASSES), metavar="P1,P2",
        help=f"comma-separated subset of: {', '.join(ALL_PASSES)}",
    )
    ap.add_argument(
        "--root", type=Path, default=None,
        help="repo root (default: auto-detected from cwd)",
    )
    ap.add_argument(
        "--fast", action="store_true",
        help="skip the trace pass's JAX cluster scenario",
    )
    args = ap.parse_args(argv)

    root = find_root(args.root) if args.root is None else args.root.resolve()
    passes = tuple(p.strip() for p in args.passes.split(",") if p.strip())
    report = run_analysis(
        args.paths or None, root=root, passes=passes, deep=not args.fast
    )
    if args.json is not None:
        report.write_json(args.json)
    print(report.render())
    return 1 if report.failed(args.fail_on) else 0


if __name__ == "__main__":
    sys.exit(main())
