"""Shared finding/report plumbing for the ``repro.analysis`` suite.

Every pass (lint / contracts / trace / links) emits :class:`Finding`
records; the CLI collects them into a :class:`Report` with JSON + human
rendering and severity gating (``--fail-on``). Suppression is per-line:
a trailing ``# noqa: RULE`` or ``# analysis: ignore[RULE]`` comment on
the flagged line silences that rule there (``RULE`` may be a rule id
like ``RA004`` or ``*`` for all rules).
"""
from __future__ import annotations

import json
import re
from dataclasses import asdict, dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

SEVERITIES = ("info", "warning", "error")  # ascending


def severity_rank(severity: str) -> int:
    try:
        return SEVERITIES.index(severity)
    except ValueError:
        raise ValueError(
            f"unknown severity {severity!r}; expected one of {SEVERITIES}"
        ) from None


@dataclass(frozen=True)
class Finding:
    """One violation: where, which rule, how bad, and why it matters."""

    rule: str  # rule id, e.g. "RA004"
    severity: str  # "info" | "warning" | "error"
    path: str  # repo-relative file (or pseudo-path like "<registry>")
    line: int  # 1-based; 0 when not line-addressable (contracts/trace)
    message: str
    pass_name: str = "lint"  # which pass produced it

    def render(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        return f"{loc}: {self.severity.upper()} [{self.rule}] {self.message}"


# -- suppression comments ----------------------------------------------------

_NOQA_RE = re.compile(
    r"#\s*(?:noqa:\s*(?P<noqa>[\w*, ]+)|analysis:\s*ignore\[(?P<ign>[\w*, ]+)\])",
    re.IGNORECASE,
)


def suppressed_rules(source_line: str) -> frozenset:
    """Rule ids silenced by a trailing comment on ``source_line``."""
    m = _NOQA_RE.search(source_line)
    if not m:
        return frozenset()
    raw = m.group("noqa") or m.group("ign") or ""
    return frozenset(r.strip().upper() for r in raw.split(",") if r.strip())


def filter_suppressed(
    findings: Sequence[Finding], lines: Sequence[str]
) -> List[Finding]:
    """Drop findings whose source line carries a matching suppression."""
    kept = []
    for f in findings:
        if 1 <= f.line <= len(lines):
            rules = suppressed_rules(lines[f.line - 1])
            if "*" in rules or f.rule.upper() in rules:
                continue
        kept.append(f)
    return kept


# -- report ------------------------------------------------------------------


@dataclass
class Report:
    """All findings from one analysis run, with gating + serialization."""

    findings: List[Finding] = field(default_factory=list)
    passes_run: List[str] = field(default_factory=list)
    files_scanned: int = 0

    def extend(self, findings: Sequence[Finding]):
        self.findings.extend(findings)

    def count(self, severity: str) -> int:
        return sum(1 for f in self.findings if f.severity == severity)

    def worst_rank(self) -> int:
        return max((severity_rank(f.severity) for f in self.findings), default=-1)

    def failed(self, fail_on: str) -> bool:
        """True when any finding is at/above the ``fail_on`` severity."""
        if fail_on == "never":
            return False
        return self.worst_rank() >= severity_rank(fail_on)

    def to_json(self) -> Dict:
        return {
            "passes": sorted(self.passes_run),
            "files_scanned": self.files_scanned,
            "summary": {s: self.count(s) for s in SEVERITIES},
            "findings": [asdict(f) for f in sorted_findings(self.findings)],
        }

    def write_json(self, path: Path):
        Path(path).write_text(json.dumps(self.to_json(), indent=2) + "\n")

    def render(self) -> str:
        lines = [f.render() for f in sorted_findings(self.findings)]
        summary = ", ".join(f"{self.count(s)} {s}" for s in reversed(SEVERITIES))
        lines.append(
            f"analysis: {len(self.findings)} finding(s) ({summary}) across "
            f"{self.files_scanned} file(s); passes: "
            f"{', '.join(sorted(self.passes_run)) or 'none'}"
        )
        return "\n".join(lines)


def sorted_findings(findings: Sequence[Finding]) -> List[Finding]:
    """Stable order: worst first, then path / line / rule."""
    return sorted(
        findings,
        key=lambda f: (-severity_rank(f.severity), f.path, f.line, f.rule),
    )
