"""Pass 2 — registry contract cross-validation.

The exchange/graph/allocation registries promise behaviour through
declarative ``ClassVar`` flags (``repro/core/exchange.py`` lines 119-126:
``name``, ``is_async``, ``requires_key``, ``decomposes_per_edge``,
``requires_full_graph``, ``sharded``, ``lossy``, ``hierarchical``).
Nothing in Python makes
a flag true — a protocol can declare ``lossy = False`` while its codec
drops bits, and every downstream consumer (EF-SGD, the cost model, the
cluster's refusal paths) silently mis-behaves. This pass instantiates
every registered implementation and *executes* each flag's observable
consequence against its declaration:

* ``RC001`` name integrity — ``cls.name`` matches its registry key, no
  ``":"`` inside a name (it is the spec parameter separator).
* ``RC002`` ``requires_key`` ⇔ ``host_encode(key=None)`` raises.
* ``RC003`` ``lossy`` ⇔ ``combine_ef`` is overridden (EF needs the local
  decoded image; lossless protocols must keep the zero-residual default).
* ``RC004`` ``lossy`` ⇔ the host wire roundtrip is lossy: encode+decode
  of a seeded random gradient tree must be exact for lossless protocols
  and must NOT be exact for lossy ones.
* ``RC005`` ``is_async`` ⇔ carried state: ``init_state`` non-None and
  ``combine(state=None)`` refused.
* ``RC006`` refusal paths — ``exchange_context`` on a sparse overlay
  (ring, P=6) raises iff ``requires_full_graph or not
  decomposes_per_edge``.
* ``RC007`` wire accounting — decomposing protocols satisfy
  ``wire_bytes == round(per_edge * degree)`` numerically; fused
  collectives override ``wire_bytes``; sharded protocols override
  ``host_wire_bytes``.
* ``RC008`` ``sharded`` ⇔ the shard surface exists (``plan`` /
  ``host_encode_shard`` / ``host_decode_shard``) and the plan produces
  one shard per peer.
* ``RC009`` spec parsing — parameterized protocols accept their sample
  ``name:arg`` spec; every other protocol rejects ``name:1`` with a
  clean ``ValueError`` (never a raw ``TypeError`` signature leak). Same
  check for the graph registry.
* ``RC010`` graph registry — every overlay at P=8 is symmetric,
  connected, and its Metropolis–Hastings mixing matrix is doubly
  stochastic (rows sum to 1, symmetric).
* ``RC011`` allocation registry — every policy returns the planner's
  ``planned_mb`` when it has no history to learn from.
* ``RC012`` (info) cross-registry name reuse — the same name registered
  in two registries is legal (namespaces are distinct) but worth knowing.
* ``RC013`` graph sparse surface — every registered overlay answers the
  CSR-era queries (``neighbors_array`` / ``mixing_row`` / ``degrees`` /
  ``mix_apply`` / power-iteration ``spectral_gap``) consistently with
  the dense oracles: per-row mixing weights are bit-equal to the dense
  matrix row, and the power gap matches the eigvalsh gap.
"""
from __future__ import annotations

import inspect
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.analysis.common import Finding

PASS_NAME = "contracts"

CONTRACT_RULES = tuple(f"RC{i:03d}" for i in range(1, 14))

# Parameterized protocols and a known-good sample argument; every other
# registered name must REJECT a ':' parameter.
PARAM_EXCHANGE_SAMPLES: Dict[str, str] = {"trimmed_mean": "0.25", "krum": "2"}
PARAM_GRAPH_SAMPLES: Dict[str, str] = {"gossip": "3", "hierarchical": "4"}

_P = 6  # peer count used for contract-instantiated contexts


def _where(cls: type) -> Tuple[str, int]:
    try:
        path = inspect.getsourcefile(cls) or "<registry>"
        line = inspect.getsourcelines(cls)[1]
    except (OSError, TypeError):
        path, line = "<registry>", 1
    return path, line


class _Checker:
    def __init__(self) -> None:
        self.findings: List[Finding] = []
        self.checks_run = 0

    def expect(
        self, ok: bool, rule: str, cls: type, message: str, *,
        severity: str = "error",
    ) -> None:
        self.checks_run += 1
        if not ok:
            path, line = _where(cls)
            self.findings.append(Finding(
                rule=rule, severity=severity, path=path, line=line,
                message=f"{cls.__name__}: {message}", pass_name=PASS_NAME,
            ))

    def raises(
        self, fn: Callable[[], Any], exc: type = ValueError
    ) -> Optional[bool]:
        """True if fn raised exc, False if it returned, None on another
        exception (reported by the caller as its own violation)."""
        try:
            fn()
        except exc:
            return True
        except Exception:
            return None
        return False


def _sample_tree(seed: int = 0):
    import jax.numpy as jnp

    rng = np.random.default_rng(seed)
    return {
        "w": jnp.asarray(rng.standard_normal((8, 4)), jnp.float32),
        "b": jnp.asarray(rng.standard_normal((16,)), jnp.float32),
    }


def _trees_equal(a, b) -> bool:
    import jax

    leaves_a, leaves_b = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(leaves_a) == len(leaves_b) and all(
        np.array_equal(np.asarray(x, np.float32), np.asarray(y, np.float32))
        for x, y in zip(leaves_a, leaves_b)
    )


def _check_exchange(ck: _Checker) -> None:
    import jax

    from repro.core.exchange import (
        ExchangeContext, ExchangeProtocol, available_exchanges, get_exchange,
    )
    from repro.core.p2p import Topology, exchange_context

    ctx = ExchangeContext(num_peers=_P)
    tree = _sample_tree()
    key = jax.random.PRNGKey(0)

    for name in available_exchanges():
        spec = name
        if name in PARAM_EXCHANGE_SAMPLES:
            spec = f"{name}:{PARAM_EXCHANGE_SAMPLES[name]}"
        proto = get_exchange(spec)
        cls = type(proto)

        # RC001 — name integrity
        ck.expect(
            proto.name == name, "RC001", cls,
            f"registered as {name!r} but cls.name is {proto.name!r}",
        )
        ck.expect(
            ":" not in name, "RC001", cls,
            f"name {name!r} contains ':', the spec parameter separator",
        )

        # RC002 — requires_key ⇔ keyless host_encode refused
        keyless = ck.raises(lambda: proto.host_encode(tree, ctx, key=None))
        if proto.requires_key:
            ck.expect(
                keyless is True, "RC002", cls,
                "declares requires_key=True but host_encode(key=None) did "
                "not raise ValueError",
            )
        else:
            ck.expect(
                keyless is False, "RC002", cls,
                "declares requires_key=False but host_encode(key=None) "
                "failed — either it needs a key (set requires_key=True) or "
                "the keyless encode path is broken",
            )

        # RC003 — lossy ⇔ combine_ef override
        overridden = cls.combine_ef is not ExchangeProtocol.combine_ef
        ck.expect(
            overridden == proto.lossy, "RC003", cls,
            f"lossy={proto.lossy} but combine_ef is "
            f"{'overridden' if overridden else 'the zero-residual default'} "
            "— error feedback only applies to (and must cover all) lossy "
            "codecs",
        )

        # RC004 — lossy ⇔ wire roundtrip drops information (dense wire only)
        if not proto.sharded:
            payload, nbytes = proto.host_encode(
                tree, ctx, key=key if proto.requires_key else None
            )
            decoded = proto.host_decode(payload, tree, ctx)
            exact = _trees_equal(decoded, tree)
            ck.expect(
                exact != proto.lossy, "RC004", cls,
                f"lossy={proto.lossy} but the host encode/decode roundtrip "
                f"{'was exact' if exact else 'changed the gradient'}",
            )
            ck.expect(
                isinstance(nbytes, int) and nbytes > 0, "RC004", cls,
                f"host_encode reported non-positive wire bytes ({nbytes!r})",
            )

        # RC005 — is_async ⇔ carried mailbox state
        state = proto.init_state(tree, ctx)
        if proto.is_async:
            ck.expect(
                state is not None, "RC005", cls,
                "declares is_async=True but init_state returned None — an "
                "async protocol must carry mailbox state",
            )
            stateless = ck.raises(
                lambda: proto.combine(tree, ctx, state=None)
            )
            ck.expect(
                stateless is True, "RC005", cls,
                "declares is_async=True but combine(state=None) did not "
                "refuse with ValueError",
            )
        else:
            ck.expect(
                state is None, "RC005", cls,
                "declares is_async=False but init_state returned carried "
                "state",
            )

        # RC006 — sparse-overlay refusal path matches the flags
        must_refuse = proto.requires_full_graph or not proto.decomposes_per_edge
        refused = ck.raises(lambda: exchange_context(
            Topology(exchange=spec, graph="ring"), num_peers=_P
        ))
        ck.expect(
            refused is must_refuse, "RC006", cls,
            f"requires_full_graph={proto.requires_full_graph}, "
            f"decomposes_per_edge={proto.decomposes_per_edge} but a ring "
            f"overlay was {'accepted' if refused is False else 'refused' if refused else 'broken'}"
            " — the flags and the refusal path disagree",
        )

        # RC007 — wire accounting matches the decomposition flag
        if proto.decomposes_per_edge and not proto.sharded:
            per_edge = proto.wire_bytes_per_edge(tree, ctx)
            total = proto.wire_bytes(tree, ctx)
            ck.expect(
                total == int(round(per_edge * ctx.degree)), "RC007", cls,
                f"decomposes_per_edge=True but wire_bytes ({total}) != "
                f"per_edge ({per_edge}) x degree ({ctx.degree})",
            )
        if not proto.decomposes_per_edge or proto.sharded:
            ck.expect(
                cls.wire_bytes is not ExchangeProtocol.wire_bytes, "RC007",
                cls,
                "a fused/sharded collective must override wire_bytes — the "
                "per-edge x degree default does not describe its traffic",
            )
        if proto.sharded:
            ck.expect(
                cls.host_wire_bytes is not ExchangeProtocol.host_wire_bytes,
                "RC007", cls,
                "sharded=True but host_wire_bytes is the one-edge-payload "
                "default; shard scatter publishes P payloads per step",
            )

        # RC008 — sharded ⇔ shard surface
        shard_api = all(
            callable(getattr(proto, m, None))
            for m in ("plan", "host_encode_shard", "host_decode_shard")
        )
        ck.expect(
            shard_api == proto.sharded, "RC008", cls,
            f"sharded={proto.sharded} but the shard surface (plan / "
            f"host_encode_shard / host_decode_shard) is "
            f"{'present' if shard_api else 'missing'}",
        )
        if proto.sharded and shard_api:
            plan = proto.plan(tree, ctx)
            ck.expect(
                int(plan.num_shards) == _P, "RC008", cls,
                f"plan produced {plan.num_shards} shards for {_P} peers — "
                "the sharded exchange owns one shard per peer",
            )
            row = plan.shards(tree)[0]
            wire, nb = proto.host_encode_shard(row, ctx)
            back = proto.host_decode_shard(wire, ctx)
            ck.expect(
                np.allclose(np.asarray(back), np.asarray(row, np.float32)),
                "RC008", cls, "shard encode/decode roundtrip changed values",
            )

        # RC009 — spec parameter parsing
        if name in PARAM_EXCHANGE_SAMPLES:
            parsed = ck.raises(lambda: get_exchange(spec))
            ck.expect(
                parsed is False, "RC009", cls,
                f"sample spec {spec!r} was rejected by get_exchange",
            )
        else:
            rejected = ck.raises(lambda: get_exchange(f"{name}:1"))
            ck.expect(
                rejected is True, "RC009", cls,
                f"{name}:1 must be rejected with a clean ValueError (got "
                f"{'no error' if rejected is False else 'a non-ValueError'})",
            )


def _check_graphs(ck: _Checker) -> None:
    from repro.core.graph import available_graphs, get_graph

    P = 8
    for name in available_graphs():
        if name == "static":
            # name-only construction is (correctly) refused — build an
            # explicit instance for the structural checks instead
            from repro.core.graph import StaticGraph

            refused = ck.raises(lambda: get_graph("static", P, seed=0))
            ck.expect(
                refused is True, "RC009", StaticGraph,
                "get_graph('static', P) must refuse with ValueError — the "
                "static overlay needs an explicit adjacency",
            )
            g = StaticGraph.from_edges(P, [(i, (i + 1) % P) for i in range(P)])
        else:
            spec = name
            if name in PARAM_GRAPH_SAMPLES:
                spec = f"{name}:{PARAM_GRAPH_SAMPLES[name]}"
            g = get_graph(spec, P, seed=0)
        cls = type(g)
        ck.expect(
            g.name == name, "RC001", cls,
            f"registered as {name!r} but cls.name is {g.name!r}",
        )
        adj = np.asarray(g.adjacency, bool)
        ck.expect(
            bool((adj == adj.T).all()), "RC010", cls,
            "adjacency is not symmetric — the P2P overlay is undirected",
        )
        ck.expect(
            not adj.diagonal().any(), "RC010", cls,
            "adjacency has self-loops; a peer is not its own neighbor",
        )
        ck.expect(
            bool(g.is_connected()), "RC010", cls,
            f"overlay is disconnected at P={P}; gossip averaging cannot "
            "reach consensus",
        )
        W = np.asarray(g.mixing_matrix(), np.float64)
        ck.expect(
            np.allclose(W.sum(axis=1), 1.0) and np.allclose(W, W.T),
            "RC010", cls,
            "Metropolis–Hastings mixing matrix is not doubly stochastic",
        )
        # RC013 — the sparse scaling surface must agree with the dense
        # oracles (the 10k-100k-peer path never materializes P x P)
        ck.expect(
            all(
                np.array_equal(g.neighbors_array(r), np.flatnonzero(adj[r]))
                for r in range(P)
            ),
            "RC013", cls,
            "neighbors_array(r) disagrees with the dense adjacency row",
        )
        ck.expect(
            all(
                np.array_equal(g.mixing_row(r), np.asarray(g.mixing_matrix())[r])
                for r in range(P)
            ),
            "RC013", cls,
            "lazy mixing_row(r) is not bit-equal to mixing_matrix()[r]",
        )
        ck.expect(
            np.array_equal(g.degrees, adj.sum(axis=1)),
            "RC013", cls,
            "CSR degrees disagree with dense adjacency row sums",
        )
        x = np.random.default_rng(0).standard_normal(P)
        ck.expect(
            bool(np.allclose(g.mix_apply(x), W @ x, atol=1e-12)),
            "RC013", cls,
            "sparse mix_apply(x) disagrees with the dense W @ x",
        )
        ck.expect(
            abs(g.spectral_gap(method="power") - g.spectral_gap(method="dense"))
            <= 1e-6,
            "RC013", cls,
            "power-iteration spectral gap drifts from the eigvalsh oracle",
        )
        # RC009 — non-param graphs reject a ':' parameter cleanly
        if name not in PARAM_GRAPH_SAMPLES and name != "static":
            rejected = ck.raises(lambda: get_graph(f"{name}:2", P, seed=0))
            ck.expect(
                rejected is True, "RC009", cls,
                f"{name}:2 must be rejected with a clean ValueError (got "
                f"{'no error' if rejected is False else 'a non-ValueError'})",
            )


def _check_allocations(ck: _Checker) -> None:
    from repro.core.events import available_allocations, get_allocation

    for name in available_allocations():
        pol = get_allocation(name)
        cls = type(pol)
        ck.expect(
            pol.name == name, "RC001", cls,
            f"registered as {name!r} but cls.name is {pol.name!r}",
        )
        got = pol.memory_mb(epoch=0, planned_mb=1792, history=[])
        ck.expect(
            got == 1792, "RC011", cls,
            f"with no fan-out history the policy must fall back to the "
            f"planner's static fit (1792 MB), got {got}",
        )


def _check_cross_registry(ck: _Checker) -> None:
    from repro.core.events import available_allocations
    from repro.core.exchange import available_exchanges
    from repro.core.graph import available_graphs

    registries = {
        "exchange": set(available_exchanges()),
        "graph": set(available_graphs()),
        "allocation": set(available_allocations()),
    }
    names = sorted(set().union(*registries.values()))
    for n in names:
        owners = sorted(k for k, v in registries.items() if n in v)
        ck.checks_run += 1
        if len(owners) > 1:
            ck.findings.append(Finding(
                rule="RC012", severity="info", path="<registries>", line=1,
                message=(
                    f"name {n!r} is registered in multiple registries "
                    f"({', '.join(owners)}); namespaces are distinct but a "
                    "spec string's meaning now depends on position"
                ),
                pass_name=PASS_NAME,
            ))


def contracts_pass() -> Tuple[List[Finding], int]:
    """Run every registry contract; returns ``(findings, checks_run)``."""
    ck = _Checker()
    _check_exchange(ck)
    _check_graphs(ck)
    _check_allocations(ck)
    _check_cross_registry(ck)
    return ck.findings, ck.checks_run
