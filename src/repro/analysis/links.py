"""Pass 4 — docs link integrity (the old ``scripts/check_links.py``).

Every relative markdown link in ``README.md`` and ``docs/*.md`` must
resolve to an existing file. External links (http/https/mailto) and pure
in-page anchors are skipped; a relative link's optional ``#fragment`` is
stripped before the existence check. One ``RL001`` error per broken link,
plus ``RL002`` if an expected markdown file itself is missing.
"""
from __future__ import annotations

import re
from pathlib import Path
from typing import List, Tuple

from repro.analysis.common import Finding

PASS_NAME = "links"

LINK_RULES = ("RL001", "RL002")

# [text](target) — target up to the first closing paren / whitespace
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def check_markdown(md: Path, root: Path) -> List[Finding]:
    findings: List[Finding] = []
    text = md.read_text()
    for lineno, line in enumerate(text.splitlines(), start=1):
        for m in LINK_RE.finditer(line):
            target = m.group(1)
            if target.startswith(SKIP_PREFIXES):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            if not (md.parent / path).exists():
                findings.append(Finding(
                    rule="RL001", severity="error",
                    path=str(md.relative_to(root)), line=lineno,
                    message=f"broken relative link: {target}",
                    pass_name=PASS_NAME,
                ))
    return findings


def links_pass(root: Path) -> Tuple[List[Finding], int]:
    """Check README.md + docs/*.md under ``root``; -> (findings, files)."""
    root = Path(root)
    files = [root / "README.md", *sorted((root / "docs").glob("*.md"))]
    findings: List[Finding] = []
    checked = 0
    for f in files:
        if not f.exists():
            findings.append(Finding(
                rule="RL002", severity="error",
                path=str(f.relative_to(root)), line=1,
                message="expected markdown file is missing",
                pass_name=PASS_NAME,
            ))
            continue
        checked += 1
        findings.extend(check_markdown(f, root))
    return findings, checked
