"""AST lint pass — the JAX/Pallas + discrete-event pitfalls this codebase
actually has (see docs/ANALYSIS.md for the catalog with rationale).

Rules:

* RA001 ``prng-key-reuse`` (error) — the same PRNG key Name consumed by two
  ``jax.random`` sampler calls in one function without an intervening
  reassignment / ``split`` / ``fold_in``. Reused keys silently correlate
  "independent" randomness (quantization noise, init, attacks).
* RA002 ``traced-branch`` (error) — Python ``if``/``while`` on a function
  parameter inside a ``@jax.jit``-decorated function. Traced values have no
  runtime truth value; the branch either crashes (ConcretizationTypeError)
  or silently bakes in the tracing-time path.
* RA003 ``unseeded-rng`` (error) — module-level ``np.random.*`` /
  stdlib ``random.*`` draws (global, unseeded RNG state), or
  ``np.random.default_rng()`` with no seed. Every stochastic model in this
  repo must draw from an explicitly seeded Generator so a fixed seed fixes
  the whole simulation.
* RA004 ``mutable-default`` (error) — mutable default argument values
  (shared across calls; a classic cross-epoch state-leak vector).
* RA005 ``unordered-iteration`` (error) — iterating ``dict.values() /
  .items() / .keys()`` or a ``set(...)`` directly (no ``sorted(...)``)
  in ordering-sensitive modules (``mailbox.py`` / ``events.py`` /
  ``simulate.py``): message and event ordering must not depend on
  container insertion/hash order.
* RA006 ``float-eq`` (warning) — ``==``/``!=`` against a nonzero float
  literal, or between identifiers named like costs/times (``*_s``,
  ``*_usd``, ``*time*``, ``*cost*``, ``*_bps``). Accumulated float
  quantities compare reliably only via tolerances; exact-zero sentinel
  checks (``== 0.0``) are exempt.
* RA007 ``missing-classvar`` (error) — registry base classes (identified
  by the ``name = "?"`` registration sentinel) must annotate class-level
  contract attributes as ``ClassVar``: a plain annotation makes
  dataclass-style tooling treat them as instance fields and hides the
  subclass-override contract the checker in ``contracts.py`` enforces.
* RA008 ``control-flow-assert`` (warning) — ``assert`` used for runtime
  validation in ``repro.core`` simulation modules. ``python -O`` strips
  asserts, so a barrier/invariant check silently disappears; raise an
  explicit exception instead. (Kernel shape guards outside ``core`` are
  exempt by scope.)
* RA009 ``wallclock-in-sim`` (error) — reading the wall clock
  (``time.time`` / ``perf_counter`` / ``monotonic`` / ``datetime.now``)
  inside the pure discrete-event module (``events.py``): simulated time
  must advance only through the event heap, or same-seed runs stop being
  reproducible.
"""
from __future__ import annotations

import ast
import re
from pathlib import Path
from typing import List, Optional, Sequence

from repro.analysis.common import Finding, filter_suppressed

PASS_NAME = "lint"

# jax.random callees that DERIVE keys rather than consuming entropy
_KEY_DERIVERS = frozenset(
    {"split", "fold_in", "PRNGKey", "key", "wrap_key_data", "key_data", "clone"}
)
# np.random constructors that are fine (they take / carry an explicit seed)
_NP_RANDOM_OK = frozenset(
    {"default_rng", "Generator", "SeedSequence", "PCG64", "Philox", "MT19937"}
)
_WALLCLOCK_FNS = frozenset({"time", "perf_counter", "monotonic", "process_time"})
_FLOATY_NAME = re.compile(r"(_s|_secs|_seconds|_usd|_bps)$|time|cost|price")

# Module scoping: which basenames are ordering-sensitive / pure-sim / core.
_ORDER_SENSITIVE = ("mailbox", "events", "simulate")
_SIM_PURE = ("events",)


def _name_of(node: ast.AST) -> Optional[str]:
    """Terminal identifier of a Name/Attribute chain (``a.b.c`` -> ``c``)."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        return node.attr
    return None


def _dotted(node: ast.AST) -> Optional[str]:
    """Full dotted path of a Name/Attribute chain, or None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


class _RuleContext:
    def __init__(self, path: str, *, order_sensitive: bool, sim_pure: bool,
                 core_module: bool):
        self.path = path
        self.order_sensitive = order_sensitive
        self.sim_pure = sim_pure
        self.core_module = core_module
        self.findings: List[Finding] = []

    def add(self, rule: str, severity: str, node: ast.AST, message: str):
        self.findings.append(
            Finding(
                rule=rule,
                severity=severity,
                path=self.path,
                line=getattr(node, "lineno", 0),
                message=message,
                pass_name=PASS_NAME,
            )
        )


# ---------------------------------------------------------------------------
# RA001 — PRNG key reuse
# ---------------------------------------------------------------------------


def _stored_names(node: ast.AST) -> List[str]:
    return [
        t.id
        for t in ast.walk(node)
        if isinstance(t, ast.Name) and isinstance(t.ctx, ast.Store)
    ]


def _terminates(body) -> bool:
    """True when a statement list cannot fall through to the next
    statement (its tail is return/raise/break/continue)."""
    return bool(body) and isinstance(
        body[-1], (ast.Return, ast.Raise, ast.Break, ast.Continue)
    )


class _KeyFlow:
    """Path-sensitive tracker of spent PRNG keys within ONE function scope.

    ``if``/``try`` branches fork the spent set (exclusive paths may each
    consume the key once); loop bodies are scanned twice so loop-carried
    reuse (consuming the same key every iteration) is caught. Nested
    function definitions are separate scopes and are skipped here — the
    driver lints every def independently.
    """

    def __init__(self, ctx: _RuleContext):
        self.ctx = ctx
        self.reported = set()  # (line, name) dedupe across loop re-scans

    def run(self, fn) -> None:
        self._stmts(fn.body, {})

    # -- expression scan ----------------------------------------------------
    def _consumes(self, expr: ast.AST):
        """(line, key-name) for each jax.random sampler call in ``expr``,
        not descending into nested defs/lambdas."""
        stack, hits = [expr], []
        while stack:
            node = stack.pop()
            if isinstance(node, (ast.Lambda, ast.FunctionDef,
                                 ast.AsyncFunctionDef)):
                continue
            stack.extend(ast.iter_child_nodes(node))
            if not isinstance(node, ast.Call):
                continue
            dotted = _dotted(node.func) or ""
            parts = dotted.split(".")
            is_jax_random = dotted.startswith("jax.random.") or (
                len(parts) == 2 and parts[0] in ("jrandom", "jr")
            )
            if is_jax_random and parts[-1] not in _KEY_DERIVERS and node.args:
                arg = node.args[0]
                if isinstance(arg, ast.Name):
                    hits.append((node.lineno, arg.id))
        return sorted(hits)

    def _eval(self, expr: ast.AST, spent: dict):
        for line, name in self._consumes(expr):
            if name in spent:
                if (line, name) not in self.reported:
                    self.reported.add((line, name))
                    self.ctx.findings.append(Finding(
                        rule="RA001", severity="error", path=self.ctx.path,
                        line=line,
                        message=(
                            f"PRNG key {name!r} consumed again (first use "
                            f"line {spent[name]}) without split/fold_in — "
                            f"correlated randomness"
                        ),
                        pass_name=PASS_NAME,
                    ))
            else:
                spent[name] = line

    # -- statement interpretation -------------------------------------------
    def _stmts(self, body, spent: dict):
        for stmt in body:
            self._stmt(stmt, spent)

    def _stmt(self, stmt, spent: dict):
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
            return  # separate scope; linted independently
        if isinstance(stmt, ast.If):
            self._eval(stmt.test, spent)
            a, b = dict(spent), dict(spent)
            self._stmts(stmt.body, a)
            self._stmts(stmt.orelse, b)
            # conservative join — but a branch that cannot fall through
            # (return/raise/break/continue) never reaches the code after
            # the if, so its spends don't propagate
            spent.clear()
            if _terminates(stmt.body) and not _terminates(stmt.orelse):
                spent.update(b)
            elif _terminates(stmt.orelse) and not _terminates(stmt.body):
                spent.update(a)
            else:
                spent.update({**a, **b})
            return
        if isinstance(stmt, (ast.For, ast.AsyncFor)):
            self._eval(stmt.iter, spent)
            for n in _stored_names(stmt.target):
                spent.pop(n, None)
            # two passes: the second catches loop-carried key reuse
            self._stmts(stmt.body, spent)
            for n in _stored_names(stmt.target):
                spent.pop(n, None)
            self._stmts(stmt.body, spent)
            self._stmts(stmt.orelse, spent)
            return
        if isinstance(stmt, ast.While):
            self._eval(stmt.test, spent)
            self._stmts(stmt.body, spent)
            self._stmts(stmt.body, spent)
            self._stmts(stmt.orelse, spent)
            return
        if isinstance(stmt, ast.Try):
            a = dict(spent)
            self._stmts(stmt.body, a)
            merged = dict(a)
            for handler in stmt.handlers:
                h = dict(spent)
                self._stmts(handler.body, h)
                merged.update(h)
            self._stmts(stmt.orelse, merged)
            self._stmts(stmt.finalbody, merged)
            spent.clear()
            spent.update(merged)
            return
        if isinstance(stmt, ast.With):
            for item in stmt.items:
                self._eval(item.context_expr, spent)
            self._stmts(stmt.body, spent)
            return
        # straight-line statement: evaluate value exprs, then clear stores
        for expr in ast.iter_child_nodes(stmt):
            self._eval(expr, spent)
        if isinstance(stmt, (ast.Assign, ast.AnnAssign, ast.AugAssign,
                             ast.NamedExpr)):
            for n in _stored_names(stmt):
                spent.pop(n, None)


def _check_key_reuse(fn: ast.AST, ctx: _RuleContext):
    _KeyFlow(ctx).run(fn)


# ---------------------------------------------------------------------------
# RA002 — Python branch on traced value inside jit
# ---------------------------------------------------------------------------


def _is_jit_decorated(fn) -> bool:
    for dec in fn.decorator_list:
        target = dec.func if isinstance(dec, ast.Call) else dec
        dotted = _dotted(target) or ""
        if dotted in ("jax.jit", "jit", "jax.pmap", "pmap"):
            return True
        # functools.partial(jax.jit, ...)
        if isinstance(dec, ast.Call) and dotted.endswith("partial") and dec.args:
            inner = _dotted(dec.args[0]) or ""
            if inner in ("jax.jit", "jit"):
                return True
    return False


def _check_traced_branch(fn, ctx: _RuleContext):
    if not _is_jit_decorated(fn):
        return
    params = {a.arg for a in fn.args.args + fn.args.kwonlyargs}
    params.discard("self")

    def traced_names(test: ast.AST) -> List[str]:
        hits = []
        for node in ast.walk(test):
            if isinstance(node, ast.Attribute):
                # x.shape / x.dtype / cfg.field are static at trace time —
                # drop the whole chain, including its root Name
                continue
            if isinstance(node, ast.Compare) and any(
                isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops
            ):
                return []  # `x is None` guards are static
            if isinstance(node, ast.Name) and node.id in params:
                hits.append(node.id)
        # remove names that only appear as attribute roots
        attr_roots = {
            n.value.id
            for n in ast.walk(test)
            if isinstance(n, ast.Attribute) and isinstance(n.value, ast.Name)
        }
        return [h for h in hits if h not in attr_roots]

    for node in ast.walk(fn):
        if isinstance(node, (ast.If, ast.While)):
            names = traced_names(node.test)
            if names:
                ctx.add(
                    "RA002", "error", node,
                    f"Python {'while' if isinstance(node, ast.While) else 'if'} "
                    f"on traced value(s) {sorted(set(names))} inside a "
                    f"jit-compiled function — use lax.cond/select or hoist "
                    f"the branch out of the traced region",
                )


# ---------------------------------------------------------------------------
# RA003 — unseeded global RNG
# ---------------------------------------------------------------------------


def _check_unseeded_rng(tree: ast.AST, ctx: _RuleContext):
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        dotted = _dotted(node.func) or ""
        parts = dotted.split(".")
        if len(parts) == 3 and parts[0] in ("np", "numpy") and parts[1] == "random":
            fn = parts[2]
            if fn == "default_rng" and not node.args and not node.keywords:
                ctx.add(
                    "RA003", "error", node,
                    "np.random.default_rng() without a seed — pass an explicit "
                    "seed so the simulation is reproducible",
                )
            elif fn not in _NP_RANDOM_OK:
                ctx.add(
                    "RA003", "error", node,
                    f"np.random.{fn} draws from the unseeded GLOBAL numpy RNG; "
                    f"thread a seeded np.random.default_rng(seed) Generator "
                    f"instead",
                )
        elif len(parts) == 2 and parts[0] == "random" and parts[1] not in (
            "Random", "SystemRandom"
        ):
            ctx.add(
                "RA003", "error", node,
                f"stdlib random.{parts[1]} uses global unseeded RNG state; "
                f"use a seeded random.Random(seed) or numpy Generator",
            )


# ---------------------------------------------------------------------------
# RA004 — mutable default arguments
# ---------------------------------------------------------------------------

_MUTABLE_CALLS = frozenset({"list", "dict", "set", "bytearray", "defaultdict", "deque"})


def _check_mutable_default(fn, ctx: _RuleContext):
    defaults = list(fn.args.defaults) + [
        d for d in fn.args.kw_defaults if d is not None
    ]
    for d in defaults:
        bad = isinstance(d, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                             ast.DictComp, ast.SetComp))
        if isinstance(d, ast.Call):
            bad = bad or (_name_of(d.func) in _MUTABLE_CALLS)
        if bad:
            ctx.add(
                "RA004", "error", d,
                f"mutable default argument in {fn.name}() is shared across "
                f"calls — default to None and construct inside the body",
            )


# ---------------------------------------------------------------------------
# RA005 — unordered dict/set iteration in ordering-sensitive modules
# ---------------------------------------------------------------------------


def _iter_sites(tree: ast.AST):
    for node in ast.walk(tree):
        if isinstance(node, (ast.For, ast.AsyncFor)):
            yield node.iter
        elif isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                               ast.GeneratorExp)):
            for gen in node.generators:
                yield gen.iter


def _check_unordered_iteration(tree: ast.AST, ctx: _RuleContext):
    if not ctx.order_sensitive:
        return
    for it in _iter_sites(tree):
        if isinstance(it, ast.Call):
            callee = it.func
            if isinstance(callee, ast.Attribute) and callee.attr in (
                "values", "items", "keys"
            ) and not it.args:
                ctx.add(
                    "RA005", "error", it,
                    f"iteration over .{callee.attr}() in an ordering-sensitive "
                    f"module depends on dict insertion order — iterate "
                    f"sorted(...) so message/event order is explicit",
                )
            elif _name_of(callee) == "set":
                ctx.add(
                    "RA005", "error", it,
                    "iteration over a set in an ordering-sensitive module is "
                    "hash-order dependent — iterate sorted(...) instead",
                )


# ---------------------------------------------------------------------------
# RA006 — float == on costs/times
# ---------------------------------------------------------------------------


def _floaty(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Constant) and isinstance(node.value, float):
        return None if node.value == 0.0 else f"float literal {node.value!r}"
    name = _name_of(node)
    if name and _FLOATY_NAME.search(name):
        return f"cost/time-named value {name!r}"
    return None


def _check_float_eq(tree: ast.AST, ctx: _RuleContext):
    for node in ast.walk(tree):
        if not isinstance(node, ast.Compare):
            continue
        if not any(isinstance(op, (ast.Eq, ast.NotEq)) for op in node.ops):
            continue
        sides = [node.left, *node.comparators]
        if any(
            isinstance(s, ast.Constant) and isinstance(s.value, (str, bytes, bool))
            or (isinstance(s, ast.Constant) and s.value is None)
            for s in sides
        ):
            continue  # string/None/bool sentinel comparisons are not float math
        if any(
            isinstance(s, ast.Constant) and isinstance(s.value, float)
            and s.value == 0.0
            for s in sides
        ):
            continue  # exact-zero sentinel ("never set") checks are exempt
        for side in sides:
            why = _floaty(side)
            if why:
                ctx.add(
                    "RA006", "warning", node,
                    f"exact ==/!= against {why}; accumulated float "
                    f"costs/times need a tolerance (math.isclose / abs diff)",
                )
                break


# ---------------------------------------------------------------------------
# RA007 — registry contract attributes must be ClassVar
# ---------------------------------------------------------------------------


def _is_registry_base(cls: ast.ClassDef) -> bool:
    """The codebase convention: registry bases carry ``name = "?"`` which
    the @register_* decorator overwrites."""
    for stmt in cls.body:
        target = None
        if isinstance(stmt, ast.AnnAssign) and isinstance(stmt.target, ast.Name):
            target, value = stmt.target.id, stmt.value
        elif isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 and isinstance(
            stmt.targets[0], ast.Name
        ):
            target, value = stmt.targets[0].id, stmt.value
        if target == "name" and isinstance(value, ast.Constant) and value.value == "?":
            return True
    return False


def _check_missing_classvar(tree: ast.AST, ctx: _RuleContext):
    for cls in ast.walk(tree):
        if not isinstance(cls, ast.ClassDef) or not _is_registry_base(cls):
            continue
        for stmt in cls.body:
            if not (isinstance(stmt, ast.AnnAssign) and stmt.value is not None
                    and isinstance(stmt.target, ast.Name)):
                continue
            ann = ast.unparse(stmt.annotation)
            if "ClassVar" not in ann:
                ctx.add(
                    "RA007", "error", stmt,
                    f"registry base {cls.name}.{stmt.target.id} is a "
                    f"class-level contract attribute — annotate it "
                    f"ClassVar[{ann}] so instance shadowing is a type error "
                    f"and the contract checker can enumerate it",
                )


# ---------------------------------------------------------------------------
# RA008 — control-flow asserts in core simulation modules
# ---------------------------------------------------------------------------


def _check_control_flow_assert(tree: ast.AST, ctx: _RuleContext):
    if not ctx.core_module:
        return
    for node in ast.walk(tree):
        if isinstance(node, ast.Assert):
            ctx.add(
                "RA008", "warning", node,
                "assert used as a runtime invariant in a core simulation "
                "module — python -O strips it; raise ValueError/RuntimeError "
                "explicitly",
            )


# ---------------------------------------------------------------------------
# RA009 — wall clock reads inside the pure discrete-event module
# ---------------------------------------------------------------------------


def _check_wallclock(tree: ast.AST, ctx: _RuleContext):
    if not ctx.sim_pure:
        return
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        dotted = _dotted(node.func) or ""
        parts = dotted.split(".")
        if (len(parts) == 2 and parts[0] == "time" and parts[1] in _WALLCLOCK_FNS) or (
            dotted in ("datetime.now", "datetime.datetime.now", "datetime.utcnow")
        ):
            ctx.add(
                "RA009", "error", node,
                f"{dotted}() reads the wall clock inside the discrete-event "
                f"module — simulated time must advance only via the event "
                f"heap or same-seed runs diverge",
            )


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------

ALL_RULES = (
    "RA001", "RA002", "RA003", "RA004", "RA005", "RA006", "RA007", "RA008",
    "RA009",
)


def lint_source(
    source: str,
    path: str = "<string>",
    *,
    order_sensitive: Optional[bool] = None,
    sim_pure: Optional[bool] = None,
    core_module: Optional[bool] = None,
) -> List[Finding]:
    """Lint one module's source. Scope flags default from the basename:
    ordering rules fire for mailbox/events/simulate modules, the wall-clock
    rule for events modules, the assert rule for ``repro/core`` files."""
    basename = Path(path).name
    posix = Path(path).as_posix()
    if order_sensitive is None:
        order_sensitive = any(tag in basename for tag in _ORDER_SENSITIVE)
    if sim_pure is None:
        sim_pure = any(tag in basename for tag in _SIM_PURE)
    if core_module is None:
        core_module = "/core/" in posix or "core_" in basename
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [Finding("RA000", "error", path, e.lineno or 0,
                        f"syntax error: {e.msg}", PASS_NAME)]
    ctx = _RuleContext(
        path, order_sensitive=order_sensitive, sim_pure=sim_pure,
        core_module=core_module,
    )
    _check_unseeded_rng(tree, ctx)
    _check_unordered_iteration(tree, ctx)
    _check_float_eq(tree, ctx)
    _check_missing_classvar(tree, ctx)
    _check_control_flow_assert(tree, ctx)
    _check_wallclock(tree, ctx)
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            _check_key_reuse(node, ctx)
            _check_traced_branch(node, ctx)
            _check_mutable_default(node, ctx)
    return filter_suppressed(ctx.findings, source.splitlines())


def lint_file(path: Path, root: Optional[Path] = None, **scopes) -> List[Finding]:
    path = Path(path)
    rel = str(path.relative_to(root)) if root else str(path)
    findings = lint_source(path.read_text(), rel, **scopes)
    # re-anchor pseudo-paths produced by lint_source onto the relative path
    return [
        Finding(f.rule, f.severity, rel, f.line, f.message, f.pass_name)
        for f in findings
    ]


def lint_paths(paths: Sequence[Path], root: Optional[Path] = None):
    """Lint every ``*.py`` under the given files/directories.

    Returns ``(findings, files_scanned)``.
    """
    files: List[Path] = []
    for p in paths:
        p = Path(p)
        files.extend(sorted(p.rglob("*.py")) if p.is_dir() else [p])
    findings: List[Finding] = []
    for f in files:
        findings.extend(lint_file(f, root))
    return findings, len(files)
