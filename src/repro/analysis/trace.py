"""Pass 3 — event-trace recording and dynamic determinism checks.

The simulators (``EventEngine``, ``ServerlessRuntime``, ``HostMailbox``,
``LocalP2PCluster``) accept an optional ``tracer``; when given a
:class:`TraceRecorder` they emit one canonical event per schedule / fire /
publish / consume / miss / blocked. This pass builds a happens-before view
over those events and checks:

* ``RT001`` **latest-wins-overwrite** (warning) — a publish replaced a
  same-epoch message in the same ``(peer, shard)`` register that no
  consumer ever read: the producer is outrunning its consumers, so part of
  the gradient stream silently vanishes (the mailbox's ``compacted``
  counter, localized to the exact event).
* ``RT002`` **same-instant-tie** (info) — two events fired at identical
  ``(time, priority)``. The engine breaks the tie by insertion sequence,
  which is deterministic, so this is informational: it marks the places
  where a non-FIFO scheduler would diverge.
* ``RT003`` **trace-divergence** (error) — the double-run differ: two
  same-seed runs of the same scenario must produce bit-identical trace
  digests. Checked for the serverless fan-out (faults, cold starts,
  stragglers, concurrency throttling ON) and for the async P2P cluster
  (churn ON, ``sim_compute_s`` pinning the virtual clock).
* ``RT004`` **unseeded-engine** (error) — an engine joined the trace
  without announcing a seeded RNG.

Digests are sha256 over the canonical event tuples, so "identical trace"
means identical event kinds, orders, times, and payload metadata — not
just identical final metrics.
"""
from __future__ import annotations

import hashlib
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.analysis.common import Finding

PASS_NAME = "trace"

TRACE_RULES = ("RT001", "RT002", "RT003", "RT004")


class TraceRecorder:
    """Append-only canonical event log with a stable digest.

    ``record(kind, **fields)`` canonicalizes the event as ``(kind, sorted
    (field, value) pairs)``; values must be hashable scalars (numbers,
    strings, bools, None, or tuples thereof). The digest is order- and
    value-sensitive by construction.
    """

    def __init__(self) -> None:
        self.events: List[Tuple[Any, ...]] = []

    def record(self, kind: str, **fields: Any) -> None:
        self.events.append((kind,) + tuple(sorted(fields.items())))

    def __len__(self) -> int:
        return len(self.events)

    def digest(self) -> str:
        h = hashlib.sha256()
        for ev in self.events:
            h.update(repr(ev).encode())
        return h.hexdigest()


def _fields(event: Tuple[Any, ...]) -> Dict[str, Any]:
    return dict(event[1:])


def check_trace(
    events: List[Tuple[Any, ...]], *, label: str = "<trace>"
) -> List[Finding]:
    """Static checks over one recorded trace (RT001 / RT002 / RT004)."""
    findings: List[Finding] = []
    # (peer, shard) -> index of the last unconsumed publish at that epoch
    live: Dict[Tuple[Any, Any], Tuple[int, Any]] = {}
    last_fire: Optional[Tuple[Any, Any]] = None
    for i, ev in enumerate(events):
        kind, f = ev[0], _fields(ev)
        if kind == "engine" and not f.get("seeded", False):
            findings.append(Finding(
                rule="RT004", severity="error", path=label, line=i + 1,
                message="event engine joined the trace without a seeded RNG; "
                        "same-seed reproducibility is impossible",
                pass_name=PASS_NAME,
            ))
        elif kind == "publish":
            key = (f.get("actor"), f.get("shard"))
            prev = live.get(key)
            if prev is not None and prev[1] == f.get("epoch"):
                findings.append(Finding(
                    rule="RT001", severity="warning", path=label, line=i + 1,
                    message=(
                        f"peer {f.get('actor')} shard {f.get('shard')!r} "
                        f"re-published epoch {f.get('epoch')} before any "
                        "consumer read the previous message — the earlier "
                        "gradient was silently overwritten (latest-wins race)"
                    ),
                    pass_name=PASS_NAME,
                ))
            live[key] = (i, f.get("epoch"))
        elif kind == "consume":
            live.pop((f.get("peer"), f.get("shard")), None)
        elif kind == "fire":
            tie = (f.get("time"), f.get("priority"))
            if last_fire is not None and tie == last_fire:
                findings.append(Finding(
                    rule="RT002", severity="info", path=label, line=i + 1,
                    message=(
                        f"two events fired at identical (time={tie[0]}, "
                        f"priority={tie[1]}); ordering relies on the "
                        "engine's insertion-sequence tie-break"
                    ),
                    pass_name=PASS_NAME,
                ))
            last_fire = tie
    return findings


# ---------------------------------------------------------------------------
# Double-run determinism differ
# ---------------------------------------------------------------------------


def diff_runs(
    scenario: str, run: Callable[[TraceRecorder], None]
) -> Tuple[List[Finding], TraceRecorder]:
    """Run ``run(tracer)`` twice with fresh recorders; RT003 on divergence.

    Returns the findings plus the first run's recorder so callers can
    layer :func:`check_trace` on the same trace without a third run.
    """
    first, second = TraceRecorder(), TraceRecorder()
    run(first)
    run(second)
    findings: List[Finding] = []
    if first.digest() != second.digest():
        line = 1 + next(
            (i for i, (a, b) in enumerate(zip(first.events, second.events))
             if a != b),
            min(len(first.events), len(second.events)),
        )
        findings.append(Finding(
            rule="RT003", severity="error", path=f"<trace:{scenario}>",
            line=line,
            message=(
                f"same-seed double run of {scenario!r} diverged: "
                f"{first.digest()[:12]} != {second.digest()[:12]} "
                f"(first differing event #{line} of "
                f"{len(first.events)}/{len(second.events)})"
            ),
            pass_name=PASS_NAME,
        ))
    return findings, first


def _run_serverless(tracer: TraceRecorder) -> None:
    """Serverless fan-out with every stochastic effect switched on."""
    from repro.core.events import RuntimeConfig, ServerlessRuntime

    cfg = RuntimeConfig(
        concurrency_limit=3, cold_start_s=1.5, failure_rate=0.3,
        straggler_prob=0.3, straggler_slowdown=2.0, seed=7,
    )
    rt = ServerlessRuntime(cfg, tracer=tracer)
    for _ in range(3):  # warm pools + RNG stream persist across fan-outs
        rt.fanout([0.5, 1.0, 0.25, 0.75, 0.5, 1.25], memory_mb=1024)


def _run_cluster(tracer: TraceRecorder) -> None:
    """Async P2P cluster with churn on and a pinned virtual compute time."""
    from repro.configs import get_config
    from repro.core.simulate import LocalP2PCluster
    from repro.data import make_dataset
    from repro.optim import sgd

    cluster = LocalP2PCluster(
        get_config("squeezenet1.1"),
        make_dataset("mnist", size=64, image_hw=8, channels=1),
        num_peers=2, batch_size=8, batches_per_epoch=1,
        optimizer=sgd(momentum=0.0), lr=0.05, sync=False,
        churn_prob=0.3, churn_downtime_s=0.5,
        sim_compute_s=lambda rank, epoch: 0.1 + 0.01 * rank,
        tracer=tracer, seed=11,
    )
    for epoch in range(2):
        cluster.run_epoch_async(epoch)


def trace_pass(*, deep: bool = True) -> Tuple[List[Finding], int]:
    """Run the dynamic trace checks; returns ``(findings, scenarios_run)``.

    ``deep=False`` skips the cluster scenario (it compiles a small JAX
    model); the serverless differ is numpy-only and always runs.
    """
    scenarios: List[Tuple[str, Callable[[TraceRecorder], None]]] = [
        ("serverless-fanout-faulty", _run_serverless),
    ]
    if deep:
        scenarios.append(("p2p-cluster-async-churn", _run_cluster))
    findings: List[Finding] = []
    for name, run in scenarios:
        diff_findings, recorder = diff_runs(name, run)
        findings.extend(diff_findings)
        findings.extend(
            f for f in check_trace(recorder.events, label=f"<trace:{name}>")
            if f.severity != "info"  # engine ties are by-design (see RT002)
        )
    return findings, len(scenarios)
