"""JAX version compatibility layer.

The code targets the unified-mesh API (``jax.shard_map`` / ``jax.set_mesh``
/ ``jax.sharding.AxisType``); this container ships an older JAX where those
live under different names (``jax.experimental.shard_map``, the ``Mesh``
context manager) or don't exist at all (``AxisType``). Everything that
touches mesh/axis state goes through this module so the rest of the code
is version-agnostic:

    from repro import compat
    mesh = compat.make_mesh((4, 2), ("data", "model"),
                            axis_types=(compat.AxisType.Auto,) * 2)
    with compat.set_mesh(mesh):
        fn = compat.shard_map(body, mesh=mesh, in_specs=..., out_specs=...,
                              axis_names={"data"}, check_vma=False)

On old JAX, ``shard_map(axis_names=...)`` maps to the experimental
``auto=`` complement and records the manual axes in a context variable so
:func:`auto_axes` (used by the logical-sharding layer) still knows which
mesh axes GSPMD owns inside the manual region.
"""
from __future__ import annotations

import contextlib
import enum
from contextvars import ContextVar
from typing import Any, Optional, Sequence

import jax

try:  # new API (jax >= 0.5.x)
    from jax.sharding import AxisType  # type: ignore

    _HAS_AXIS_TYPE = True
except ImportError:  # old API
    _HAS_AXIS_TYPE = False

    class AxisType(enum.Enum):  # type: ignore[no-redef]
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"


_HAS_NEW_SHARD_MAP = hasattr(jax, "shard_map")
_HAS_SET_MESH = hasattr(jax, "set_mesh")

# Old-API bookkeeping: the *auto* (GSPMD-owned) axes of the innermost
# compat-shard_map region, set while its body traces.
_AUTO_AXES: ContextVar[Optional[frozenset]] = ContextVar("auto_axes", default=None)


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str], *,
              axis_types: Optional[Sequence[Any]] = None, devices=None):
    """``jax.make_mesh`` that tolerates old versions without ``axis_types``."""
    kw = {} if devices is None else {"devices": devices}
    if _HAS_AXIS_TYPE and axis_types is not None:
        try:
            return jax.make_mesh(axis_shapes, axis_names, axis_types=tuple(axis_types), **kw)
        except TypeError:  # AxisType exists but make_mesh predates the kwarg
            pass
    return jax.make_mesh(axis_shapes, axis_names, **kw)


@contextlib.contextmanager
def set_mesh(mesh):
    """``jax.set_mesh`` context; falls back to the ``Mesh`` context manager."""
    if _HAS_SET_MESH:
        with jax.set_mesh(mesh):
            yield mesh
    else:
        with mesh:
            yield mesh


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None, check_vma=False):
    """``jax.shard_map`` (manual over ``axis_names``) on any JAX version.

    Old JAX expresses "manual over axis_names" as the complement
    ``auto=`` set and calls the replication check ``check_rep``.
    """
    manual = frozenset(axis_names) if axis_names else frozenset(mesh.axis_names)
    if _HAS_NEW_SHARD_MAP:
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                             axis_names=manual, check_vma=check_vma)

    from jax.experimental.shard_map import shard_map as _shard_map

    # Old XLA CHECK-fails on control flow (lax.scan) inside a *partial*-auto
    # shard_map region, so the fallback runs full-manual: the would-be auto
    # axes replicate the per-peer compute instead of GSPMD-partitioning it.
    # Numerics are identical; only the intra-peer fan-out optimization is
    # lost (host meshes default those axes to size 1 anyway).
    auto: frozenset = frozenset()

    def wrapped(*args):
        token = _AUTO_AXES.set(auto)
        try:
            return f(*args)
        finally:
            _AUTO_AXES.reset(token)

    return _shard_map(wrapped, mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=bool(check_vma))


def auto_axes() -> Optional[frozenset]:
    """Mesh axes currently owned by GSPMD (Auto), or None if unknown.

    New API: read the abstract mesh's axis types. Old API: inside a compat
    ``shard_map`` the auto set recorded at trace time; elsewhere None
    (every axis behaves as auto, so callers skip filtering).
    """
    if _HAS_AXIS_TYPE:
        try:
            am = jax.sharding.get_abstract_mesh()
        except Exception:
            return _AUTO_AXES.get()
        if am is None or not am.axis_names:
            return _AUTO_AXES.get()
        try:
            return frozenset(
                n for n, t in zip(am.axis_names, am.axis_types) if t == AxisType.Auto
            )
        except Exception:
            return frozenset(am.axis_names)
    return _AUTO_AXES.get()
