"""Config registry: ``get_config(name)`` / ``--arch <id>``."""
from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import (
    ModelConfig,
    ShapeConfig,
    SHAPES,
    TRAIN_4K,
    PREFILL_32K,
    DECODE_32K,
    LONG_500K,
    reduced,
)

# arch id -> module name
_ARCH_MODULES = {
    "mamba2-370m": "mamba2_370m",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "qwen2.5-3b": "qwen2_5_3b",
    "dbrx-132b": "dbrx_132b",
    "internvl2-26b": "internvl2_26b",
    "gemma2-2b": "gemma2_2b",
    "whisper-base": "whisper_base",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "starcoder2-3b": "starcoder2_3b",
    "zamba2-1.2b": "zamba2_1_2b",
    # the paper's own models
    "vgg11": "vgg11",
    "mobilenet-v3-small": "mobilenet_v3_small",
    "squeezenet1.1": "squeezenet1_1",
}

ASSIGNED_ARCHS = tuple(list(_ARCH_MODULES)[:10])
PAPER_ARCHS = tuple(list(_ARCH_MODULES)[10:])


def get_config(name: str) -> ModelConfig:
    if name not in _ARCH_MODULES:
        raise KeyError(
            f"unknown arch {name!r}; available: {', '.join(_ARCH_MODULES)}"
        )
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[name]}")
    return mod.CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {n: get_config(n) for n in _ARCH_MODULES}


__all__ = [
    "ModelConfig",
    "ShapeConfig",
    "SHAPES",
    "TRAIN_4K",
    "PREFILL_32K",
    "DECODE_32K",
    "LONG_500K",
    "reduced",
    "get_config",
    "all_configs",
    "ASSIGNED_ARCHS",
    "PAPER_ARCHS",
]
