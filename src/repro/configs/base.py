"""Configuration system for the serverless-P2P training framework.

Two config families:

* :class:`ModelConfig` — one per architecture (the 10 assigned archs, plus
  the paper's own CNNs). A config fully determines parameter shapes, the
  per-layer block pattern, and the sharding hints used by the launcher.
* :class:`ShapeConfig` — one per assigned input shape (train_4k,
  prefill_32k, decode_32k, long_500k).

Everything is a frozen dataclass so configs are hashable and usable as
static jit arguments.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


# ---------------------------------------------------------------------------
# Per-layer block specification
# ---------------------------------------------------------------------------
# mixer:  "attn" | "attn_local" | "mamba" | "shared_attn" (weight-tied, zamba)
# ffn:    "dense" | "moe" | "none"


@dataclass(frozen=True)
class BlockSpec:
    mixer: str = "attn"
    ffn: str = "dense"


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description.

    ``d_ff`` follows the assignment sheet: for MoE archs it is the *expert*
    hidden width (fine-grained experts); for dense archs the MLP width.
    """

    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm | cnn
    source: str  # citation from the assignment sheet

    num_layers: int = 0
    d_model: int = 0
    num_heads: int = 0
    num_kv_heads: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    head_dim: int = 0  # 0 -> d_model // num_heads

    # --- attention details -------------------------------------------------
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    attn_logit_softcap: float = 0.0  # gemma2 = 50.0
    final_logit_softcap: float = 0.0  # gemma2 = 30.0
    sliding_window: int = 0  # window for "attn_local" mixers
    local_global_pattern: int = 0  # gemma2: every Nth layer is global

    # --- MoE ----------------------------------------------------------------
    num_experts: int = 0
    experts_per_token: int = 0
    router_aux_coef: float = 0.01
    moe_shared_ff: int = 0  # width of an always-on shared expert (0 = none)

    # --- SSM (Mamba2 / SSD) -------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_ngroups: int = 1
    ssm_conv: int = 4
    ssm_chunk: int = 256

    # --- hybrid (zamba2) ----------------------------------------------------
    shared_attn_every: int = 0  # insert the shared attention block every N layers

    # --- encoder/decoder (whisper) -------------------------------------------
    encoder_layers: int = 0
    encoder_seq: int = 0  # frames produced by the (stubbed) conv frontend

    # --- VLM (internvl2) ------------------------------------------------------
    vision_tokens: int = 0  # prefix embeddings from the (stubbed) ViT

    # --- CNN (paper's own models) --------------------------------------------
    cnn_variant: str = ""  # vgg11 | mobilenet_v3_small | squeezenet1_1
    image_size: int = 32
    image_channels: int = 3
    num_classes: int = 10

    # --- numerics / structure -------------------------------------------------
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    act: str = "silu"  # silu (SwiGLU) | gelu
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: bool = True

    # --- sharding hints ---------------------------------------------------------
    fsdp: bool = False  # additionally shard params over the data axis (ZeRO-3)
    serve_window: int = 0  # opt-in sliding-window serving for long_500k

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.num_heads, 1))

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 256 so embedding/unembedding
        tables shard evenly on any production mesh axis (logits are sliced
        back to ``vocab_size``)."""
        return -(-self.vocab_size // 256) * 256

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def block_specs(self) -> Tuple[BlockSpec, ...]:
        """The per-layer pattern of the decoder stack."""
        if self.family == "cnn":
            return ()
        specs = []
        for i in range(self.num_layers):
            if self.family == "ssm":
                specs.append(BlockSpec("mamba", "none"))
            elif self.family == "hybrid":
                # zamba2: mamba backbone; a weight-tied attention+MLP block is
                # applied every `shared_attn_every` layers.
                if self.shared_attn_every and (i + 1) % self.shared_attn_every == 0:
                    specs.append(BlockSpec("shared_attn", "dense"))
                else:
                    specs.append(BlockSpec("mamba", "none"))
            else:
                if self.local_global_pattern:
                    # gemma2: alternating local / global attention
                    mixer = (
                        "attn"
                        if (i % self.local_global_pattern)
                        == self.local_global_pattern - 1
                        else "attn_local"
                    )
                else:
                    mixer = "attn"
                ffn = "moe" if self.num_experts else "dense"
                specs.append(BlockSpec(mixer, ffn))
        return tuple(specs)

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        if self.family == "cnn":
            return -1  # computed from the pytree instead
        d, hd = self.d_model, self.resolved_head_dim
        n = self.vocab_size * d  # embedding
        if not self.tie_embeddings:
            n += self.vocab_size * d
        attn = d * (self.num_heads * hd) + 2 * d * (self.num_kv_heads * hd) + (
            self.num_heads * hd
        ) * d
        dense_ffn = 3 * d * self.d_ff
        moe_ffn = self.num_experts * 3 * d * self.d_ff + d * self.num_experts
        if self.moe_shared_ff:
            moe_ffn += 3 * d * self.moe_shared_ff
        mamba = 0
        if self.ssm_state:
            di, H, N, G = self.d_inner, self.ssm_heads, self.ssm_state, self.ssm_ngroups
            in_proj = d * (2 * di + 2 * G * N + H)
            mamba = in_proj + self.ssm_conv * (di + 2 * G * N) + di * d + 2 * H + di
        shared = attn + dense_ffn  # counted once if weight-tied
        tied_done = False
        for spec in self.block_specs():
            n += 2 * d  # norms
            if spec.mixer in ("attn", "attn_local"):
                n += attn
            elif spec.mixer == "mamba":
                n += mamba
            elif spec.mixer == "shared_attn":
                if not tied_done:
                    n += shared
                    tied_done = True
                continue  # ffn included in the tied block
            if spec.ffn == "dense":
                n += dense_ffn
            elif spec.ffn == "moe":
                n += moe_ffn
        if self.encoder_layers:
            n += self.encoder_layers * (2 * d + attn + dense_ffn)
            n += self.num_layers * (d + attn)  # decoder cross-attention
        return n

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only routed experts)."""
        if not self.num_experts:
            return self.param_count()
        full = self.param_count()
        d = self.d_model
        per_expert = 3 * d * self.d_ff
        inactive = (self.num_experts - self.experts_per_token) * per_expert
        return full - self.num_layers * inactive


# ---------------------------------------------------------------------------
# Input shapes (the 4 assigned shapes)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # "train" | "prefill" | "decode"


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family variant for CPU smoke tests (<=2 layers, d<=512)."""
    small = dict(
        num_layers=2,
        d_model=min(cfg.d_model, 128) or 128,
        num_heads=min(cfg.num_heads, 4) or 4,
        num_kv_heads=min(cfg.num_kv_heads, 2) or 2,
        d_ff=min(cfg.d_ff, 256) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512),
        head_dim=32 if cfg.num_heads else 0,
    )
    if cfg.num_experts:
        small.update(num_experts=4, experts_per_token=min(cfg.experts_per_token, 2))
    if cfg.ssm_state:
        small.update(ssm_state=16, ssm_headdim=32, ssm_chunk=32)
    if cfg.shared_attn_every:
        small.update(shared_attn_every=2)
    if cfg.local_global_pattern:
        small.update(local_global_pattern=2, sliding_window=64)
    if cfg.sliding_window and not cfg.local_global_pattern:
        small.update(sliding_window=64)
    if cfg.encoder_layers:
        small.update(encoder_layers=2, encoder_seq=64)
    if cfg.vision_tokens:
        small.update(vision_tokens=16)
    if cfg.moe_shared_ff:
        small.update(moe_shared_ff=64)
    small.update(name=cfg.name + "-smoke", remat=False, fsdp=False)
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
