"""dbrx-132b — MoE 16 experts top-4, fine-grained [hf:databricks/dbrx-base].

40L d_model=6144 48H (GQA kv=8) expert d_ff=10752 vocab=100352.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    source="[hf:databricks/dbrx-base]",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=10_752,
    vocab_size=100_352,
    num_experts=16,
    experts_per_token=4,
    rope_theta=500_000.0,
    fsdp=True,  # 132B params: shard weights over data axis too (ZeRO-3)
    serve_window=4_096,
)
