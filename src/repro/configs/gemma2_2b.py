"""gemma2-2b — local+global alternating attention, logit softcaps
[arXiv:2408.00118].

26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    source="Gemma 2 [arXiv:2408.00118]",
    num_layers=26,
    d_model=2304,
    num_heads=8,
    num_kv_heads=4,
    d_ff=9216,
    vocab_size=256_000,
    head_dim=256,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    sliding_window=4_096,
    local_global_pattern=2,  # every 2nd layer is global
    act="gelu",
    tie_embeddings=True,
)
