"""internvl2-26b — VLM: InternViT (stubbed frontend) + InternLM2-20B backbone
[arXiv:2404.16821].

LM backbone: 48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553.
The vision encoder + projector is a STUB per the assignment carve-out:
``input_specs()`` provides precomputed patch embeddings (vision_tokens x d).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    source="InternVL2 [arXiv:2404.16821]",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16_384,
    vocab_size=92_553,
    vision_tokens=256,  # stubbed ViT patch embeddings prepended to the text
    fsdp=True,
    serve_window=4_096,
)
