"""mamba2-370m — SSD (state-space duality) [arXiv:2405.21060].

48L d_model=1024, attention-free, vocab=50280, ssm_state=128.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    source="SSD / Mamba-2 [arXiv:2405.21060]",
    num_layers=48,
    d_model=1024,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50_280,
    ssm_state=128,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_ngroups=1,
    ssm_conv=4,
    ssm_chunk=256,
    tie_embeddings=True,
)
