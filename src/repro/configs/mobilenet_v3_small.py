"""MobileNetV3-Small — the paper's own lightweight CNN (~2.5M params).

Inverted residual blocks + squeeze-and-excitation; paper §IV-B.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mobilenet-v3-small",
    family="cnn",
    source="MobileNetV3 [Howard et al. 2019]; paper §IV-B",
    cnn_variant="mobilenet_v3_small",
    image_size=32,
    image_channels=3,
    num_classes=10,
)
