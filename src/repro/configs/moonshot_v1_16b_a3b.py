"""moonshot-v1-16b-a3b — Moonlight-style MoE, 64 experts top-6 + shared expert
[hf:moonshotai/Moonlight-16B-A3B].

48L d_model=2048 16H (GQA kv=16) expert d_ff=1408 vocab=163840.
The assignment sheet labels it [dense] but specifies "MoE 64e top-6"; we
implement the MoE as specified (fine-grained experts + one shared expert,
DeepSeek-V3-style, which Moonlight follows).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    source="Moonlight [hf:moonshotai/Moonlight-16B-A3B]",
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=163_840,
    num_experts=64,
    experts_per_token=6,
    moe_shared_ff=1408 * 2,  # always-on shared expert
    fsdp=True,
    serve_window=4_096,
)
