"""qwen2.5-3b — dense, GQA with QKV bias [hf:Qwen/Qwen2.5-0.5B family].

36L d_model=2048 16H (GQA kv=2) d_ff=11008 vocab=151936.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b",
    family="dense",
    source="[hf:Qwen/Qwen2.5-0.5B]",
    num_layers=36,
    d_model=2048,
    num_heads=16,
    num_kv_heads=2,
    d_ff=11_008,
    vocab_size=151_936,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    serve_window=4_096,  # opt-in SWA variant for long_500k serving
)
