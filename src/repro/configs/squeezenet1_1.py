"""SqueezeNet 1.1 — the paper's own smallest CNN (~1.2M params, <5MB).

Fire modules (squeeze 1x1 -> expand 1x1/3x3); paper §IV-B.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="squeezenet1.1",
    family="cnn",
    source="SqueezeNet [arXiv:1602.07360]; paper §IV-B",
    cnn_variant="squeezenet1_1",
    image_size=32,
    image_channels=3,
    num_classes=10,
)
