"""starcoder2-3b — dense, GQA + RoPE [arXiv:2402.19173].

30L d_model=3072 24H (GQA kv=2) d_ff=12288 vocab=49152.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    family="dense",
    source="StarCoder2 [arXiv:2402.19173]",
    num_layers=30,
    d_model=3072,
    num_heads=24,
    num_kv_heads=2,
    d_ff=12_288,
    vocab_size=49_152,
    qkv_bias=True,
    rope_theta=999_999.4,
    act="gelu",
    serve_window=4_096,
)
