"""VGG-11 — the paper's own heavyweight CNN [arXiv:1409.1556].

~132.9M parameters at 224x224. The paper trains it on MNIST/CIFAR on
t2.large instances; we default to 32x32 inputs (CIFAR-native) for the CPU
benchmark harness, with ``image_size=224`` available.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="vgg11",
    family="cnn",
    source="VGG [arXiv:1409.1556]; paper §IV-B",
    cnn_variant="vgg11",
    image_size=32,
    image_channels=3,
    num_classes=10,
)
