"""whisper-base — encoder-decoder, conv/mel frontend STUBBED
[arXiv:2212.04356].

6L encoder + 6L decoder, d_model=512 8H d_ff=2048 vocab=51865.
``input_specs()`` provides precomputed frame embeddings (encoder_seq x d)
per the assignment carve-out — the mel-spectrogram + conv feature extractor
is not implemented.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="encdec",
    source="Whisper [arXiv:2212.04356]",
    num_layers=6,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    d_ff=2048,
    vocab_size=51_865,
    encoder_layers=6,
    encoder_seq=1500,  # 30 s of audio after the (stubbed) conv frontend
    act="gelu",
    rope_theta=0.0,  # whisper uses learned/sinusoidal positions, not RoPE
)
