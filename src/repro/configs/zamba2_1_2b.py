"""zamba2-1.2b — hybrid: Mamba2 backbone + shared (weight-tied) attention
blocks [arXiv:2411.15242].

38L d_model=2048 32H (kv=32, MHA) d_ff=8192 vocab=32000, ssm_state=64.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    source="Zamba2 [arXiv:2411.15242]",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32_000,
    ssm_state=64,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_conv=4,
    ssm_chunk=256,
    shared_attn_every=6,  # a weight-tied attn+MLP block every 6 layers
    tie_embeddings=True,
)
