"""Core: the paper's contribution — serverless P2P distributed training."""
from repro.core.exchange import (
    ExchangeContext,
    ExchangeProtocol,
    available_exchanges,
    get_exchange,
    register_exchange,
)
from repro.core.graph import (
    PeerGraph,
    StaticGraph,
    available_graphs,
    get_graph,
    register_graph,
)
from repro.core.p2p import (
    TrainState,
    Topology,
    as_train_state,
    build_p2p_train_step,
    exchange_context,
    exchange_gradients,
    init_mailbox,
    lambda_shard,
)
from repro.core.compression import QSGDConfig, quantize_tree, dequantize_tree
from repro.core.convergence import (
    ConvergenceDetector,
    EarlyStopping,
    ReduceLROnPlateau,
)
from repro.core.cost import CommCost, InstanceCost, ServerlessCost, TPUCost
from repro.core.events import (
    AllocationPolicy,
    EventEngine,
    FanoutResult,
    InvocationRecord,
    LinkModel,
    RuntimeConfig,
    ServerlessRuntime,
    available_allocations,
    get_allocation,
    register_allocation,
)
from repro.core.mailbox import HostMailbox
from repro.core.shard import ShardPlan
from repro.core.serverless import (
    ExecutionReport,
    ServerlessExecutor,
    ServerlessPlanner,
    StepFunctionPlan,
)
from repro.core.simulate import LocalP2PCluster

__all__ = [
    "ExchangeContext",
    "ExchangeProtocol",
    "available_exchanges",
    "get_exchange",
    "register_exchange",
    "PeerGraph",
    "StaticGraph",
    "available_graphs",
    "get_graph",
    "register_graph",
    "TrainState",
    "Topology",
    "as_train_state",
    "build_p2p_train_step",
    "exchange_context",
    "exchange_gradients",
    "init_mailbox",
    "lambda_shard",
    "QSGDConfig",
    "quantize_tree",
    "dequantize_tree",
    "ConvergenceDetector",
    "EarlyStopping",
    "ReduceLROnPlateau",
    "CommCost",
    "InstanceCost",
    "ServerlessCost",
    "TPUCost",
    "AllocationPolicy",
    "EventEngine",
    "FanoutResult",
    "InvocationRecord",
    "LinkModel",
    "RuntimeConfig",
    "ServerlessRuntime",
    "available_allocations",
    "get_allocation",
    "register_allocation",
    "HostMailbox",
    "ShardPlan",
    "ExecutionReport",
    "ServerlessExecutor",
    "ServerlessPlanner",
    "StepFunctionPlan",
    "LocalP2PCluster",
]
