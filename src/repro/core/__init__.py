"""Core: the paper's contribution — serverless P2P distributed training."""
from repro.core.p2p import (
    Topology,
    build_p2p_train_step,
    exchange_gradients,
    init_mailbox,
    lambda_shard,
)
from repro.core.compression import QSGDConfig, quantize_tree, dequantize_tree
from repro.core.convergence import (
    ConvergenceDetector,
    EarlyStopping,
    ReduceLROnPlateau,
)
from repro.core.cost import InstanceCost, ServerlessCost, TPUCost
from repro.core.mailbox import HostMailbox
from repro.core.serverless import (
    ServerlessExecutor,
    ServerlessPlanner,
    StepFunctionPlan,
)
from repro.core.simulate import LocalP2PCluster

__all__ = [
    "Topology",
    "build_p2p_train_step",
    "exchange_gradients",
    "init_mailbox",
    "lambda_shard",
    "QSGDConfig",
    "quantize_tree",
    "dequantize_tree",
    "ConvergenceDetector",
    "EarlyStopping",
    "ReduceLROnPlateau",
    "InstanceCost",
    "ServerlessCost",
    "TPUCost",
    "HostMailbox",
    "ServerlessExecutor",
    "ServerlessPlanner",
    "StepFunctionPlan",
    "LocalP2PCluster",
]
