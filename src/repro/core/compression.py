"""QSGD gradient compression (Alistarh et al., NeurIPS'17) — paper §III-B.4.

For a bucket v of B elements and s quantization levels:
    Q(v_i) = ||v||_2 * sgn(v_i) * xi_i,   xi_i = (l_i + Bern(p_i)) / s
where l_i = floor(s*|v_i|/||v||) and p_i = s*|v_i|/||v|| - l_i. The estimator
is unbiased: E[Q(v)] = v (property-tested in tests/test_compression.py).

Wire format per leaf: int8 signed levels (sign folded into the level) plus
one fp32 norm per bucket -> 8 bits/element + 32/bucket_size overhead versus
32 bits/element uncompressed.

Two execution paths:
  * ``impl="jnp"``   — pure jnp (oracle / CPU).
  * ``impl="kernel"``— Pallas TPU kernel (repro/kernels/qsgd.py), validated
                        against the jnp path in interpret mode.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class QSGDConfig:
    levels: int = 127  # s; must fit in int8 with sign
    bucket: int = 2048  # elements per norm bucket
    impl: str = "jnp"  # "jnp" | "kernel"

    @property
    def bits_per_element(self) -> float:
        return 8.0 + 32.0 / self.bucket


def _pad_to_buckets(x: jnp.ndarray, bucket: int) -> Tuple[jnp.ndarray, int]:
    flat = x.reshape(-1)
    pad = (-flat.size) % bucket
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, bucket), pad


def quantize(
    x: jnp.ndarray, key: jax.Array, cfg: QSGDConfig
) -> Dict[str, jnp.ndarray]:
    """Returns {"levels": int8 (nb, bucket), "norms": f32 (nb,)} + shape meta."""
    s = cfg.levels
    if cfg.impl == "kernel":
        from repro.kernels import ops as kops

        buckets, pad = _pad_to_buckets(x.astype(jnp.float32), cfg.bucket)
        u = jax.random.uniform(key, buckets.shape, jnp.float32)
        levels, norms = kops.qsgd_quantize(buckets, u, s)
    else:
        buckets, pad = _pad_to_buckets(x.astype(jnp.float32), cfg.bucket)
        u = jax.random.uniform(key, buckets.shape, jnp.float32)
        levels, norms = qsgd_quantize_ref(buckets, u, s)
    return {
        "levels": levels,
        "norms": norms,
        "shape": np.asarray(x.shape, np.int64),
        "pad": np.int64(pad),
    }


def qsgd_quantize_ref(
    buckets: jnp.ndarray, u: jnp.ndarray, s: int
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Pure-jnp QSGD. buckets: (nb, B) f32; u: uniforms in [0,1)."""
    norms = jnp.linalg.norm(buckets, axis=-1)  # (nb,)
    safe = jnp.maximum(norms, 1e-30)[:, None]
    r = jnp.abs(buckets) / safe * s  # in [0, s]
    l = jnp.floor(r)
    p = r - l
    xi = l + (u < p).astype(jnp.float32)  # stochastic rounding
    lev = jnp.clip(xi, 0, s) * jnp.sign(buckets)
    return lev.astype(jnp.int8), norms.astype(jnp.float32)


def dequantize(payload: Dict[str, jnp.ndarray], cfg: QSGDConfig) -> jnp.ndarray:
    if cfg.impl == "kernel":
        from repro.kernels import ops as kops

        flat = kops.qsgd_dequantize(payload["levels"], payload["norms"], cfg.levels)
    else:
        flat = qsgd_dequantize_ref(payload["levels"], payload["norms"], cfg.levels)
    flat = flat.reshape(-1)
    shape = tuple(int(d) for d in np.asarray(payload["shape"]))
    n = int(np.prod(shape)) if shape else 1
    return flat[:n].reshape(shape)


def qsgd_dequantize_ref(
    levels: jnp.ndarray, norms: jnp.ndarray, s: int
) -> jnp.ndarray:
    return levels.astype(jnp.float32) * (norms[:, None] / s)


def dequant_reduce(
    levels: jnp.ndarray,  # (P, nb, bucket) int8 — gathered peer banks
    norms: jnp.ndarray,  # (P, nb) f32
    w: jnp.ndarray,  # (P,) f32 mixing weights (uniform 1/P on the full graph)
    cfg: QSGDConfig,
) -> jnp.ndarray:
    """Fused decode: ``sum_p w[p] * dequantize(levels[p], norms[p])``.

    ``impl="kernel"`` runs the single-pass Pallas kernel
    (``repro.kernels.qsgd._dequant_reduce_kernel``); ``impl="jnp"`` is the
    reduce-after-dequantize formulation (same math, reference path).
    Returns (nb, bucket) f32.
    """
    if cfg.impl == "kernel":
        from repro.kernels import ops as kops

        return kops.qsgd_dequant_reduce(levels, norms, w, cfg.levels)
    deq = levels.astype(jnp.float32) * (norms.astype(jnp.float32) / cfg.levels)[..., None]
    return jnp.tensordot(w.astype(jnp.float32), deq, axes=(0, 0))


# ---------------------------------------------------------------------------
# pytree API
# ---------------------------------------------------------------------------


def quantize_tree(tree, key: jax.Array, cfg: QSGDConfig):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(key, len(leaves))
    payloads = [quantize(x, k, cfg) for x, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, payloads), treedef


def dequantize_tree(payload_tree, cfg: QSGDConfig):
    is_payload = lambda x: isinstance(x, dict) and "levels" in x
    return jax.tree.map(
        lambda p: dequantize(p, cfg), payload_tree, is_leaf=is_payload
    )


def payload_bytes(payload_tree) -> int:
    """Wire size of the compressed gradients."""
    total = 0

    def visit(p):
        nonlocal total
        total += p["levels"].size * 1 + p["norms"].size * 4

    jax.tree.map(visit, payload_tree, is_leaf=lambda x: isinstance(x, dict) and "levels" in x)
    return total


def raw_bytes(tree) -> int:
    return sum(x.size * 4 for x in jax.tree.leaves(tree))
