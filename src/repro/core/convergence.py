"""Convergence detection — paper §III-B.7.

Two host-side controllers driven by a validation metric:
  * :class:`ReduceLROnPlateau` — PyTorch-semantics LR reduction.
  * :class:`EarlyStopping` — stop when the metric stops improving.
``ConvergenceDetector`` combines them exactly as the paper describes: LR is
reduced when improvement stalls; training stops on sustained degradation or
at the epoch limit.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional


class ReduceLROnPlateau:
    def __init__(
        self,
        lr: float,
        *,
        mode: str = "min",
        factor: float = 0.5,
        patience: int = 2,
        threshold: float = 1e-4,
        min_lr: float = 1e-6,
    ):
        if mode not in ("min", "max"):
            raise ValueError(f"mode must be 'min' or 'max', got {mode!r}")
        self.lr = lr
        self.mode = mode
        self.factor = factor
        self.patience = patience
        self.threshold = threshold
        self.min_lr = min_lr
        self.best: Optional[float] = None
        self.bad_epochs = 0
        self.num_reductions = 0

    def _improved(self, metric: float) -> bool:
        if not math.isfinite(metric):
            # NaN compares False against everything, which without this
            # guard would leave bad_epochs frozen; Inf/-Inf would become an
            # unbeatable "best". A diverged metric is always a bad epoch.
            return False
        if self.best is None:
            return True
        if self.mode == "min":
            return metric < self.best - self.threshold
        return metric > self.best + self.threshold

    def step(self, metric: float) -> float:
        """Feed one validation metric; returns the (possibly reduced) lr."""
        if self._improved(metric):
            self.best = metric
            self.bad_epochs = 0
        else:
            self.bad_epochs += 1
            if self.bad_epochs > self.patience:
                new_lr = max(self.lr * self.factor, self.min_lr)
                if new_lr < self.lr:
                    self.num_reductions += 1
                self.lr = new_lr
                self.bad_epochs = 0
        return self.lr


class EarlyStopping:
    def __init__(self, *, mode: str = "min", patience: int = 5, min_delta: float = 0.0):
        if mode not in ("min", "max"):
            raise ValueError(f"mode must be 'min' or 'max', got {mode!r}")
        self.mode = mode
        self.patience = patience
        self.min_delta = min_delta
        self.best: Optional[float] = None
        self.bad_epochs = 0
        self.stopped = False

    def step(self, metric: float) -> bool:
        """Feed one validation metric; returns True when training should stop."""
        improved = math.isfinite(metric) and (
            self.best is None
            or (self.mode == "min" and metric < self.best - self.min_delta)
            or (self.mode == "max" and metric > self.best + self.min_delta)
        )
        if improved:
            self.best = metric
            self.bad_epochs = 0
        else:
            self.bad_epochs += 1
            if self.bad_epochs >= self.patience:
                self.stopped = True
        return self.stopped


class ConvergenceDetector:
    """ReduceLROnPlateau + EarlyStopping + epoch limit (paper §III-B.7)."""

    def __init__(
        self,
        lr: float,
        *,
        mode: str = "min",
        plateau_patience: int = 2,
        stop_patience: int = 6,
        factor: float = 0.5,
        max_epochs: int = 100,
    ):
        self.plateau = ReduceLROnPlateau(
            lr, mode=mode, factor=factor, patience=plateau_patience
        )
        self.stopper = EarlyStopping(mode=mode, patience=stop_patience)
        self.max_epochs = max_epochs
        self.epoch = 0

    @property
    def lr(self) -> float:
        return self.plateau.lr

    def step(self, metric: float) -> bool:
        """Returns True when converged / should stop."""
        self.epoch += 1
        self.plateau.step(metric)
        stop = self.stopper.step(metric)
        return stop or self.epoch >= self.max_epochs
