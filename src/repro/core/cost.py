"""Cost model — reproduces the paper's Tables II/III and extends to TPU.

AWS backend (paper-faithful):
  * Lambda (ARM, the paper packages for "our custom ARM architecture"):
    $0.0000133334 per GB-second. With this constant the paper's per-second
    Lambda costs reproduce exactly: 4400 MB -> $0.0000573/s, 2800 MB ->
    $0.0000362/s, 1800 MB -> $0.0000233/s, 1700 MB -> $0.0000220/s.
  * EC2 on-demand: t2.small $0.023/h ($0.00000639/s, paper Table II),
    t2.medium $0.0464/h, t2.large $0.0928/h ($0.00002578/s, paper Table III).

  Formula (1):  cost_serverless = (lambda_cost_s * num_batches + ec2_cost_s) * T
  Formula (2):  cost_instance  = ec2_cost_s * T

TPU backend (for the roofline work): chip-seconds at an on-demand v5e rate.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

LAMBDA_USD_PER_GB_S_ARM = 0.0000133334
LAMBDA_USD_PER_REQUEST = 0.20 / 1_000_000

EC2_USD_PER_HOUR = {
    "t2.nano": 0.0058,
    "t2.micro": 0.0116,
    "t2.small": 0.023,
    "t2.medium": 0.0464,
    "t2.large": 0.0928,
    "t2.xlarge": 0.1856,
}

TPU_V5E_USD_PER_CHIP_HOUR = 1.20


def ec2_cost_per_second(instance: str) -> float:
    return EC2_USD_PER_HOUR[instance] / 3600.0


def lambda_cost_per_second(memory_mb: int) -> float:
    return (memory_mb / 1024.0) * LAMBDA_USD_PER_GB_S_ARM


@dataclass(frozen=True)
class ServerlessCost:
    """Paper formula (1) plus full invocation billing.

    Beyond the paper's ``(lambda_s * m + ec2_s) * T``, the runtime engine
    threads through what real Lambda bills: the per-request fee for every
    invocation *including retries*, the GB-seconds burned by failed
    attempts that re-executed, and cold-start init time.
    """

    compute_time_s: float
    num_batches: int
    lambda_memory_mb: int
    instance: str = "t2.small"
    include_request_fee: bool = True  # bill every invocation, like AWS does
    num_retries: int = 0  # re-invocations after failures/timeouts
    retry_billed_s: float = 0.0  # Lambda seconds burned by failed attempts
    cold_start_billed_s: float = 0.0  # container init time billed as GB-s
    # degree-aware exchange egress: bytes the peer moved on the overlay
    # this epoch (per-edge payload x degree, from the exchange accounting)
    egress_bytes: int = 0
    usd_per_gb_egress: float = 0.0

    @property
    def lambda_cost_s(self) -> float:
        return lambda_cost_per_second(self.lambda_memory_mb)

    @property
    def request_fee_usd(self) -> float:
        if not self.include_request_fee:
            return 0.0
        return LAMBDA_USD_PER_REQUEST * (self.num_batches + self.num_retries)

    @property
    def egress_usd(self) -> float:
        return self.egress_bytes / 1e9 * self.usd_per_gb_egress

    @property
    def cost_per_peer(self) -> float:
        """Formula (1) + retries + cold-start GB-s + request fees + egress."""
        c = (
            self.lambda_cost_s * self.num_batches
            + ec2_cost_per_second(self.instance)
        ) * self.compute_time_s
        c += self.lambda_cost_s * (self.retry_billed_s + self.cold_start_billed_s)
        return c + self.request_fee_usd + self.egress_usd


@dataclass(frozen=True)
class InstanceCost:
    compute_time_s: float
    instance: str = "t2.large"

    @property
    def cost_per_peer(self) -> float:
        """Paper formula (2)."""
        return ec2_cost_per_second(self.instance) * self.compute_time_s


@dataclass(frozen=True)
class CommCost:
    """Per-step gradient-exchange wire cost of one peer.

    ``wire_bytes_per_step`` comes straight from the active
    :class:`~repro.core.exchange.ExchangeProtocol`'s byte accounting
    (``protocol.wire_bytes`` / ``P2PTrainer.comm_cost`` /
    ``LocalP2PCluster.comm_cost``), so compression and sparsification show
    up in wire seconds and egress dollars without re-deriving sizes.

    Degree-aware since the PeerGraph redesign: ``bytes_per_edge`` is the
    payload on one overlay edge and ``degree`` the peer's neighbor count,
    so sparse topologies (ring: 2, gossip: k) read O(degree) per peer
    while the full mesh reads O(P). ``bytes_per_edge=0`` marks a fused
    collective (e.g. psum_mean) whose traffic doesn't decompose into
    edges — ``wire_bytes_per_step`` is then the only authoritative figure.
    """

    wire_bytes_per_step: int
    bandwidth_bps: float = 1e9  # the paper's simulated inter-peer link
    usd_per_gb_egress: float = 0.0  # e.g. S3 / inter-AZ transfer pricing
    bytes_per_edge: int = 0  # payload per overlay edge; 0 = fused/unknown
    degree: float = 0.0  # mean neighbor count under the overlay graph
    graph_name: str = "full"
    # Sharded exchange (reduce_scatter): the per-edge payload is ONE shard
    # of the flattened gradient buffer — model/P bytes — so it shrinks as
    # 1/P while dense protocols stay flat. num_shards=1 marks unsharded.
    num_shards: int = 1
    shard_bytes: int = 0  # one shard's wire payload; 0 = unsharded

    @property
    def seconds_per_step(self) -> float:
        return self.wire_bytes_per_step * 8.0 / self.bandwidth_bps

    @property
    def usd_per_step(self) -> float:
        return self.wire_bytes_per_step / 1e9 * self.usd_per_gb_egress

    def summary(self) -> str:
        s = (
            f"{self.wire_bytes_per_step/1e6:.2f} MB/peer/step on the wire "
            f"({self.seconds_per_step*1e3:.1f} ms at "
            f"{self.bandwidth_bps/1e9:g} Gb/s)"
        )
        if self.bytes_per_edge:
            s += (
                f" [{self.graph_name} graph: {self.bytes_per_edge/1e6:.2f} MB"
                f"/edge x degree {self.degree:g}]"
            )
        if self.num_shards > 1:
            s += (
                f" [sharded: {self.num_shards} shards x "
                f"{self.shard_bytes/1e6:.2f} MB]"
            )
        return s


@dataclass(frozen=True)
class TPUCost:
    """Beyond-paper: the same trade-off expressed in chip-seconds."""

    step_time_s: float
    chips: int
    usd_per_chip_hour: float = TPU_V5E_USD_PER_CHIP_HOUR

    @property
    def cost_per_step(self) -> float:
        return self.step_time_s * self.chips * self.usd_per_chip_hour / 3600.0


def paper_table2_row(batch_size: int) -> Dict[str, float]:
    """The paper's measured Table II inputs, for validation tests."""
    rows = {
        1024: dict(num_batches=15, lambda_memory_mb=4400, compute_time_s=41.2),
        512: dict(num_batches=30, lambda_memory_mb=2800, compute_time_s=28.1),
        128: dict(num_batches=118, lambda_memory_mb=1800, compute_time_s=12.9),
        64: dict(num_batches=235, lambda_memory_mb=1700, compute_time_s=10.5),
    }
    return rows[batch_size]


def paper_table3_row(batch_size: int) -> Dict[str, float]:
    rows = {
        1024: dict(compute_time_s=258.0),
        512: dict(compute_time_s=278.4),
        128: dict(compute_time_s=330.4),
        64: dict(compute_time_s=394.8),
    }
    return rows[batch_size]
