"""Cost model — reproduces the paper's Tables II/III and extends to TPU.

AWS backend (paper-faithful):
  * Lambda (ARM, the paper packages for "our custom ARM architecture"):
    $0.0000133334 per GB-second. With this constant the paper's per-second
    Lambda costs reproduce exactly: 4400 MB -> $0.0000573/s, 2800 MB ->
    $0.0000362/s, 1800 MB -> $0.0000233/s, 1700 MB -> $0.0000220/s.
  * EC2 on-demand: t2.small $0.023/h ($0.00000639/s, paper Table II),
    t2.medium $0.0464/h, t2.large $0.0928/h ($0.00002578/s, paper Table III).

  Formula (1):  cost_serverless = (lambda_cost_s * num_batches + ec2_cost_s) * T
  Formula (2):  cost_instance  = ec2_cost_s * T

TPU backend (for the roofline work): chip-seconds at an on-demand v5e rate.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

LAMBDA_USD_PER_GB_S_ARM = 0.0000133334
LAMBDA_USD_PER_REQUEST = 0.20 / 1_000_000

EC2_USD_PER_HOUR = {
    "t2.nano": 0.0058,
    "t2.micro": 0.0116,
    "t2.small": 0.023,
    "t2.medium": 0.0464,
    "t2.large": 0.0928,
    "t2.xlarge": 0.1856,
}

# t2 tier shapes (AWS docs): what the instance-baseline simulation sizes
# against — memory bounds the resident model + batch working set (the
# paper's "resource-constrained scenario" forces mini-batch splitting when
# it doesn't fit), vCPUs scale sequential gradient compute.
EC2_MEMORY_MB = {
    "t2.nano": 512,
    "t2.micro": 1024,
    "t2.small": 2048,
    "t2.medium": 4096,
    "t2.large": 8192,
    "t2.xlarge": 16384,
}

EC2_VCPUS = {
    "t2.nano": 1,
    "t2.micro": 1,
    "t2.small": 1,
    "t2.medium": 2,
    "t2.large": 2,
    "t2.xlarge": 4,
}

# ---------------------------------------------------------------------------
# GPU instance tiers (heterogeneous-fleet extension)
# ---------------------------------------------------------------------------
# The 2025 follow-up ("Cost-Performance Analysis: CPU-Based Serverless vs
# GPU-Based Training Architectures") argues the real decision space is
# CPU-serverless vs GPU instances vs mixed fleets. These are single-GPU AWS
# on-demand tiers (us-east-1 list prices at paper time): per-hour price,
# device (HBM) memory bounding the resident working set, wall-clock speedup
# of one training epoch vs the 1-vCPU CPU reference the per-batch times are
# measured on (compute-bound training), and provisioning/boot time (AMI
# pull + driver/CUDA init — materially slower than a t2 boot).

GPU_USD_PER_HOUR = {
    "g4dn.xlarge": 0.526,  # 1x T4 (16 GB)
    "g5.xlarge": 1.006,  # 1x A10G (24 GB)
    "p3.2xlarge": 3.06,  # 1x V100 (16 GB)
}

GPU_MEMORY_MB = {
    "g4dn.xlarge": 16_384,
    "g5.xlarge": 24_576,
    "p3.2xlarge": 16_384,
}

# Epoch-compute speedup vs the 1-vCPU reference machine (the same baseline
# `EC2_VCPUS` scales against), i.e. "equivalent vCPUs" of the device on
# this workload class. Conservative mid-size-CNN figures.
GPU_SPEEDUP = {
    "g4dn.xlarge": 8.0,
    "g5.xlarge": 16.0,
    "p3.2xlarge": 24.0,
}

GPU_BOOT_S = {
    "g4dn.xlarge": 60.0,
    "g5.xlarge": 60.0,
    "p3.2xlarge": 90.0,
}

# Unified tier views: every InstanceRuntime surface (pricing, memory fit,
# compute scaling) resolves tiers through these, so GPU and CPU instances
# ride the same billing/boot/churn machinery.
INSTANCE_USD_PER_HOUR = {**EC2_USD_PER_HOUR, **GPU_USD_PER_HOUR}
INSTANCE_MEMORY_MB = {**EC2_MEMORY_MB, **GPU_MEMORY_MB}


def is_gpu_instance(instance: str) -> bool:
    return instance in GPU_USD_PER_HOUR


def instance_equivalent_vcpus(instance: str) -> float:
    """Compute speed of a tier in 1-vCPU-reference units: vCPU count for
    CPU tiers, the measured epoch speedup for GPU tiers."""
    if instance in GPU_SPEEDUP:
        return GPU_SPEEDUP[instance]
    return float(EC2_VCPUS[instance])


TPU_V5E_USD_PER_CHIP_HOUR = 1.20


def working_set_mb(
    model_bytes: int, batch_bytes: int, overhead_mb: float = 0.0
) -> float:
    """Resident working set of one training step, in MB: params + grads
    (2x model) + activations (~3x one batch) + runtime overhead. The ONE
    sizing model shared by ``ServerlessPlanner.lambda_memory_mb`` (Lambda
    tier fit) and ``repro.core.instance.instance_splits`` (EC2
    mini-batch splitting), so the two backends' memory stories cannot
    drift apart."""
    return (2 * model_bytes + 3 * batch_bytes) / 1e6 + overhead_mb


def ec2_cost_per_second(instance: str) -> float:
    """Per-second on-demand price of any instance tier — CPU (t2.*) or GPU
    (g4dn/g5/p3) — so :class:`InstanceCost` prices GPU fleets unchanged."""
    return INSTANCE_USD_PER_HOUR[instance] / 3600.0


def lambda_cost_per_second(memory_mb: int) -> float:
    return (memory_mb / 1024.0) * LAMBDA_USD_PER_GB_S_ARM


@dataclass(frozen=True)
class ServerlessCost:
    """Paper formula (1) plus full invocation billing.

    Beyond the paper's ``(lambda_s * m + ec2_s) * T``, the runtime engine
    threads through what real Lambda bills: the per-request fee for every
    invocation *including retries*, the GB-seconds burned by failed
    attempts that re-executed, and cold-start init time.
    """

    compute_time_s: float
    num_batches: int
    lambda_memory_mb: int
    instance: str = "t2.small"
    include_request_fee: bool = True  # bill every invocation, like AWS does
    num_retries: int = 0  # re-invocations after failures/timeouts
    retry_billed_s: float = 0.0  # Lambda seconds burned by failed attempts
    cold_start_billed_s: float = 0.0  # container init time billed as GB-s
    # degree-aware exchange egress: bytes the peer moved on the overlay
    # this epoch (per-edge payload x degree, from the exchange accounting)
    egress_bytes: int = 0
    usd_per_gb_egress: float = 0.0

    @property
    def lambda_cost_s(self) -> float:
        return lambda_cost_per_second(self.lambda_memory_mb)

    @property
    def request_fee_usd(self) -> float:
        if not self.include_request_fee:
            return 0.0
        return LAMBDA_USD_PER_REQUEST * (self.num_batches + self.num_retries)

    @property
    def egress_usd(self) -> float:
        return self.egress_bytes / 1e9 * self.usd_per_gb_egress

    @property
    def cost_per_peer(self) -> float:
        """Formula (1) + retries + cold-start GB-s + request fees + egress."""
        c = (
            self.lambda_cost_s * self.num_batches
            + ec2_cost_per_second(self.instance)
        ) * self.compute_time_s
        c += self.lambda_cost_s * (self.retry_billed_s + self.cold_start_billed_s)
        return c + self.request_fee_usd + self.egress_usd


@dataclass(frozen=True)
class InstanceCost:
    """Paper formula (2) plus full per-second EC2 billing.

    The analytic form — ``ec2_cost_s * T`` with every engine field at its
    zero default — is exactly the paper's Formula (2). The engine-priced
    variant (:class:`repro.core.instance.InstanceRuntime`) additionally
    bills what a real VM fleet bills: boot/provisioning time (the meter
    runs while the stack starts) and idle time (e.g. waiting at the sync
    barrier for slower peers), while churn ``unbilled_downtime_s`` — the
    gap between a VM dying and its replacement starting to boot — extends
    the wall-clock without extending the bill.
    """

    compute_time_s: float  # busy seconds: batches + churn redos + wire time
    instance: str = "t2.large"
    boot_s: float = 0.0  # provisioning/boot seconds (billed)
    idle_s: float = 0.0  # billed-but-idle seconds (barrier wait)
    unbilled_downtime_s: float = 0.0  # churn gaps with no VM running

    @property
    def billed_s(self) -> float:
        return self.compute_time_s + self.boot_s + self.idle_s

    @property
    def wall_time_s(self) -> float:
        return self.billed_s + self.unbilled_downtime_s

    @property
    def cost_per_peer(self) -> float:
        """Paper formula (2); boot/idle extend T, downtime never does."""
        return ec2_cost_per_second(self.instance) * self.billed_s


@dataclass(frozen=True)
class CommCost:
    """Per-step gradient-exchange wire cost of one peer.

    ``wire_bytes_per_step`` comes straight from the active
    :class:`~repro.core.exchange.ExchangeProtocol`'s byte accounting
    (``protocol.wire_bytes`` / ``P2PTrainer.comm_cost`` /
    ``LocalP2PCluster.comm_cost``), so compression and sparsification show
    up in wire seconds and egress dollars without re-deriving sizes.

    Degree-aware since the PeerGraph redesign: ``bytes_per_edge`` is the
    payload on one overlay edge and ``degree`` the peer's neighbor count,
    so sparse topologies (ring: 2, gossip: k) read O(degree) per peer
    while the full mesh reads O(P). ``bytes_per_edge=0`` marks a fused
    collective (e.g. psum_mean) whose traffic doesn't decompose into
    edges — ``wire_bytes_per_step`` is then the only authoritative figure.
    """

    wire_bytes_per_step: int
    bandwidth_bps: float = 1e9  # the paper's simulated inter-peer link
    usd_per_gb_egress: float = 0.0  # e.g. S3 / inter-AZ transfer pricing
    bytes_per_edge: int = 0  # payload per overlay edge; 0 = fused/unknown
    degree: float = 0.0  # mean neighbor count under the overlay graph
    graph_name: str = "full"
    # Sharded exchange (reduce_scatter): the per-edge payload is ONE shard
    # of the flattened gradient buffer — model/P bytes — so it shrinks as
    # 1/P while dense protocols stay flat. num_shards=1 marks unsharded.
    num_shards: int = 1
    shard_bytes: int = 0  # one shard's wire payload; 0 = unsharded

    @property
    def seconds_per_step(self) -> float:
        return self.wire_bytes_per_step * 8.0 / self.bandwidth_bps

    @property
    def usd_per_step(self) -> float:
        return self.wire_bytes_per_step / 1e9 * self.usd_per_gb_egress

    def summary(self) -> str:
        s = (
            f"{self.wire_bytes_per_step/1e6:.2f} MB/peer/step on the wire "
            f"({self.seconds_per_step*1e3:.1f} ms at "
            f"{self.bandwidth_bps/1e9:g} Gb/s)"
        )
        if self.bytes_per_edge:
            s += (
                f" [{self.graph_name} graph: {self.bytes_per_edge/1e6:.2f} MB"
                f"/edge x degree {self.degree:g}]"
            )
        if self.num_shards > 1:
            s += (
                f" [sharded: {self.num_shards} shards x "
                f"{self.shard_bytes/1e6:.2f} MB]"
            )
        return s


@dataclass(frozen=True)
class TPUCost:
    """Beyond-paper: the same trade-off expressed in chip-seconds."""

    step_time_s: float
    chips: int
    usd_per_chip_hour: float = TPU_V5E_USD_PER_CHIP_HOUR

    @property
    def cost_per_step(self) -> float:
        return self.step_time_s * self.chips * self.usd_per_chip_hour / 3600.0


# ---------------------------------------------------------------------------
# CostReport — the unified cost–time frontier API
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class CostReport:
    """One backend's (wall-clock, dollars) point for one peer-epoch.

    The common currency between :class:`ServerlessCost` and the
    engine-priced :class:`InstanceCost`: both execution paths reduce their
    accounting to a ``CostReport`` (``ExecutionReport.cost_report()``), so
    the paper's headline comparison — serverless up to 97.34% faster at up
    to 5.4x the cost — is a pair of these and two method calls.
    """

    backend: str  # "serverless" | "instance" | "fleet" (heterogeneous mix)
    wall_time_s: float
    cost_usd: float  # per peer per epoch
    instance: str = ""  # EC2 tier (baseline VM or serverless orchestrator)
    lambda_memory_mb: int = 0  # serverless only
    num_peers: int = 1
    label: str = ""  # free-form scenario tag for frontier plots

    @property
    def total_usd(self) -> float:
        """Whole-cluster epoch cost (every peer pays its own bill)."""
        return self.cost_usd * self.num_peers

    def speedup_pct_vs(self, baseline: "CostReport") -> float:
        """Wall-clock improvement over ``baseline``, in percent (the
        paper's 97.34% figure is ``serverless.speedup_pct_vs(instance)``)."""
        if baseline.wall_time_s <= 0.0:
            return 0.0
        return 100.0 * (1.0 - self.wall_time_s / baseline.wall_time_s)

    def cost_multiple_vs(self, baseline: "CostReport") -> float:
        """Dollar multiple over ``baseline`` (the paper's 5.4x figure)."""
        if baseline.cost_usd <= 0.0:
            return float("inf") if self.cost_usd > 0 else 1.0
        return self.cost_usd / baseline.cost_usd

    def summary(self) -> str:
        s = f"{self.backend}: wall {self.wall_time_s:.2f}s ${self.cost_usd:.6f}/peer/epoch"
        if self.lambda_memory_mb:
            s += f" ({self.lambda_memory_mb}MB Lambda)"
        if self.instance:
            s += f" [{self.instance}]"
        return s


def compare_backends(
    serverless: CostReport,
    instance: CostReport,
    fleet: Optional[CostReport] = None,
) -> Dict[str, float]:
    """The paper's headline comparison as one dict: speedup % and cost
    multiple of the serverless point over the instance baseline, plus the
    raw coordinates of both points (handy for JSON benchmark records).

    ``fleet`` mode: pass a third (heterogeneous-fleet) point and the dict
    additionally carries its coordinates and its speedup/cost-multiple
    over the same instance baseline — the three-way comparison the
    auto-scheduler navigates (fig14)."""
    out = {
        "speedup_pct": serverless.speedup_pct_vs(instance),
        "cost_multiple": serverless.cost_multiple_vs(instance),
        "serverless_wall_s": serverless.wall_time_s,
        "instance_wall_s": instance.wall_time_s,
        "serverless_usd": serverless.cost_usd,
        "instance_usd": instance.cost_usd,
    }
    if fleet is not None:
        out.update({
            "fleet_wall_s": fleet.wall_time_s,
            "fleet_usd": fleet.cost_usd,
            "fleet_speedup_pct": fleet.speedup_pct_vs(instance),
            "fleet_cost_multiple": fleet.cost_multiple_vs(instance),
        })
    return out


def dominates(a: CostReport, b: CostReport) -> bool:
    """True iff ``a`` Pareto-dominates ``b``: at least as fast AND at
    least as cheap, strictly better in at least one coordinate. Two points
    with identical coordinates never dominate each other."""
    return (
        a.wall_time_s <= b.wall_time_s
        and a.cost_usd <= b.cost_usd
        and (a.wall_time_s < b.wall_time_s or a.cost_usd < b.cost_usd)
    )


def _frontier_key(p: CostReport):
    # A TOTAL order over CostReports: (wall, cost) first, then every
    # identity field as a deterministic tie-break — so equal-coordinate
    # points sort the same way under any input permutation and the
    # frontier's membership/order never depends on arrival order.
    return (
        p.wall_time_s, p.cost_usd,
        p.backend, p.instance, p.label, p.lambda_memory_mb, p.num_peers,
    )


def pareto_frontier(points: Sequence[CostReport]) -> List[CostReport]:
    """The non-dominated subset of (wall_time_s, cost_usd) points, sorted
    by wall-clock ascending — the cost–time frontier a deployment actually
    chooses from. A point survives iff no other point is at least as fast
    AND at least as cheap (strictly better in one coordinate).

    Coordinate ties are kept, not evicted: two reports with equal wall AND
    equal cost do not dominate each other, so both stay on the frontier
    (previously the later-sorted one was silently dropped, which made the
    frontier's membership depend on input order)."""
    pts = sorted(points, key=_frontier_key)
    frontier: List[CostReport] = []
    best_cost = float("inf")
    best_wall = float("inf")
    for p in pts:
        if p.cost_usd < best_cost:
            frontier.append(p)
            best_cost = p.cost_usd
            best_wall = p.wall_time_s
        # intentionally EXACT: only bit-identical coordinates are mutual
        # non-domination ties; approximate ties are real dominations
        elif p.cost_usd == best_cost and p.wall_time_s == best_wall:  # noqa: RA006
            # exact coordinate tie with the last frontier point: mutually
            # non-dominated, keep both
            frontier.append(p)
    return frontier


def paper_table2_row(batch_size: int) -> Dict[str, float]:
    """The paper's measured Table II inputs, for validation tests."""
    rows = {
        1024: dict(num_batches=15, lambda_memory_mb=4400, compute_time_s=41.2),
        512: dict(num_batches=30, lambda_memory_mb=2800, compute_time_s=28.1),
        128: dict(num_batches=118, lambda_memory_mb=1800, compute_time_s=12.9),
        64: dict(num_batches=235, lambda_memory_mb=1700, compute_time_s=10.5),
    }
    return rows[batch_size]


def paper_table3_row(batch_size: int) -> Dict[str, float]:
    rows = {
        1024: dict(compute_time_s=258.0),
        512: dict(compute_time_s=278.4),
        128: dict(compute_time_s=330.4),
        64: dict(compute_time_s=394.8),
    }
    return rows[batch_size]
