"""Unified discrete-event ServerlessRuntime — the time model under both
serverless execution paths.

Before this module existed the repo carried two divergent time models: the
closed-form accounting in ``ServerlessExecutor.run`` (``max(batch_time /
speedup) + fixed overheads``) and an ad-hoc heapq loop inside
``LocalP2PCluster.run_epoch_async``. Neither could express what real
serverless training is dominated by (arXiv:2105.07806): cold starts,
invocation-level variance, concurrency throttling, and failures
(arXiv:2309.14148, SPIRT). This module replaces both with one seeded
discrete-event engine plus a runtime layered on top of it:

* :class:`EventEngine` — a deterministic event heap ordered by
  ``(time, priority, insertion seq)``. The priority slot reproduces the old
  async loop's ``(clock, rank)`` tie-breaking bit-for-bit.
* :class:`RuntimeConfig` — the fault/cold-start/concurrency knobs. The
  default config is *ideal* (no faults, no cold starts, unbounded
  concurrency) and reproduces the old analytic wall-times exactly;
  :meth:`RuntimeConfig.aws_default` is a realistic Lambda preset.
* :class:`ServerlessRuntime` — simulates a per-peer Lambda fan-out on the
  engine: warm-container reuse pools keyed by ``(function, memory tier)``,
  concurrency caps with FIFO queueing, per-attempt failures retried with
  exponential backoff, and seeded straggler tail latency. Emits
  per-invocation :class:`InvocationRecord` stage timings (queue wait /
  cold start / retry) that feed ``StageMetrics``, ``ExecutionReport`` and
  ``ServerlessCost``.
* :class:`AllocationPolicy` registry — pluggable per-epoch Lambda memory
  re-sizing from the previous epoch's measured per-batch times: the
  paper's "dynamic resource allocation" made concrete. Mirrors the
  ``ExchangeProtocol`` registry pattern.

The module is dependency-light on purpose (numpy + stdlib): it knows
nothing about JAX, gradients, or dollars — callers translate.
"""
from __future__ import annotations

import abc
import heapq
import math
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, ClassVar, Dict, List, Optional, Sequence, Tuple, Type

import numpy as np


# ---------------------------------------------------------------------------
# Event engine
# ---------------------------------------------------------------------------


class EventEngine:
    """Deterministic discrete-event scheduler.

    Events fire in ``(time, priority, insertion order)`` order; callbacks
    may schedule further events. ``rng`` is a seeded numpy Generator shared
    by every stochastic model riding on the engine, so a fixed seed fixes
    the whole simulation.
    """

    def __init__(
        self,
        *,
        seed: int = 0,
        rng: Optional[np.random.Generator] = None,
        tracer: Optional[Any] = None,
    ):
        self.now = 0.0
        self.processed = 0
        self.rng = rng if rng is not None else np.random.default_rng(seed)
        # Optional repro.analysis.trace.TraceRecorder: records every
        # schedule/fire for the happens-before / determinism checkers.
        # None (the default) keeps the hot loop allocation-free.
        self.tracer = tracer
        if tracer is not None:
            tracer.record("engine", time=self.now, seeded=True)
        self._heap: List[Tuple[float, int, int, Callable[[], None]]] = []
        self._seq = 0

    def schedule_at(self, time: float, fn: Callable[[], None], *, priority: int = 0):
        """Schedule ``fn`` at absolute ``time`` (clamped to not run in the past)."""
        t = max(float(time), self.now)
        if self.tracer is not None:
            self.tracer.record(
                "schedule", time=self.now, at=t, priority=priority, seq=self._seq
            )
        heapq.heappush(self._heap, (t, priority, self._seq, fn))
        self._seq += 1

    def schedule_in(self, delay: float, fn: Callable[[], None], *, priority: int = 0):
        self.schedule_at(self.now + delay, fn, priority=priority)

    def reset(self, now: float = 0.0):
        """Rewind the clock between independent simulation rounds."""
        if self._heap:
            raise RuntimeError("cannot reset an engine with pending events")
        self.now = float(now)

    def run(self) -> float:
        """Process events until the heap drains; returns the final clock."""
        while self._heap:
            t, prio, seq, fn = heapq.heappop(self._heap)
            self.now = t
            self.processed += 1
            if self.tracer is not None:
                self.tracer.record("fire", time=t, priority=prio, seq=seq)
            fn()
        return self.now


# ---------------------------------------------------------------------------
# Link model
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LinkModel:
    """Wire time of one message on a simulated inter-peer link.

    Upload/download charging for event-driven simulations goes through
    ``transfer_s``: the P2P cluster charges one per publish and one per
    edge-respecting consume (``HostMailbox.download_time_s(link=...)``
    adds the S3 round trip on top for indirected payloads), so with a
    sparse overlay graph a peer's per-step wire time is O(degree) rather
    than O(P).
    """

    bandwidth_bps: float = 1e9
    per_message_overhead_s: float = 0.0  # broker hop / TLS / framing

    def transfer_s(self, nbytes: int) -> float:
        return nbytes * 8.0 / self.bandwidth_bps + self.per_message_overhead_s


# ---------------------------------------------------------------------------
# Runtime configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class RuntimeConfig:
    """Fault/cold-start/concurrency model of the simulated Lambda service.

    The zero-argument default is the *ideal* runtime — no cold starts, no
    failures, no stragglers, unbounded concurrency — under which the engine
    reproduces the legacy closed-form accounting exactly (see the
    equivalence tests). Every effect is opt-in.
    """

    concurrency_limit: Optional[int] = None  # None = unbounded fan-out
    cold_start_s: float = 0.0  # container init time added to a cold invocation
    container_keepalive_s: float = 900.0  # warm pool idle TTL
    failure_rate: float = 0.0  # P(an attempt fails)
    failure_runtime_frac: float = 1.0  # fraction of the attempt burned before failing
    max_retries: int = 4  # retry budget per invocation
    retry_backoff_s: float = 0.5  # backoff = base * 2**(attempt-1)
    straggler_prob: float = 0.0  # P(invocation draws a tail latency)
    straggler_slowdown: float = 3.0  # mean extra slowdown (exponential tail)
    seed: int = 0

    @staticmethod
    def ideal() -> "RuntimeConfig":
        return RuntimeConfig()

    @staticmethod
    def aws_default() -> "RuntimeConfig":
        """Realistic Lambda figures: 1000 default account concurrency,
        seconds-scale cold starts, rare crashes, occasional stragglers."""
        return RuntimeConfig(
            concurrency_limit=1000,
            cold_start_s=2.5,
            failure_rate=0.005,
            straggler_prob=0.02,
        )


@dataclass(frozen=True)
class InstanceConfig:
    """Provisioning/boot/churn model of the simulated EC2 fleet — the
    conventional instance-based P2P baseline's counterpart to
    :class:`RuntimeConfig`.

    The zero-argument default is the *ideal* fleet — instant boot, no
    churn — under which :class:`repro.core.instance.InstanceRuntime`
    reproduces the legacy closed-form Formula-(2) accounting exactly (see
    the equivalence tests). Every effect is opt-in, mirroring the
    serverless config.
    """

    boot_s: float = 0.0  # VM provision + boot delay before the first batch
    churn_prob: float = 0.0  # P(the VM dies while computing one batch)
    churn_downtime_s: float = 0.0  # detection + replacement gap (not billed)
    max_churn_redos: int = 5  # then the VM is forcibly kept up (epochs end)
    seed: int = 0

    @staticmethod
    def ideal() -> "InstanceConfig":
        return InstanceConfig()

    @staticmethod
    def aws_default() -> "InstanceConfig":
        """Realistic EC2 figures: tens-of-seconds boot (image pull + stack
        start), rare spot-style interruptions with a detection delay."""
        return InstanceConfig(
            boot_s=40.0,
            churn_prob=0.002,
            churn_downtime_s=30.0,
        )


@dataclass
class InstanceEpochResult:
    """Stage-level timing of one simulated instance-backend peer epoch.

    ``billed_s`` partitions cleanly: boot + compute + redo + wire + idle
    are billed (per-second EC2 billing runs whenever a VM exists, idle or
    not); ``downtime_s`` — the gap between a churn death and the
    replacement VM starting to boot — is the one unbilled component.
    ``makespan_s`` is the full wall-clock including that downtime.
    """

    makespan_s: float = 0.0  # epoch submit -> last event, incl. downtime
    boot_s: float = 0.0  # provisioning time paid (first boot + churn reboots)
    compute_s: float = 0.0  # productive batch execution (incl. split overhead)
    redo_s: float = 0.0  # partial batch work lost to churn, re-executed
    downtime_s: float = 0.0  # churn gaps with no VM running (NOT billed)
    wire_s: float = 0.0  # exchange upload + degree-many downloads on the link
    idle_s: float = 0.0  # billed-but-idle (e.g. sync-barrier wait)
    churn_drops: int = 0
    splits: int = 1  # micro-batches per batch under memory pressure

    @property
    def billed_s(self) -> float:
        """EC2-billed seconds: everything a running VM existed for."""
        return self.boot_s + self.compute_s + self.redo_s + self.wire_s + self.idle_s


# ---------------------------------------------------------------------------
# Per-invocation records
# ---------------------------------------------------------------------------


@dataclass
class InvocationRecord:
    """Stage-level timing of one simulated Lambda invocation."""

    index: int
    memory_mb: int
    submit_s: float
    start_s: float = 0.0  # first attempt's start
    end_s: float = 0.0  # successful completion
    exec_s: float = 0.0  # successful attempt's execution (incl. straggler factor)
    download_s: float = 0.0  # payload fetch time (e.g. shard pieces from S3)
    queue_wait_s: float = 0.0  # total time spent throttled, all attempts
    cold_start_s: float = 0.0  # container init time burned, all attempts
    cold_starts: int = 0
    straggler_factor: float = 1.0
    attempts: int = 0
    retries: int = 0
    backoff_s: float = 0.0  # total backoff waiting between attempts
    failed_s: float = 0.0  # post-init execution burned by failed attempts
    billed_s: float = 0.0  # Lambda-billed seconds across all attempts


@dataclass
class FanoutResult:
    """Outcome of one fan-out (one peer epoch) on the runtime."""

    makespan_s: float  # submit -> last completion
    memory_mb: int
    invocations: List[InvocationRecord]

    @property
    def num_cold_starts(self) -> int:
        return sum(r.cold_starts for r in self.invocations)

    @property
    def num_retries(self) -> int:
        return sum(r.retries for r in self.invocations)

    @property
    def cold_start_s_total(self) -> float:
        return sum(r.cold_start_s for r in self.invocations)

    @property
    def queue_wait_s_total(self) -> float:
        return sum(r.queue_wait_s for r in self.invocations)

    @property
    def retry_s_total(self) -> float:
        """Time burned recovering from failures: dead work + backoff."""
        return sum(r.failed_s + r.backoff_s for r in self.invocations)

    @property
    def billed_s_total(self) -> float:
        return sum(r.billed_s for r in self.invocations)

    @property
    def max_exec_s(self) -> float:
        return max((r.exec_s for r in self.invocations), default=0.0)


class FanoutTimeout(RuntimeError):
    """An invocation exhausted its retry budget against the hard timeout."""


# ---------------------------------------------------------------------------
# Warm-container pool
# ---------------------------------------------------------------------------


class _ContainerPool:
    """Warm containers keyed by (function, memory tier), AWS-style LIFO reuse.

    A container freed at ``t0`` can serve a new invocation at ``t`` iff
    ``t0 <= t <= t0 + keepalive``. Changing the memory tier (dynamic
    allocation) strands the old tier's pool — re-sizing pays cold starts
    again, which is exactly the trade-off an AllocationPolicy navigates.
    """

    def __init__(self, keepalive_s: float):
        self.keepalive_s = keepalive_s
        self._idle: Dict[Tuple[Any, int], List[float]] = {}

    def acquire(self, key: Tuple[Any, int], at: float) -> bool:
        """True -> warm container reused; False -> cold start."""
        idle = self._idle.get(key, [])
        # prune expired, then take the most recently used warm container
        idle = [t for t in idle if at - t <= self.keepalive_s]
        best = None
        for i, t in enumerate(idle):
            if t <= at and (best is None or t > idle[best]):
                best = i
        if best is None:
            self._idle[key] = idle
            return False
        idle.pop(best)
        self._idle[key] = idle
        return True

    def release(self, key: Tuple[Any, int], at: float):
        self._idle.setdefault(key, []).append(at)


# ---------------------------------------------------------------------------
# ServerlessRuntime
# ---------------------------------------------------------------------------


class ServerlessRuntime:
    """Simulates Lambda fan-outs on the event engine.

    One runtime instance persists warm pools and the RNG stream across
    fan-outs (epochs), so container reuse and fault sampling behave like a
    long-lived deployment; a fixed ``RuntimeConfig.seed`` makes the whole
    trajectory deterministic.
    """

    def __init__(
        self, config: Optional[RuntimeConfig] = None, *, tracer: Optional[Any] = None
    ):
        self.config = config or RuntimeConfig()
        self.rng = np.random.default_rng(self.config.seed)
        self.pool = _ContainerPool(self.config.container_keepalive_s)
        self.tracer = tracer  # optional repro.analysis.trace.TraceRecorder
        self.fanouts_run = 0
        self.clock = 0.0  # deployment-lifetime clock; warm pools live on it

    def fanout(
        self,
        exec_times_s: Sequence[float],
        *,
        memory_mb: int,
        function_key: Any = 0,
        invoke_overhead_s: float = 0.0,
        timeout_s: Optional[float] = None,
        submit_time: Optional[float] = None,
        download_bytes: Optional[Sequence[int]] = None,
        link: Optional[LinkModel] = None,
    ) -> FanoutResult:
        """Simulate one fan-out of ``len(exec_times_s)`` invocations.

        ``exec_times_s`` are warm, straggler-free execution times (already
        scaled to the memory tier's vCPU share). ``submit_time`` defaults
        to the runtime's own clock, which advances past each fan-out — so
        containers freed by one epoch are warm (within the keepalive TTL)
        for the next. ``download_bytes`` (with ``link``) charges each
        invocation a payload fetch — e.g. a sharded aggregator downloading
        its P-1 shard pieces before reducing them — billed like execution
        and re-paid on retries. Returns the makespan and per-invocation
        stage records; all record times are absolute on the runtime clock.
        """
        cfg = self.config
        if submit_time is None:
            submit_time = self.clock
        engine = EventEngine(rng=self.rng, tracer=self.tracer)
        engine.now = float(submit_time)
        key = (function_key, int(memory_mb))
        records = [
            InvocationRecord(index=i, memory_mb=int(memory_mb), submit_s=submit_time)
            for i in range(len(exec_times_s))
        ]
        capacity = cfg.concurrency_limit or math.inf
        state = {"running": 0, "last_end": submit_time}
        waiting: deque = deque()  # FIFO throttle queue of (index, enqueue time)

        def try_start(i: int):
            if state["running"] < capacity:
                state["running"] += 1
                start_attempt(i)
            else:
                waiting.append((i, engine.now))

        def release_slot():
            state["running"] -= 1
            if waiting:
                i, t_enq = waiting.popleft()
                records[i].queue_wait_s += engine.now - t_enq
                state["running"] += 1
                start_attempt(i)

        def start_attempt(i: int):
            rec = records[i]
            rec.attempts += 1
            if rec.attempts == 1:
                rec.start_s = engine.now
                if cfg.straggler_prob > 0.0 and engine.rng.random() < cfg.straggler_prob:
                    rec.straggler_factor = 1.0 + engine.rng.exponential(
                        cfg.straggler_slowdown
                    )
            cold = not self.pool.acquire(key, engine.now)
            init_s = cfg.cold_start_s if cold else 0.0
            if cold:
                rec.cold_starts += 1
            dl_s = 0.0
            if download_bytes is not None and link is not None:
                dl_s = link.transfer_s(int(download_bytes[i]))
            exec_s = exec_times_s[i] * rec.straggler_factor + dl_s
            duration = init_s + invoke_overhead_s + exec_s
            out_of_retries = rec.attempts > cfg.max_retries
            timed_out = timeout_s is not None and duration > timeout_s
            failed = timed_out or (
                cfg.failure_rate > 0.0
                and not out_of_retries
                and engine.rng.random() < cfg.failure_rate
            )
            if failed and timed_out and out_of_retries:
                raise FanoutTimeout(
                    f"invocation {i} still exceeds the {timeout_s:.0f}s timeout "
                    f"after {cfg.max_retries} retries on a {memory_mb}MB function"
                )
            if failed:
                run_for = min(
                    duration * cfg.failure_runtime_frac,
                    timeout_s if timed_out else duration,
                )
                # split the burn so cold_start_s and failed_s partition the
                # attempt's time (no double-billing downstream): init burns
                # first, whatever remains was dead execution
                burned_init = min(run_for, init_s)
                rec.cold_start_s += burned_init
                rec.failed_s += run_for - burned_init
                rec.billed_s += run_for
                rec.retries += 1
                backoff = cfg.retry_backoff_s * (2.0 ** (rec.attempts - 1))
                rec.backoff_s += backoff
                # a crashed/timed-out container is not returned to the pool
                # the slot frees when the attempt dies; the retry re-enters
                # admission (FIFO) after its backoff
                engine.schedule_at(engine.now + run_for, release_slot)
                engine.schedule_at(engine.now + run_for + backoff, lambda i=i: try_start(i))
                # a straggler that burned its retry budget against the hard
                # timeout is forced back to nominal speed so the redo can fit
                if timed_out and rec.attempts >= cfg.max_retries:
                    rec.straggler_factor = 1.0
                return
            rec.cold_start_s += init_s
            rec.exec_s = exec_s
            rec.download_s = dl_s
            rec.billed_s += duration

            def complete(i=i, duration=duration):
                rec = records[i]
                rec.end_s = engine.now
                state["last_end"] = max(state["last_end"], engine.now)
                self.pool.release(key, engine.now)
                release_slot()

            engine.schedule_at(engine.now + duration, complete)

        for i in range(len(exec_times_s)):
            engine.schedule_at(submit_time, lambda i=i: try_start(i))
        engine.run()
        self.fanouts_run += 1
        self.clock = max(self.clock, state["last_end"])
        if self.tracer is not None:
            self.tracer.record(
                "fanout",
                time=state["last_end"],
                invocations=len(records),
                cold_starts=sum(r.cold_starts for r in records),
                retries=sum(r.retries for r in records),
            )
        return FanoutResult(
            makespan_s=state["last_end"] - submit_time,
            memory_mb=int(memory_mb),
            invocations=records,
        )


# ---------------------------------------------------------------------------
# AllocationPolicy registry (mirrors the ExchangeProtocol registry)
# ---------------------------------------------------------------------------


class AllocationPolicy(abc.ABC):
    """Per-epoch Lambda memory sizing — the paper's "dynamic resource
    allocation" as a pluggable policy.

    ``memory_mb`` sees the planner's static minimum (the smallest tier the
    model fits in) and the peer's fan-out history, and returns a memory
    suggestion; the executor clamps it to ``[planned_mb, LAMBDA cap]`` and
    rounds to the 64 MB tier grid. Lambda vCPU share scales linearly with
    memory, so raising memory buys wall-time at a dollar premium — the
    paper's headline time/cost trade-off.
    """

    name: ClassVar[str] = "?"  # set by @register_allocation

    @abc.abstractmethod
    def memory_mb(
        self, *, epoch: int, planned_mb: int, history: Sequence[FanoutResult]
    ) -> int:
        """Return the memory size for this epoch's fan-out."""

    def describe(self) -> str:
        return (self.__doc__ or "").strip().splitlines()[0] if self.__doc__ else ""


_ALLOC_REGISTRY: Dict[str, Type[AllocationPolicy]] = {}


def register_allocation(name: str):
    """Class decorator: make a policy reachable by name everywhere."""

    def deco(cls: Type[AllocationPolicy]) -> Type[AllocationPolicy]:
        if not issubclass(cls, AllocationPolicy):
            raise TypeError(f"{cls!r} must subclass AllocationPolicy")
        cls.name = name
        _ALLOC_REGISTRY[name] = cls
        return cls

    return deco


def available_allocations() -> Tuple[str, ...]:
    return tuple(sorted(_ALLOC_REGISTRY))


def get_allocation(name: str, **kwargs) -> AllocationPolicy:
    try:
        cls = _ALLOC_REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown allocation policy {name!r}; registered policies: "
            f"{', '.join(available_allocations())}"
        ) from None
    return cls(**kwargs)


@register_allocation("static")
class StaticAllocation(AllocationPolicy):
    """The planner's static minimum-fit memory, every epoch (paper-faithful)."""

    def memory_mb(self, *, epoch, planned_mb, history):
        return planned_mb


@register_allocation("latency")
class LatencyTargetAllocation(AllocationPolicy):
    """Multiplicative sizing to hit a per-batch latency target.

    Lambda compute scales ~linearly with memory, so if the previous epoch's
    slowest batch ran in ``t`` seconds at ``m`` MB, hitting ``target``
    needs ``m * t / target`` MB. Shrinks (never below the planner's fit
    floor) when comfortably under target, trading wall-time back for cost.
    """

    def __init__(self, target_batch_s: float = 1.0, shrink_threshold: float = 0.6):
        self.target_batch_s = target_batch_s
        self.shrink_threshold = shrink_threshold

    def memory_mb(self, *, epoch, planned_mb, history):
        if not history:
            return planned_mb
        prev = history[-1]
        worst = prev.max_exec_s
        if worst <= 0.0:
            return prev.memory_mb
        if worst > self.target_batch_s or worst < self.shrink_threshold * self.target_batch_s:
            return int(round(prev.memory_mb * worst / self.target_batch_s))
        return prev.memory_mb


@register_allocation("aimd")
class AIMDAllocation(AllocationPolicy):
    """Additive-increase / multiplicative-decrease around a latency target.

    Conservative: grows one fixed step when the previous epoch missed the
    target (or paid retries), decays by ``decrease`` when comfortably
    under it. Converges near the cheapest tier that meets the target.
    """

    def __init__(
        self,
        target_batch_s: float = 1.0,
        increase_mb: int = 1024,
        decrease: float = 0.8,
    ):
        self.target_batch_s = target_batch_s
        self.increase_mb = increase_mb
        self.decrease = decrease

    def memory_mb(self, *, epoch, planned_mb, history):
        if not history:
            return planned_mb
        prev = history[-1]
        if prev.max_exec_s > self.target_batch_s or prev.num_retries > 0:
            return prev.memory_mb + self.increase_mb
        if prev.max_exec_s < 0.5 * self.target_batch_s:
            return int(round(prev.memory_mb * self.decrease))
        return prev.memory_mb
