"""Unified discrete-event ServerlessRuntime — the time model under both
serverless execution paths.

Before this module existed the repo carried two divergent time models: the
closed-form accounting in ``ServerlessExecutor.run`` (``max(batch_time /
speedup) + fixed overheads``) and an ad-hoc heapq loop inside
``LocalP2PCluster.run_epoch_async``. Neither could express what real
serverless training is dominated by (arXiv:2105.07806): cold starts,
invocation-level variance, concurrency throttling, and failures
(arXiv:2309.14148, SPIRT). This module replaces both with one seeded
discrete-event engine plus a runtime layered on top of it:

* :class:`EventEngine` — a deterministic event heap ordered by
  ``(time, priority, insertion seq)``. The priority slot reproduces the old
  async loop's ``(clock, rank)`` tie-breaking bit-for-bit.
* :class:`RuntimeConfig` — the fault/cold-start/concurrency knobs. The
  default config is *ideal* (no faults, no cold starts, unbounded
  concurrency) and reproduces the old analytic wall-times exactly;
  :meth:`RuntimeConfig.aws_default` is a realistic Lambda preset.
* :class:`ServerlessRuntime` — simulates a per-peer Lambda fan-out on the
  engine: warm-container reuse pools keyed by ``(function, memory tier)``,
  concurrency caps with FIFO queueing, per-attempt failures retried with
  exponential backoff, and seeded straggler tail latency. Emits
  per-invocation :class:`InvocationRecord` stage timings (queue wait /
  cold start / retry) that feed ``StageMetrics``, ``ExecutionReport`` and
  ``ServerlessCost``.
* :class:`AllocationPolicy` registry — pluggable per-epoch Lambda memory
  re-sizing from the previous epoch's measured per-batch times: the
  paper's "dynamic resource allocation" made concrete. Mirrors the
  ``ExchangeProtocol`` registry pattern.

The module is dependency-light on purpose (numpy + stdlib): it knows
nothing about JAX, gradients, or dollars — callers translate.
"""
from __future__ import annotations

import abc
import bisect
import heapq
import math
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, ClassVar, Dict, List, Optional, Sequence, Tuple, Type

import numpy as np


# ---------------------------------------------------------------------------
# Event engine
# ---------------------------------------------------------------------------


class EventEngine:
    """Deterministic discrete-event scheduler.

    Events fire in ``(time, priority, insertion order)`` order; callbacks
    may schedule further events. ``rng`` is a seeded numpy Generator shared
    by every stochastic model riding on the engine, so a fixed seed fixes
    the whole simulation.
    """

    def __init__(
        self,
        *,
        seed: int = 0,
        rng: Optional[np.random.Generator] = None,
        tracer: Optional[Any] = None,
    ):
        self.now = 0.0
        self.processed = 0
        self.rng = rng if rng is not None else np.random.default_rng(seed)
        # Optional repro.analysis.trace.TraceRecorder: records every
        # schedule/fire for the happens-before / determinism checkers.
        # None (the default) keeps the hot loop allocation-free.
        self.tracer = tracer
        if tracer is not None:
            tracer.record("engine", time=self.now, seeded=True)
        self._heap: List[Tuple[float, int, int, Callable[[], None]]] = []
        self._seq = 0

    def schedule_at(self, time: float, fn: Callable[[], None], *, priority: int = 0):
        """Schedule ``fn`` at absolute ``time`` (clamped to not run in the past)."""
        t = max(float(time), self.now)
        if self.tracer is not None:
            self.tracer.record(
                "schedule", time=self.now, at=t, priority=priority, seq=self._seq
            )
        heapq.heappush(self._heap, (t, priority, self._seq, fn))
        self._seq += 1

    def schedule_in(self, delay: float, fn: Callable[[], None], *, priority: int = 0):
        self.schedule_at(self.now + delay, fn, priority=priority)

    def reset(self, now: float = 0.0):
        """Rewind the clock between independent simulation rounds."""
        if self._heap:
            raise RuntimeError("cannot reset an engine with pending events")
        self.now = float(now)

    def run(self) -> float:
        """Process events until the heap drains; returns the final clock."""
        while self._heap:
            t, prio, seq, fn = heapq.heappop(self._heap)
            self.now = t
            self.processed += 1
            if self.tracer is not None:
                self.tracer.record("fire", time=t, priority=prio, seq=seq)
            fn()
        return self.now


# ---------------------------------------------------------------------------
# Link model
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LinkModel:
    """Wire time of one message on a simulated inter-peer link.

    Upload/download charging for event-driven simulations goes through
    ``transfer_s``: the P2P cluster charges one per publish and one per
    edge-respecting consume (``HostMailbox.download_time_s(link=...)``
    adds the S3 round trip on top for indirected payloads), so with a
    sparse overlay graph a peer's per-step wire time is O(degree) rather
    than O(P).
    """

    bandwidth_bps: float = 1e9
    per_message_overhead_s: float = 0.0  # broker hop / TLS / framing

    def transfer_s(self, nbytes: int) -> float:
        return nbytes * 8.0 / self.bandwidth_bps + self.per_message_overhead_s


# ---------------------------------------------------------------------------
# Runtime configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class RuntimeConfig:
    """Fault/cold-start/concurrency model of the simulated Lambda service.

    The zero-argument default is the *ideal* runtime — no cold starts, no
    failures, no stragglers, unbounded concurrency — under which the engine
    reproduces the legacy closed-form accounting exactly (see the
    equivalence tests). Every effect is opt-in.
    """

    concurrency_limit: Optional[int] = None  # None = unbounded fan-out
    cold_start_s: float = 0.0  # container init time added to a cold invocation
    container_keepalive_s: float = 900.0  # warm pool idle TTL
    failure_rate: float = 0.0  # P(an attempt fails)
    failure_runtime_frac: float = 1.0  # fraction of the attempt burned before failing
    max_retries: int = 4  # retry budget per invocation
    retry_backoff_s: float = 0.5  # backoff = base * 2**(attempt-1)
    straggler_prob: float = 0.0  # P(invocation draws a tail latency)
    straggler_slowdown: float = 3.0  # mean extra slowdown (exponential tail)
    seed: int = 0

    @staticmethod
    def ideal() -> "RuntimeConfig":
        return RuntimeConfig()

    @staticmethod
    def aws_default() -> "RuntimeConfig":
        """Realistic Lambda figures: 1000 default account concurrency,
        seconds-scale cold starts, rare crashes, occasional stragglers."""
        return RuntimeConfig(
            concurrency_limit=1000,
            cold_start_s=2.5,
            failure_rate=0.005,
            straggler_prob=0.02,
        )


@dataclass(frozen=True)
class InstanceConfig:
    """Provisioning/boot/churn model of the simulated EC2 fleet — the
    conventional instance-based P2P baseline's counterpart to
    :class:`RuntimeConfig`.

    The zero-argument default is the *ideal* fleet — instant boot, no
    churn — under which :class:`repro.core.instance.InstanceRuntime`
    reproduces the legacy closed-form Formula-(2) accounting exactly (see
    the equivalence tests). Every effect is opt-in, mirroring the
    serverless config.
    """

    boot_s: float = 0.0  # VM provision + boot delay before the first batch
    churn_prob: float = 0.0  # P(the VM dies while computing one batch)
    churn_downtime_s: float = 0.0  # detection + replacement gap (not billed)
    max_churn_redos: int = 5  # then the VM is forcibly kept up (epochs end)
    seed: int = 0

    @staticmethod
    def ideal() -> "InstanceConfig":
        return InstanceConfig()

    @staticmethod
    def aws_default() -> "InstanceConfig":
        """Realistic EC2 figures: tens-of-seconds boot (image pull + stack
        start), rare spot-style interruptions with a detection delay."""
        return InstanceConfig(
            boot_s=40.0,
            churn_prob=0.002,
            churn_downtime_s=30.0,
        )

    @staticmethod
    def gpu_default(boot_s: float = 90.0) -> "InstanceConfig":
        """GPU fleet preset: markedly slower provisioning (GPU AMI pull +
        driver/CUDA init) than a t2 boot, same interruption shape. The
        per-tier figure lives in :data:`repro.core.cost.GPU_BOOT_S` —
        pass it here (this module stays dollar/tier-agnostic)."""
        return InstanceConfig(
            boot_s=float(boot_s),
            churn_prob=0.002,
            churn_downtime_s=30.0,
        )


@dataclass
class InstanceEpochResult:
    """Stage-level timing of one simulated instance-backend peer epoch.

    ``billed_s`` partitions cleanly: boot + compute + redo + wire + idle
    are billed (per-second EC2 billing runs whenever a VM exists, idle or
    not); ``downtime_s`` — the gap between a churn death and the
    replacement VM starting to boot — is the one unbilled component.
    ``makespan_s`` is the full wall-clock including that downtime.
    """

    makespan_s: float = 0.0  # epoch submit -> last event, incl. downtime
    boot_s: float = 0.0  # provisioning time paid (first boot + churn reboots)
    compute_s: float = 0.0  # productive batch execution (incl. split overhead)
    redo_s: float = 0.0  # partial batch work lost to churn, re-executed
    downtime_s: float = 0.0  # churn gaps with no VM running (NOT billed)
    wire_s: float = 0.0  # exchange upload + degree-many downloads on the link
    idle_s: float = 0.0  # billed-but-idle (e.g. sync-barrier wait)
    churn_drops: int = 0
    splits: int = 1  # micro-batches per batch under memory pressure

    @property
    def billed_s(self) -> float:
        """EC2-billed seconds: everything a running VM existed for."""
        return self.boot_s + self.compute_s + self.redo_s + self.wire_s + self.idle_s


# ---------------------------------------------------------------------------
# Per-invocation records
# ---------------------------------------------------------------------------


@dataclass
class InvocationRecord:
    """Stage-level timing of one simulated Lambda invocation."""

    index: int
    memory_mb: int
    submit_s: float
    start_s: float = 0.0  # first attempt's start
    end_s: float = 0.0  # successful completion
    exec_s: float = 0.0  # successful attempt's execution (incl. straggler factor)
    download_s: float = 0.0  # payload fetch time (e.g. shard pieces from S3)
    queue_wait_s: float = 0.0  # total time spent throttled, all attempts
    cold_start_s: float = 0.0  # container init time burned, all attempts
    cold_starts: int = 0
    straggler_factor: float = 1.0
    attempts: int = 0
    retries: int = 0
    backoff_s: float = 0.0  # total backoff waiting between attempts
    failed_s: float = 0.0  # post-init execution burned by failed attempts
    billed_s: float = 0.0  # Lambda-billed seconds across all attempts


@dataclass
class FanoutResult:
    """Outcome of one fan-out (one peer epoch) on the runtime."""

    makespan_s: float  # submit -> last completion
    memory_mb: int
    invocations: List[InvocationRecord]

    @property
    def num_cold_starts(self) -> int:
        return sum(r.cold_starts for r in self.invocations)

    @property
    def num_retries(self) -> int:
        return sum(r.retries for r in self.invocations)

    @property
    def cold_start_s_total(self) -> float:
        return sum(r.cold_start_s for r in self.invocations)

    @property
    def queue_wait_s_total(self) -> float:
        return sum(r.queue_wait_s for r in self.invocations)

    @property
    def retry_s_total(self) -> float:
        """Time burned recovering from failures: dead work + backoff."""
        return sum(r.failed_s + r.backoff_s for r in self.invocations)

    @property
    def billed_s_total(self) -> float:
        return sum(r.billed_s for r in self.invocations)

    @property
    def max_exec_s(self) -> float:
        return max((r.exec_s for r in self.invocations), default=0.0)


class FanoutTimeout(RuntimeError):
    """An invocation exhausted its retry budget against the hard timeout."""


# ---------------------------------------------------------------------------
# Warm-container pool
# ---------------------------------------------------------------------------


class _ContainerPool:
    """Warm containers keyed by (function, memory tier), AWS-style LIFO reuse.

    A container freed at ``t0`` can serve a new invocation at ``t`` iff
    ``t0 <= t <= t0 + keepalive``. Changing the memory tier (dynamic
    allocation) strands the old tier's pool — re-sizing pays cold starts
    again, which is exactly the trade-off an AllocationPolicy navigates.

    Each key's idle containers are a release-time-sorted list, so acquire
    is a bisect (most recent usable = LIFO) plus amortized-O(1) expiry
    from the stale end — the old implementation rebuilt the list and
    linearly scanned for the maximum on every acquire. ``stats`` counts
    warm hits, cold misses, and keepalive expiries for the micro-rails.
    """

    def __init__(self, keepalive_s: float):
        self.keepalive_s = keepalive_s
        self._idle: Dict[Tuple[Any, int], List[float]] = {}  # sorted ascending
        self.stats = {"hits": 0, "misses": 0, "expired": 0}

    def _expire(self, row: List[float], at: float) -> None:
        cut = bisect.bisect_left(row, at - self.keepalive_s)
        if cut:
            self.stats["expired"] += cut
            del row[:cut]

    def acquire(self, key: Tuple[Any, int], at: float) -> bool:
        """True -> warm container reused; False -> cold start."""
        row = self._idle.get(key)
        if row:
            self._expire(row, at)
            # most recently released container with release time <= at
            # (entries beyond are future releases pre-staged by the
            # batched fanout path; they are invisible until their time)
            i = bisect.bisect_right(row, at) - 1
            if i >= 0:
                row.pop(i)
                self.stats["hits"] += 1
                return True
        self.stats["misses"] += 1
        return False

    def take_available(self, key: Tuple[Any, int], at: float, want: int) -> int:
        """Batch form of ``want`` same-instant acquires: claims (and
        removes) up to ``want`` warm containers usable at ``at``, returns
        how many were claimed. Equivalent to ``want`` acquire() calls at
        the same timestamp."""
        got = 0
        row = self._idle.get(key)
        if row:
            self._expire(row, at)
            hi = bisect.bisect_right(row, at)
            got = min(want, hi)
            if got:
                del row[hi - got:hi]
        self.stats["hits"] += got
        self.stats["misses"] += want - got
        return got

    def release(self, key: Tuple[Any, int], at: float):
        row = self._idle.setdefault(key, [])
        if row and at < row[-1]:
            bisect.insort(row, at)  # rare: out-of-order release
        else:
            row.append(at)

    def release_many(self, key: Tuple[Any, int], times: Sequence[float]):
        """Batch release at ascending-sorted ``times`` (the batched fanout
        path stages a whole wave's completion releases at once)."""
        row = self._idle.setdefault(key, [])
        needs_sort = bool(row) and len(times) > 0 and row[-1] > times[0]
        row.extend(float(t) for t in times)
        if needs_sort:
            row.sort()


# ---------------------------------------------------------------------------
# ServerlessRuntime
# ---------------------------------------------------------------------------

# Fan-outs at least this large auto-select the batched (array-valued)
# engine when no tracer is attached; below it the scalar engine's
# per-event cost is negligible and its full trace stream is worth keeping.
BATCHED_FANOUT_MIN = 256


class ServerlessRuntime:
    """Simulates Lambda fan-outs on the event engine.

    One runtime instance persists warm pools and the RNG stream across
    fan-outs (epochs), so container reuse and fault sampling behave like a
    long-lived deployment; a fixed ``RuntimeConfig.seed`` makes the whole
    trajectory deterministic.
    """

    def __init__(
        self, config: Optional[RuntimeConfig] = None, *, tracer: Optional[Any] = None
    ):
        self.config = config or RuntimeConfig()
        self.rng = np.random.default_rng(self.config.seed)
        self.pool = _ContainerPool(self.config.container_keepalive_s)
        self.tracer = tracer  # optional repro.analysis.trace.TraceRecorder
        self.fanouts_run = 0
        self.clock = 0.0  # deployment-lifetime clock; warm pools live on it

    def fanout(
        self,
        exec_times_s: Sequence[float],
        *,
        memory_mb: int,
        function_key: Any = 0,
        invoke_overhead_s: float = 0.0,
        timeout_s: Optional[float] = None,
        submit_time: Optional[float] = None,
        download_bytes: Optional[Sequence[int]] = None,
        link: Optional[LinkModel] = None,
        batched: Optional[bool] = None,
    ) -> FanoutResult:
        """Simulate one fan-out of ``len(exec_times_s)`` invocations.

        ``exec_times_s`` are warm, straggler-free execution times (already
        scaled to the memory tier's vCPU share). ``submit_time`` defaults
        to the runtime's own clock, which advances past each fan-out — so
        containers freed by one epoch are warm (within the keepalive TTL)
        for the next. ``download_bytes`` (with ``link``) charges each
        invocation a payload fetch — e.g. a sharded aggregator downloading
        its P-1 shard pieces before reducing them — billed like execution
        and re-paid on retries. Returns the makespan and per-invocation
        stage records; all record times are absolute on the runtime clock.

        Every stochastic choice (stragglers, per-attempt failures) is
        pre-drawn as index-keyed numpy vectors before simulation starts,
        so the two engines below consume identical randomness:

        * the *scalar* engine — one closure per invocation event on the
          :class:`EventEngine` heap (the legacy oracle; full per-event
          trace records);
        * the *batched* engine — array-valued waves with only the retry /
          completion frontier on a primitive heap, ~two orders of
          magnitude faster at P >= 10k.

        ``batched=None`` picks the batched engine for fan-outs of at
        least ``BATCHED_FANOUT_MIN`` invocations when no tracer is
        attached (the batched engine emits only the condensed ``fanout``
        trace record); pass True/False to force. Same seed, same config
        => both engines produce identical records and makespan (the
        equivalence rail in the tests).
        """
        cfg = self.config
        if submit_time is None:
            submit_time = self.clock
        submit_time = float(submit_time)
        n = len(exec_times_s)
        times = np.asarray(exec_times_s, dtype=np.float64)
        key = (function_key, int(memory_mb))
        # -- pre-draw all randomness, index-keyed (shared by both engines) --
        factors = np.ones(n, dtype=np.float64)
        if cfg.straggler_prob > 0.0:
            hits = self.rng.random(n) < cfg.straggler_prob
            k = int(hits.sum())
            if k:
                factors[hits] = 1.0 + self.rng.exponential(cfg.straggler_slowdown, k)
        # u_fail[a-1, i] decides attempt a of invocation i (attempts past
        # the retry budget never draw — they only fail by timeout)
        u_fail = None
        if cfg.failure_rate > 0.0 and cfg.max_retries > 0:
            u_fail = self.rng.random((cfg.max_retries, n))
        dl_s = np.zeros(n, dtype=np.float64)
        if download_bytes is not None and link is not None:
            dl_s = (
                np.asarray(download_bytes, dtype=np.float64).astype(np.int64)
                * 8.0 / link.bandwidth_bps
                + link.per_message_overhead_s
            )
        if batched is None:
            batched = self.tracer is None and n >= BATCHED_FANOUT_MIN
        run = self._fanout_batched if batched else self._fanout_scalar
        records, last_end = run(
            times, factors, u_fail, dl_s,
            memory_mb=int(memory_mb), key=key,
            invoke_overhead_s=invoke_overhead_s, timeout_s=timeout_s,
            submit_time=submit_time,
        )
        self.fanouts_run += 1
        self.clock = max(self.clock, last_end)
        if self.tracer is not None:
            self.tracer.record(
                "fanout",
                time=last_end,
                invocations=len(records),
                cold_starts=sum(r.cold_starts for r in records),
                retries=sum(r.retries for r in records),
            )
        return FanoutResult(
            makespan_s=last_end - submit_time,
            memory_mb=int(memory_mb),
            invocations=records,
        )

    def _fanout_scalar(
        self, times, factors, u_fail, dl_s, *,
        memory_mb, key, invoke_overhead_s, timeout_s, submit_time,
    ) -> Tuple[List[InvocationRecord], float]:
        """Legacy closure-per-event engine (oracle path, full tracing)."""
        cfg = self.config
        n = times.shape[0]
        engine = EventEngine(rng=self.rng, tracer=self.tracer)
        engine.now = submit_time
        records = [
            InvocationRecord(index=i, memory_mb=memory_mb, submit_s=submit_time)
            for i in range(n)
        ]
        capacity = cfg.concurrency_limit or math.inf
        state = {"running": 0, "last_end": submit_time}
        waiting: deque = deque()  # FIFO throttle queue of (index, enqueue time)

        def try_start(i: int):
            if state["running"] < capacity:
                state["running"] += 1
                start_attempt(i)
            else:
                waiting.append((i, engine.now))

        def release_slot():
            state["running"] -= 1
            if waiting:
                i, t_enq = waiting.popleft()
                records[i].queue_wait_s += engine.now - t_enq
                state["running"] += 1
                start_attempt(i)

        def start_attempt(i: int):
            rec = records[i]
            rec.attempts += 1
            if rec.attempts == 1:
                rec.start_s = engine.now
                rec.straggler_factor = float(factors[i])
            cold = not self.pool.acquire(key, engine.now)
            init_s = cfg.cold_start_s if cold else 0.0
            if cold:
                rec.cold_starts += 1
            exec_s = float(times[i] * rec.straggler_factor + dl_s[i])
            duration = init_s + invoke_overhead_s + exec_s
            out_of_retries = rec.attempts > cfg.max_retries
            timed_out = timeout_s is not None and duration > timeout_s
            failed = timed_out or (
                u_fail is not None
                and not out_of_retries
                and u_fail[rec.attempts - 1, i] < cfg.failure_rate
            )
            if failed and timed_out and out_of_retries:
                raise FanoutTimeout(
                    f"invocation {i} still exceeds the {timeout_s:.0f}s timeout "
                    f"after {cfg.max_retries} retries on a {memory_mb}MB function"
                )
            if failed:
                run_for = min(
                    duration * cfg.failure_runtime_frac,
                    timeout_s if timed_out else duration,
                )
                # split the burn so cold_start_s and failed_s partition the
                # attempt's time (no double-billing downstream): init burns
                # first, whatever remains was dead execution
                burned_init = min(run_for, init_s)
                rec.cold_start_s += burned_init
                rec.failed_s += run_for - burned_init
                rec.billed_s += run_for
                rec.retries += 1
                backoff = cfg.retry_backoff_s * (2.0 ** (rec.attempts - 1))
                rec.backoff_s += backoff
                # a crashed/timed-out container is not returned to the pool
                # the slot frees when the attempt dies; the retry re-enters
                # admission (FIFO) after its backoff
                engine.schedule_at(engine.now + run_for, release_slot)
                engine.schedule_at(engine.now + run_for + backoff, lambda i=i: try_start(i))
                # a straggler that burned its retry budget against the hard
                # timeout is forced back to nominal speed so the redo can fit
                if timed_out and rec.attempts >= cfg.max_retries:
                    rec.straggler_factor = 1.0
                return
            rec.cold_start_s += init_s
            rec.exec_s = float(exec_s)
            rec.download_s = float(dl_s[i])
            rec.billed_s += duration

            def complete(i=i, duration=duration):
                rec = records[i]
                rec.end_s = engine.now
                state["last_end"] = max(state["last_end"], engine.now)
                self.pool.release(key, engine.now)
                release_slot()

            engine.schedule_at(engine.now + duration, complete)

        for i in range(n):
            engine.schedule_at(submit_time, lambda i=i: try_start(i))
        engine.run()
        return records, state["last_end"]

    def _fanout_batched(
        self, times, factors, u_fail, dl_s, *,
        memory_mb, key, invoke_overhead_s, timeout_s, submit_time,
    ) -> Tuple[List[InvocationRecord], float]:
        """Array-valued fanout engine.

        The homogeneous first wave (every invocation admitted at the
        submit instant, i.e. capacity >= n) is computed as pure numpy —
        warm/cold split, durations, failure partition, completion times —
        with completion releases bulk-staged into the warm pool. Only the
        *frontier* then rides a primitive-tuple heap: retry re-arrivals
        and, under a concurrency cap, slot releases and completions. No
        Python closure is ever scheduled, and records materialize once at
        the end.

        Event ordering reproduces the scalar engine exactly: the heap is
        keyed ``(time, seq)`` and ``seq`` is advanced in the same order
        the scalar engine allocates its insertion sequence (including for
        events the batched path never needs to materialize), so ties
        resolve identically and the two engines agree to the last bit.
        """
        cfg = self.config
        n = times.shape[0]
        capacity = cfg.concurrency_limit or math.inf
        pool = self.pool
        factors = factors.copy()  # the forced-nominal rule mutates it
        rate = cfg.failure_rate
        TRY, RELEASE, COMPLETE = 0, 1, 2

        attempts = np.zeros(n, np.int64)
        start_s = np.zeros(n)
        end_s = np.zeros(n)
        exec_s = np.zeros(n)
        download_s = np.zeros(n)
        queue_wait = np.zeros(n)
        cold_s = np.zeros(n)
        cold_n = np.zeros(n, np.int64)
        retries = np.zeros(n, np.int64)
        backoff_tot = np.zeros(n)
        failed_tot = np.zeros(n)
        billed = np.zeros(n)

        heap: List[Tuple[float, int, int, int]] = []
        waiting: deque = deque()
        state = {"running": 0, "seq": 0, "last_end": submit_time}
        bounded = capacity < n  # slots can actually contend

        def timeout_msg(i: int) -> str:
            return (
                f"invocation {i} still exceeds the {timeout_s:.0f}s timeout "
                f"after {cfg.max_retries} retries on a {memory_mb}MB function"
            )

        def start_attempt(i: int, now: float):
            attempts[i] += 1
            a = int(attempts[i])
            if a == 1:
                start_s[i] = now
            cold = not pool.acquire(key, now)
            init_s = cfg.cold_start_s if cold else 0.0
            if cold:
                cold_n[i] += 1
            ex = times[i] * factors[i] + dl_s[i]
            duration = init_s + invoke_overhead_s + ex
            out_of_retries = a > cfg.max_retries
            timed_out = timeout_s is not None and duration > timeout_s
            failed = timed_out or (
                u_fail is not None
                and not out_of_retries
                and u_fail[a - 1, i] < rate
            )
            if failed and timed_out and out_of_retries:
                raise FanoutTimeout(timeout_msg(i))
            if failed:
                run_for = min(
                    duration * cfg.failure_runtime_frac,
                    timeout_s if timed_out else duration,
                )
                burned_init = min(run_for, init_s)
                cold_s[i] += burned_init
                failed_tot[i] += run_for - burned_init
                billed[i] += run_for
                retries[i] += 1
                backoff = cfg.retry_backoff_s * (2.0 ** (a - 1))
                backoff_tot[i] += backoff
                if bounded:
                    heapq.heappush(
                        heap, (now + run_for, state["seq"], RELEASE, -1)
                    )
                state["seq"] += 1  # scalar allocates this seq either way
                heapq.heappush(
                    heap, (now + run_for + backoff, state["seq"], TRY, i)
                )
                state["seq"] += 1
                if timed_out and a >= cfg.max_retries:
                    factors[i] = 1.0
                return
            cold_s[i] += init_s
            exec_s[i] = ex
            download_s[i] = dl_s[i]
            billed[i] += duration
            heapq.heappush(heap, (now + duration, state["seq"], COMPLETE, i))
            state["seq"] += 1

        def admit_next(now: float):
            state["running"] -= 1
            if waiting:
                j, t_enq = waiting.popleft()
                queue_wait[j] += now - t_enq
                state["running"] += 1
                start_attempt(j, now)

        if n and not bounded:
            # -- vectorized first wave: all n admitted at the submit instant
            warm = np.zeros(n, dtype=bool)
            warm[: pool.take_available(key, submit_time, n)] = True
            init = np.where(warm, 0.0, cfg.cold_start_s)
            cold_n += ~warm
            ex = times * factors + dl_s
            duration = init + invoke_overhead_s + ex
            if timeout_s is None:
                timed_out = np.zeros(n, dtype=bool)
            else:
                timed_out = duration > timeout_s
            oor = 1 > cfg.max_retries  # attempt 1 already out of retries
            fail_draw = (
                (u_fail[0] < rate)
                if (u_fail is not None and not oor)
                else np.zeros(n, dtype=bool)
            )
            failed = timed_out | fail_draw
            if oor and bool(np.any(failed & timed_out)):
                raise FanoutTimeout(
                    timeout_msg(int(np.argmax(failed & timed_out)))
                )
            attempts[:] = 1
            start_s[:] = submit_time
            ok = ~failed
            cold_s[ok] += init[ok]
            exec_s[ok] = ex[ok]
            download_s[ok] = dl_s[ok]
            billed[ok] += duration[ok]
            ends = submit_time + duration[ok]
            end_s[ok] = ends
            if ends.size:
                state["last_end"] = max(state["last_end"], float(ends.max()))
                pool.release_many(key, np.sort(ends))
            # seq parity with the scalar engine: n initial try_starts, then
            # (ascending index) 1 seq per success, 2 per failure
            costs = np.where(failed, 2, 1)
            seq_base = n + np.concatenate(([0], np.cumsum(costs)[:-1]))
            state["seq"] = n + int(costs.sum())
            fid = np.flatnonzero(failed)
            if fid.size:
                cap_arr = duration if timeout_s is None else np.where(
                    timed_out, timeout_s, duration
                )
                run_for = np.minimum(duration * cfg.failure_runtime_frac, cap_arr)
                burned = np.minimum(run_for, init)
                cold_s[fid] += burned[fid]
                failed_tot[fid] += (run_for - burned)[fid]
                billed[fid] += run_for[fid]
                retries[fid] += 1
                backoff = cfg.retry_backoff_s  # 2**(1-1)
                backoff_tot[fid] += backoff
                if timeout_s is not None and cfg.max_retries <= 1:
                    factors[np.flatnonzero(failed & timed_out)] = 1.0
                for i in fid:
                    heapq.heappush(
                        heap,
                        (
                            submit_time + float(run_for[i]) + backoff,
                            int(seq_base[i]) + 1,
                            TRY,
                            int(i),
                        ),
                    )
        else:
            # capacity-bound admission: same event algebra as the scalar
            # engine, but primitive heap tuples instead of closures
            heap = [(submit_time, i, TRY, i) for i in range(n)]
            heapq.heapify(heap)
            state["seq"] = n

        while heap:
            now, _seq, kind, i = heapq.heappop(heap)
            if kind == TRY:
                # when capacity >= n slots can never contend (an invocation
                # has at most one outstanding attempt), so admission is
                # unconditional and slot bookkeeping is skipped entirely
                if not bounded:
                    start_attempt(i, now)
                elif state["running"] < capacity:
                    state["running"] += 1
                    start_attempt(i, now)
                else:
                    waiting.append((i, now))
            elif kind == RELEASE:
                admit_next(now)
            else:  # COMPLETE
                end_s[i] = now
                state["last_end"] = max(state["last_end"], now)
                pool.release(key, now)
                if bounded:
                    admit_next(now)

        records = [
            InvocationRecord(
                index=i,
                memory_mb=memory_mb,
                submit_s=submit_time,
                start_s=float(start_s[i]),
                end_s=float(end_s[i]),
                exec_s=float(exec_s[i]),
                download_s=float(download_s[i]),
                queue_wait_s=float(queue_wait[i]),
                cold_start_s=float(cold_s[i]),
                cold_starts=int(cold_n[i]),
                straggler_factor=float(factors[i]),
                attempts=int(attempts[i]),
                retries=int(retries[i]),
                backoff_s=float(backoff_tot[i]),
                failed_s=float(failed_tot[i]),
                billed_s=float(billed[i]),
            )
            for i in range(n)
        ]
        return records, state["last_end"]


# ---------------------------------------------------------------------------
# AllocationPolicy registry (mirrors the ExchangeProtocol registry)
# ---------------------------------------------------------------------------


class AllocationPolicy(abc.ABC):
    """Per-epoch Lambda memory sizing — the paper's "dynamic resource
    allocation" as a pluggable policy.

    ``memory_mb`` sees the planner's static minimum (the smallest tier the
    model fits in) and the peer's fan-out history, and returns a memory
    suggestion; the executor clamps it to ``[planned_mb, LAMBDA cap]`` and
    rounds to the 64 MB tier grid. Lambda vCPU share scales linearly with
    memory, so raising memory buys wall-time at a dollar premium — the
    paper's headline time/cost trade-off.
    """

    name: ClassVar[str] = "?"  # set by @register_allocation

    @abc.abstractmethod
    def memory_mb(
        self, *, epoch: int, planned_mb: int, history: Sequence[FanoutResult]
    ) -> int:
        """Return the memory size for this epoch's fan-out."""

    def describe(self) -> str:
        return (self.__doc__ or "").strip().splitlines()[0] if self.__doc__ else ""


_ALLOC_REGISTRY: Dict[str, Type[AllocationPolicy]] = {}


def register_allocation(name: str):
    """Class decorator: make a policy reachable by name everywhere."""

    def deco(cls: Type[AllocationPolicy]) -> Type[AllocationPolicy]:
        if not issubclass(cls, AllocationPolicy):
            raise TypeError(f"{cls!r} must subclass AllocationPolicy")
        cls.name = name
        _ALLOC_REGISTRY[name] = cls
        return cls

    return deco


def available_allocations() -> Tuple[str, ...]:
    return tuple(sorted(_ALLOC_REGISTRY))


def get_allocation(name: str, **kwargs) -> AllocationPolicy:
    try:
        cls = _ALLOC_REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown allocation policy {name!r}; registered policies: "
            f"{', '.join(available_allocations())}"
        ) from None
    return cls(**kwargs)


@register_allocation("static")
class StaticAllocation(AllocationPolicy):
    """The planner's static minimum-fit memory, every epoch (paper-faithful)."""

    def memory_mb(self, *, epoch, planned_mb, history):
        return planned_mb


@register_allocation("latency")
class LatencyTargetAllocation(AllocationPolicy):
    """Multiplicative sizing to hit a per-batch latency target.

    Lambda compute scales ~linearly with memory, so if the previous epoch's
    slowest batch ran in ``t`` seconds at ``m`` MB, hitting ``target``
    needs ``m * t / target`` MB. Shrinks (never below the planner's fit
    floor) when comfortably under target, trading wall-time back for cost.
    """

    def __init__(self, target_batch_s: float = 1.0, shrink_threshold: float = 0.6):
        self.target_batch_s = target_batch_s
        self.shrink_threshold = shrink_threshold

    def memory_mb(self, *, epoch, planned_mb, history):
        if not history:
            return planned_mb
        prev = history[-1]
        worst = prev.max_exec_s
        if worst <= 0.0:
            return prev.memory_mb
        if worst > self.target_batch_s or worst < self.shrink_threshold * self.target_batch_s:
            return int(round(prev.memory_mb * worst / self.target_batch_s))
        return prev.memory_mb


@register_allocation("aimd")
class AIMDAllocation(AllocationPolicy):
    """Additive-increase / multiplicative-decrease around a latency target.

    Conservative: grows one fixed step when the previous epoch missed the
    target (or paid retries), decays by ``decrease`` when comfortably
    under it. Converges near the cheapest tier that meets the target.
    """

    def __init__(
        self,
        target_batch_s: float = 1.0,
        increase_mb: int = 1024,
        decrease: float = 0.8,
    ):
        self.target_batch_s = target_batch_s
        self.increase_mb = increase_mb
        self.decrease = decrease

    def memory_mb(self, *, epoch, planned_mb, history):
        if not history:
            return planned_mb
        prev = history[-1]
        if prev.max_exec_s > self.target_batch_s or prev.num_retries > 0:
            return prev.memory_mb + self.increase_mb
        if prev.max_exec_s < 0.5 * self.target_batch_s:
            return int(round(prev.memory_mb * self.decrease))
        return prev.memory_mb
