"""Pluggable gradient-exchange protocols — the paper's §III-B as an API.

The exchange layer (RabbitMQ mailboxes, QSGD compression, sync/async
consumption) is the paper's core contribution, so it is a first-class,
registry-backed abstraction instead of a string-dispatched ``if/elif``
chain. One :class:`ExchangeProtocol` subclass implements BOTH execution
paths plus its wire-byte accounting:

* **device path** — :meth:`~ExchangeProtocol.combine` runs inside the
  ``shard_map`` manual region of the TPU train step; peers are mesh-axis
  slices and the mailbox is an all-gathered register bank carried in the
  train state.
* **host path** — :meth:`~ExchangeProtocol.host_encode` /
  :meth:`~ExchangeProtocol.host_decode` serialize one peer's gradient for
  the :class:`~repro.core.mailbox.HostMailbox` used by the
  ``LocalP2PCluster`` discrete-event simulator.
* **accounting** — :meth:`~ExchangeProtocol.wire_bytes_per_edge` reports
  the payload crossing one overlay edge; :meth:`~ExchangeProtocol.wire_bytes`
  scales it by the peer's graph degree (``P - 1`` on the full mesh);
  :class:`repro.core.cost.CommCost` turns that into wire seconds / dollars.

The peer overlay itself (full / ring / gossip-k / hierarchical) is the
:class:`repro.core.graph.PeerGraph` carried in :class:`ExchangeContext`:
sync protocols mix with the graph's Metropolis–Hastings weights instead
of the global mean whenever ``ctx.mixing`` is set (it is ``None`` on the
full graph, which keeps the legacy arithmetic bit-exact).

Adding a protocol is one registered class::

    @register_exchange("my_protocol")
    class MyProtocol(ExchangeProtocol):
        def combine(self, grads, ctx, *, key=None, state=None):
            ...
            return averaged, state

``Topology(exchange="my_protocol")`` then works everywhere — the TPU step
builder, the host cluster, ``launch/train.py`` CLI and the benchmarks all
resolve names through this registry.
"""
from __future__ import annotations

import abc
import dataclasses
from dataclasses import dataclass
from typing import Any, ClassVar, Dict, Optional, Tuple, Type

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import compression as C
from repro.core import robust as R
from repro.core.shard import ShardPlan


@dataclass(frozen=True)
class ExchangeContext:
    """Everything a protocol needs besides the gradients themselves.

    ``axis`` is the peer mesh axis (name or tuple of names) for device
    collectives; None on the host path, where peers are Python objects and
    the mailbox delivers payloads instead of ``all_gather``.

    ``graph`` / ``mixing`` carry the peer overlay (see
    ``repro.core.graph``): ``graph`` is the resolved :class:`PeerGraph`
    and ``mixing`` its Metropolis–Hastings matrix ``W`` as an fp32
    ``(P, P)`` array — or ``None`` for the full graph, where the weights
    are uniformly ``1/P`` and protocols keep the legacy (bit-exact)
    global-mean arithmetic. Sync protocols generalize the mean to
    ``x_r <- sum_j W[r, j] x_j`` when ``mixing`` is set.
    """

    axis: Any = None
    num_peers: int = 1
    wire_dtype: Any = jnp.float32
    qsgd: Optional[C.QSGDConfig] = None
    topk_frac: float = 0.01
    topk_impl: str = "jnp"  # "jnp" (lax.top_k oracle) | "kernel" (Pallas)
    staleness: int = 1
    graph: Any = None  # resolved repro.core.graph.PeerGraph, or None
    mixing: Any = None  # (P, P) fp32 MH matrix; None => uniform 1/P (full)
    # robust-aggregation knobs (see repro.core.robust); a parameterized
    # protocol spec ("trimmed_mean:0.25", "krum:3") overrides these
    trim_frac: float = 0.0  # trimmed_mean: fraction dropped from EACH end
    krum_m: int = 1  # krum: multi-Krum selection count
    krum_f: Optional[int] = None  # krum: assumed attackers (None = max tolerable)
    robust_clip: float = 0.0  # >0: per-peer norm clip before robust combine

    def __post_init__(self):
        # A graph sized for a different peer count silently mis-mixes (the
        # MH matrix rows no longer line up with mesh ranks) — refuse here,
        # at construction, with an actionable message.
        gp = getattr(self.graph, "num_peers", None)
        if gp is not None and gp != self.num_peers:
            raise ValueError(
                f"ExchangeContext(num_peers={self.num_peers}) does not match "
                f"its overlay graph, which was built for {gp} peers "
                f"({self.graph.describe()}); resolve the graph for the "
                f"actual peer count (get_graph(spec, num_peers))"
            )

    @property
    def degree(self) -> float:
        """Mean neighbor count of one peer — (P-1) when no graph is set."""
        if self.graph is not None:
            return float(self.graph.mean_degree)
        return float(max(self.num_peers - 1, 0))

    def mix_row(self):
        """This peer's mixing weights ``W[r]`` inside the manual region."""
        r = lax.axis_index(self.axis)
        return jnp.asarray(self.mixing, jnp.float32)[r], r


class ExchangeProtocol(abc.ABC):
    """Abstract gradient-exchange protocol (see module docstring)."""

    name: ClassVar[str] = "?"  # set by @register_exchange
    is_async: ClassVar[bool] = False  # consumes stale mailbox state
    requires_key: ClassVar[bool] = False  # needs an rng key (stochastic codec)
    decomposes_per_edge: ClassVar[bool] = True  # False: fused collective
    requires_full_graph: ClassVar[bool] = False  # True: refuses sparse overlays
    sharded: ClassVar[bool] = False  # True: shards, not pytrees, on the wire
    lossy: ClassVar[bool] = False  # True: codec drops information (EF applies)
    hierarchical: ClassVar[bool] = False  # True: multi-level tree reduce

    # -- device path --------------------------------------------------------
    def init_state(self, grads_like, ctx: ExchangeContext):
        """Per-protocol carried state (e.g. the async mailbox); None if none."""
        return None

    @abc.abstractmethod
    def combine(self, grads, ctx: ExchangeContext, *, key=None, state=None):
        """(grads, state) -> (averaged_grads fp32, new_state).

        Runs inside the manual region; sync protocols pass ``state``
        through untouched.
        """

    def combine_ef(self, grads, ctx: ExchangeContext, *, key=None, state=None):
        """Error-feedback variant: -> (averaged, local_image, new_state).

        ``local_image`` is the decoded image of THIS peer's shipped
        contribution — what the rest of the swarm actually received from
        us. EF-SGD accumulates ``residual = grads - local_image`` and adds
        it back before the next encode. Lossless protocols ship ``grads``
        verbatim, so the default keeps the residual identically zero;
        lossy codecs (qsgd, topk) override.
        """
        avg, state = self.combine(grads, ctx, key=key, state=state)
        return avg, grads, state

    # -- host path -----------------------------------------------------------
    def host_encode(self, grads, ctx: ExchangeContext, *, key=None):
        """One peer's gradient -> (wire payload, wire bytes)."""
        wire = jax.tree.map(lambda g: g.astype(ctx.wire_dtype), grads)
        return wire, _tree_bytes(wire)

    def host_decode(self, payload, grads_like, ctx: ExchangeContext):
        """Wire payload -> this peer's dense fp32 gradient contribution."""
        return jax.tree.map(lambda g: g.astype(jnp.float32), payload)

    def host_combine(self, grads_peers, rank: int, ctx: ExchangeContext):
        """Protocol-specific host-path aggregation, or ``None`` for the
        default (graph-weighted mean) arithmetic.

        ``grads_peers`` maps contributor rank -> decoded fp32 gradient
        (always including ``rank``'s own). Protocols whose estimator is
        NOT a weighted mean (the robust family) override this; the
        cluster's ``_update`` dispatches here first and falls back to the
        legacy Metropolis–Hastings / plain-mean path on ``None``.
        """
        return None

    # -- accounting ----------------------------------------------------------
    def wire_bytes_per_edge(self, grads_like, ctx: ExchangeContext) -> int:
        """Payload bytes crossing ONE graph edge (one peer -> one neighbor).

        This is the unit the overlay-aware accounting is built from:
        compression/sparsification protocols override it, the degree
        scaling lives in :meth:`wire_bytes`.
        """
        itemsize = jnp.dtype(ctx.wire_dtype).itemsize
        return sum(int(np.prod(x.shape)) * itemsize for x in jax.tree.leaves(grads_like))

    def wire_bytes(self, grads_like, ctx: ExchangeContext) -> int:
        """Total bytes one peer moves per step: per-edge payload x degree.

        Degree comes from the overlay graph in ``ctx`` (``P - 1`` for the
        full mesh), so sparse topologies (ring: 2, gossip: k) show their
        O(degree) per-peer traffic while full-mesh grows O(P). Fused
        collectives that don't decompose into edges override this whole
        method (see ``psum_mean``).
        """
        return int(round(self.wire_bytes_per_edge(grads_like, ctx) * ctx.degree))

    def host_wire_bytes(self, grads_like, ctx: ExchangeContext) -> int:
        """Bytes one peer PUBLISHES on the host mailbox path per step.

        The mailbox is a latest-wins register: a peer publishes its
        payload once and each neighbor pays the download separately
        (charged per consume by ``HostMailbox.download_time_s``), so the
        publish figure is one edge-payload regardless of degree.
        """
        return self.wire_bytes_per_edge(grads_like, ctx)

    def describe(self) -> str:
        return (self.__doc__ or "").strip().splitlines()[0] if self.__doc__ else ""


def _tree_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Type[ExchangeProtocol]] = {}


def register_exchange(name: str):
    """Class decorator: make a protocol reachable as ``Topology(exchange=name)``."""

    def deco(cls: Type[ExchangeProtocol]) -> Type[ExchangeProtocol]:
        if not issubclass(cls, ExchangeProtocol):
            raise TypeError(f"{cls!r} must subclass ExchangeProtocol")
        cls.name = name
        _REGISTRY[name] = cls
        return cls

    return deco


def available_exchanges() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def get_exchange(spec: str) -> ExchangeProtocol:
    """Resolve a protocol spec: a registered name with an optional
    parameter suffix, mirroring the graph registry — ``"allgather_mean"``,
    ``"trimmed_mean:0.25"``, ``"krum:3"``. The parameter overrides the
    matching :class:`ExchangeContext` knob for this instance."""
    name, _, arg = str(spec).partition(":")
    try:
        cls = _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown exchange protocol {spec!r}; registered protocols: "
            f"{', '.join(available_exchanges())}"
        ) from None
    if not arg:
        return cls()
    try:
        return cls(param=arg)
    except TypeError:
        raise ValueError(
            f"exchange protocol {name!r} does not take a ':' parameter "
            f"(got {spec!r})"
        ) from None


# ---------------------------------------------------------------------------
# Registered protocols
# ---------------------------------------------------------------------------


@register_exchange("allgather_mean")
class AllGatherMean(ExchangeProtocol):
    """Paper-faithful Algorithm 1: publish to own queue, consume all, average.

    Device image: ``all_gather`` over the peer axis + local mean — the
    gather IS the synchronization barrier (§III-B.6). Under a sparse
    overlay (``ctx.mixing`` set) the mean generalizes to the
    Metropolis–Hastings neighbor mix ``W[r] @ bank``; on the full graph
    ``W`` is uniform ``1/P`` and the legacy mean path is kept bit-exact.
    """

    def combine(self, grads, ctx, *, key=None, state=None):
        bank = jax.tree.map(
            lambda g: lax.all_gather(g.astype(ctx.wire_dtype), ctx.axis), grads
        )
        if ctx.mixing is None:
            avg = jax.tree.map(lambda b: b.astype(jnp.float32).mean(axis=0), bank)
        else:
            w, _ = ctx.mix_row()
            avg = jax.tree.map(
                lambda b: jnp.tensordot(w, b.astype(jnp.float32), axes=(0, 0)),
                bank,
            )
        return avg, state


@register_exchange("psum_mean")
class PsumMean(ExchangeProtocol):
    """Beyond-paper optimized sync exchange: one fused all-reduce.

    Mathematically identical to allgather_mean, strictly less traffic (no
    P-way buffer materialization); a ring all-reduce moves
    ``2 (P-1)/P x raw`` bytes per peer. The fused reduction is inherently
    global, so this protocol only supports the full overlay graph.
    """

    decomposes_per_edge = False

    def combine(self, grads, ctx, *, key=None, state=None):
        if ctx.mixing is not None:
            raise ValueError(
                "psum_mean is a fused global all-reduce and only supports "
                "graph='full'; use allgather_mean (or qsgd/topk) for sparse "
                "overlays"
            )
        avg = jax.tree.map(
            lambda g: lax.pmean(g.astype(ctx.wire_dtype), ctx.axis).astype(jnp.float32),
            grads,
        )
        return avg, state

    def wire_bytes(self, grads_like, ctx) -> int:
        # Fused ring all-reduce: does not decompose into per-edge messages.
        raw = self.wire_bytes_per_edge(grads_like, ctx)
        P_ = max(ctx.num_peers, 1)
        return int(raw * 2 * (P_ - 1) / P_)


@register_exchange("qsgd")
class QSGDExchange(ExchangeProtocol):
    """QSGD-compressed exchange (paper §III-B.4): int8 levels + bucket norms.

    Stochastic quantization keeps the estimator unbiased; 8 + 32/bucket
    bits/element on the wire vs 32 uncompressed.
    """

    requires_key = True
    lossy = True

    def _cfg(self, ctx) -> C.QSGDConfig:
        return ctx.qsgd or C.QSGDConfig()

    def _combine(self, grads, ctx, *, key, want_local: bool):
        """Shared device path. The decode side is the FUSED formulation
        ``dequant_reduce`` (one pass over all P gathered int8 banks,
        mixing-weighted) — Pallas kernel when ``cfg.impl == "kernel"``,
        jnp reference otherwise. Returns (avg, local_image-or-None).
        """
        qcfg = self._cfg(ctx)
        if key is None:
            raise ValueError("qsgd exchange requires an rng key")
        key = jax.random.fold_in(key, lax.axis_index(ctx.axis))

        w = None if ctx.mixing is None else ctx.mix_row()[0]

        def leaf(g, k):
            payload = C.quantize(g, k, qcfg)  # routes cfg.impl for encode
            lev = lax.all_gather(payload["levels"], ctx.axis)  # (P, nb, B)
            nrm = lax.all_gather(payload["norms"], ctx.axis)  # (P, nb)
            P_ = lev.shape[0]
            wrow = jnp.full((P_,), 1.0 / P_, jnp.float32) if w is None else w
            flat = C.dequant_reduce(lev, nrm, wrow, qcfg).reshape(-1)
            avg = flat[: g.size].reshape(g.shape)
            if not want_local:
                return avg, None
            local = C.dequantize(payload, qcfg).reshape(g.shape)
            return avg, local

        leaves, treedef = jax.tree_util.tree_flatten(grads)
        keys = jax.random.split(key, len(leaves))
        pairs = [leaf(g, k) for g, k in zip(leaves, keys)]
        avg = jax.tree_util.tree_unflatten(treedef, [p[0] for p in pairs])
        if not want_local:
            return avg, None
        local = jax.tree_util.tree_unflatten(treedef, [p[1] for p in pairs])
        return avg, local

    def combine(self, grads, ctx, *, key=None, state=None):
        avg, _ = self._combine(grads, ctx, key=key, want_local=False)
        return avg, state

    def combine_ef(self, grads, ctx, *, key=None, state=None):
        avg, local = self._combine(grads, ctx, key=key, want_local=True)
        return avg, local, state

    def host_encode(self, grads, ctx, *, key=None):
        if key is None:
            raise ValueError("qsgd exchange requires an rng key")
        payload, _ = C.quantize_tree(grads, key, self._cfg(ctx))
        return payload, C.payload_bytes(payload)

    def host_decode(self, payload, grads_like, ctx):
        dense = C.dequantize_tree(payload, self._cfg(ctx))
        return jax.tree.map(lambda d, g: d.reshape(g.shape), dense, grads_like)

    def wire_bytes_per_edge(self, grads_like, ctx) -> int:
        qcfg = self._cfg(ctx)
        total = 0
        for x in jax.tree.leaves(grads_like):
            nb = -(-int(np.prod(x.shape)) // qcfg.bucket)  # ceil: padded buckets
            total += nb * qcfg.bucket * 1 + nb * 4  # int8 levels + fp32 norms
        return total


@register_exchange("topk")
class TopKExchange(ExchangeProtocol):
    """Top-k sparsified exchange: each peer ships only its ``topk_frac``
    largest-magnitude gradient entries (values + int32 indices); receivers
    scatter-add and average. Deterministic, biased towards large
    coordinates — the registry's proof-of-extension protocol.

    ``ctx.topk_impl`` picks the select/scatter implementation:
    ``"jnp"`` is the ``lax.top_k`` + ``.at[].add`` oracle; ``"kernel"``
    runs the Pallas bisection-threshold select+pack encoder and the fused
    scatter-accumulate decoder (``repro.kernels.topk``). On exact
    magnitude ties at the k-th position the two may pick different (equal
    magnitude) coordinates; otherwise they select identically.
    """

    lossy = True

    @staticmethod
    def _k(n: int, frac: float) -> int:
        return max(1, min(n, int(round(n * frac))))

    @staticmethod
    def _select(flat, k: int, ctx):
        """(k,) f32 values + (k,) int32 indices of the k largest |flat|."""
        from repro.kernels import ops as kops
        from repro.kernels import ref as kref

        if ctx.topk_impl == "kernel":
            return kops.topk_select_pack(flat, k)
        return kref.topk_select_ref(flat, k)

    @staticmethod
    def _scatter(vbank, ibank, wrow, n: int, ctx):
        """Fused sparse decode-reduce: (P, k) banks -> weighted dense (n,)."""
        from repro.kernels import ops as kops
        from repro.kernels import ref as kref

        if ctx.topk_impl == "kernel":
            return kops.topk_scatter_accum(vbank, ibank, wrow, n)
        return kref.topk_scatter_ref(vbank, ibank, wrow, n)

    def _combine(self, grads, ctx, *, want_local: bool):
        frac = ctx.topk_frac
        w = None if ctx.mixing is None else ctx.mix_row()[0]

        def leaf(g):
            flat = g.astype(jnp.float32).reshape(-1)
            k = self._k(flat.size, frac)
            vals, idx = self._select(flat, k, ctx)
            vbank = lax.all_gather(vals.astype(ctx.wire_dtype), ctx.axis)  # (P, k)
            ibank = lax.all_gather(idx, ctx.axis)  # (P, k)
            P_ = vbank.shape[0]
            wrow = jnp.full((P_,), 1.0 / P_, jnp.float32) if w is None else w
            dense = self._scatter(
                vbank.astype(jnp.float32), ibank, wrow, flat.size, ctx
            )
            avg = dense.reshape(g.shape)
            if not want_local:
                return avg, None
            local = self._scatter(
                vals[None].astype(jnp.float32),
                idx[None],
                jnp.ones((1,), jnp.float32),
                flat.size,
                ctx,
            ).reshape(g.shape)
            return avg, local

        leaves, treedef = jax.tree_util.tree_flatten(grads)
        pairs = [leaf(g) for g in leaves]
        avg = jax.tree_util.tree_unflatten(treedef, [p[0] for p in pairs])
        if not want_local:
            return avg, None
        local = jax.tree_util.tree_unflatten(treedef, [p[1] for p in pairs])
        return avg, local

    def combine(self, grads, ctx, *, key=None, state=None):
        avg, _ = self._combine(grads, ctx, want_local=False)
        return avg, state

    def combine_ef(self, grads, ctx, *, key=None, state=None):
        avg, local = self._combine(grads, ctx, want_local=True)
        return avg, local, state

    def host_encode(self, grads, ctx, *, key=None):
        frac = ctx.topk_frac
        itemsize = jnp.dtype(ctx.wire_dtype).itemsize
        nbytes = 0
        payload = []
        for g in jax.tree.leaves(grads):
            flat = jnp.asarray(g, jnp.float32).reshape(-1)
            k = self._k(flat.size, frac)
            vals, idx = self._select(flat, k, ctx)
            payload.append(
                {
                    "values": vals.astype(ctx.wire_dtype),
                    "idx": idx,
                    "shape": np.asarray(g.shape, np.int64),
                }
            )
            nbytes += k * (itemsize + 4)
        treedef = jax.tree_util.tree_structure(grads)
        return jax.tree_util.tree_unflatten(treedef, payload), nbytes

    def host_decode(self, payload, grads_like, ctx):
        def leaf(p, g):
            n = int(np.prod(p["shape"])) if len(p["shape"]) else 1
            dense = self._scatter(
                p["values"].astype(jnp.float32)[None],
                jnp.asarray(p["idx"])[None],
                jnp.ones((1,), jnp.float32),
                n,
                ctx,
            )
            return dense.reshape(tuple(int(d) for d in p["shape"]))

        is_payload = lambda x: isinstance(x, dict) and "values" in x
        return jax.tree.map(leaf, payload, grads_like, is_leaf=is_payload)

    def wire_bytes_per_edge(self, grads_like, ctx) -> int:
        itemsize = jnp.dtype(ctx.wire_dtype).itemsize
        return sum(
            self._k(int(np.prod(x.shape)), ctx.topk_frac) * (itemsize + 4)
            for x in jax.tree.leaves(grads_like)
        )


@register_exchange("async")
class StalenessMailbox(ExchangeProtocol):
    """Asynchronous staleness-K mailbox exchange (paper's "latest available
    gradient", generalized). The carried state is a ring of the last K
    published register banks, leaves shaped ``(K, P, *grad)``; peers consume
    the bank published K steps ago (K=1 == the paper's staleness-1) while
    their own contribution is always fresh.
    """

    is_async = True

    def init_state(self, grads_like, ctx):
        K = max(1, int(ctx.staleness))
        return jax.tree.map(
            lambda g: jnp.zeros((K, ctx.num_peers) + tuple(g.shape), jnp.float32),
            grads_like,
        )

    def combine(self, grads, ctx, *, key=None, state=None):
        if state is None:
            raise ValueError(
                "async exchange requires mailbox state; initialize the train "
                "state with init_mailbox(...) or ExchangeProtocol.init_state(...)"
            )
        r = lax.axis_index(ctx.axis)
        # Gather in the wire dtype (so byte accounting matches what ships),
        # store the ring in fp32 for the staleness arithmetic.
        fresh = jax.tree.map(
            lambda g: lax.all_gather(g.astype(ctx.wire_dtype), ctx.axis)
            .astype(jnp.float32),
            grads,
        )

        w = None if ctx.mixing is None else jnp.asarray(ctx.mixing, jnp.float32)[r]

        def comb(ring, g):
            oldest = ring[0]  # bank published K steps ago
            if w is None:
                nP = oldest.shape[0]
                others = oldest.sum(0) - oldest[r]
                return (others + g.astype(jnp.float32)) / nP
            # neighbor-weighted stale mix; own contribution is always fresh
            others = jnp.tensordot(w, oldest, axes=(0, 0)) - w[r] * oldest[r]
            return others + w[r] * g.astype(jnp.float32)

        avg = jax.tree.map(comb, state, grads)
        new_state = jax.tree.map(
            lambda ring, f: jnp.concatenate([ring[1:], f[None]], axis=0), state, fresh
        )
        return avg, new_state


@register_exchange("reduce_scatter")
class ReduceScatterMean(ExchangeProtocol):
    """Sharded mean: ring reduce-scatter + allgather over contiguous shards.

    The LambdaML/SPIRT communication pattern brought into the registry:
    the gradient pytree flattens into one buffer (:class:`ShardPlan`),
    peer ``r`` ends up owning the fully-reduced shard ``r`` after ``P-1``
    ``ppermute`` ring hops, divides by ``P``, and an allgather of the
    owned shards reconstructs the global mean everywhere. Shards — not
    whole pytrees — are the unit of exchange, so the per-edge payload is
    ``model / P`` and each peer's aggregation work is ``O(model / P)``
    per contribution instead of ``O(model)``.

    Bit-math: the reduced buffer equals the peer mean (summation order
    differs from ``mean(axis=0)`` only by float re-association), so the
    full-graph result matches ``allgather_mean`` to ~1e-6 — the safety
    rail the equivalence tests pin down on device and host. The shard
    layout is inherently global (shard ``r`` aggregates over ALL peers),
    so sparse overlays are refused, like ``psum_mean``.

    Host image: peers publish shard-addressed *pieces* to the mailbox,
    each owner aggregates only its shard and re-broadcasts it — P
    aggregators that run as parallel serverless invocations (see
    ``ServerlessExecutor.simulate_aggregation``), with Lambda memory
    sized from shard bytes instead of model bytes.
    """

    requires_full_graph = True
    sharded = True

    def plan(self, grads_like, ctx: ExchangeContext) -> ShardPlan:
        """The shard layout for this peer count — one shard per peer."""
        return ShardPlan.for_tree(grads_like, max(int(ctx.num_peers), 1))

    def _check_full(self, ctx: ExchangeContext):
        if ctx.mixing is not None:
            raise ValueError(
                "reduce_scatter shards are aggregated over ALL peers and "
                "the protocol only supports graph='full'; use "
                "allgather_mean (or qsgd/topk) for sparse overlays"
            )

    # -- device path ---------------------------------------------------------
    def combine(self, grads, ctx, *, key=None, state=None):
        self._check_full(ctx)
        P_ = int(ctx.num_peers)
        plan = self.plan(grads, ctx)
        buf = plan.shards(grads).astype(jnp.float32)  # (P, S)
        if P_ == 1:
            return plan.unflatten(buf), state
        r = lax.axis_index(ctx.axis)
        perm = [(i, (i + 1) % P_) for i in range(P_)]

        def take(c):
            return lax.dynamic_index_in_dim(buf, c, axis=0, keepdims=False)

        # Ring reduce-scatter: after P-1 hops rank r holds sum_j shard_r(j).
        # Invariant: before hop s, the carried partial covers shard
        # (r - 1 - s) mod P over peers {r-s, ..., r}; each hop forwards the
        # partial one rank clockwise and the receiver adds its own piece.
        acc = take(jnp.mod(r - 1, P_))
        for s in range(P_ - 1):
            acc = lax.ppermute(acc.astype(ctx.wire_dtype), ctx.axis, perm)
            acc = acc.astype(jnp.float32) + take(jnp.mod(r - 2 - s, P_))
        own = acc / P_  # rank r owns the fully-reduced (mean) shard r
        # Allgather phase: rank j contributes reduced shard j, so the
        # gathered bank rows are already in shard-index order.
        bank = lax.all_gather(own.astype(ctx.wire_dtype), ctx.axis)
        return plan.unflatten(bank.astype(jnp.float32)), state

    # -- host path (shard-addressed) -----------------------------------------
    def host_encode_shard(self, shard_values, ctx: ExchangeContext, *, key=None):
        """One shard row -> (wire payload, wire bytes)."""
        wire = jnp.asarray(shard_values).astype(ctx.wire_dtype)
        return wire, int(wire.size * jnp.dtype(ctx.wire_dtype).itemsize)

    def host_decode_shard(self, payload, ctx: ExchangeContext):
        """Wire shard payload -> fp32 shard row."""
        return jnp.asarray(payload).astype(jnp.float32)

    # -- accounting ----------------------------------------------------------
    def wire_bytes_per_edge(self, grads_like, ctx) -> int:
        """One shard crosses one edge: ``model / P`` bytes — the payload
        figure that shrinks as 1/P while dense protocols stay flat."""
        return self.plan(grads_like, ctx).shard_bytes(ctx.wire_dtype)

    def wire_bytes(self, grads_like, ctx) -> int:
        """Ring reduce-scatter + allgather: (P-1) shard sends per phase."""
        P_ = max(int(ctx.num_peers), 1)
        return 2 * (P_ - 1) * self.wire_bytes_per_edge(grads_like, ctx)

    def host_wire_bytes(self, grads_like, ctx) -> int:
        """Mailbox publishes per step: P-1 shard pieces (one per other
        owner) + this peer's re-broadcast aggregated shard."""
        P_ = max(int(ctx.num_peers), 1)
        return P_ * self.wire_bytes_per_edge(grads_like, ctx)


# ---------------------------------------------------------------------------
# Byzantine-robust protocols (estimators in repro.core.robust)
# ---------------------------------------------------------------------------


class _RobustExchange(ExchangeProtocol):
    """Shared machinery of the robust family: gather the full dense bank,
    optionally norm-clip each peer row (``ctx.robust_clip``), and hand the
    bank to the subclass estimator.

    Wire accounting is HONEST about the robustness tax: these protocols
    need every neighbor's dense gradient materialized (order statistics /
    distance scores don't decompose into a fused reduction), so they
    inherit the dense ``allgather_mean`` byte counts — ``(P-1) x model``
    per peer on the full mesh, vs ``2(P-1)/P x model`` for ``psum_mean``
    and ``2(P-1)/P x model`` total for ``reduce_scatter``. That delta IS
    the robustness-vs-wire-cost trade-off fig12 quantifies.
    """

    def _mask(self, ctx: ExchangeContext):
        """(P,) closed-neighborhood mask for this rank, or None on the
        full graph (every peer is a member — skip the mask arithmetic)."""
        if ctx.mixing is None:
            return None
        closed = np.asarray(ctx.graph.adjacency) | np.eye(
            ctx.num_peers, dtype=bool
        )
        r = lax.axis_index(ctx.axis)
        return lax.dynamic_index_in_dim(
            jnp.asarray(closed), r, 0, keepdims=False
        )

    def _prepare(self, bank, ctx: ExchangeContext):
        if ctx.robust_clip > 0.0:
            return R.clip_bank_to_norm(bank, ctx.robust_clip)
        return bank

    def _aggregate(self, bank, mask, ctx: ExchangeContext):
        raise NotImplementedError

    def combine(self, grads, ctx, *, key=None, state=None):
        bank = jax.tree.map(
            lambda g: lax.all_gather(g.astype(ctx.wire_dtype), ctx.axis)
            .astype(jnp.float32),
            grads,
        )
        mask = self._mask(ctx)
        return self._aggregate(self._prepare(bank, ctx), mask, ctx), state

    def host_combine(self, grads_peers, rank: int, ctx: ExchangeContext):
        """Robust aggregate over the contributions that actually arrived
        (the mailbox already restricted consumption to graph edges, so
        the arrived set IS the closed neighborhood — possibly smaller
        under churn, which the order statistics absorb)."""
        ranks = sorted(grads_peers)
        bank = jax.tree.map(
            lambda *xs: jnp.stack([jnp.asarray(x, jnp.float32) for x in xs]),
            *[grads_peers[j] for j in ranks],
        )
        return self._aggregate(self._prepare(bank, ctx), None, ctx)


@register_exchange("trimmed_mean")
class TrimmedMeanExchange(_RobustExchange):
    """Coordinate-wise trimmed mean: drop the ``f`` fraction of values
    from each end of every coordinate, mean the rest. ``trimmed_mean:f``
    (e.g. ``trimmed_mean:0.25``) sets the trim; bare ``trimmed_mean``
    reads ``ctx.trim_frac``. Survives up to ``f`` Byzantine peers per
    coordinate; ``f=0`` is exactly the plain mean (the equivalence rail).
    Composes with sparse overlays: each peer trims over its closed
    neighborhood instead of mixing with MH weights."""

    def __init__(self, param: Optional[str] = None):
        self.frac: Optional[float] = None
        if param is not None:
            self.frac = float(param)
            if not 0.0 <= self.frac < 0.5:
                raise ValueError(
                    f"trimmed_mean trim fraction must be in [0, 0.5), "
                    f"got {self.frac}"
                )

    def _trim(self, ctx) -> float:
        return ctx.trim_frac if self.frac is None else self.frac

    def _aggregate(self, bank, mask, ctx):
        frac = self._trim(ctx)

        def leaf(b):
            # host path under churn: bank rows = contributions that
            # ARRIVED, possibly < num_peers — size the mask from the leaf
            m = jnp.ones((b.shape[0],), bool) if mask is None else mask
            return R.masked_trimmed_mean(b, m, frac)

        return jax.tree.map(leaf, bank)


@register_exchange("median")
class CoordinateMedianExchange(_RobustExchange):
    """Coordinate-wise median — the no-hyperparameter robust baseline
    with breakdown point 1/2 per coordinate. Composes with sparse
    overlays (median over the closed neighborhood)."""

    def _aggregate(self, bank, mask, ctx):
        def leaf(b):
            m = jnp.ones((b.shape[0],), bool) if mask is None else mask
            return R.masked_median(b, m)

        return jax.tree.map(leaf, bank)


@register_exchange("krum")
class KrumExchange(_RobustExchange):
    """Krum / multi-Krum (Blanchard et al., 2017): score every
    contribution by its summed squared distance to its ``P - f - 2``
    nearest peers, average the ``m`` lowest-scored gradients.
    ``krum`` selects 1 (classic Krum); ``krum:m`` averages the top m.
    The pairwise distances need ALL contributions, so sparse overlays
    are refused (``requires_full_graph``), like ``reduce_scatter``."""

    requires_full_graph = True

    def __init__(self, param: Optional[str] = None):
        self.m: Optional[int] = None
        if param is not None:
            self.m = int(param)
            if self.m < 1:
                raise ValueError(f"krum selection count must be >= 1, got {self.m}")

    def _select_count(self, ctx) -> int:
        return ctx.krum_m if self.m is None else self.m

    def _aggregate(self, bank, mask, ctx):
        flat, unflatten = R.flatten_bank(bank)
        m = min(self._select_count(ctx), int(flat.shape[0]))
        agg, _ = R.krum_select(flat, m=m, f=ctx.krum_f)
        return unflatten(agg)
