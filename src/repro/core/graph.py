"""Peer overlay graphs — the communication topology as a first-class API.

Every layer of the seed repro hard-coded a fully-connected overlay: the
exchange protocols averaged over *all* peers, :class:`HostMailbox`
broadcast to all P queues, and the cost model charged ``(P-1) x payload``
per step. The paper's central scalability concern is exactly that
communication overhead as P grows; SPIRT (arXiv:2309.14148) and the
fault-tolerance architecture study (arXiv:2302.13995) both motivate
sparser, churn-tolerant peer graphs. This module makes the overlay a
registry-backed abstraction, mirroring ``exchange.py``:

* :class:`PeerGraph` — neighbor sets, a Metropolis–Hastings mixing matrix
  ``W``, and diagnostics (degrees, spectral gap).
* ``@register_graph`` / :func:`get_graph` — name-based resolution with
  parameterized specs: ``"full"``, ``"ring"``, ``"gossip:k"`` (seeded
  random ≥k-regular on a ring backbone), ``"hierarchical[:group]"``
  (hub-and-spoke groups, hubs fully connected), ``"static"`` (explicit
  adjacency, programmatic only).

``Topology(graph="ring")`` resolves through this registry; sync exchange
protocols generalize from the global mean to neighbor-weighted mixing
``x_r <- sum_j W[r, j] x_j``.

Why Metropolis–Hastings: with ``W_ij = 1 / (1 + max(d_i, d_j))`` on edges
and ``W_ii = 1 - sum_j W_ij``, the matrix is symmetric and doubly
stochastic for ANY undirected graph, so decentralized SGD preserves the
gradient average in expectation and converges at a rate governed by the
spectral gap ``1 - |lambda_2(W)|``. On the complete graph every degree is
``P - 1``, so ``W_ij = 1/P`` everywhere — the neighbor-weighted mix
*provably reduces* to today's ``allgather_mean`` arithmetic; the exchange
layer exploits this by keeping the legacy (bit-exact) mean path whenever
the resolved graph is ``full``.
"""
from __future__ import annotations

import abc
from typing import ClassVar, Dict, List, Optional, Sequence, Tuple, Type

import numpy as np


class PeerGraph(abc.ABC):
    """An undirected overlay over ``num_peers`` ranks.

    Rank ``r`` is the peer's mesh-axis index on the device path and the
    ``PeerState.rank`` on the host path, so one graph object describes
    both. Subclasses implement :meth:`build_adjacency`; everything else
    (neighbors, mixing matrix, diagnostics) derives from it.
    """

    name: ClassVar[str] = "?"  # set by @register_graph

    def __init__(self, num_peers: int):
        if num_peers < 1:
            raise ValueError(f"num_peers must be >= 1, got {num_peers}")
        self.num_peers = int(num_peers)
        adj = np.asarray(self.build_adjacency(), dtype=bool)
        if adj.shape != (num_peers, num_peers):
            raise ValueError(
                f"{type(self).__name__} built adjacency {adj.shape}, "
                f"expected {(num_peers, num_peers)}"
            )
        if not np.array_equal(adj, adj.T):
            raise ValueError(f"{type(self).__name__} adjacency must be symmetric")
        np.fill_diagonal(adj, False)  # no self-loops; W_ii comes from MH
        self._adj = adj
        self._adj.setflags(write=False)

    # -- construction --------------------------------------------------------
    @abc.abstractmethod
    def build_adjacency(self) -> np.ndarray:
        """(P, P) symmetric bool adjacency; the diagonal is ignored."""

    # -- neighbor sets -------------------------------------------------------
    @property
    def adjacency(self) -> np.ndarray:
        return self._adj

    def neighbors(self, rank: int) -> Tuple[int, ...]:
        """Ranks adjacent to ``rank`` (self excluded), ascending."""
        return tuple(int(j) for j in np.flatnonzero(self._adj[rank]))

    @property
    def is_full(self) -> bool:
        """True iff every pair of distinct peers is connected."""
        P = self.num_peers
        return bool(self._adj.sum() == P * (P - 1))

    def is_connected(self) -> bool:
        P = self.num_peers
        seen = {0}
        frontier = [0]
        while frontier:
            r = frontier.pop()
            for j in self.neighbors(r):
                if j not in seen:
                    seen.add(j)
                    frontier.append(j)
        return len(seen) == P

    # -- mixing --------------------------------------------------------------
    def mixing_matrix(self) -> np.ndarray:
        """Metropolis–Hastings weights: symmetric, doubly stochastic fp64.

        ``W_ij = 1 / (1 + max(d_i, d_j))`` on edges, ``W_ii`` absorbs the
        remainder. Degrees exclude self, so an isolated peer gets
        ``W_ii = 1`` (it keeps its own gradient).
        """
        P = self.num_peers
        d = self.degrees
        W = np.zeros((P, P), dtype=np.float64)
        for i in range(P):
            for j in self.neighbors(i):
                W[i, j] = 1.0 / (1.0 + max(d[i], d[j]))
            W[i, i] = 1.0 - W[i].sum()
        return W

    # -- diagnostics ---------------------------------------------------------
    @property
    def degrees(self) -> np.ndarray:
        return self._adj.sum(axis=1).astype(np.int64)

    @property
    def max_degree(self) -> int:
        return int(self.degrees.max())

    @property
    def mean_degree(self) -> float:
        return float(self.degrees.mean())

    @property
    def num_edges(self) -> int:
        """Undirected edge count."""
        return int(self._adj.sum()) // 2

    def spectral_gap(self) -> float:
        """``1 - |lambda_2|`` of the mixing matrix — the decentralized-SGD
        consensus rate. 1.0 for the complete graph (one-shot consensus),
        0.0 for a disconnected graph (no consensus across components)."""
        if self.num_peers == 1:
            return 1.0
        lam = np.linalg.eigvalsh(self.mixing_matrix())
        mags = np.sort(np.abs(lam))[::-1]
        return float(1.0 - mags[1])

    def describe(self) -> str:
        return (
            f"{self.name}(P={self.num_peers}, degree"
            f"={self.mean_degree:g} mean/{self.max_degree} max, "
            f"edges={self.num_edges}, spectral_gap={self.spectral_gap():.3f})"
        )


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Type[PeerGraph]] = {}


def register_graph(name: str):
    """Class decorator: make a graph reachable as ``Topology(graph=name)``."""

    def deco(cls: Type[PeerGraph]) -> Type[PeerGraph]:
        if not issubclass(cls, PeerGraph):
            raise TypeError(f"{cls!r} must subclass PeerGraph")
        cls.name = name
        _REGISTRY[name] = cls
        return cls

    return deco


def available_graphs() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def get_graph(spec, num_peers: int, *, seed: int = 0) -> PeerGraph:
    """Resolve a graph spec for ``num_peers`` ranks.

    ``spec`` is a :class:`PeerGraph` instance (validated for size and
    passed through), or a registered name with an optional integer
    parameter suffix: ``"full"``, ``"ring"``, ``"gossip:3"``,
    ``"hierarchical:4"``. ``seed`` feeds stochastic constructions
    (``gossip``) so the overlay is reproducible.
    """
    if isinstance(spec, PeerGraph):
        if spec.num_peers != num_peers:
            raise ValueError(
                f"graph was built for {spec.num_peers} peers, "
                f"topology has {num_peers}"
            )
        return spec
    name, _, arg = str(spec).partition(":")
    try:
        cls = _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown peer graph {spec!r}; registered graphs: "
            f"{', '.join(available_graphs())}"
        ) from None
    kwargs = {}
    if arg:
        try:
            kwargs["param"] = int(arg)
        except ValueError:
            raise ValueError(
                f"graph spec {spec!r}: parameter after ':' must be an int"
            ) from None
    try:
        return cls(num_peers, seed=seed, **kwargs)
    except TypeError:
        # mirror get_exchange: an un-parameterized graph given a ':' arg is
        # a clean spec error, not a constructor-signature leak
        if kwargs:
            raise ValueError(
                f"peer graph {name!r} does not take a ':' parameter "
                f"(got {spec!r})"
            ) from None
        raise


# ---------------------------------------------------------------------------
# Registered graphs
# ---------------------------------------------------------------------------


@register_graph("full")
class FullGraph(PeerGraph):
    """Complete graph — the seed repo's implicit overlay. MH mixing is the
    uniform ``1/P`` matrix, i.e. exactly the global mean."""

    def __init__(self, num_peers: int, *, seed: int = 0):
        super().__init__(num_peers)

    def build_adjacency(self) -> np.ndarray:
        return ~np.eye(self.num_peers, dtype=bool)


@register_graph("ring")
class RingGraph(PeerGraph):
    """Bidirectional ring: ``r`` talks to ``(r ± 1) mod P``. Per-peer wire
    bytes are O(1) in P — the canonical sparse decentralized-SGD overlay."""

    def __init__(self, num_peers: int, *, seed: int = 0):
        super().__init__(num_peers)

    def build_adjacency(self) -> np.ndarray:
        P = self.num_peers
        adj = np.zeros((P, P), dtype=bool)
        for r in range(P):
            adj[r, (r + 1) % P] = adj[(r + 1) % P, r] = True
        np.fill_diagonal(adj, False)  # P == 1, 2 degenerate cases
        return adj


@register_graph("gossip")
class GossipGraph(PeerGraph):
    """Seeded random ≥k-regular gossip overlay on a ring backbone.

    A ring guarantees connectivity; extra edges are then sampled
    uniformly (without replacement, seeded) until every peer has degree
    at least ``k``. ``"gossip:3"`` selects k=3; per-peer wire bytes are
    O(k), independent of P.
    """

    def __init__(self, num_peers: int, *, seed: int = 0, param: Optional[int] = None):
        self.k = int(param) if param is not None else 3
        if self.k < 1:
            raise ValueError(f"gossip degree k must be >= 1, got {self.k}")
        self.seed = seed
        super().__init__(num_peers)

    def build_adjacency(self) -> np.ndarray:
        P = self.num_peers
        adj = RingGraph(P).adjacency.copy()
        if self.k <= 2 or P <= 3:
            return adj
        rng = np.random.default_rng(self.seed)
        # candidate non-ring edges, shuffled once for determinism
        cand = [(i, j) for i in range(P) for j in range(i + 1, P) if not adj[i, j]]
        rng.shuffle(cand)
        deg = adj.sum(axis=1)
        for i, j in cand:
            if deg.min() >= self.k:
                break
            if deg[i] < self.k or deg[j] < self.k:
                adj[i, j] = adj[j, i] = True
                deg[i] += 1
                deg[j] += 1
        return adj


@register_graph("hierarchical")
class HierarchicalGraph(PeerGraph):
    """Hub-and-spoke groups: peers split into consecutive groups of
    ``group`` ranks, each group's first rank is its hub; spokes connect
    only to their hub, hubs form a complete graph among themselves.
    ``"hierarchical:4"`` selects group size 4 (default: ~sqrt(P)) — the
    SPIRT-style two-level aggregation overlay."""

    def __init__(self, num_peers: int, *, seed: int = 0, param: Optional[int] = None):
        if param is not None and param < 1:
            raise ValueError(f"hierarchical group size must be >= 1, got {param}")
        self.group = int(param) if param is not None else max(
            1, int(round(np.sqrt(num_peers)))
        )
        super().__init__(num_peers)

    def build_adjacency(self) -> np.ndarray:
        P = self.num_peers
        adj = np.zeros((P, P), dtype=bool)
        hubs = list(range(0, P, self.group))
        for h in hubs:
            for r in range(h + 1, min(h + self.group, P)):
                adj[h, r] = adj[r, h] = True  # spoke <-> its hub
        for a in hubs:
            for b in hubs:
                if a != b:
                    adj[a, b] = adj[b, a] = True  # hub mesh
        return adj


@register_graph("static")
class StaticGraph(PeerGraph):
    """Explicit adjacency — programmatic only (``Topology(graph=StaticGraph
    .from_edges(P, [...]))``); resolving the bare name raises because there
    is no adjacency to build from."""

    def __init__(self, num_peers: int, adjacency=None, *, seed: int = 0):
        if adjacency is None:
            raise ValueError(
                "static graph needs an explicit adjacency: construct "
                "StaticGraph(P, adjacency) or StaticGraph.from_edges(P, edges) "
                "and pass the instance, not the name"
            )
        self._static_adj = np.asarray(adjacency, dtype=bool)
        super().__init__(num_peers)

    @classmethod
    def from_edges(cls, num_peers: int, edges: Sequence[Tuple[int, int]]):
        adj = np.zeros((num_peers, num_peers), dtype=bool)
        for i, j in edges:
            adj[i, j] = adj[j, i] = True
        return cls(num_peers, adj)

    def build_adjacency(self) -> np.ndarray:
        return self._static_adj
