"""Peer overlay graphs — the communication topology as a first-class API.

Every layer of the seed repro hard-coded a fully-connected overlay: the
exchange protocols averaged over *all* peers, :class:`HostMailbox`
broadcast to all P queues, and the cost model charged ``(P-1) x payload``
per step. The paper's central scalability concern is exactly that
communication overhead as P grows; SPIRT (arXiv:2309.14148) and the
fault-tolerance architecture study (arXiv:2302.13995) both motivate
sparser, churn-tolerant peer graphs. This module makes the overlay a
registry-backed abstraction, mirroring ``exchange.py``:

* :class:`PeerGraph` — neighbor sets, a Metropolis–Hastings mixing matrix
  ``W``, and diagnostics (degrees, spectral gap).
* ``@register_graph`` / :func:`get_graph` — name-based resolution with
  parameterized specs: ``"full"``, ``"ring"``, ``"gossip:k"`` (seeded
  random ≥k-regular on a ring backbone), ``"hierarchical[:group]"``
  (hub-and-spoke groups, hubs fully connected), ``"static"`` (explicit
  adjacency, programmatic only).

``Topology(graph="ring")`` resolves through this registry; sync exchange
protocols generalize from the global mean to neighbor-weighted mixing
``x_r <- sum_j W[r, j] x_j``.

Why Metropolis–Hastings: with ``W_ij = 1 / (1 + max(d_i, d_j))`` on edges
and ``W_ii = 1 - sum_j W_ij``, the matrix is symmetric and doubly
stochastic for ANY undirected graph, so decentralized SGD preserves the
gradient average in expectation and converges at a rate governed by the
spectral gap ``1 - |lambda_2(W)|``. On the complete graph every degree is
``P - 1``, so ``W_ij = 1/P`` everywhere — the neighbor-weighted mix
*provably reduces* to today's ``allgather_mean`` arithmetic; the exchange
layer exploits this by keeping the legacy (bit-exact) mean path whenever
the resolved graph is ``full``.

Storage contract (10k–100k peers): graphs are CSR neighbor lists
(``indptr`` / ``indices``), built vectorized — O(E) memory, never O(P²).
The dense surfaces (``adjacency``, ``mixing_matrix()``) are *lazy* and
gated behind ``DENSE_MATERIALIZE_LIMIT``: below the limit they
materialize (and the sparse per-row accessors are property-tested against
them); above it they raise with a pointer to the O(degree) accessors —
``neighbors_array(r)``, ``mixing_row(r)``, ``mixing_weights(r)``,
``has_edge(i, j)``. The spectral gap switches from the O(P³)
``eigvalsh`` oracle to power iteration on the sparse mixing operator.
``FullGraph`` stores nothing at all (the complete graph is implicit), so
even P=100k "full" overlays cost O(1) memory.
"""
from __future__ import annotations

import abc
from typing import ClassVar, Dict, List, Optional, Sequence, Tuple, Type

import numpy as np

# Largest peer count for which the dense (P, P) surfaces — ``adjacency``
# and ``mixing_matrix()`` — may materialize. 4096² bools = 16 MB /
# float64s = 128 MB: fine for tests and small fleets, a hard refusal
# beyond (a 100k-peer dense mixing matrix would be 80 GB).
DENSE_MATERIALIZE_LIMIT = 4096


def _csr_from_edges(num_peers: int, edges: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Unique undirected edges ``(E, 2)`` -> sorted CSR (indptr, indices)."""
    P = int(num_peers)
    edges = np.asarray(edges, np.int64).reshape(-1, 2)
    if edges.size:
        a = np.minimum(edges[:, 0], edges[:, 1])
        b = np.maximum(edges[:, 0], edges[:, 1])
        keep = a != b  # no self-loops
        a, b = a[keep], b[keep]
        key = np.unique(a * P + b)  # dedupe + deterministic order
        a, b = key // P, key % P
        both = np.concatenate([np.stack([a, b], 1), np.stack([b, a], 1)])
        order = np.lexsort((both[:, 1], both[:, 0]))
        both = both[order]
        indices = np.ascontiguousarray(both[:, 1])
        counts = np.bincount(both[:, 0], minlength=P)
    else:
        indices = np.zeros(0, np.int64)
        counts = np.zeros(P, np.int64)
    indptr = np.zeros(P + 1, np.int64)
    np.cumsum(counts, out=indptr[1:])
    return indptr, indices


def _gather_rows(
    indptr: np.ndarray, indices: np.ndarray, rows: np.ndarray
) -> np.ndarray:
    """Concatenated CSR rows ``indices[indptr[r]:indptr[r+1]] for r in rows``
    without a Python loop (the classic multi-range gather trick)."""
    starts = indptr[rows]
    counts = indptr[rows + 1] - starts
    total = int(counts.sum())
    if total == 0:
        return np.zeros(0, np.int64)
    # src[t] = starts[r] + (t - cumstart[r]) for the row r owning slot t
    cumstart = np.concatenate([[0], np.cumsum(counts)[:-1]])
    src = np.repeat(starts - cumstart, counts) + np.arange(total, dtype=np.int64)
    return indices[src]


class PeerGraph(abc.ABC):
    """An undirected overlay over ``num_peers`` ranks.

    Rank ``r`` is the peer's mesh-axis index on the device path and the
    ``PeerState.rank`` on the host path, so one graph object describes
    both. Subclasses implement :meth:`build_neighbors` (CSR, preferred —
    O(E)) or legacy :meth:`build_adjacency` (dense, auto-converted);
    everything else (neighbor queries, mixing weights, diagnostics)
    derives from the CSR storage.
    """

    name: ClassVar[str] = "?"  # set by @register_graph
    # Implicit graphs (the complete graph) answer every query analytically
    # and skip CSR storage entirely — O(1) memory at any P.
    implicit: ClassVar[bool] = False

    def __init__(self, num_peers: int):
        if num_peers < 1:
            raise ValueError(f"num_peers must be >= 1, got {num_peers}")
        self.num_peers = int(num_peers)
        self._dense: Optional[np.ndarray] = None  # lazy (P, P) bool
        self._degrees: Optional[np.ndarray] = None
        # lazy Metropolis–Hastings CSR-aligned edge weights + self weights
        self._mix_rows_cache: Optional[np.ndarray] = None  # row of each nz
        self._mix_w: Optional[np.ndarray] = None
        self._mix_self: Optional[np.ndarray] = None
        if not self.implicit:
            self._indptr, self._indices = self._validated_csr()

    # -- construction --------------------------------------------------------
    def build_neighbors(self) -> Tuple[np.ndarray, np.ndarray]:
        """CSR ``(indptr, indices)`` — override this for O(E) construction.

        The default converts a legacy dense :meth:`build_adjacency`, so
        existing subclasses keep working unchanged (at dense cost).
        """
        adj = np.asarray(self.build_adjacency(), dtype=bool)
        P = self.num_peers
        if adj.shape != (P, P):
            raise ValueError(
                f"{type(self).__name__} built adjacency {adj.shape}, "
                f"expected {(P, P)}"
            )
        if not np.array_equal(adj, adj.T):
            raise ValueError(f"{type(self).__name__} adjacency must be symmetric")
        adj = adj.copy()
        np.fill_diagonal(adj, False)  # no self-loops; W_ii comes from MH
        rows, cols = np.nonzero(adj)
        indptr = np.zeros(P + 1, np.int64)
        np.cumsum(np.bincount(rows, minlength=P), out=indptr[1:])
        return indptr, cols.astype(np.int64)

    def build_adjacency(self) -> np.ndarray:
        """(P, P) symmetric bool adjacency; the diagonal is ignored.
        Legacy hook — implement :meth:`build_neighbors` for large P."""
        raise NotImplementedError(
            f"{type(self).__name__} must implement build_neighbors() "
            "(CSR, scalable) or build_adjacency() (dense, legacy)"
        )

    def _validated_csr(self) -> Tuple[np.ndarray, np.ndarray]:
        indptr, indices = self.build_neighbors()
        indptr = np.asarray(indptr, np.int64)
        indices = np.asarray(indices, np.int64)
        P = self.num_peers
        if indptr.shape != (P + 1,) or indptr[0] != 0 or indptr[-1] != indices.size:
            raise ValueError(
                f"{type(self).__name__} built a malformed CSR indptr "
                f"(shape {indptr.shape}, last={indptr[-1] if indptr.size else '-'}, "
                f"nnz={indices.size})"
            )
        if indices.size:
            if indices.min() < 0 or indices.max() >= P:
                raise ValueError(
                    f"{type(self).__name__} CSR indices out of range [0, {P})"
                )
            rows = np.repeat(np.arange(P, dtype=np.int64), np.diff(indptr))
            if np.any(rows == indices):
                raise ValueError(
                    f"{type(self).__name__} adjacency has self-loops; a peer "
                    "is not its own neighbor"
                )
            # symmetry: the directed edge multiset must equal its reverse
            fwd = np.sort(rows * P + indices)
            rev = np.sort(indices * P + rows)
            if not np.array_equal(fwd, rev):
                raise ValueError(
                    f"{type(self).__name__} adjacency must be symmetric"
                )
            if fwd.size != np.unique(fwd).size:
                raise ValueError(
                    f"{type(self).__name__} CSR contains duplicate edges"
                )
        indptr.setflags(write=False)
        indices.setflags(write=False)
        return indptr, indices

    # -- neighbor sets -------------------------------------------------------
    @property
    def adjacency(self) -> np.ndarray:
        """Dense (P, P) bool view — lazy, and refused above
        ``DENSE_MATERIALIZE_LIMIT`` (use :meth:`neighbors_array` /
        :meth:`has_edge` at scale)."""
        if self._dense is None:
            self._check_dense_ok("adjacency")
            P = self.num_peers
            dense = np.zeros((P, P), dtype=bool)
            if self._indices.size:
                rows = np.repeat(np.arange(P), np.diff(self._indptr))
                dense[rows, self._indices] = True
            dense.setflags(write=False)
            self._dense = dense
        return self._dense

    def _check_dense_ok(self, what: str) -> None:
        if self.num_peers > DENSE_MATERIALIZE_LIMIT:
            raise ValueError(
                f"refusing to materialize dense {what} for P="
                f"{self.num_peers} (> DENSE_MATERIALIZE_LIMIT="
                f"{DENSE_MATERIALIZE_LIMIT}): that is O(P^2) memory. Use the "
                "sparse surface instead — neighbors_array(r), mixing_row(r), "
                "mixing_weights(r), has_edge(i, j), spectral_gap()."
            )

    def neighbors_array(self, rank: int) -> np.ndarray:
        """Ranks adjacent to ``rank`` as an int64 array (ascending) —
        an O(1) CSR slice, the scalable form of :meth:`neighbors`."""
        return self._indices[self._indptr[rank]:self._indptr[rank + 1]]

    def neighbors(self, rank: int) -> Tuple[int, ...]:
        """Ranks adjacent to ``rank`` (self excluded), ascending."""
        return tuple(int(j) for j in self.neighbors_array(rank))

    def has_edge(self, i: int, j: int) -> bool:
        """O(log degree) undirected edge test (False for i == j)."""
        row = self.neighbors_array(i)
        pos = np.searchsorted(row, j)
        return bool(pos < row.size and row[pos] == j)

    @property
    def is_full(self) -> bool:
        """True iff every pair of distinct peers is connected."""
        P = self.num_peers
        return self.num_edges * 2 == P * (P - 1)

    def is_connected(self) -> bool:
        """Vectorized frontier BFS on the CSR rows."""
        P = self.num_peers
        if P <= 1:
            return True
        seen = np.zeros(P, dtype=bool)
        seen[0] = True
        frontier = np.array([0], dtype=np.int64)
        n_seen = 1
        while frontier.size:
            nxt = np.unique(_gather_rows(self._indptr, self._indices, frontier))
            nxt = nxt[~seen[nxt]]
            if nxt.size == 0:
                break
            seen[nxt] = True
            n_seen += int(nxt.size)
            frontier = nxt
        return n_seen == P

    # -- mixing --------------------------------------------------------------
    def _ensure_mix(self) -> None:
        """CSR-aligned MH edge weights + per-row self weights (lazy)."""
        if self._mix_w is not None:
            return
        d = self.degrees
        P = self.num_peers
        rows = np.repeat(np.arange(P, dtype=np.int64), np.diff(self._indptr))
        w = 1.0 / (1.0 + np.maximum(d[rows], d[self._indices]).astype(np.float64))
        w_self = 1.0 - np.bincount(rows, weights=w, minlength=P)
        self._mix_rows_cache = rows
        self._mix_w = w
        self._mix_self = w_self

    def mixing_weights(self, rank: int) -> Tuple[np.ndarray, np.ndarray, float]:
        """O(degree) Metropolis–Hastings row: ``(neighbor_ranks, weights,
        self_weight)`` — the sparse form of :meth:`mixing_row`."""
        self._ensure_mix()
        lo, hi = self._indptr[rank], self._indptr[rank + 1]
        return self._indices[lo:hi], self._mix_w[lo:hi], float(self._mix_self[rank])

    def mixing_row(self, rank: int) -> np.ndarray:
        """Dense float64 row ``W[rank]`` assembled from the sparse weights
        — identical to ``mixing_matrix()[rank]`` (the equivalence every
        registered graph is contract-checked for) without ever building
        the (P, P) matrix."""
        P = self.num_peers
        d = self.degrees
        row = np.zeros(P, dtype=np.float64)
        nbrs = self.neighbors_array(rank)
        if nbrs.size:
            row[nbrs] = 1.0 / (
                1.0 + np.maximum(d[rank], d[nbrs]).astype(np.float64)
            )
        row[rank] = 1.0 - row.sum()
        return row

    def mixing_matrix(self) -> np.ndarray:
        """Metropolis–Hastings weights: symmetric, doubly stochastic fp64.

        ``W_ij = 1 / (1 + max(d_i, d_j))`` on edges, ``W_ii`` absorbs the
        remainder. Degrees exclude self, so an isolated peer gets
        ``W_ii = 1`` (it keeps its own gradient). Dense — refused above
        ``DENSE_MATERIALIZE_LIMIT``; use :meth:`mixing_row` /
        :meth:`mixing_weights` at scale.
        """
        self._check_dense_ok("mixing_matrix")
        P = self.num_peers
        d = self.degrees
        W = np.zeros((P, P), dtype=np.float64)
        if self._indices.size:
            rows = np.repeat(np.arange(P, dtype=np.int64), np.diff(self._indptr))
            W[rows, self._indices] = 1.0 / (
                1.0 + np.maximum(d[rows], d[self._indices]).astype(np.float64)
            )
        W[np.arange(P), np.arange(P)] = 1.0 - W.sum(axis=1)
        return W

    def mix_apply(self, x: np.ndarray) -> np.ndarray:
        """``W @ x`` through the sparse operator — O(E), never O(P²).
        ``x`` may be (P,) or (P, k)."""
        self._ensure_mix()
        x = np.asarray(x, np.float64)
        contrib = self._mix_w[:, None] * x[self._indices] if x.ndim == 2 else (
            self._mix_w * x[self._indices]
        )
        if x.ndim == 2:
            y = self._mix_self[:, None] * x
            np.add.at(y, self._mix_rows_cache, contrib)
        else:
            y = self._mix_self * x + np.bincount(
                self._mix_rows_cache, weights=contrib, minlength=self.num_peers
            )
        return y

    # -- diagnostics ---------------------------------------------------------
    @property
    def degrees(self) -> np.ndarray:
        if self._degrees is None:
            d = np.diff(self._indptr).astype(np.int64)
            d.setflags(write=False)
            self._degrees = d
        return self._degrees

    def degree(self, rank: int) -> int:
        """O(1) neighbor count of one rank."""
        return int(self._indptr[rank + 1] - self._indptr[rank])

    @property
    def max_degree(self) -> int:
        return int(self.degrees.max())

    @property
    def mean_degree(self) -> float:
        return float(self.degrees.mean())

    @property
    def num_edges(self) -> int:
        """Undirected edge count."""
        return int(self._indptr[-1]) // 2

    def spectral_gap(
        self,
        method: str = "auto",
        *,
        max_iter: int = 500,
        tol: float = 1e-12,
    ) -> float:
        """``1 - |lambda_2|`` of the mixing matrix — the decentralized-SGD
        consensus rate. 1.0 for the complete graph (one-shot consensus),
        0.0 for a disconnected graph (no consensus across components).

        ``method="dense"`` is the O(P³) ``eigvalsh`` oracle (refused above
        the dense limit); ``method="power"`` runs power iteration on the
        sparse operator with the uniform top eigenvector deflated (W is
        doubly stochastic, so its dominant eigenpair is ``(1, 1/sqrt(P))``
        exactly); ``"auto"`` picks the oracle for small P.
        """
        if self.num_peers == 1:
            return 1.0
        if method not in ("auto", "dense", "power"):
            raise ValueError(
                f"spectral_gap method must be 'auto', 'dense' or 'power', "
                f"got {method!r}"
            )
        if method == "auto":
            method = "dense" if self.num_peers <= 512 else "power"
        if method == "dense":
            lam = np.linalg.eigvalsh(self.mixing_matrix())
            mags = np.sort(np.abs(lam))[::-1]
            return float(1.0 - mags[1])
        P = self.num_peers
        # deterministic seeded start vector, orthogonal to the uniform
        # dominant eigenvector (re-projected every iteration against drift)
        x = np.random.default_rng(0).standard_normal(P)
        x -= x.mean()
        nx = np.linalg.norm(x)
        if nx == 0.0:
            return 1.0
        x /= nx
        lam2, prev = 0.0, np.inf
        for _ in range(max_iter):
            y = self.mix_apply(x)
            y -= y.mean()
            ny = np.linalg.norm(y)
            if ny <= 1e-300:
                lam2 = 0.0  # W annihilates the complement (complete graph)
                break
            lam2 = ny  # ||W x|| with ||x|| = 1 -> |lambda| estimate
            x = y / ny
            if abs(lam2 - prev) <= tol * max(lam2, 1e-30):
                break
            prev = lam2
        return float(1.0 - min(lam2, 1.0))

    def describe(self) -> str:
        return (
            f"{self.name}(P={self.num_peers}, degree"
            f"={self.mean_degree:g} mean/{self.max_degree} max, "
            f"edges={self.num_edges}, spectral_gap={self.spectral_gap():.3f})"
        )


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Type[PeerGraph]] = {}


def register_graph(name: str):
    """Class decorator: make a graph reachable as ``Topology(graph=name)``."""

    def deco(cls: Type[PeerGraph]) -> Type[PeerGraph]:
        if not issubclass(cls, PeerGraph):
            raise TypeError(f"{cls!r} must subclass PeerGraph")
        cls.name = name
        _REGISTRY[name] = cls
        return cls

    return deco


def available_graphs() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def get_graph(spec, num_peers: int, *, seed: int = 0) -> PeerGraph:
    """Resolve a graph spec for ``num_peers`` ranks.

    ``spec`` is a :class:`PeerGraph` instance (validated for size and
    passed through), or a registered name with an optional integer
    parameter suffix: ``"full"``, ``"ring"``, ``"gossip:3"``,
    ``"hierarchical:4"``. ``seed`` feeds stochastic constructions
    (``gossip``) so the overlay is reproducible.
    """
    if isinstance(spec, PeerGraph):
        if spec.num_peers != num_peers:
            raise ValueError(
                f"graph was built for {spec.num_peers} peers, "
                f"topology has {num_peers}"
            )
        return spec
    name, _, arg = str(spec).partition(":")
    try:
        cls = _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown peer graph {spec!r}; registered graphs: "
            f"{', '.join(available_graphs())}"
        ) from None
    kwargs = {}
    if arg:
        try:
            kwargs["param"] = int(arg)
        except ValueError:
            raise ValueError(
                f"graph spec {spec!r}: parameter after ':' must be an int"
            ) from None
    try:
        return cls(num_peers, seed=seed, **kwargs)
    except TypeError:
        # mirror get_exchange: an un-parameterized graph given a ':' arg is
        # a clean spec error, not a constructor-signature leak
        if kwargs:
            raise ValueError(
                f"peer graph {name!r} does not take a ':' parameter "
                f"(got {spec!r})"
            ) from None
        raise


# ---------------------------------------------------------------------------
# Registered graphs
# ---------------------------------------------------------------------------


@register_graph("full")
class FullGraph(PeerGraph):
    """Complete graph — the seed repo's implicit overlay. MH mixing is the
    uniform ``1/P`` matrix, i.e. exactly the global mean. Stored
    implicitly: every query is answered analytically in O(1)/O(P), so a
    100k-peer full overlay costs no edge memory at all."""

    implicit = True

    def __init__(self, num_peers: int, *, seed: int = 0):
        super().__init__(num_peers)

    def build_adjacency(self) -> np.ndarray:
        return ~np.eye(self.num_peers, dtype=bool)

    # -- implicit sparse surface --------------------------------------------
    @property
    def adjacency(self) -> np.ndarray:
        if self._dense is None:
            self._check_dense_ok("adjacency")
            dense = ~np.eye(self.num_peers, dtype=bool)
            dense.setflags(write=False)
            self._dense = dense
        return self._dense

    def neighbors_array(self, rank: int) -> np.ndarray:
        out = np.arange(self.num_peers, dtype=np.int64)
        return np.delete(out, rank)

    def has_edge(self, i: int, j: int) -> bool:
        P = self.num_peers
        return bool(i != j and 0 <= i < P and 0 <= j < P)

    @property
    def is_full(self) -> bool:
        return True

    def is_connected(self) -> bool:
        return True

    @property
    def degrees(self) -> np.ndarray:
        if self._degrees is None:
            d = np.full(self.num_peers, self.num_peers - 1, np.int64)
            d.setflags(write=False)
            self._degrees = d
        return self._degrees

    def degree(self, rank: int) -> int:
        return self.num_peers - 1

    @property
    def num_edges(self) -> int:
        P = self.num_peers
        return P * (P - 1) // 2

    def mixing_weights(self, rank: int) -> Tuple[np.ndarray, np.ndarray, float]:
        P = self.num_peers
        nbrs = self.neighbors_array(rank)
        return nbrs, np.full(nbrs.size, 1.0 / P, np.float64), 1.0 / P

    def mixing_row(self, rank: int) -> np.ndarray:
        return np.full(self.num_peers, 1.0 / self.num_peers, np.float64)

    def mixing_matrix(self) -> np.ndarray:
        self._check_dense_ok("mixing_matrix")
        P = self.num_peers
        return np.full((P, P), 1.0 / P, np.float64)

    def mix_apply(self, x: np.ndarray) -> np.ndarray:
        x = np.asarray(x, np.float64)
        return np.broadcast_to(x.mean(axis=0), x.shape).copy()

    def spectral_gap(self, method: str = "auto", **kw) -> float:
        # W = uniform 1/P: eigenvalues are {1, 0, ..., 0} exactly.
        return 1.0


@register_graph("ring")
class RingGraph(PeerGraph):
    """Bidirectional ring: ``r`` talks to ``(r ± 1) mod P``. Per-peer wire
    bytes are O(1) in P — the canonical sparse decentralized-SGD overlay."""

    def __init__(self, num_peers: int, *, seed: int = 0):
        super().__init__(num_peers)

    def build_neighbors(self) -> Tuple[np.ndarray, np.ndarray]:
        P = self.num_peers
        r = np.arange(P, dtype=np.int64)
        edges = np.stack([r, (r + 1) % P], axis=1)  # P==1,2 dedupe in CSR
        return _csr_from_edges(P, edges)


def _ring_edges(P: int) -> np.ndarray:
    r = np.arange(P, dtype=np.int64)
    return np.stack([r, (r + 1) % P], axis=1)


@register_graph("gossip")
class GossipGraph(PeerGraph):
    """Seeded random ≥k-regular gossip overlay on a ring backbone.

    A ring guarantees connectivity; extra edges are then sampled in seeded
    vectorized rounds (each round proposes one uniform partner per
    still-deficient peer) until every peer has degree at least ``k``.
    ``"gossip:3"`` selects k=3; per-peer wire bytes are O(k), independent
    of P. ``k`` must satisfy ``k < P`` — a simple graph cannot give a
    peer more than P-1 distinct neighbors.
    """

    def __init__(self, num_peers: int, *, seed: int = 0, param: Optional[int] = None):
        self.k = int(param) if param is not None else 3
        if self.k < 1:
            raise ValueError(f"gossip degree k must be >= 1, got {self.k}")
        if self.k >= num_peers > 1:
            raise ValueError(
                f"gossip degree k={self.k} is unsatisfiable for "
                f"num_peers={num_peers}: a simple graph gives each peer at "
                f"most P-1={num_peers - 1} neighbors; pick k <= "
                f"{max(num_peers - 1, 1)} or grow the fleet"
            )
        self.seed = seed
        super().__init__(num_peers)

    def build_neighbors(self) -> Tuple[np.ndarray, np.ndarray]:
        P, k = self.num_peers, self.k
        ring = _ring_edges(P)
        if k <= 2 or P <= 3:
            return _csr_from_edges(P, ring)
        rng = np.random.default_rng(self.seed)
        a = np.minimum(ring[:, 0], ring[:, 1])
        b = np.maximum(ring[:, 0], ring[:, 1])
        keys = np.unique(a * P + b)  # existing undirected edge keys
        deg = np.bincount(
            np.concatenate([keys // P, keys % P]), minlength=P
        ).astype(np.int64)
        # seeded vectorized rounds: shuffle the still-deficient peers and
        # pair them up, so every accepted edge lifts TWO deficient degrees
        # and the overlay stays near-regular; an odd straggler proposes a
        # uniform partner. Duplicates and existing edges are dropped, so a
        # round is O(deficient log E) — a handful of rounds reach k
        for _ in range(4 * k + 32):
            deficient = np.flatnonzero(deg < k)
            if deficient.size == 0:
                break
            order = rng.permutation(deficient)
            half = order.size // 2
            src, dst = order[:half], order[half:2 * half]
            if order.size % 2:
                odd = order[-1:]
                partner = rng.integers(0, P - 1, size=1)
                partner += partner >= odd  # uniform over P-1 non-self ranks
                src = np.concatenate([src, odd])
                dst = np.concatenate([dst, partner])
            lo = np.minimum(src, dst)
            hi = np.maximum(src, dst)
            keep = lo != hi
            prop = np.unique(lo[keep] * P + hi[keep])
            new = prop[~np.isin(prop, keys)]
            if new.size == 0:
                continue
            keys = np.concatenate([keys, new])
            deg += np.bincount(
                np.concatenate([new // P, new % P]), minlength=P
            )
        else:
            # deterministic circulant fallback for pathological draws
            for off in range(2, P // 2 + 1):
                deficient = np.flatnonzero(deg < k)
                if deficient.size == 0:
                    break
                j = (deficient + off) % P
                lo, hi = np.minimum(deficient, j), np.maximum(deficient, j)
                prop = np.unique(lo * P + hi)
                new = prop[~np.isin(prop, keys)]
                if new.size == 0:
                    continue
                keys = np.concatenate([keys, new])
                deg += np.bincount(
                    np.concatenate([new // P, new % P]), minlength=P
                )
        edges = np.stack([keys // P, keys % P], axis=1)
        return _csr_from_edges(P, edges)


@register_graph("hierarchical")
class HierarchicalGraph(PeerGraph):
    """Hub-and-spoke groups: peers split into consecutive groups of
    ``group`` ranks, each group's first rank is its hub; spokes connect
    only to their hub, hubs form a complete graph among themselves.
    ``"hierarchical:4"`` selects group size 4 (default: ~sqrt(P)) — the
    SPIRT-style two-level aggregation overlay."""

    def __init__(self, num_peers: int, *, seed: int = 0, param: Optional[int] = None):
        if param is not None and param < 1:
            raise ValueError(f"hierarchical group size must be >= 1, got {param}")
        self.group = int(param) if param is not None else max(
            1, int(round(np.sqrt(num_peers)))
        )
        super().__init__(num_peers)

    def build_neighbors(self) -> Tuple[np.ndarray, np.ndarray]:
        P, group = self.num_peers, self.group
        r = np.arange(P, dtype=np.int64)
        hub_of = (r // group) * group
        spokes = r[r != hub_of]
        spoke_edges = np.stack([hub_of[spokes], spokes], axis=1)
        hubs = np.arange(0, P, group, dtype=np.int64)
        ih, jh = np.triu_indices(hubs.size, k=1)
        hub_edges = np.stack([hubs[ih], hubs[jh]], axis=1)
        return _csr_from_edges(P, np.concatenate([spoke_edges, hub_edges]))


@register_graph("static")
class StaticGraph(PeerGraph):
    """Explicit adjacency — programmatic only (``Topology(graph=StaticGraph
    .from_edges(P, [...]))``); resolving the bare name raises because there
    is no adjacency to build from."""

    def __init__(self, num_peers: int, adjacency=None, *, seed: int = 0):
        if adjacency is None:
            raise ValueError(
                "static graph needs an explicit adjacency: construct "
                "StaticGraph(P, adjacency) or StaticGraph.from_edges(P, edges) "
                "and pass the instance, not the name"
            )
        self._static_adj = np.asarray(adjacency, dtype=bool)
        super().__init__(num_peers)

    @classmethod
    def from_edges(cls, num_peers: int, edges: Sequence[Tuple[int, int]]):
        adj = np.zeros((num_peers, num_peers), dtype=bool)
        for i, j in edges:
            adj[i, j] = adj[j, i] = True
        return cls(num_peers, adj)

    def build_adjacency(self) -> np.ndarray:
        return self._static_adj
