"""InstanceRuntime — the conventional instance-based P2P baseline,
simulated on the same discrete-event engine as the serverless path.

The paper's central claim is a *comparison*: serverless parallel gradient
computation is up to 97.34% faster than conventional instance-based P2P
training, at up to 5.4x the cost. Until this module existed the repo only
simulated the serverless side with engine fidelity
(:class:`repro.core.events.ServerlessRuntime`) while the instance baseline
was the static closed-form Formula (2) — no boot time, no idle billing, no
resource-constrained sequential computation. SPIRT (arXiv:2309.14148) and
"Towards Demystifying Serverless Machine Learning Training"
(arXiv:2105.07806) both stress that cost–time frontiers are only credible
when the VM baseline is modeled with the same fidelity as the serverless
path. This module is that baseline:

* **Provisioning/boot** — the first epoch (and every churn recovery) pays
  :class:`~repro.core.events.InstanceConfig.boot_s` before any batch runs;
  the VM then stays up across epochs on the runtime's deployment-lifetime
  clock (the instance analogue of the serverless warm-container pool).
* **Per-second billing including idle** — the EC2 meter runs from boot
  start through barrier waits; only churn downtime (no VM exists) is
  unbilled. See :class:`repro.core.cost.InstanceCost.billed_s`.
* **Memory-constrained mini-batch splitting** — when the model + one
  batch's working set exceed the tier's memory
  (:data:`repro.core.cost.EC2_MEMORY_MB`), each batch is split into the
  smallest number of sequential micro-batches that fit, paying a per-split
  gradient-accumulation overhead: the paper's "resource-constrained
  scenario", where the weak instance computes gradients strictly
  sequentially and slower.
* **Peer churn** — reuses the fault machinery idiom of the serverless
  runtime (seeded RNG on the engine, bounded redos): a VM can die
  mid-batch, losing partial work, and rejoin after a downtime on a fresh
  (re-billed) boot.
* **Degree-aware wire charging** — the exchange phase charges one upload
  plus degree-many downloads through the shared
  :class:`~repro.core.events.LinkModel`, so sparse
  :class:`~repro.core.graph.PeerGraph` overlays pay O(degree), exactly as
  the serverless path accounts egress.

Pricing glue lives in :meth:`repro.core.serverless.ServerlessExecutor.
simulate_instance`, which turns an :class:`InstanceEpochResult` into an
``ExecutionReport`` + engine-priced :class:`~repro.core.cost.InstanceCost`
directly comparable (via :class:`~repro.core.cost.CostReport`) with the
serverless accounting.
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro.core.cost import (
    INSTANCE_MEMORY_MB,
    InstanceCost,
    instance_equivalent_vcpus,
    working_set_mb,
)
from repro.core.events import (
    EventEngine,
    InstanceConfig,
    InstanceEpochResult,
    LinkModel,
)

# Default runtime overhead, matching ServerlessPlanner's default; the
# resident-set formula itself is repro.core.cost.working_set_mb, shared
# with the Lambda planner so the two sizing models cannot drift apart.
INSTANCE_RUNTIME_OVERHEAD_MB = 700


def instance_splits(
    model_bytes: int,
    batch_bytes: int,
    instance: str,
    *,
    runtime_overhead_mb: int = INSTANCE_RUNTIME_OVERHEAD_MB,
) -> int:
    """Micro-batches one batch must be split into to fit the tier's memory.

    Returns the smallest ``k`` such that ``2*model + 3*batch/k + runtime``
    fits in :data:`~repro.core.cost.INSTANCE_MEMORY_MB` (CPU tiers use
    host RAM, GPU tiers use device memory) — 1 when unconstrained (the
    paper's comfortable case), >1 in the resource-constrained scenario.
    Raises when even ``k -> inf`` cannot fit (the model itself overflows
    the tier), mirroring the Lambda-cap check in the planner.
    """
    mem_mb = INSTANCE_MEMORY_MB[instance]
    fixed_mb = working_set_mb(model_bytes, 0, runtime_overhead_mb)
    if fixed_mb > mem_mb:
        raise ValueError(
            f"model needs {fixed_mb:.0f} MB resident > {instance} memory "
            f"{mem_mb} MB; no amount of batch splitting fits it — pick a "
            "larger tier"
        )
    if batch_bytes <= 0:
        return 1
    avail_mb = mem_mb - fixed_mb
    if avail_mb <= 0:  # model exactly fills the tier: no room for any slice
        raise ValueError(
            f"model fills all {mem_mb} MB of {instance}; no memory left for "
            "even one micro-batch slice — pick a larger tier"
        )
    per_batch_mb = working_set_mb(0, batch_bytes)
    if per_batch_mb <= avail_mb:
        return 1
    return int(math.ceil(per_batch_mb / avail_mb))


def instance_speedup(instance: str, reference_vcpus: Optional[float]) -> float:
    """Tier compute speed relative to the machine the per-batch times were
    measured on. ``None`` means "measured on this tier" (the legacy
    convention — no scaling); otherwise the tier's equivalent-vCPU share
    (:func:`repro.core.cost.instance_equivalent_vcpus` — real vCPUs for
    CPU tiers, the calibrated GPU speedup factor for GPU tiers) scales
    linearly with the same 0.25 floor as
    :func:`repro.core.serverless.lambda_speedup`."""
    if reference_vcpus is None:
        return 1.0
    return max(
        instance_equivalent_vcpus(instance) / float(reference_vcpus), 0.25
    )


class InstanceRuntime:
    """Simulates one peer's instance-based epochs on the event engine.

    One runtime instance persists the VM fleet (which peers have booted)
    and the RNG stream across epochs, so boot is paid once per VM lifetime
    — like a long-lived deployment — and a fixed
    :class:`~repro.core.events.InstanceConfig.seed` makes the whole churn
    trajectory deterministic. The serverless counterpart is
    :class:`~repro.core.events.ServerlessRuntime`; both ride the same
    :class:`~repro.core.events.EventEngine`.
    """

    def __init__(
        self,
        config: Optional[InstanceConfig] = None,
        *,
        instance: str = "t2.large",
        split_overhead_s: float = 0.05,  # per extra micro-batch: reload + accumulate
        tracer: Any = None,
    ):
        if instance not in INSTANCE_MEMORY_MB:
            raise ValueError(
                f"unknown instance tier {instance!r}; known tiers: "
                f"{', '.join(sorted(INSTANCE_MEMORY_MB))}"
            )
        self.config = config or InstanceConfig()
        self.instance = instance
        self.split_overhead_s = split_overhead_s
        self.tracer = tracer
        self.rng = np.random.default_rng(self.config.seed)
        self.clock = 0.0  # deployment-lifetime clock; VMs stay up on it
        self.epochs_run = 0
        self._vm_up: Dict[Any, bool] = {}  # peer -> VM currently provisioned

    def run_epoch(
        self,
        exec_times_s: Sequence[float],
        *,
        peer: Any = 0,
        splits: int = 1,
        submit_time: Optional[float] = None,
        upload_bytes: int = 0,
        download_bytes: Sequence[int] = (),
        link: Optional[LinkModel] = None,
        barrier_wait_s: float = 0.0,
    ) -> InstanceEpochResult:
        """Simulate one peer epoch: [boot ->] batches, sequentially, then
        the exchange wire phase and any barrier idle.

        ``exec_times_s`` are this tier's per-batch execution times (already
        vCPU-scaled by the caller; see :func:`instance_speedup`). With
        ``splits > 1`` each batch additionally pays ``(splits - 1) *
        split_overhead_s`` of gradient-accumulation overhead — the
        memory-constrained sequential path. ``upload_bytes`` /
        ``download_bytes`` (with ``link``) charge the exchange: one publish
        plus one download per overlay neighbor, so wire time is O(degree).
        ``barrier_wait_s`` is billed idle (the VM waits, the meter runs).
        """
        cfg = self.config
        if link is None and (upload_bytes or len(download_bytes)):
            raise ValueError(
                "upload_bytes/download_bytes given without a LinkModel; "
                "pass link= so the exchange wire time is actually charged"
            )
        if submit_time is None:
            submit_time = self.clock
        if self.tracer is not None:
            self.tracer.record(
                "instance_epoch",
                instance=self.instance,
                peer=peer,
                batches=len(exec_times_s),
                splits=max(int(splits), 1),
                submit=float(submit_time),
            )
        engine = EventEngine(rng=self.rng, tracer=self.tracer)
        engine.now = float(submit_time)
        res = InstanceEpochResult(splits=max(int(splits), 1))
        times: List[float] = [
            float(t) + (res.splits - 1) * self.split_overhead_s
            for t in exec_times_s
        ]
        state = {"i": 0, "redos": 0}

        def boot(then):
            res.boot_s += cfg.boot_s
            self._vm_up[peer] = True
            engine.schedule_in(cfg.boot_s, then)

        def start_batch():
            if state["i"] >= len(times):
                finish()
                return
            t = times[state["i"]]
            if (
                cfg.churn_prob > 0.0
                and state["redos"] < cfg.max_churn_redos
                and engine.rng.random() < cfg.churn_prob
            ):
                # VM died mid-batch: partial work lost, meter stops, the
                # replacement re-pays boot after the detection gap
                lost = t * engine.rng.random()
                state["redos"] += 1
                res.churn_drops += 1
                res.redo_s += lost
                res.downtime_s += cfg.churn_downtime_s
                self._vm_up.pop(peer, None)
                engine.schedule_in(
                    lost + cfg.churn_downtime_s, lambda: boot(start_batch)
                )
                return
            state["redos"] = 0
            res.compute_s += t
            state["i"] += 1
            engine.schedule_in(t, start_batch)

        def finish():
            wire = 0.0
            if link is not None:
                if upload_bytes:
                    wire += link.transfer_s(int(upload_bytes))
                for nb in download_bytes:
                    wire += link.transfer_s(int(nb))
            res.wire_s = wire
            res.idle_s += float(barrier_wait_s)
            if wire + barrier_wait_s > 0.0:
                engine.schedule_in(wire + barrier_wait_s, lambda: None)

        if self._vm_up.get(peer):
            engine.schedule_at(submit_time, start_batch)
        else:
            boot(start_batch)
        end = engine.run()
        res.makespan_s = end - submit_time
        self.clock = max(self.clock, end)
        self.epochs_run += 1
        return res

    def price(self, res: InstanceEpochResult) -> InstanceCost:
        """Engine-priced Formula (2): busy + boot + idle billed per second
        on this tier; churn downtime extends the wall but not the bill."""
        return InstanceCost(
            compute_time_s=res.compute_s + res.redo_s + res.wire_s,
            instance=self.instance,
            boot_s=res.boot_s,
            idle_s=res.idle_s,
            unbilled_downtime_s=res.downtime_s,
        )
