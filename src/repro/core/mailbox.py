"""RabbitMQ-analogue gradient mailboxes — paper §III-B.3.

The paper gives every peer a dedicated queue holding a single *persistent*
gradient message: a new gradient replaces the previous one ("latest wins"),
and consumers read without deleting. That is register semantics, which we
model two ways:

* :class:`HostMailbox` — host-level, used by the local P2P cluster and the
  async discrete-event simulator. Also models the paper's 100 MB message cap
  (large payloads are "stored in S3 and referenced by UUID": we count the
  indirection but deliver the payload either way).
* device-level — in the distributed JAX path the mailbox is the all-gathered
  register bank inside the train step (see ``repro/core/p2p.py``).
"""
from __future__ import annotations

import uuid
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

MESSAGE_CAP_BYTES = 100 * 1024 * 1024  # Amazon MQ per-message limit
S3_ROUND_TRIP_S = 0.05  # fetch-by-UUID latency for indirected payloads


@dataclass
class Message:
    payload: Any
    publish_time: float
    epoch: int
    nbytes: int = 0  # wire size, charged to the consumer's simulated link
    via_s3: bool = False
    s3_uuid: Optional[str] = None


class _Registers:
    """One shard tag's register bank: struct-of-arrays over all P peers.

    Replaces the per-message dict-of-dataclasses storage — a publish is a
    handful of O(1) array writes, and the bank's footprint is preallocated
    columns (floats/ints/bools plus two object slots per peer) instead of
    a heap object per live message. :class:`Message` remains the *read*
    API: ``consume`` materializes one on demand.
    """

    __slots__ = (
        "payload", "publish_time", "epoch", "nbytes", "via_s3", "s3_uuid",
        "filled",
    )

    def __init__(self, num_peers: int):
        self.payload: List[Any] = [None] * num_peers
        self.publish_time = np.zeros(num_peers, dtype=np.float64)
        self.epoch = np.zeros(num_peers, dtype=np.int64)
        self.nbytes = np.zeros(num_peers, dtype=np.int64)
        self.via_s3 = np.zeros(num_peers, dtype=bool)
        self.s3_uuid: List[Optional[str]] = [None] * num_peers
        self.filled = np.zeros(num_peers, dtype=bool)


class HostMailbox:
    """One latest-wins register per (peer, shard) + a barrier queue.

    ``graph`` (a :class:`repro.core.graph.PeerGraph`) restricts deliveries
    to overlay edges: a consumer identifying itself via ``consume(...,
    consumer=r)`` can only read queues of its graph neighbors — reads from
    non-neighbors return ``None`` and count in ``stats["blocked"]``. With
    no graph (or an anonymous consumer) the mailbox behaves like the
    paper's fully-connected broker.

    ``shard`` addresses sub-queues within a peer's mailbox — the sharded
    exchange publishes one *piece* message per shard owner plus one
    aggregated-shard broadcast, so a peer's queue space is a small fixed
    set of registers, not one monolithic gradient slot.

    Memory stays bounded by construction: publishes REPLACE the register
    (never append), so the live message count is at most ``num_peers x
    shard-tags`` regardless of how many epochs run. A publish that lands
    on a register already holding a message from the SAME epoch compacts
    it (latest wins within the (peer, epoch) cell) and counts in
    ``stats["compacted"]`` — the signal that producers are re-publishing
    faster than consumers drain.
    """

    def __init__(
        self, num_peers: int, *, s3_rtt_s: float = S3_ROUND_TRIP_S, graph=None,
        tracer=None,
    ):
        self.num_peers = num_peers
        self.s3_rtt_s = s3_rtt_s
        self.graph = graph
        # Optional repro.analysis.trace.TraceRecorder: every publish/consume
        # is recorded for the happens-before race checker and the same-seed
        # determinism differ. None keeps the broker overhead-free.
        self.tracer = tracer
        # shard tag -> preallocated register bank over all peers;
        # shard=None is the classic whole-gradient register
        self._shards: Dict[Any, _Registers] = {}
        self._live = 0  # filled registers across all banks (O(1) count)
        # epoch -> (per-peer signalled flags, distinct-signal count):
        # signal/complete/reset are all O(1) in signals ever sent
        self._barrier: Dict[int, Tuple[np.ndarray, int]] = {}
        self.stats = {
            "publishes": 0, "consumes": 0, "s3_indirections": 0, "blocked": 0,
            "compacted": 0, "poisoned_publishes": 0, "rejected_nonfinite": 0,
        }
        # (consumer, producer) pairs actually delivered — lets tests assert
        # every delivery rode a graph edge, churn or not
        self.delivered_edges: set = set()

    # -- gradient queues ---------------------------------------------------
    def publish(
        self, peer: int, payload: Any, *, nbytes: int, time: float, epoch: int,
        shard: Any = None, poisoned: bool = False,
    ):
        if not 0 <= peer < self.num_peers:
            raise IndexError(f"peer {peer} out of range [0, {self.num_peers})")
        if poisoned:
            # Adversary-model bookkeeping only: the broker can't actually
            # tell; robust consumers must survive without this signal.
            self.stats["poisoned_publishes"] += 1
        via_s3 = nbytes > MESSAGE_CAP_BYTES
        regs = self._shards.get(shard)
        if regs is None:
            regs = self._shards[shard] = _Registers(self.num_peers)
        replaced_epoch: Optional[int] = None
        if regs.filled[peer]:
            replaced_epoch = int(regs.epoch[peer])
            if replaced_epoch == epoch:
                # latest-wins compaction within the (peer, epoch) cell
                self.stats["compacted"] += 1
        else:
            regs.filled[peer] = True
            self._live += 1
        # replaces the previous message (latest wins)
        regs.payload[peer] = payload
        regs.publish_time[peer] = time
        regs.epoch[peer] = epoch
        regs.nbytes[peer] = nbytes
        regs.via_s3[peer] = via_s3
        regs.s3_uuid[peer] = str(uuid.uuid4()) if via_s3 else None
        self.stats["publishes"] += 1
        if via_s3:
            self.stats["s3_indirections"] += 1
        if self.tracer is not None:
            self.tracer.record(
                "publish", time=time, actor=peer, epoch=epoch, shard=shard,
                nbytes=nbytes, replaced_epoch=replaced_epoch,
            )

    @property
    def live_messages(self) -> int:
        """Registers currently holding a message — bounded by peers x shards,
        NOT by epochs run (replacement, not append). O(1): maintained as a
        counter, never scanned."""
        return self._live

    def download_time_s(
        self, msg: Message, bandwidth_bps: Optional[float] = None, *, link=None
    ) -> float:
        """Receive-side wire time: payload transfer + the S3 fetch round trip
        for indirected (>100 MB) messages. Charged against the consumer's
        simulated link by the cluster / event engine. Pass either a raw
        ``bandwidth_bps`` or a :class:`repro.core.events.LinkModel` (which
        adds its per-message overhead)."""
        if link is not None:
            t = link.transfer_s(msg.nbytes)
        else:
            t = msg.nbytes * 8.0 / bandwidth_bps
        if msg.via_s3:
            t += self.s3_rtt_s
        return t

    def consume(
        self,
        peer: int,
        *,
        at_time: Optional[float] = None,
        consumer: Optional[int] = None,
        shard: Any = None,
    ) -> Optional[Message]:
        """Read (without deleting) peer's latest message visible at `at_time`.

        ``consumer`` identifies the reading peer; when the mailbox carries
        an overlay graph, reads across non-edges are refused. ``shard``
        selects a shard-addressed register (see :meth:`publish`).
        """
        if not 0 <= peer < self.num_peers:
            raise IndexError(f"peer {peer} out of range [0, {self.num_peers})")
        if (
            self.graph is not None
            and consumer is not None
            and consumer != peer
            and not self.graph.has_edge(consumer, peer)
        ):
            self.stats["blocked"] += 1
            if self.tracer is not None:
                self.tracer.record(
                    "blocked", time=at_time, actor=consumer, peer=peer,
                    shard=shard,
                )
            return None
        regs = self._shards.get(shard)
        self.stats["consumes"] += 1
        if (
            regs is None
            or not regs.filled[peer]
            or (at_time is not None and regs.publish_time[peer] > at_time)
        ):
            # nothing in the register, or not yet published at this
            # simulated time — either way the consumer sees a miss
            if self.tracer is not None:
                self.tracer.record(
                    "miss", time=at_time, actor=consumer, peer=peer, shard=shard,
                )
            return None
        msg = Message(
            regs.payload[peer],
            float(regs.publish_time[peer]),
            int(regs.epoch[peer]),
            nbytes=int(regs.nbytes[peer]),
            via_s3=bool(regs.via_s3[peer]),
            s3_uuid=regs.s3_uuid[peer],
        )
        if consumer is not None:
            self.delivered_edges.add((consumer, peer))
        if self.tracer is not None:
            self.tracer.record(
                "consume", time=at_time, actor=consumer, peer=peer, shard=shard,
                epoch=msg.epoch, published=msg.publish_time,
            )
        return msg

    # -- synchronization barrier (paper §III-B.6) ---------------------------
    # Per-epoch signalled-flag arrays + distinct counts: every operation is
    # O(1), where the old list-of-(peer, epoch) storage rescanned all
    # signals ever sent on each complete/reset.
    def barrier_signal(self, peer: int, epoch: int):
        cell = self._barrier.get(epoch)
        if cell is None:
            cell = (np.zeros(self.num_peers, dtype=bool), 0)
        seen, count = cell
        if not seen[peer]:
            seen[peer] = True
            count += 1  # duplicate signals never over-count
        self._barrier[epoch] = (seen, count)

    def barrier_complete(self, epoch: int) -> bool:
        cell = self._barrier.get(epoch)
        return cell is not None and cell[1] == self.num_peers

    def barrier_reset(self, epoch: int):
        self._barrier.pop(epoch, None)
