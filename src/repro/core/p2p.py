"""P2P distributed training — Algorithm 1 of the paper, on a TPU mesh.

Peers are slices of the *manual* mesh axes (``peer_axes``); the serverless
lambda pool / tensor parallelism is the remaining *auto* axis handled by
GSPMD. The whole train step runs inside ``jax.shard_map`` manual over
``peer_axes`` so the per-peer gradient ``g_{t,r}`` is a first-class value and
the gradient exchange is an explicit, swappable collective:

  exchange="allgather_mean"  (paper-faithful)
      every peer publishes g_r to its queue and consumes everyone else's,
      then averages locally  ->  all_gather over peers + local mean.
      The all_gather *is* the synchronization barrier (§III-B.6).
  exchange="psum_mean"       (beyond-paper optimized)
      one fused all-reduce; mathematically identical, strictly less traffic
      (no P-way buffer materialization).
  exchange="qsgd"            (paper §III-B.4)
      QSGD-quantize g_r, all_gather the int8 payload + bucket norms,
      dequantize + average locally. 8/32 bits on the wire.

Async (staleness-1) exchange keeps the mailbox register bank from the
previous step in the training state — other peers' gradients are consumed
one step stale, the paper's "latest available gradient" semantics.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core import compression as C
from repro.optim import Optimizer, apply_updates, clip_by_global_norm


@dataclass(frozen=True)
class Topology:
    """How the P2P system maps onto the mesh."""

    peer_axes: Tuple[str, ...] = ("data",)  # manual axes: one peer per slice
    lambda_axis: Optional[str] = "model"  # auto axis: serverless pool / TP
    exchange: str = "allgather_mean"  # allgather_mean | psum_mean | qsgd
    qsgd: Optional[C.QSGDConfig] = None
    async_mode: bool = False  # staleness-1 mailbox exchange
    serverless: bool = True  # fan micro-batches out over lambda_axis
    grad_clip: float = 0.0
    # beyond-paper knobs (EXPERIMENTS.md §Perf):
    exchange_dtype: str = "float32"  # bfloat16 halves exchange wire bytes
    cast_params_once: bool = False  # one bf16 cast per step -> bf16 ZeRO gathers
    # Gradient accumulation: when a peer's m batches exceed the lambda
    # slots (the paper's Step-Functions queueing case), split the peer
    # batch into `accum_steps` sequential micro-rounds and average —
    # AverageBatchesGradients with bounded activation memory.
    accum_steps: int = 1

    @property
    def axis(self):
        return self.peer_axes if len(self.peer_axes) > 1 else self.peer_axes[0]


def peer_rank(topo: Topology) -> jnp.ndarray:
    return lax.axis_index(topo.axis)


def peer_count_static(topo: Topology, mesh) -> int:
    n = 1
    for a in topo.peer_axes:
        n *= mesh.shape[a]
    return n


# ---------------------------------------------------------------------------
# Gradient exchange protocols (run inside the manual region)
# ---------------------------------------------------------------------------


def exchange_gradients(
    grads, topo: Topology, key: Optional[jax.Array] = None, mailbox=None
):
    """Returns (averaged_grads, new_mailbox).

    ``mailbox`` (async mode only) is the register bank of every peer's last
    published gradient, shape (P, ...) per leaf.
    """
    if not topo.peer_axes:
        return grads, mailbox

    # Wire dtype: bf16 halves the exchange bytes (beyond-paper knob); the
    # averaged result is promoted back to fp32 for the optimizer.
    xdt = jnp.dtype(topo.exchange_dtype)

    if topo.async_mode:
        if mailbox is None:
            raise ValueError("async exchange requires a mailbox state")
        fresh_bank = jax.tree.map(
            lambda g: lax.all_gather(g.astype(jnp.float32), topo.axis), grads
        )
        r = peer_rank(topo)
        nP = fresh_bank and jax.tree.leaves(fresh_bank)[0].shape[0]

        def combine(bank_old, g):
            # own gradient fresh; others consumed from the (stale) mailbox
            others = bank_old.sum(0) - bank_old[r]
            return (others + g.astype(jnp.float32)) / nP

        avg = jax.tree.map(combine, mailbox, grads)
        return avg, fresh_bank

    if topo.exchange == "allgather_mean":
        # Algorithm 1: publish to own queue, consume all queues, average.
        bank = jax.tree.map(
            lambda g: lax.all_gather(g.astype(xdt), topo.axis), grads
        )
        avg = jax.tree.map(lambda b: b.astype(jnp.float32).mean(axis=0), bank)
        return avg, mailbox

    if topo.exchange == "psum_mean":
        avg = jax.tree.map(
            lambda g: lax.pmean(g.astype(xdt), topo.axis).astype(jnp.float32),
            grads,
        )
        return avg, mailbox

    if topo.exchange == "qsgd":
        qcfg = topo.qsgd or C.QSGDConfig()
        if key is None:
            raise ValueError("qsgd exchange requires an rng key")
        key = jax.random.fold_in(key, peer_rank(topo))

        def leaf(g, k):
            payload = C.quantize(g, k, qcfg)
            lev = lax.all_gather(payload["levels"], topo.axis)  # (P, nb, B)
            nrm = lax.all_gather(payload["norms"], topo.axis)  # (P, nb)
            deq = jax.vmap(lambda l, n: C.qsgd_dequantize_ref(l, n, qcfg.levels))(
                lev, nrm
            )
            flat = deq.mean(axis=0).reshape(-1)
            n = g.size
            return flat[:n].reshape(g.shape)

        leaves, treedef = jax.tree_util.tree_flatten(grads)
        keys = jax.random.split(key, len(leaves))
        avg = jax.tree_util.tree_unflatten(
            treedef, [leaf(g, k) for g, k in zip(leaves, keys)]
        )
        return avg, mailbox

    raise ValueError(f"unknown exchange {topo.exchange!r}")


def init_mailbox(grads_like, num_peers: int):
    return jax.tree.map(
        lambda g: jnp.zeros((num_peers,) + g.shape, jnp.float32), grads_like
    )


# ---------------------------------------------------------------------------
# Serverless intra-peer fan-out (paper §III-C)
# ---------------------------------------------------------------------------


def lambda_shard(batch: Dict[str, jnp.ndarray], topo: Topology):
    """Fan the peer's micro-batches out over the lambda (auto) axis.

    Inside the manual region the leading dim of every batch leaf is the
    peer-local batch; constraining it over the lambda axis makes XLA compute
    per-lambda partial gradients and reduce them — the TPU-native image of
    the paper's parallel Lambda invocations + gradient averaging.
    """
    if not (topo.serverless and topo.lambda_axis):
        return batch
    ax = topo.lambda_axis
    return jax.tree.map(
        lambda x: lax.with_sharding_constraint(x, P(*((ax,) + (None,) * (x.ndim - 1)))),
        batch,
    )


# ---------------------------------------------------------------------------
# The P2P train step builder
# ---------------------------------------------------------------------------


def build_p2p_train_step(
    loss_fn: Callable,  # (params, batch) -> (loss, aux)
    optimizer: Optimizer,
    topo: Topology,
    mesh,
    schedule: Callable[[jnp.ndarray], jnp.ndarray],
):
    """Returns step(train_state, batch) -> (train_state, metrics).

    train_state = {params, opt_state, step, key[, mailbox]}.
    """

    def peer_body(params, opt_state, step_idx, key, batch, mailbox):
        batch = lambda_shard(batch, topo)
        if topo.cast_params_once:
            # One bf16 cast per step: ZeRO weight gathers then move bf16
            # instead of fp32 (halves per-layer gather bytes). Master params
            # and the optimizer stay fp32; norm vectors keep full precision.
            compute_params = jax.tree.map(
                lambda p: p.astype(jnp.bfloat16)
                if (p.dtype == jnp.float32 and p.ndim >= 2)
                else p,
                params,
            )
        else:
            compute_params = params
        if topo.accum_steps > 1:
            # sequential micro-rounds over the leading batch dim (each round
            # still fans out over the lambda axis); grads averaged in fp32
            n = topo.accum_steps

            def split(x):
                return x.reshape((n, x.shape[0] // n) + x.shape[1:])

            micro = jax.tree.map(split, batch)

            def round_fn(carry, mb):
                (loss, aux), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    compute_params, mb
                )
                acc_g, acc_l, acc_a = carry
                acc_g = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32) / n, acc_g, g
                )
                return (acc_g, acc_l + loss / n, acc_a + aux / n), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), compute_params
            )
            (grads, loss, aux), _ = lax.scan(
                round_fn, (zeros, jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
                micro,
            )
        else:
            (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                compute_params, batch
            )
        if topo.grad_clip:
            grads, gnorm = clip_by_global_norm(grads, topo.grad_clip)
        else:
            gnorm = jnp.zeros((), jnp.float32)
        step_key = jax.random.fold_in(key, step_idx)
        avg, new_mailbox = exchange_gradients(grads, topo, step_key, mailbox)
        lr = schedule(step_idx)
        updates, opt_state = optimizer.update(avg, opt_state, params, lr)
        params = apply_updates(params, updates)
        if topo.peer_axes:
            loss = lax.pmean(loss, topo.axis)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr, "aux": aux}
        return params, opt_state, metrics, new_mailbox

    if not topo.peer_axes:

        def step(state, batch):
            params, opt_state, metrics, mb = peer_body(
                state["params"], state["opt_state"], state["step"], state["key"],
                batch, state.get("mailbox"),
            )
            out = {**state, "params": params, "opt_state": opt_state,
                   "step": state["step"] + 1}
            if mb is not None:
                out["mailbox"] = mb
            return out, metrics

        return step

    batch_spec = P(topo.axis)
    replicated = P()

    def step(state, batch):
        mailbox = state.get("mailbox")
        bspec = jax.tree.map(lambda _: batch_spec, batch)
        mspec = None if mailbox is None else jax.tree.map(lambda _: replicated, mailbox)
        fn = jax.shard_map(
            peer_body,
            mesh=mesh,
            in_specs=(
                jax.tree.map(lambda _: replicated, state["params"]),
                jax.tree.map(lambda _: replicated, state["opt_state"]),
                replicated,
                replicated,
                bspec,
                mspec,
            ),
            out_specs=(
                jax.tree.map(lambda _: replicated, state["params"]),
                jax.tree.map(lambda _: replicated, state["opt_state"]),
                {"loss": replicated, "grad_norm": replicated, "lr": replicated,
                 "aux": replicated},
                mspec,
            ),
            axis_names=set(topo.peer_axes),
            check_vma=False,
        )
        params, opt_state, metrics, mb = fn(
            state["params"], state["opt_state"], state["step"], state["key"],
            batch, mailbox,
        )
        out = {**state, "params": params, "opt_state": opt_state,
               "step": state["step"] + 1}
        if mb is not None:
            out["mailbox"] = mb
        return out, metrics

    return step
