"""P2P distributed training — Algorithm 1 of the paper, on a TPU mesh.

Peers are slices of the *manual* mesh axes (``peer_axes``); the serverless
lambda pool / tensor parallelism is the remaining *auto* axis handled by
GSPMD. The whole train step runs inside ``shard_map`` manual over
``peer_axes`` so the per-peer gradient ``g_{t,r}`` is a first-class value
and the gradient exchange is an explicit, swappable
:class:`~repro.core.exchange.ExchangeProtocol` resolved from the registry
by name:

  ``allgather_mean``  (paper-faithful)   publish/consume/average; the
                      all_gather IS the synchronization barrier (§III-B.6)
  ``psum_mean``       (beyond-paper)     one fused all-reduce, same math
  ``qsgd``            (paper §III-B.4)   int8 levels + bucket norms
  ``topk``            (beyond-paper)     top-k sparsified values + indices
  ``async``           (paper §III-B.5)   staleness-K mailbox register bank

``Topology(exchange="<name>")`` accepts any registered name, so adding a
protocol never touches this module. The overlay topology is equally
pluggable: ``Topology(graph="ring" | "gossip:3" | "hierarchical" | ...)``
resolves a :class:`~repro.core.graph.PeerGraph` whose Metropolis–Hastings
mixing matrix generalizes the sync protocols' global mean to
neighbor-weighted mixing (the full graph keeps the legacy bit-exact mean).
The train state is the :class:`TrainState` dataclass pytree (dict-style
access kept for backward compatibility).
"""
from __future__ import annotations

import dataclasses
import warnings
from dataclasses import dataclass
from typing import Any, Callable, Dict, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import compression as C
from repro.core import robust as R
from repro.core.exchange import (
    ExchangeContext,
    ExchangeProtocol,
    get_exchange,
)
from repro.core.graph import PeerGraph, get_graph
from repro.optim import Optimizer, apply_updates, clip_by_global_norm


@dataclass(frozen=True)
class Topology:
    """How the P2P system maps onto the mesh."""

    peer_axes: Tuple[str, ...] = ("data",)  # manual axes: one peer per slice
    lambda_axis: Optional[str] = "model"  # auto axis: serverless pool / TP
    exchange: str = "allgather_mean"  # any name in exchange.available_exchanges()
    graph: Any = "full"  # peer overlay: name in graph.available_graphs()
    #   ("ring", "gossip:3", ...) or a PeerGraph instance
    graph_seed: int = 0  # seeds stochastic overlays (gossip)
    qsgd: Optional[C.QSGDConfig] = None
    async_mode: bool = False  # DEPRECATED: use exchange="async"
    staleness: int = 1  # async: consume banks published K steps ago
    topk_frac: float = 0.01  # topk: fraction of entries shipped
    topk_impl: str = "jnp"  # topk select/scatter: "jnp" oracle | Pallas "kernel"
    # Error feedback (EF-SGD): accumulate the compression residual
    # r <- (g + r) - decode(encode(g + r)) per peer and re-inject it next
    # step. Keeps the biased top-k sparsifier convergent at aggressive
    # fractions; unbiased qsgd converges without it. No-op (residual
    # identically zero) for lossless protocols.
    ef: bool = False
    # robust-aggregation knobs (see repro.core.robust); a parameterized
    # spec (exchange="trimmed_mean:0.25" / "krum:3") overrides these
    trim_frac: float = 0.0  # trimmed_mean: fraction dropped from EACH end
    krum_m: int = 1  # krum: multi-Krum selection count
    krum_f: Optional[int] = None  # krum: assumed attackers (None = max)
    robust_clip: float = 0.0  # >0: per-peer norm clip before robust combine
    serverless: bool = True  # fan micro-batches out over lambda_axis
    grad_clip: float = 0.0
    # beyond-paper knobs (EXPERIMENTS.md §Perf):
    exchange_dtype: str = "float32"  # bfloat16 halves exchange wire bytes
    cast_params_once: bool = False  # one bf16 cast per step -> bf16 ZeRO gathers
    # Gradient accumulation: when a peer's m batches exceed the lambda
    # slots (the paper's Step-Functions queueing case), split the peer
    # batch into `accum_steps` sequential micro-rounds and average —
    # AverageBatchesGradients with bounded activation memory.
    accum_steps: int = 1

    def __post_init__(self):
        if self.async_mode:
            warnings.warn(
                'Topology(async_mode=True) is deprecated; use '
                'Topology(exchange="async") — one name per protocol',
                DeprecationWarning,
                stacklevel=3,
            )

    @property
    def axis(self):
        return self.peer_axes if len(self.peer_axes) > 1 else self.peer_axes[0]

    @property
    def exchange_name(self) -> str:
        return "async" if self.async_mode else self.exchange

    def protocol(self) -> ExchangeProtocol:
        return get_exchange(self.exchange_name)

    def peer_graph(self, num_peers: int) -> PeerGraph:
        """Resolve the overlay for ``num_peers`` ranks via the registry."""
        return get_graph(self.graph, num_peers, seed=self.graph_seed)


def peer_rank(topo: Topology) -> jnp.ndarray:
    return lax.axis_index(topo.axis)


def peer_count_static(topo: Topology, mesh) -> int:
    n = 1
    for a in topo.peer_axes:
        n *= mesh.shape[a]
    return n


def exchange_context(
    topo: Topology, mesh=None, *, num_peers: Optional[int] = None
) -> ExchangeContext:
    """Build the :class:`ExchangeContext` a protocol sees for ``topo``.

    Resolves the overlay graph for the peer count and attaches its
    Metropolis–Hastings mixing matrix; on the full graph (where MH is
    exactly uniform ``1/P``) ``mixing`` stays ``None`` so protocols keep
    the legacy bit-exact global-mean arithmetic.
    """
    if num_peers is None:
        num_peers = peer_count_static(topo, mesh) if (mesh is not None and topo.peer_axes) else 1
    graph = topo.peer_graph(num_peers)
    mixing = (
        None
        if (graph.is_full or num_peers <= 1)
        else graph.mixing_matrix().astype(np.float32)
    )
    proto = topo.protocol()
    if mixing is not None and (
        not proto.decomposes_per_edge or proto.requires_full_graph
    ):
        # fail at construction, not inside the first jitted step trace
        kind = (
            "a sharded global reduce-scatter"
            if proto.requires_full_graph and proto.decomposes_per_edge
            else "a fused global collective"
        )
        raise ValueError(
            f"exchange protocol {topo.exchange_name!r} is {kind} "
            f"and only supports graph='full'; got "
            f"{graph.describe()}"
        )
    return ExchangeContext(
        axis=topo.axis if topo.peer_axes else None,
        num_peers=num_peers,
        wire_dtype=jnp.dtype(topo.exchange_dtype),
        qsgd=topo.qsgd,
        topk_frac=topo.topk_frac,
        topk_impl=topo.topk_impl,
        staleness=topo.staleness,
        graph=graph,
        mixing=mixing,
        trim_frac=topo.trim_frac,
        krum_m=topo.krum_m,
        krum_f=topo.krum_f,
        robust_clip=topo.robust_clip,
    )


# ---------------------------------------------------------------------------
# Train state
# ---------------------------------------------------------------------------


@dataclass
class TrainState:
    """The train-step carry, as a registered dataclass pytree.

    Replaces the raw ``{"params": ..., "opt_state": ...}`` dict;
    ``state["params"]``, ``state.get("mailbox")`` and ``dict(state)`` keep
    working so existing call sites migrate incrementally. ``mailbox`` holds
    the exchange protocol's carried state (None for sync protocols);
    ``ef`` holds the per-peer error-feedback residual bank — leaves shaped
    ``(P, *param)`` — when ``Topology(ef=True)``, else None.
    """

    params: Any
    opt_state: Any
    step: Any
    key: Any
    mailbox: Any = None
    ef: Any = None

    # dict-style access (legacy call sites). Matches the old dict's
    # semantics: the optional fields ("mailbox", "ef") are only present
    # when set, so lookups of an absent one raise KeyError and membership
    # tests return False.
    def __getitem__(self, name: str):
        if name not in self.keys():
            raise KeyError(name)
        return getattr(self, name)

    def get(self, name: str, default=None):
        if name not in _TRAIN_STATE_FIELDS:
            return default
        val = getattr(self, name)
        return default if (name in _OPTIONAL_STATE_FIELDS and val is None) else val

    def keys(self):
        return [
            f for f in _TRAIN_STATE_FIELDS
            if not (f in _OPTIONAL_STATE_FIELDS and getattr(self, f) is None)
        ]

    def __contains__(self, name) -> bool:
        return name in self.keys()

    def __iter__(self):
        return iter(self.keys())

    def replace(self, **updates) -> "TrainState":
        return dataclasses.replace(self, **updates)


_TRAIN_STATE_FIELDS = tuple(f.name for f in dataclasses.fields(TrainState))
_OPTIONAL_STATE_FIELDS = ("mailbox", "ef")


def _train_state_flatten_with_keys(s: TrainState):
    children = tuple(
        (jax.tree_util.GetAttrKey(name), getattr(s, name))
        for name in _TRAIN_STATE_FIELDS
    )
    return children, None


def _train_state_flatten(s: TrainState):
    return tuple(getattr(s, name) for name in _TRAIN_STATE_FIELDS), None


def _train_state_unflatten(_, children) -> TrainState:
    return TrainState(*children)


jax.tree_util.register_pytree_with_keys(
    TrainState,
    _train_state_flatten_with_keys,
    _train_state_unflatten,
    _train_state_flatten,
)


def as_train_state(state) -> TrainState:
    """Accept a TrainState or a legacy state dict."""
    if isinstance(state, TrainState):
        return state
    if isinstance(state, Mapping):
        extra = set(state) - set(_TRAIN_STATE_FIELDS)
        if extra:
            # Refuse rather than silently dropping caller-carried entries.
            raise ValueError(
                f"legacy train-state dict has entries TrainState cannot carry: "
                f"{sorted(extra)}; TrainState fields are {_TRAIN_STATE_FIELDS}"
            )
        return TrainState(
            params=state["params"],
            opt_state=state["opt_state"],
            step=state["step"],
            key=state["key"],
            mailbox=state.get("mailbox"),
            ef=state.get("ef"),
        )
    raise TypeError(f"expected TrainState or mapping, got {type(state)!r}")


# ---------------------------------------------------------------------------
# Gradient exchange (registry-dispatched; see repro/core/exchange.py)
# ---------------------------------------------------------------------------


def exchange_gradients(
    grads,
    topo: Topology,
    key: Optional[jax.Array] = None,
    mailbox=None,
    *,
    num_peers: Optional[int] = None,
):
    """Returns (averaged_grads, new_mailbox) via the registered protocol.

    Thin compatibility wrapper over ``topo.protocol().combine``; the train
    step builder calls the protocol directly. ``num_peers`` must be passed
    explicitly for sync protocols (there is no mailbox state to infer it
    from); for async state the ring's axis-1 extent is accepted as a
    fallback but an explicit count always wins.
    """
    if not topo.peer_axes:
        return grads, mailbox
    inferred = _mailbox_peers(mailbox)
    if num_peers is None:
        num_peers = inferred
        if num_peers is None:
            raise ValueError(
                "exchange_gradients needs num_peers=...: it cannot be "
                "inferred without an async mailbox state (and graph-local "
                "state need not span all peers)"
            )
    elif inferred is not None and inferred != num_peers:
        raise ValueError(
            f"exchange_gradients got num_peers={num_peers} but the async "
            f"mailbox state spans {inferred} peers; the mixing weights "
            f"would silently mis-align — rebuild the mailbox for "
            f"{num_peers} peers or pass the matching count"
        )
    # exchange_context -> ExchangeContext.__post_init__ validates that the
    # resolved overlay graph matches num_peers, raising a clear error
    # instead of silently mis-mixing.
    ctx = exchange_context(topo, num_peers=num_peers)
    return topo.protocol().combine(grads, ctx, key=key, state=mailbox)


def _mailbox_peers(mailbox) -> Optional[int]:
    """Peer count from an async mailbox ring (leaves (K, P, *grad)), else None."""
    if mailbox is None:
        return None
    leaves = jax.tree.leaves(mailbox)
    return int(leaves[0].shape[1]) if leaves else None


def init_mailbox(grads_like, num_peers: int, *, staleness: int = 1):
    """Zero-initialized staleness-K mailbox ring, leaves (K, P, *grad)."""
    return get_exchange("async").init_state(
        grads_like, ExchangeContext(num_peers=num_peers, staleness=staleness)
    )


def init_ef(grads_like, num_peers: int):
    """Zero-initialized EF-SGD residual bank: leaves (P, *grad) fp32.

    The bank is replicated across the mesh (each peer reads/writes its own
    row inside the manual region and the rows are re-gathered so the carry
    stays consistent everywhere), mirroring the async mailbox layout.
    """
    return jax.tree.map(
        lambda g: jnp.zeros((num_peers,) + tuple(g.shape), jnp.float32),
        grads_like,
    )


# ---------------------------------------------------------------------------
# Serverless intra-peer fan-out (paper §III-C)
# ---------------------------------------------------------------------------


def lambda_shard(batch: Dict[str, jnp.ndarray], topo: Topology):
    """Fan the peer's micro-batches out over the lambda (auto) axis.

    Inside the manual region the leading dim of every batch leaf is the
    peer-local batch; constraining it over the lambda axis makes XLA compute
    per-lambda partial gradients and reduce them — the TPU-native image of
    the paper's parallel Lambda invocations + gradient averaging.
    """
    if not (topo.serverless and topo.lambda_axis):
        return batch
    ax = topo.lambda_axis
    auto = compat.auto_axes()
    if auto is not None and ax not in auto:
        # Old-JAX full-manual fallback: the lambda axis is manual here, so
        # the GSPMD fan-out constraint would be rejected; peers replicate
        # their compute over it instead (see repro.compat.shard_map).
        return batch
    return jax.tree.map(
        lambda x: lax.with_sharding_constraint(x, P(*((ax,) + (None,) * (x.ndim - 1)))),
        batch,
    )


# ---------------------------------------------------------------------------
# The P2P train step builder
# ---------------------------------------------------------------------------


def build_p2p_train_step(
    loss_fn: Callable,  # (params, batch) -> (loss, aux)
    optimizer: Optimizer,
    topo: Topology,
    mesh,
    schedule: Callable[[jnp.ndarray], jnp.ndarray],
    *,
    adversary: Optional[R.AdversarySpec] = None,
):
    """Returns step(train_state, batch) -> (train_state, metrics).

    ``train_state`` is a :class:`TrainState` (legacy dicts still accepted).
    One code path serves both the peer (``shard_map`` over ``peer_axes``)
    and the no-peer (single worker) case: the peer body is identical, only
    the wrapping differs.

    ``adversary`` (a :class:`repro.core.robust.AdversarySpec`) makes the
    seeded attacker ranks publish poisoned gradients: their bank row is
    replaced (sign-flip / scaled noise) *before* the exchange collective,
    so every consumer — and the exchange protocol's estimator — sees the
    poisoned contribution. ``stale_replay`` is payload-level and only
    exists on the host mailbox path; it is refused here at build time.
    """
    protocol = topo.protocol() if topo.peer_axes else None
    ctx = exchange_context(topo, mesh) if topo.peer_axes else None
    attack_mask = None
    if adversary is not None and adversary.active and topo.peer_axes:
        if adversary.attack == "stale_replay":
            raise ValueError(
                "stale_replay replays a previous epoch's wire payload and "
                "only exists on the host mailbox path (LocalP2PCluster); "
                "use sign_flip or scaled_noise on the device path"
            )
        attack_mask = jnp.asarray(adversary.mask(ctx.num_peers))

    def peer_body(params, opt_state, step_idx, key, batch, mailbox, ef):
        batch = lambda_shard(batch, topo)
        if topo.cast_params_once:
            # One bf16 cast per step: ZeRO weight gathers then move bf16
            # instead of fp32 (halves per-layer gather bytes). Master params
            # and the optimizer stay fp32; norm vectors keep full precision.
            compute_params = jax.tree.map(
                lambda p: p.astype(jnp.bfloat16)
                if (p.dtype == jnp.float32 and p.ndim >= 2)
                else p,
                params,
            )
        else:
            compute_params = params
        if topo.accum_steps > 1:
            # sequential micro-rounds over the leading batch dim (each round
            # still fans out over the lambda axis); grads averaged in fp32
            n = topo.accum_steps

            def split(x):
                return x.reshape((n, x.shape[0] // n) + x.shape[1:])

            micro = jax.tree.map(split, batch)

            def round_fn(carry, mb):
                (loss, aux), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    compute_params, mb
                )
                acc_g, acc_l, acc_a = carry
                acc_g = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32) / n, acc_g, g
                )
                return (acc_g, acc_l + loss / n, acc_a + aux / n), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), compute_params
            )
            (grads, loss, aux), _ = lax.scan(
                round_fn, (zeros, jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
                micro,
            )
        else:
            (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                compute_params, batch
            )
        if topo.grad_clip:
            grads, gnorm = clip_by_global_norm(grads, topo.grad_clip)
        else:
            gnorm = jnp.zeros((), jnp.float32)
        step_key = jax.random.fold_in(key, step_idx)
        if attack_mask is not None:
            # Byzantine ranks publish a poisoned contribution: the honest
            # gradient still exists locally, only the exchanged row flips.
            r = lax.axis_index(topo.axis)
            poison_key = jax.random.fold_in(jax.random.fold_in(step_key, 7919), r)
            poisoned = R.poison_gradients(grads, adversary, poison_key)
            grads = jax.tree.map(
                lambda h, p: jnp.where(attack_mask[r], p, h), grads, poisoned
            )
        if protocol is None:
            avg, new_mailbox, new_ef = grads, mailbox, ef
        elif ef is not None:
            # EF-SGD: re-inject this peer's accumulated compression residual
            # before encoding, then keep what the codec dropped. local_image
            # is the decoded image of our shipped payload, so the residual
            # is exactly the information the swarm never received.
            r = lax.axis_index(topo.axis)
            corrected = jax.tree.map(
                lambda g, e: g.astype(jnp.float32) + e[r], grads, ef
            )
            avg, local_image, new_mailbox = protocol.combine_ef(
                corrected, ctx, key=step_key, state=mailbox
            )
            residual = jax.tree.map(
                lambda c, l: c - l.astype(jnp.float32), corrected, local_image
            )
            # Re-gather the per-peer rows so the replicated carry stays
            # identical on every mesh slice (same layout as the async ring).
            new_ef = jax.tree.map(
                lambda x: lax.all_gather(x, topo.axis), residual
            )
        else:
            avg, new_mailbox = protocol.combine(
                grads, ctx, key=step_key, state=mailbox
            )
            new_ef = None
        lr = schedule(step_idx)
        updates, opt_state = optimizer.update(avg, opt_state, params, lr)
        params = apply_updates(params, updates)
        if topo.peer_axes:
            loss = lax.pmean(loss, topo.axis)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr, "aux": aux}
        return params, opt_state, metrics, new_mailbox, new_ef

    def run_body(state: TrainState, batch):
        if not topo.peer_axes:
            return peer_body(
                state.params, state.opt_state, state.step, state.key,
                batch, state.mailbox, state.ef,
            )
        replicated = P()
        bspec = jax.tree.map(lambda _: P(topo.axis), batch)
        mspec = (
            None if state.mailbox is None
            else jax.tree.map(lambda _: replicated, state.mailbox)
        )
        efspec = (
            None if state.ef is None
            else jax.tree.map(lambda _: replicated, state.ef)
        )
        fn = compat.shard_map(
            peer_body,
            mesh=mesh,
            in_specs=(
                jax.tree.map(lambda _: replicated, state.params),
                jax.tree.map(lambda _: replicated, state.opt_state),
                replicated,
                replicated,
                bspec,
                mspec,
                efspec,
            ),
            out_specs=(
                jax.tree.map(lambda _: replicated, state.params),
                jax.tree.map(lambda _: replicated, state.opt_state),
                {"loss": replicated, "grad_norm": replicated, "lr": replicated,
                 "aux": replicated},
                mspec,
                efspec,
            ),
            axis_names=set(topo.peer_axes),
            check_vma=False,
        )
        return fn(
            state.params, state.opt_state, state.step, state.key,
            batch, state.mailbox, state.ef,
        )

    def step(state, batch):
        state = as_train_state(state)
        params, opt_state, metrics, mb, ef = run_body(state, batch)
        new_state = state.replace(
            params=params, opt_state=opt_state, step=state.step + 1,
            mailbox=mb, ef=ef,
        )
        return new_state, metrics

    return step
