"""Byzantine-robust aggregation — combinators + the adversary model.

The paper motivates P2P training with fault tolerance, but churn and
stragglers are *benign* faults: every published gradient is assumed
honest. SPIRT (arXiv:2309.14148) extends exactly this architecture with
robust aggregation against *malicious* peers, and the fault-tolerance
architecture study (arXiv:2302.13995) argues robustness is the reason to
pay the P2P communication overhead at all. This module supplies the two
halves of that scenario:

* **Robust combinators** — pure functions over a peer-stacked gradient
  bank (leaves shaped ``(P, ...)``): coordinate-wise trimmed mean,
  coordinate median, Krum / multi-Krum distance scoring, and per-peer
  gradient-norm clipping. The registered ``trimmed_mean:f`` / ``median``
  / ``krum[:m]`` :class:`~repro.core.exchange.ExchangeProtocol`s are thin
  wrappers over these, so the device ``shard_map`` path and the host
  mailbox path share one implementation of the estimator math.

  The masked variants take a ``(P,)`` membership mask so the same code
  serves the full mesh (mask = all peers) and a sparse
  :class:`~repro.core.graph.PeerGraph` overlay, where each peer computes
  the order statistic over its *closed neighborhood* (self + graph
  neighbors) instead of a Metropolis–Hastings weighted mix — robust
  order statistics do not commute with weighted averaging, so
  neighborhood-robust aggregation is the composable estimator. Krum
  scores need pairwise distances over ALL contributions and therefore
  refuses sparse overlays (``requires_full_graph``).

* **Adversary model** — :class:`AdversarySpec`: a seeded attacker subset
  of the peers plus an attack kind (``sign_flip`` / ``scaled_noise`` /
  ``stale_replay``). The host cluster poisons attacker *publishes* (the
  wire payload every neighbor consumes), composable with the PR-2 churn
  machinery because both ride the same mailbox; the device path poisons
  attacker ranks' gradients inside the train step before the exchange
  collective. ``stale_replay`` re-publishes the attacker's previous
  epoch's payload and is host-path only (the device step carries no
  cross-step payload cache).

Breakdown points (fraction of Byzantine peers each estimator survives,
coordinate-wise unless noted):

==================  =====================================================
``trimmed_mean:f``  up to ``f`` per end — choose ``f >=`` attacker frac
``median``          < 1/2
``krum[:m]``        ``f <= (P - 3) / 2`` (vector-wise, by construction)
plain mean          0 — one unbounded coordinate destroys the aggregate
==================  =====================================================
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

# ---------------------------------------------------------------------------
# Bank helpers: a "bank" is a pytree whose leaves are (P, ...) — one row per
# peer, the shape the device all_gather and the host contribution-stack both
# produce.
# ---------------------------------------------------------------------------


def bank_peer_norms(bank) -> jnp.ndarray:
    """Per-peer GLOBAL gradient norm across the whole bank tree: ``(P,)``."""
    sq = None
    for leaf in jax.tree.leaves(bank):
        s = jnp.sum(
            jnp.asarray(leaf, jnp.float32) ** 2,
            axis=tuple(range(1, leaf.ndim)),
        )
        sq = s if sq is None else sq + s
    if sq is None:
        raise ValueError("empty gradient bank")
    return jnp.sqrt(sq)


def clip_bank_to_norm(bank, max_norm) -> Any:
    """Per-peer gradient-norm clipping: rescale every peer row whose global
    norm exceeds ``max_norm``. Bounds the damage of one scaled-up
    contribution *before* the estimator sees it (norm defense composes
    with any combinator below)."""
    norms = bank_peer_norms(bank)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norms, 1e-12))

    def leaf(x):
        s = scale.reshape((x.shape[0],) + (1,) * (x.ndim - 1))
        return jnp.asarray(x, jnp.float32) * s

    return jax.tree.map(leaf, bank)


def clip_bank_to_median_norm(bank) -> Any:
    """Clip every peer row to the MEDIAN of the per-peer norms — the
    self-calibrating variant (no magnitude hyperparameter): honest norms
    concentrate, so the median is an honest-scale estimate as long as
    attackers are a minority."""
    return clip_bank_to_norm(bank, jnp.median(bank_peer_norms(bank)))


# ---------------------------------------------------------------------------
# Coordinate-wise order statistics (masked: one code path for full mesh and
# sparse-graph closed neighborhoods)
# ---------------------------------------------------------------------------


def _mask_like(x, mask):
    P = x.shape[0]
    m = jnp.asarray(mask, bool).reshape((P,) + (1,) * (x.ndim - 1))
    return m


def masked_trimmed_mean(x, mask, trim_frac: float):
    """Coordinate-wise trimmed mean of ``x[(P, ...)]`` over ``mask[(P,)]``.

    Sorts each coordinate across member rows, drops ``floor(trim_frac*k)``
    values from EACH end (``k`` = member count, trim clamped so at least
    one value survives), and means the rest. ``trim_frac=0`` on a full
    mask is the plain mean (float re-association only — matches
    ``allgather_mean`` to ~1e-6, the safety rail the equivalence tests
    pin down)."""
    if not 0.0 <= float(trim_frac) < 0.5:
        raise ValueError(f"trim_frac must be in [0, 0.5), got {trim_frac}")
    P = x.shape[0]
    m = _mask_like(x, mask)
    k = jnp.sum(jnp.asarray(mask, bool)).astype(jnp.int32)
    xs = jnp.sort(jnp.where(m, jnp.asarray(x, jnp.float32), jnp.inf), axis=0)
    t = jnp.floor(trim_frac * k).astype(jnp.int32)
    t = jnp.minimum(t, (k - 1) // 2)  # keep >= 1 surviving value
    idx = jnp.arange(P).reshape((P,) + (1,) * (x.ndim - 1))
    keep = (idx >= t) & (idx < k - t)
    cnt = jnp.maximum(k - 2 * t, 1).astype(jnp.float32)
    return jnp.where(keep, xs, 0.0).sum(axis=0) / cnt


def masked_median(x, mask):
    """Coordinate-wise median of ``x[(P, ...)]`` over ``mask[(P,)]`` —
    even member counts average the two middle values (numpy semantics)."""
    m = _mask_like(x, mask)
    k = jnp.sum(jnp.asarray(mask, bool)).astype(jnp.int32)
    xs = jnp.sort(jnp.where(m, jnp.asarray(x, jnp.float32), jnp.inf), axis=0)
    lo = lax.dynamic_index_in_dim(xs, (k - 1) // 2, 0, keepdims=False)
    hi = lax.dynamic_index_in_dim(xs, k // 2, 0, keepdims=False)
    return 0.5 * (lo + hi)


# ---------------------------------------------------------------------------
# Krum / multi-Krum (vector-wise, full bank)
# ---------------------------------------------------------------------------


def flatten_bank(bank) -> Tuple[jnp.ndarray, Any]:
    """Bank tree -> ``(P, D)`` matrix + an unflatten closure for one row."""
    leaves, treedef = jax.tree_util.tree_flatten(bank)
    P = leaves[0].shape[0]
    flat = jnp.concatenate(
        [jnp.asarray(l, jnp.float32).reshape(P, -1) for l in leaves], axis=1
    )
    shapes = [l.shape[1:] for l in leaves]
    sizes = [int(np.prod(s)) if s else 1 for s in shapes]
    offsets = np.cumsum([0] + sizes)

    def unflatten(row):
        outs = [
            row[offsets[i]: offsets[i + 1]].reshape(shapes[i])
            for i in range(len(leaves))
        ]
        return jax.tree_util.tree_unflatten(treedef, outs)

    return flat, unflatten


def krum_scores(flat: jnp.ndarray, f: Optional[int] = None) -> jnp.ndarray:
    """Krum distance scores over a ``(P, D)`` bank: ``score_i`` = sum of
    squared distances to ``i``'s ``P - f - 2`` nearest OTHER rows
    (Blanchard et al., 2017). Lower = more central = more trustworthy.

    ``f`` is the assumed Byzantine count; defaults to the maximum the
    estimator tolerates, ``floor((P - 3) / 2)``. Distances come from the
    Gram matrix (``O(P^2 D)`` flops but only ``O(P^2)`` memory), clamped
    at zero against float cancellation.
    """
    P = int(flat.shape[0])
    if P < 3:
        raise ValueError(f"krum needs at least 3 peers, got {P}")
    if f is None:
        f = (P - 3) // 2
    f = int(f)
    if not 0 <= f <= P - 3:
        raise ValueError(f"krum assumed attacker count f={f} outside [0, {P - 3}]")
    sqn = jnp.sum(flat * flat, axis=1)
    d2 = jnp.maximum(sqn[:, None] + sqn[None, :] - 2.0 * flat @ flat.T, 0.0)
    d2 = d2 + jnp.diag(jnp.full((P,), jnp.inf, jnp.float32))  # exclude self
    near = P - f - 2  # >= 1 by the f bound above
    return jnp.sort(d2, axis=1)[:, :near].sum(axis=1)


def krum_select(
    flat: jnp.ndarray, *, m: int = 1, f: Optional[int] = None
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Multi-Krum: average the ``m`` lowest-scored rows of ``(P, D)``.

    Returns ``(aggregate (D,), selected row indices (m,))``; ``m=1`` is
    classic Krum (the single most central gradient).
    """
    P = int(flat.shape[0])
    m = int(m)
    if not 1 <= m <= P:
        raise ValueError(f"krum selection count m={m} outside [1, {P}]")
    scores = krum_scores(flat, f)
    sel = jnp.argsort(scores)[:m]
    return jnp.take(flat, sel, axis=0).mean(axis=0), sel


# ---------------------------------------------------------------------------
# Adversary model
# ---------------------------------------------------------------------------

ATTACK_KINDS = ("sign_flip", "scaled_noise", "stale_replay")


@dataclass(frozen=True)
class AdversarySpec:
    """A seeded Byzantine attacker set + the attack its members mount.

    ``fraction`` of the peers (or an explicit ``num``) are attackers,
    chosen uniformly without replacement from ``seed`` — so a fixed seed
    fixes WHICH peers are malicious across protocols/graphs in a sweep,
    isolating the estimator as the only variable. Attack kinds:

    * ``sign_flip`` — publish ``-scale x`` the honest gradient (the
      classic reverse-the-update poisoning).
    * ``scaled_noise`` — publish ``scale x N(0, 1)`` noise of the honest
      gradient's shape (seeded per peer x epoch).
    * ``stale_replay`` — re-publish the attacker's previous epoch's wire
      payload verbatim (epoch 0 has nothing to replay and publishes
      honestly). Host path only: it replays the *encoded payload*, which
      exists only on the mailbox path.

    Composable with churn: both ride :class:`LocalP2PCluster`'s publish
    path, so a churned-out attacker's stale poisoned register keeps being
    consumed — exactly the failure mode robust estimators must absorb.
    """

    fraction: float = 0.0
    num: Optional[int] = None  # explicit attacker count, overrides fraction
    attack: str = "sign_flip"
    scale: float = 10.0  # sign-flip / noise magnitude multiplier
    seed: int = 0

    def __post_init__(self):
        if self.attack not in ATTACK_KINDS:
            raise ValueError(
                f"unknown attack {self.attack!r}; kinds: {', '.join(ATTACK_KINDS)}"
            )
        if not 0.0 <= self.fraction <= 1.0:
            raise ValueError(f"fraction must be in [0, 1], got {self.fraction}")
        if self.num is not None and self.num < 0:
            raise ValueError(f"num must be >= 0, got {self.num}")

    def num_attackers(self, num_peers: int) -> int:
        if self.num is not None:
            return min(int(self.num), int(num_peers))
        return int(round(self.fraction * num_peers))

    def attackers(self, num_peers: int) -> Tuple[int, ...]:
        """The seeded attacker ranks, ascending."""
        n = self.num_attackers(num_peers)
        if n == 0:
            return ()
        rng = np.random.default_rng(self.seed)
        return tuple(
            sorted(int(r) for r in rng.choice(num_peers, size=n, replace=False))
        )

    def is_attacker(self, rank: int, num_peers: int) -> bool:
        return rank in self.attackers(num_peers)

    def mask(self, num_peers: int) -> np.ndarray:
        """(P,) bool — True at attacker ranks."""
        m = np.zeros(num_peers, dtype=bool)
        for r in self.attackers(num_peers):
            m[r] = True
        return m

    @property
    def active(self) -> bool:
        return self.num is not None and self.num > 0 or self.fraction > 0.0

    def describe(self) -> str:
        return (
            f"adversary({self.attack}, "
            f"{'num=' + str(self.num) if self.num is not None else f'frac={self.fraction:g}'}"
            f", scale={self.scale:g}, seed={self.seed})"
        )


def poison_gradients(grads, spec: AdversarySpec, key):
    """One attacker's poisoned gradient under ``sign_flip``/``scaled_noise``.

    Pure and path-agnostic: the host cluster poisons before encoding, the
    device step applies it under a rank predicate inside the manual
    region. ``stale_replay`` is payload-level and handled by the
    cluster's publish cache (this function refuses it)."""
    if spec.attack == "sign_flip":
        return jax.tree.map(
            lambda g: -spec.scale * jnp.asarray(g, jnp.float32), grads
        )
    if spec.attack == "scaled_noise":
        leaves, treedef = jax.tree_util.tree_flatten(grads)
        keys = jax.random.split(key, len(leaves))
        return jax.tree_util.tree_unflatten(
            treedef,
            [
                spec.scale * jax.random.normal(k, g.shape, jnp.float32)
                for g, k in zip(leaves, keys)
            ],
        )
    raise ValueError(
        f"attack {spec.attack!r} is payload-level (host mailbox path only) "
        "and cannot be expressed as a gradient transform"
    )


def tree_all_finite(tree) -> bool:
    """Host-side non-finite check: True iff every leaf is finite everywhere."""
    return all(
        bool(jnp.all(jnp.isfinite(jnp.asarray(leaf, jnp.float32))))
        for leaf in jax.tree.leaves(tree)
    )
