"""Heterogeneous fleets and the cost-aware auto-scheduler.

PR 5 made the cost–time frontier *descriptive*: ``pareto_frontier`` plots
pure-serverless vs pure-EC2 points and a human picks one. The 2025
follow-up ("Cost-Performance Analysis: CPU-Based Serverless vs GPU-Based
Training Architectures", PAPERS.md) shows the real decision space is
heterogeneous — CPU serverless vs GPU instances vs mixed fleets — and
"Towards Demystifying Serverless ML Training" (Jiang et al.) shows that
per-workload backend selection, not a fixed choice, is what makes
serverless training economical. This module makes the frontier
*prescriptive*:

* :class:`PeerAssignment` / :class:`FleetPlan` — a per-rank backend map:
  each peer runs on a pinned serverless tier, a CPU instance, or a GPU
  instance (:data:`repro.core.cost.GPU_USD_PER_HOUR` etc.).
* :class:`FleetExecutor` — runs one epoch of a plan on the existing
  engines (:class:`~repro.core.serverless.ServerlessExecutor` for Lambda
  peers, one persistent :class:`~repro.core.instance.InstanceRuntime` per
  instance tier). Epoch wall-clock is the max over heterogeneous per-peer
  makespans; epoch cost is the sum over per-peer bills, with barrier idle
  (the gap to the slowest peer) billed on instance peers — a VM's meter
  runs while it waits, a Lambda's does not.
* :class:`Scheduler` registry (mirroring
  :class:`~repro.core.events.AllocationPolicy`) — policies that re-pick
  the plan each epoch from *measured* :class:`~repro.core.cost.CostReport`
  history: ``cheapest_under_deadline``, ``fastest_under_budget``, and the
  best-effort greedy ``pareto_walk``.

Conventions: per-peer batch times are measured on the 1-vCPU reference
machine (the same baseline ``instance_vcpus`` scales against), so a GPU
peer runs them :func:`repro.core.cost.instance_equivalent_vcpus` times
faster; a deadline constrains the fleet epoch wall-clock
(``CostReport.wall_time_s``); a budget constrains the whole-cluster epoch
cost (``CostReport.total_usd``).
"""
from __future__ import annotations

import abc
from dataclasses import dataclass
from typing import (
    Any,
    Callable,
    ClassVar,
    Dict,
    List,
    Optional,
    Sequence,
    Tuple,
    Type,
    Union,
)

from repro.core.cost import (
    GPU_BOOT_S,
    INSTANCE_MEMORY_MB,
    CostReport,
    ec2_cost_per_second,
    is_gpu_instance,
    pareto_frontier,
)
from repro.core.events import InstanceConfig, RuntimeConfig, ServerlessRuntime
from repro.core.instance import InstanceRuntime
from repro.core.serverless import (
    LAMBDA_MAX_MEMORY_MB,
    ExecutionReport,
    ServerlessExecutor,
    ServerlessPlanner,
)

# ---------------------------------------------------------------------------
# FleetPlan — a per-rank backend assignment
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class PeerAssignment:
    """Where one rank runs: a Lambda tier or an instance tier.

    ``backend="serverless"`` with ``memory_mb=0`` lets the planner /
    allocation policy size the function; a nonzero ``memory_mb`` pins the
    tier (still clamped to the fit floor and the Lambda cap).
    ``backend="instance"`` requires a tier from
    :data:`repro.core.cost.INSTANCE_MEMORY_MB` — CPU (t2.*) or GPU
    (g4dn/g5/p3) — and takes no ``memory_mb``.
    """

    backend: str  # "serverless" | "instance"
    instance: str = ""  # instance tier; instance backend only
    memory_mb: int = 0  # pinned Lambda tier; serverless backend only

    def __post_init__(self):
        if self.backend not in ("serverless", "instance"):
            raise ValueError(
                f"backend must be 'serverless' or 'instance', got "
                f"{self.backend!r}"
            )
        if self.backend == "instance":
            if self.instance not in INSTANCE_MEMORY_MB:
                raise ValueError(
                    f"unknown instance tier {self.instance!r}; known tiers: "
                    f"{', '.join(sorted(INSTANCE_MEMORY_MB))}"
                )
            if self.memory_mb:
                raise ValueError(
                    "memory_mb is a serverless knob; an instance peer's "
                    "memory is its tier's"
                )
        else:
            if self.instance:
                raise ValueError(
                    "instance is an instance-backend knob; a serverless "
                    "peer has no VM tier"
                )
            if self.memory_mb and not (
                128 <= self.memory_mb <= LAMBDA_MAX_MEMORY_MB
            ):
                raise ValueError(
                    f"memory_mb must be 0 (auto) or in [128, "
                    f"{LAMBDA_MAX_MEMORY_MB}], got {self.memory_mb}"
                )

    @property
    def is_gpu(self) -> bool:
        return self.backend == "instance" and is_gpu_instance(self.instance)

    def describe(self) -> str:
        if self.backend == "serverless":
            return f"lambda:{self.memory_mb or 'auto'}"
        return f"{'gpu' if self.is_gpu else 'cpu'}:{self.instance}"


@dataclass(frozen=True)
class FleetPlan:
    """One epoch's rank → backend map: ``assignments[rank]`` says where
    that peer computes its gradients. Pure plans (every rank identical)
    reproduce PR 5's single-backend accounting exactly — the equivalence
    rail in ``tests/test_scheduler.py``."""

    assignments: Tuple[PeerAssignment, ...]
    name: str = ""

    def __post_init__(self):
        object.__setattr__(self, "assignments", tuple(self.assignments))
        if not self.assignments:
            raise ValueError("a FleetPlan needs at least one peer")

    @staticmethod
    def pure(
        backend: str,
        num_peers: int,
        *,
        instance: str = "",
        memory_mb: int = 0,
        name: str = "",
    ) -> "FleetPlan":
        """Every rank on the same backend/tier — PR 5's pure configs as a
        degenerate fleet."""
        a = PeerAssignment(backend, instance=instance, memory_mb=memory_mb)
        return FleetPlan(
            (a,) * int(num_peers), name=name or f"pure-{a.describe()}"
        )

    @property
    def num_peers(self) -> int:
        return len(self.assignments)

    @property
    def is_pure(self) -> bool:
        return len(set(self.assignments)) == 1

    def describe(self) -> str:
        counts: Dict[str, int] = {}
        for a in self.assignments:
            counts[a.describe()] = counts.get(a.describe(), 0) + 1
        parts = [f"{n}x {kind}" for kind, n in sorted(counts.items())]
        return f"{self.name or 'fleet'}[{', '.join(parts)}]"


# ---------------------------------------------------------------------------
# FleetExecutor — one epoch of a mixed fleet on the existing engines
# ---------------------------------------------------------------------------


@dataclass
class FleetReport:
    """One fleet epoch: per-peer engine reports plus the fleet-level
    reduction — wall = max over peers (the sync barrier), cost = sum over
    peers (every peer pays its own bill, idle included)."""

    plan: FleetPlan
    epoch: int
    per_peer: List[ExecutionReport]
    wall_time_s: float  # max over per-peer makespans
    total_usd: float  # sum over per-peer bills (incl. barrier idle)

    def cost_report(self, *, label: str = "") -> CostReport:
        """The fleet's point on the frontier. Pure plans report under
        their real backend name (so single-backend fleets are directly
        comparable to PR 5 pure reports); mixed plans report
        ``backend="fleet"``. ``cost_usd`` is per peer (``total_usd / P``),
        matching the pure convention."""
        p = self.plan
        a0 = p.assignments[0]
        pure = p.is_pure
        return CostReport(
            backend=a0.backend if pure else "fleet",
            wall_time_s=self.wall_time_s,
            cost_usd=self.total_usd / p.num_peers,
            instance=a0.instance if pure else "",
            lambda_memory_mb=(
                self.per_peer[0].lambda_memory_mb
                if pure and a0.backend == "serverless"
                else 0
            ),
            num_peers=p.num_peers,
            label=label or p.name or p.describe(),
        )


class FleetExecutor:
    """Runs fleet epochs: Lambda peers on one persistent
    :class:`~repro.core.serverless.ServerlessExecutor` (warm pools and
    allocation history keyed per rank), instance peers on one persistent
    :class:`~repro.core.instance.InstanceRuntime` per tier (VM fleets stay
    booted across epochs). GPU tiers default to
    :meth:`~repro.core.events.InstanceConfig.gpu_default` boot figures
    (:data:`repro.core.cost.GPU_BOOT_S`); CPU tiers default to the ideal
    config, matching PR 5's ``InstanceRuntime`` default.

    ``tracer`` threads a :class:`repro.analysis.trace.TraceRecorder`
    through every engine underneath, so a mixed epoch is digest-stable
    under a fixed seed exactly like the pure paths (PR 8 rail).
    """

    def __init__(
        self,
        *,
        runtime: Union[RuntimeConfig, ServerlessRuntime, None] = None,
        instance_config: Optional[InstanceConfig] = None,  # override ALL tiers
        planner: Optional[ServerlessPlanner] = None,
        instance_vcpus: float = 1.0,
        allocation: str = "static",
        invoke_overhead_s: float = 0.15,
        orchestration_overhead_s: float = 0.30,
        tracer: Any = None,
    ):
        self.tracer = tracer
        self.instance_vcpus = instance_vcpus
        self._instance_config = instance_config
        self._planner = planner or ServerlessPlanner()
        self._invoke_overhead_s = invoke_overhead_s
        self._orchestration_overhead_s = orchestration_overhead_s
        if not isinstance(runtime, ServerlessRuntime):
            runtime = ServerlessRuntime(runtime, tracer=tracer)
        self.serverless = ServerlessExecutor(
            backend="serverless",
            planner=self._planner,
            instance_vcpus=instance_vcpus,
            invoke_overhead_s=invoke_overhead_s,
            orchestration_overhead_s=orchestration_overhead_s,
            runtime=runtime,
            allocation=allocation,
        )
        self._per_tier: Dict[str, ServerlessExecutor] = {}
        self.epochs_run = 0

    def _tier_config(self, tier: str) -> InstanceConfig:
        if self._instance_config is not None:
            return self._instance_config
        if is_gpu_instance(tier):
            return InstanceConfig.gpu_default(GPU_BOOT_S[tier])
        return InstanceConfig()

    def instance_executor(self, tier: str) -> ServerlessExecutor:
        """The persistent instance accountant for one tier (VM fleet + RNG
        stream live across epochs, like a long-lived deployment)."""
        if tier not in self._per_tier:
            self._per_tier[tier] = ServerlessExecutor(
                backend="instance",
                planner=self._planner,
                instance=tier,
                instance_vcpus=self.instance_vcpus,
                invoke_overhead_s=self._invoke_overhead_s,
                orchestration_overhead_s=self._orchestration_overhead_s,
                instance_config=InstanceRuntime(
                    self._tier_config(tier), instance=tier, tracer=self.tracer
                ),
            )
        return self._per_tier[tier]

    def run_epoch(
        self,
        plan: FleetPlan,
        per_peer_batch_s: Sequence[Sequence[float]],
        *,
        model_bytes: int,
        batch_bytes: int,
        epoch: Optional[int] = None,
    ) -> FleetReport:
        """One synchronous fleet epoch: every rank computes its own batch
        list on its assigned backend, then all meet at the exchange
        barrier. ``per_peer_batch_s[rank]`` are that rank's reference-
        machine batch times (heterogeneous per-peer workloads are the
        point — see fig14). Instance peers bill their barrier idle (wall
        minus own makespan) at their tier's per-second rate; serverless
        peers bill nothing while idle (the functions already exited)."""
        if len(per_peer_batch_s) != plan.num_peers:
            raise ValueError(
                f"plan has {plan.num_peers} peers but "
                f"{len(per_peer_batch_s)} per-peer batch lists were given"
            )
        if epoch is None:
            epoch = self.epochs_run
        if self.tracer is not None:
            self.tracer.record(
                "fleet_epoch",
                epoch=epoch,
                peers=plan.num_peers,
                plan=plan.describe(),
            )
        reports: List[ExecutionReport] = []
        for rank, (a, times) in enumerate(
            zip(plan.assignments, per_peer_batch_s)
        ):
            if a.backend == "serverless":
                rep = self.serverless.simulate(
                    times,
                    model_bytes=model_bytes,
                    batch_bytes=batch_bytes,
                    epoch=epoch,
                    peer=rank,
                    memory_mb=a.memory_mb or None,
                )
            else:
                rep = self.instance_executor(a.instance).simulate_instance(
                    times,
                    model_bytes=model_bytes,
                    batch_bytes=batch_bytes,
                    epoch=epoch,
                    peer=rank,
                    reference_vcpus=self.instance_vcpus,
                )
            reports.append(rep)
        wall = max(r.wall_time_s for r in reports)
        for a, rep in zip(plan.assignments, reports):
            if a.backend == "instance":
                idle = wall - rep.wall_time_s
                if idle > 0.0:
                    rep.idle_s += idle
                    rep.instance_billed_s += idle
                    rep.cost_usd += ec2_cost_per_second(a.instance) * idle
        self.epochs_run += 1
        return FleetReport(
            plan=plan,
            epoch=epoch,
            per_peer=reports,
            wall_time_s=wall,
            total_usd=float(sum(r.cost_usd for r in reports)),
        )


def evaluate_candidates(
    candidates: Sequence[FleetPlan],
    per_peer_batch_s: Union[
        Sequence[Sequence[float]],
        Callable[[FleetPlan], Sequence[Sequence[float]]],
    ],
    *,
    model_bytes: int,
    batch_bytes: int,
    warm: bool = True,
    runtime: Union[RuntimeConfig, ServerlessRuntime, None] = None,
    instance_config: Optional[InstanceConfig] = None,
    instance_vcpus: float = 1.0,
    tracer: Any = None,
) -> List[CostReport]:
    """Measure every candidate plan — the scheduler's observation pass.

    Each candidate runs on a FRESH :class:`FleetExecutor` (no warm-pool or
    VM-state pollution between candidates). ``warm=True`` runs two epochs
    and reports the second — the steady state a multi-epoch training run
    lives in, with VM boots paid and containers warm — so a GPU peer's
    90 s boot doesn't disqualify it from a 60 s/epoch deadline it meets
    every epoch after the first. ``per_peer_batch_s`` is either one
    per-peer list-of-lists (every plan must have matching P) or a callable
    ``plan -> per-peer lists`` for candidates of varying P.
    """
    reports: List[CostReport] = []
    for plan in candidates:
        times = (
            per_peer_batch_s(plan)
            if callable(per_peer_batch_s)
            else per_peer_batch_s
        )
        fx = FleetExecutor(
            runtime=runtime,
            instance_config=instance_config,
            instance_vcpus=instance_vcpus,
            tracer=tracer,
        )
        fr = fx.run_epoch(
            plan, times, model_bytes=model_bytes, batch_bytes=batch_bytes
        )
        if warm:
            fr = fx.run_epoch(
                plan, times, model_bytes=model_bytes, batch_bytes=batch_bytes
            )
        reports.append(fr.cost_report())
    return reports


def standard_candidates(
    num_peers: int,
    *,
    memory_tiers: Sequence[int] = (0, 4400, LAMBDA_MAX_MEMORY_MB),
    cpu_tiers: Sequence[str] = ("t2.large", "t2.xlarge"),
    gpu_tiers: Sequence[str] = ("g4dn.xlarge", "p3.2xlarge"),
    mixed_gpu: str = "p3.2xlarge",
) -> List[FleetPlan]:
    """The default candidate set the trainer/CLI schedulers pick from:
    pure serverless at each memory tier (0 = planner auto), pure instance
    at each CPU/GPU tier, plus one half-GPU half-serverless mixed plan
    (ranks [0, P/2) on the GPU — pair them with the heavy workloads)."""
    cands = [
        FleetPlan.pure(
            "serverless",
            num_peers,
            memory_mb=m,
            name=f"serverless-{m or 'auto'}",
        )
        for m in memory_tiers
    ]
    for tier in list(cpu_tiers) + list(gpu_tiers):
        cands.append(
            FleetPlan.pure(
                "instance", num_peers, instance=tier, name=f"instance-{tier}"
            )
        )
    if num_peers >= 2:
        k = num_peers // 2
        mixed = tuple(
            PeerAssignment("instance", instance=mixed_gpu) for _ in range(k)
        ) + tuple(
            PeerAssignment("serverless") for _ in range(num_peers - k)
        )
        cands.append(FleetPlan(mixed, name=f"mixed-{k}x{mixed_gpu}"))
    return cands


# ---------------------------------------------------------------------------
# Scheduler registry — prescriptive frontier navigation
# ---------------------------------------------------------------------------


class Scheduler(abc.ABC):
    """Picks next epoch's plan from measured cost reports.

    ``choose`` sees one :class:`~repro.core.cost.CostReport` per candidate
    (same order as the candidate list, e.g. from
    :func:`evaluate_candidates`) plus the operator's constraints, and
    returns the index of the plan to run. A deadline bounds the fleet
    epoch wall-clock (``wall_time_s``); a budget bounds the whole-cluster
    epoch cost (``total_usd``). Strict policies raise ``ValueError`` when
    no candidate is feasible — they never silently violate a constraint;
    ``pareto_walk`` is the best-effort alternative.
    """

    name: ClassVar[str] = "?"  # set by @register_scheduler

    @abc.abstractmethod
    def choose(
        self,
        reports: Sequence[CostReport],
        *,
        deadline_s: Optional[float] = None,
        budget_usd: Optional[float] = None,
    ) -> int:
        """Return the index of the candidate to run next epoch."""


_SCHED_REGISTRY: Dict[str, Type[Scheduler]] = {}


def register_scheduler(name: str):
    """Class decorator: make a scheduler reachable by name everywhere."""

    def deco(cls: Type[Scheduler]) -> Type[Scheduler]:
        if not issubclass(cls, Scheduler):
            raise TypeError(f"{cls!r} must subclass Scheduler")
        cls.name = name
        _SCHED_REGISTRY[name] = cls
        return cls

    return deco


def available_schedulers() -> Tuple[str, ...]:
    return tuple(sorted(_SCHED_REGISTRY))


def get_scheduler(name: str, **kwargs) -> Scheduler:
    try:
        cls = _SCHED_REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown scheduler {name!r}; registered schedulers: "
            f"{', '.join(available_schedulers())}"
        )
    return cls(**kwargs)


@register_scheduler("cheapest_under_deadline")
class CheapestUnderDeadline(Scheduler):
    """Minimum whole-cluster cost among plans meeting the wall-clock
    deadline. With no deadline, simply the cheapest plan. Raises when no
    candidate is fast enough — the caller must relax the deadline or add
    candidates, never overshoot silently."""

    def choose(self, reports, *, deadline_s=None, budget_usd=None):
        feasible = [
            i
            for i, r in enumerate(reports)
            if deadline_s is None or r.wall_time_s <= deadline_s
        ]
        if not feasible:
            fastest = min(r.wall_time_s for r in reports)
            raise ValueError(
                f"no candidate meets the {deadline_s:.3g}s deadline; the "
                f"fastest plan takes {fastest:.3g}s"
            )
        return min(
            feasible,
            key=lambda i: (reports[i].total_usd, reports[i].wall_time_s, i),
        )


@register_scheduler("fastest_under_budget")
class FastestUnderBudget(Scheduler):
    """Minimum epoch wall-clock among plans within the whole-cluster
    budget. With no budget, simply the fastest plan. Raises when every
    candidate overspends."""

    def choose(self, reports, *, deadline_s=None, budget_usd=None):
        feasible = [
            i
            for i, r in enumerate(reports)
            if budget_usd is None or r.total_usd <= budget_usd
        ]
        if not feasible:
            cheapest = min(r.total_usd for r in reports)
            raise ValueError(
                f"no candidate fits the ${budget_usd:.3g} epoch budget; the "
                f"cheapest plan costs ${cheapest:.3g}"
            )
        return min(
            feasible,
            key=lambda i: (reports[i].wall_time_s, reports[i].total_usd, i),
        )


@register_scheduler("pareto_walk")
class ParetoWalk(Scheduler):
    """Greedy best-effort frontier walk.

    Starts at the cheapest point of the measured Pareto frontier and steps
    toward faster/costlier frontier points only while the deadline is
    still violated and the next step stays within budget. Never picks a
    dominated plan and never raises: infeasible constraints yield the
    closest frontier point (the fastest affordable one when no point meets
    the deadline; the cheapest one when everything overspends)."""

    def choose(self, reports, *, deadline_s=None, budget_usd=None):
        front = pareto_frontier(reports)
        # frontier is wall-ascending == cost-descending; walk cheapest-first
        order = [reports.index(p) for p in reversed(front)]
        pick = order[0]
        for nxt in order[1:]:
            if deadline_s is None or reports[pick].wall_time_s <= deadline_s:
                break  # deadline met (or absent): stop, this is cheapest
            if (
                budget_usd is not None
                and reports[nxt].total_usd > budget_usd
            ):
                break  # the faster step would overspend: best effort stops
            pick = nxt
        return pick
