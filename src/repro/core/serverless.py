"""Serverless gradient offload — paper §III-C / §IV-D.

Two halves:

* :class:`ServerlessPlanner` — sizes the Lambda pool for a workload: memory
  per function (from the model + batch footprint, mirroring the paper's
  per-batch-size memory column in Table II), number of invocations, and the
  Step-Functions-style dynamic fan-out plan.
* :class:`ServerlessExecutor` — executes a peer's per-batch gradient
  computations. The math runs for real (the gradient returned is exact);
  wall-clock is *accounted* under the chosen backend:
    - "instance": resource-constrained sequential processing (the paper's
      PyTorch-on-small-EC2 baseline) -> sum of batch times.
    - "serverless": parallel Lambda fan-out -> max of batch times, scaled by
      the Lambda/instance speed ratio, plus invocation + orchestration
      overheads.
  On the TPU path the fan-out is not simulated at all — it is the lambda
  mesh axis (see repro/core/p2p.py::lambda_shard).

Since the ServerlessRuntime refactor the executor no longer owns a time
model: wall-clock comes from a discrete-event fan-out simulation on
:class:`repro.core.events.ServerlessRuntime` (cold/warm container pools,
concurrency caps, retries, stragglers), and per-epoch memory sizing is
delegated to a pluggable :class:`repro.core.events.AllocationPolicy`.
The default :class:`repro.core.events.RuntimeConfig` is ideal (no faults,
no cold starts, unbounded concurrency) and reproduces the legacy analytic
accounting to float precision.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

import jax
import numpy as np

from repro.core.cost import (
    CostReport,
    ServerlessCost,
    ec2_cost_per_second,
    lambda_cost_per_second,
    working_set_mb,
)
from repro.core.events import (
    AllocationPolicy,
    FanoutResult,
    InstanceConfig,
    InstanceEpochResult,
    InvocationRecord,
    LinkModel,
    RuntimeConfig,
    ServerlessRuntime,
    get_allocation,
)
from repro.core.instance import InstanceRuntime, instance_speedup, instance_splits

LAMBDA_MAX_MEMORY_MB = 10_240  # AWS cap (paper §III-A)
LAMBDA_TIMEOUT_S = 15 * 60
LAMBDA_MB_PER_VCPU = 1_769  # AWS: 1 vCPU per 1769 MB
DEPLOY_ZIP_CAP_MB = 50
DEPLOY_UNZIPPED_CAP_MB = 250


def lambda_speedup(memory_mb: int, instance_vcpus: float) -> float:
    """Lambda vCPU share relative to the baseline instance (floored at 0.25:
    even tiny functions make some progress)."""
    return max((memory_mb / LAMBDA_MB_PER_VCPU) / instance_vcpus, 0.25)


@dataclass(frozen=True)
class LambdaSpec:
    memory_mb: int
    speedup_vs_instance: float  # Lambda vCPUs / instance vCPUs available

    @property
    def vcpus(self) -> float:
        return self.memory_mb / LAMBDA_MB_PER_VCPU


@dataclass(frozen=True)
class StepFunctionPlan:
    """The dynamically generated parallel state machine (paper §IV-D.3)."""

    num_branches: int
    lambda_spec: LambdaSpec
    payload_keys: Tuple[str, ...]  # S3 batch keys, one per branch

    def asl_sketch(self) -> Dict[str, Any]:
        """Amazon-States-Language-shaped dict (for docs/tests)."""
        return {
            "StartAt": "ParallelGradients",
            "States": {
                "ParallelGradients": {
                    "Type": "Map",
                    "MaxConcurrency": self.num_branches,
                    "ItemsPath": "$.batches",
                    "Iterator": {
                        "StartAt": "ComputeBatchGradient",
                        "States": {
                            "ComputeBatchGradient": {
                                "Type": "Task",
                                "Resource": "arn:aws:lambda:::function:grad",
                                "End": True,
                            }
                        },
                    },
                    "End": True,
                }
            },
        }


class ServerlessPlanner:
    """Sizes Lambda memory like the paper: the minimum that fits the model,
    activations for one batch, and the runtime, rounded up to 64 MB."""

    def __init__(self, *, runtime_overhead_mb: int = 700):
        self.runtime_overhead_mb = runtime_overhead_mb

    def lambda_memory_mb(self, model_bytes: int, batch_bytes: int) -> int:
        # params + grads + activations + runtime (shared sizing model)
        need = working_set_mb(model_bytes, batch_bytes, self.runtime_overhead_mb)
        mb = int(math.ceil(need / 64.0) * 64)
        if mb > LAMBDA_MAX_MEMORY_MB:
            raise ValueError(
                f"workload needs {mb} MB > Lambda cap {LAMBDA_MAX_MEMORY_MB} MB"
            )
        return max(mb, 128)

    def plan(
        self,
        *,
        model_bytes: int,
        batch_bytes: int,
        num_batches: int,
        instance_vcpus: float = 1.0,
        batch_keys: Optional[Sequence[str]] = None,
    ) -> StepFunctionPlan:
        mem = self.lambda_memory_mb(model_bytes, batch_bytes)
        spec = LambdaSpec(
            memory_mb=mem,
            speedup_vs_instance=lambda_speedup(mem, instance_vcpus),
        )
        keys = tuple(batch_keys or (f"batch-{i:05d}" for i in range(num_batches)))
        return StepFunctionPlan(num_batches, spec, keys)


@dataclass
class ExecutionReport:
    backend: str
    wall_time_s: float  # accounted wall-clock under the backend model
    measured_compute_s: float  # actual CPU time spent on the gradients
    per_batch_s: List[float]
    num_batches: int
    lambda_memory_mb: int = 0
    cost_usd: float = 0.0
    # -- runtime-engine accounting (serverless backend) ---------------------
    epoch: int = 0
    num_cold_starts: int = 0
    cold_start_s: float = 0.0  # total container init time across invocations
    queue_wait_s: float = 0.0  # total concurrency-throttle wait
    num_retries: int = 0
    retry_s: float = 0.0  # dead work + backoff recovering from failures
    billed_lambda_s: float = 0.0  # Lambda-billed seconds across all attempts
    request_fee_usd: float = 0.0  # per-request fee incl. retried invocations
    egress_bytes: int = 0  # exchange bytes moved on the overlay this epoch
    egress_usd: float = 0.0
    download_s: float = 0.0  # payload fetch time (sharded aggregator pieces)
    invocations: List[InvocationRecord] = field(default_factory=list)
    # -- instance-runtime accounting (instance backend) ---------------------
    instance: str = ""  # EC2 tier (baseline VM / serverless orchestrator)
    boot_s: float = 0.0  # VM provisioning time paid this epoch (billed)
    idle_s: float = 0.0  # billed-but-idle seconds (barrier wait)
    downtime_s: float = 0.0  # unbilled churn gaps (no VM running)
    churn_drops: int = 0
    num_splits: int = 1  # micro-batches per batch under memory pressure
    wire_s: float = 0.0  # exchange upload + degree-many downloads
    instance_billed_s: float = 0.0  # EC2-billed seconds (boot+busy+idle)

    def cost_report(self, *, num_peers: int = 1, label: str = "") -> CostReport:
        """This epoch's point on the cost–time frontier — the common
        currency that makes serverless and instance accounting directly
        comparable (``repro.core.cost.compare_backends``)."""
        return CostReport(
            backend=self.backend,
            wall_time_s=self.wall_time_s,
            cost_usd=self.cost_usd,
            instance=self.instance,
            lambda_memory_mb=self.lambda_memory_mb,
            num_peers=num_peers,
            label=label,
        )


class ServerlessExecutor:
    """Runs per-batch gradient thunks; time/cost comes from the runtime engine.

    ``run`` measures the real per-batch compute, then hands the measured
    times to :meth:`simulate`, which prices them under the configured
    :class:`~repro.core.events.ServerlessRuntime` (cold starts, concurrency
    queueing, retries, stragglers) with the Lambda memory chosen per epoch
    by the :class:`~repro.core.events.AllocationPolicy`.
    """

    def __init__(
        self,
        *,
        backend: str = "serverless",  # "serverless" | "instance"
        planner: Optional[ServerlessPlanner] = None,
        instance: str = "t2.small",
        instance_vcpus: float = 1.0,
        invoke_overhead_s: float = 0.15,  # warm-start + S3 batch fetch
        orchestration_overhead_s: float = 0.30,  # Step Functions state machine
        runtime: Union[RuntimeConfig, ServerlessRuntime, None] = None,
        allocation: Union[str, AllocationPolicy] = "static",
        instance_config: Union[InstanceConfig, InstanceRuntime, None] = None,
    ):
        if backend not in ("serverless", "instance"):
            raise ValueError(
                f"backend must be 'serverless' or 'instance', got {backend!r}"
            )
        self.backend = backend
        self.planner = planner or ServerlessPlanner()
        self.instance = instance
        self.instance_vcpus = instance_vcpus
        self.invoke_overhead_s = invoke_overhead_s
        self.orchestration_overhead_s = orchestration_overhead_s
        if isinstance(runtime, ServerlessRuntime):
            self.runtime = runtime
        else:
            self.runtime = ServerlessRuntime(runtime)
        # The instance-baseline counterpart of `runtime`: a discrete-event
        # VM fleet (boot, per-second billing, churn). The ideal default
        # reproduces the legacy Formula-(2) closed form exactly.
        if isinstance(instance_config, InstanceRuntime):
            self.instance_runtime = instance_config
        else:
            self.instance_runtime = InstanceRuntime(
                instance_config, instance=instance
            )
        if isinstance(allocation, str):
            allocation = get_allocation(allocation)
        self.allocation: AllocationPolicy = allocation
        # per-peer fan-out history, the allocation policy's observation stream
        self.history: Dict[Any, List[FanoutResult]] = {}
        # per-peer instance-epoch history (the VM fleet's observation stream)
        self.instance_history: Dict[Any, List[InstanceEpochResult]] = {}

    # ------------------------------------------------------------------
    def _memory_mb(self, planned_mb: int, epoch: int, peer: Any) -> int:
        """Policy suggestion clamped to [fit floor, Lambda cap], 64 MB tiers."""
        mem = self.allocation.memory_mb(
            epoch=epoch, planned_mb=planned_mb, history=self.history.get(peer, ()),
        )
        mem = max(planned_mb, min(int(mem), LAMBDA_MAX_MEMORY_MB))
        return int(math.ceil(mem / 64.0) * 64)

    def simulate(
        self,
        per_batch_s: Sequence[float],
        *,
        model_bytes: int,
        batch_bytes: int,
        epoch: Optional[int] = None,
        peer: Any = 0,
        egress_bytes: int = 0,
        usd_per_gb_egress: float = 0.0,
        memory_mb: Optional[int] = None,
    ) -> ExecutionReport:
        """Account measured instance-side batch times under the runtime.

        This is the accounting half of :meth:`run`, usable on its own when
        the math already happened elsewhere (e.g. on the TPU lambda axis:
        ``P2PTrainer.account_serverless``). ``egress_bytes`` is the peer's
        degree-aware exchange traffic for the epoch (per-edge payload x
        overlay degree, from ``ExchangeProtocol.wire_bytes``); it is billed
        at ``usd_per_gb_egress`` on top of the Lambda formula.
        ``memory_mb`` pins this peer's Lambda tier explicitly (a
        ``FleetPlan`` assignment), bypassing the allocation policy; it is
        still clamped to [fit floor, Lambda cap] on the 64 MB grid.
        """
        per_batch = [float(t) for t in per_batch_s]
        measured = float(sum(per_batch))
        if epoch is None:
            epoch = len(self.history.get(peer, ()))
        plan = self.planner.plan(
            model_bytes=model_bytes,
            batch_bytes=batch_bytes,
            num_batches=len(per_batch),
            instance_vcpus=self.instance_vcpus,
        )
        if memory_mb is None:
            mem = self._memory_mb(plan.lambda_spec.memory_mb, epoch, peer)
        else:
            mem = max(
                plan.lambda_spec.memory_mb,
                min(int(memory_mb), LAMBDA_MAX_MEMORY_MB),
            )
            mem = int(math.ceil(mem / 64.0) * 64)
        speed = lambda_speedup(mem, self.instance_vcpus)
        lam_times = [t / speed + self.invoke_overhead_s for t in per_batch]
        if lam_times and max(lam_times) > LAMBDA_TIMEOUT_S:
            raise ValueError(
                f"a batch needs {max(lam_times):.0f}s on a "
                f"{mem}MB Lambda — exceeds the "
                f"{LAMBDA_TIMEOUT_S}s cap (paper §III-A); shrink the batch "
                "or raise memory"
            )
        res = self.runtime.fanout(
            [t / speed for t in per_batch],
            memory_mb=mem,
            function_key=peer,
            invoke_overhead_s=self.invoke_overhead_s,
            timeout_s=LAMBDA_TIMEOUT_S,
        )
        self.history.setdefault(peer, []).append(res)
        wall = self.orchestration_overhead_s + res.makespan_s
        cost = ServerlessCost(
            compute_time_s=wall,
            num_batches=len(per_batch),
            lambda_memory_mb=mem,
            instance=self.instance,
            num_retries=res.num_retries,
            retry_billed_s=sum(r.failed_s for r in res.invocations),
            cold_start_billed_s=res.cold_start_s_total,
            egress_bytes=egress_bytes,
            usd_per_gb_egress=usd_per_gb_egress,
        )
        return ExecutionReport(
            backend="serverless",
            wall_time_s=wall,
            measured_compute_s=measured,
            per_batch_s=per_batch,
            num_batches=len(per_batch),
            lambda_memory_mb=mem,
            cost_usd=cost.cost_per_peer,
            epoch=epoch,
            num_cold_starts=res.num_cold_starts,
            cold_start_s=res.cold_start_s_total,
            queue_wait_s=res.queue_wait_s_total,
            num_retries=res.num_retries,
            retry_s=res.retry_s_total,
            billed_lambda_s=res.billed_s_total,
            request_fee_usd=cost.request_fee_usd,
            egress_bytes=egress_bytes,
            egress_usd=cost.egress_usd,
            invocations=res.invocations,
            instance=self.instance,
        )

    def simulate_aggregation(
        self,
        per_shard_s: Sequence[float],
        *,
        shard_bytes: int,
        num_contributions: int,
        epoch: Optional[int] = None,
        peer: Any = "aggregate",
        link=None,
        usd_per_gb_egress: float = 0.0,
    ) -> ExecutionReport:
        """Price P parallel serverless aggregators under the runtime engine.

        The sharded-exchange aggregation stage (SPIRT / LambdaML): one
        Lambda invocation PER SHARD, all submitted concurrently, each
        downloading its ``num_contributions - 1`` foreign shard pieces
        (charged via ``link``) and reducing ``shard_bytes`` worth of
        parameters per contribution. Cold starts, stragglers, concurrency
        caps, and retries apply per shard; the
        :class:`~repro.core.events.AllocationPolicy` sizes aggregator
        memory from SHARD bytes — not model bytes — so doubling the peer
        count halves both the aggregation makespan and the memory tier.

        ``per_shard_s`` are instance-side measured reduce times, one per
        shard (``len(per_shard_s)`` = the shard count P).
        """
        per_shard = [float(t) for t in per_shard_s]
        key = ("agg", peer)
        if epoch is None:
            epoch = len(self.history.get(key, ()))
        # Aggregator footprint: the shard accumulator + one incoming piece
        # + runtime — the planner's model slot holds the shard, not the
        # model, which is the whole point of sharding the aggregation.
        planned = self.planner.lambda_memory_mb(
            model_bytes=int(shard_bytes), batch_bytes=int(shard_bytes)
        )
        mem = self._memory_mb(planned, epoch, key)
        speed = lambda_speedup(mem, self.instance_vcpus)
        dl_bytes = max(num_contributions - 1, 0) * int(shard_bytes)
        res = self.runtime.fanout(
            [t / speed for t in per_shard],
            memory_mb=mem,
            function_key=key,
            invoke_overhead_s=self.invoke_overhead_s,
            timeout_s=LAMBDA_TIMEOUT_S,
            download_bytes=[dl_bytes] * len(per_shard),
            link=link,
        )
        self.history.setdefault(key, []).append(res)
        wall = self.orchestration_overhead_s + res.makespan_s
        egress_bytes = dl_bytes * len(per_shard)
        cost = ServerlessCost(
            compute_time_s=wall,
            num_batches=len(per_shard),
            lambda_memory_mb=mem,
            instance=self.instance,
            num_retries=res.num_retries,
            retry_billed_s=sum(r.failed_s for r in res.invocations),
            cold_start_billed_s=res.cold_start_s_total,
            egress_bytes=egress_bytes,
            usd_per_gb_egress=usd_per_gb_egress,
        )
        return ExecutionReport(
            backend="serverless",
            wall_time_s=wall,
            measured_compute_s=float(sum(per_shard)),
            per_batch_s=per_shard,
            num_batches=len(per_shard),
            lambda_memory_mb=mem,
            cost_usd=cost.cost_per_peer,
            epoch=epoch,
            num_cold_starts=res.num_cold_starts,
            cold_start_s=res.cold_start_s_total,
            queue_wait_s=res.queue_wait_s_total,
            num_retries=res.num_retries,
            retry_s=res.retry_s_total,
            billed_lambda_s=res.billed_s_total,
            request_fee_usd=cost.request_fee_usd,
            egress_bytes=egress_bytes,
            egress_usd=cost.egress_usd,
            download_s=sum(r.download_s for r in res.invocations),
            invocations=res.invocations,
            instance=self.instance,
        )

    def simulate_instance(
        self,
        per_batch_s: Sequence[float],
        *,
        model_bytes: int = 0,
        batch_bytes: int = 0,
        epoch: Optional[int] = None,
        peer: Any = 0,
        reference_vcpus: Optional[float] = None,
        upload_bytes: int = 0,
        download_bytes: Sequence[int] = (),
        link: Optional[LinkModel] = None,
        barrier_wait_s: float = 0.0,
        strict_fit: bool = True,
    ) -> ExecutionReport:
        """Account measured per-batch times under the instance baseline.

        The instance-side mirror of :meth:`simulate`: the same measured
        batch times, priced on :class:`~repro.core.instance.InstanceRuntime`
        — sequential execution on the configured EC2 tier, with boot,
        per-second billing including idle, memory-constrained mini-batch
        splitting (``model_bytes``/``batch_bytes`` against the tier's
        memory), seeded churn, and degree-aware wire charging
        (``upload_bytes`` + one ``download_bytes`` entry per overlay
        neighbor, through ``link``). ``reference_vcpus`` rescales times
        measured on a different machine onto this tier's vCPUs (``None`` =
        already measured here, the legacy convention). The ideal
        :class:`~repro.core.events.InstanceConfig` with no wire/barrier
        charging reproduces the legacy closed form: ``wall = sum(
        per_batch_s)``, ``cost = Formula (2)`` — equivalence-tested.
        """
        per_batch = [float(t) for t in per_batch_s]
        measured = float(sum(per_batch))
        rt = self.instance_runtime
        if epoch is None:
            epoch = len(self.instance_history.get(peer, ()))
        splits = 1
        if model_bytes > 0:
            try:
                splits = instance_splits(
                    model_bytes, batch_bytes, rt.instance,
                    runtime_overhead_mb=self.planner.runtime_overhead_mb,
                )
            except ValueError:
                # the model alone overflows the tier: with strict_fit the
                # scenario is refused (fig10 marks it "does not fit");
                # without, fall back to the legacy no-memory-model
                # accounting (the operator provisioned swap/host memory)
                if strict_fit:
                    raise
                splits = 1
        speed = instance_speedup(rt.instance, reference_vcpus)
        res = rt.run_epoch(
            [t / speed for t in per_batch],
            peer=peer,
            splits=splits,
            upload_bytes=upload_bytes,
            download_bytes=download_bytes,
            link=link,
            barrier_wait_s=barrier_wait_s,
        )
        self.instance_history.setdefault(peer, []).append(res)
        cost = rt.price(res)
        return ExecutionReport(
            backend="instance",
            wall_time_s=res.makespan_s,
            measured_compute_s=measured,
            per_batch_s=per_batch,
            num_batches=len(per_batch),
            cost_usd=cost.cost_per_peer,
            epoch=epoch,
            instance=rt.instance,
            boot_s=res.boot_s,
            idle_s=res.idle_s,
            downtime_s=res.downtime_s,
            churn_drops=res.churn_drops,
            num_splits=res.splits,
            wire_s=res.wire_s,
            instance_billed_s=cost.billed_s,
        )

    def run(
        self,
        grad_thunks: Sequence[Callable[[], Any]],
        *,
        model_bytes: int,
        batch_bytes: int,
        combine: Callable[[List[Any]], Any],
        epoch: Optional[int] = None,
        peer: Any = 0,
    ) -> Tuple[Any, ExecutionReport]:
        """Execute every thunk (exact math), account wall time per backend."""
        results: List[Any] = []
        per_batch: List[float] = []
        for thunk in grad_thunks:
            t0 = time.perf_counter()
            out = thunk()
            jax.block_until_ready(out)
            per_batch.append(time.perf_counter() - t0)
            results.append(out)
        measured = float(sum(per_batch))
        g = combine(results)

        if self.backend == "instance":
            # engine-priced baseline: boot, churn, memory-constrained
            # splitting apply; the ideal default reproduces the legacy
            # closed form (wall = measured, cost = Formula (2)) exactly.
            # strict_fit off: an oversized model falls back to the legacy
            # no-memory-model accounting instead of refusing the epoch
            report = self.simulate_instance(
                per_batch,
                model_bytes=model_bytes,
                batch_bytes=batch_bytes,
                epoch=epoch,
                peer=peer,
                strict_fit=False,
            )
            return g, report

        report = self.simulate(
            per_batch,
            model_bytes=model_bytes,
            batch_bytes=batch_bytes,
            epoch=epoch,
            peer=peer,
        )
        return g, report
