"""ShardPlan — contiguous gradient sharding, the unit of sharded exchange.

SPIRT (arXiv:2309.14148) partitions model updates so each peer aggregates
only its shard; LambdaML (arXiv:2105.07806) shows scatter-reduce-style
aggregation is the winning communication pattern for serverless training.
Both need the same primitive: a deterministic, shape-preserving mapping
between a gradient pytree and ``P`` equal-size contiguous shards. That
mapping is a :class:`ShardPlan`:

* **flatten** — every leaf is raveled (C order), cast to a common buffer
  dtype (the NumPy promotion of all leaf dtypes, so no leaf loses
  precision), and concatenated into ONE contiguous buffer, zero-padded to
  a multiple of ``num_shards``.
* **shard** — the padded buffer splits into ``num_shards`` equal
  contiguous rows, ``shards[i] = buffer[i*S : (i+1)*S]``; shard ``i`` is
  owned by peer ``i`` under the sharded exchange protocols.
* **unflatten** — the exact inverse: slice each leaf's ``[offset,
  offset+size)`` range back out, reshape, and cast to the original leaf
  dtype. ``unflatten(shards(tree)) == tree`` bit-for-bit as long as the
  buffer dtype can represent every leaf value (always true for the float
  promotions used here; property-tested in ``tests/test_shard.py``).

The plan is built once from *shapes* (arrays or ``ShapeDtypeStruct``s) and
is pure static metadata, so it is free to construct inside a jitted trace
— the device ``reduce_scatter`` protocol builds one per ``combine`` call —
and equally usable on the host path, where the mailbox carries
shard-addressed messages and the cost model prices shard-sized payloads.

Padding edge case worth noting: with more shards than parameters
(``P > total``) the element shard size is 1 and the trailing shards are
pure padding — exchanged, aggregated, and then dropped by ``unflatten``.
"""
from __future__ import annotations

import functools
import math
from dataclasses import dataclass
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class ShardPlan:
    """Static metadata mapping one pytree <-> ``num_shards`` contiguous shards."""

    treedef: Any
    shapes: Tuple[Tuple[int, ...], ...]
    dtypes: Tuple[Any, ...]
    offsets: Tuple[int, ...]  # element offset of each leaf in the buffer
    total: int  # unpadded element count across all leaves
    num_shards: int
    shard_size: int  # elements per shard (padded; equal for every shard)
    buffer_dtype: Any  # promoted dtype every leaf roundtrips through

    # -- construction --------------------------------------------------------
    @classmethod
    def for_tree(cls, tree_like, num_shards: int) -> "ShardPlan":
        """Build a plan from a pytree of arrays / ShapeDtypeStructs."""
        if num_shards < 1:
            raise ValueError(f"num_shards must be >= 1, got {num_shards}")
        leaves, treedef = jax.tree_util.tree_flatten(tree_like)
        shapes = tuple(tuple(int(d) for d in x.shape) for x in leaves)
        dtypes = tuple(jnp.dtype(x.dtype) for x in leaves)
        sizes = [int(np.prod(s)) if s else 1 for s in shapes]
        offsets, off = [], 0
        for n in sizes:
            offsets.append(off)
            off += n
        total = off
        buffer_dtype = (
            functools.reduce(jnp.promote_types, dtypes)
            if dtypes
            else jnp.dtype(jnp.float32)
        )
        shard_size = math.ceil(total / num_shards) if total else 0
        return cls(
            treedef=treedef,
            shapes=shapes,
            dtypes=dtypes,
            offsets=tuple(offsets),
            total=total,
            num_shards=int(num_shards),
            shard_size=shard_size,
            buffer_dtype=jnp.dtype(buffer_dtype),
        )

    # -- derived sizes -------------------------------------------------------
    @property
    def sizes(self) -> Tuple[int, ...]:
        return tuple(int(np.prod(s)) if s else 1 for s in self.shapes)

    @property
    def padded_size(self) -> int:
        return self.num_shards * self.shard_size

    @property
    def pad(self) -> int:
        """Zero elements appended so every shard is exactly ``shard_size``."""
        return self.padded_size - self.total

    def shard_slice(self, i: int) -> Tuple[int, int]:
        """Element range ``[start, stop)`` of shard ``i`` in the buffer."""
        if not 0 <= i < self.num_shards:
            raise IndexError(f"shard {i} out of range [0, {self.num_shards})")
        return i * self.shard_size, (i + 1) * self.shard_size

    def shard_bytes(self, wire_dtype: Optional[Any] = None) -> int:
        """Bytes of ONE shard on the wire — the sharded per-edge payload
        and the figure aggregator memory is sized from (O(model / P))."""
        dt = jnp.dtype(wire_dtype) if wire_dtype is not None else self.buffer_dtype
        return self.shard_size * dt.itemsize

    # -- flatten / shard -----------------------------------------------------
    def flatten(self, tree) -> jnp.ndarray:
        """Pytree -> one contiguous padded 1-D buffer (``buffer_dtype``)."""
        leaves = jax.tree_util.tree_leaves(tree)
        if len(leaves) != len(self.shapes):
            raise ValueError(
                f"tree has {len(leaves)} leaves, plan was built for "
                f"{len(self.shapes)}"
            )
        if not leaves:
            return jnp.zeros((self.padded_size,), self.buffer_dtype)
        flat = jnp.concatenate(
            [jnp.ravel(x).astype(self.buffer_dtype) for x in leaves]
        )
        if self.pad:
            flat = jnp.concatenate(
                [flat, jnp.zeros((self.pad,), self.buffer_dtype)]
            )
        return flat

    def shards(self, tree) -> jnp.ndarray:
        """Pytree -> ``(num_shards, shard_size)``; row ``i`` is shard ``i``."""
        return self.flatten(tree).reshape(self.num_shards, self.shard_size)

    # -- unflatten -----------------------------------------------------------
    def unflatten(self, buffer) -> Any:
        """Inverse of :meth:`flatten` / :meth:`shards`.

        Accepts the 1-D padded buffer or the ``(num_shards, shard_size)``
        stack; padding is dropped, every leaf is reshaped and cast back to
        its original dtype.
        """
        buf = jnp.asarray(buffer).reshape(-1)
        if buf.shape[0] != self.padded_size:
            raise ValueError(
                f"buffer has {buf.shape[0]} elements, plan expects "
                f"{self.padded_size} (= {self.num_shards} x {self.shard_size})"
            )
        leaves = []
        for shape, dtype, off, n in zip(
            self.shapes, self.dtypes, self.offsets, self.sizes
        ):
            leaf = jax.lax.dynamic_slice_in_dim(buf, off, n).reshape(shape)
            leaves.append(leaf.astype(dtype))
        return jax.tree_util.tree_unflatten(self.treedef, leaves)

    def describe(self) -> str:
        return (
            f"ShardPlan(P={self.num_shards}, {self.total} elems -> "
            f"{self.shard_size}/shard (+{self.pad} pad), "
            f"buffer={self.buffer_dtype.name}, "
            f"{self.shard_bytes()} B/shard)"
        )
