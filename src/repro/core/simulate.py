"""Local P2P cluster — a literal, runnable Algorithm 1.

Runs P peers in one process with *real* per-peer models, optimizers, data
partitions, gradient mailboxes and (optionally) the serverless executor.
This is what the paper's CNN experiments run on: Table I (stage resources),
Fig. 3 (serverless speedup), Fig. 4 (compute/comm scaling), Fig. 5 (QSGD),
Fig. 6 (sync vs async convergence).

Synchronous mode executes epochs in lockstep with the RabbitMQ barrier
semantics. Asynchronous mode runs on the shared discrete-event
:class:`~repro.core.events.EventEngine` (the same engine that times the
serverless fan-out): each peer has a speed factor, advances its own virtual
clock by its *measured* compute time x speed, publishes gradients at
completion instants, and consumes whatever other-peer gradients are visible
at its own clock — the paper's "latest available, possibly stale"
behaviour, which is what destabilizes async convergence in Fig. 6. Peer
churn (SPIRT-style, arXiv:2309.14148) rides on the engine: a peer can drop
mid-epoch, lose its partial work, and rejoin after a downtime while the
others keep consuming its last published gradient — well-defined because
the mailbox is a latest-wins register.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import models
from repro.configs.base import ModelConfig
from repro.core import compression as C
from repro.core.convergence import ConvergenceDetector
from repro.core.cost import CommCost
from repro.core.events import EventEngine, LinkModel
from repro.core.exchange import ExchangeContext, ExchangeProtocol, get_exchange
from repro.core.graph import PeerGraph, get_graph
from repro.core.mailbox import HostMailbox
from repro.core.robust import AdversarySpec, poison_gradients, tree_all_finite
from repro.core.serverless import ExecutionReport, ServerlessExecutor
from repro.data import DataLoader, Dataset, Partitioner, BatchKey
from repro.metrics import StageMetrics
from repro.optim import Optimizer, apply_updates


def cnn_loss(params, batch, cfg):
    logits, _ = models.forward(params, batch, cfg)
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32))
    loss = -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()
    acc = (logits.argmax(-1) == labels).mean()
    return loss, acc


@dataclass
class PeerState:
    rank: int
    params: Any
    opt_state: Any
    loader: DataLoader
    metrics: StageMetrics
    clock: float = 0.0  # virtual time (async mode)
    speed: float = 1.0  # relative compute speed
    steps_done: int = 0
    comm_bytes_sent: int = 0
    send_time_s: float = 0.0
    recv_time_s: float = 0.0
    compute_time_s: float = 0.0
    drops: int = 0  # churn events survived (async mode)
    downtime_s: float = 0.0  # simulated time lost to churn
    reports: List[ExecutionReport] = field(default_factory=list)
    ef: Any = None  # EF-SGD residual pytree (lazily zero-init on first publish)


class LocalP2PCluster:
    """P peers, real compute, mailbox exchange, sync or async."""

    def __init__(
        self,
        cfg: ModelConfig,
        dataset: Dataset,
        *,
        num_peers: int,
        batch_size: int,
        batches_per_epoch: int,
        optimizer: Optimizer,
        lr: float = 0.001,
        sync: bool = True,
        executor: Optional[ServerlessExecutor] = None,
        exchange: Optional[str] = None,  # registered protocol name
        graph: Any = "full",  # peer overlay: registered name or PeerGraph
        graph_seed: Optional[int] = None,  # defaults to `seed`
        qsgd: Optional[C.QSGDConfig] = None,
        topk_frac: float = 0.01,
        topk_impl: str = "jnp",  # topk select/scatter: "jnp" | Pallas "kernel"
        ef: bool = False,  # EF-SGD residual feedback for lossy codecs
        network_bandwidth_bps: float = 1e9,  # simulated inter-peer link
        peer_speeds: Optional[Sequence[float]] = None,
        churn_prob: float = 0.0,  # async: P(peer drops mid-step), per attempt
        churn_downtime_s: float = 1.0,  # async: rejoin delay after a drop
        adversary: Optional[AdversarySpec] = None,  # Byzantine attacker model
        reject_nonfinite: bool = False,  # drop NaN/Inf contributions at consume
        trim_frac: float = 0.0,  # trimmed_mean default (spec param overrides)
        krum_m: int = 1,  # multi-Krum default (spec param overrides)
        krum_f: Optional[int] = None,  # Krum's assumed Byzantine count
        robust_clip: float = 0.0,  # per-contribution norm clip, 0 = off
        sim_compute_s: Optional[Any] = None,  # float | callable(rank, epoch)
        tracer: Any = None,  # repro.analysis.trace.TraceRecorder, optional
        seed: int = 0,
    ):
        import dataclasses as _dc

        if cfg.family == "cnn" and dataset.kind == "image":
            cfg = _dc.replace(
                cfg,
                image_size=dataset.image_hw,
                image_channels=dataset.channels,
                num_classes=dataset.num_classes,
            )
        self.cfg = cfg
        self.dataset = dataset
        self.num_peers = num_peers
        self.batch_size = batch_size
        self.batches_per_epoch = batches_per_epoch
        self.optimizer = optimizer
        self.sync = sync
        self.executor = executor
        self.qsgd = qsgd
        # The wire format comes from the same ExchangeProtocol registry the
        # TPU shard_map path uses; the legacy qsgd= kwarg implies "qsgd".
        if exchange is None:
            exchange = "qsgd" if qsgd is not None else "allgather_mean"
        self.protocol: ExchangeProtocol = get_exchange(exchange)
        # Peer overlay: consumption walks graph edges only, updates use the
        # graph's Metropolis–Hastings weights (uniform mean on the full
        # graph — the legacy, bit-exact path).
        self.graph: PeerGraph = get_graph(
            graph, num_peers, seed=seed if graph_seed is None else graph_seed
        )
        self._mixing = (
            None if (self.graph.is_full or num_peers <= 1)
            else self.graph.mixing_matrix()
        )
        if self._mixing is not None and (
            not self.protocol.decomposes_per_edge
            or self.protocol.requires_full_graph
        ):
            kind = (
                "a sharded global reduce-scatter"
                if self.protocol.requires_full_graph
                and self.protocol.decomposes_per_edge
                else "a fused global collective"
            )
            raise ValueError(
                f"exchange protocol {self.protocol.name!r} is {kind} "
                f"and only supports graph='full'; got "
                f"{self.graph.describe()}"
            )
        if self.protocol.sharded and not sync:
            raise ValueError(
                f"exchange protocol {self.protocol.name!r} is a barriered "
                "sharded exchange (scatter -> aggregate -> re-broadcast) and "
                "only runs in sync mode; use exchange='async' for "
                "asynchronous epochs"
            )
        # Adversary model: a seeded subset of peers publishes poisoned (or
        # stale-replayed) payloads through the SAME publish path honest
        # peers use — composable with churn, graphs and every wire codec.
        self.adversary = adversary
        self._attackers = (
            frozenset(adversary.attackers(num_peers))
            if adversary is not None else frozenset()
        )
        if self._attackers and self.protocol.sharded:
            raise ValueError(
                f"exchange protocol {self.protocol.name!r} exchanges "
                "shard pieces, not whole-gradient payloads; the adversary "
                "model poisons whole-gradient publishes — use a dense "
                "protocol (allgather_mean / trimmed_mean / median / krum)"
            )
        self._poison_key = jax.random.PRNGKey(
            adversary.seed if adversary is not None else 0
        )
        self._replay_cache: Dict[int, Tuple[Any, int]] = {}  # stale_replay
        self.reject_nonfinite = reject_nonfinite
        self.ef = bool(ef)
        if self.ef and self.protocol.sharded:
            raise ValueError(
                f"exchange protocol {self.protocol.name!r} exchanges shard "
                "pieces and bypasses the per-peer publish path; error "
                "feedback applies to lossy whole-gradient codecs (qsgd/topk)"
            )
        self.xctx = ExchangeContext(
            num_peers=num_peers, qsgd=qsgd, topk_frac=topk_frac,
            topk_impl=topk_impl,
            graph=self.graph, mixing=self._mixing,
            trim_frac=trim_frac, krum_m=krum_m, krum_f=krum_f,
            robust_clip=robust_clip,
        )
        self.bw = network_bandwidth_bps
        self.link = LinkModel(bandwidth_bps=network_bandwidth_bps)
        # Deterministic virtual compute time. The async clock normally
        # advances by MEASURED wall time x speed, which varies run to run;
        # sim_compute_s (a constant, or callable(rank, epoch) -> seconds)
        # replaces the measurement so same-seed traces are bit-identical —
        # required by the repro.analysis.trace double-run differ.
        self.sim_compute_s = sim_compute_s
        self.tracer = tracer
        self.mailbox = HostMailbox(num_peers, graph=self.graph, tracer=tracer)
        self.detector = ConvergenceDetector(lr, mode="max", max_epochs=10_000)
        self.key = jax.random.PRNGKey(seed)
        self.churn_prob = churn_prob
        self.churn_downtime_s = churn_downtime_s
        # one RNG stream for all async-epoch stochastics (churn); the engine
        # itself is rebuilt per epoch but shares this stream, so a fixed
        # seed fixes the whole multi-epoch trajectory
        self._rng = np.random.default_rng(seed)
        self.last_event_order: List[int] = []  # rank processing order, last async epoch

        part = Partitioner(dataset, num_peers, shuffle_seed=seed)
        init_params = models.init_model(jax.random.PRNGKey(seed), cfg)
        self.peers: List[PeerState] = []
        speeds = list(peer_speeds or [1.0] * num_peers)
        for r in range(num_peers):
            self.peers.append(
                PeerState(
                    rank=r,
                    params=jax.tree.map(jnp.copy, init_params),
                    opt_state=optimizer.init(init_params),
                    loader=DataLoader(part, r, batch_size),
                    metrics=StageMetrics(),
                    speed=speeds[r],
                )
            )

        cfg_static = cfg

        @jax.jit
        def _grad(params, batch):
            (loss, acc), g = jax.value_and_grad(cnn_loss, has_aux=True)(
                params, batch, cfg_static
            )
            return g, loss, acc

        self._grad = _grad

        @jax.jit
        def _apply(params, opt_state, avg_grads, lr):
            upd, opt_state = optimizer.update(avg_grads, opt_state, params, lr)
            return apply_updates(params, upd), opt_state

        self._apply = _apply

        @jax.jit
        def _eval(params, batch):
            return cnn_loss(params, batch, cfg_static)

        self._eval = _eval

        self._model_bytes = sum(x.size * 4 for x in jax.tree.leaves(init_params))
        # Sharded exchange: one contiguous shard per peer (gradients share
        # the params' structure), plus the per-epoch parallel-aggregation
        # reports when a serverless executor prices the aggregators.
        self.shard_plan = (
            self.protocol.plan(init_params, self.xctx)
            if self.protocol.sharded else None
        )
        self.aggregation_reports: List[ExecutionReport] = []

        # Warm the jit caches so stage timings measure compute, not compilation.
        wb = jax.tree.map(jnp.asarray, self.peers[0].loader.load(BatchKey(0, 0, 0)))
        g0, _, _ = self._grad(init_params, wb)
        jax.block_until_ready(
            self._apply(init_params, self.peers[0].opt_state, g0, jnp.float32(lr))
        )
        jax.block_until_ready(self._eval(init_params, wb))

    # ------------------------------------------------------------------
    def _batch_thunks(self, peer: PeerState, epoch: int):
        keys = [
            BatchKey(peer.rank, epoch, i % peer.loader.num_batches)
            for i in range(self.batches_per_epoch)
        ]
        batches = [jax.tree.map(jnp.asarray, peer.loader.load(k)) for k in keys]

        def mk(b):
            return lambda: self._grad(peer.params, b)

        return [mk(b) for b in batches], batches

    def _compute_peer_gradient(self, peer: PeerState, epoch: int):
        """ComputeBatchGradients + AverageBatchesGradients (Algorithm 1)."""
        thunks, batches = self._batch_thunks(peer, epoch)
        batch_bytes = sum(
            sum(np.asarray(b[k]).nbytes for k in sorted(b)) for b in batches
        ) // max(len(batches), 1)

        def combine(outs):
            gs = [o[0] for o in outs]
            avg = jax.tree.map(lambda *xs: sum(x.astype(jnp.float32) for x in xs) / len(xs), *gs)
            loss = float(np.mean([float(o[1]) for o in outs]))
            acc = float(np.mean([float(o[2]) for o in outs]))
            return avg, loss, acc

        if self.executor is not None:
            (g, loss, acc), report = self.executor.run(
                thunks,
                model_bytes=self._model_bytes,
                batch_bytes=batch_bytes,
                combine=combine,
                epoch=epoch,
                peer=peer.rank,
            )
            peer.reports.append(report)
            if report.backend == "serverless":
                # engine-simulated per-invocation stages, Table-I style
                peer.metrics.add_simulated("cold_start", report.cold_start_s)
                peer.metrics.add_simulated("queue_wait", report.queue_wait_s)
                peer.metrics.add_simulated("retry", report.retry_s)
            else:
                # instance baseline: VM provisioning + churn gaps (the
                # cluster's own link charges exchange wire separately)
                peer.metrics.add_simulated("boot", report.boot_s)
                peer.metrics.add_simulated("churn_downtime", report.downtime_s)
            compute_wall = report.wall_time_s
        else:
            t0 = time.perf_counter()
            outs = [t() for t in thunks]
            g, loss, acc = combine(outs)
            compute_wall = time.perf_counter() - t0
        if self.sim_compute_s is not None:
            compute_wall = float(
                self.sim_compute_s(peer.rank, epoch)
                if callable(self.sim_compute_s) else self.sim_compute_s
            )
        peer.compute_time_s += compute_wall
        return g, loss, acc, compute_wall

    def _publish(self, peer: PeerState, grads, epoch: int, at_time: float):
        """SendGradientsToMyQueue via the exchange protocol's wire format.

        Byzantine peers poison HERE — the publish is the wire, so every
        neighbor (and only neighbors) consumes the poisoned payload while
        the attacker's own local gradient stays honest. ``sign_flip`` /
        ``scaled_noise`` transform the gradient before encoding (composes
        with any codec); ``stale_replay`` re-publishes the attacker's
        previous epoch's encoded payload verbatim.

        Returns this peer's OWN contribution for the consume/update phase:
        the raw gradient normally, or — under error feedback — the decoded
        image of the encoded payload, with the residual (what the codec
        dropped) accumulated into ``peer.ef`` for re-injection next step.
        """
        poisoned = False
        if peer.rank in self._attackers and self.adversary.attack != "stale_replay":
            pk = jax.random.fold_in(
                jax.random.fold_in(self._poison_key, epoch), peer.rank
            )
            grads = poison_gradients(grads, self.adversary, pk)
            poisoned = True
        if self.ef:
            if peer.ef is None:
                peer.ef = jax.tree.map(
                    lambda g: jnp.zeros(g.shape, jnp.float32), grads
                )
            grads = jax.tree.map(
                lambda g, e: g.astype(jnp.float32) + e, grads, peer.ef
            )
        own = grads
        with peer.metrics.stage("send_gradients"):
            key = None
            if self.protocol.requires_key:
                self.key, key = jax.random.split(self.key)
            payload, nbytes = self.protocol.host_encode(grads, self.xctx, key=key)
            if self.ef:
                image = self.protocol.host_decode(payload, grads, self.xctx)
                peer.ef = jax.tree.map(
                    lambda g, i: g - i.astype(jnp.float32), grads, image
                )
                own = image
            if peer.rank in self._attackers and self.adversary.attack == "stale_replay":
                replayed = self._replay_cache.get(peer.rank)
                self._replay_cache[peer.rank] = (payload, nbytes)
                if replayed is not None:
                    payload, nbytes = replayed  # epoch e ships epoch e-1's wire
                    poisoned = True
            msg = (self.protocol.name, payload)
            jax.block_until_ready(jax.tree.leaves(payload))
            wire_s = self.link.transfer_s(nbytes)
            self.mailbox.publish(
                peer.rank, msg, nbytes=nbytes, time=at_time + wire_s, epoch=epoch,
                poisoned=poisoned,
            )
        peer.comm_bytes_sent += nbytes
        peer.send_time_s += wire_s
        return own

    def _consume_all(self, peer: PeerState, own_grads, at_time: Optional[float]):
        """ConsumeGradientsFromQueue along the peer's overlay edges.

        The seed repo walked every other peer (full mesh); consumption now
        follows ``self.graph.neighbors`` — per-peer download traffic is
        O(degree), not O(P). Returns ``(grads_peers, recv_wire_s)``: the
        consumed gradient set and the receive-side wire time — payload
        download plus the S3 round trip for >100 MB indirected messages —
        charged against the simulated link (async mode also advances the
        peer's clock by it).
        """
        grads_peers = {peer.rank: own_grads}
        recv_wire_s = 0.0
        with peer.metrics.stage("receive_gradients"):
            for other in self.graph.neighbors(peer.rank):
                msg = self.mailbox.consume(
                    other, at_time=at_time, consumer=peer.rank
                )
                if msg is None:
                    continue  # async: nothing published yet -> skip
                _, payload = msg.payload
                decoded = self.protocol.host_decode(payload, own_grads, self.xctx)
                wire_s = self.mailbox.download_time_s(msg, link=self.link)
                peer.recv_time_s += wire_s
                recv_wire_s += wire_s
                if self.reject_nonfinite and not tree_all_finite(decoded):
                    # The bytes still crossed the wire (charged above); the
                    # contribution is dropped at the trust boundary.
                    self.mailbox.stats["rejected_nonfinite"] += 1
                    continue
                grads_peers[other] = decoded
        return grads_peers, recv_wire_s

    def _update(self, peer: PeerState, grads_peers: Dict[int, Any], lr: float):
        """Mix the consumed gradients and step the peer's optimizer.

        Robust protocols (trimmed mean / median / Krum) take over the whole
        combine via :meth:`ExchangeProtocol.host_combine`; otherwise:

        Full graph: plain mean over contributions (legacy, bit-exact).
        Sparse graph: Metropolis–Hastings weights ``W[r]``, renormalized
        over the contributions that actually arrived so a not-yet-published
        (or churned-out) neighbor doesn't shrink the update.
        """
        with peer.metrics.stage("model_update"):
            robust = self.protocol.host_combine(grads_peers, peer.rank, self.xctx)
            if robust is not None:
                self._apply_avg(peer, robust, lr)
                return
            if self._mixing is None:
                n = len(grads_peers)
                avg = jax.tree.map(
                    lambda *xs: sum(x.astype(jnp.float32) for x in xs) / n,
                    *grads_peers.values(),
                )
            else:
                # CSR-backed per-row weights — bit-equal to the dense
                # matrix row, no P x P materialization on the hot path
                w = self.graph.mixing_row(peer.rank)
                ranks = sorted(grads_peers)
                total = float(sum(w[j] for j in ranks))
                avg = jax.tree.map(
                    lambda *xs: sum(
                        float(w[j]) * x.astype(jnp.float32)
                        for j, x in zip(ranks, xs)
                    )
                    / total,
                    *[grads_peers[j] for j in ranks],
                )
            self._apply_avg(peer, avg, lr)

    def _apply_avg(self, peer: PeerState, avg, lr: float):
        """Step the peer's optimizer with an already-mixed gradient."""
        peer.params, peer.opt_state = self._apply(
            peer.params, peer.opt_state, avg, jnp.float32(lr)
        )
        jax.block_until_ready(jax.tree.leaves(peer.params))
        peer.steps_done += 1

    def _sharded_exchange_sync(self, grads: Dict[int, Any], epoch: int):
        """Shard-addressed exchange (reduce_scatter host image, SPIRT-style).

        Three phases over the mailbox, shards — not pytrees — on the wire:

        1. **scatter** — each peer splits its gradient into P contiguous
           shards (:class:`~repro.core.shard.ShardPlan`) and publishes one
           *piece* message per foreign shard owner (``shard=("piece", j)``).
        2. **aggregate** — owner ``j`` consumes only the pieces of ITS
           shard, reduces ``model/P`` elements per contribution (the
           O(model) -> O(model/P) cut), and re-broadcasts the aggregated
           shard (``shard=("agg",)``). When a serverless executor is
           attached, the P concurrent aggregator invocations are priced on
           the runtime engine with memory sized from shard bytes.
        3. **gather** — every peer consumes the P-1 foreign aggregated
           shards, reassembles the buffer in shard-index order, unflattens
           to the global mean, and steps its optimizer.
        """
        plan, P = self.shard_plan, self.num_peers
        # -- phase 1: scatter shard pieces ---------------------------------
        rows: Dict[int, Any] = {}
        for peer in self.peers:
            r = peer.rank
            with peer.metrics.stage("send_gradients"):
                shard_rows = plan.shards(grads[r])  # (P, S)
                jax.block_until_ready(shard_rows)
                rows[r] = shard_rows
                for j in range(P):
                    if j == r:
                        continue  # own piece never leaves the peer
                    payload, nbytes = self.protocol.host_encode_shard(
                        shard_rows[j], self.xctx
                    )
                    wire_s = self.link.transfer_s(nbytes)
                    self.mailbox.publish(
                        r, payload, nbytes=nbytes, time=wire_s, epoch=epoch,
                        shard=("piece", j),
                    )
                    peer.comm_bytes_sent += nbytes
                    peer.send_time_s += wire_s
        # -- phase 2: owners aggregate their shard, re-broadcast -----------
        agg_rows: Dict[int, Any] = {}
        per_shard_s: List[float] = []
        for peer in self.peers:
            r = peer.rank
            with peer.metrics.stage("receive_gradients"):
                pieces = [rows[r][r].astype(jnp.float32)]
                for other in range(P):
                    if other == r:
                        continue
                    msg = self.mailbox.consume(
                        other, consumer=r, shard=("piece", r)
                    )
                    peer.recv_time_s += self.mailbox.download_time_s(
                        msg, link=self.link
                    )
                    pieces.append(
                        self.protocol.host_decode_shard(msg.payload, self.xctx)
                    )
            t0 = time.perf_counter()
            agg = sum(pieces[1:], pieces[0]) / P
            jax.block_until_ready(agg)
            per_shard_s.append(time.perf_counter() - t0)
            agg_rows[r] = agg
            with peer.metrics.stage("send_gradients"):
                payload, nbytes = self.protocol.host_encode_shard(agg, self.xctx)
                wire_s = self.link.transfer_s(nbytes)
                self.mailbox.publish(
                    r, payload, nbytes=nbytes, time=wire_s, epoch=epoch,
                    shard=("agg",),
                )
                peer.comm_bytes_sent += nbytes
                peer.send_time_s += wire_s
        if self.executor is not None and self.executor.backend == "serverless":
            self.aggregation_reports.append(
                self.executor.simulate_aggregation(
                    per_shard_s,
                    shard_bytes=plan.shard_bytes(self.xctx.wire_dtype),
                    num_contributions=P,
                    epoch=epoch,
                    link=self.link,
                )
            )
        # -- phase 3: reassemble the mean, step ----------------------------
        for peer in self.peers:
            r = peer.rank
            with peer.metrics.stage("receive_gradients"):
                bank = []
                for j in range(P):
                    if j == r:
                        bank.append(agg_rows[r])
                        continue
                    msg = self.mailbox.consume(j, consumer=r, shard=("agg",))
                    peer.recv_time_s += self.mailbox.download_time_s(
                        msg, link=self.link
                    )
                    bank.append(
                        self.protocol.host_decode_shard(msg.payload, self.xctx)
                    )
            avg = plan.unflatten(jnp.stack(bank))
            with peer.metrics.stage("model_update"):
                self._apply_avg(peer, avg, self.detector.lr)

    def _tree_exchange_sync(self, grads: Dict[int, Any], epoch: int):
        """Hierarchical tree exchange (``tree[:fanout]`` host image).

        Peers form the protocol's k-ary :class:`~repro.core.tree.TreePlan`
        (rank 0 = root, parent of ``i`` is ``(i-1)//k``) and run two
        sweeps over the mailbox, whole flattened buffers on the wire:

        1. **up-sweep** — deepest level first: every non-root peer
           publishes its partial sum (own gradient + consumed children)
           to its ``shard=("up",)`` register; each hub fans in at most
           ``fanout`` children instead of ``P - 1`` peers. The root
           divides the global sum by ``P``.
        2. **down-sweep** — root to leaves: each hub publishes the mean
           once to its ``shard=("down",)`` register and all its children
           read it (latest-wins broadcast: one upload per hub, one
           download per child).

        When a serverless executor is attached, each level's hub
        aggregations are priced as one parallel invocation wave with
        memory sized from buffer bytes — the per-level egress/wire
        accounting the fig11 benchmark reads out.
        """
        plan, P = self.shard_plan, self.num_peers
        tp = self.protocol.tree_plan(P)
        partial: Dict[int, Any] = {}
        # -- up-sweep: children publish partials, hubs fan in --------------
        for level in range(tp.depth - 1, -1, -1):
            start, stop = tp.level_bounds(level)
            per_hub_s: List[float] = []
            for r in range(start, stop):
                peer = self.peers[r]
                kids = tp.children(r)
                t0 = time.perf_counter()
                acc = plan.flatten(grads[r]).astype(jnp.float32)
                with peer.metrics.stage("receive_gradients"):
                    for c in kids:
                        msg = self.mailbox.consume(c, consumer=r, shard=("up",))
                        peer.recv_time_s += self.mailbox.download_time_s(
                            msg, link=self.link
                        )
                        acc = acc + self.protocol.host_decode_shard(
                            msg.payload, self.xctx
                        )
                jax.block_until_ready(acc)
                if kids:
                    per_hub_s.append(time.perf_counter() - t0)
                partial[r] = acc
                if r != 0:
                    with peer.metrics.stage("send_gradients"):
                        payload, nbytes = self.protocol.host_encode_shard(
                            acc, self.xctx
                        )
                        wire_s = self.link.transfer_s(nbytes)
                        self.mailbox.publish(
                            r, payload, nbytes=nbytes, time=wire_s,
                            epoch=epoch, shard=("up",),
                        )
                        peer.comm_bytes_sent += nbytes
                        peer.send_time_s += wire_s
            if (
                per_hub_s
                and self.executor is not None
                and self.executor.backend == "serverless"
            ):
                # one parallel aggregation wave per hub level
                self.aggregation_reports.append(
                    self.executor.simulate_aggregation(
                        per_hub_s,
                        shard_bytes=plan.padded_size
                        * jnp.dtype(self.xctx.wire_dtype).itemsize,
                        num_contributions=tp.fanout + 1,
                        epoch=epoch,
                        link=self.link,
                    )
                )
        # -- down-sweep: hubs relay the mean toward the leaves -------------
        down: Dict[int, Any] = {0: partial[0] / P}
        for level in range(tp.depth):
            start, stop = tp.level_bounds(level)
            for r in range(start, stop):
                peer = self.peers[r]
                if r != 0:
                    with peer.metrics.stage("receive_gradients"):
                        msg = self.mailbox.consume(
                            tp.parent(r), consumer=r, shard=("down",)
                        )
                        peer.recv_time_s += self.mailbox.download_time_s(
                            msg, link=self.link
                        )
                        down[r] = self.protocol.host_decode_shard(
                            msg.payload, self.xctx
                        )
                if tp.children(r):
                    with peer.metrics.stage("send_gradients"):
                        payload, nbytes = self.protocol.host_encode_shard(
                            down[r], self.xctx
                        )
                        wire_s = self.link.transfer_s(nbytes)
                        self.mailbox.publish(
                            r, payload, nbytes=nbytes, time=wire_s,
                            epoch=epoch, shard=("down",),
                        )
                        peer.comm_bytes_sent += nbytes
                        peer.send_time_s += wire_s
        for peer in self.peers:
            avg = plan.unflatten(down[peer.rank])
            with peer.metrics.stage("model_update"):
                self._apply_avg(peer, avg, self.detector.lr)

    def comm_cost(self, *, usd_per_gb: float = 0.0) -> CommCost:
        """Per-step wire cost of one peer under protocol + overlay graph.

        Degree-aware and on the same ``per_edge x degree`` convention as
        ``P2PTrainer.comm_cost`` — O(degree) for sparse overlays, O(P)
        for the full mesh. (The cluster's simulated link additionally
        charges one publish per step — ``_publish`` — on top of the
        degree-many downloads counted here.)
        """
        grads_like = jax.eval_shape(lambda p: p, self.peers[0].params)
        if self.protocol.sharded:
            # Shard-addressed: per-edge payload is one shard. The per-step
            # total is the protocol's own accounting — 2(P-1) x shard,
            # which on the host path is exactly the peer's DOWNLOAD count
            # (P-1 pieces in the aggregate phase + P-1 foreign aggregated
            # shards in the gather phase), the same receive-side
            # convention as the dense branch below; publish uploads
            # (host_wire_bytes = P x shard) are charged separately per
            # publish, as for dense protocols.
            return CommCost(
                wire_bytes_per_step=self.protocol.wire_bytes(
                    grads_like, self.xctx
                ),
                bandwidth_bps=self.bw,
                usd_per_gb_egress=usd_per_gb,
                bytes_per_edge=self.protocol.wire_bytes_per_edge(
                    grads_like, self.xctx
                ),
                degree=self.xctx.degree,
                graph_name=self.graph.name,
                num_shards=self.shard_plan.num_shards,
                shard_bytes=self.shard_plan.shard_bytes(self.xctx.wire_dtype),
            )
        per_edge = self.protocol.host_wire_bytes(grads_like, self.xctx)
        return CommCost(
            wire_bytes_per_step=int(round(per_edge * self.xctx.degree)),
            bandwidth_bps=self.bw,
            usd_per_gb_egress=usd_per_gb,
            bytes_per_edge=per_edge,
            degree=self.xctx.degree,
            graph_name=self.graph.name,
        )

    def evaluate(self, peer_rank: int = 0, *, num_batches: int = 2, epoch: int = 10_000):
        peer = self.peers[peer_rank]
        accs, losses = [], []
        with peer.metrics.stage("convergence_detection"):
            for i in range(num_batches):
                b = jax.tree.map(
                    jnp.asarray, peer.loader.load(BatchKey(peer.rank, epoch, i))
                )
                loss, acc = self._eval(peer.params, b)
                losses.append(float(loss))
                accs.append(float(acc))
        return float(np.mean(losses)), float(np.mean(accs))

    # ------------------------------------------------------------------
    def run_epoch_sync(self, epoch: int) -> Dict[str, float]:
        """One synchronous epoch: compute -> publish -> barrier -> consume -> update."""
        grads, stats = {}, []
        sharded = self.protocol.sharded
        for peer in self.peers:
            with peer.metrics.stage("compute_gradients"):
                g, loss, acc, wall = self._compute_peer_gradient(peer, epoch)
            grads[peer.rank] = g
            stats.append((loss, acc))
            if not sharded:
                # own contribution for the update phase: the decoded image
                # of the published payload under EF, the raw gradient else
                grads[peer.rank] = self._publish(peer, g, epoch, at_time=0.0)
            self.mailbox.barrier_signal(peer.rank, epoch)
        if not self.mailbox.barrier_complete(epoch):  # SynchronisationBarrier
            raise RuntimeError(
                f"synchronisation barrier incomplete for epoch {epoch}: not "
                f"every peer signalled completion before the consume phase"
            )
        self.mailbox.barrier_reset(epoch)
        if sharded and self.protocol.hierarchical:
            self._tree_exchange_sync(grads, epoch)
        elif sharded:
            self._sharded_exchange_sync(grads, epoch)
        else:
            for peer in self.peers:
                gp, _ = self._consume_all(peer, grads[peer.rank], at_time=None)
                self._update(peer, gp, self.detector.lr)
        loss = float(np.mean([s[0] for s in stats]))
        acc = float(np.mean([s[1] for s in stats]))
        return {"loss": loss, "acc": acc}

    def run_epoch_async(self, epoch: int) -> Dict[str, float]:
        """Async epoch on the event engine: no barrier, stale gradients allowed.

        Events fire in ``(virtual time, rank)`` order — identical to the
        legacy heapq loop when churn is off. With ``churn_prob > 0`` a peer
        may drop mid-step (SPIRT-style): the partial work is lost, the peer
        rejoins ``churn_downtime_s`` later and redoes the step, while other
        peers keep consuming its last published (stale) gradient.
        """
        engine = EventEngine(rng=self._rng, tracer=self.tracer)
        engine.now = min((p.clock for p in self.peers), default=0.0)
        stats = []
        order = self.last_event_order = []

        def schedule_peer(peer: PeerState):
            cache: Dict[str, Any] = {}

            def compute_fire():
                order.append(peer.rank)
                with peer.metrics.stage("compute_gradients"):
                    g, loss, acc, wall = self._compute_peer_gradient(peer, epoch)
                cache.update(g=g, loss=loss, acc=acc, wall=wall, attempts=0)
                attempt_fire()

            def attempt_fire():
                sim_wall = cache["wall"] * peer.speed
                cache["attempts"] += 1
                if (
                    self.churn_prob > 0.0
                    and cache["attempts"] <= 5  # then forcibly stay up
                    and engine.rng.random() < self.churn_prob
                ):
                    # dropped mid-compute: partial work lost, rejoin later
                    lost = sim_wall * engine.rng.random() + self.churn_downtime_s
                    peer.clock += lost
                    peer.drops += 1
                    peer.downtime_s += lost
                    engine.schedule_at(peer.clock, attempt_fire, priority=peer.rank)
                    return
                peer.clock += sim_wall
                own = self._publish(peer, cache["g"], epoch, at_time=peer.clock)
                gp, recv_wire_s = self._consume_all(
                    peer, own, at_time=peer.clock
                )
                peer.clock += recv_wire_s
                self._update(peer, gp, self.detector.lr)
                stats.append((cache["loss"], cache["acc"]))

            engine.schedule_at(peer.clock, compute_fire, priority=peer.rank)

        for peer in self.peers:
            schedule_peer(peer)
        engine.run()
        loss = float(np.mean([s[0] for s in stats]))
        acc = float(np.mean([s[1] for s in stats]))
        return {"loss": loss, "acc": acc}

    def run(self, epochs: int, *, eval_every: int = 1) -> List[Dict[str, float]]:
        history = []
        for e in range(epochs):
            rec = self.run_epoch_sync(e) if self.sync else self.run_epoch_async(e)
            if (e + 1) % eval_every == 0:
                vloss, vacc = self.evaluate(epoch=10_000 + e)
                rec.update(val_loss=vloss, val_acc=vacc)
                if self.detector.step(vacc):
                    history.append({**rec, "epoch": e, "converged": True})
                    break
            history.append({**rec, "epoch": e})
        return history
