"""Multi-level tree aggregation — hierarchical reduce through hub peers.

Flat aggregation concentrates fan-in: under ``allgather_mean`` every peer
downloads ``P - 1`` gradients per step, and under ``reduce_scatter`` every
shard owner still fans in ``P - 1`` pieces in one round. SPIRT
(arXiv:2309.14148) and LambdaML (arXiv:2105.07806) both identify exactly
this per-peer coordination fan-in as the serverless scaling bottleneck.

``tree[:fanout]`` bounds it. Peers form an implicit k-ary heap-indexed
aggregation tree (:class:`TreePlan`): rank 0 is the root, rank ``i``'s
parent is ``(i - 1) // k``. One step runs two sweeps over the mailbox:

* **up-sweep** — leaves publish their gradient buffer; each hub consumes
  its ≤ k children's partial sums, adds its own gradient, and publishes
  ONE partial up. After ``depth - 1`` levels the root holds the global
  sum and divides by ``P``.
* **down-sweep** — the mean relays root → leaves: each hub publishes one
  latest-wins register its children read, so a broadcast costs one
  upload per hub regardless of fanout.

Per-peer per-round fan-in is ``fanout`` instead of ``P - 1``, and no peer
uploads more than 2 buffers (one up, one down relay) — the hub bottleneck
of flat aggregation becomes ``O(log_k P)`` rounds of bounded-degree
traffic. Total wire stays ``2 (P - 1)`` buffer messages (information flow
is conserved; the accounting methods are honest about this).

The buffer layout rides the PR-4 :class:`~repro.core.shard.ShardPlan`
machinery: :class:`TreeAggregate` subclasses ``reduce_scatter`` to
inherit its plan / shard-wire codec (the sharded-surface contract RC008),
and the up/down payloads are the plan's flattened padded buffer encoded
with the same ``host_encode_shard`` wire cast.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
from jax import lax

from repro.core.exchange import ReduceScatterMean, register_exchange


@dataclass(frozen=True)
class TreePlan:
    """Static k-ary heap-indexed aggregation tree over ``num_peers`` ranks.

    Rank ``i``'s parent is ``(i - 1) // fanout``; its children are
    ``fanout * i + 1 .. fanout * i + fanout`` (clipped to ``num_peers``).
    Level ``l`` spans ranks ``[(k^l - 1) / (k - 1), (k^{l+1} - 1) / (k - 1))``
    — contiguous, so a level is a range, not a list.
    """

    num_peers: int
    fanout: int

    def __post_init__(self):
        if self.num_peers < 1:
            raise ValueError(f"num_peers must be >= 1, got {self.num_peers}")
        if self.fanout < 2:
            raise ValueError(
                f"tree fanout must be >= 2, got {self.fanout} "
                "(a 1-ary tree is a chain with O(P) depth)"
            )

    # -- structure -----------------------------------------------------------
    def parent(self, rank: int) -> Optional[int]:
        """Parent rank, or ``None`` for the root."""
        self._check(rank)
        return None if rank == 0 else (rank - 1) // self.fanout

    def children(self, rank: int) -> range:
        """This rank's children (possibly empty, at most ``fanout``)."""
        self._check(rank)
        lo = self.fanout * rank + 1
        return range(min(lo, self.num_peers),
                     min(lo + self.fanout, self.num_peers))

    def child_slot(self, rank: int) -> int:
        """Which of its parent's ``fanout`` slots this (non-root) rank fills."""
        self._check(rank)
        if rank == 0:
            raise ValueError("the root fills no child slot")
        return (rank - 1) % self.fanout

    def level_of(self, rank: int) -> int:
        """Depth of ``rank`` (root = 0)."""
        self._check(rank)
        level = 0
        while rank > 0:
            rank = (rank - 1) // self.fanout
            level += 1
        return level

    @property
    def depth(self) -> int:
        """Number of levels (1 for a single peer)."""
        return self.level_of(self.num_peers - 1) + 1

    def level_bounds(self, level: int) -> Tuple[int, int]:
        """Rank range ``[start, stop)`` of one level (clipped to P)."""
        if not 0 <= level < self.depth:
            raise IndexError(f"level {level} out of range [0, {self.depth})")
        k = self.fanout
        start = (k ** level - 1) // (k - 1)
        stop = (k ** (level + 1) - 1) // (k - 1)
        return min(start, self.num_peers), min(stop, self.num_peers)

    def levels(self) -> List[range]:
        """All levels, root first."""
        return [range(*self.level_bounds(l)) for l in range(self.depth)]

    @property
    def num_hubs(self) -> int:
        """Interior nodes — the ranks that aggregate children."""
        return sum(1 for r in range(self.num_peers) if len(self.children(r)))

    def _check(self, rank: int):
        if not 0 <= rank < self.num_peers:
            raise IndexError(
                f"rank {rank} out of range [0, {self.num_peers})"
            )

    def describe(self) -> str:
        return (
            f"TreePlan(P={self.num_peers}, fanout={self.fanout}, "
            f"depth={self.depth}, hubs={self.num_hubs})"
        )


@register_exchange("tree")
class TreeAggregate(ReduceScatterMean):
    """Hierarchical k-ary tree mean: bounded fan-in, O(log_k P) rounds.

    ``tree`` / ``tree:4`` — the parameter is the tree fanout (default 2).
    Same estimator as ``allgather_mean`` / ``reduce_scatter`` (the exact
    peer mean, modulo float re-association along tree edges — ≤1e-6 on
    the equivalence rail), different traffic shape: every peer talks to
    at most ``fanout + 1`` others per step instead of ``P - 1``.

    Device path: masked ``ppermute`` up/down sweeps over the flattened
    :class:`~repro.core.shard.ShardPlan` buffer — children forward
    partial sums to parents one level at a time (one collective per
    (level, child-slot) pair, so each permute is a valid one-to-one map),
    the root divides by ``P``, and the mean relays back down.

    Host image: :meth:`LocalP2PCluster._tree_exchange_sync` — hubs are
    mailbox registers, each level's aggregations price as one parallel
    serverless wave sized from buffer bytes.

    The shard layout is inherently global (the root's sum covers ALL
    peers), so sparse overlays are refused, like ``reduce_scatter``.
    """

    requires_full_graph = True
    sharded = True
    hierarchical = True

    def __init__(self, param: Optional[str] = None):
        self.fanout = 2 if param is None else int(param)
        if self.fanout < 2:
            raise ValueError(
                f"tree fanout must be >= 2, got {self.fanout}"
            )
        self._plans: Dict[int, TreePlan] = {}

    def tree_plan(self, num_peers: int) -> TreePlan:
        """The (cached) aggregation tree for this peer count."""
        plan = self._plans.get(num_peers)
        if plan is None:
            plan = self._plans[num_peers] = TreePlan(
                max(int(num_peers), 1), self.fanout
            )
        return plan

    def _check_full(self, ctx):
        if ctx.mixing is not None:
            raise ValueError(
                "tree aggregation reduces over ALL peers through hub "
                "ranks and the protocol only supports graph='full'; use "
                "allgather_mean (or qsgd/topk) for sparse overlays"
            )

    # -- device path ---------------------------------------------------------
    def combine(self, grads, ctx, *, key=None, state=None):
        self._check_full(ctx)
        P_ = int(ctx.num_peers)
        plan = self.plan(grads, ctx)
        acc = plan.flatten(grads).astype(jnp.float32)
        if P_ == 1:
            return plan.unflatten(acc), state
        tp = self.tree_plan(P_)
        r = lax.axis_index(ctx.axis)
        # Up-sweep, deepest level first: children forward their finalized
        # partial to the parent. Grouping the sends of one level by child
        # slot makes each ppermute a one-to-one map (a parent receives
        # from exactly one slot-s child); ranks outside the pairs receive
        # zeros, so a plain add is a no-op for them.
        for level in range(tp.depth - 1, 0, -1):
            start, stop = tp.level_bounds(level)
            for slot in range(tp.fanout):
                pairs = [
                    (i, (i - 1) // tp.fanout)
                    for i in range(start, stop)
                    if (i - 1) % tp.fanout == slot
                ]
                if not pairs:
                    continue
                recv = lax.ppermute(
                    acc.astype(ctx.wire_dtype), ctx.axis, pairs
                )
                acc = acc + recv.astype(jnp.float32)
        acc = acc / P_  # the root now holds the global mean; others, partials
        # Down-sweep: each level's parents relay the mean to their children.
        for level in range(tp.depth - 1):
            nstart, nstop = tp.level_bounds(level + 1)
            for slot in range(tp.fanout):
                pairs = [
                    ((i - 1) // tp.fanout, i)
                    for i in range(nstart, nstop)
                    if (i - 1) % tp.fanout == slot
                ]
                if not pairs:
                    continue
                recv = lax.ppermute(
                    acc.astype(ctx.wire_dtype), ctx.axis, pairs
                )
                targets = jnp.asarray([t for _, t in pairs])
                acc = jnp.where(
                    jnp.any(r == targets), recv.astype(jnp.float32), acc
                )
        return plan.unflatten(acc), state

    # -- accounting ----------------------------------------------------------
    def wire_bytes_per_edge(self, grads_like, ctx) -> int:
        """One tree hop carries the WHOLE flattened buffer (a partial sum
        is as dense as the model), not a 1/P shard."""
        plan = self.plan(grads_like, ctx)
        return plan.padded_size * jnp.dtype(ctx.wire_dtype).itemsize

    def wire_bytes(self, grads_like, ctx) -> int:
        """Total tree traffic per step: P-1 up messages + P-1 down relays.

        Same order as flat aggregation — a tree conserves information
        flow; what it cuts is the per-peer fan-in (``fanout`` vs ``P-1``
        downloads per round) and the hub upload (≤ 2 buffers per peer
        regardless of P).
        """
        P_ = max(int(ctx.num_peers), 1)
        return 2 * (P_ - 1) * self.wire_bytes_per_edge(grads_like, ctx)

    def host_wire_bytes(self, grads_like, ctx) -> int:
        """Mailbox publishes per peer per step: at most one partial up
        plus one down relay (leaves publish 1, the root publishes 1)."""
        return 2 * self.wire_bytes_per_edge(grads_like, ctx)
