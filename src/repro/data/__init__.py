from repro.data.pipeline import (
    Dataset,
    make_dataset,
    Partitioner,
    DataLoader,
    BatchKey,
)

__all__ = ["Dataset", "make_dataset", "Partitioner", "DataLoader", "BatchKey"]
