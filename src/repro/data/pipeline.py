"""Deterministic data pipeline with the paper's S3-style batch addressing.

The paper preprocesses the dataset, partitions it per peer, splits each
partition into batches and uploads every batch to S3 under a key the Lambda
workers fetch. We reproduce the *addressing scheme* — every batch is
reachable by ``BatchKey(peer, epoch, index)`` and is a pure function of
(dataset seed, key) — with procedural datasets, since the container is
offline:

* ``mnist`` / ``cifar`` — class-template images + Gaussian noise, matching
  the shapes/statistics of the real datasets (28x28x1 / 32x32x3, 10 classes,
  60k train). Learnable by the paper's CNNs in a few hundred steps.
* ``lm`` — synthetic token streams with learnable bigram structure for the
  transformer architectures.

Preprocessing (min-max scaling / standardization / normalization, paper
§III-B.1) is applied at generation time.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class BatchKey:
    """The S3-object analogue: uniquely addresses one batch."""

    peer: int
    epoch: int
    index: int

    def s3_key(self, dataset: str) -> str:
        return f"{dataset}/peer={self.peer}/epoch={self.epoch}/batch={self.index:05d}.npz"


@dataclass(frozen=True)
class Dataset:
    name: str
    kind: str  # "image" | "lm"
    size: int
    image_hw: int = 0
    channels: int = 0
    num_classes: int = 0
    vocab_size: int = 0
    seq_len: int = 0
    seed: int = 0
    preprocessing: str = "standardize"  # minmax | standardize | none


def make_dataset(name: str, **overrides) -> Dataset:
    presets = {
        "mnist": Dataset("mnist", "image", 60_000, image_hw=28, channels=1, num_classes=10),
        "cifar": Dataset("cifar", "image", 60_000, image_hw=32, channels=3, num_classes=10),
        "lm": Dataset("lm", "lm", 1_000_000, vocab_size=512, seq_len=128),
    }
    if name not in presets:
        raise KeyError(f"unknown dataset {name!r}")
    return dataclasses.replace(presets[name], **overrides)


# ---------------------------------------------------------------------------
# Procedural sample generation
# ---------------------------------------------------------------------------


def _class_templates(ds: Dataset) -> np.ndarray:
    rng = np.random.default_rng(ds.seed + 7)
    t = rng.normal(0, 1, (ds.num_classes, ds.image_hw, ds.image_hw, ds.channels))
    # smooth templates so they have low-frequency, learnable structure
    for _ in range(2):
        t = 0.5 * t + 0.125 * (
            np.roll(t, 1, 1) + np.roll(t, -1, 1) + np.roll(t, 1, 2) + np.roll(t, -1, 2)
        )
    # renormalize to unit per-template std so the class signal survives noise
    t = t / (t.std(axis=(1, 2, 3), keepdims=True) + 1e-9)
    return t.astype(np.float32)


_TEMPLATE_CACHE: Dict[Tuple, np.ndarray] = {}


def generate_images(ds: Dataset, indices: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Pure function of (dataset, indices) -> (images, labels)."""
    ck = (ds.name, ds.seed, ds.image_hw, ds.channels, ds.num_classes)
    if ck not in _TEMPLATE_CACHE:
        _TEMPLATE_CACHE[ck] = _class_templates(ds)
    templates = _TEMPLATE_CACHE[ck]
    labels = (indices * 2654435761 % ds.num_classes).astype(np.int32)
    imgs = np.empty((len(indices), ds.image_hw, ds.image_hw, ds.channels), np.float32)
    for i, (idx, lab) in enumerate(zip(indices, labels)):
        rng = np.random.default_rng(ds.seed * 1_000_003 + int(idx))
        imgs[i] = templates[lab] + rng.normal(0, 0.5, templates[lab].shape)
    if ds.preprocessing == "minmax":
        lo, hi = imgs.min(), imgs.max()
        imgs = (imgs - lo) / max(hi - lo, 1e-9)
    elif ds.preprocessing == "standardize":
        imgs = (imgs - imgs.mean()) / max(imgs.std(), 1e-9)
    return imgs, labels


def generate_tokens(ds: Dataset, indices: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Synthetic LM sequences with a fixed random bigram transition table."""
    rng0 = np.random.default_rng(ds.seed + 13)
    # sparse deterministic "grammar": each token has 4 likely successors
    succ = rng0.integers(0, ds.vocab_size, (ds.vocab_size, 4))
    toks = np.empty((len(indices), ds.seq_len + 1), np.int32)
    for i, idx in enumerate(indices):
        rng = np.random.default_rng(ds.seed * 999_983 + int(idx))
        seq = np.empty(ds.seq_len + 1, np.int32)
        seq[0] = rng.integers(0, ds.vocab_size)
        choices = rng.integers(0, 4, ds.seq_len)
        noise = rng.random(ds.seq_len) < 0.1
        rand_toks = rng.integers(0, ds.vocab_size, ds.seq_len)
        for t in range(ds.seq_len):
            seq[t + 1] = rand_toks[t] if noise[t] else succ[seq[t], choices[t]]
        toks[i] = seq
    return toks[:, :-1], toks[:, 1:]


# ---------------------------------------------------------------------------
# Partitioning & loading (paper §III-B.1)
# ---------------------------------------------------------------------------


class Partitioner:
    """Disjoint, exhaustive split of the dataset across P peers."""

    def __init__(self, ds: Dataset, num_peers: int, *, shuffle_seed: int = 0):
        self.ds = ds
        self.num_peers = num_peers
        rng = np.random.default_rng(shuffle_seed)
        self._perm = rng.permutation(ds.size)

    def partition(self, peer: int) -> np.ndarray:
        if not (0 <= peer < self.num_peers):
            raise IndexError(peer)
        per = self.ds.size // self.num_peers
        return self._perm[peer * per : (peer + 1) * per]


class DataLoader:
    """Batches one peer's partition; every batch addressable by BatchKey."""

    def __init__(
        self,
        partitioner: Partitioner,
        peer: int,
        batch_size: int,
        *,
        drop_remainder: bool = True,
    ):
        self.part = partitioner.partition(peer)
        self.ds = partitioner.ds
        self.peer = peer
        self.batch_size = batch_size
        self.num_batches = (
            len(self.part) // batch_size
            if drop_remainder
            else -(-len(self.part) // batch_size)
        )

    def batch_indices(self, key: BatchKey) -> np.ndarray:
        rng = np.random.default_rng((self.ds.seed, key.peer, key.epoch))
        order = rng.permutation(len(self.part))
        sel = order[key.index * self.batch_size : (key.index + 1) * self.batch_size]
        return self.part[sel]

    def load(self, key: BatchKey) -> Dict[str, np.ndarray]:
        idx = self.batch_indices(key)
        if self.ds.kind == "image":
            x, y = generate_images(self.ds, idx)
            return {"images": x, "labels": y}
        x, y = generate_tokens(self.ds, idx)
        return {"tokens": x, "labels": y}

    def epoch(self, epoch: int) -> Iterator[Dict[str, np.ndarray]]:
        for i in range(self.num_batches):
            yield self.load(BatchKey(self.peer, epoch, i))
