"""Pallas TPU kernels for the framework's compute hot-spots.

  qsgd.py            — QSGD quantize/dequantize + fused decode-reduce (§III-B.4)
  topk.py            — top-k select+pack / fused scatter-accumulate decode
  ssd_scan.py        — Mamba-2 chunked SSD scan (SSM archs' hot loop)
  flash_attention.py — blocked online-softmax attention forward
  ops.py             — jit'd public wrappers (interpret on CPU, compiled on TPU)
  ref.py             — pure-jnp oracles every kernel is validated against
"""
from repro.kernels import ops

__all__ = ["ops"]
