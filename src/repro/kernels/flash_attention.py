"""Flash attention (forward) Pallas TPU kernel with GQA, causal masking,
sliding window and logit softcap.

Layout: q (B, H, nq, Qb, D), k/v (B, K, nk, Kb, D); grid (B, H, nq, nk) with
the KV block index innermost — sequential on TPU, so the online-softmax
running state (m, l, acc) lives in VMEM scratch across KV steps. Block sizes
default to 512x512 (MXU-aligned; D is the lane dim and must be >= 128-friendly,
padded if needed by the wrapper).

Causal + window masks are computed from global positions reconstructed with
iota off the block indices — no mask tensors in HBM.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
    *, scale: float, causal: bool, softcap: float, window: int,
    block_q: int, block_kv: int, nk: int, kv_len: int,
):
    kv_idx = pl.program_id(3)
    q_idx = pl.program_id(2)

    @pl.when(kv_idx == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0, 0].astype(jnp.float32) * scale  # (Qb, D)
    k = k_ref[0, 0, 0].astype(jnp.float32)  # (Kb, D)
    v = v_ref[0, 0, 0].astype(jnp.float32)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (Qb, Kb)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap

    q_pos = q_idx * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    k_pos = kv_idx * block_kv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    valid = k_pos < kv_len
    if causal:
        rel = q_pos - k_pos
        valid &= rel >= 0
        if window:
            valid &= rel < window
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(kv_idx == nk - 1)
    def _():
        o_ref[0, 0, 0] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "softcap", "window", "block_q", "block_kv", "interpret"),
)
def flash_attention(
    q: jnp.ndarray,  # (B, Sq, H, D)
    k: jnp.ndarray,  # (B, Skv, K, D)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    softcap: float = 0.0,
    window: int = 0,
    block_q: int = 512,
    block_kv: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    B, Sq, H, D = q.shape
    _, Skv, K, _ = k.shape
    rep = H // K
    scale = 1.0 / math.sqrt(D)

    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Skv)
    pad_q = (-Sq) % block_q
    pad_kv = (-Skv) % block_kv
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
    nq = (Sq + pad_q) // block_q
    nk = (Skv + pad_kv) // block_kv

    qk = q.transpose(0, 2, 1, 3).reshape(B, H, nq, block_q, D)
    kk = k.transpose(0, 2, 1, 3).reshape(B, K, nk, block_kv, D)
    vk = v.transpose(0, 2, 1, 3).reshape(B, K, nk, block_kv, D)

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel,
            scale=scale, causal=causal, softcap=softcap, window=window,
            block_q=block_q, block_kv=block_kv, nk=nk, kv_len=Skv,
        ),
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0, 0)),
            pl.BlockSpec((1, 1, 1, block_kv, D), lambda b, h, i, j: (b, h // rep, j, 0, 0)),
            pl.BlockSpec((1, 1, 1, block_kv, D), lambda b, h, i, j: (b, h // rep, j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, nq, block_q, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(qk, kk, vk)
    out = out.reshape(B, H, Sq + pad_q, D).transpose(0, 2, 1, 3)[:, :Sq]
    return out
