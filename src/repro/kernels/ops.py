"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU (this container validates kernels in
interpret mode on CPU; on a real TPU backend the compiled kernels run).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import qsgd as _qsgd
from repro.kernels import ssd_scan as _ssd
from repro.kernels import topk as _topk


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def qsgd_quantize(buckets: jnp.ndarray, u: jnp.ndarray, s: int):
    return _qsgd.qsgd_quantize(buckets, u, s, interpret=default_interpret())


def qsgd_dequantize(levels: jnp.ndarray, norms: jnp.ndarray, s: int):
    return _qsgd.qsgd_dequantize(levels, norms, s, interpret=default_interpret())


def qsgd_dequant_reduce(
    levels: jnp.ndarray, norms: jnp.ndarray, w: jnp.ndarray, s: int
):
    """Fused decode: (P, nb, B) int8 banks -> weighted dense sum (nb, B) f32."""
    return _qsgd.qsgd_dequant_reduce(levels, norms, w, s, interpret=default_interpret())


def topk_select_pack(x: jnp.ndarray, k: int):
    return _topk.topk_select_pack(x, k, interpret=default_interpret())


def topk_scatter_accum(vals: jnp.ndarray, idx: jnp.ndarray, w: jnp.ndarray, n: int):
    return _topk.topk_scatter_accum(vals, idx, w, n, interpret=default_interpret())


def ssd_scan(
    x: jnp.ndarray,
    dt: jnp.ndarray,
    A: jnp.ndarray,
    Bm: jnp.ndarray,
    Cm: jnp.ndarray,
    *,
    chunk: int = 256,
) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    y = _ssd.ssd_scan_pallas(x, dt, A, Bm, Cm, chunk=chunk, interpret=default_interpret())
    return y, None


def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    softcap: float = 0.0,
    window: int = 0,
    block_q: int = 512,
    block_kv: int = 512,
) -> jnp.ndarray:
    return _fa.flash_attention(
        q, k, v,
        causal=causal, softcap=softcap, window=window,
        block_q=block_q, block_kv=block_kv,
        interpret=default_interpret(),
    )
