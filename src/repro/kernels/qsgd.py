"""QSGD quantize/dequantize Pallas TPU kernels.

The gradient tensor is pre-bucketed to (nb, BUCKET) f32. Each grid step
processes a (TILE_NB, BUCKET) tile resident in VMEM: one fp32 L2-norm
reduction per bucket row plus elementwise stochastic rounding — VPU work,
8x128-lane aligned (BUCKET is a multiple of 128, TILE_NB a multiple of 8).
Uniform randoms are passed in as an operand so the kernel is a pure function
(deterministic vs the oracle; on-chip PRNG would break bit-reproducibility
between interpret mode and the jnp reference).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_NB = 8  # bucket rows per grid step (sublane-aligned)


def _quantize_kernel(x_ref, u_ref, s_ref, lev_ref, nrm_ref):
    x = x_ref[...].astype(jnp.float32)  # (TILE_NB, BUCKET)
    u = u_ref[...].astype(jnp.float32)
    s = s_ref[0]
    norms = jnp.sqrt(jnp.sum(x * x, axis=-1))  # (TILE_NB,)
    safe = jnp.maximum(norms, 1e-30)[:, None]
    r = jnp.abs(x) / safe * s
    l = jnp.floor(r)
    xi = l + (u < (r - l)).astype(jnp.float32)
    lev = jnp.clip(xi, 0.0, s) * jnp.sign(x)
    lev_ref[...] = lev.astype(jnp.int8)
    nrm_ref[...] = norms.astype(jnp.float32)


def _dequantize_kernel(lev_ref, nrm_ref, s_ref, out_ref):
    lev = lev_ref[...].astype(jnp.float32)
    nrm = nrm_ref[...].astype(jnp.float32)
    out_ref[...] = lev * (nrm[:, None] / s_ref[0])


def _dequant_reduce_kernel(lev_ref, nrm_ref, w_ref, s_ref, out_ref):
    """Fused decode-dequantize-reduce over the gathered peer banks.

    One VMEM pass: every peer's int8 levels tile is dequantized and folded
    into the mixing-weighted sum without ever materializing the P dense
    fp32 gradients in HBM (the unfused path vmap-dequantizes all P banks,
    then reduces — P x the fp32 traffic).
    """
    lev = lev_ref[...].astype(jnp.float32)  # (P, TILE_NB, BUCKET)
    nrm = nrm_ref[...].astype(jnp.float32)  # (P, TILE_NB)
    w = w_ref[...].astype(jnp.float32)  # (P,)
    scale = (w[:, None] * nrm) / s_ref[0]  # (P, TILE_NB)
    out_ref[...] = jnp.sum(lev * scale[:, :, None], axis=0)


@functools.partial(jax.jit, static_argnames=("s", "interpret"))
def qsgd_quantize(buckets: jnp.ndarray, u: jnp.ndarray, s: int, *, interpret: bool = True):
    """buckets, u: (nb, BUCKET) f32 -> (levels int8 (nb, BUCKET), norms f32 (nb,))."""
    nb, bucket = buckets.shape
    assert bucket % 128 == 0, f"bucket {bucket} must be lane-aligned (128)"
    pad = (-nb) % TILE_NB
    if pad:
        buckets = jnp.pad(buckets, ((0, pad), (0, 0)))
        u = jnp.pad(u, ((0, pad), (0, 0)), constant_values=1.0)
    nbp = nb + pad
    grid = (nbp // TILE_NB,)
    s_arr = jnp.full((1,), float(s), jnp.float32)
    lev, nrm = pl.pallas_call(
        _quantize_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_NB, bucket), lambda i: (i, 0)),
            pl.BlockSpec((TILE_NB, bucket), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((TILE_NB, bucket), lambda i: (i, 0)),
            pl.BlockSpec((TILE_NB,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nbp, bucket), jnp.int8),
            jax.ShapeDtypeStruct((nbp,), jnp.float32),
        ],
        interpret=interpret,
    )(buckets, u, s_arr)
    return lev[:nb], nrm[:nb]


@functools.partial(jax.jit, static_argnames=("s", "interpret"))
def qsgd_dequantize(levels: jnp.ndarray, norms: jnp.ndarray, s: int, *, interpret: bool = True):
    """levels (nb, BUCKET) int8, norms (nb,) -> f32 (nb, BUCKET)."""
    nb, bucket = levels.shape
    assert bucket % 128 == 0
    pad = (-nb) % TILE_NB
    if pad:
        levels = jnp.pad(levels, ((0, pad), (0, 0)))
        norms = jnp.pad(norms, (0, pad))
    nbp = nb + pad
    s_arr = jnp.full((1,), float(s), jnp.float32)
    out = pl.pallas_call(
        _dequantize_kernel,
        grid=(nbp // TILE_NB,),
        in_specs=[
            pl.BlockSpec((TILE_NB, bucket), lambda i: (i, 0)),
            pl.BlockSpec((TILE_NB,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((TILE_NB, bucket), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nbp, bucket), jnp.float32),
        interpret=interpret,
    )(levels, norms, s_arr)
    return out[:nb]


@functools.partial(jax.jit, static_argnames=("s", "interpret"))
def qsgd_dequant_reduce(
    levels: jnp.ndarray,
    norms: jnp.ndarray,
    w: jnp.ndarray,
    s: int,
    *,
    interpret: bool = True,
):
    """Fused decode-dequantize-reduce over P gathered peer banks.

    levels (P, nb, BUCKET) int8, norms (P, nb) f32, w (P,) f32 mixing
    weights -> (nb, BUCKET) f32 = sum_p w[p] * dequantize(levels[p], norms[p]).
    Replaces the unfused vmap-dequantize-then-reduce path with a single
    VMEM pass per tile (the dense fp32 per-peer banks are never built).
    """
    P, nb, bucket = levels.shape
    assert bucket % 128 == 0
    assert norms.shape == (P, nb) and w.shape == (P,)
    pad = (-nb) % TILE_NB
    if pad:
        levels = jnp.pad(levels, ((0, 0), (0, pad), (0, 0)))
        norms = jnp.pad(norms, ((0, 0), (0, pad)))
    nbp = nb + pad
    s_arr = jnp.full((1,), float(s), jnp.float32)
    out = pl.pallas_call(
        _dequant_reduce_kernel,
        grid=(nbp // TILE_NB,),
        in_specs=[
            pl.BlockSpec((P, TILE_NB, bucket), lambda i: (0, i, 0)),
            pl.BlockSpec((P, TILE_NB), lambda i: (0, i)),
            pl.BlockSpec((P,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((TILE_NB, bucket), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nbp, bucket), jnp.float32),
        interpret=interpret,
    )(levels, norms, w.astype(jnp.float32), s_arr)
    return out[:nb]
