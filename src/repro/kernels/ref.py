"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

These are deliberately naive/sequential formulations — the ground truth the
kernels (run in interpret mode on CPU, compiled on TPU) are validated
against in tests/test_kernels.py.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

# ---------------------------------------------------------------------------
# QSGD (same math as repro.core.compression, re-exported for kernel tests)
# ---------------------------------------------------------------------------
from repro.core.compression import qsgd_quantize_ref, qsgd_dequantize_ref  # noqa: F401


def qsgd_dequant_reduce_ref(
    levels: jnp.ndarray,  # (P, nb, BUCKET) int8
    norms: jnp.ndarray,  # (P, nb) f32
    w: jnp.ndarray,  # (P,) f32 mixing weights
    s: int,
) -> jnp.ndarray:
    """Unfused decode: dequantize every peer bank, then weighted-reduce.

    This is the vmap-dequantize-then-reduce formulation the fused
    ``qsgd._dequant_reduce_kernel`` replaces — it materializes all P dense
    fp32 banks before reducing. Returns (nb, BUCKET) f32.
    """
    deq = jax.vmap(lambda l, n: qsgd_dequantize_ref(l, n, s))(levels, norms)
    return jnp.tensordot(w.astype(jnp.float32), deq, axes=(0, 0))


# ---------------------------------------------------------------------------
# Top-k sparsification (select+pack encode, scatter-accumulate decode)
# ---------------------------------------------------------------------------


def topk_select_ref(x: jnp.ndarray, k: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (n,) -> (values f32 (k,), indices int32 (k,)) of the k largest |x|."""
    flat = x.reshape(-1).astype(jnp.float32)
    _, idx = lax.top_k(jnp.abs(flat), k)
    return jnp.take(flat, idx), idx.astype(jnp.int32)


def topk_scatter_ref(
    vals: jnp.ndarray,  # (P, k) f32
    idx: jnp.ndarray,  # (P, k) int32
    w: jnp.ndarray,  # (P,) f32 mixing weights
    n: int,
) -> jnp.ndarray:
    """Weighted scatter-accumulate of P sparse banks into a dense (n,) f32."""
    contrib = vals.astype(jnp.float32) * w.astype(jnp.float32)[:, None]
    return (
        jnp.zeros((n,), jnp.float32)
        .at[idx.reshape(-1)]
        .add(contrib.reshape(-1))
    )


# ---------------------------------------------------------------------------
# SSD: naive per-timestep recurrence  h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t
# ---------------------------------------------------------------------------


def ssd_scan_ref(
    x: jnp.ndarray,  # (B, S, H, P)
    dt: jnp.ndarray,  # (B, S, H)
    A: jnp.ndarray,  # (H,)
    Bm: jnp.ndarray,  # (B, S, G, N)
    Cm: jnp.ndarray,  # (B, S, G, N)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sequential reference. Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    Bsz, S, H, Pd = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    f32 = jnp.float32
    Bh = jnp.repeat(Bm.astype(f32), rep, axis=2)  # (B,S,H,N)
    Ch = jnp.repeat(Cm.astype(f32), rep, axis=2)

    def step(h, inp):
        x_t, dt_t, B_t, C_t = inp  # (B,H,P), (B,H), (B,H,N), (B,H,N)
        decay = jnp.exp(dt_t * A.astype(f32))  # (B,H)
        h = h * decay[..., None, None] + jnp.einsum(
            "bhp,bhn->bhpn", x_t * dt_t[..., None], B_t
        )
        y_t = jnp.einsum("bhpn,bhn->bhp", h, C_t)
        return h, y_t

    h0 = jnp.zeros((Bsz, H, Pd, N), f32)
    xs = (
        x.astype(f32).swapaxes(0, 1),
        dt.astype(f32).swapaxes(0, 1),
        Bh.swapaxes(0, 1),
        Ch.swapaxes(0, 1),
    )
    hT, ys = lax.scan(step, h0, xs)
    return ys.swapaxes(0, 1), hT


# ---------------------------------------------------------------------------
# Attention: naive full-softmax causal attention
# ---------------------------------------------------------------------------


def attention_ref(
    q: jnp.ndarray,  # (B, S, H, D)
    k: jnp.ndarray,  # (B, S, K, D)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    softcap: float = 0.0,
    window: int = 0,
) -> jnp.ndarray:
    B, S, H, D = q.shape
    K = k.shape[2]
    G = H // K
    qf = q.astype(jnp.float32).reshape(B, S, K, G, D) / math.sqrt(D)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qf, k.astype(jnp.float32))
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    i = jnp.arange(S)
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= i[:, None] >= i[None, :]
        if window:
            mask &= i[:, None] - i[None, :] < window
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(B, S, H, D)
