"""Chunked SSD (Mamba-2) scan as a Pallas TPU kernel.

TPU adaptation of the SSD algorithm (arXiv:2405.21060 §6): the GPU version
leans on warp-level parallel prefix scans; on TPU we restructure the
computation around the MXU — each chunk is processed with dense
(chunk x chunk) and (chunk x state) matmuls, and the inter-chunk recurrence
is carried in a VMEM scratch accumulator across sequential grid steps
(the TPU grid is executed in order, which *is* the scan).

Grid: (B, H, num_chunks) — chunks innermost, so the state scratch carries
the running (P, N) state for one (batch, head) pair and is reset whenever a
new (b, h) pair begins.

Blocks (per grid step, all VMEM, f32):
  x   (Q, P)   Q = chunk (default 256, multiple of 8), P = headdim
  dt  (Q,)     B/C (Q, N) — group-mapped via the index_map (no repeat in HBM)
  L   (Q, Q)   intra-chunk decay matrix, built on the fly
  y   (Q, P)   output block
  state scratch (P, N)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, st_ref, *, nc: int):
    c_idx = pl.program_id(2)

    @pl.when(c_idx == 0)
    def _():
        st_ref[...] = jnp.zeros_like(st_ref)

    x = x_ref[0, 0, 0].astype(jnp.float32)  # (Q, P)
    dt = dt_ref[0, 0, 0].astype(jnp.float32)  # (Q,)
    A = a_ref[0]  # scalar decay rate for this head
    Bm = b_ref[0, 0, 0].astype(jnp.float32)  # (Q, N)
    Cm = c_ref[0, 0, 0].astype(jnp.float32)  # (Q, N)

    a = dt * A  # (Q,) log-decay
    cum = jnp.cumsum(a)  # inclusive
    # L[i, j] = exp(cum_i - cum_j) for i >= j else 0
    diff = cum[:, None] - cum[None, :]
    Q = x.shape[0]
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    # mask before exp (upper triangle would overflow; see models/ssm.py)
    Lmat = jnp.exp(jnp.where(ii >= jj, diff, -jnp.inf))

    xdt = x * dt[:, None]  # (Q, P)

    # intra-chunk (dual / "attention" form): (C B^T . L) @ xdt  -> MXU matmuls
    scores = jnp.dot(Cm, Bm.T, preferred_element_type=jnp.float32) * Lmat
    y = jnp.dot(scores, xdt, preferred_element_type=jnp.float32)

    # inter-chunk: contribution of the carried state
    state = st_ref[...]  # (P, N)
    decay_from_start = jnp.exp(cum)  # (Q,)
    y += jnp.dot(Cm, state.T, preferred_element_type=jnp.float32) * decay_from_start[:, None]

    # update the carried state: S <- exp(sum a) S + sum_j exp(cum_Q - cum_j) B_j xdt_j
    decay_to_end = jnp.exp(cum[-1] - cum)  # (Q,)
    new_state = jnp.dot(
        (xdt * decay_to_end[:, None]).T, Bm, preferred_element_type=jnp.float32
    )  # (P, N)
    st_ref[...] = state * jnp.exp(cum[-1]) + new_state

    y_ref[0, 0, 0] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_pallas(
    x: jnp.ndarray,  # (B, S, H, P)
    dt: jnp.ndarray,  # (B, S, H)
    A: jnp.ndarray,  # (H,)
    Bm: jnp.ndarray,  # (B, S, G, N)
    Cm: jnp.ndarray,  # (B, S, G, N)
    *,
    chunk: int = 256,
    interpret: bool = True,
):
    """Returns y (B, S, H, P) f32. (Final state is recoverable but not
    returned — training/prefill is the kernel's role; decode uses the O(1)
    recurrent step which needs no kernel.)"""
    Bsz, S, H, Pd = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = S + pad
    nc = Sp // chunk

    # kernel layouts: x (B,H,nc,Q,P); dt (B,H,nc,Q); B/C (B,G,nc,Q,N)
    xk = x.transpose(0, 2, 1, 3).reshape(Bsz, H, nc, chunk, Pd)
    dtk = dt.transpose(0, 2, 1).reshape(Bsz, H, nc, chunk)
    Bk = Bm.transpose(0, 2, 1, 3).reshape(Bsz, G, nc, chunk, N)
    Ck = Cm.transpose(0, 2, 1, 3).reshape(Bsz, G, nc, chunk, N)

    rep = H // G

    y = pl.pallas_call(
        functools.partial(_ssd_kernel, nc=nc),
        grid=(Bsz, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, chunk, Pd), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, 1, 1, chunk, N), lambda b, h, c: (b, h // rep, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk, N), lambda b, h, c: (b, h // rep, c, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, chunk, Pd), lambda b, h, c: (b, h, c, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((Bsz, H, nc, chunk, Pd), jnp.float32),
        scratch_shapes=[pltpu.VMEM((Pd, N), jnp.float32)],
        interpret=interpret,
    )(xk, dtk, A.astype(jnp.float32), Bk, Ck)

    y = y.reshape(Bsz, H, Sp, Pd).transpose(0, 2, 1, 3)[:, :S]
    return y
