"""Top-k select+pack / scatter-accumulate Pallas TPU kernels.

The sparsified exchange ships only the k largest-|x| entries of each
gradient leaf as (value, int32 index) pairs. ``lax.top_k`` sorts the whole
vector (O(n log n) and an awkward fit for the VPU); the kernel instead
finds the k-th magnitude by **iterative norm thresholding** — a 64-step
bisection on the threshold t, each step a full-tile compare+popcount
(O(n) VPU work per step, no sort) — then packs the survivors into dense
(k,) value/index banks with a cumsum prefix scan.

Ties at the threshold are resolved in two tiers so the output is exactly
k entries: everything strictly above the converged upper bracket is kept,
and the remaining slots are filled with boundary-magnitude entries in
ascending index order. For distinct magnitudes this matches ``lax.top_k``
exactly; on exact magnitude ties only the tie-break order may differ
(the decoded dense tensor is identical when tied values are equal).

The decoder is a fused scatter-accumulate: all P peers' (k,) banks are
dequantized and folded into the mixing-weighted dense sum in one VMEM
pass — the sparse analogue of ``qsgd._dequant_reduce_kernel``.

Both kernels operate on the whole (padded) leaf as a single VMEM block:
per-leaf gradients at the repo's benchmark scale fit comfortably; leaves
beyond the VMEM budget should use the ``jnp`` oracle path
(``kernels/ref.py``), which the exchange layer keeps as the default.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128  # lane width: flat vectors are tiled to (rows, LANE)
ROW_TILE = 8  # sublane alignment for f32 tiles
_BISECT_STEPS = 64  # enough to converge f32 brackets to adjacent floats


def _pad_rows(n: int) -> int:
    rows = -(-n // LANE)
    return rows + ((-rows) % ROW_TILE)


def _select_kernel(x_ref, out_v_ref, out_i_ref, *, n: int, k: int):
    x = x_ref[...].astype(jnp.float32)  # (R, LANE)
    rows, lanes = x.shape
    flat_idx = (
        jax.lax.broadcasted_iota(jnp.int32, (rows, lanes), 0) * lanes
        + jax.lax.broadcasted_iota(jnp.int32, (rows, lanes), 1)
    )
    valid = flat_idx < n
    mag = jnp.where(valid, jnp.abs(x), -1.0)  # padding can never be selected

    # Bisection invariant: count(mag >= lo) >= k  and  count(mag >= hi) < k.
    lo0 = jnp.float32(0.0)  # every valid |x| >= 0, and n >= k by contract
    hi0 = jnp.max(mag) * jnp.float32(1.0 + 1e-6) + jnp.float32(1e-30)

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        c = jnp.sum((mag >= mid).astype(jnp.int32))
        big = c >= k
        return jnp.where(big, mid, lo), jnp.where(big, hi, mid)

    lo, hi = jax.lax.fori_loop(0, _BISECT_STEPS, body, (lo0, hi0))

    # Two-tier exact-k selection: keep everything strictly above the upper
    # bracket (count < k), then fill the remaining slots with boundary
    # entries (lo <= mag < hi) in ascending index order.
    sure = (mag >= hi).reshape(-1)
    edge = ((mag >= lo) & (mag < hi)).reshape(-1)
    n_sure = jnp.sum(sure.astype(jnp.int32))
    fill = k - n_sure
    sure_rank = jnp.cumsum(sure.astype(jnp.int32)) - 1
    edge_rank = jnp.cumsum(edge.astype(jnp.int32)) - 1
    take_edge = edge & (edge_rank < fill)
    take = sure | take_edge
    slot = jnp.where(sure, sure_rank, n_sure + edge_rank)

    kp = out_v_ref.shape[0]
    flat_v = x.reshape(-1)
    flat_i = flat_idx.reshape(-1)
    tgt = jnp.where(take, slot, kp)  # non-selected entries dropped
    out_v_ref[...] = (
        jnp.zeros((kp,), jnp.float32)
        .at[tgt]
        .set(jnp.where(take, flat_v, 0.0), mode="drop")
    )
    out_i_ref[...] = (
        jnp.zeros((kp,), jnp.int32)
        .at[tgt]
        .set(jnp.where(take, flat_i, 0), mode="drop")
    )


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def topk_select_pack(x: jnp.ndarray, k: int, *, interpret: bool = True):
    """x: (n,) f32 -> (values f32 (k,), indices int32 (k,)) of the k largest |x|."""
    n = x.shape[0]
    assert 1 <= k <= n, f"k={k} out of range for n={n}"
    rows = _pad_rows(n)
    xp = jnp.pad(x.astype(jnp.float32), (0, rows * LANE - n)).reshape(rows, LANE)
    kp = k + ((-k) % LANE)
    vals, idx = pl.pallas_call(
        functools.partial(_select_kernel, n=n, k=k),
        grid=(1,),
        in_specs=[pl.BlockSpec((rows, LANE), lambda i: (0, 0))],
        out_specs=[
            pl.BlockSpec((kp,), lambda i: (0,)),
            pl.BlockSpec((kp,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((kp,), jnp.float32),
            jax.ShapeDtypeStruct((kp,), jnp.int32),
        ],
        interpret=interpret,
    )(xp)
    return vals[:k], idx[:k]


def _scatter_kernel(v_ref, i_ref, w_ref, out_ref):
    v = v_ref[...].astype(jnp.float32)  # (P, kp)
    w = w_ref[...].astype(jnp.float32)  # (P,)
    contrib = (v * w[:, None]).reshape(-1)
    tgt = i_ref[...].reshape(-1)
    out_ref[...] = (
        jnp.zeros(out_ref.shape, jnp.float32).at[tgt].add(contrib, mode="drop")
    )


@functools.partial(jax.jit, static_argnames=("n", "interpret"))
def topk_scatter_accum(
    vals: jnp.ndarray,
    idx: jnp.ndarray,
    w: jnp.ndarray,
    n: int,
    *,
    interpret: bool = True,
):
    """Fused sparse decode-reduce.

    vals (P, k) f32, idx (P, k) int32, w (P,) f32 -> dense (n,) f32 holding
    sum_p w[p] * scatter(vals[p], idx[p]) in one pass. Padding slots carry
    value 0.0 so their scatter-adds are no-ops.
    """
    P, k = vals.shape
    kp = k + ((-k) % LANE)
    if kp != k:
        vals = jnp.pad(vals.astype(jnp.float32), ((0, 0), (0, kp - k)))
        idx = jnp.pad(idx, ((0, 0), (0, kp - k)))
    np_ = n + ((-n) % LANE)
    out = pl.pallas_call(
        _scatter_kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((P, kp), lambda i: (0, 0)),
            pl.BlockSpec((P, kp), lambda i: (0, 0)),
            pl.BlockSpec((P,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((np_,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((np_,), jnp.float32),
        interpret=interpret,
    )(vals.astype(jnp.float32), idx, w.astype(jnp.float32))
    return out[:n]
