"""Multi-pod dry-run: lower + compile every (arch x input-shape) combination
on the production meshes, print memory/cost analysis, and extract the
roofline terms (FLOPs / HBM bytes / collective bytes).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-3b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--json out.json]

This is the ONLY entry point that forces 512 host devices; smoke tests and
benchmarks see the real device count.
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
).strip()

import argparse
import dataclasses
import json
import re
import sys
import time
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat, models
from repro.configs import ASSIGNED_ARCHS, SHAPES, get_config
from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.p2p import TrainState, Topology
from repro.launch import sharding as SH
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16, make_production_mesh
from repro.models.layers import axis_rules
from repro.optim import adam, sgd
from repro.train import build_train_step, lm_loss

# (arch, shape) pairs that are skipped by design — see DESIGN.md §Arch-applicability
SKIPS = {
    ("whisper-base", "long_500k"): "enc-dec audio decoder; 500k autoregressive decode is meaningless",
}


def topology_for(
    cfg: ModelConfig, mesh, *,
    exchange: str = "allgather_mean",
    exchange_dtype: str = "float32",
    cast_params_once: bool = False,
) -> Topology:
    axes = set(mesh.axis_names)
    if cfg.fsdp:
        peer_axes = ("pod",) if "pod" in axes else ()
    else:
        peer_axes = ("pod", "data") if "pod" in axes else ("data",)
    return Topology(
        peer_axes=peer_axes,
        lambda_axis="model",
        exchange=exchange,
        exchange_dtype=exchange_dtype,
        cast_params_once=cast_params_once,
        # Regime A only: fan micro-batches over the lambda axis. Regime B
        # (fsdp) uses the model axis for tensor parallelism instead.
        serverless=not cfg.fsdp,
    )


def cfg_for_shape(cfg: ModelConfig, shape: ShapeConfig) -> ModelConfig:
    """serve_window (the SWA serving variant) applies only to long_500k."""
    if shape.name != "long_500k" and cfg.serve_window:
        return dataclasses.replace(cfg, serve_window=0)
    return cfg


def input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh, rules):
    """ShapeDtypeStruct stand-ins + shardings for one (arch, shape)."""
    batch, batch_sh = SH.batch_specs(cfg, shape, mesh, rules)
    if shape.mode in ("train", "prefill"):
        return batch, batch_sh
    # decode: single token + cache state
    B = shape.global_batch
    token = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    token_sh = NamedSharding(mesh, P(rules["batch"]) if rules["batch"] else P())
    state_shapes = jax.eval_shape(
        lambda: models.init_decode_state(cfg, B, shape.seq_len)
    )
    state_sh = SH.decode_state_shardings(state_shapes, cfg, mesh, rules)
    return (token, state_shapes), (token_sh, state_sh)


def lower_one(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    exchange: str = "allgather_mean",
    exchange_dtype: str = "float32",
    cast_params_once: bool = False,
    moe_dispatch: str = "dense",
    optimizer: str = "adam",
    donate: bool = True,
):
    """Lower + compile one combination. Returns (lowered, compiled, meta)."""
    cfg = cfg_for_shape(get_config(arch), SHAPES[shape_name])
    shape = SHAPES[shape_name]
    if (arch, shape_name) in SKIPS:
        raise SkipCombo(SKIPS[(arch, shape_name)])
    mesh = make_production_mesh(multi_pod=multi_pod)
    topo = topology_for(
        cfg, mesh, exchange=exchange, exchange_dtype=exchange_dtype,
        cast_params_once=cast_params_once,
    )
    rules = SH.activation_rules(cfg, shape, mesh, peer_axes=topo.peer_axes)

    with compat.set_mesh(mesh):
        with axis_rules(rules):
            if shape.mode == "train":
                opt = adam() if optimizer == "adam" else sgd(momentum=0.9)
                params_shapes = jax.eval_shape(
                    lambda: models.init_model(jax.random.PRNGKey(0), cfg)
                )
                opt_shapes = jax.eval_shape(opt.init, params_shapes)
                p_sh = SH.param_shardings(params_shapes, cfg, mesh)
                o_sh = SH.param_shardings(opt_shapes, cfg, mesh)
                state_shapes = TrainState(
                    params=params_shapes,
                    opt_state=opt_shapes,
                    step=jax.ShapeDtypeStruct((), jnp.int32),
                    key=jax.ShapeDtypeStruct((2,), jnp.uint32),
                )
                state_sh = TrainState(
                    params=p_sh,
                    opt_state=o_sh,
                    step=NamedSharding(mesh, P()),
                    key=NamedSharding(mesh, P()),
                )
                batch, batch_sh = input_specs(cfg, shape, mesh, rules)
                step = build_train_step(
                    cfg, opt, topo, mesh,
                    schedule=lambda s: jnp.float32(1e-3),
                    moe_dispatch=moe_dispatch,
                )
                fn = jax.jit(
                    step,
                    in_shardings=(state_sh, batch_sh),
                    donate_argnums=(0,) if donate else (),
                )
                lowered = fn.lower(state_shapes, batch)
            elif shape.mode == "prefill":
                params_shapes = jax.eval_shape(
                    lambda: models.init_model(jax.random.PRNGKey(0), cfg)
                )
                p_sh = SH.param_shardings(params_shapes, cfg, mesh)
                batch, batch_sh = input_specs(cfg, shape, mesh, rules)

                def prefill(params, batch):
                    logits, _ = models.forward(
                        params, batch, cfg, moe_dispatch=moe_dispatch
                    )
                    return logits

                fn = jax.jit(prefill, in_shardings=(p_sh, batch_sh))
                lowered = fn.lower(params_shapes, batch)
            else:  # decode
                params_shapes = jax.eval_shape(
                    lambda: models.init_model(jax.random.PRNGKey(0), cfg)
                )
                p_sh = SH.param_shardings(params_shapes, cfg, mesh)
                (token, state_shapes), (token_sh, state_sh) = input_specs(
                    cfg, shape, mesh, rules
                )

                def serve_step(params, state, token):
                    return models.decode_step(
                        params, state, token, cfg, moe_dispatch=moe_dispatch
                    )

                fn = jax.jit(
                    serve_step,
                    in_shardings=(p_sh, state_sh, token_sh),
                    out_shardings=(None, state_sh),
                    donate_argnums=(1,) if donate else (),
                )
                lowered = fn.lower(params_shapes, state_shapes, token)

            compiled = lowered.compile()
    meta = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "mode": shape.mode,
        "exchange": exchange if shape.mode == "train" else "-",
        "peers": int(np.prod([mesh.shape[a] for a in topo.peer_axes])) if topo.peer_axes else 1,
        "moe_dispatch": moe_dispatch if cfg.num_experts else "-",
    }
    return lowered, compiled, meta


class SkipCombo(Exception):
    pass


# ---------------------------------------------------------------------------
# Roofline extraction
# ---------------------------------------------------------------------------

def roofline(compiled, mesh, cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Three-term roofline from the compiled per-partition HLO.

    ``cost_analysis()`` counts while bodies once (useless for scanned
    stacks), so FLOPs / dot-traffic / collective bytes come from the HLO
    analyzer, which scales loop bodies by their trip counts. All analyzer
    numbers are per-device; totals multiply by chip count.
    """
    from repro.launch import hlo_analysis as HA

    chips = int(np.prod(list(mesh.devices.shape)))
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    hlo = compiled.as_text()
    st = HA.analyze(hlo)
    flops = st.flops * chips  # totals across the mesh
    bytes_accessed = st.dot_bytes * chips
    coll = {k: v * chips for k, v in st.collective_bytes.items()}
    coll_total = float(sum(coll.values()))

    t_compute = flops / (chips * PEAK_FLOPS_BF16)
    t_memory = bytes_accessed / (chips * HBM_BW)
    t_coll = coll_total / (chips * ICI_BW)
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)

    # MODEL_FLOPS: 6*N*D for train (fwd+bwd), 2*N*D for inference
    n_active = cfg.active_param_count() if cfg.family != "cnn" else 0
    tokens = shape.global_batch * (shape.seq_len if shape.mode != "decode" else 1)
    mult = 6 if shape.mode == "train" else 2
    model_flops = mult * n_active * tokens
    mem = {}
    try:
        ma = compiled.memory_analysis()
        mem = {
            "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
            "output_bytes": getattr(ma, "output_size_in_bytes", None),
            "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
            "peak_bytes": getattr(ma, "peak_memory_in_bytes", None),
        }
    except Exception as e:  # pragma: no cover
        mem = {"error": str(e)}
    return {
        "chips": chips,
        "hlo_flops": flops,
        "hlo_bytes": bytes_accessed,
        "collective_bytes": coll_total,
        "collectives": coll,
        "terms_s": terms,
        "dominant": dominant,
        "model_flops": float(model_flops),
        "useful_flops_ratio": float(model_flops / flops) if flops else 0.0,
        "raw_cost_analysis": {
            "flops": float(ca.get("flops", 0.0)),
            "bytes accessed": float(ca.get("bytes accessed", 0.0)),
        },
        "memory": mem,
    }


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def run_one(arch: str, shape_name: str, *, multi_pod: bool, verbose: bool = True,
            **kw) -> Optional[Dict[str, Any]]:
    t0 = time.time()
    try:
        lowered, compiled, meta = lower_one(
            arch, shape_name, multi_pod=multi_pod, **kw
        )
    except SkipCombo as e:
        if verbose:
            print(f"SKIP {arch} x {shape_name}: {e}")
        return {"arch": arch, "shape": shape_name, "skipped": str(e)}
    cfg = cfg_for_shape(get_config(arch), SHAPES[shape_name])
    mesh = make_production_mesh(multi_pod=multi_pod)
    rf = roofline(compiled, mesh, cfg, SHAPES[shape_name])
    rec = {**meta, **rf, "lower_compile_s": round(time.time() - t0, 1)}
    if verbose:
        mem = rf["memory"]
        peak = mem.get("peak_bytes") or 0
        args = mem.get("argument_bytes") or 0
        print(
            f"OK {arch} x {shape_name} [{meta['mesh']}] peers={meta['peers']} "
            f"flops={rf['hlo_flops']:.3e} bytes={rf['hlo_bytes']:.3e} "
            f"coll={rf['collective_bytes']:.3e} dom={rf['dominant']} "
            f"useful={rf['useful_flops_ratio']:.2f} "
            f"mem(arg={args/1e9:.2f}GB peak={peak/1e9:.2f}GB) "
            f"t={rec['lower_compile_s']}s"
        )
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--exchange", default="allgather_mean")
    ap.add_argument("--exchange-dtype", default="float32")
    ap.add_argument("--cast-params", action="store_true")
    ap.add_argument("--moe-dispatch", default="dense")
    ap.add_argument("--optimizer", default="adam")
    ap.add_argument("--json", default=None)
    args = ap.parse_args(argv)

    combos = []
    if args.all:
        for a in ASSIGNED_ARCHS:
            for s in SHAPES:
                combos.append((a, s))
    else:
        combos.append((args.arch, args.shape))

    records = []
    failed = []
    for a, s in combos:
        try:
            rec = run_one(
                a, s,
                multi_pod=args.multi_pod,
                exchange=args.exchange,
                exchange_dtype=args.exchange_dtype,
                cast_params_once=args.cast_params,
                moe_dispatch=args.moe_dispatch,
                optimizer=args.optimizer,
            )
            records.append(rec)
        except Exception as e:
            failed.append((a, s, repr(e)))
            print(f"FAIL {a} x {s}: {e!r}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(records, f, indent=1, default=str)
    print(f"\n{len([r for r in records if 'skipped' not in r])} ok, "
          f"{len([r for r in records if 'skipped' in r])} skipped, {len(failed)} failed")
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
