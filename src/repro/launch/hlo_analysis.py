"""HLO text analyzer: true FLOPs / dot-traffic / collective bytes with
while-loop trip-count scaling.

``compiled.cost_analysis()`` counts every while body exactly once (verified:
a 2-layer and an 8-layer scanned stack report identical FLOPs), which makes
it useless for scan-over-layers models. This analyzer parses the
post-partitioning HLO text instead:

* builds the computation call graph (while bodies, fusions, calls),
* recovers while trip counts from the loop-condition's `constant(N)`,
* counts per-instruction FLOPs for dot/convolution ops (2 * |out| * K),
* counts operand+result bytes of dots (a fused-elementwise lower bound on
  HBM traffic), and
* sums result bytes of all-gather / all-reduce / reduce-scatter / all-to-all
  / collective-permute ops,

each multiplied by the product of enclosing trip counts. Numbers are
per-device (the module is the post-SPMD per-partition program).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "f32": 4, "s32": 4, "u32": 4, "f64": 8, "s64": 8, "u64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _parse_shape(s: str) -> Tuple[str, Tuple[int, ...]]:
    m = _SHAPE_RE.match(s.strip())
    if not m:
        return ("", ())
    dims = tuple(int(d) for d in m.group(2).split(",")) if m.group(2) else ()
    return m.group(1), dims


def _nbytes(ty: str, dims: Tuple[int, ...]) -> int:
    n = 1
    for d in dims:
        n *= d
    return n * _BYTES.get(ty, 4)


def _all_shapes_bytes(type_str: str) -> int:
    """Total bytes over every array shape mentioned in a (maybe tuple) type."""
    total = 0
    for t, d in _SHAPE_RE.findall(type_str):
        dims = tuple(int(x) for x in d.split(",")) if d else ()
        total += _nbytes(t, dims)
    return total


@dataclass
class Instr:
    name: str
    ty: str  # result type string (may be tuple)
    opcode: str
    operands: List[str]
    raw: str


@dataclass
class Computation:
    name: str
    instrs: Dict[str, Instr] = field(default_factory=dict)
    order: List[str] = field(default_factory=list)


_COMP_HEADER = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*((?:\([^()]*\)|[a-z0-9]+\[[0-9,]*\][^\s]*))\s*"
    r"([\w\-]+)\((.*)$"
)
_OPERAND = re.compile(r"%([\w\.\-]+)")


def parse_hlo(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry = None
    current: Optional[Computation] = None
    for line in text.splitlines():
        stripped = line.strip()
        if not stripped or stripped.startswith("//"):
            continue
        if stripped.endswith("{") and "->" in stripped:
            m = _COMP_HEADER.match(stripped)
            if m:
                current = Computation(m.group(2))
                comps[current.name] = current
                if m.group(1):
                    entry = current.name
                continue
        if stripped == "}":
            current = None
            continue
        if current is None:
            continue
        m = _INSTR.match(stripped)
        if not m:
            continue
        name, ty, opcode, rest = m.groups()
        # operand names = %refs before any attribute section
        args_part = rest.split("), ")[0] if "), " in rest else rest
        operands = _OPERAND.findall(args_part)
        current.instrs[name] = Instr(name, ty, opcode, operands, stripped)
        current.order.append(name)
    return comps, entry


def _trip_count(cond: Computation) -> int:
    """Recover N from the loop bound constant in the condition computation.

    Post-optimization the `compare(i, N), direction=LT` is often wrapped in a
    fusion, so we take the largest positive s32 constant in the condition —
    for counted jax loops (scan/fori/remat) that is the trip count.
    """
    best = 1
    for ins in cond.instrs.values():
        if ins.opcode == "constant":
            m = re.search(r"constant\((\d+)\)", ins.raw)
            if m:
                best = max(best, int(m.group(1)))
    return best


def _dot_flops_bytes(ins: Instr, comp: Computation) -> Tuple[float, float]:
    out_ty, out_dims = _parse_shape(ins.ty)
    out_n = 1
    for d in out_dims:
        out_n *= d
    # contraction size from lhs operand shape + lhs_contracting_dims
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.raw)
    k = 1
    if m and ins.operands:
        lhs = comp.instrs.get(ins.operands[0])
        if lhs is not None:
            _, ldims = _parse_shape(lhs.ty)
            for idx in (int(i) for i in m.group(1).split(",") if i):
                if idx < len(ldims):
                    k *= ldims[idx]
    flops = 2.0 * out_n * k
    byts = _nbytes(out_ty or "f32", out_dims)
    for opn in ins.operands[:2]:
        o = comp.instrs.get(opn)
        if o is not None:
            t, d = _parse_shape(o.ty)
            byts += _nbytes(t, d)
    return flops, byts


def _conv_flops(ins: Instr, comp: Computation) -> float:
    out_ty, out_dims = _parse_shape(ins.ty)
    out_n = 1
    for d in out_dims:
        out_n *= d
    k = 1
    if len(ins.operands) >= 2:
        rhs = comp.instrs.get(ins.operands[1])
        if rhs is not None:
            _, rdims = _parse_shape(rhs.ty)
            # kernel spatial dims x input features ~= prod(rhs)/output_features
            n = 1
            for d in rdims:
                n *= d
            of = max(out_dims[-1] if out_dims else 1, 1)
            k = max(n // of, 1)
    return 2.0 * out_n * k


@dataclass
class HloStats:
    flops: float = 0.0
    dot_bytes: float = 0.0
    collective_bytes: Dict[str, float] = field(default_factory=dict)
    while_trips: List[int] = field(default_factory=list)

    @property
    def coll_total(self) -> float:
        return float(sum(self.collective_bytes.values()))


def analyze(text: str) -> HloStats:
    comps, entry = parse_hlo(text)
    stats = HloStats()
    if entry is None:
        return stats

    memo: Dict[str, Tuple[float, float, Dict[str, float]]] = {}

    def visit(cname: str) -> Tuple[float, float, Dict[str, float]]:
        if cname in memo:
            return memo[cname]
        comp = comps.get(cname)
        if comp is None:
            return (0.0, 0.0, {})
        memo[cname] = (0.0, 0.0, {})  # cycle guard
        flops = 0.0
        dbytes = 0.0
        coll: Dict[str, float] = {}

        def add_coll(d: Dict[str, float], scale=1.0):
            for k, v in d.items():
                coll[k] = coll.get(k, 0.0) + v * scale

        for ins in comp.instrs.values():
            op = ins.opcode
            if op == "dot":
                f, b = _dot_flops_bytes(ins, comp)
                flops += f
                dbytes += b
            elif op == "convolution":
                flops += _conv_flops(ins, comp)
            elif any(op.startswith(c) for c in COLLECTIVES):
                if op.endswith("-done"):
                    continue
                base = next(c for c in COLLECTIVES if op.startswith(c))
                coll[base] = coll.get(base, 0.0) + _all_shapes_bytes(ins.ty)
            elif op == "while":
                body = cond = None
                mb = re.search(r"body=%?([\w\.\-]+)", ins.raw)
                mc = re.search(r"condition=%?([\w\.\-]+)", ins.raw)
                if mb:
                    body = mb.group(1)
                if mc:
                    cond = mc.group(1)
                trips = _trip_count(comps[cond]) if cond in comps else 1
                stats.while_trips.append(trips)
                if body:
                    f, b, c = visit(body)
                    flops += f * trips
                    dbytes += b * trips
                    add_coll(c, trips)
            elif op in ("fusion", "call", "custom-call", "async-start"):
                m = re.search(r"calls=%?([\w\.\-]+)", ins.raw)
                if m:
                    f, b, c = visit(m.group(1))
                    flops += f
                    dbytes += b
                    add_coll(c)
            elif op == "conditional":
                for m in re.finditer(r"(?:branch_computations=\{([^}]*)\}|true_computation=%?([\w\.\-]+)|false_computation=%?([\w\.\-]+))", ins.raw):
                    names = (m.group(1) or "").replace("%", "").split(",") if m.group(1) else [g for g in m.groups()[1:] if g]
                    for nm in names:
                        nm = nm.strip()
                        if nm in comps:
                            f, b, c = visit(nm)
                            flops += f
                            dbytes += b
                            add_coll(c)
        memo[cname] = (flops, dbytes, coll)
        return memo[cname]

    f, b, c = visit(entry)
    stats.flops = f
    stats.dot_bytes = b
    stats.collective_bytes = c
    return stats
