"""Production mesh construction.

Single pod: (16, 16) = 256 chips, axes ("data", "model").
Multi-pod:  (2, 16, 16) = 512 chips, axes ("pod", "data", "model") — the
"pod" axis crosses the inter-pod DCN/ICI boundary; the P2P gradient
exchange runs over ("pod", "data") (or just "pod" for FSDP archs, where a
whole pod acts as one peer).

Defined as functions so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax initialization).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro.compat import AxisType, make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(shape))


def make_host_mesh(data: Optional[int] = None, model: int = 1):
    """A small mesh over whatever devices exist (CPU tests / examples)."""
    n = len(jax.devices())
    if data is None:
        data = n // model
    return make_mesh((data, model), ("data", "model"),
                     axis_types=(AxisType.Auto, AxisType.Auto))


# Hardware constants for the roofline analysis (TPU v5e).
PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link
