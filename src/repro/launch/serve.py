"""Serving driver: batched greedy decode with a KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --reduced \
        --batch 4 --prompt-len 16 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat, models
from repro.configs import get_config, reduced
from repro.launch.mesh import make_host_mesh
from repro.launch.sharding import activation_rules
from repro.models.layers import axis_rules
from repro.configs.base import ShapeConfig
from repro.train import checkpoint as ckpt


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg, vocab_size=512)
    if cfg.family == "cnn":
        raise SystemExit("CNNs are not served autoregressively")
    mesh = make_host_mesh()
    max_len = args.prompt_len + args.gen

    params = models.init_model(jax.random.PRNGKey(0), cfg)
    if args.checkpoint:
        params, meta = ckpt.restore(args.checkpoint, params)
        print(f"restored checkpoint (step {meta.get('step')})")

    B = args.batch
    key = jax.random.PRNGKey(1)
    prompts = jax.random.randint(key, (B, args.prompt_len), 0, cfg.vocab_size)
    state = models.init_decode_state(cfg, B, max_len)

    @jax.jit
    def step(params, state, token, key):
        logits, state = models.decode_step(params, state, token, cfg)
        if args.temperature > 0:
            tok = jax.random.categorical(key, logits / args.temperature, axis=-1)
        else:
            tok = logits.argmax(-1)
        return tok[:, None].astype(jnp.int32), state

    shape = ShapeConfig("serve", max_len, B, "decode")
    rules = activation_rules(cfg, shape, mesh)
    out_tokens = []

    @jax.jit
    def do_prefill(params, state, prompt):
        return models.prefill(params, state, {"tokens": prompt}, cfg)

    with compat.set_mesh(mesh):
        with axis_rules(rules):
            t0 = time.time()
            logits, state = do_prefill(params, state, prompts)  # one-shot prefill
            tok = logits.argmax(-1)[:, None].astype(jnp.int32)
            out_tokens.append(np.asarray(tok)[:, 0])
            for i in range(args.gen - 1):
                key, sub = jax.random.split(key)
                tok, state = step(params, state, tok, sub)
                out_tokens.append(np.asarray(tok)[:, 0])
            dt = time.time() - t0
    gen = np.stack(out_tokens, axis=1)
    toks_per_s = B * (args.prompt_len + args.gen) / dt
    print(f"generated {gen.shape} in {dt:.2f}s ({toks_per_s:.1f} tok/s incl. prefill)")
    for b in range(min(B, 2)):
        print(f"request {b}: {gen[b][:24].tolist()}")
    return gen


if __name__ == "__main__":
    main()
