"""Sharding policy: parameter specs, activation axis rules, batch specs.

Two regimes, chosen per architecture (DESIGN.md §4):

* Regime A (non-FSDP archs — the paper's serverless P2P image).
  Peers = the ("pod","data") axes (manual / shard_map). The "model" axis is
  the *serverless lambda pool*: inside each peer the micro-batches fan out
  over "model" (each lambda slot computes a micro-batch gradient; XLA's
  reduction over the axis is the per-peer gradient average). Parameters are
  *stored* sharded over "model" (ZeRO-3: like Lambda workers pulling model
  shards from S3) and gathered per-layer for compute; activation tensor
  rules stay unconstrained so GSPMD keeps batch-over-model throughout.

* Regime B (fsdp=True archs: dbrx-132b, internvl2-26b, moonshot — too big
  for replication). Peers = pods; within a pod classic 2D FSDP("data") x
  TP("model"): weights shard output-features over "model" (Megatron
  column/row split, expert dim for MoE) + largest remaining dim over
  "data"; activations shard batch over "data" and heads/ff/experts over
  "model".

Prefill/decode always use TP-style (regime B) activation rules — the
weight shardings align with head/ff activation sharding (column-parallel),
so serving needs no ZeRO gathers.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig

MIN_SHARD_SIZE = 1 << 14  # leaves smaller than this stay replicated

# weight-name classes for Megatron-style column/row splits
_COL_PARALLEL = {"wq", "wk", "wv", "w_gate", "w_up", "in_proj", "unembed"}
_ROW_PARALLEL = {"wo", "w_down", "out_proj"}
_EXPERT_NAMES = {"w_gate", "w_up", "w_down"}


def _path_keys(path) -> Tuple[str, ...]:
    return tuple(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def _div(dim: int, size: int) -> bool:
    return dim % size == 0


def sanitize_spec(shape: Tuple[int, ...], spec: P, mesh) -> P:
    """Drop spec axes whose size doesn't divide the corresponding dim
    (jit in_shardings require exact divisibility, unlike constraints)."""
    out = []
    for i, entry in enumerate(spec):
        if entry is None or i >= len(shape):
            out.append(None)
            continue
        axes = (entry,) if isinstance(entry, str) else tuple(entry)
        keep = []
        prod = 1
        for a in axes:
            sz = mesh.shape[a]
            if _div(shape[i], prod * sz):
                keep.append(a)
                prod *= sz
        if not keep:
            out.append(None)
        elif len(keep) == 1:
            out.append(keep[0])
        else:
            out.append(tuple(keep))
    return P(*out)


def param_spec(
    keys: Tuple[str, ...], shape: Tuple[int, ...], cfg: ModelConfig, mesh
) -> P:
    if len(shape) == 0 or int(np.prod(shape)) < MIN_SHARD_SIZE:
        return P()
    msz = mesh.shape["model"]
    dsz = mesh.shape.get("data", 1)
    spec: list = [None] * len(shape)
    start = (
        1
        if keys and keys[0] in ("stack", "encoder", "decoder") and len(shape) > 1
        else 0
    )
    name = keys[-1] if keys else ""
    cand = list(range(start, len(shape)))

    model_dim = None
    is_expert = name in _EXPERT_NAMES and (len(shape) - start == 3)
    if is_expert and _div(shape[start], msz):
        model_dim = start  # expert-parallel
    elif is_expert:
        # E not divisible (granite's 40 experts on a 16-wide axis): fall back
        # to Megatron *within* each expert — w_gate/w_up column-parallel (f),
        # w_down row-parallel (f) -> one psum per MoE layer. Measured ~5%
        # less prefill collective traffic vs sharding d (EXPERIMENTS.md §Perf).
        model_dim = (len(shape) - 1) if name in ("w_gate", "w_up") else start + 1
    elif name in _ROW_PARALLEL and _div(shape[start], msz):
        model_dim = start
    elif name in _COL_PARALLEL and _div(shape[-1], msz):
        model_dim = len(shape) - 1
    elif name == "embed" and _div(shape[0], msz):
        model_dim = 0  # vocab-sharded embedding
    if model_dim is None:
        order = sorted(cand, key=lambda i: shape[i], reverse=True)
        for i in order:
            if _div(shape[i], msz):
                model_dim = i
                break
        if model_dim is None:
            for i in order:
                if shape[i] >= msz:
                    model_dim = i
                    break
    if model_dim is not None:
        spec[model_dim] = "model"
    # Embedding tables keep a single sharded axis: 2D-sharded gather operands
    # inside a manual (shard_map) region hit an XLA SPMD PartitionGather
    # CHECK-failure (spmd_partitioner_util.cc:504, cf. b/433785288). The
    # memory cost of not FSDP-sharding the table's second axis is < 0.5
    # GB/chip for every assigned arch.
    if name in ("embed", "unembed"):
        return P(*spec)
    if cfg.fsdp and dsz > 1:
        rest = sorted(
            (i for i in cand if i != model_dim),
            key=lambda i: shape[i],
            reverse=True,
        )
        for i in rest:
            if _div(shape[i], dsz) or shape[i] >= 4 * dsz:
                spec[i] = "data"
                break
    return P(*spec)


def param_shardings(params_shapes, cfg: ModelConfig, mesh):
    """Pytree of NamedShardings matching a params (or opt-state) shape tree."""

    def spec_for(path, leaf):
        keys = _path_keys(path)
        while keys and keys[0] in ("mu", "nu", "momentum"):
            keys = keys[1:]
        spec = param_spec(keys, tuple(leaf.shape), cfg, mesh)
        return NamedSharding(mesh, sanitize_spec(tuple(leaf.shape), spec, mesh))

    return jax.tree_util.tree_map_with_path(spec_for, params_shapes)


# ---------------------------------------------------------------------------
# Activation logical-axis rules
# ---------------------------------------------------------------------------


def _fits(n: int, sz: int) -> bool:
    return n % sz == 0 and n >= sz


def activation_rules(
    cfg: ModelConfig, shape: ShapeConfig, mesh, *, peer_axes: Tuple[str, ...] = ()
) -> Dict[str, Any]:
    msz = mesh.shape["model"]
    batch_axes = [a for a in mesh.axis_names if a != "model"]
    B = shape.global_batch

    chosen_batch: list = []
    nbatch = 1
    for a in batch_axes:
        if _fits(B, nbatch * mesh.shape[a]):
            chosen_batch.append(a)
            nbatch *= mesh.shape[a]

    regime_a = not cfg.fsdp
    if shape.mode == "train" and regime_a:
        # Regime A: lambda (batch) parallelism over "model"; tensor rules off.
        # The batch rule INCLUDES "model": inside the peer body the residual
        # stream stays pinned batch-over-model, which forces XLA to gather
        # the (small) ZeRO weight shards per layer instead of all-gathering
        # the (huge) fp32 activations at every matmul — measured 4.8x less
        # collective traffic on qwen2.5-3b train_4k (EXPERIMENTS.md §Perf).
        # Input shardings are sanitized separately (global B may not divide
        # by all 3 axes; the in-peer constraint still applies).
        return {
            "batch": (tuple(chosen_batch) or ()) + ("model",),
            "embed": None, "ff": None, "heads": None, "kv_heads": None,
            "experts": None, "vocab": None, "kv_seq": None, "seq": None,
        }

    rules: Dict[str, Any] = {
        "batch": tuple(chosen_batch) or None,
        "seq": None,  # sequence parallelism for the residual stream (opt-in)
        "embed": None,
        "ff": "model" if cfg.d_ff and _fits(cfg.d_ff, msz) else None,
        "heads": "model" if cfg.num_heads and _fits(cfg.num_heads, msz) else None,
        "kv_heads": "model"
        if cfg.num_kv_heads and _fits(cfg.num_kv_heads, msz)
        else None,
        "experts": "model" if cfg.num_experts >= msz else None,
        "vocab": "model" if cfg.vocab_size >= 4 * msz else None,
        "kv_seq": None,
    }
    if cfg.ssm_state and _fits(cfg.ssm_heads, msz):
        rules["heads"] = "model"
    if shape.mode == "decode":
        spare = tuple(a for a in batch_axes if a not in chosen_batch)
        kv_axes = (() if rules["kv_heads"] else ("model",)) + spare
        rules["kv_seq"] = kv_axes if kv_axes else None
    return rules


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins) + their shardings
# ---------------------------------------------------------------------------


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh, rules) -> Tuple[dict, dict]:
    """(ShapeDtypeStructs, NamedShardings) for a train/prefill batch."""
    import jax.numpy as jnp

    B, S = shape.global_batch, shape.seq_len
    bspec = P(rules["batch"]) if rules["batch"] else P()
    bspec = sanitize_spec((B, S), bspec, mesh)
    out = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    sh = {"tokens": NamedSharding(mesh, bspec)}
    if shape.mode == "train":
        out["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        sh["labels"] = NamedSharding(mesh, bspec)
    if cfg.family == "vlm":
        out["patches"] = jax.ShapeDtypeStruct(
            (B, cfg.vision_tokens, cfg.d_model), jnp.bfloat16
        )
        sh["patches"] = NamedSharding(mesh, bspec)
    if cfg.family == "encdec":
        out["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16
        )
        sh["frames"] = NamedSharding(mesh, bspec)
    return out, sh


def decode_state_shardings(state_shapes, cfg: ModelConfig, mesh, rules):
    """Shardings for the decode cache pytree."""
    batch_rule = rules["batch"]
    kvh = rules["kv_heads"]
    kvs = rules["kv_seq"]
    heads = rules["heads"]

    def spec_for(path, leaf):
        keys = _path_keys(path)
        nd = len(leaf.shape)
        spec = [None] * nd
        if nd and keys[-1] in ("k", "v") and nd >= 4:
            lead = nd - 4  # (.., B, S, K, hd)
            spec[lead + 0] = batch_rule
            spec[lead + 1] = kvs
            spec[lead + 2] = kvh
        elif nd and keys[-1] == "ssm" and nd >= 4:
            lead = nd - 4  # (.., B, H, P, N)
            spec[lead + 0] = batch_rule
            spec[lead + 1] = heads
        elif nd and keys[-1] == "conv" and nd >= 3:
            lead = nd - 3  # (.., B, K-1, C)
            spec[lead + 0] = batch_rule
        return NamedSharding(
            mesh, sanitize_spec(tuple(leaf.shape), P(*spec), mesh)
        )

    return jax.tree_util.tree_map_with_path(spec_for, state_shapes)
