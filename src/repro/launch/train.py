"""Training driver: real steps on whatever devices exist.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b --reduced \
        --steps 50 --batch 8 --seq 64 --exchange allgather_mean

On this CPU container you train REDUCED variants (or the paper's CNNs via
benchmarks/); on a TPU slice the same driver runs the full configs with the
production mesh. Any protocol registered in
``repro.core.exchange`` is accepted by ``--exchange``.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat, models
from repro.configs import get_config, reduced
from repro.core.compression import QSGDConfig
from repro.core.convergence import ConvergenceDetector
from repro.core.cost import INSTANCE_MEMORY_MB
from repro.core.events import InstanceConfig, RuntimeConfig, available_allocations
from repro.core.scheduler import available_schedulers
from repro.core.exchange import available_exchanges, get_exchange
from repro.core.p2p import Topology
from repro.core.robust import ATTACK_KINDS, AdversarySpec
from repro.data import BatchKey, DataLoader, Partitioner, make_dataset
from repro.launch.mesh import make_host_mesh
from repro.launch.sharding import activation_rules
from repro.models.layers import axis_rules
from repro.optim import adam, sgd
from repro.optim.schedules import warmup_cosine
from repro.train import P2PTrainer
from repro.configs.base import ShapeConfig


def make_lm_batch(loader: DataLoader, key: BatchKey, vocab: int):
    b = loader.load(key)
    return {
        "tokens": jnp.asarray(b["tokens"] % vocab),
        "labels": jnp.asarray(b["labels"] % vocab),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--optimizer", default="adam", choices=["adam", "sgd"])
    ap.add_argument("--exchange", default="allgather_mean",
                    help="exchange protocol, optionally parameterized "
                         "NAME[:ARG] (e.g. trimmed_mean:0.25, krum:2); "
                         f"names: {', '.join(available_exchanges())}")
    ap.add_argument("--graph", default="full",
                    help="peer overlay graph: full | ring | gossip:K | "
                         "hierarchical[:GROUP] (see repro.core.graph)")
    ap.add_argument("--graph-seed", type=int, default=0,
                    help="seed for stochastic overlays (gossip)")
    ap.add_argument("--staleness", type=int, default=1,
                    help="async: consume banks published K steps ago")
    ap.add_argument("--topk-frac", type=float, default=0.01,
                    help="topk: fraction of gradient entries shipped")
    ap.add_argument("--topk-impl", default="jnp", choices=["jnp", "kernel"],
                    help="topk select/scatter implementation: jnp oracle or "
                         "the Pallas select+pack / scatter-accumulate kernels")
    ap.add_argument("--qsgd-impl", default="jnp", choices=["jnp", "kernel"],
                    help="qsgd codec implementation: jnp oracle or the Pallas "
                         "quantize + fused decode-dequantize-reduce kernels")
    ap.add_argument("--qsgd-levels", type=int, default=127,
                    help="qsgd quantization levels s (int8 range; 3 = the "
                         "aggressive setting EF keeps convergent)")
    ap.add_argument("--ef", action="store_true",
                    help="EF-SGD error feedback: accumulate the compression "
                         "residual per peer and re-inject it next step "
                         "(keeps qsgd/topk convergent at aggressive settings)")
    # robust aggregation + adversary model (repro.core.robust)
    ap.add_argument("--trim-frac", type=float, default=0.0,
                    help="trimmed_mean: fraction trimmed from EACH end "
                         "(spec param trimmed_mean:F overrides)")
    ap.add_argument("--krum-m", type=int, default=1,
                    help="krum: multi-Krum m, averages the m lowest-scored "
                         "peers (spec param krum:M overrides)")
    ap.add_argument("--robust-clip", type=float, default=0.0,
                    help="robust protocols: clip each peer's contribution "
                         "to this global norm before aggregation (0 = off)")
    ap.add_argument("--adversary-frac", type=float, default=0.0,
                    help="fraction of peers that publish poisoned gradients")
    ap.add_argument("--adversary-num", type=int, default=None,
                    help="exact Byzantine peer count (overrides --adversary-frac)")
    ap.add_argument("--attack", default="sign_flip", choices=list(ATTACK_KINDS),
                    help="poison applied by Byzantine peers (stale_replay is "
                         "host-cluster only)")
    ap.add_argument("--adversary-scale", type=float, default=10.0,
                    help="attack magnitude (sign-flip multiplier / noise std)")
    ap.add_argument("--adversary-seed", type=int, default=0,
                    help="seed selecting WHICH peers are Byzantine")
    ap.add_argument("--data-parallel", type=int, default=None)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--restore", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    # serverless runtime model (ServerlessRuntime event engine)
    ap.add_argument("--runtime-preset", default="ideal", choices=["ideal", "aws"],
                    help="base fault/cold-start model for serverless accounting")
    ap.add_argument("--failure-rate", type=float, default=None,
                    help="override: P(invocation attempt fails)")
    ap.add_argument("--cold-start-s", type=float, default=None,
                    help="override: container init seconds on a cold start")
    ap.add_argument("--concurrency", type=int, default=None,
                    help="override: Lambda concurrency cap (0 = unbounded)")
    ap.add_argument("--straggler-prob", type=float, default=None,
                    help="override: P(invocation draws a tail latency)")
    ap.add_argument("--allocation", default="static",
                    choices=list(available_allocations()),
                    help="per-epoch Lambda memory sizing policy")
    ap.add_argument("--serverless-report", action="store_true",
                    help="account measured step times under the runtime at exit")
    # instance-baseline model (InstanceRuntime event engine)
    ap.add_argument("--backend", default="serverless",
                    choices=["serverless", "instance"],
                    help="which accounting model prices the measured steps")
    ap.add_argument("--instance-type", default="t2.large",
                    choices=sorted(INSTANCE_MEMORY_MB),
                    help="instance tier of the baseline: CPU (t2.*) or "
                         "GPU (g4dn/g5/p3)")
    ap.add_argument("--boot-s", type=float, default=None,
                    help="instance: VM provision+boot seconds (billed)")
    ap.add_argument("--instance-churn-prob", type=float, default=None,
                    help="instance: P(the VM dies while computing a batch)")
    ap.add_argument("--cost-report", action="store_true",
                    help="price the measured steps under BOTH backends at "
                         "exit and print the cost-time frontier comparison")
    # cost-aware auto-scheduler (repro.core.scheduler)
    ap.add_argument("--scheduler", default=None,
                    choices=list(available_schedulers()),
                    help="pick next epoch's fleet plan from measured step "
                         "times at exit: sweeps serverless tiers, CPU/GPU "
                         "instances, and a mixed fleet")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="scheduler: epoch wall-clock deadline in seconds")
    ap.add_argument("--budget-usd", type=float, default=None,
                    help="scheduler: whole-cluster epoch budget in dollars")
    args = ap.parse_args(argv)

    import dataclasses as _dc

    runtime = (RuntimeConfig.aws_default() if args.runtime_preset == "aws"
               else RuntimeConfig())
    overrides = {}
    if args.failure_rate is not None:
        overrides["failure_rate"] = args.failure_rate
    if args.cold_start_s is not None:
        overrides["cold_start_s"] = args.cold_start_s
    if args.concurrency is not None:
        overrides["concurrency_limit"] = args.concurrency or None
    if args.straggler_prob is not None:
        overrides["straggler_prob"] = args.straggler_prob
    if overrides:
        runtime = _dc.replace(runtime, **overrides)

    instance_cfg = (InstanceConfig.aws_default()
                    if args.runtime_preset == "aws" else InstanceConfig())
    inst_overrides = {}
    if args.boot_s is not None:
        inst_overrides["boot_s"] = args.boot_s
    if args.instance_churn_prob is not None:
        inst_overrides["churn_prob"] = args.instance_churn_prob
    if inst_overrides:
        instance_cfg = _dc.replace(instance_cfg, **inst_overrides)

    get_exchange(args.exchange)  # fail fast on unknown/invalid NAME[:ARG]

    adversary = None
    if args.adversary_frac > 0 or args.adversary_num:
        adversary = AdversarySpec(
            fraction=args.adversary_frac, num=args.adversary_num,
            attack=args.attack, scale=args.adversary_scale,
            seed=args.adversary_seed,
        )

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg, vocab_size=512)
    mesh = make_host_mesh(args.data_parallel, args.model_parallel)
    npeers = mesh.shape["data"]
    print(f"mesh={dict(mesh.shape)} peers={npeers} arch={cfg.name}")

    topo = Topology(
        peer_axes=("data",) if npeers > 1 else (),
        lambda_axis="model" if mesh.shape["model"] > 1 else None,
        exchange=args.exchange,
        graph=args.graph,
        graph_seed=args.graph_seed,
        qsgd=(
            QSGDConfig(levels=args.qsgd_levels, bucket=512, impl=args.qsgd_impl)
            if args.exchange == "qsgd" else None
        ),
        staleness=args.staleness,
        topk_frac=args.topk_frac,
        topk_impl=args.topk_impl,
        ef=args.ef,
        trim_frac=args.trim_frac,
        krum_m=args.krum_m,
        robust_clip=args.robust_clip,
        serverless=mesh.shape["model"] > 1,
    )
    opt = adam() if args.optimizer == "adam" else sgd(momentum=0.9)
    sched = warmup_cosine(args.lr, args.steps // 10 + 1, args.steps)
    trainer = P2PTrainer(cfg, opt, topo, mesh, sched,
                         runtime=runtime, allocation=args.allocation,
                         backend=args.backend, instance_type=args.instance_type,
                         instance_config=instance_cfg, adversary=adversary,
                         scheduler=args.scheduler)
    if adversary is not None:
        print(f"adversary: {adversary.describe()} "
              f"(attackers={sorted(adversary.attackers(npeers))})")
    state = trainer.init_state(jax.random.PRNGKey(0))
    if args.restore:
        state = trainer.restore(args.restore, state)
        print(f"restored checkpoint from {args.restore} (step {int(state.step)})")
    if topo.peer_axes:
        cc = trainer.comm_cost(state.params)
        print(f"graph: {trainer.graph.describe()}")
        print(f"exchange={topo.exchange_name}: {cc.summary()}")
        plan = trainer.shard_plan(state.params)
        if plan is not None:
            print(f"shard plan: {plan.describe()}")

    ds = make_dataset("lm", size=200_000, vocab_size=cfg.vocab_size, seq_len=args.seq)
    loader = DataLoader(Partitioner(ds, 1), 0, args.batch)

    shape = ShapeConfig("host", args.seq, args.batch, "train")
    rules = activation_rules(cfg, shape, mesh, peer_axes=topo.peer_axes)
    detector = ConvergenceDetector(args.lr, mode="min", max_epochs=10**6)

    t0 = time.time()
    step_times = []
    with compat.set_mesh(mesh):
        with axis_rules(rules):
            for i in range(args.steps):
                batch = make_lm_batch(
                    loader, BatchKey(0, i // loader.num_batches, i % loader.num_batches),
                    cfg.vocab_size,
                )
                ts = time.time()
                state, metrics = trainer.step(state, batch)
                if args.serverless_report or args.cost_report or args.scheduler:
                    jax.block_until_ready(state.params)
                    step_times.append(time.time() - ts)
                if (i + 1) % args.log_every == 0 or i == 0:
                    loss = float(metrics["loss"])
                    print(
                        f"step {i+1:5d} loss {loss:.4f} ce {float(metrics['aux']):.4f} "
                        f"lr {float(metrics['lr']):.2e} "
                        f"({(time.time()-t0)/(i+1):.2f} s/step)"
                    )
                    if detector.step(loss):
                        print("converged (early stop)")
                        break
    if (args.serverless_report or args.cost_report or args.scheduler) \
            and step_times:
        # skip step 0 (compilation); one "epoch" = the measured step batch
        times = step_times[1:] or step_times
        if args.serverless_report and args.backend == "instance":
            rep = trainer.account_instance(
                times, epoch=0, charge_exchange=bool(topo.peer_axes)
            )
            print(
                f"instance accounting [{args.instance_type}]: "
                f"{rep.num_batches} sequential batches x {rep.num_splits} "
                f"split(s), wall {rep.wall_time_s:.2f}s "
                f"(measured {rep.measured_compute_s:.2f}s), "
                f"boot={rep.boot_s:.1f}s wire={rep.wire_s:.2f}s "
                f"drops={rep.churn_drops} cost=${rep.cost_usd:.6f}"
            )
        elif args.serverless_report:
            rep = trainer.account_serverless(times, epoch=0)
            print(
                f"serverless accounting [{args.runtime_preset}/{args.allocation}]: "
                f"{rep.num_batches} invocations x {rep.lambda_memory_mb}MB, "
                f"wall {rep.wall_time_s:.2f}s (measured {rep.measured_compute_s:.2f}s), "
                f"cold_starts={rep.num_cold_starts} retries={rep.num_retries} "
                f"queue_wait={rep.queue_wait_s:.2f}s cost=${rep.cost_usd:.6f}"
            )
            if trainer.protocol.sharded:
                agg = trainer.account_aggregation(epoch=0)
                print(
                    f"sharded aggregation: {agg.num_batches} parallel aggregators "
                    f"x {agg.lambda_memory_mb}MB (sized from shard bytes), "
                    f"wall {agg.wall_time_s:.3f}s cold_starts={agg.num_cold_starts} "
                    f"cost=${agg.cost_usd:.6f}"
                )
        if args.cost_report:
            # gradient-computation scope, fresh accountants on both sides:
            # reproducible regardless of the report branch above
            fr = trainer.cost_frontier(times)
            print(
                f"gradient-computation cost-time frontier "
                f"[{args.instance_type} baseline]: "
                f"serverless {fr['speedup_pct']:.2f}% faster at "
                f"{fr['cost_multiple']:.2f}x the cost "
                f"(serverless {fr['serverless_wall_s']:.2f}s/"
                f"${fr['serverless_usd']:.6f} vs instance "
                f"{fr['instance_wall_s']:.2f}s/${fr['instance_usd']:.6f} "
                f"per peer-epoch)"
            )
        if args.scheduler:
            # every peer runs the same measured step batch: the scheduler
            # sweeps serverless tiers, CPU/GPU instances, and a mixed
            # fleet, then picks under the deadline/budget
            per_peer = [list(times)] * max(npeers, 2)
            try:
                pick = trainer.schedule_epoch(
                    per_peer,
                    deadline_s=args.deadline_s,
                    budget_usd=args.budget_usd,
                )
            except ValueError as e:
                print(f"scheduler [{args.scheduler}]: infeasible — {e}")
            else:
                rep = pick["report"]
                constraints = []
                if args.deadline_s is not None:
                    constraints.append(f"deadline {args.deadline_s:g}s")
                if args.budget_usd is not None:
                    constraints.append(f"budget ${args.budget_usd:g}")
                print(
                    f"scheduler [{args.scheduler}"
                    f"{' | ' + ', '.join(constraints) if constraints else ''}]: "
                    f"chose {pick['plan'].describe()} — epoch wall "
                    f"{rep.wall_time_s:.2f}s, cluster ${rep.total_usd:.6f} "
                    f"({len(pick['candidates'])} candidates measured)"
                )
    if args.checkpoint:
        trainer.save(args.checkpoint, state)
        print(f"saved checkpoint to {args.checkpoint}")
    return state


if __name__ == "__main__":
    main()
