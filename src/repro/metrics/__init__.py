from repro.metrics.resources import StageMetrics, StageProbe

__all__ = ["StageMetrics", "StageProbe"]
