"""Per-stage resource metrics — the paper's §III-B.8 instrumentation.

The paper records, per training stage (compute gradients / send / receive /
model update / convergence detection):
  * CPU usage      — psutil, real-time
  * memory         — tracemalloc (plus RSS)
  * processing time — time.perf_counter

``StageProbe`` is a context manager; ``StageMetrics`` aggregates means per
stage across epochs exactly like Table I.
"""
from __future__ import annotations

import time
import tracemalloc
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List

try:
    import psutil

    _PROC = psutil.Process()
except Exception:  # pragma: no cover
    psutil = None
    _PROC = None


@dataclass
class StageRecord:
    seconds: float
    cpu_percent: float
    mem_mb: float
    rss_mb: float


class StageProbe:
    def __init__(self, metrics: "StageMetrics", stage: str):
        self.metrics = metrics
        self.stage = stage

    def __enter__(self):
        if not tracemalloc.is_tracing():
            tracemalloc.start()
        tracemalloc.reset_peak()
        if _PROC is not None:
            self._cpu0 = _PROC.cpu_times()
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        dt = max(time.perf_counter() - self._t0, 1e-9)
        _, peak = tracemalloc.get_traced_memory()
        cpu = 0.0
        rss = 0.0
        if _PROC is not None:
            c1 = _PROC.cpu_times()
            cpu = 100.0 * ((c1.user - self._cpu0.user) + (c1.system - self._cpu0.system)) / dt
            rss = _PROC.memory_info().rss / 1e6
        self.metrics.add(self.stage, StageRecord(dt, cpu, peak / 1e6, rss))
        return False


class StageMetrics:
    """Aggregates per-stage records; `table()` emits Table-I-shaped rows.

    Besides the probe-measured Table-I stages, the serverless runtime
    engine reports *simulated* stages (cold_start / queue_wait / retry):
    per-invocation time that exists only in simulated wall-clock, recorded
    via :meth:`add_simulated` with zero CPU/memory attribution.
    """

    STAGES = (
        "compute_gradients",
        "send_gradients",
        "receive_gradients",
        "model_update",
        "convergence_detection",
    )
    SIM_STAGES = (
        "cold_start",
        "queue_wait",
        "retry",
    )

    def __init__(self):
        self.records: Dict[str, List[StageRecord]] = defaultdict(list)

    def stage(self, name: str) -> StageProbe:
        return StageProbe(self, name)

    def add(self, stage: str, rec: StageRecord) -> None:
        self.records[stage].append(rec)

    def add_simulated(self, stage: str, seconds: float) -> None:
        """Record engine-simulated time (no CPU/memory — it never ran here)."""
        self.records[stage].append(StageRecord(float(seconds), 0.0, 0.0, 0.0))

    def mean(self, stage: str) -> StageRecord:
        rs = self.records.get(stage, [])
        if not rs:
            return StageRecord(0.0, 0.0, 0.0, 0.0)
        n = len(rs)
        return StageRecord(
            sum(r.seconds for r in rs) / n,
            sum(r.cpu_percent for r in rs) / n,
            sum(r.mem_mb for r in rs) / n,
            sum(r.rss_mb for r in rs) / n,
        )

    def table(self) -> Dict[str, Dict[str, float]]:
        out = {}
        for s in self.STAGES + self.SIM_STAGES:
            m = self.mean(s)
            out[s] = {
                "cpu_percent": round(m.cpu_percent, 2),
                "memory_mb": round(max(m.mem_mb, m.rss_mb), 2),
                "time_s": round(m.seconds, 4),
            }
        return out
