"""Uniform model API over all families.

``batch`` dicts carry, depending on family:
  tokens  (B, S) int32      — all LM families
  labels  (B, S) int32      — training targets (LM) / (B,) int32 (CNN)
  patches (B, V, d) float   — VLM stubbed vision embeddings
  frames  (B, F, d) float   — enc-dec stubbed audio frame embeddings
  images  (B, H, W, C)      — CNN
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import cnn as _cnn
from repro.models import transformer as _tf
from repro.models.layers import Params


def init_model(key, cfg: ModelConfig) -> Params:
    if cfg.family == "cnn":
        return _cnn.init_cnn(key, cfg)
    if cfg.family == "encdec":
        return _tf.init_encdec(key, cfg)
    return _tf.init_lm(key, cfg)


def forward(
    params: Params,
    batch: Dict[str, jnp.ndarray],
    cfg: ModelConfig,
    *,
    moe_dispatch: str = "dense",
    use_ssd_kernel: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (logits, aux_loss)."""
    if cfg.family == "cnn":
        logits = _cnn.cnn_forward(params, batch["images"], cfg)
        return logits, jnp.zeros((), jnp.float32)
    if cfg.family == "encdec":
        return _tf.encdec_forward(params, batch["frames"], batch["tokens"], cfg)
    return _tf.lm_forward(
        params, batch["tokens"], cfg,
        patches=batch.get("patches"),
        moe_dispatch=moe_dispatch,
        use_ssd_kernel=use_ssd_kernel,
    )


def init_decode_state(cfg: ModelConfig, batch: int, seq_len: int) -> Params:
    if cfg.family == "cnn":
        raise ValueError("CNNs have no decode step")
    if cfg.family == "encdec":
        return _tf.init_encdec_state(cfg, batch, seq_len)
    return _tf.init_decode_state(cfg, batch, seq_len)


def prefill(
    params: Params,
    state: Params,
    batch: Dict[str, jnp.ndarray],
    cfg: ModelConfig,
    *,
    moe_dispatch: str = "dense",
) -> Tuple[jnp.ndarray, Params]:
    """One-shot prompt prefill into a decode state. Returns
    (last-token logits, state positioned after the prompt)."""
    if cfg.family == "cnn":
        raise ValueError("CNNs have no decode step")
    if cfg.family == "encdec":
        return _tf.encdec_prefill(params, state, batch["frames"], batch["tokens"], cfg)
    return _tf.lm_prefill(
        params, state, batch["tokens"], cfg,
        patches=batch.get("patches"), moe_dispatch=moe_dispatch,
    )


def decode_step(
    params: Params,
    state: Params,
    token: jnp.ndarray,
    cfg: ModelConfig,
    *,
    moe_dispatch: str = "dense",
) -> Tuple[jnp.ndarray, Params]:
    if cfg.family == "encdec":
        return _tf.encdec_decode_step(params, state, token, cfg)
    return _tf.lm_decode_step(params, state, token, cfg, moe_dispatch=moe_dispatch)


def param_count(params: Params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))
