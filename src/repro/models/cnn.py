"""The paper's own CNNs — VGG-11, MobileNetV3-Small, SqueezeNet 1.1 — in
pure JAX (NHWC, ``lax.conv_general_dilated``).

BatchNorm is applied in batch-statistics mode (no running averages): every
peer normalizes with its own batch moments, which matches what the paper's
per-peer PyTorch training does during the measured training stages.
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
from jax import lax

Params = Dict[str, Any]


def _conv_init(key, kh, kw, cin, cout, dtype=jnp.float32):
    fan_in = kh * kw * cin
    w = jax.random.normal(key, (kh, kw, cin, cout)) * math.sqrt(2.0 / fan_in)
    return {"w": w.astype(dtype), "b": jnp.zeros((cout,), dtype)}


def conv2d(p: Params, x, stride=1, padding="SAME", groups=1):
    y = lax.conv_general_dilated(
        x, p["w"],
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups,
    )
    return y + p["b"]


def _bn_init(c, dtype=jnp.float32):
    return {"scale": jnp.ones((c,), dtype), "bias": jnp.zeros((c,), dtype)}


def batchnorm(p: Params, x, eps=1e-5):
    mu = x.mean(axis=(0, 1, 2), keepdims=True)
    var = x.var(axis=(0, 1, 2), keepdims=True)
    return (x - mu) * lax.rsqrt(var + eps) * p["scale"] + p["bias"]


def max_pool(x, window=2, stride=2):
    return lax.reduce_window(
        x, -jnp.inf, lax.max, (1, window, window, 1), (1, stride, stride, 1), "VALID"
    )


def avg_pool_to(x, out_hw: int):
    h = x.shape[1]
    if h == out_hw:
        return x
    win = max(h // out_hw, 1)
    return lax.reduce_window(
        x, 0.0, lax.add, (1, win, win, 1), (1, win, win, 1), "VALID"
    ) / (win * win)


def _linear_init(key, din, dout, dtype=jnp.float32):
    w = jax.random.normal(key, (din, dout)) * math.sqrt(2.0 / din)
    return {"w": w.astype(dtype), "b": jnp.zeros((dout,), dtype)}


def linear(p, x):
    return x @ p["w"] + p["b"]


# ---------------------------------------------------------------------------
# VGG-11
# ---------------------------------------------------------------------------

_VGG11_PLAN = [64, "M", 128, "M", 256, 256, "M", 512, 512, "M", 512, 512, "M"]


def init_vgg11(key, cfg) -> Params:
    ks = iter(jax.random.split(key, 16))
    cin = cfg.image_channels
    convs: List[Params] = []
    for item in _VGG11_PLAN:
        if item == "M":
            continue
        convs.append(_conv_init(next(ks), 3, 3, cin, item))
        cin = item
    pool_hw = 7 if cfg.image_size >= 64 else 1
    flat = 512 * pool_hw * pool_hw
    return {
        "convs": convs,
        "fc1": _linear_init(next(ks), flat, 4096),
        "fc2": _linear_init(next(ks), 4096, 4096),
        "fc3": _linear_init(next(ks), 4096, cfg.num_classes),
    }


def vgg11_forward(params: Params, images: jnp.ndarray, cfg) -> jnp.ndarray:
    x = images
    ci = 0
    for item in _VGG11_PLAN:
        if item == "M":
            x = max_pool(x)
        else:
            x = jax.nn.relu(conv2d(params["convs"][ci], x))
            ci += 1
    x = avg_pool_to(x, 7 if cfg.image_size >= 64 else 1)
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(linear(params["fc1"], x))
    x = jax.nn.relu(linear(params["fc2"], x))
    return linear(params["fc3"], x)


# ---------------------------------------------------------------------------
# SqueezeNet 1.1
# ---------------------------------------------------------------------------

# (squeeze, expand1x1, expand3x3)
_FIRE_PLAN = [
    (16, 64, 64), (16, 64, 64),
    (32, 128, 128), (32, 128, 128),
    (48, 192, 192), (48, 192, 192), (64, 256, 256), (64, 256, 256),
]
_FIRE_POOL_AFTER = {1, 3}  # maxpool after these fire indices (v1.1)


def init_squeezenet(key, cfg) -> Params:
    ks = iter(jax.random.split(key, 4 + 3 * len(_FIRE_PLAN)))
    p: Params = {"stem": _conv_init(next(ks), 3, 3, cfg.image_channels, 64)}
    cin = 64
    fires = []
    for (s, e1, e3) in _FIRE_PLAN:
        fires.append(
            {
                "squeeze": _conv_init(next(ks), 1, 1, cin, s),
                "e1": _conv_init(next(ks), 1, 1, s, e1),
                "e3": _conv_init(next(ks), 3, 3, s, e3),
            }
        )
        cin = e1 + e3
    p["fires"] = fires
    p["head"] = _conv_init(next(ks), 1, 1, cin, cfg.num_classes)
    return p


def squeezenet_forward(params: Params, images: jnp.ndarray, cfg) -> jnp.ndarray:
    small = cfg.image_size < 64
    x = jax.nn.relu(conv2d(params["stem"], images, stride=1 if small else 2))
    if not small:
        x = max_pool(x, 3, 2)
    for i, f in enumerate(params["fires"]):
        s = jax.nn.relu(conv2d(f["squeeze"], x))
        x = jnp.concatenate(
            [jax.nn.relu(conv2d(f["e1"], s)), jax.nn.relu(conv2d(f["e3"], s))], axis=-1
        )
        if i in _FIRE_POOL_AFTER:
            x = max_pool(x, 3, 2)
    x = jax.nn.relu(conv2d(params["head"], x))
    return x.mean(axis=(1, 2))  # global average pool -> logits


# ---------------------------------------------------------------------------
# MobileNetV3-Small
# ---------------------------------------------------------------------------

# (kernel, exp, out, SE, activation, stride)
_MBV3_PLAN = [
    (3, 16, 16, True, "relu", 2),
    (3, 72, 24, False, "relu", 2),
    (3, 88, 24, False, "relu", 1),
    (5, 96, 40, True, "hswish", 2),
    (5, 240, 40, True, "hswish", 1),
    (5, 240, 40, True, "hswish", 1),
    (5, 120, 48, True, "hswish", 1),
    (5, 144, 48, True, "hswish", 1),
    (5, 288, 96, True, "hswish", 2),
    (5, 576, 96, True, "hswish", 1),
    (5, 576, 96, True, "hswish", 1),
]


def _act(x, kind):
    return jax.nn.relu(x) if kind == "relu" else x * jax.nn.relu6(x + 3) / 6


def init_mobilenet_v3_small(key, cfg) -> Params:
    ks = iter(jax.random.split(key, 8 + 8 * len(_MBV3_PLAN)))
    p: Params = {
        "stem": _conv_init(next(ks), 3, 3, cfg.image_channels, 16),
        "stem_bn": _bn_init(16),
    }
    cin = 16
    blocks = []
    for (k, exp, out, se, actk, stride) in _MBV3_PLAN:
        b: Params = {
            "expand": _conv_init(next(ks), 1, 1, cin, exp),
            "expand_bn": _bn_init(exp),
            "dw": _conv_init(next(ks), k, k, 1, exp),
            "dw_bn": _bn_init(exp),
            "project": _conv_init(next(ks), 1, 1, exp, out),
            "project_bn": _bn_init(out),
        }
        if se:
            sq = max(exp // 4, 8)
            b["se_fc1"] = _conv_init(next(ks), 1, 1, exp, sq)
            b["se_fc2"] = _conv_init(next(ks), 1, 1, sq, exp)
        blocks.append(b)
        cin = out
    p["blocks"] = blocks
    p["head_conv"] = _conv_init(next(ks), 1, 1, cin, 576)
    p["head_bn"] = _bn_init(576)
    p["fc1"] = _linear_init(next(ks), 576, 1024)
    p["fc2"] = _linear_init(next(ks), 1024, cfg.num_classes)
    return p


def mobilenet_v3_small_forward(params: Params, images: jnp.ndarray, cfg) -> jnp.ndarray:
    small = cfg.image_size < 64
    x = conv2d(params["stem"], images, stride=1 if small else 2)
    x = _act(batchnorm(params["stem_bn"], x), "hswish")
    for b, (k, exp, out, se, actk, stride) in zip(params["blocks"], _MBV3_PLAN):
        if small and x.shape[1] <= 4:
            stride = 1  # don't collapse tiny feature maps below 4x4
        inp = x
        h = _act(batchnorm(b["expand_bn"], conv2d(b["expand"], x)), actk)
        h = conv2d(b["dw"], h, stride=stride, groups=h.shape[-1])
        h = _act(batchnorm(b["dw_bn"], h), actk)
        if "se_fc1" in b:
            s = h.mean(axis=(1, 2), keepdims=True)
            s = jax.nn.relu(conv2d(b["se_fc1"], s))
            s = jax.nn.sigmoid(conv2d(b["se_fc2"], s))
            h = h * s
        h = batchnorm(b["project_bn"], conv2d(b["project"], h))
        x = h + inp if (stride == 1 and inp.shape[-1] == h.shape[-1]) else h
    x = _act(batchnorm(params["head_bn"], conv2d(params["head_conv"], x)), "hswish")
    x = x.mean(axis=(1, 2))
    x = _act(linear(params["fc1"], x), "hswish")
    return linear(params["fc2"], x)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

CNN_ZOO = {
    "vgg11": (init_vgg11, vgg11_forward),
    "squeezenet1_1": (init_squeezenet, squeezenet_forward),
    "mobilenet_v3_small": (init_mobilenet_v3_small, mobilenet_v3_small_forward),
}


def init_cnn(key, cfg) -> Params:
    return CNN_ZOO[cfg.cnn_variant][0](key, cfg)


def cnn_forward(params: Params, images: jnp.ndarray, cfg) -> jnp.ndarray:
    return CNN_ZOO[cfg.cnn_variant][1](params, images, cfg)
