"""Pure-JAX model primitives shared by every architecture.

Parameters are nested dicts of ``jnp.ndarray`` (fp32 storage, bf16 compute
by default). Every layer has an ``init_*`` (returns the param pytree) and an
apply function. Sharding is expressed through *logical axis* constraints
(:func:`shard`) resolved against the active mesh by the launcher; with no
mesh active they are no-ops, so the same code runs single-device smoke tests
and 512-chip dry-runs.
"""
from __future__ import annotations

import math
from contextlib import contextmanager
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

Params = Dict[str, Any]

# ---------------------------------------------------------------------------
# Logical-axis sharding hints
# ---------------------------------------------------------------------------

_AXIS_RULES: Dict[str, Any] = {}


@contextmanager
def axis_rules(rules: Dict[str, Any]):
    """Install logical-axis -> mesh-axis rules (used inside ``mesh`` scopes)."""
    global _AXIS_RULES
    old = _AXIS_RULES
    _AXIS_RULES = dict(rules)
    try:
        yield
    finally:
        _AXIS_RULES = old


def _auto_axes() -> Optional[frozenset]:
    """Mesh axes currently in Auto (GSPMD) mode; None if no mesh context."""
    from repro.compat import auto_axes

    return auto_axes()


def logical_to_spec(*names: Optional[str]) -> P:
    auto = _auto_axes()

    def resolve(n):
        if not n:
            return None
        ax = _AXIS_RULES.get(n)
        if ax is None:
            return None
        axes = (ax,) if isinstance(ax, str) else tuple(ax)
        if auto is not None:
            axes = tuple(a for a in axes if a in auto)
        if not axes:
            return None
        return axes[0] if len(axes) == 1 else axes

    return P(*[resolve(n) for n in names])


def shard(x: jnp.ndarray, *names: Optional[str]) -> jnp.ndarray:
    """Constrain ``x`` to the logical axes ``names`` (no-op without rules).

    Axis references that resolve to *manual* mesh axes (inside a shard_map
    region) are dropped — the manual axes already partition those dims.
    """
    if not _AXIS_RULES:
        return x
    spec = logical_to_spec(*names)
    if all(s is None for s in spec):
        return x
    return lax.with_sharding_constraint(x, spec)


# ---------------------------------------------------------------------------
# Initializers / numerics
# ---------------------------------------------------------------------------


def _dense_init(key, in_dim, out_dim, dtype) -> jnp.ndarray:
    scale = 1.0 / math.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim)) * scale).astype(dtype)


def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    return jnp.tanh(x / cap) * cap if cap else x


def activation(x: jnp.ndarray, kind: str) -> jnp.ndarray:
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x)
    if kind == "relu":
        return jax.nn.relu(x)
    raise ValueError(f"unknown activation {kind}")


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_rmsnorm(dim: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm(params: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


def init_layernorm(dim: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def layernorm(params: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * lax.rsqrt(var + eps)
    return (y * params["scale"] + params["bias"]).astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embeddings (GPT-NeoX half-rotation convention)
# ---------------------------------------------------------------------------


def rope_tables(positions: jnp.ndarray, head_dim: int, theta: float):
    """positions: (..., S) int32 -> (sin, cos) of shape (..., S, head_dim//2)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.sin(angles), jnp.cos(angles)


def apply_rope(x: jnp.ndarray, sin: jnp.ndarray, cos: jnp.ndarray) -> jnp.ndarray:
    """x: (B, S, H, D); sin/cos: (B, S, D/2) or (S, D/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if sin.ndim == 2:  # (S, half) -> (1, S, half)
        sin, cos = sin[None], cos[None]
    sin, cos = sin[:, :, None, :], cos[:, :, None, :]  # insert head axis
    sin, cos = sin.astype(x.dtype), cos.astype(x.dtype)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


# ---------------------------------------------------------------------------
# Attention (GQA, optional sliding window / softcap / cross-attention),
# flash-style blockwise for long sequences, direct path for decode.
# ---------------------------------------------------------------------------


def init_attention(key, cfg, *, cross: bool = False) -> Params:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, k = cfg.num_heads, cfg.num_kv_heads
    pdt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(ks[0], d, h * hd, pdt),
        "wk": _dense_init(ks[1], d, k * hd, pdt),
        "wv": _dense_init(ks[2], d, k * hd, pdt),
        "wo": _dense_init(ks[3], h * hd, d, pdt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), pdt)
        p["bk"] = jnp.zeros((k * hd,), pdt)
        p["bv"] = jnp.zeros((k * hd,), pdt)
    return p


def _mask_value(dtype):
    return jnp.asarray(jnp.finfo(jnp.float32).min, jnp.float32)


def attend(
    q: jnp.ndarray,  # (B, Sq, H, D)
    k: jnp.ndarray,  # (B, Skv, K, D)
    v: jnp.ndarray,
    *,
    causal: bool,
    q_positions: jnp.ndarray,  # (Sq,) absolute positions of queries
    kv_positions: jnp.ndarray,  # (Skv,) absolute positions of keys (-1 = invalid)
    window: int = 0,
    softcap_val: float = 0.0,
    block_kv: int = 1024,
) -> jnp.ndarray:
    """Masked multi-head attention with GQA and online-softmax blocking.

    Query/key validity and locality are driven entirely by *positions*, which
    makes the same code path serve full causal attention, sliding windows,
    rolling decode caches and cross attention (``causal=False``).
    """
    B, Sq, H, D = q.shape
    _, Skv, K, _ = k.shape
    assert H % K == 0, (H, K)
    G = H // K
    qf = q.reshape(B, Sq, K, G, D).astype(jnp.float32) / math.sqrt(D)
    scale_dtype = jnp.float32

    def block(kb, vb, kpos):
        s = jnp.einsum("bqkgd,bskd->bkgqs", qf, kb.astype(jnp.float32))
        s = softcap(s, softcap_val)
        valid = (kpos >= 0)[None, None, None, None, :]
        if causal:
            rel = q_positions[:, None] - kpos[None, :]  # (Sq, Skv_b)
            ok = rel >= 0
            if window:
                ok &= rel < window
            valid = valid & ok[None, None, None, :, :]
        elif window:
            rel = jnp.abs(q_positions[:, None] - kpos[None, :])
            valid = valid & (rel < window)[None, None, None, :, :]
        return jnp.where(valid, s, _mask_value(scale_dtype)), vb

    if Skv <= block_kv:
        s, vb = block(k, v, kv_positions)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgqs,bskd->bqkgd", p, vb.astype(jnp.float32))
        return o.reshape(B, Sq, H, D).astype(q.dtype)

    # Online-softmax over kv blocks (flash-style; memory O(block)).
    nblocks = (Skv + block_kv - 1) // block_kv
    pad = nblocks * block_kv - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, (0, pad), constant_values=-1)
    kb = k.reshape(B, nblocks, block_kv, K, D).swapaxes(0, 1)
    vb = v.reshape(B, nblocks, block_kv, K, D).swapaxes(0, 1)
    pb = kv_positions.reshape(nblocks, block_kv)

    def step(carry, blk):
        m, l, acc = carry
        kb_i, vb_i, pos_i = blk
        s, vv = block(kb_i, vb_i, pos_i)  # (B,K,G,Sq,bkv)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", p, vv.astype(jnp.float32)
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, K, G, Sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, K, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, K, G, Sq, D), jnp.float32)
    (m, l, acc), _ = lax.scan(step, (m0, l0, a0), (kb, vb, pb))
    o = acc / jnp.maximum(l, 1e-30)[..., None]
    o = o.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, D)
    return o.astype(q.dtype)


def attention_apply(
    params: Params,
    x: jnp.ndarray,  # (B, S, d)
    cfg,
    *,
    positions: jnp.ndarray,  # (S,) absolute positions of x
    causal: bool = True,
    window: int = 0,
    cache: Optional[Params] = None,  # decode: {"k","v"} rolling/absolute buffers
    cache_pos: Optional[jnp.ndarray] = None,  # scalar: current decode position
    cross_kv: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
) -> Tuple[jnp.ndarray, Optional[Params]]:
    B, S, d = x.shape
    hd, H, K = cfg.resolved_head_dim, cfg.num_heads, cfg.num_kv_heads
    dt = x.dtype

    q = (x @ params["wq"].astype(dt)).reshape(B, S, H, hd)
    if "bq" in params:
        q = q + params["bq"].astype(dt).reshape(H, hd)

    if cross_kv is not None:
        kx, vx = cross_kv  # precomputed encoder K/V: (B, Senc, K, hd)
        q = shard(q, "batch", None, "heads", None)
        o = attend(
            q, kx, vx,
            causal=False,
            q_positions=positions,
            kv_positions=jnp.arange(kx.shape[1]),
            softcap_val=cfg.attn_logit_softcap,
        )
        y = o.reshape(B, S, H * hd) @ params["wo"].astype(dt)
        return shard(y, "batch", "seq", "embed"), cache

    k = (x @ params["wk"].astype(dt)).reshape(B, S, K, hd)
    v = (x @ params["wv"].astype(dt)).reshape(B, S, K, hd)
    if "bk" in params:
        k = k + params["bk"].astype(dt).reshape(K, hd)
        v = v + params["bv"].astype(dt).reshape(K, hd)

    if cfg.rope_theta:
        sin, cos = rope_tables(positions, hd, cfg.rope_theta)
        q = apply_rope(q, sin, cos)
        k = apply_rope(k, sin, cos)

    q = shard(q, "batch", None, "heads", None)

    new_cache = None
    if cache is not None and S > 1:
        # Prefill: fill the cache with the whole prompt's K/V in one pass
        # and attend causally over the prompt itself.
        import numpy as np

        Sc = cache["k"].shape[1]
        kc, vc = k, v
        if Sc < S:  # rolling window cache: keep the last Sc tokens,
            # written at slot t % Sc so decode's rolling scheme continues.
            kc = kc[:, S - Sc :]
            vc = vc[:, S - Sc :]
            slots = np.array([(S - Sc + i) % Sc for i in range(Sc)])
            perm = np.argsort(slots)
            kc = kc[:, perm]
            vc = vc[:, perm]
            ck = kc.astype(cache["k"].dtype)
            cv = vc.astype(cache["v"].dtype)
        else:
            ck = lax.dynamic_update_slice(
                cache["k"], kc.astype(cache["k"].dtype), (0, 0, 0, 0)
            )
            cv = lax.dynamic_update_slice(
                cache["v"], vc.astype(cache["v"].dtype), (0, 0, 0, 0)
            )
        ck = shard(ck, "batch", "kv_seq", "kv_heads", None)
        cv = shard(cv, "batch", "kv_seq", "kv_heads", None)
        new_cache = {"k": ck, "v": cv}
        k = shard(k, "batch", None, "kv_heads", None)
        v = shard(v, "batch", None, "kv_heads", None)
        o = attend(
            q, k, v,
            causal=causal,
            q_positions=positions,
            kv_positions=positions,
            window=window,
            softcap_val=cfg.attn_logit_softcap,
        )
        y = o.reshape(B, S, H * hd) @ params["wo"].astype(dt)
        return shard(y, "batch", "seq", "embed"), new_cache

    if cache is not None:
        # Decode: write this step's K/V into the cache, attend over the cache.
        Sc = cache["k"].shape[1]
        if window and Sc == window:
            slot = (cache_pos % window).astype(jnp.int32)
            # slot j holds absolute position p - ((p - j) mod W)
            j = jnp.arange(Sc)
            kv_pos = cache_pos - ((cache_pos - j) % window)
        else:
            slot = cache_pos.astype(jnp.int32)
            j = jnp.arange(Sc)
            kv_pos = jnp.where(j <= cache_pos, j, -1)
        ck = lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
        cv = lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
        ck = shard(ck, "batch", "kv_seq", "kv_heads", None)
        cv = shard(cv, "batch", "kv_seq", "kv_heads", None)
        new_cache = {"k": ck, "v": cv}
        kv_pos = jnp.where(kv_pos >= 0, kv_pos, -1)
        o = attend(
            q, ck, cv,
            causal=True,
            q_positions=positions,
            kv_positions=kv_pos,
            window=window,
            softcap_val=cfg.attn_logit_softcap,
        )
    else:
        k = shard(k, "batch", None, "kv_heads", None)
        v = shard(v, "batch", None, "kv_heads", None)
        o = attend(
            q, k, v,
            causal=causal,
            q_positions=positions,
            kv_positions=positions,
            window=window,
            softcap_val=cfg.attn_logit_softcap,
        )

    y = o.reshape(B, S, H * hd) @ params["wo"].astype(dt)
    return shard(y, "batch", "seq", "embed"), new_cache


def init_decode_cache(cfg, batch: int, seq_len: int, layer_window: int, dtype) -> Params:
    """Cache buffers for one attention layer (rolling if windowed)."""
    size = min(seq_len, layer_window) if layer_window else seq_len
    shape = (batch, size, cfg.num_kv_heads, cfg.resolved_head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


# ---------------------------------------------------------------------------
# Dense MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------


def init_mlp(key, d: int, f: int, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": _dense_init(k1, d, f, dtype),
        "w_up": _dense_init(k2, d, f, dtype),
        "w_down": _dense_init(k3, f, d, dtype),
    }


def mlp_apply(params: Params, x: jnp.ndarray, act: str) -> jnp.ndarray:
    dt = x.dtype
    mid = (None,) * (x.ndim - 2)  # rank-agnostic: (B,S,d) or flat (T,d)
    g = activation(x @ params["w_gate"].astype(dt), act)
    u = x @ params["w_up"].astype(dt)
    h = shard(g * u, "batch", *mid, "ff")
    return shard(h @ params["w_down"].astype(dt), "batch", *mid, "embed")


# ---------------------------------------------------------------------------
# Mixture of Experts (token-choice top-k)
#
# Baseline path: dense einsum over the expert dimension (every expert sees
# every token, gates zero out unrouted pairs). Memory-bounded by scanning
# token chunks; expert dim shards over the `experts` logical axis. This is
# compile-robust and exactly matches the reference semantics; the
# capacity-based dispatch (`moe_dispatch="capacity"`) is the optimized path
# measured in EXPERIMENTS.md §Perf.
# ---------------------------------------------------------------------------


def init_moe(key, cfg) -> Params:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    pdt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 5)
    p = {
        "router": _dense_init(ks[0], d, E, pdt),
        "w_gate": (jax.random.normal(ks[1], (E, d, f)) / math.sqrt(d)).astype(pdt),
        "w_up": (jax.random.normal(ks[2], (E, d, f)) / math.sqrt(d)).astype(pdt),
        "w_down": (jax.random.normal(ks[3], (E, f, d)) / math.sqrt(f)).astype(pdt),
    }
    if cfg.moe_shared_ff:
        p["shared"] = init_mlp(ks[4], d, cfg.moe_shared_ff, pdt)
    return p


def router_topk(logits: jnp.ndarray, k: int) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Return (dense_gates (T,E), aux_loss, raw probs)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    vals, idx = lax.top_k(probs, k)
    vals = vals / jnp.maximum(vals.sum(-1, keepdims=True), 1e-9)
    onehot = jax.nn.one_hot(idx, probs.shape[-1], dtype=jnp.float32)  # (T,k,E)
    dense_gates = (onehot * vals[..., None]).sum(axis=-2)  # (T,E)
    # Switch-style load-balance loss.
    E = probs.shape[-1]
    frac_tokens = (onehot.sum(-2) > 0).astype(jnp.float32).mean(axis=0)
    frac_probs = probs.mean(axis=0)
    aux = E * jnp.sum(frac_tokens * frac_probs)
    return dense_gates, aux, probs


def moe_apply(
    params: Params,
    x: jnp.ndarray,  # (B, S, d)
    cfg,
    *,
    dispatch: str = "dense",
    token_chunk: int = 4096,
    capacity_factor: float = 1.25,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y, aux_loss)."""
    B, S, d = x.shape
    dt = x.dtype
    T = B * S
    xt = x.reshape(T, d)
    logits = xt @ params["router"].astype(dt)  # (T, E)
    gates, aux, _ = router_topk(logits, cfg.experts_per_token)
    gates = gates.astype(dt)

    wg = params["w_gate"].astype(dt)
    wu = params["w_up"].astype(dt)
    wd = params["w_down"].astype(dt)

    if dispatch == "dense":
        nchunks = max(1, T // max(token_chunk, 1)) if T > token_chunk else 1
        while T % nchunks:
            nchunks -= 1
        xc = xt.reshape(nchunks, T // nchunks, d)
        gc = gates.reshape(nchunks, T // nchunks, -1)

        def chunk_fn(carry, inp):
            xi, gi = inp  # (Tc, d), (Tc, E)
            h1 = jnp.einsum("td,edf->etf", xi, wg)
            h2 = jnp.einsum("td,edf->etf", xi, wu)
            h = activation(h1, cfg.act) * h2
            h = shard(h, "experts", None, None)
            yi = jnp.einsum("etf,efd,te->td", h, wd, gi)
            return carry, yi

        _, yc = lax.scan(chunk_fn, 0, (xc, gc))
        y = yc.reshape(T, d)
    elif dispatch == "capacity":
        y = _moe_capacity(xt, gates, wg, wu, wd, cfg, capacity_factor)
    else:
        raise ValueError(f"unknown moe dispatch {dispatch!r}")

    if "shared" in params:
        y = y + mlp_apply(params["shared"], xt, cfg.act)
    return shard(y.reshape(B, S, d), "batch", "seq", "embed"), aux.astype(jnp.float32)


def _moe_capacity(xt, gates, wg, wu, wd, cfg, capacity_factor) -> jnp.ndarray:
    """Capacity-based gather/scatter dispatch: compute only routed tokens.

    Each (token, expert) pair with a non-zero gate is assigned a slot in the
    expert's buffer (capacity C ~= k*T/E * factor); overflow tokens are
    dropped (standard token-choice capacity semantics).
    """
    T, E = gates.shape
    k = cfg.experts_per_token
    C = max(int(math.ceil(k * T / E * capacity_factor)), 1)
    routed = gates > 0  # (T, E)
    # slot index = exclusive cumsum of routed within each expert column
    pos = jnp.cumsum(routed.astype(jnp.int32), axis=0) - 1  # (T, E)
    keep = routed & (pos < C)
    # Build (E, C) gather indices: token index occupying each slot.
    slot_token = jnp.zeros((E, C), jnp.int32)
    t_idx = jnp.broadcast_to(jnp.arange(T)[:, None], (T, E))
    flat_dest = jnp.where(keep, jnp.arange(E)[None, :] * C + pos, E * C)
    slot_token = (
        jnp.zeros((E * C + 1,), jnp.int32)
        .at[flat_dest.reshape(-1)]
        .max(t_idx.reshape(-1))[: E * C]
        .reshape(E, C)
    )
    occupied = (
        jnp.zeros((E * C + 1,), jnp.bool_)
        .at[flat_dest.reshape(-1)]
        .max(keep.reshape(-1))[: E * C]
        .reshape(E, C)
    )
    xe = jnp.take(xt, slot_token, axis=0)  # (E, C, d)
    xe = jnp.where(occupied[..., None], xe, 0)
    xe = shard(xe, "experts", None, None)
    h = activation(jnp.einsum("ecd,edf->ecf", xe, wg), cfg.act) * jnp.einsum(
        "ecd,edf->ecf", xe, wu
    )
    h = shard(h, "experts", None, None)
    ye = jnp.einsum("ecf,efd->ecd", h, wd)  # (E, C, d)
    g = gates[slot_token, jnp.arange(E)[:, None]]  # (E, C)
    ye = ye * (g * occupied)[..., None]
    y = jnp.zeros_like(xt).at[slot_token.reshape(-1)].add(ye.reshape(E * C, -1))
    return y
