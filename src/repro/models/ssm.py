"""Mamba2 (SSD — state-space duality) blocks, pure JAX.

Training/prefill uses the chunked SSD algorithm (arXiv:2405.21060 §6):
intra-chunk attention-like dual form + inter-chunk state recurrence, which
maps onto MXU-shaped matmuls. Decode uses the O(1) recurrent step.

The chunked core here is also the reference ("ref") semantics for the
Pallas kernel in ``repro/kernels/ssd_scan.py``.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import Params, _dense_init, init_rmsnorm, rmsnorm, shard


def init_mamba2(key, cfg) -> Params:
    d = cfg.d_model
    di = cfg.d_inner
    H, N, G = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_ngroups
    conv_ch = di + 2 * G * N
    pdt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    return {
        # order: [z (di), x (di), B (G*N), C (G*N), dt (H)]
        "in_proj": _dense_init(ks[0], d, 2 * di + 2 * G * N + H, pdt),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, conv_ch)) * 0.1).astype(pdt),
        "conv_b": jnp.zeros((conv_ch,), pdt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(pdt),
        "D": jnp.ones((H,), pdt),
        "dt_bias": jnp.zeros((H,), pdt),
        "norm": init_rmsnorm(di, pdt),
        "out_proj": _dense_init(ks[2], di, d, pdt),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv1d. x: (B,S,C); w: (K,C)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(K):
        out = out + xp[:, i : i + x.shape[1], :] * w[i]
    return out + b


def ssd_chunked(
    x: jnp.ndarray,  # (B, S, H, P)
    dt: jnp.ndarray,  # (B, S, H) (post-softplus)
    A: jnp.ndarray,  # (H,) negative decay rates
    Bm: jnp.ndarray,  # (B, S, G, N)
    Cm: jnp.ndarray,  # (B, S, G, N)
    chunk: int,
    init_state: Optional[jnp.ndarray] = None,  # (B, H, P, N)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD scan. Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    Bsz, S, H, Pd = x.shape
    G = Bm.shape[2]
    rep = H // G
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = S + pad
    nc = Sp // chunk

    f32 = jnp.float32
    xc = x.reshape(Bsz, nc, chunk, H, Pd).astype(f32)
    dtc = dt.reshape(Bsz, nc, chunk, H).astype(f32)
    Bc = Bm.reshape(Bsz, nc, chunk, G, N := Bm.shape[-1]).astype(f32)
    Cc = Cm.reshape(Bsz, nc, chunk, G, N).astype(f32)

    a = dtc * A.astype(f32)  # (B,nc,Q,H) log-decay per step
    cum = jnp.cumsum(a, axis=2)  # inclusive cumsum within chunk
    # intra-chunk "attention" matrix L[i,j] = exp(cum_i - cum_j) for i >= j.
    # Mask BEFORE the exp: the upper triangle has diff > 0 and exp would
    # overflow there — harmless forward (where() discards it) but the
    # overflowed branch poisons the backward pass with inf * 0 = NaN.
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,Q,Q,H)
    ii = jnp.arange(chunk)
    causal = (ii[:, None] >= ii[None, :])[None, None, :, :, None]
    L = jnp.exp(jnp.where(causal, diff, -jnp.inf))

    # weight each source step j by dt_j (discretized input)
    xdt = xc * dtc[..., None]  # (B,nc,Q,H,P)

    Bh = jnp.repeat(Bc, rep, axis=3)  # (B,nc,Q,H,N)
    Ch = jnp.repeat(Cc, rep, axis=3)

    # diagonal (intra-chunk) term
    scores = jnp.einsum("bnqhk,bnshk->bnqsh", Ch, Bh) * L  # (B,nc,Q,Q,H)
    y_diag = jnp.einsum("bnqsh,bnshp->bnqhp", scores, xdt)

    # per-chunk end states: S_n = sum_j exp(cum_last - cum_j) B_j x_j dt_j
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)  # (B,nc,Q,H)
    states = jnp.einsum("bnqhk,bnqh,bnqhp->bnhpk", Bh, decay_to_end, xdt)

    # inter-chunk recurrence over chunk states
    chunk_decay = jnp.exp(cum[:, :, -1, :])  # (B,nc,H)

    def scan_fn(R, inp):
        s_n, g_n = inp  # (B,H,P,N), (B,H)
        R_out = R  # state *entering* this chunk
        R_next = R * g_n[..., None, None] + s_n
        return R_next, R_out

    R0 = (
        init_state.astype(f32)
        if init_state is not None
        else jnp.zeros((Bsz, H, Pd, N), f32)
    )
    states_t = states.swapaxes(0, 1)  # (nc, B, H, P, N)
    decay_t = chunk_decay.swapaxes(0, 1)  # (nc, B, H)
    final, entering = lax.scan(scan_fn, R0, (states_t, decay_t))
    entering = entering.swapaxes(0, 1)  # (B, nc, H, P, N)

    # off-diagonal contribution: C_i · (exp(cum_i) * R_entering)
    decay_from_start = jnp.exp(cum)  # (B,nc,Q,H)
    y_off = jnp.einsum(
        "bnqhk,bnhpk,bnqh->bnqhp", Ch, entering, decay_from_start
    )

    y = (y_diag + y_off).reshape(Bsz, Sp, H, Pd)[:, :S]
    return y, final


def ssd_decode_step(
    x: jnp.ndarray,  # (B, H, P)
    dt: jnp.ndarray,  # (B, H)
    A: jnp.ndarray,  # (H,)
    Bm: jnp.ndarray,  # (B, G, N)
    Cm: jnp.ndarray,  # (B, G, N)
    state: jnp.ndarray,  # (B, H, P, N)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    f32 = jnp.float32
    H = x.shape[1]
    G = Bm.shape[1]
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=1).astype(f32)  # (B,H,N)
    Ch = jnp.repeat(Cm, rep, axis=1).astype(f32)
    decay = jnp.exp(dt.astype(f32) * A.astype(f32))  # (B,H)
    upd = jnp.einsum("bhp,bhk->bhpk", x.astype(f32) * dt.astype(f32)[..., None], Bh)
    new_state = state * decay[..., None, None] + upd
    y = jnp.einsum("bhpk,bhk->bhp", new_state, Ch)
    return y, new_state


def mamba2_apply(
    params: Params,
    x: jnp.ndarray,  # (B, S, d)
    cfg,
    *,
    state: Optional[Params] = None,  # decode: {"ssm": (B,H,P,N), "conv": (B,K-1,C)}
    use_kernel: bool = False,
) -> Tuple[jnp.ndarray, Optional[Params]]:
    B, S, d = x.shape
    di, H, N, G = cfg.d_inner, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_ngroups
    Pd = cfg.ssm_headdim
    dt_ = x.dtype

    proj = x @ params["in_proj"].astype(dt_)
    z, xs, Bm, Cm, dt_raw = jnp.split(
        proj, [di, 2 * di, 2 * di + G * N, 2 * di + 2 * G * N], axis=-1
    )
    conv_in = jnp.concatenate([xs, Bm, Cm], axis=-1)  # (B,S,conv_ch)

    if state is None or S > 1:
        # full-sequence path; with `state` given this is a PREFILL: the
        # chunked scan's final SSM state + the conv tail fill the decode state.
        conv_out = _causal_conv(
            conv_in, params["conv_w"].astype(dt_), params["conv_b"].astype(dt_)
        )
        conv_out = jax.nn.silu(conv_out)
        xs, Bm, Cm = jnp.split(conv_out, [di, di + G * N], axis=-1)
        xs = shard(xs.reshape(B, S, H, Pd), "batch", None, "heads", None)
        Bm = Bm.reshape(B, S, G, N)
        Cm = Cm.reshape(B, S, G, N)
        dtv = jax.nn.softplus(
            dt_raw.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32)
        )
        A = -jnp.exp(params["A_log"].astype(jnp.float32))
        if use_kernel and state is None:
            from repro.kernels import ops as kops

            y, final = kops.ssd_scan(xs, dtv, A, Bm, Cm, chunk=cfg.ssm_chunk)
        else:
            y, final = ssd_chunked(xs, dtv, A, Bm, Cm, cfg.ssm_chunk)
        y = y + xs.astype(jnp.float32) * params["D"].astype(jnp.float32)[:, None]
        y = y.reshape(B, S, di).astype(dt_)
        new_state = None
        if state is not None:
            K = cfg.ssm_conv
            tail = conv_in[:, -(K - 1):] if S >= K - 1 else jnp.concatenate(
                [state["conv"][:, S:], conv_in], axis=1
            )
            new_state = {
                "ssm": final.astype(state["ssm"].dtype),
                "conv": tail.astype(state["conv"].dtype),
            }
    else:
        # single-token decode
        assert S == 1
        K = cfg.ssm_conv
        conv_buf = jnp.concatenate(
            [state["conv"], conv_in.astype(state["conv"].dtype)], axis=1
        )  # (B, K, C)
        w = params["conv_w"].astype(dt_)
        conv_out = (conv_buf.astype(dt_) * w[None]).sum(axis=1) + params[
            "conv_b"
        ].astype(dt_)
        conv_out = jax.nn.silu(conv_out)  # (B, C)
        xs1, Bm1, Cm1 = jnp.split(conv_out, [di, di + G * N], axis=-1)
        xs1 = xs1.reshape(B, H, Pd)
        Bm1 = Bm1.reshape(B, G, N)
        Cm1 = Cm1.reshape(B, G, N)
        dtv = jax.nn.softplus(
            dt_raw[:, 0].astype(jnp.float32) + params["dt_bias"].astype(jnp.float32)
        )  # (B,H)
        A = -jnp.exp(params["A_log"].astype(jnp.float32))
        y1, ssm_new = ssd_decode_step(xs1, dtv, A, Bm1, Cm1, state["ssm"].astype(jnp.float32))
        y1 = y1 + xs1.astype(jnp.float32) * params["D"].astype(jnp.float32)[:, None]
        y = y1.reshape(B, 1, di).astype(dt_)
        new_state = {
            "ssm": ssm_new.astype(state["ssm"].dtype),
            "conv": conv_buf[:, 1:],
        }

    # gated RMSNorm then output projection
    y = rmsnorm(params["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = y @ params["out_proj"].astype(dt_)
    return shard(out, "batch", "seq", "embed"), new_state


def init_mamba2_state(cfg, batch: int, dtype) -> Params:
    di, H, N, G = cfg.d_inner, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_ngroups
    conv_ch = di + 2 * G * N
    return {
        "ssm": jnp.zeros((batch, H, cfg.ssm_headdim, N), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), dtype),
    }
