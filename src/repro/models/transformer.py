"""Unified decoder LM covering dense / MoE / SSM / hybrid / VLM families,
plus the Whisper-style encoder-decoder.

Layers are grouped by the smallest period of the per-layer block pattern and
stacked so the model body is a ``lax.scan`` over layer groups — this keeps
HLO size (and 512-device GSPMD partitioning time) independent of depth.
Weight-tied blocks (zamba2's shared attention) live outside the stack and
are closed over by the scan body.
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import BlockSpec, ModelConfig
from repro.models import layers as L
from repro.models import ssm as S
from repro.models.layers import Params, shard

# ---------------------------------------------------------------------------
# Block init / apply
# ---------------------------------------------------------------------------


def _init_block(key, cfg: ModelConfig, spec: BlockSpec, *, cross: bool = False) -> Params:
    ks = jax.random.split(key, 4)
    pdt = jnp.dtype(cfg.param_dtype)
    p: Params = {"ln1": L.init_rmsnorm(cfg.d_model, pdt)}
    if spec.mixer == "mamba":
        p["mixer"] = S.init_mamba2(ks[0], cfg)
    elif spec.mixer in ("attn", "attn_local"):
        p["mixer"] = L.init_attention(ks[0], cfg)
    elif spec.mixer == "shared_attn":
        pass  # weights live in the shared block
    else:
        raise ValueError(spec.mixer)
    if spec.ffn == "dense":
        p["ln2"] = L.init_rmsnorm(cfg.d_model, pdt)
        p["ffn"] = L.init_mlp(ks[1], cfg.d_model, cfg.d_ff, pdt)
    elif spec.ffn == "moe":
        p["ln2"] = L.init_rmsnorm(cfg.d_model, pdt)
        p["ffn"] = L.init_moe(ks[1], cfg)
    if cross:
        p["ln_cross"] = L.init_rmsnorm(cfg.d_model, pdt)
        p["cross"] = L.init_attention(ks[2], cfg, cross=True)
    return p


def _block_apply(
    params: Params,
    x: jnp.ndarray,
    cfg: ModelConfig,
    spec: BlockSpec,
    *,
    positions: jnp.ndarray,
    cache: Optional[Params] = None,
    cache_pos: Optional[jnp.ndarray] = None,
    shared: Optional[Params] = None,
    cross_kv: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
    causal: bool = True,
    moe_dispatch: str = "dense",
    use_ssd_kernel: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray, Optional[Params]]:
    """Returns (x, aux_loss, new_cache)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache = None

    if spec.mixer == "shared_attn":
        # zamba2: a full weight-tied attn+MLP block
        h = L.rmsnorm(shared["ln1"], x, cfg.norm_eps)
        att, new_attn_cache = L.attention_apply(
            shared["mixer"], h, cfg,
            positions=positions, causal=causal, window=0,
            cache=None if cache is None else cache,
            cache_pos=cache_pos,
        )
        x = x + att
        h = L.rmsnorm(shared["ln2"], x, cfg.norm_eps)
        x = x + L.mlp_apply(shared["ffn"], h, cfg.act)
        return x, aux, new_attn_cache

    h = L.rmsnorm(params["ln1"], x, cfg.norm_eps)
    if spec.mixer == "mamba":
        y, new_cache = S.mamba2_apply(
            params["mixer"], h, cfg, state=cache, use_kernel=use_ssd_kernel
        )
    else:
        window = cfg.sliding_window if spec.mixer == "attn_local" else (
            cfg.serve_window if (cache is not None and cfg.serve_window and cfg.sliding_window == 0) else 0
        )
        y, new_cache = L.attention_apply(
            params["mixer"], h, cfg,
            positions=positions, causal=causal, window=window,
            cache=cache, cache_pos=cache_pos,
        )
    x = x + y

    if cross_kv is not None and "cross" in params:
        h = L.rmsnorm(params["ln_cross"], x, cfg.norm_eps)
        y, _ = L.attention_apply(
            params["cross"], h, cfg, positions=positions, cross_kv=cross_kv
        )
        x = x + y

    if spec.ffn == "dense":
        h = L.rmsnorm(params["ln2"], x, cfg.norm_eps)
        x = x + L.mlp_apply(params["ffn"], h, cfg.act)
    elif spec.ffn == "moe":
        h = L.rmsnorm(params["ln2"], x, cfg.norm_eps)
        y, aux = L.moe_apply(params["ffn"], h, cfg, dispatch=moe_dispatch)
        x = x + y
    return x, aux, new_cache


# ---------------------------------------------------------------------------
# Periodic layer grouping
# ---------------------------------------------------------------------------


def layer_grouping(cfg: ModelConfig) -> Tuple[Tuple[BlockSpec, ...], int, int]:
    """Return (period_specs, n_groups, n_remainder)."""
    specs = cfg.block_specs()
    Lnum = len(specs)
    for p in range(1, Lnum + 1):
        if Lnum % p and (Lnum // p) * p + (Lnum % p) != Lnum:
            continue
        n = Lnum // p
        if n == 0:
            continue
        ok = all(specs[i] == specs[i % p] for i in range(n * p))
        if ok and n >= 1:
            return specs[:p], n, Lnum - n * p
    return specs, 1, 0


def _stack(trees: List[Params]) -> Params:
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


# ---------------------------------------------------------------------------
# Decoder-only LM (dense / moe / ssm / hybrid / vlm)
# ---------------------------------------------------------------------------


def init_lm(key, cfg: ModelConfig) -> Params:
    specs = cfg.block_specs()
    period, n_groups, rem = layer_grouping(cfg)
    P_len = len(period)
    ks = jax.random.split(key, 6)
    pdt = jnp.dtype(cfg.param_dtype)

    params: Params = {
        "embed": (jax.random.normal(ks[0], (cfg.padded_vocab, cfg.d_model)) * 0.02).astype(pdt),
        "final_norm": L.init_rmsnorm(cfg.d_model, pdt),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = L._dense_init(ks[1], cfg.d_model, cfg.padded_vocab, pdt)

    if any(s.mixer == "shared_attn" for s in specs):
        sk = jax.random.split(ks[2], 3)
        params["shared_block"] = {
            "ln1": L.init_rmsnorm(cfg.d_model, pdt),
            "mixer": L.init_attention(sk[0], cfg),
            "ln2": L.init_rmsnorm(cfg.d_model, pdt),
            "ffn": L.init_mlp(sk[1], cfg.d_model, cfg.d_ff, pdt),
        }
    if cfg.vision_tokens:
        params["projector"] = L._dense_init(ks[3], cfg.d_model, cfg.d_model, pdt)

    layer_keys = jax.random.split(ks[4], len(specs))
    stacks = []
    for j, spec in enumerate(period):
        group_params = [
            _init_block(layer_keys[g * P_len + j], cfg, spec) for g in range(n_groups)
        ]
        stacks.append(_stack(group_params))
    params["stack"] = stacks
    params["tail"] = [
        _init_block(layer_keys[n_groups * P_len + r], cfg, specs[n_groups * P_len + r])
        for r in range(rem)
    ]
    return params


def _run_stack(
    params: Params,
    x: jnp.ndarray,
    cfg: ModelConfig,
    *,
    positions: jnp.ndarray,
    caches: Optional[List[Params]] = None,  # one stacked cache per period slot
    tail_caches: Optional[List[Params]] = None,
    cache_pos: Optional[jnp.ndarray] = None,
    cross_kv: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
    causal: bool = True,
    moe_dispatch: str = "dense",
    use_ssd_kernel: bool = False,
):
    period, n_groups, rem = layer_grouping(cfg)
    shared = params.get("shared_block")
    specs = cfg.block_specs()

    def group_body(carry, xs):
        h, aux = carry
        stacked_params, stacked_caches = xs
        new_caches = []
        for j, spec in enumerate(period):
            cache_j = None if stacked_caches is None else stacked_caches[j]
            h, a, nc = _block_apply(
                stacked_params[j], h, cfg, spec,
                positions=positions, cache=cache_j, cache_pos=cache_pos,
                shared=shared, cross_kv=cross_kv, causal=causal,
                moe_dispatch=moe_dispatch, use_ssd_kernel=use_ssd_kernel,
            )
            new_caches.append(nc)
        if stacked_caches is None:
            return (h, aux + a), None
        return (h, aux + a), new_caches

    body = group_body
    if cfg.remat and caches is None:
        body = jax.checkpoint(group_body)

    xs = (params["stack"], caches)
    (x, aux), new_caches = lax.scan(body, (x, jnp.zeros((), jnp.float32)), xs)

    new_tail = []
    for r, tp in enumerate(params["tail"]):
        spec = specs[n_groups * len(period) + r]
        tc = None if tail_caches is None else tail_caches[r]
        x, a, nc = _block_apply(
            tp, x, cfg, spec,
            positions=positions, cache=tc, cache_pos=cache_pos,
            shared=shared, cross_kv=cross_kv, causal=causal,
            moe_dispatch=moe_dispatch, use_ssd_kernel=use_ssd_kernel,
        )
        aux = aux + a
        new_tail.append(nc)
    return x, aux, new_caches, new_tail


def _unembed(params: Params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    dt = x.dtype
    if cfg.tie_embeddings:
        logits = x @ params["embed"].astype(dt).T
    else:
        logits = x @ params["unembed"].astype(dt)
    if cfg.padded_vocab != cfg.vocab_size:
        logits = logits[..., : cfg.vocab_size]
    logits = L.softcap(logits.astype(jnp.float32), cfg.final_logit_softcap)
    return shard(logits, "batch", None, "vocab")


def lm_forward(
    params: Params,
    tokens: jnp.ndarray,  # (B, S)
    cfg: ModelConfig,
    *,
    patches: Optional[jnp.ndarray] = None,  # VLM stub embeddings (B, V, d)
    moe_dispatch: str = "dense",
    use_ssd_kernel: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence forward (train / prefill). Returns (logits, aux)."""
    dt = jnp.dtype(cfg.dtype)
    x = params["embed"].astype(dt)[tokens]
    if cfg.vision_tokens and patches is not None:
        pe = patches.astype(dt) @ params["projector"].astype(dt)
        x = jnp.concatenate([pe, x], axis=1)
    x = shard(x, "batch", "seq", "embed")
    positions = jnp.arange(x.shape[1])
    x, aux, _, _ = _run_stack(
        params, x, cfg, positions=positions,
        moe_dispatch=moe_dispatch, use_ssd_kernel=use_ssd_kernel,
    )
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if cfg.vision_tokens and patches is not None:
        x = x[:, patches.shape[1]:]
    return _unembed(params, x, cfg), aux


# ---------------------------------------------------------------------------
# Decode state (serve_step)
# ---------------------------------------------------------------------------


def init_decode_state(cfg: ModelConfig, batch: int, seq_len: int) -> Params:
    """KV caches / SSM states for every layer, grouped like the param stack."""
    period, n_groups, rem = layer_grouping(cfg)
    dt = jnp.dtype(cfg.dtype)
    specs = cfg.block_specs()

    def one(spec: BlockSpec) -> Params:
        if spec.mixer == "mamba":
            return S.init_mamba2_state(cfg, batch, dt)
        window = cfg.sliding_window if spec.mixer == "attn_local" else cfg.serve_window
        return L.init_decode_cache(cfg, batch, seq_len, window, dt)

    stacked = [
        jax.tree.map(lambda *xs: jnp.stack(xs), *[one(spec) for _ in range(n_groups)])
        if n_groups > 1
        else jax.tree.map(lambda x: x[None], one(spec))
        for spec in period
    ]
    tail = [one(specs[n_groups * len(period) + r]) for r in range(rem)]
    return {"pos": jnp.zeros((), jnp.int32), "layers": stacked, "tail": tail}


def lm_prefill(
    params: Params,
    state: Params,
    tokens: jnp.ndarray,  # (B, S) the full prompt
    cfg: ModelConfig,
    *,
    patches: Optional[jnp.ndarray] = None,
    moe_dispatch: str = "dense",
) -> Tuple[jnp.ndarray, Params]:
    """One-shot prefill: runs the prompt through the stack, filling every
    layer's KV cache / SSM state. Returns (last-token logits, state ready
    for decode at position S)."""
    dt = jnp.dtype(cfg.dtype)
    B, S = tokens.shape
    x = params["embed"].astype(dt)[tokens]
    if cfg.vision_tokens and patches is not None:
        pe = patches.astype(dt) @ params["projector"].astype(dt)
        x = jnp.concatenate([pe, x], axis=1)
    x = shard(x, "batch", "seq", "embed")
    total = x.shape[1]
    positions = jnp.arange(total)
    x, _, new_caches, new_tail = _run_stack(
        params, x, cfg,
        positions=positions,
        caches=state["layers"], tail_caches=state["tail"],
        cache_pos=jnp.zeros((), jnp.int32),
        moe_dispatch=moe_dispatch,
    )
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = _unembed(params, x[:, -1:], cfg)[:, 0]
    return logits, {
        "pos": jnp.asarray(total, jnp.int32),
        "layers": new_caches,
        "tail": new_tail,
    }


def lm_decode_step(
    params: Params,
    state: Params,
    token: jnp.ndarray,  # (B, 1)
    cfg: ModelConfig,
    *,
    moe_dispatch: str = "dense",
) -> Tuple[jnp.ndarray, Params]:
    """One decode step: returns (logits (B, vocab), new_state)."""
    dt = jnp.dtype(cfg.dtype)
    pos = state["pos"]
    x = params["embed"].astype(dt)[token]
    x = shard(x, "batch", "seq", "embed")
    positions = pos[None]
    x, _, new_caches, new_tail = _run_stack(
        params, x, cfg,
        positions=positions,
        caches=state["layers"], tail_caches=state["tail"], cache_pos=pos,
        moe_dispatch=moe_dispatch,
    )
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = _unembed(params, x, cfg)[:, 0]
    return logits, {"pos": pos + 1, "layers": new_caches, "tail": new_tail}


# ---------------------------------------------------------------------------
# Encoder-decoder (whisper)
# ---------------------------------------------------------------------------


def _sinusoidal(positions: jnp.ndarray, d: int) -> jnp.ndarray:
    half = d // 2
    freqs = jnp.exp(-math.log(10_000.0) * jnp.arange(half) / max(half - 1, 1))
    ang = positions[:, None].astype(jnp.float32) * freqs[None]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def init_encdec(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 4)
    pdt = jnp.dtype(cfg.param_dtype)
    spec = BlockSpec("attn", "dense")
    enc_keys = jax.random.split(ks[0], cfg.encoder_layers)
    dec_keys = jax.random.split(ks[1], cfg.num_layers)
    return {
        "embed": (jax.random.normal(ks[2], (cfg.padded_vocab, cfg.d_model)) * 0.02).astype(pdt),
        "encoder": _stack([_init_block(k, cfg, spec) for k in enc_keys]),
        "decoder": _stack([_init_block(k, cfg, spec, cross=True) for k in dec_keys]),
        "enc_norm": L.init_rmsnorm(cfg.d_model, pdt),
        "final_norm": L.init_rmsnorm(cfg.d_model, pdt),
        "unembed": L._dense_init(ks[3], cfg.d_model, cfg.padded_vocab, pdt),
    }


def encode(params: Params, frames: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """frames: (B, enc_seq, d) stubbed conv-frontend output."""
    dt = jnp.dtype(cfg.dtype)
    Senc = frames.shape[1]
    x = frames.astype(dt) + _sinusoidal(jnp.arange(Senc), cfg.d_model).astype(dt)
    x = shard(x, "batch", "seq", "embed")
    positions = jnp.arange(Senc)
    spec = BlockSpec("attn", "dense")

    def body(h, p):
        h, _, _ = _block_apply(p, h, cfg, spec, positions=positions, causal=False)
        return h, None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = lax.scan(body, x, params["encoder"])
    return L.rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def _cross_kv(block_params: Params, enc_out: jnp.ndarray, cfg: ModelConfig):
    B, Senc, _ = enc_out.shape
    hd, K = cfg.resolved_head_dim, cfg.num_kv_heads
    dt = enc_out.dtype
    k = (enc_out @ block_params["cross"]["wk"].astype(dt)).reshape(B, Senc, K, hd)
    v = (enc_out @ block_params["cross"]["wv"].astype(dt)).reshape(B, Senc, K, hd)
    return k, v


def encdec_forward(
    params: Params,
    frames: jnp.ndarray,  # (B, enc_seq, d)
    tokens: jnp.ndarray,  # (B, S)
    cfg: ModelConfig,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    dt = jnp.dtype(cfg.dtype)
    enc_out = encode(params, frames, cfg)
    B, Sdec = tokens.shape
    x = params["embed"].astype(dt)[tokens]
    x = x + _sinusoidal(jnp.arange(Sdec), cfg.d_model).astype(dt)
    x = shard(x, "batch", "seq", "embed")
    positions = jnp.arange(Sdec)
    spec = BlockSpec("attn", "dense")

    def body(h, p):
        ckv = _cross_kv(p, enc_out, cfg)
        h, _, _ = _block_apply(
            p, h, cfg, spec, positions=positions, cross_kv=ckv, causal=True
        )
        return h, None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = lax.scan(body, x, params["decoder"])
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = (x @ params["unembed"].astype(dt))[..., : cfg.vocab_size]
    return logits.astype(jnp.float32), jnp.zeros((), jnp.float32)


def init_encdec_state(cfg: ModelConfig, batch: int, seq_len: int, frames=None, params=None) -> Params:
    """Decoder self-attn caches + precomputed cross K/V."""
    dt = jnp.dtype(cfg.dtype)
    hd, K = cfg.resolved_head_dim, cfg.num_kv_heads
    Lnum = cfg.num_layers
    caches = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[L.init_decode_cache(cfg, batch, seq_len, 0, dt) for _ in range(Lnum)],
    )
    cross = {
        "k": jnp.zeros((Lnum, batch, cfg.encoder_seq, K, hd), dt),
        "v": jnp.zeros((Lnum, batch, cfg.encoder_seq, K, hd), dt),
    }
    return {"pos": jnp.zeros((), jnp.int32), "self": caches, "cross": cross}


def encdec_prefill(
    params: Params,
    state: Params,
    frames: jnp.ndarray,  # (B, enc_seq, d)
    tokens: jnp.ndarray,  # (B, S) decoder prompt
    cfg: ModelConfig,
) -> Tuple[jnp.ndarray, Params]:
    """Encode once, precompute per-layer cross K/V, prefill decoder caches."""
    dt = jnp.dtype(cfg.dtype)
    enc_out = encode(params, frames, cfg)
    B, S = tokens.shape
    x = params["embed"].astype(dt)[tokens]
    x = x + _sinusoidal(jnp.arange(S), cfg.d_model).astype(dt)
    positions = jnp.arange(S)
    spec = BlockSpec("attn", "dense")

    def body(carry, xs):
        h = carry
        p, cache = xs
        ck, cv = _cross_kv(p, enc_out, cfg)
        h, _, nc = _block_apply(
            p, h, cfg, spec,
            positions=positions, cache=cache,
            cache_pos=jnp.zeros((), jnp.int32),
            cross_kv=(ck, cv), causal=True,
        )
        return h, (nc, ck, cv)

    x, (new_caches, cks, cvs) = lax.scan(body, x, (params["decoder"], state["self"]))
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = (x @ params["unembed"].astype(dt)).astype(jnp.float32)[:, -1, : cfg.vocab_size]
    return logits, {
        "pos": jnp.asarray(S, jnp.int32),
        "self": new_caches,
        "cross": {"k": cks, "v": cvs},
    }


def encdec_decode_step(
    params: Params,
    state: Params,
    token: jnp.ndarray,  # (B, 1)
    cfg: ModelConfig,
) -> Tuple[jnp.ndarray, Params]:
    dt = jnp.dtype(cfg.dtype)
    pos = state["pos"]
    B = token.shape[0]
    x = params["embed"].astype(dt)[token]
    x = x + _sinusoidal(pos[None], cfg.d_model).astype(dt)
    positions = pos[None]
    spec = BlockSpec("attn", "dense")

    def body(carry, xs):
        h = carry
        p, cache, ck, cv = xs
        h, _, nc = _block_apply(
            p, h, cfg, spec,
            positions=positions, cache=cache, cache_pos=pos,
            cross_kv=(ck, cv), causal=True,
        )
        return h, nc

    x, new_caches = lax.scan(
        body, x, (params["decoder"], state["self"], state["cross"]["k"], state["cross"]["v"])
    )
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = (x @ params["unembed"].astype(dt)).astype(jnp.float32)[:, 0, : cfg.vocab_size]
    return logits, {"pos": pos + 1, "self": new_caches, "cross": state["cross"]}
