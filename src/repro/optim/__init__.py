from repro.optim.optimizers import (
    Optimizer,
    sgd,
    adam,
    adamw,
    apply_updates,
    global_norm,
    clip_by_global_norm,
)
from repro.optim.schedules import constant, cosine, warmup_cosine

__all__ = [
    "Optimizer",
    "sgd",
    "adam",
    "adamw",
    "apply_updates",
    "global_norm",
    "clip_by_global_norm",
    "constant",
    "cosine",
    "warmup_cosine",
]
