"""Minimal optimizer library (optax is not available offline).

An :class:`Optimizer` is a pair of pure functions:
  init(params)                         -> opt_state
  update(grads, opt_state, params, lr) -> (updates, opt_state)
``updates`` are *descent* directions: apply with ``apply_updates``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jnp.ndarray], Tuple[Any, Any]]
    name: str = "optimizer"


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, tree), norm


def apply_updates(params, updates):
    return jax.tree.map(
        lambda p, u: (p.astype(jnp.float32) - u.astype(jnp.float32)).astype(p.dtype),
        params,
        updates,
    )


def sgd(momentum: float = 0.0, nesterov: bool = False) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return ()
        return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)

    def update(grads, state, params, lr):
        if momentum == 0.0:
            return jax.tree.map(lambda g: lr * g.astype(jnp.float32), grads), state
        new_m = jax.tree.map(
            lambda m, g: momentum * m + g.astype(jnp.float32), state, grads
        )
        if nesterov:
            upd = jax.tree.map(
                lambda m, g: lr * (momentum * m + g.astype(jnp.float32)), new_m, grads
            )
        else:
            upd = jax.tree.map(lambda m: lr * m, new_m)
        return upd, new_m

    return Optimizer(init, update, f"sgd(m={momentum})")


def adam(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros_like(p, jnp.float32)
        return {
            "mu": jax.tree.map(z, params),
            "nu": jax.tree.map(z, params),
            "t": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, lr):
        t = state["t"] + 1
        mu = jax.tree.map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state["mu"], grads
        )
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["nu"],
            grads,
        )
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)
        upd = jax.tree.map(
            lambda m, v: lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps), mu, nu
        )
        return upd, {"mu": mu, "nu": nu, "t": t}

    return Optimizer(init, update, "adam")


def adamw(
    b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8, weight_decay: float = 0.01
) -> Optimizer:
    base = adam(b1, b2, eps)

    def update(grads, state, params, lr):
        upd, state2 = base.update(grads, state, params, lr)
        upd = jax.tree.map(
            lambda u, p: u + lr * weight_decay * p.astype(jnp.float32), upd, params
        )
        return upd, state2

    return Optimizer(base.init, update, "adamw")
