from repro.train.steps import (
    lm_loss,
    build_train_step,
    build_serve_step,
    init_train_state,
)

__all__ = ["lm_loss", "build_train_step", "build_serve_step", "init_train_state"]
