from repro.train.steps import (
    lm_loss,
    build_train_step,
    build_serve_step,
    init_train_state,
)
from repro.train.trainer import P2PTrainer

__all__ = [
    "lm_loss",
    "build_train_step",
    "build_serve_step",
    "init_train_state",
    "P2PTrainer",
]
