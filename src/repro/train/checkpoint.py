"""Checkpointing: pytree <-> npz with path-flattened keys + JSON metadata.

Works for params, optimizer states and mailbox buffers; sharded arrays are
fully gathered before save (fine at the scales we train on CPU; the dry-run
scale never checkpoints).
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        flat[key] = np.asarray(leaf)
    return flat


def save(path: str, tree: Any, *, step: int = 0, extra: Optional[dict] = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    np.savez(path if path.endswith(".npz") else path + ".npz", **flat)
    meta = {"step": step, "treedef": _treedef_repr(tree), **(extra or {})}
    with open(_meta_path(path), "w") as f:
        json.dump(meta, f)


def restore(path: str, like: Any) -> Tuple[Any, dict]:
    """Restore into the structure of ``like`` (shapes/dtypes must match)."""
    npz = np.load(path if path.endswith(".npz") else path + ".npz")
    flat_like = _flatten(like)
    missing = set(flat_like) - set(npz.files)
    if missing:
        raise ValueError(f"checkpoint missing keys: {sorted(missing)[:5]} ...")
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    keys = [
        "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        for path, _ in jax.tree_util.tree_flatten_with_path(like)[0]
    ]
    new_leaves = []
    for key, leaf in zip(keys, leaves_like):
        arr = npz[key]
        if arr.shape != leaf.shape:
            raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
        new_leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    meta = {}
    mp = _meta_path(path)
    if os.path.exists(mp):
        with open(mp) as f:
            meta = json.load(f)
    return jax.tree_util.tree_unflatten(treedef, new_leaves), meta


def _meta_path(path: str) -> str:
    base = path[:-4] if path.endswith(".npz") else path
    return base + ".meta.json"


def _treedef_repr(tree) -> str:
    return re.sub(r"\s+", " ", str(jax.tree_util.tree_structure(tree)))[:2000]
