"""Checkpointing: pytree <-> npz with path-flattened keys + JSON metadata.

Works for params, optimizer states and mailbox buffers; sharded arrays are
fully gathered before save (fine at the scales we train on CPU; the dry-run
scale never checkpoints).

Formats are versioned through the ``format`` metadata key:

* (absent) / ``"pytree/v1"`` — a bare pytree, typically params-only
  (what ``save`` writes).
* ``"train-state/v2"`` — a full :class:`~repro.core.p2p.TrainState`
  (params + opt state + step + rng + exchange mailbox), written by
  :func:`save_state`. :func:`restore_state` reads either: a v1 params-only
  checkpoint restores into ``like.params`` and keeps the rest fresh.
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

V1_FORMAT = "pytree/v1"
STATE_FORMAT = "train-state/v2"


def _path_str(p) -> str:
    # DictKey -> .key, SequenceKey -> .idx, GetAttrKey (dataclass pytrees
    # like TrainState) -> .name; fall back to str(p) otherwise.
    for attr in ("key", "idx", "name"):
        if hasattr(p, attr):
            return str(getattr(p, attr))
    return str(p)


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(path: str, tree: Any, *, step: int = 0, extra: Optional[dict] = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    np.savez(path if path.endswith(".npz") else path + ".npz", **flat)
    meta = {"step": step, "treedef": _treedef_repr(tree),
            "format": V1_FORMAT, **(extra or {})}
    with open(_meta_path(path), "w") as f:
        json.dump(meta, f)


def restore(path: str, like: Any) -> Tuple[Any, dict]:
    """Restore into the structure of ``like`` (shapes/dtypes must match)."""
    npz = np.load(path if path.endswith(".npz") else path + ".npz")
    flat_like = _flatten(like)
    missing = set(flat_like) - set(npz.files)
    if missing:
        raise ValueError(f"checkpoint missing keys: {sorted(missing)[:5]} ...")
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    keys = [
        "/".join(_path_str(p) for p in path)
        for path, _ in jax.tree_util.tree_flatten_with_path(like)[0]
    ]
    new_leaves = []
    for key, leaf in zip(keys, leaves_like):
        arr = npz[key]
        if arr.shape != leaf.shape:
            raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
        new_leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    meta = {}
    mp = _meta_path(path)
    if os.path.exists(mp):
        with open(mp) as f:
            meta = json.load(f)
    return jax.tree_util.tree_unflatten(treedef, new_leaves), meta


def save_state(path: str, state, *, extra: Optional[dict] = None) -> None:
    """Save a full TrainState (v2 format): params, opt state, step, rng, mailbox."""
    from repro.core.p2p import as_train_state

    state = as_train_state(state)
    save(
        path, state, step=int(jax.device_get(state.step)),
        extra={"format": STATE_FORMAT, **(extra or {})},
    )


def restore_state(path: str, like) -> Tuple[Any, dict]:
    """Restore a TrainState from a v2 checkpoint, or params-only from v1.

    ``like`` supplies the target structure (shapes/dtypes must match). A v1
    / unversioned checkpoint holds bare params: they restore into
    ``like.params`` and the optimizer state / step / rng stay as in ``like``.
    """
    from repro.core.p2p import as_train_state

    like = as_train_state(like)
    meta = {}
    mp = _meta_path(path)
    if os.path.exists(mp):
        with open(mp) as f:
            meta = json.load(f)
    if meta.get("format") == STATE_FORMAT:
        # Optional TrainState fields (the async mailbox ring, the EF
        # residual bank) may be absent from the saved checkpoint — e.g. a
        # v2 state saved under a sync protocol restored into an async
        # `like`, or a pre-EF checkpoint restored with ef=True. Restore
        # the saved fields and keep `like`'s cold buffers for the rest.
        absent = []
        if like.mailbox is not None or like.ef is not None:
            with np.load(path if path.endswith(".npz") else path + ".npz") as npz:
                for fieldname in ("mailbox", "ef"):
                    if getattr(like, fieldname) is None:
                        continue
                    saved = any(
                        k == fieldname or k.startswith(fieldname + "/")
                        for k in npz.files
                    )
                    if not saved:
                        absent.append(fieldname)
        if absent:
            core, cmeta = restore(
                path, like.replace(**{f: None for f in absent})
            )
            return (
                core.replace(**{f: getattr(like, f) for f in absent}),
                cmeta,
            )
        return restore(path, like)
    params, pmeta = restore(path, like.params)
    return like.replace(params=params), {**meta, **pmeta}


def _meta_path(path: str) -> str:
    base = path[:-4] if path.endswith(".npz") else path
    return base + ".meta.json"


def _treedef_repr(tree) -> str:
    return re.sub(r"\s+", " ", str(jax.tree_util.tree_structure(tree)))[:2000]
