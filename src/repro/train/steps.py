"""Train/serve step builders tying models + optimizers + the P2P core."""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro import models
from repro.configs.base import ModelConfig
from repro.core.p2p import TrainState, Topology, build_p2p_train_step
from repro.optim import Optimizer


def lm_loss(
    params,
    batch: Dict[str, jnp.ndarray],
    cfg: ModelConfig,
    *,
    moe_dispatch: str = "dense",
    use_ssd_kernel: bool = False,
    z_loss: float = 1e-4,
):
    """Next-token cross-entropy (+ router aux + z-loss). Returns (loss, aux)."""
    logits, aux = models.forward(
        params, batch, cfg, moe_dispatch=moe_dispatch, use_ssd_kernel=use_ssd_kernel
    )
    labels = batch["labels"]
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    ce = (lse - gold).mean()
    loss = ce
    if z_loss:
        loss = loss + z_loss * jnp.square(lse).mean()
    if cfg.num_experts:
        loss = loss + cfg.router_aux_coef * aux
    return loss, ce


def init_train_state(
    key: jax.Array, cfg: ModelConfig, optimizer: Optimizer
) -> TrainState:
    params = models.init_model(key, cfg)
    return TrainState(
        params=params,
        opt_state=optimizer.init(params),
        step=jnp.zeros((), jnp.int32),
        key=jax.random.fold_in(key, 1),
    )


def build_train_step(
    cfg: ModelConfig,
    optimizer: Optimizer,
    topo: Topology,
    mesh,
    schedule: Callable,
    *,
    moe_dispatch: str = "dense",
    use_ssd_kernel: bool = False,
):
    loss_fn = partial(
        lm_loss, cfg=cfg, moe_dispatch=moe_dispatch, use_ssd_kernel=use_ssd_kernel
    )
    return build_p2p_train_step(
        lambda p, b: loss_fn(p, b), optimizer, topo, mesh, schedule
    )


def build_serve_step(cfg: ModelConfig, *, moe_dispatch: str = "dense"):
    """serve_step(params, state, token) -> (logits, new_state)."""

    def serve_step(params, state, token):
        return models.decode_step(
            params, state, token, cfg, moe_dispatch=moe_dispatch
        )

    return serve_step
