"""P2PTrainer — the one-object facade over the P2P training stack.

Bundles what every driver used to assemble by hand (topology resolution,
exchange-protocol lookup, step building, state init, checkpointing, wire
cost) behind a single API::

    trainer = P2PTrainer(cfg, optimizer, topo, mesh, schedule)
    state = trainer.init_state(jax.random.PRNGKey(0))
    state, metrics = trainer.step(state, batch)
    print(trainer.comm_cost().seconds_per_step)

Used by ``launch/train.py``, ``examples/p2p_serverless_train.py`` and the
benchmarks; ``core/simulate.py`` shares the same ExchangeProtocol
implementations through the registry.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Optional, Sequence, Union

import jax

from repro.configs.base import ModelConfig
from repro.core.cost import CommCost, compare_backends
from repro.core.events import (
    AllocationPolicy,
    InstanceConfig,
    LinkModel,
    RuntimeConfig,
)
from repro.core.exchange import ExchangeProtocol
from repro.core.p2p import (
    TrainState,
    Topology,
    as_train_state,
    build_p2p_train_step,
    exchange_context,
    init_ef,
)
from repro.core.robust import AdversarySpec
from repro.core.scheduler import (
    FleetExecutor,
    FleetPlan,
    FleetReport,
    Scheduler,
    evaluate_candidates,
    get_scheduler,
    standard_candidates,
)
from repro.core.serverless import ExecutionReport, ServerlessExecutor
from repro.core.shard import ShardPlan
from repro.optim import Optimizer
from repro.train import checkpoint as ckpt
from repro.train.steps import init_train_state, lm_loss


class P2PTrainer:
    """Facade over loss/step/exchange/state for P2P training on a mesh."""

    def __init__(
        self,
        cfg: ModelConfig,
        optimizer: Optimizer,
        topo: Topology,
        mesh,
        schedule: Callable,
        *,
        loss_fn: Optional[Callable] = None,  # (params, batch) -> (loss, aux)
        moe_dispatch: str = "dense",
        use_ssd_kernel: bool = False,
        jit: bool = True,
        runtime: Optional[RuntimeConfig] = None,  # serverless fault/cold-start model
        allocation: Union[str, AllocationPolicy] = "static",  # per-epoch memory sizing
        graph: Any = None,  # overlay override: name ("ring", "gossip:3") or PeerGraph
        backend: str = "serverless",  # which accounting model `account()` prices
        instance_type: str = "t2.large",  # EC2 tier of the instance baseline
        instance_config: Optional[InstanceConfig] = None,  # boot/churn model
        adversary: Optional[AdversarySpec] = None,  # Byzantine peers on the mesh
        ef: Optional[bool] = None,  # error feedback override (else topo.ef)
        scheduler: Union[str, Scheduler, None] = None,  # cost-aware plan picker
    ):
        import dataclasses as _dc

        if backend not in ("serverless", "instance"):
            raise ValueError(
                f"backend must be 'serverless' or 'instance', got {backend!r}"
            )
        if graph is not None:
            topo = _dc.replace(topo, graph=graph)
        if ef is not None:
            topo = _dc.replace(topo, ef=bool(ef))
        self.cfg = cfg
        self.optimizer = optimizer
        self.topo = topo
        self.mesh = mesh
        self.schedule = schedule
        self.backend = backend
        self.instance_type = instance_type
        self.instance_config = instance_config or InstanceConfig()
        # raw arg, so FleetExecutor's per-tier defaults (GPU boot preset)
        # apply unless the caller explicitly pinned a config
        self._fleet_instance_config = instance_config
        self.runtime_config = runtime or RuntimeConfig()
        self.allocation = allocation
        if isinstance(scheduler, str):
            scheduler = get_scheduler(scheduler)
        self.scheduler: Optional[Scheduler] = scheduler
        self._serverless: Optional[ServerlessExecutor] = None
        self._instance_executor: Optional[ServerlessExecutor] = None
        self._fleet: Optional[FleetExecutor] = None
        self.protocol: ExchangeProtocol = topo.protocol()
        self.ctx = exchange_context(topo, mesh)
        if loss_fn is None:
            loss_fn = partial(
                lm_loss, cfg=cfg, moe_dispatch=moe_dispatch,
                use_ssd_kernel=use_ssd_kernel,
            )
        self.loss_fn = loss_fn
        self.adversary = adversary
        self.step_fn = build_p2p_train_step(
            loss_fn, optimizer, topo, mesh, schedule, adversary=adversary
        )
        self._step = jax.jit(self.step_fn) if jit else self.step_fn

    @property
    def num_peers(self) -> int:
        return self.ctx.num_peers

    @property
    def graph(self):
        """The resolved :class:`~repro.core.graph.PeerGraph` overlay."""
        return self.ctx.graph

    def shard_plan(self, params_like=None) -> Optional[ShardPlan]:
        """The sharded-exchange layout (one shard per peer), or ``None``
        when the active protocol exchanges whole pytrees."""
        if not self.protocol.sharded:
            return None
        if params_like is None:
            params_like = self._params_like()
        return self.protocol.plan(params_like, self.ctx)

    def _params_like(self):
        return jax.eval_shape(
            lambda: init_train_state(jax.random.PRNGKey(0), self.cfg,
                                     self.optimizer)
        ).params

    # -- state ---------------------------------------------------------------
    def init_state(self, key: jax.Array) -> TrainState:
        state = init_train_state(key, self.cfg, self.optimizer)
        if self.topo.peer_axes:
            mailbox = self.protocol.init_state(state.params, self.ctx)
            if mailbox is not None:
                state = state.replace(mailbox=mailbox)
            if self.topo.ef:
                # EF residual bank (zeros): leaves (P, *param) fp32. Kept for
                # lossless protocols too — their residual stays identically
                # zero (combine_ef ships grads verbatim), which IS the
                # equivalence rail the tests pin down.
                state = state.replace(
                    ef=init_ef(state.params, self.ctx.num_peers)
                )
        return state

    # -- stepping ------------------------------------------------------------
    def step(self, state, batch):
        """One P2P train step; returns (new_state, metrics)."""
        return self._step(as_train_state(state), batch)

    # -- accounting ----------------------------------------------------------
    def wire_bytes_per_step(self, params_like=None) -> int:
        """Bytes one peer publishes per step under the active protocol."""
        if params_like is None:
            params_like = self._params_like()
        return self.protocol.wire_bytes(params_like, self.ctx)

    def comm_cost(
        self, params_like=None, *, bandwidth_bps: float = 1e9,
        usd_per_gb: float = 0.0,
    ) -> CommCost:
        """Per-step exchange cost, straight from the protocol's byte counts
        (degree-aware: per-edge payload x the overlay graph's degree)."""
        if params_like is None:
            params_like = self._params_like()
        plan = self.shard_plan(params_like)
        return CommCost(
            wire_bytes_per_step=self.protocol.wire_bytes(params_like, self.ctx),
            bandwidth_bps=bandwidth_bps,
            usd_per_gb_egress=usd_per_gb,
            bytes_per_edge=(
                self.protocol.wire_bytes_per_edge(params_like, self.ctx)
                if self.protocol.decomposes_per_edge else 0
            ),
            degree=self.ctx.degree,
            graph_name=self.ctx.graph.name if self.ctx.graph is not None else "full",
            num_shards=plan.num_shards if plan is not None else 1,
            shard_bytes=(
                plan.shard_bytes(self.ctx.wire_dtype) if plan is not None else 0
            ),
        )

    @property
    def serverless(self) -> ServerlessExecutor:
        """The trainer's serverless accountant, built from ``runtime`` /
        ``allocation``. Warm pools and allocation history persist across
        :meth:`account_serverless` calls, like a long-lived deployment."""
        if self._serverless is None:
            self._serverless = ServerlessExecutor(
                backend="serverless",
                runtime=self.runtime_config,
                allocation=self.allocation,
            )
        return self._serverless

    def account_serverless(
        self,
        per_batch_s: Sequence[float],
        *,
        batch_bytes: int = 0,
        epoch: Optional[int] = None,
        peer: Any = 0,
        egress_bytes: int = 0,  # e.g. steps x comm_cost().wire_bytes_per_step
        usd_per_gb_egress: float = 0.0,
    ) -> ExecutionReport:
        """Price measured per-batch times under the serverless runtime.

        On the TPU path the Lambda fan-out is the mesh axis, so the math
        already ran; this method answers "what would these batch times have
        taken/cost on Lambda" under the configured fault/cold-start model
        and allocation policy. Model bytes come from the config's abstract
        parameter shapes (fp32), no allocation happens.
        """
        return self.serverless.simulate(
            per_batch_s,
            model_bytes=self.model_bytes,
            batch_bytes=batch_bytes,
            epoch=epoch,
            peer=peer,
            egress_bytes=egress_bytes,
            usd_per_gb_egress=usd_per_gb_egress,
        )

    @property
    def model_bytes(self) -> int:
        """fp32 parameter bytes from the config's abstract shapes (no
        allocation happens) — sizes both Lambda memory and the instance
        baseline's memory-constrained splitting."""
        if not hasattr(self, "_model_bytes"):
            shapes = jax.eval_shape(
                lambda: init_train_state(
                    jax.random.PRNGKey(0), self.cfg, self.optimizer
                )
            ).params
            import numpy as np

            self._model_bytes = sum(
                int(np.prod(x.shape)) * 4 for x in jax.tree.leaves(shapes)
            )
        return self._model_bytes

    @property
    def instance_executor(self) -> ServerlessExecutor:
        """The instance-baseline accountant: same executor type, backend
        "instance", pricing on the discrete-event ``InstanceRuntime``
        (boot, per-second billing incl. idle, churn). VM state and epoch
        history persist across :meth:`account_instance` calls."""
        if self._instance_executor is None:
            self._instance_executor = ServerlessExecutor(
                backend="instance",
                instance=self.instance_type,
                instance_config=self.instance_config,
            )
        return self._instance_executor

    def account_instance(
        self,
        per_batch_s: Sequence[float],
        *,
        batch_bytes: int = 0,
        epoch: Optional[int] = None,
        peer: Any = 0,
        charge_exchange: bool = False,  # add degree-aware wire time
        bandwidth_bps: float = 1e9,
        barrier_wait_s: float = 0.0,  # billed idle at the sync barrier
        reference_vcpus: Optional[float] = None,
        strict_fit: bool = False,  # True: refuse a model that overflows the tier
    ) -> ExecutionReport:
        """Price measured per-batch times under the instance baseline.

        The conventional-P2P mirror of :meth:`account_serverless`: the
        same measured batch times, executed *sequentially* on the
        trainer's ``instance_type`` VM — boot delay, per-second billing
        including idle, memory-constrained mini-batch splitting against
        the tier's memory, and (with ``charge_exchange=True``) one upload
        plus degree-many downloads through the overlay graph's
        ``LinkModel``. Together with :meth:`account_serverless` this is
        the paper's headline comparison (see :meth:`cost_frontier`).
        """
        upload_bytes, download_bytes, link = 0, (), None
        if charge_exchange:
            cc = self.comm_cost(bandwidth_bps=bandwidth_bps)
            link = LinkModel(bandwidth_bps=bandwidth_bps)
            if cc.bytes_per_edge:
                upload_bytes = cc.bytes_per_edge
                download_bytes = [cc.bytes_per_edge] * int(round(cc.degree))
            else:  # fused collective: one aggregate transfer figure
                download_bytes = [cc.wire_bytes_per_step]
        return self.instance_executor.simulate_instance(
            per_batch_s,
            model_bytes=self.model_bytes,
            batch_bytes=batch_bytes,
            epoch=epoch,
            peer=peer,
            reference_vcpus=reference_vcpus,
            upload_bytes=upload_bytes,
            download_bytes=download_bytes,
            link=link,
            barrier_wait_s=barrier_wait_s,
            strict_fit=strict_fit,
        )

    def account(self, per_batch_s: Sequence[float], **kw) -> ExecutionReport:
        """Price per-batch times under the trainer's configured backend
        (``backend="serverless" | "instance"``); keyword arguments pass
        through to :meth:`account_serverless` / :meth:`account_instance`."""
        if self.backend == "instance":
            return self.account_instance(per_batch_s, **kw)
        return self.account_serverless(per_batch_s, **kw)

    def cost_frontier(
        self,
        per_batch_s: Sequence[float],
        *,
        batch_bytes: int = 0,
        epoch: int = 0,
        peer: Any = 0,
    ) -> dict:
        """Both backends priced on the same measured epoch: returns
        ``{"serverless": CostReport, "instance": CostReport, "speedup_pct",
        "cost_multiple", ...}`` — the paper's 97.34% / 5.4x trade-off for
        THIS workload, one call.

        Scope and determinism: this compares the *gradient-computation*
        stage — the paper's headline quantity — so exchange wire is
        charged on NEITHER side (use :meth:`account_instance`
        (``charge_exchange=True``) and :meth:`comm_cost` for epoch-level
        accounting). Both sides are priced on FRESH accountants built
        from the trainer's configs, so the result is a pure function of
        the measured times — unaffected by warm pools, VM boots, or
        allocation history left behind by earlier ``account_*`` calls."""
        s_ex = ServerlessExecutor(
            runtime=self.runtime_config, allocation=self.allocation,
        )
        i_ex = ServerlessExecutor(
            backend="instance", instance=self.instance_type,
            instance_config=self.instance_config,
        )
        s = s_ex.simulate(
            per_batch_s, model_bytes=self.model_bytes,
            batch_bytes=batch_bytes, epoch=epoch, peer=peer,
        )
        i = i_ex.simulate_instance(
            per_batch_s, model_bytes=self.model_bytes,
            batch_bytes=batch_bytes, epoch=epoch, peer=peer,
            strict_fit=False,
        )
        sr = s.cost_report(num_peers=self.num_peers, label="serverless")
        ir = i.cost_report(num_peers=self.num_peers, label=self.instance_type)
        return {"serverless": sr, "instance": ir, **compare_backends(sr, ir)}

    @property
    def fleet_executor(self) -> FleetExecutor:
        """The trainer's heterogeneous-fleet accountant: Lambda peers on
        the configured serverless runtime, instance peers on one VM fleet
        per tier (GPU tiers default to the GPU boot preset unless an
        ``instance_config`` was pinned). Warm pools and VM state persist
        across :meth:`account_fleet` calls."""
        if self._fleet is None:
            self._fleet = FleetExecutor(
                runtime=self.runtime_config,
                instance_config=self._fleet_instance_config,
                allocation=(
                    self.allocation
                    if isinstance(self.allocation, str)
                    else "static"
                ),
            )
        return self._fleet

    def account_fleet(
        self,
        plan: FleetPlan,
        per_peer_batch_s: Sequence[Sequence[float]],
        *,
        batch_bytes: int = 0,
        epoch: Optional[int] = None,
    ) -> FleetReport:
        """Price one heterogeneous fleet epoch: ``per_peer_batch_s[rank]``
        runs on ``plan.assignments[rank]``'s backend; epoch wall is the
        max over per-peer makespans, cost the sum over per-peer bills
        (instance peers bill their barrier idle). The fleet counterpart
        of :meth:`account_serverless` / :meth:`account_instance`."""
        return self.fleet_executor.run_epoch(
            plan,
            per_peer_batch_s,
            model_bytes=self.model_bytes,
            batch_bytes=batch_bytes,
            epoch=epoch,
        )

    def schedule_epoch(
        self,
        per_peer_batch_s: Sequence[Sequence[float]],
        *,
        batch_bytes: int = 0,
        candidates: Optional[Sequence[FleetPlan]] = None,
        deadline_s: Optional[float] = None,
        budget_usd: Optional[float] = None,
        warm: bool = True,
    ) -> dict:
        """Let the configured scheduler pick next epoch's plan.

        Measures every candidate plan on fresh executors
        (:func:`repro.core.scheduler.evaluate_candidates`, steady-state
        when ``warm``) against this epoch's measured per-peer batch times,
        then asks ``self.scheduler`` to choose under the deadline/budget.
        Returns ``{"plan", "report", "index", "candidates"}`` — the chosen
        :class:`FleetPlan`, its measured ``CostReport``, its index, and
        all candidates' reports (the frontier the choice was made on)."""
        if self.scheduler is None:
            raise ValueError(
                "no scheduler configured; construct "
                "P2PTrainer(scheduler='cheapest_under_deadline' | "
                "'fastest_under_budget' | 'pareto_walk')"
            )
        if candidates is None:
            candidates = standard_candidates(len(per_peer_batch_s))
        reports = evaluate_candidates(
            candidates,
            per_peer_batch_s,
            model_bytes=self.model_bytes,
            batch_bytes=batch_bytes,
            warm=warm,
            runtime=self.runtime_config,
            instance_config=self._fleet_instance_config,
        )
        idx = self.scheduler.choose(
            reports, deadline_s=deadline_s, budget_usd=budget_usd
        )
        return {
            "plan": candidates[idx],
            "report": reports[idx],
            "index": idx,
            "candidates": list(reports),
        }

    def account_aggregation(
        self,
        per_shard_s: Optional[Sequence[float]] = None,
        *,
        reduce_bytes_per_s: float = 4e9,
        epoch: Optional[int] = None,
        peer: Any = 0,
        link=None,
        usd_per_gb_egress: float = 0.0,
    ) -> ExecutionReport:
        """Price the sharded aggregation stage as P parallel Lambdas.

        Only meaningful for a sharded protocol (``reduce_scatter``). With
        no measured ``per_shard_s``, each aggregator's reduce time is
        estimated from shard bytes x contributions at
        ``reduce_bytes_per_s`` — good enough for sizing/scaling studies;
        pass measured times for real accounting. Memory is sized from
        shard bytes (see ``ServerlessExecutor.simulate_aggregation``).
        """
        plan = self.shard_plan()
        if plan is None:
            raise ValueError(
                f"exchange protocol {self.protocol.name!r} is not sharded; "
                "aggregation accounting applies to reduce_scatter-style "
                "protocols only"
            )
        P = self.num_peers
        if per_shard_s is None:
            t = plan.shard_bytes(self.ctx.wire_dtype) * P / reduce_bytes_per_s
            per_shard_s = [t] * plan.num_shards
        return self.serverless.simulate_aggregation(
            per_shard_s,
            shard_bytes=plan.shard_bytes(self.ctx.wire_dtype),
            num_contributions=P,
            epoch=epoch,
            peer=peer,
            link=link,
            usd_per_gb_egress=usd_per_gb_egress,
        )

    # -- checkpointing -------------------------------------------------------
    def save(self, path: str, state, *, extra: Optional[dict] = None) -> None:
        ckpt.save_state(path, as_train_state(state), extra=extra)

    def restore(self, path: str, like: Optional[TrainState] = None) -> TrainState:
        if like is None:
            like = self.init_state(jax.random.PRNGKey(0))
        state, _ = ckpt.restore_state(path, like)
        return state
