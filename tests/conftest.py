import os
import sys

# NOTE: deliberately NO XLA_FLAGS device-count override here — smoke tests
# and benchmarks must see the real (single) device. Only launch/dryrun.py
# forces 512 placeholder devices, in its own process.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
