"""Planted RA001: the same key feeds two samplers without a split."""
import jax


def sample_pair(key):
    a = jax.random.normal(key, (4,))
    b = jax.random.uniform(key, (4,))  # key already spent on line above
    return a + b
