"""Clean twin of ra001_bad: every sampler gets a freshly split key."""
import jax


def sample_pair(key):
    key, k1 = jax.random.split(key)
    a = jax.random.normal(k1, (4,))
    key, k2 = jax.random.split(key)
    b = jax.random.uniform(k2, (4,))
    return a + b


def sample_branches(key, flag):
    # exclusive if/else arms may each consume the key once
    if flag:
        return jax.random.normal(key, (4,))
    return jax.random.uniform(key, (4,))
