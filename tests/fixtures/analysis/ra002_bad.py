"""Planted RA002: Python control flow on a traced parameter inside jit."""
import jax


@jax.jit
def step(x, flag):
    if flag:  # traced value has no runtime truth value
        return x + 1
    return x - 1


@jax.jit
def drain(x, n):
    while n:  # traced loop condition
        x = x * 0.5
        n = n - 1
    return x
