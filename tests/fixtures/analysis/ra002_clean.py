"""Clean twin of ra002_bad: static attributes / None-guards / lax.cond."""
import jax
import jax.numpy as jnp
from jax import lax


@jax.jit
def step(x, flag):
    return jnp.where(flag, x + 1, x - 1)


@jax.jit
def maybe_scale(x, scale=None):
    if scale is None:  # `is None` guards are static at trace time
        return x
    return x * scale


@jax.jit
def by_shape(x):
    if x.shape[0] > 4:  # shapes are static at trace time
        return x[:4]
    return x


@jax.jit
def cond_step(x, flag):
    return lax.cond(flag, lambda v: v + 1, lambda v: v - 1, x)
