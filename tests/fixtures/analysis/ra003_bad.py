"""Planted RA003: global / unseeded RNG draws."""
import random

import numpy as np

JITTER = np.random.rand(4)  # module-level draw from the global numpy RNG


def make_rng():
    return np.random.default_rng()  # seedless Generator


def pick(items):
    return random.choice(items)  # stdlib global RNG state
