"""Clean twin of ra003_bad: every stream carries an explicit seed."""
import random

import numpy as np


def make_rng(seed: int):
    return np.random.default_rng(seed)


def jitter(seed: int):
    return np.random.default_rng(seed).random(4)


def pick(items, seed: int):
    return random.Random(seed).choice(items)
