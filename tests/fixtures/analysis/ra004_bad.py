"""Planted RA004: mutable default arguments shared across calls."""
from collections import defaultdict


def record(value, history=[]):
    history.append(value)
    return history


def index(key, table=defaultdict(list), weights={}):
    table[key].append(weights)
    return table
