"""Clean twin of ra004_bad: None defaults, containers built per call."""
from collections import defaultdict


def record(value, history=None):
    if history is None:
        history = []
    history.append(value)
    return history


def index(key, table=None, weights=None):
    table = defaultdict(list) if table is None else table
    weights = {} if weights is None else weights
    table[key].append(weights)
    return table
