"""Planted RA005: unordered container iteration feeding message order."""


def drain(queues: dict):
    out = []
    for msg in queues.values():  # dict insertion order decides delivery
        out.append(msg)
    return out


def fanout(peers):
    return [p for p in set(peers)]  # hash order decides fan-out order
