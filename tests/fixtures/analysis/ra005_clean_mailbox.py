"""Clean twin of ra005_bad_mailbox: explicit sorted order everywhere."""


def drain(queues: dict):
    out = []
    for key in sorted(queues):
        out.append(queues[key])
    return out


def fanout(peers):
    return [p for p in sorted(set(peers))]
