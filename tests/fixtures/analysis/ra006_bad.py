"""Planted RA006: exact float equality on cost/time quantities."""


def same_cost(total_cost_usd, quote_usd):
    return total_cost_usd == quote_usd


def is_warm(elapsed_s):
    return elapsed_s != 1.5
