"""Clean twin of ra006_bad: tolerances, zero sentinels, string compares."""
import math


def same_cost(total_cost_usd, quote_usd):
    return math.isclose(total_cost_usd, quote_usd, rel_tol=1e-9)


def is_free(total_cost_usd):
    return total_cost_usd == 0.0  # exact-zero sentinel is exempt


def is_aws(runtime_preset):
    return runtime_preset == "aws"  # string compare, not float math
