"""Planted RA007: registry base with un-ClassVar'd contract attributes."""


class Protocol:
    name = "?"  # registration sentinel marks this as a registry base
    is_async: bool = False
    lossy: bool = False

    def combine(self, grads):
        raise NotImplementedError
