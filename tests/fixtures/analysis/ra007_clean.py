"""Clean twin of ra007_bad: contract attributes annotated ClassVar."""
from typing import ClassVar


class Protocol:
    name: ClassVar[str] = "?"  # registration sentinel
    is_async: ClassVar[bool] = False
    lossy: ClassVar[bool] = False

    def combine(self, grads):
        raise NotImplementedError
