"""Planted RA008: assert as a runtime invariant in a core sim module."""


def barrier_check(done: int, total: int):
    assert done == total, "barrier incomplete"  # stripped under python -O
    return True
