"""Clean twin of ra008_bad_core_sim: explicit exception survives -O."""


def barrier_check(done: int, total: int):
    if done != total:
        raise RuntimeError(f"barrier incomplete: {done}/{total} peers signalled")
    return True
