"""Planted RA009: wall-clock reads inside the discrete-event module."""
import time
from datetime import datetime


def advance(engine):
    engine.now = time.perf_counter()  # real wall time leaks into sim time
    return datetime.now()
