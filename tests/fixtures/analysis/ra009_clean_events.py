"""Clean twin of ra009_bad_events: time advances only via the heap."""
import heapq


def advance(engine):
    t, prio, seq, fn = heapq.heappop(engine.heap)
    engine.now = t
    fn()
    return engine.now
