"""Gradient accumulation (Topology.accum_steps) must match the single-shot
step exactly (same total batch, fp32 accumulation), and the Lambda timeout
cap must be enforced."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.p2p import Topology
from repro.core.serverless import LAMBDA_TIMEOUT_S, ServerlessExecutor
from repro.optim import sgd
from repro.optim.schedules import constant
from repro.train import build_train_step, init_train_state


def test_accumulation_matches_single_shot():
    cfg = reduced(get_config("qwen2.5-3b"), num_layers=2, d_model=64, vocab_size=64,
                  remat=False)
    opt = sgd(momentum=0.0)
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, 64),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (8, 16), 0, 64),
    }
    state0 = init_train_state(jax.random.PRNGKey(0), cfg, opt)

    outs = {}
    for n in (1, 4):
        topo = Topology(peer_axes=(), lambda_axis=None, serverless=False,
                        accum_steps=n)
        step = jax.jit(build_train_step(cfg, opt, topo, None, constant(1e-2)))
        s, m = step(state0, batch)
        outs[n] = (s["params"], float(m["loss"]))

    # micro-round mean of per-round means == global mean (equal splits)
    assert outs[1][1] == pytest.approx(outs[4][1], rel=1e-5)
    # bf16 compute: micro-round reduction order differs from the fused batch
    for a, b in zip(jax.tree.leaves(outs[1][0]), jax.tree.leaves(outs[4][0])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-4)


def test_lambda_timeout_enforced():
    import time

    ex = ServerlessExecutor(backend="serverless")
    # tiny model -> low-memory, slow lambda; fake a measured batch that would
    # exceed the 15-minute cap after the speed scaling
    slow = LAMBDA_TIMEOUT_S * 0.6  # /0.43 speed -> >15 min on the lambda

    class FakeThunk:
        def __call__(self):
            return jnp.zeros(())

    real_pc = time.perf_counter
    ticks = iter([0.0, slow])
    time.perf_counter = lambda: next(ticks, slow)
    try:
        with pytest.raises(ValueError, match="exceeds"):
            ex.run([FakeThunk()], model_bytes=int(1e6), batch_bytes=int(1e5),
                   combine=lambda xs: xs[0])
    finally:
        time.perf_counter = real_pc
