"""Tests for the repro.analysis suite: planted-violation fixtures, registry
contract checks, trace race/determinism checks, link integrity, the CLI,
and regression tests for the real violations the suite found (and PR 8
fixed) in src/repro."""
import json
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis import Report, find_root, run_analysis
from repro.analysis.common import Finding, filter_suppressed
from repro.analysis.contracts import contracts_pass
from repro.analysis.links import links_pass
from repro.analysis.lint import ALL_RULES, lint_file, lint_paths, lint_source
from repro.analysis.trace import (
    TraceRecorder, check_trace, diff_runs, _run_serverless,
)

ROOT = Path(__file__).resolve().parents[1]
FIXTURES = ROOT / "tests" / "fixtures" / "analysis"

# rule -> (bad fixture, clean twin, explicit scope overrides)
FIXTURE_PAIRS = {
    "RA001": ("ra001_bad.py", "ra001_clean.py", {}),
    "RA002": ("ra002_bad.py", "ra002_clean.py", {}),
    "RA003": ("ra003_bad.py", "ra003_clean.py", {}),
    "RA004": ("ra004_bad.py", "ra004_clean.py", {}),
    "RA005": (
        "ra005_bad_mailbox.py", "ra005_clean_mailbox.py",
        {"order_sensitive": True},
    ),
    "RA006": ("ra006_bad.py", "ra006_clean.py", {}),
    "RA007": ("ra007_bad.py", "ra007_clean.py", {}),
    "RA008": (
        "ra008_bad_core_sim.py", "ra008_clean_core_sim.py",
        {"core_module": True},
    ),
    "RA009": ("ra009_bad_events.py", "ra009_clean_events.py", {"sim_pure": True}),
}


# ---------------------------------------------------------------------------
# lint pass — every rule catches its planted fixture, passes the clean twin
# ---------------------------------------------------------------------------


def test_every_rule_has_a_fixture_pair():
    assert set(FIXTURE_PAIRS) == set(ALL_RULES)


@pytest.mark.parametrize("rule", sorted(FIXTURE_PAIRS))
def test_rule_catches_planted_fixture(rule):
    bad, _, scopes = FIXTURE_PAIRS[rule]
    findings = lint_file(FIXTURES / bad, ROOT, **scopes)
    assert any(f.rule == rule for f in findings), (
        f"{rule} missed its planted fixture {bad}: {findings}"
    )


@pytest.mark.parametrize("rule", sorted(FIXTURE_PAIRS))
def test_rule_passes_clean_twin(rule):
    _, clean, scopes = FIXTURE_PAIRS[rule]
    findings = lint_file(FIXTURES / clean, ROOT, **scopes)
    assert findings == [], f"clean twin {clean} was flagged: {findings}"


def test_scope_defaults_derive_from_basename():
    # the *_mailbox / *_events / *_core_sim fixture names trigger their
    # scoped rules without explicit overrides
    assert any(
        f.rule == "RA005"
        for f in lint_file(FIXTURES / "ra005_bad_mailbox.py", ROOT)
    )
    assert any(
        f.rule == "RA009"
        for f in lint_file(FIXTURES / "ra009_bad_events.py", ROOT)
    )
    # outside an order-sensitive module the same code is fine
    source = (FIXTURES / "ra005_bad_mailbox.py").read_text()
    assert lint_source(source, "helpers.py") == []


def test_noqa_suppression():
    src = "import jax\n\ndef f(key):\n    a = jax.random.normal(key, (2,))\n    b = jax.random.normal(key, (2,))  # noqa: RA001\n    return a + b\n"
    assert lint_source(src, "mod.py") == []
    src_ignored = src.replace("# noqa: RA001", "# analysis: ignore[RA001]")
    assert lint_source(src_ignored, "mod.py") == []
    src_star = src.replace("# noqa: RA001", "# noqa: *")
    assert lint_source(src_star, "mod.py") == []
    # an unrelated rule id does NOT silence it
    src_wrong = src.replace("# noqa: RA001", "# noqa: RA004")
    assert any(f.rule == "RA001" for f in lint_source(src_wrong, "mod.py"))


def test_key_reuse_is_path_sensitive():
    # exclusive branches may each consume the key once
    src = (
        "import jax\n"
        "def f(key, flag):\n"
        "    if flag:\n"
        "        return jax.random.normal(key, (2,))\n"
        "    return jax.random.uniform(key, (2,))\n"
    )
    assert lint_source(src, "m.py") == []
    # loop-carried reuse IS flagged
    src_loop = (
        "import jax\n"
        "def f(key, n):\n"
        "    out = []\n"
        "    for _ in range(n):\n"
        "        out.append(jax.random.normal(key, (2,)))\n"
        "    return out\n"
    )
    assert any(f.rule == "RA001" for f in lint_source(src_loop, "m.py"))


def test_report_severity_gating(tmp_path):
    report = Report(findings=[
        Finding("RA006", "warning", "x.py", 1, "w"),
        Finding("RC012", "info", "<registries>", 1, "i", "contracts"),
    ], passes_run=["lint"], files_scanned=1)
    assert not report.failed("error")
    assert report.failed("warning")
    assert report.failed("info")
    assert not report.failed("never")
    out = tmp_path / "report.json"
    report.write_json(out)
    data = json.loads(out.read_text())
    assert data["summary"] == {"info": 1, "warning": 1, "error": 0}
    assert len(data["findings"]) == 2
    # worst first in both JSON and human rendering
    assert data["findings"][0]["severity"] == "warning"
    assert "1 warning" in report.render()


# ---------------------------------------------------------------------------
# contracts pass
# ---------------------------------------------------------------------------


def test_registered_protocols_honor_their_contracts():
    findings, checks_run = contracts_pass()
    errors = [f for f in findings if f.severity in ("warning", "error")]
    assert errors == [], "\n".join(f.render() for f in errors)
    assert checks_run > 50  # every protocol x every contract clause


def test_contracts_catch_a_broken_protocol():
    from repro.core.exchange import (
        ExchangeProtocol, _REGISTRY, register_exchange,
    )

    @register_exchange("_broken_for_test")
    class BrokenProtocol(ExchangeProtocol):
        # every declaration here is a lie the checker must catch:
        requires_key = True  # ...but host_encode ignores the key (RC002)
        lossy = True  # ...but the default roundtrip is exact and
        #               combine_ef is not overridden (RC003, RC004)
        is_async = True  # ...but there is no carried state (RC005)

        def combine(self, grads, ctx, *, key=None, state=None):
            return grads, state

    try:
        findings, _ = contracts_pass()
        broken = {
            f.rule for f in findings if "BrokenProtocol" in f.message
        }
        assert {"RC002", "RC003", "RC004", "RC005"} <= broken, broken
    finally:
        _REGISTRY.pop("_broken_for_test", None)


# ---------------------------------------------------------------------------
# trace pass
# ---------------------------------------------------------------------------


def test_trace_recorder_digest_is_order_and_value_sensitive():
    a, b, c = TraceRecorder(), TraceRecorder(), TraceRecorder()
    a.record("publish", time=1.0, actor=0)
    a.record("consume", time=2.0, actor=1)
    b.record("consume", time=2.0, actor=1)
    b.record("publish", time=1.0, actor=0)
    c.record("publish", time=1.0, actor=0)
    c.record("consume", time=2.5, actor=1)
    assert a.digest() != b.digest()  # order
    assert a.digest() != c.digest()  # values
    assert a.digest() != TraceRecorder().digest()  # not the empty digest


def test_check_trace_flags_latest_wins_race():
    t = TraceRecorder()
    t.record("publish", time=1.0, actor=0, epoch=3, shard=None, nbytes=8)
    t.record("publish", time=2.0, actor=0, epoch=3, shard=None, nbytes=8)
    races = [f for f in check_trace(t.events) if f.rule == "RT001"]
    assert len(races) == 1 and races[0].line == 2
    # a consume between the publishes clears the race
    t2 = TraceRecorder()
    t2.record("publish", time=1.0, actor=0, epoch=3, shard=None, nbytes=8)
    t2.record("consume", time=1.5, actor=1, peer=0, shard=None, epoch=3)
    t2.record("publish", time=2.0, actor=0, epoch=3, shard=None, nbytes=8)
    assert [f for f in check_trace(t2.events) if f.rule == "RT001"] == []
    # a later epoch on the same register is progress, not a race
    t3 = TraceRecorder()
    t3.record("publish", time=1.0, actor=0, epoch=3, shard=None, nbytes=8)
    t3.record("publish", time=2.0, actor=0, epoch=4, shard=None, nbytes=8)
    assert [f for f in check_trace(t3.events) if f.rule == "RT001"] == []


def test_check_trace_flags_ties_and_unseeded_engine():
    t = TraceRecorder()
    t.record("engine", time=0.0, seeded=False)
    t.record("fire", time=1.0, priority=0, seq=0)
    t.record("fire", time=1.0, priority=0, seq=1)
    rules = {f.rule for f in check_trace(t.events)}
    assert "RT004" in rules and "RT002" in rules


def test_diff_runs_flags_nondeterminism():
    state = {"n": 0}

    def run(tracer):
        state["n"] += 1
        tracer.record("fire", time=float(state["n"]), priority=0, seq=0)

    findings, _ = diff_runs("synthetic", run)
    assert [f.rule for f in findings] == ["RT003"]
    assert findings[0].severity == "error"


def test_serverless_runtime_trace_is_deterministic():
    findings, recorder = diff_runs("serverless", _run_serverless)
    assert findings == []
    kinds = {e[0] for e in recorder.events}
    assert {"engine", "schedule", "fire", "fanout"} <= kinds
    # the faulty runtime really exercised retries/cold starts
    fanouts = [e for e in recorder.events if e[0] == "fanout"]
    assert len(fanouts) == 3


def test_mailbox_trace_records_and_race_detection():
    from repro.core.mailbox import HostMailbox

    t = TraceRecorder()
    box = HostMailbox(2, tracer=t)
    box.publish(0, "g0", nbytes=8, time=1.0, epoch=0)
    box.publish(0, "g0b", nbytes=8, time=2.0, epoch=0)  # overwrote unread
    msg = box.consume(0, at_time=3.0, consumer=1)
    assert msg is not None and msg.payload == "g0b"
    kinds = [e[0] for e in t.events]
    assert kinds == ["publish", "publish", "consume"]
    races = [f for f in check_trace(t.events) if f.rule == "RT001"]
    assert len(races) == 1


@pytest.mark.slow
def test_p2p_cluster_async_trace_is_deterministic():
    from repro.analysis.trace import _run_cluster

    findings, recorder = diff_runs("cluster", _run_cluster)
    assert findings == [], "\n".join(f.render() for f in findings)
    kinds = {e[0] for e in recorder.events}
    assert {"engine", "fire", "publish", "consume"} <= kinds
    # the real publish/consume stream must be race-free
    assert [f for f in check_trace(recorder.events) if f.rule == "RT001"] == []


def test_sim_compute_s_pins_the_async_clock():
    from repro.configs import get_config
    from repro.core.simulate import LocalP2PCluster
    from repro.data import make_dataset
    from repro.optim import sgd

    def build():
        return LocalP2PCluster(
            get_config("squeezenet1.1"),
            make_dataset("mnist", size=64, image_hw=8, channels=1),
            num_peers=2, batch_size=8, batches_per_epoch=1,
            optimizer=sgd(momentum=0.0), lr=0.05, sync=False,
            sim_compute_s=0.25, seed=5,
        )

    a, b = build(), build()
    a.run_epoch_async(0)
    b.run_epoch_async(0)
    assert [p.clock for p in a.peers] == [p.clock for p in b.peers]
    assert all(p.compute_time_s == 0.25 for p in a.peers)


# ---------------------------------------------------------------------------
# links pass
# ---------------------------------------------------------------------------


def test_links_pass_flags_broken_and_passes_good(tmp_path):
    (tmp_path / "docs").mkdir()
    (tmp_path / "real.md").write_text("target\n")
    (tmp_path / "README.md").write_text(
        "[ok](real.md) [web](https://x.test) [anchor](#here)\n"
        "[broken](missing.md)\n"
    )
    (tmp_path / "docs" / "GUIDE.md").write_text("[up](../real.md#frag)\n")
    findings, checked = links_pass(tmp_path)
    assert checked == 2
    assert [(f.rule, f.path, f.line) for f in findings] == [
        ("RL001", "README.md", 2)
    ]


def test_links_pass_on_this_repo_is_clean():
    findings, checked = links_pass(ROOT)
    assert findings == [], "\n".join(f.render() for f in findings)
    assert checked >= 2  # README + docs/


def test_check_links_shim_still_works():
    out = subprocess.run(
        [sys.executable, str(ROOT / "scripts" / "check_links.py")],
        capture_output=True, text=True, cwd=ROOT,
        env={"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin:/usr/local/bin"},
    )
    assert out.returncode == 0, out.stdout + out.stderr


# ---------------------------------------------------------------------------
# CLI + whole-suite
# ---------------------------------------------------------------------------


def test_cli_runs_green_on_src(tmp_path):
    from repro.analysis.__main__ import main

    report_path = tmp_path / "analysis.json"
    rc = main([
        str(ROOT / "src"), "--root", str(ROOT), "--passes", "lint,links",
        "--fail-on", "error", "--json", str(report_path),
    ])
    assert rc == 0
    data = json.loads(report_path.read_text())
    assert data["summary"]["error"] == 0
    assert set(data["passes"]) == {"lint", "links"}


def test_cli_rejects_unknown_pass():
    with pytest.raises(ValueError, match="unknown analysis pass"):
        run_analysis(root=ROOT, passes=("lint", "bogus"))


def test_src_is_lint_clean():
    """Regression net over the PR-8 fixes: the shipped source must carry
    zero lint findings (key reuse, asserts, unordered iteration, ...)."""
    findings, files = lint_paths([ROOT / "src"], ROOT)
    assert files > 50
    assert findings == [], "\n".join(f.render() for f in findings)


# ---------------------------------------------------------------------------
# regression tests for the real violations the suite surfaced
# ---------------------------------------------------------------------------


def test_graph_spec_param_rejection_is_a_clean_valueerror():
    from repro.core.graph import get_graph

    with pytest.raises(ValueError, match="does not take a ':' parameter"):
        get_graph("full:2", 8)


def test_exchange_spec_param_rejection_is_a_clean_valueerror():
    from repro.core.exchange import get_exchange

    with pytest.raises(ValueError, match="does not take a ':' parameter"):
        get_exchange("allgather_mean:1")


def test_convergence_mode_validation_survives_python_O():
    from repro.core.convergence import EarlyStopping, ReduceLROnPlateau

    with pytest.raises(ValueError, match="mode must be"):
        ReduceLROnPlateau(0.1, mode="bogus")
    with pytest.raises(ValueError, match="mode must be"):
        EarlyStopping(mode="bogus")


def test_executor_backend_validation_survives_python_O():
    from repro.core.serverless import ServerlessExecutor

    with pytest.raises(ValueError, match="backend must be"):
        ServerlessExecutor(backend="bogus")


def test_repro_deprecations_escalate_to_errors():
    """pytest.ini escalates repro DeprecationWarnings: accidental use of a
    deprecated surface (the PR-3 Topology(async_mode=...) shim) fails the
    suite instead of scrolling by. Intentional checks use pytest.warns,
    which still passes under escalation (see test_graph.py)."""
    from repro.core.p2p import Topology

    with pytest.raises(DeprecationWarning, match='exchange="async"'):
        Topology(peer_axes=("data",), async_mode=True)
