"""QSGD property tests (hypothesis): unbiasedness, bounded error, roundtrip."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import compression as C


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 300),
    s=st.sampled_from([1, 3, 15, 127]),
    scale=st.floats(1e-3, 1e3),
)
def test_roundtrip_shape_and_error_bound(n, s, scale):
    """|Q(v)_i - v_i| <= ||bucket|| / s element-wise (quantization grid)."""
    cfg = C.QSGDConfig(levels=s, bucket=128)
    key = jax.random.PRNGKey(n)
    x = jax.random.normal(key, (n,)) * scale
    payload = C.quantize(x, jax.random.PRNGKey(1), cfg)
    xh = C.dequantize(payload, cfg)
    assert xh.shape == x.shape
    buckets, _ = C._pad_to_buckets(x, cfg.bucket)
    norms = jnp.linalg.norm(buckets, axis=-1)
    bound = float(norms.max()) / s + 1e-6
    assert float(jnp.abs(xh - x).max()) <= bound


def test_unbiasedness():
    """E[Q(v)] == v (the core QSGD property)."""
    cfg = C.QSGDConfig(levels=7, bucket=128)
    x = jax.random.normal(jax.random.PRNGKey(0), (128,))
    acc = jnp.zeros_like(x)
    trials = 600
    for i in range(trials):
        payload = C.quantize(x, jax.random.PRNGKey(100 + i), cfg)
        acc = acc + C.dequantize(payload, cfg)
    mean = acc / trials
    # std of the mean ~ (||x||/s)/sqrt(trials); allow 5 sigma
    sigma = float(jnp.linalg.norm(x)) / 7 / np.sqrt(trials)
    assert float(jnp.abs(mean - x).max()) < 5 * sigma


def test_sign_preserved():
    cfg = C.QSGDConfig(levels=127, bucket=128)
    x = jnp.asarray(np.linspace(-4, 4, 256), jnp.float32)
    payload = C.quantize(x, jax.random.PRNGKey(2), cfg)
    xh = C.dequantize(payload, cfg)
    nz = np.abs(np.asarray(xh)) > 0
    assert np.all(np.sign(np.asarray(xh))[nz] == np.sign(np.asarray(x))[nz])


def test_tree_roundtrip_and_wire_size():
    cfg = C.QSGDConfig(levels=127, bucket=256)
    tree = {
        "a": jax.random.normal(jax.random.PRNGKey(0), (37, 19)),
        "b": {"c": jax.random.normal(jax.random.PRNGKey(1), (512,))},
    }
    payload, _ = C.quantize_tree(tree, jax.random.PRNGKey(3), cfg)
    back = C.dequantize_tree(payload, cfg)
    for k, v in jax.tree.leaves_with_path(tree):
        pass
    flat_in = jax.tree.leaves(tree)
    flat_out = jax.tree.leaves(back)
    assert all(a.shape == b.shape for a, b in zip(flat_in, flat_out))
    wire = C.payload_bytes(payload)
    raw = C.raw_bytes(tree)
    assert wire < raw / 3  # ~8+ bits/elt vs 32
    rel = max(
        float(jnp.abs(a - b).max() / (jnp.abs(a).max() + 1e-9))
        for a, b in zip(flat_in, flat_out)
    )
    assert rel < 0.2


@settings(max_examples=10, deadline=None)
@given(bucket=st.sampled_from([128, 512, 2048]))
def test_bits_per_element(bucket):
    cfg = C.QSGDConfig(levels=127, bucket=bucket)
    assert cfg.bits_per_element == pytest.approx(8 + 32 / bucket)
