"""Mailbox, convergence detection, cost model, serverless planner tests."""
import numpy as np
import pytest

from repro.core.convergence import ConvergenceDetector, EarlyStopping, ReduceLROnPlateau
from repro.core.cost import (
    InstanceCost,
    ServerlessCost,
    ec2_cost_per_second,
    lambda_cost_per_second,
    paper_table2_row,
    paper_table3_row,
)
from repro.core.mailbox import HostMailbox, MESSAGE_CAP_BYTES
from repro.core.serverless import (
    LAMBDA_MAX_MEMORY_MB,
    ServerlessExecutor,
    ServerlessPlanner,
)


# ---------------------------------------------------------------------------
# Mailbox (RabbitMQ semantics)
# ---------------------------------------------------------------------------

def test_mailbox_latest_wins():
    mb = HostMailbox(2)
    mb.publish(0, "g1", nbytes=10, time=1.0, epoch=0)
    mb.publish(0, "g2", nbytes=10, time=2.0, epoch=0)
    assert mb.consume(0).payload == "g2"  # replaced, not queued


def test_mailbox_read_does_not_delete():
    mb = HostMailbox(2)
    mb.publish(1, "g", nbytes=10, time=0.0, epoch=0)
    assert mb.consume(1).payload == "g"
    assert mb.consume(1).payload == "g"


def test_mailbox_async_visibility():
    mb = HostMailbox(2)
    mb.publish(0, "late", nbytes=10, time=5.0, epoch=0)
    assert mb.consume(0, at_time=4.0) is None  # not yet visible
    assert mb.consume(0, at_time=6.0).payload == "late"


def test_mailbox_s3_indirection_for_large_messages():
    mb = HostMailbox(1)
    mb.publish(0, "big", nbytes=MESSAGE_CAP_BYTES + 1, time=0.0, epoch=0)
    msg = mb.consume(0)
    assert msg.via_s3 and msg.s3_uuid is not None
    assert mb.stats["s3_indirections"] == 1


def test_mailbox_barrier():
    mb = HostMailbox(3)
    for p in range(3):
        assert not mb.barrier_complete(0)
        mb.barrier_signal(p, 0)
    assert mb.barrier_complete(0)
    mb.barrier_reset(0)
    assert not mb.barrier_complete(0)


# ---------------------------------------------------------------------------
# Convergence detection
# ---------------------------------------------------------------------------

def test_plateau_reduces_lr():
    p = ReduceLROnPlateau(0.1, patience=1, factor=0.5)
    p.step(1.0)
    p.step(1.0)  # bad 1
    lr = p.step(1.0)  # bad 2 > patience -> reduce
    assert lr == pytest.approx(0.05)


def test_plateau_respects_min_lr():
    p = ReduceLROnPlateau(1e-6, patience=0, factor=0.5, min_lr=1e-6)
    p.step(1.0)
    assert p.step(1.0) == pytest.approx(1e-6)


def test_early_stopping():
    e = EarlyStopping(patience=2)
    assert not e.step(1.0)
    assert not e.step(1.0)
    assert e.step(1.0)


def test_early_stopping_resets_on_improvement():
    e = EarlyStopping(patience=2, min_delta=0.0)
    e.step(1.0)
    e.step(1.0)
    e.step(0.5)  # improvement resets
    assert not e.stopped
    e.step(0.6)
    assert e.step(0.7)


def test_detector_epoch_limit():
    d = ConvergenceDetector(0.1, mode="max", max_epochs=3, stop_patience=100)
    assert not d.step(0.1)
    assert not d.step(0.2)
    assert d.step(0.3)  # epoch limit


# ---------------------------------------------------------------------------
# Cost model: reproduce the paper's Tables II & III
# ---------------------------------------------------------------------------

@pytest.mark.parametrize(
    "batch,paper_lambda_cost,paper_total",
    [
        (1024, 0.0000573, 0.03567),
        (512, 0.0000362, 0.03069),
        (128, 0.0000233, 0.03451),
        (64, 0.0000220, 0.05435),
    ],
)
def test_paper_table2_serverless_costs(batch, paper_lambda_cost, paper_total):
    row = paper_table2_row(batch)
    assert lambda_cost_per_second(row["lambda_memory_mb"]) == pytest.approx(
        paper_lambda_cost, rel=0.02
    )
    cost = ServerlessCost(
        compute_time_s=row["compute_time_s"],
        num_batches=row["num_batches"],
        lambda_memory_mb=row["lambda_memory_mb"],
        instance="t2.small",
    ).cost_per_peer
    # rel=0.04: the paper's own batch-128 row is ~3.5% off its formula (1)
    # — (2.33e-5*118 + 6.39e-6)*12.9 = 0.03555, printed as 0.03451.
    assert cost == pytest.approx(paper_total, rel=0.04)


@pytest.mark.parametrize(
    "batch,paper_total",
    [(1024, 0.00665), (512, 0.00717), (128, 0.00851), (64, 0.01017)],
)
def test_paper_table3_instance_costs(batch, paper_total):
    row = paper_table3_row(batch)
    cost = InstanceCost(row["compute_time_s"], "t2.large").cost_per_peer
    assert cost == pytest.approx(paper_total, rel=0.02)


def test_paper_cost_ratio_5x():
    """Headline claim: serverless ~5.34x the instance cost at batch 1024."""
    s = ServerlessCost(41.2, 15, 4400, "t2.small").cost_per_peer
    i = InstanceCost(258.0, "t2.large").cost_per_peer
    assert s / i == pytest.approx(5.34, rel=0.05)


def test_ec2_rates_match_paper():
    assert ec2_cost_per_second("t2.small") == pytest.approx(0.00000639, rel=0.01)
    assert ec2_cost_per_second("t2.large") == pytest.approx(0.00002578, rel=0.01)


# ---------------------------------------------------------------------------
# Serverless planner / executor
# ---------------------------------------------------------------------------

def test_planner_memory_monotonic_in_model_size():
    p = ServerlessPlanner()
    m1 = p.lambda_memory_mb(int(5e6), int(1e6))
    m2 = p.lambda_memory_mb(int(5e8), int(1e6))
    assert m2 > m1
    assert m1 % 64 == 0


def test_planner_rejects_oversized_workloads():
    p = ServerlessPlanner()
    with pytest.raises(ValueError):
        p.lambda_memory_mb(int(20e9), int(1e6))  # > 10GB Lambda cap


def test_planner_state_machine_plan():
    p = ServerlessPlanner()
    plan = p.plan(model_bytes=int(1e8), batch_bytes=int(1e6), num_batches=7)
    assert plan.num_branches == 7
    asl = plan.asl_sketch()
    assert asl["States"]["ParallelGradients"]["MaxConcurrency"] == 7


def test_executor_accounting_parallel_vs_sequential():
    import time

    def slow():
        time.sleep(0.02)
        return 1.0

    thunks = [slow] * 5
    seq = ServerlessExecutor(backend="instance")
    _, rs = seq.run(thunks, model_bytes=int(4e9), batch_bytes=int(1e6),
                    combine=lambda xs: sum(xs))
    par = ServerlessExecutor(
        backend="serverless", invoke_overhead_s=0.0, orchestration_overhead_s=0.0
    )
    _, rp = par.run(thunks, model_bytes=int(4e9), batch_bytes=int(1e6),
                    combine=lambda xs: sum(xs))
    # the 4e9-byte model forces a high-memory (multi-vCPU) lambda: parallel
    # wall time must be well under the sequential sum
    assert rp.wall_time_s < rs.wall_time_s / 2
    assert rp.lambda_memory_mb > 4000
    assert rs.cost_usd > 0 and rp.cost_usd > 0
