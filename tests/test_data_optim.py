"""Data pipeline + optimizer tests (incl. hypothesis invariants)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.data import BatchKey, DataLoader, Partitioner, make_dataset
from repro.optim import adam, adamw, apply_updates, clip_by_global_norm, global_norm, sgd
from repro.optim.schedules import constant, cosine, warmup_cosine


# ---------------------------------------------------------------------------
# Partitioner invariants
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(num_peers=st.integers(1, 12), size=st.integers(12, 500))
def test_partitions_disjoint_cover(num_peers, size):
    ds = make_dataset("mnist", size=size)
    part = Partitioner(ds, num_peers)
    seen = set()
    for p in range(num_peers):
        idx = part.partition(p)
        s = set(int(i) for i in idx)
        assert not (seen & s), "partitions overlap"
        seen |= s
    per = size // num_peers
    assert len(seen) == per * num_peers  # exhaustive up to remainder


def test_partition_out_of_range():
    ds = make_dataset("mnist", size=100)
    part = Partitioner(ds, 4)
    with pytest.raises(IndexError):
        part.partition(4)


# ---------------------------------------------------------------------------
# Batch addressing determinism (the S3-key analogue)
# ---------------------------------------------------------------------------

def test_batches_deterministic_by_key():
    ds = make_dataset("cifar", size=256, image_hw=8)
    part = Partitioner(ds, 2)
    dl = DataLoader(part, 0, 16)
    k = BatchKey(0, 3, 1)
    b1, b2 = dl.load(k), dl.load(k)
    np.testing.assert_array_equal(b1["images"], b2["images"])
    np.testing.assert_array_equal(b1["labels"], b2["labels"])
    assert "peer=0" in k.s3_key("cifar") and "epoch=3" in k.s3_key("cifar")


def test_batches_differ_across_epochs_and_batches():
    ds = make_dataset("mnist", size=256, image_hw=8)
    dl = DataLoader(Partitioner(ds, 2), 0, 16)
    a = dl.load(BatchKey(0, 0, 0))["images"]
    b = dl.load(BatchKey(0, 1, 0))["images"]
    assert not np.array_equal(a, b)


def test_lm_dataset_shapes():
    ds = make_dataset("lm", size=64, vocab_size=128, seq_len=32)
    dl = DataLoader(Partitioner(ds, 2), 1, 8)
    b = dl.load(BatchKey(1, 0, 0))
    assert b["tokens"].shape == (8, 32) and b["labels"].shape == (8, 32)
    assert b["tokens"].max() < 128
    # labels are next-token shifted
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_image_labels_balanced_enough():
    ds = make_dataset("mnist", size=1000, image_hw=8)
    dl = DataLoader(Partitioner(ds, 1), 0, 500)
    labels = dl.load(BatchKey(0, 0, 0))["labels"]
    counts = np.bincount(labels, minlength=10)
    assert counts.min() > 10  # all classes present


# ---------------------------------------------------------------------------
# Optimizers vs numpy references
# ---------------------------------------------------------------------------

def test_sgd_momentum_matches_numpy():
    opt = sgd(momentum=0.9)
    p = {"w": jnp.asarray([1.0, 2.0])}
    s = opt.init(p)
    g = {"w": jnp.asarray([0.1, -0.2])}
    lr = jnp.float32(0.5)
    m = np.zeros(2)
    w = np.array([1.0, 2.0])
    for _ in range(3):
        upd, s = opt.update(g, s, p, lr)
        p = apply_updates(p, upd)
        m = 0.9 * m + np.array([0.1, -0.2])
        w = w - 0.5 * m
    np.testing.assert_allclose(np.asarray(p["w"]), w, rtol=1e-6)


def test_adam_matches_numpy():
    opt = adam(b1=0.9, b2=0.999, eps=1e-8)
    p = {"w": jnp.asarray([1.0, -1.0])}
    s = opt.init(p)
    g = {"w": jnp.asarray([0.3, 0.7])}
    w = np.array([1.0, -1.0])
    mu = np.zeros(2)
    nu = np.zeros(2)
    for t in range(1, 4):
        upd, s = opt.update(g, s, p, jnp.float32(0.1))
        p = apply_updates(p, upd)
        gg = np.array([0.3, 0.7])
        mu = 0.9 * mu + 0.1 * gg
        nu = 0.999 * nu + 0.001 * gg**2
        w = w - 0.1 * (mu / (1 - 0.9**t)) / (np.sqrt(nu / (1 - 0.999**t)) + 1e-8)
    np.testing.assert_allclose(np.asarray(p["w"]), w, rtol=1e-5)


def test_adamw_decays_weights():
    p = {"w": jnp.asarray([10.0])}
    opt = adamw(weight_decay=0.1)
    s = opt.init(p)
    upd, s = opt.update({"w": jnp.asarray([0.0])}, s, p, jnp.float32(0.1))
    p2 = apply_updates(p, upd)
    assert float(p2["w"][0]) < 10.0


@settings(max_examples=20, deadline=None)
@given(scale=st.floats(0.1, 100.0))
def test_clip_by_global_norm(scale):
    tree = {"a": jnp.ones((4,)) * scale, "b": jnp.ones((3,)) * scale}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(global_norm(clipped)) <= 1.0 + 1e-5
    assert float(norm) == pytest.approx(scale * np.sqrt(7), rel=1e-5)


def test_schedules():
    assert float(constant(0.1)(1000)) == pytest.approx(0.1)
    c = cosine(1.0, 100, final_frac=0.1)
    assert float(c(0)) == pytest.approx(1.0)
    assert float(c(100)) == pytest.approx(0.1, abs=1e-6)
    w = warmup_cosine(1.0, 10, 110)
    assert float(w(5)) == pytest.approx(0.5)
    assert float(w(10)) == pytest.approx(1.0)
