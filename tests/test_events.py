"""ServerlessRuntime event engine: analytic equivalence, cold/warm pools,
concurrency queueing, retries, stragglers, and the AllocationPolicy
registry."""
import numpy as np
import pytest

from repro.core.cost import ServerlessCost
from repro.core.events import (
    AllocationPolicy,
    EventEngine,
    FanoutTimeout,
    RuntimeConfig,
    ServerlessRuntime,
    available_allocations,
    get_allocation,
    register_allocation,
)
from repro.core.serverless import ServerlessExecutor


# ---------------------------------------------------------------------------
# EventEngine
# ---------------------------------------------------------------------------

def test_engine_orders_by_time_priority_seq():
    eng = EventEngine()
    fired = []
    eng.schedule_at(2.0, lambda: fired.append("t2"))
    eng.schedule_at(1.0, lambda: fired.append("b"), priority=1)
    eng.schedule_at(1.0, lambda: fired.append("a"), priority=0)
    eng.schedule_at(1.0, lambda: fired.append("a2"), priority=0)  # seq tiebreak
    eng.run()
    assert fired == ["a", "a2", "b", "t2"]
    assert eng.now == 2.0 and eng.processed == 4


def test_engine_callbacks_schedule_more_events():
    eng = EventEngine()
    fired = []
    def first():
        fired.append(eng.now)
        eng.schedule_in(0.5, lambda: fired.append(eng.now))
    eng.schedule_at(1.0, first)
    eng.run()
    assert fired == [1.0, 1.5]


def test_engine_reset_requires_empty_heap():
    eng = EventEngine()
    eng.schedule_at(1.0, lambda: None)
    with pytest.raises(RuntimeError):
        eng.reset(0.0)
    eng.run()
    eng.reset(5.0)
    assert eng.now == 5.0


# ---------------------------------------------------------------------------
# Acceptance: ideal runtime == legacy analytic accounting (<= 1e-6 s)
# ---------------------------------------------------------------------------

def test_ideal_runtime_reproduces_analytic_walltime():
    """Zero faults + zero cold start + static allocation must reproduce
    wall = orchestration + max(batch/speedup + invoke_overhead) exactly."""
    ex = ServerlessExecutor()  # default = ideal runtime, static allocation
    per_batch = [0.31, 1.27, 0.064, 0.88, 0.5]
    model_bytes, batch_bytes = int(4e9), int(1e6)
    rep = ex.simulate(per_batch, model_bytes=model_bytes, batch_bytes=batch_bytes)

    plan = ex.planner.plan(
        model_bytes=model_bytes, batch_bytes=batch_bytes,
        num_batches=len(per_batch), instance_vcpus=ex.instance_vcpus,
    )
    speed = plan.lambda_spec.speedup_vs_instance
    legacy_wall = ex.orchestration_overhead_s + max(
        t / speed + ex.invoke_overhead_s for t in per_batch
    )
    assert rep.lambda_memory_mb == plan.lambda_spec.memory_mb
    assert abs(rep.wall_time_s - legacy_wall) <= 1e-6
    # and the legacy cost formula (1), modulo the now-default request fee
    legacy_cost = ServerlessCost(
        compute_time_s=legacy_wall, num_batches=len(per_batch),
        lambda_memory_mb=plan.lambda_spec.memory_mb, instance=ex.instance,
        include_request_fee=False,
    ).cost_per_peer
    assert rep.cost_usd - rep.request_fee_usd == pytest.approx(legacy_cost, abs=1e-12)
    assert rep.num_cold_starts == len(per_batch)  # first-ever containers...
    assert rep.cold_start_s == 0.0  # ...at zero penalty
    assert rep.num_retries == 0 and rep.queue_wait_s == 0.0


def test_ideal_runtime_is_deterministic_and_epoch_auto_increments():
    a = ServerlessExecutor()
    b = ServerlessExecutor()
    for ex in (a, b):
        ex.simulate([0.2, 0.4], model_bytes=int(1e8), batch_bytes=int(1e5))
        ex.simulate([0.2, 0.4], model_bytes=int(1e8), batch_bytes=int(1e5))
    assert [r.makespan_s for r in a.history[0]] == [r.makespan_s for r in b.history[0]]
    assert [len(a.history[0]), a.history[0][0].memory_mb] == [2, b.history[0][0].memory_mb]


# ---------------------------------------------------------------------------
# Cold/warm container pool
# ---------------------------------------------------------------------------

def test_warm_pool_reuse_across_epochs():
    ex = ServerlessExecutor(runtime=RuntimeConfig(cold_start_s=2.0))
    kw = dict(model_bytes=int(1e8), batch_bytes=int(1e5))
    r0 = ex.simulate([0.1] * 4, **kw)
    r1 = ex.simulate([0.1] * 4, **kw)
    assert r0.num_cold_starts == 4 and r0.cold_start_s == pytest.approx(8.0)
    assert r1.num_cold_starts == 0 and r1.cold_start_s == 0.0
    assert r0.wall_time_s == pytest.approx(r1.wall_time_s + 2.0)
    # cold-start GB-seconds are billed
    assert r0.cost_usd > r1.cost_usd


def test_memory_tier_change_strands_warm_pool():
    rt = ServerlessRuntime(RuntimeConfig(cold_start_s=1.0))
    r0 = rt.fanout([0.1] * 3, memory_mb=832)
    r1 = rt.fanout([0.1] * 3, memory_mb=832)
    r2 = rt.fanout([0.1] * 3, memory_mb=896)  # re-sized -> cold again
    assert r0.num_cold_starts == 3 and r1.num_cold_starts == 0
    assert r2.num_cold_starts == 3


def test_warm_pool_expires_after_keepalive():
    rt = ServerlessRuntime(RuntimeConfig(cold_start_s=1.0, container_keepalive_s=5.0))
    rt.fanout([0.1], memory_mb=832)
    rt.clock += 100.0  # idle deployment, TTL long gone
    r = rt.fanout([0.1], memory_mb=832)
    assert r.num_cold_starts == 1


# ---------------------------------------------------------------------------
# Concurrency caps
# ---------------------------------------------------------------------------

def test_concurrency_cap_serializes_and_records_queue_wait():
    rt = ServerlessRuntime(RuntimeConfig(concurrency_limit=1))
    r = rt.fanout([1.0, 1.0, 1.0], memory_mb=832)
    assert r.makespan_s == pytest.approx(3.0)
    assert r.queue_wait_s_total == pytest.approx(0.0 + 1.0 + 2.0)

    rt2 = ServerlessRuntime(RuntimeConfig(concurrency_limit=3))
    r2 = rt2.fanout([1.0, 1.0, 1.0], memory_mb=832)
    assert r2.makespan_s == pytest.approx(1.0)
    assert r2.queue_wait_s_total == 0.0


# ---------------------------------------------------------------------------
# Failures, retries, stragglers
# ---------------------------------------------------------------------------

def test_failures_retry_with_backoff_and_are_billed():
    cfg = RuntimeConfig(failure_rate=0.5, retry_backoff_s=0.25, seed=3)
    r = ServerlessRuntime(cfg).fanout([1.0] * 20, memory_mb=832)
    assert r.num_retries > 0
    # dead work + backoff stretch the makespan past the fault-free 1.0s
    assert r.makespan_s > 1.0
    assert r.retry_s_total > 0
    assert r.billed_s_total > sum(i.exec_s for i in r.invocations)
    # same seed -> identical trajectory
    r2 = ServerlessRuntime(cfg).fanout([1.0] * 20, memory_mb=832)
    assert [(i.attempts, i.end_s) for i in r.invocations] == [
        (i.attempts, i.end_s) for i in r2.invocations
    ]
    # retries show up in dollars: re-executed GB-s + per-request fees
    with_retries = ServerlessCost(
        compute_time_s=2.0, num_batches=20, lambda_memory_mb=832,
        num_retries=r.num_retries,
        retry_billed_s=sum(i.failed_s for i in r.invocations),
    )
    without = ServerlessCost(compute_time_s=2.0, num_batches=20, lambda_memory_mb=832)
    assert with_retries.cost_per_peer > without.cost_per_peer
    assert with_retries.request_fee_usd > without.request_fee_usd


def test_stragglers_are_seeded_and_stretch_the_tail():
    cfg = RuntimeConfig(straggler_prob=1.0, straggler_slowdown=2.0, seed=11)
    r = ServerlessRuntime(cfg).fanout([1.0] * 8, memory_mb=832)
    assert all(i.straggler_factor > 1.0 for i in r.invocations)
    assert r.makespan_s > 1.0
    r2 = ServerlessRuntime(cfg).fanout([1.0] * 8, memory_mb=832)
    assert [i.straggler_factor for i in r.invocations] == [
        i.straggler_factor for i in r2.invocations
    ]


def test_hard_timeout_exhausts_retry_budget():
    rt = ServerlessRuntime(RuntimeConfig(max_retries=2, retry_backoff_s=0.0))
    with pytest.raises(FanoutTimeout):
        rt.fanout([10.0], memory_mb=832, timeout_s=5.0)


# ---------------------------------------------------------------------------
# AllocationPolicy registry
# ---------------------------------------------------------------------------

def test_allocation_registry_enumerates_and_rejects_unknown():
    names = available_allocations()
    assert {"static", "latency", "aimd"} <= set(names)
    with pytest.raises(ValueError, match="registered policies"):
        get_allocation("definitely-not-registered")
    for n in names:
        assert get_allocation(n).name == n


def test_register_allocation_decorator():
    @register_allocation("test_fixed_tier")
    class FixedTier(AllocationPolicy):
        def memory_mb(self, *, epoch, planned_mb, history):
            return 4096

    assert "test_fixed_tier" in available_allocations()
    ex = ServerlessExecutor(allocation="test_fixed_tier")
    rep = ex.simulate([0.5], model_bytes=int(1e8), batch_bytes=int(1e5))
    assert rep.lambda_memory_mb == 4096


def test_latency_allocation_buys_walltime_with_memory():
    """Dynamic allocation measurably changes accounted wall-time vs static."""
    kw = dict(model_bytes=int(5e7), batch_bytes=int(4e6))
    static = ServerlessExecutor(allocation="static")
    dynamic = ServerlessExecutor(
        allocation=get_allocation("latency", target_batch_s=0.5)
    )
    per_batch = [1.0] * 8
    s_walls, d_walls, d_mem = [], [], []
    for epoch in range(3):
        s_walls.append(static.simulate(per_batch, epoch=epoch, **kw).wall_time_s)
        rep = dynamic.simulate(per_batch, epoch=epoch, **kw)
        d_walls.append(rep.wall_time_s)
        d_mem.append(rep.lambda_memory_mb)
    assert s_walls[0] == pytest.approx(s_walls[-1])  # static: no adaptation
    assert d_mem[-1] > d_mem[0]  # policy grew the tier
    assert d_walls[-1] < 0.7 * s_walls[-1]  # and bought wall-time for it


def test_allocation_clamped_to_fit_floor_and_lambda_cap():
    ex = ServerlessExecutor(
        allocation=get_allocation("latency", target_batch_s=1e6)  # "shrink forever"
    )
    kw = dict(model_bytes=int(4e9), batch_bytes=int(1e6))
    r0 = ex.simulate([0.5] * 2, epoch=0, **kw)
    r1 = ex.simulate([0.5] * 2, epoch=1, **kw)
    assert r1.lambda_memory_mb == r0.lambda_memory_mb  # can't go below fit floor

    @register_allocation("test_huge_tier")
    class Huge(AllocationPolicy):
        def memory_mb(self, *, epoch, planned_mb, history):
            return 10**9

    r = ServerlessExecutor(allocation="test_huge_tier").simulate([0.5], **kw)
    assert r.lambda_memory_mb == 10_240  # Lambda cap


def test_aimd_allocation_converges_near_target():
    ex = ServerlessExecutor(
        allocation=get_allocation("aimd", target_batch_s=1.0, increase_mb=512)
    )
    kw = dict(model_bytes=int(5e7), batch_bytes=int(4e6))
    mems = [
        ex.simulate([1.0] * 4, epoch=e, **kw).lambda_memory_mb for e in range(6)
    ]
    assert mems[1] > mems[0]  # additive increase while over target
    exec_last = ex.history[0][-1].max_exec_s
    assert exec_last < 1.5  # settled around the target latency
