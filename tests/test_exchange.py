"""ExchangeProtocol registry: enumeration, errors, byte accounting, host
codec roundtrips, checkpoint versioning — plus sync-protocol equivalence
with the reference mean on a 4-device CPU mesh (subprocess)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import TrainState, Topology, as_train_state
from repro.core.compression import QSGDConfig
from repro.core.exchange import (
    ExchangeContext,
    ExchangeProtocol,
    available_exchanges,
    get_exchange,
    register_exchange,
)

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def test_registry_enumerates_all_protocols():
    names = available_exchanges()
    assert {
        "allgather_mean", "psum_mean", "qsgd", "topk", "async",
        "reduce_scatter", "trimmed_mean", "median", "krum",
    } <= set(names)
    for n in names:
        proto = get_exchange(n)
        assert isinstance(proto, ExchangeProtocol)
        assert proto.name == n


def test_parameterized_exchange_specs():
    # NAME:ARG mirrors the graph registry's gossip:K idiom
    assert get_exchange("trimmed_mean:0.25").frac == 0.25
    assert get_exchange("trimmed_mean").frac is None  # falls back to ctx
    assert get_exchange("krum:2").m == 2
    with pytest.raises(ValueError, match=r"\[0, 0.5\)"):
        get_exchange("trimmed_mean:0.7")
    with pytest.raises(ValueError, match=">= 1"):
        get_exchange("krum:0")
    with pytest.raises(ValueError, match="does not take"):
        get_exchange("allgather_mean:3")
    with pytest.raises(ValueError, match="unknown exchange protocol"):
        get_exchange("nope:1")
    # krum's pairwise distances need every contribution
    assert get_exchange("krum").requires_full_graph
    assert not get_exchange("median").requires_full_graph


def test_unknown_exchange_raises_helpful_error():
    with pytest.raises(ValueError, match="unknown exchange protocol"):
        get_exchange("carrier_pigeon")
    with pytest.raises(ValueError, match="allgather_mean"):
        get_exchange("carrier_pigeon")  # message lists registered names
    # Topology resolves through the same registry
    with pytest.raises(ValueError, match="registered protocols"):
        Topology(exchange="carrier_pigeon").protocol()


def test_register_exchange_extends_topology_names():
    @register_exchange("_test_identity")
    class Identity(ExchangeProtocol):
        def combine(self, grads, ctx, *, key=None, state=None):
            return grads, state

    assert "_test_identity" in available_exchanges()
    assert isinstance(Topology(exchange="_test_identity").protocol(), Identity)


def test_wire_byte_accounting():
    grads = {"a": jnp.zeros((128, 64)), "b": jnp.zeros((100,))}
    n = 128 * 64 + 100
    ctx = ExchangeContext(num_peers=4, qsgd=QSGDConfig(levels=127, bucket=128),
                          topk_frac=0.1)
    # per-edge payload is the old publish-side figure; the per-peer total
    # scales by the overlay degree (no graph set => full mesh, P-1 = 3)
    raw = get_exchange("allgather_mean").wire_bytes_per_edge(grads, ctx)
    assert raw == n * 4
    assert get_exchange("allgather_mean").wire_bytes(grads, ctx) == 3 * raw
    # ring all-reduce: fused collective, 2(P-1)/P of raw regardless of
    # degree; the host mailbox publishes the dense payload
    assert get_exchange("psum_mean").wire_bytes(grads, ctx) == int(raw * 2 * 3 / 4)
    assert get_exchange("psum_mean").host_wire_bytes(grads, ctx) == raw
    assert not get_exchange("psum_mean").decomposes_per_edge
    # qsgd: ~1 byte/elt + norms, > 3x compression (per edge)
    q = get_exchange("qsgd").wire_bytes_per_edge(grads, ctx)
    assert q < raw / 3
    assert get_exchange("qsgd").wire_bytes(grads, ctx) == 3 * q
    # topk: k entries x (4B value + 4B index) per edge
    t = get_exchange("topk").wire_bytes_per_edge(grads, ctx)
    expect = (round(128 * 64 * 0.1)) * 8 + (round(100 * 0.1)) * 8
    assert t == expect
    # bf16 wire dtype halves value bytes
    half = ExchangeContext(num_peers=4, wire_dtype=jnp.bfloat16)
    assert get_exchange("allgather_mean").wire_bytes_per_edge(grads, half) == n * 2
    # a sparse overlay shrinks the per-peer total: ring degree is 2
    from repro.core.graph import get_graph

    rg = get_graph("ring", 8)
    rctx = ExchangeContext(num_peers=8, graph=rg, mixing=rg.mixing_matrix())
    assert get_exchange("allgather_mean").wire_bytes(grads, rctx) == 2 * n * 4


def test_qsgd_host_roundtrip_close():
    proto = get_exchange("qsgd")
    ctx = ExchangeContext(qsgd=QSGDConfig(levels=127, bucket=128))
    grads = {"w": jax.random.normal(jax.random.PRNGKey(0), (300,))}
    payload, nbytes = proto.host_encode(grads, ctx, key=jax.random.PRNGKey(1))
    assert 0 < nbytes < 300 * 4
    back = proto.host_decode(payload, grads, ctx)
    err = float(jnp.abs(back["w"] - grads["w"]).max())
    assert 0 < err < 0.5  # bounded quantization error, not exact


def test_topk_host_roundtrip_keeps_largest():
    proto = get_exchange("topk")
    ctx = ExchangeContext(topk_frac=0.2)
    g = {"w": jnp.asarray([0.1, -5.0, 0.2, 4.0, -0.3, 0.05, 0.0, 1.0, -0.2, 0.15])}
    payload, nbytes = proto.host_encode(g, ctx)
    assert nbytes == 2 * 8  # k=2 entries x 8 bytes
    back = proto.host_decode(payload, g, ctx)["w"]
    np.testing.assert_allclose(
        np.asarray(back),
        [0, -5.0, 0, 4.0, 0, 0, 0, 0, 0, 0],
        atol=1e-6,
    )


def test_async_init_state_ring_shape():
    proto = get_exchange("async")
    ring = proto.init_state(
        {"w": jnp.zeros((3, 2))}, ExchangeContext(num_peers=4, staleness=3)
    )
    assert jax.tree.leaves(ring)[0].shape == (3, 4, 3, 2)


def test_train_state_dict_compat_and_pytree():
    s = TrainState(params={"w": jnp.ones(2)}, opt_state=(), step=jnp.int32(3),
                   key=jax.random.PRNGKey(0))
    assert s["step"] == 3 and s.get("mailbox") is None
    assert "mailbox" not in dict(s)
    # absent mailbox behaves like the legacy dict: not a member, KeyError on lookup
    assert "mailbox" not in s and "params" in s
    assert list(iter(s)) == s.keys()
    with pytest.raises(KeyError):
        s["mailbox"]
    legacy = as_train_state({"params": s.params, "opt_state": (), "step": s.step,
                             "key": s.key})
    assert isinstance(legacy, TrainState)
    doubled = jax.tree.map(lambda x: x * 2, s)
    assert isinstance(doubled, TrainState)
    assert float(doubled.params["w"][0]) == 2.0
    with pytest.raises(KeyError):
        s["nope"]


def test_checkpoint_versioning(tmp_path):
    from repro.train import checkpoint as ckpt

    state = TrainState(
        params={"w": jnp.arange(4.0)},
        opt_state={"momentum": {"w": jnp.ones(4)}},
        step=jnp.int32(7),
        key=jax.random.PRNGKey(0),
    )
    # v2: full state roundtrip
    p2 = str(tmp_path / "state_v2")
    ckpt.save_state(p2, state)
    like = jax.tree.map(jnp.zeros_like, state)
    back, meta = ckpt.restore_state(p2, like)
    assert meta["format"] == ckpt.STATE_FORMAT and meta["step"] == 7
    np.testing.assert_array_equal(np.asarray(back.params["w"]), np.arange(4.0))
    assert int(back.step) == 7
    # sync-protocol v2 checkpoint restores into an async `like`: the cold
    # mailbox ring from `like` is kept, everything else comes from disk
    ring = {"w": jnp.zeros((1, 2, 4))}
    back_a, _ = ckpt.restore_state(p2, like.replace(mailbox=ring))
    np.testing.assert_array_equal(np.asarray(back_a.params["w"]), np.arange(4.0))
    assert back_a.mailbox is ring
    # v1 (params-only) restores into .params and keeps the rest fresh
    p1 = str(tmp_path / "params_v1")
    ckpt.save(p1, state.params, step=3)
    back1, meta1 = ckpt.restore_state(p1, like)
    np.testing.assert_array_equal(np.asarray(back1.params["w"]), np.arange(4.0))
    assert int(back1.step) == 0  # from `like`, not the checkpoint
    assert float(back1.opt_state["momentum"]["w"][0]) == 0.0


@pytest.mark.slow
def test_sync_protocols_match_reference_mean_multidevice():
    """psum_mean / allgather_mean / topk(frac=1) == the P-peer mean, and
    qsgd is within the quantization error bound — on a 4-device CPU mesh."""
    script = textwrap.dedent(
        """
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro import compat
        from repro.core.compression import QSGDConfig
        from repro.core.exchange import ExchangeContext, get_exchange

        mesh = compat.make_mesh((4,), ("data",),
                                axis_types=(compat.AxisType.Auto,))
        g_global = {
            "w": jax.random.normal(jax.random.PRNGKey(0), (4, 6, 33)),
            "b": jax.random.normal(jax.random.PRNGKey(1), (4, 17)),
        }
        ref = jax.tree.map(lambda x: x.mean(axis=0), g_global)

        def run(name, **ctx_kw):
            proto = get_exchange(name)
            ctx = ExchangeContext(axis="data", num_peers=4, **ctx_kw)

            def body(g):
                per_peer = jax.tree.map(lambda x: x[0], g)  # drop peer dim
                key = jax.random.PRNGKey(7) if proto.requires_key else None
                avg, _ = proto.combine(per_peer, ctx, key=key)
                return avg

            fn = compat.shard_map(
                body, mesh=mesh,
                in_specs=(jax.tree.map(lambda _: P("data"), g_global),),
                out_specs=jax.tree.map(lambda _: P(), g_global),
                axis_names={"data"}, check_vma=False,
            )
            with compat.set_mesh(mesh):
                return jax.jit(fn)(g_global)

        for name, kw, tol in [
            ("allgather_mean", {}, 1e-6),
            ("psum_mean", {}, 1e-6),
            ("reduce_scatter", {}, 1e-6),  # sharded ring, same mean
            ("tree", {}, 1e-6),  # binary tree reduce, same mean
            ("tree:3", {}, 1e-6),  # non-dyadic fanout at P=4
            ("topk", {"topk_frac": 1.0}, 1e-6),  # k=n: lossless
            ("qsgd", {"qsgd": QSGDConfig(levels=127, bucket=64)}, 0.5),
            ("trimmed_mean:0", {}, 1e-6),  # zero trim IS the mean
            ("trimmed_mean", {}, 1e-6),  # ctx default trim_frac=0.0
        ]:
            avg = run(name, **kw)
            err = max(
                float(jnp.abs(a - b).max())
                for a, b in zip(jax.tree.leaves(avg), jax.tree.leaves(ref))
            )
            assert err <= tol, (name, err)
            print(name, "err", err)

        # sparsified topk deviates but preserves the largest coordinates
        sparse = run("topk", topk_frac=0.25)
        err = float(jnp.abs(sparse["w"] - ref["w"]).max())
        assert err > 0, "frac<1 must be lossy on dense gradients"

        # coordinate median == numpy median over the peer axis
        med = run("median")
        med_ref = jax.tree.map(lambda x: jnp.median(x, axis=0), g_global)
        err = max(
            float(jnp.abs(a - b).max())
            for a, b in zip(jax.tree.leaves(med), jax.tree.leaves(med_ref))
        )
        assert err <= 1e-6, ("median", err)

        # krum picks the row with the lowest summed distance to its
        # P - f - 2 nearest peers (f defaults to (P-3)//2 = 0 at P=4)
        flat = np.concatenate(
            [np.asarray(g_global[k]).reshape(4, -1) for k in ("w", "b")], 1
        )
        d2 = ((flat[:, None, :] - flat[None, :, :]) ** 2).sum(-1)
        np.fill_diagonal(d2, np.inf)
        scores = np.sort(d2, axis=1)[:, :2].sum(1)
        kref = flat[int(np.argmin(scores))]
        kr = run("krum")
        kflat = np.concatenate(
            [np.asarray(kr[k]).reshape(-1) for k in ("w", "b")]
        )
        err = float(np.abs(kflat - kref).max())
        assert err <= 1e-5, ("krum", err)
        print("OK")
        """
    )
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = SRC
    r = subprocess.run(
        [sys.executable, "-c", script], env=env, capture_output=True, text=True,
        timeout=600,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout
