"""PeerGraph registry + overlay-aware exchange: mixing-matrix properties
(row-stochasticity, symmetry, spectral-gap sanity) for every registered
graph at P in {2, 4, 8}; device- and host-path equivalence of
``graph="full"`` with the legacy allgather_mean math; Metropolis–Hastings
mixing on the host path; HostMailbox edge enforcement under churn; the
``exchange_gradients`` num_peers fix; the ``async_mode`` deprecation."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import LocalP2PCluster, Topology, exchange_context
from repro.core.exchange import ExchangeContext, get_exchange
from repro.core.graph import (
    PeerGraph,
    StaticGraph,
    available_graphs,
    get_graph,
    register_graph,
)
from repro.core.mailbox import HostMailbox
from repro.core.p2p import exchange_gradients, init_mailbox
from repro.data import BatchKey, make_dataset
from repro.optim import sgd

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


# ---------------------------------------------------------------------------
# Registry + construction
# ---------------------------------------------------------------------------

def test_registry_enumerates_graphs():
    names = available_graphs()
    assert {"full", "ring", "gossip", "hierarchical", "static"} <= set(names)
    for name in ("full", "ring", "gossip", "hierarchical"):
        g = get_graph(name, 4)
        assert isinstance(g, PeerGraph) and g.name == name


def test_unknown_graph_and_bad_param_raise():
    with pytest.raises(ValueError, match="unknown peer graph"):
        get_graph("smallworld", 4)
    with pytest.raises(ValueError, match="registered graphs"):
        get_graph("smallworld", 4)
    with pytest.raises(ValueError, match="must be an int"):
        get_graph("gossip:many", 4)
    with pytest.raises(ValueError, match="explicit adjacency"):
        get_graph("static", 4)  # programmatic-only
    with pytest.raises(ValueError, match="built for 4 peers"):
        get_graph(get_graph("ring", 4), 8)


def test_register_graph_extends_topology_names():
    @register_graph("_test_line")
    class Line(PeerGraph):
        def __init__(self, num_peers, *, seed=0):
            super().__init__(num_peers)

        def build_adjacency(self):
            P = self.num_peers
            adj = np.zeros((P, P), dtype=bool)
            for r in range(P - 1):
                adj[r, r + 1] = adj[r + 1, r] = True
            return adj

    assert "_test_line" in available_graphs()
    topo = Topology(peer_axes=("data",), graph="_test_line")
    assert topo.peer_graph(4).neighbors(0) == (1,)


@pytest.mark.parametrize("P", [2, 4, 8])
@pytest.mark.parametrize("spec", ["full", "ring", "gossip:3", "hierarchical"])
def test_mixing_matrix_properties(spec, P):
    if spec == "gossip:3" and P <= 3:
        pytest.skip("gossip:k now validates k < P")
    g = get_graph(spec, P, seed=1)
    W = g.mixing_matrix()
    # row-stochastic, symmetric => doubly stochastic
    np.testing.assert_allclose(W.sum(axis=1), np.ones(P), atol=1e-12)
    np.testing.assert_allclose(W, W.T, atol=1e-12)
    assert (W >= -1e-12).all()
    # connected graph => spectral gap strictly positive, <= 1
    assert g.is_connected()
    gap = g.spectral_gap()
    assert 0.0 < gap <= 1.0 + 1e-12
    # off-diagonal support matches adjacency exactly
    off = W.copy()
    np.fill_diagonal(off, 0.0)
    np.testing.assert_array_equal(off > 0, g.adjacency)


def test_full_graph_mixing_is_uniform_mean():
    for P in (2, 4, 8):
        W = get_graph("full", P).mixing_matrix()
        np.testing.assert_allclose(W, np.full((P, P), 1.0 / P), atol=1e-12)
    assert get_graph("full", 8).spectral_gap() == pytest.approx(1.0)


def test_spectral_gap_orders_density():
    # denser overlays mix faster: full >= gossip:3 >= ring at P=8
    gaps = {s: get_graph(s, 8, seed=0).spectral_gap()
            for s in ("full", "gossip:3", "ring")}
    assert gaps["full"] >= gaps["gossip:3"] >= gaps["ring"] > 0


def test_hierarchical_structure():
    g = get_graph("hierarchical:4", 8)
    hubs = (0, 4)
    assert g.adjacency[0, 4]  # hub mesh
    for spoke in (1, 2, 3):
        assert g.neighbors(spoke) == (0,)  # spokes see only their hub
    for spoke in (5, 6, 7):
        assert g.neighbors(spoke) == (4,)
    assert set(g.neighbors(0)) == {1, 2, 3, 4}
    assert g.max_degree == 4 and g.is_connected()


def test_gossip_is_seeded_and_min_degree():
    a = get_graph("gossip:3", 16, seed=7)
    b = get_graph("gossip:3", 16, seed=7)
    c = get_graph("gossip:3", 16, seed=8)
    np.testing.assert_array_equal(a.adjacency, b.adjacency)
    assert not np.array_equal(a.adjacency, c.adjacency)  # seed matters
    assert int(a.degrees.min()) >= 3 and a.is_connected()


def test_gossip_degree_validated_against_num_peers():
    # regression: k >= P used to degrade silently (the round loop could
    # never reach min-degree k); now it is a clean spec error naming both
    with pytest.raises(ValueError, match=r"k=3.*num_peers=2"):
        get_graph("gossip:3", 2)
    with pytest.raises(ValueError, match=r"k=8.*num_peers=8"):
        get_graph("gossip:8", 8)
    with pytest.raises(ValueError, match="must be >= 1"):
        get_graph("gossip:0", 8)
    assert int(get_graph("gossip:7", 8).degrees.min()) >= 7  # k = P-1 is fine


def test_static_graph_from_edges():
    g = StaticGraph.from_edges(4, [(0, 1), (1, 2), (2, 3)])
    assert g.neighbors(1) == (0, 2) and not g.is_full
    assert get_graph(g, 4) is g
    with pytest.raises(ValueError, match="symmetric"):
        StaticGraph(2, np.array([[False, True], [False, False]]))


# ---------------------------------------------------------------------------
# Context resolution + degree-aware accounting
# ---------------------------------------------------------------------------

def test_exchange_context_resolves_graph_and_mixing():
    ctx = exchange_context(
        Topology(peer_axes=("data",), graph="ring"), num_peers=4
    )
    assert ctx.graph.name == "ring" and ctx.degree == 2.0
    np.testing.assert_allclose(ctx.mixing.sum(axis=1), np.ones(4), atol=1e-6)
    # full graph keeps the legacy bit-exact mean path: no mixing matrix
    ctx_full = exchange_context(Topology(peer_axes=("data",)), num_peers=4)
    assert ctx_full.graph.name == "full" and ctx_full.mixing is None
    assert ctx_full.degree == 3.0


def test_wire_bytes_scale_with_degree():
    grads = {"w": jnp.zeros((128, 64), jnp.float32)}
    proto = get_exchange("allgather_mean")
    per_edge = 128 * 64 * 4
    for P, spec, degree in [(8, "ring", 2), (8, "full", 7), (16, "full", 15)]:
        g = get_graph(spec, P)
        ctx = ExchangeContext(num_peers=P, graph=g,
                              mixing=None if g.is_full else g.mixing_matrix())
        assert proto.wire_bytes_per_edge(grads, ctx) == per_edge
        assert proto.wire_bytes(grads, ctx) == per_edge * degree
        # the host mailbox publish is one payload regardless of degree
        assert proto.host_wire_bytes(grads, ctx) == per_edge


def test_psum_mean_rejects_sparse_graph():
    g = get_graph("ring", 4)
    ctx = ExchangeContext(axis="data", num_peers=4, graph=g,
                          mixing=g.mixing_matrix())
    with pytest.raises(ValueError, match="only supports graph='full'"):
        get_exchange("psum_mean").combine({"w": jnp.zeros(3)}, ctx)
    # ...and at construction time, not just inside the jitted step trace
    with pytest.raises(ValueError, match="fused global collective"):
        exchange_context(
            Topology(peer_axes=("data",), exchange="psum_mean", graph="ring"),
            num_peers=4,
        )
    with pytest.raises(ValueError, match="fused global collective"):
        _tiny_cluster(sync=True, exchange="psum_mean", graph="ring")
    # the full graph stays fine for fused collectives
    assert exchange_context(
        Topology(peer_axes=("data",), exchange="psum_mean"), num_peers=4
    ).mixing is None


# ---------------------------------------------------------------------------
# Satellite: exchange_gradients num_peers plumbing
# ---------------------------------------------------------------------------

def test_exchange_gradients_requires_explicit_num_peers():
    topo = Topology(peer_axes=("data",), exchange="async")
    grads = {"w": jnp.ones((3,))}
    # sync/no-mailbox: peer count is no longer silently inferred as 1
    with pytest.raises(ValueError, match="num_peers"):
        exchange_gradients(grads, Topology(peer_axes=("data",)))
    # async mailbox fallback still works (ring leaves are (K, P, *grad))
    mb = init_mailbox(grads, num_peers=4)
    assert jax.tree.leaves(mb)[0].shape[:2] == (1, 4)
    # no-peer topologies pass through untouched
    out, mb2 = exchange_gradients(grads, Topology(peer_axes=()), mailbox=None)
    assert out is grads and mb2 is None


def test_topology_async_mode_deprecated():
    with pytest.warns(DeprecationWarning, match='exchange="async"'):
        topo = Topology(peer_axes=("data",), async_mode=True)
    assert topo.exchange_name == "async"  # behavior kept


# ---------------------------------------------------------------------------
# HostMailbox: deliveries respect graph edges (incl. under churn)
# ---------------------------------------------------------------------------

def test_mailbox_blocks_non_edge_consumption():
    g = get_graph("ring", 4)
    mb = HostMailbox(4, graph=g)
    mb.publish(2, "g2", nbytes=8, time=0.0, epoch=0)
    # 0-2 is not a ring edge: refused and counted
    assert mb.consume(2, consumer=0) is None
    assert mb.stats["blocked"] == 1
    # 1-2 is an edge: delivered and recorded
    assert mb.consume(2, consumer=1).payload == "g2"
    assert (1, 2) in mb.delivered_edges
    # anonymous consumers (legacy callers) keep broker semantics
    assert mb.consume(2).payload == "g2"


def _tiny_cluster(**kw):
    return LocalP2PCluster(
        get_config("squeezenet1.1"),
        make_dataset("mnist", size=128, image_hw=8, channels=1),
        num_peers=4,
        batch_size=8,
        batches_per_epoch=1,
        optimizer=sgd(momentum=0.0),
        lr=0.05,
        seed=0,
        **kw,
    )


def test_host_deliveries_respect_edges_under_churn():
    cl = _tiny_cluster(
        sync=False, graph="ring", churn_prob=0.4, churn_downtime_s=0.5,
        peer_speeds=[1.0, 2.0, 3.0, 4.0],
    )
    for e in range(3):
        cl.run_epoch_async(e)
    assert sum(p.drops for p in cl.peers) > 0  # churn actually fired
    assert cl.mailbox.delivered_edges  # gradients actually flowed
    for consumer, producer in cl.mailbox.delivered_edges:
        assert cl.graph.adjacency[consumer, producer], (consumer, producer)
    assert cl.mailbox.stats["blocked"] == 0  # cluster never even tried


# ---------------------------------------------------------------------------
# Host-path equivalence + MH mixing correctness
# ---------------------------------------------------------------------------

def test_host_full_graph_matches_legacy_bit_for_bit():
    a = _tiny_cluster(sync=True)
    b = _tiny_cluster(sync=True, graph="full")
    a.run_epoch_sync(0)
    b.run_epoch_sync(0)
    for pa, pb in zip(a.peers, b.peers):
        for x, y in zip(jax.tree.leaves(pa.params), jax.tree.leaves(pb.params)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_host_ring_applies_metropolis_hastings_weights():
    cl = _tiny_cluster(sync=True, graph="ring")
    ref = _tiny_cluster(sync=True)  # identical init (same seed)
    W = cl.graph.mixing_matrix()
    grads = {}
    for peer in ref.peers:
        b = jax.tree.map(jnp.asarray, peer.loader.load(BatchKey(peer.rank, 0, 0)))
        grads[peer.rank], _, _ = ref._grad(peer.params, b)
    cl.run_epoch_sync(0)
    for r in range(4):
        ranks = sorted([r] + list(cl.graph.neighbors(r)))
        mixed = jax.tree.map(
            lambda *xs: sum(
                float(W[r, j]) * x.astype(jnp.float32)
                for j, x in zip(ranks, xs)
            ),
            *[grads[j] for j in ranks],
        )
        want, _ = ref._apply(
            ref.peers[r].params, ref.peers[r].opt_state, mixed, jnp.float32(0.05)
        )
        for x, y in zip(jax.tree.leaves(cl.peers[r].params), jax.tree.leaves(want)):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=1e-6)


# ---------------------------------------------------------------------------
# Device-path equivalence (4-device subprocess)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_device_full_graph_bit_exact_and_ring_mixes():
    """graph='full' reproduces allgather_mean bit-for-bit; graph='ring'
    applies the MH row weights; async mixing reduces to the legacy math on
    the full graph — on a 4-device CPU mesh."""
    script = textwrap.dedent(
        """
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro import compat
        from repro.core.p2p import Topology, exchange_context

        mesh = compat.make_mesh((4,), ("data",),
                                axis_types=(compat.AxisType.Auto,))
        g_global = {
            "w": jax.random.normal(jax.random.PRNGKey(0), (4, 6, 33)),
            "b": jax.random.normal(jax.random.PRNGKey(1), (4, 17)),
        }

        def run(name="allgather_mean", **topo_kw):
            topo = Topology(peer_axes=("data",), lambda_axis=None,
                            exchange=name, **topo_kw)
            ctx = exchange_context(topo, mesh)
            proto = topo.protocol()

            def body(g):
                per = jax.tree.map(lambda x: x[0], g)
                avg, _ = proto.combine(per, ctx, key=None)
                return jax.tree.map(lambda x: x[None], avg)

            fn = compat.shard_map(
                body, mesh=mesh,
                in_specs=(jax.tree.map(lambda _: P("data"), g_global),),
                out_specs=jax.tree.map(lambda _: P("data"), g_global),
                axis_names={"data"}, check_vma=False,
            )
            with compat.set_mesh(mesh):
                return jax.jit(fn)(g_global), ctx

        legacy, _ = run()
        full, _ = run(graph="full")
        for a, b in zip(jax.tree.leaves(legacy), jax.tree.leaves(full)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

        ring, rctx = run(graph="ring")
        W = np.asarray(rctx.mixing, np.float32)
        for kname in ("w", "b"):
            want = np.einsum(
                "rp,p...->r...", W, np.asarray(g_global[kname], np.float32)
            )
            err = np.abs(np.asarray(ring[kname]) - want).max()
            assert err < 1e-5, (kname, err)

        # topk(frac=1) under ring == exact MH mix (lossless sparsification)
        ringt, _ = run("topk", graph="ring", topk_frac=1.0)
        want = np.einsum("rp,p...->r...", W,
                         np.asarray(g_global["w"], np.float32))
        assert np.abs(np.asarray(ringt["w"]) - want).max() < 1e-5
        print("OK")
        """
    )
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = SRC
    r = subprocess.run(
        [sys.executable, "-c", script], env=env, capture_output=True, text=True,
        timeout=600,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout
