"""Checkpointing, HLO analyzer, sharding policy, metrics tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import analyze
from repro.metrics import StageMetrics
from repro.train import checkpoint as ckpt


def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "a": jnp.arange(12.0).reshape(3, 4),
        "nested": {"b": jnp.ones((5,), jnp.int32), "c": [jnp.zeros(()), jnp.ones((2, 2))]},
    }
    path = str(tmp_path / "ck")
    ckpt.save(path, tree, step=42, extra={"note": "x"})
    like = jax.tree.map(jnp.zeros_like, tree)
    back, meta = ckpt.restore(path, like)
    assert meta["step"] == 42 and meta["note"] == "x"
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_shape_mismatch(tmp_path):
    path = str(tmp_path / "ck")
    ckpt.save(path, {"a": jnp.ones((2, 2))})
    with pytest.raises(ValueError):
        ckpt.restore(path, {"a": jnp.ones((3, 3))})


def test_checkpoint_missing_key(tmp_path):
    path = str(tmp_path / "ck")
    ckpt.save(path, {"a": jnp.ones((2,))})
    with pytest.raises(ValueError):
        ckpt.restore(path, {"a": jnp.ones((2,)), "b": jnp.ones((2,))})


# ---------------------------------------------------------------------------
# HLO analyzer: trip-count-scaled FLOPs must be exact for scanned stacks
# ---------------------------------------------------------------------------

def test_hlo_analyzer_scales_scan_flops():
    def f(x, w):
        def body(h, wi):
            return jnp.tanh(h @ wi), None
        h, _ = jax.lax.scan(body, x, w)
        return h.sum()

    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    for L in (3, 9):
        w = jax.ShapeDtypeStruct((L, 128, 128), jnp.float32)
        st = analyze(jax.jit(f).lower(x, w).compile().as_text())
        assert st.flops == pytest.approx(2 * 64 * 128 * 128 * L, rel=1e-6)
        assert L in st.while_trips


def test_hlo_analyzer_counts_remat_recompute():
    def f(w, x):
        def body(h, wi):
            return jnp.tanh(h @ wi), None
        return jax.grad(
            lambda ww: jax.lax.scan(jax.checkpoint(body), x, ww)[0].sum()
        )(w)

    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((5, 128, 128), jnp.float32)
    st = analyze(jax.jit(f).lower(w, x).compile().as_text())
    one_mm = 2 * 64 * 128 * 128
    # fwd + remat-fwd + 2 bwd matmuls per layer = 4x
    assert st.flops == pytest.approx(4 * 5 * one_mm, rel=0.01)


def test_stage_metrics_table_shape():
    m = StageMetrics()
    with m.stage("compute_gradients"):
        sum(range(100000))
    m.add_simulated("cold_start", 2.5)
    t = m.table()
    # Table-I stages plus the runtime engine's simulated stages
    assert set(t) == set(StageMetrics.STAGES) | set(StageMetrics.SIM_STAGES)
    assert t["compute_gradients"]["time_s"] > 0
    assert t["cold_start"]["time_s"] == pytest.approx(2.5)
    assert t["cold_start"]["cpu_percent"] == 0.0  # simulated, never ran here


# ---------------------------------------------------------------------------
# Sharding policy unit tests (no devices needed: specs only)
# ---------------------------------------------------------------------------

def test_sanitize_spec_drops_nondivisible():
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.launch.sharding import sanitize_spec

    class FakeMesh:
        shape = {"data": 16, "model": 16}

    m = FakeMesh()
    assert sanitize_spec((1500,), P("model"), m) == P(None)
    assert sanitize_spec((1600,), P("model"), m) == P("model")
    assert sanitize_spec((256, 99), P("model", "data"), m) == P("model", None)
    assert sanitize_spec((512,), P(("data", "model")), m) == P(("data", "model"))
    # partial keep: divisible by data(16) but 32 not divisible by 256
    assert sanitize_spec((32,), P(("data", "model")), m) == P("data")


def test_param_spec_rules():
    from repro.launch.sharding import param_spec
    from jax.sharding import PartitionSpec as P
    from repro.configs import get_config

    class FakeMesh:
        shape = {"data": 16, "model": 16}

    cfg = get_config("qwen2.5-3b")
    # column-parallel attention projection (stacked): shard output features
    s = param_spec(("stack", "0", "mixer", "wq"), (36, 2048, 2048), cfg, FakeMesh())
    assert s == P(None, None, "model")
    # row-parallel output projection: shard input dim
    s = param_spec(("stack", "0", "mixer", "wo"), (36, 2048, 2048), cfg, FakeMesh())
    assert s == P(None, "model", None)
    # tiny leaves replicated
    s = param_spec(("final_norm", "scale"), (2048,), cfg, FakeMesh())
    assert s == P()
    # expert weights: expert-parallel
    dbrx = get_config("dbrx-132b")
    s = param_spec(("stack", "0", "ffn", "w_gate"), (40, 16, 6144, 10752), dbrx, FakeMesh())
    assert s[1] == "model"  # expert dim
    assert "data" in s  # fsdp
