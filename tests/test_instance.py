"""InstanceRuntime (the instance-based P2P baseline on the event engine):
analytic Formula-(2) equivalence at the ideal config, boot billing and
warm VM reuse, memory-constrained mini-batch splitting, seeded churn,
degree-aware wire charging, and the CostReport frontier API."""
import numpy as np
import pytest

from repro.core.cost import (
    CostReport,
    EC2_MEMORY_MB,
    EC2_VCPUS,
    GPU_BOOT_S,
    GPU_MEMORY_MB,
    GPU_SPEEDUP,
    GPU_USD_PER_HOUR,
    INSTANCE_MEMORY_MB,
    InstanceCost,
    compare_backends,
    dominates,
    ec2_cost_per_second,
    instance_equivalent_vcpus,
    is_gpu_instance,
    pareto_frontier,
)
from repro.core.events import InstanceConfig, LinkModel
from repro.core.instance import InstanceRuntime, instance_speedup, instance_splits
from repro.core.serverless import ServerlessExecutor


# ---------------------------------------------------------------------------
# Acceptance: ideal runtime == analytic Formula (2)  (<= 1e-6)
# ---------------------------------------------------------------------------

def test_ideal_instance_runtime_reproduces_formula2():
    """Zero boot, zero churn, unconstrained memory: the engine must
    reproduce the legacy closed form — wall = sum(per_batch), USD =
    Formula (2) — to <= 1e-6 (mirror of the PR-2 serverless test)."""
    ex = ServerlessExecutor(backend="instance", instance="t2.large")
    per_batch = [0.31, 1.27, 0.064, 0.88, 0.5]
    rep = ex.simulate_instance(per_batch)
    legacy_wall = sum(per_batch)
    legacy_cost = InstanceCost(legacy_wall, "t2.large").cost_per_peer
    assert abs(rep.wall_time_s - legacy_wall) <= 1e-6
    assert abs(rep.cost_usd - legacy_cost) <= 1e-6
    assert rep.backend == "instance" and rep.instance == "t2.large"
    assert rep.boot_s == 0.0 and rep.churn_drops == 0 and rep.num_splits == 1
    assert rep.instance_billed_s == pytest.approx(legacy_wall)


def test_ideal_equivalence_through_executor_run_path():
    """The executor's instance backend (used by LocalP2PCluster / fig3)
    still prices exactly like the legacy closed form at the defaults."""
    import jax.numpy as jnp

    ex = ServerlessExecutor(backend="instance", instance="t2.small")
    thunks = [lambda: jnp.zeros(4) for _ in range(3)]
    g, rep = ex.run(
        thunks, model_bytes=int(5e6), batch_bytes=int(1e5),
        combine=lambda outs: outs[0],
    )
    assert rep.backend == "instance"
    assert rep.wall_time_s == pytest.approx(rep.measured_compute_s, abs=1e-6)
    assert rep.cost_usd == pytest.approx(
        InstanceCost(rep.wall_time_s, "t2.small").cost_per_peer, abs=1e-9
    )


# ---------------------------------------------------------------------------
# Boot: billed, paid once per VM lifetime
# ---------------------------------------------------------------------------

def test_boot_is_billed_and_vm_stays_warm_across_epochs():
    ex = ServerlessExecutor(
        backend="instance", instance="t2.small",
        instance_config=InstanceConfig(boot_s=40.0),
    )
    r0 = ex.simulate_instance([1.0] * 4)
    r1 = ex.simulate_instance([1.0] * 4)
    assert r0.boot_s == pytest.approx(40.0)
    assert r0.wall_time_s == pytest.approx(44.0)
    # per-second billing includes the boot: you pay while the stack starts
    assert r0.cost_usd == pytest.approx(ec2_cost_per_second("t2.small") * 44.0)
    # the VM stays up: epoch 1 pays no boot (warm-pool analogue)
    assert r1.boot_s == 0.0 and r1.wall_time_s == pytest.approx(4.0)
    assert r0.epoch == 0 and r1.epoch == 1  # history auto-increments


def test_boot_is_per_peer():
    rt = InstanceRuntime(InstanceConfig(boot_s=10.0), instance="t2.small")
    a = rt.run_epoch([1.0], peer=0)
    b = rt.run_epoch([1.0], peer=1)  # different VM -> its own boot
    a2 = rt.run_epoch([1.0], peer=0)
    assert a.boot_s == 10.0 and b.boot_s == 10.0 and a2.boot_s == 0.0


# ---------------------------------------------------------------------------
# Memory-constrained mini-batch splitting
# ---------------------------------------------------------------------------

def test_instance_splits_unconstrained_and_constrained():
    # 50 MB model + 4 MB batch in 8 GB: comfortable
    assert instance_splits(int(50e6), int(4e6), "t2.large") == 1
    # VGG11-scale + large image batch in 2 GB: resource-constrained
    k = instance_splits(int(531e6), int(160e6), "t2.small")
    assert k > 1
    # the chosen k actually fits: 2*model + 3*batch/k + overhead <= tier
    need_mb = 2 * 531e6 / 1e6 + 3 * 160e6 / 1e6 / k + 700
    assert need_mb <= EC2_MEMORY_MB["t2.small"]
    # one fewer split would not fit
    if k > 1:
        too_big = 2 * 531e6 / 1e6 + 3 * 160e6 / 1e6 / (k - 1) + 700
        assert too_big > EC2_MEMORY_MB["t2.small"]


def test_instance_splits_model_overflow_raises():
    with pytest.raises(ValueError, match="larger tier"):
        instance_splits(int(2e9), int(1e6), "t2.small")
    # model EXACTLY fills the tier with a batch still to place: ValueError
    # (never ZeroDivisionError — the fallback paths only catch ValueError)
    exact = int((EC2_MEMORY_MB["t2.small"] - 700) / 2 * 1e6)
    with pytest.raises(ValueError, match="larger tier"):
        instance_splits(exact, int(1e6), "t2.small")
    assert instance_splits(exact, 0, "t2.small") == 1  # no batch: exact fit ok


def test_simulate_instance_strict_fit_toggle():
    ex = ServerlessExecutor(backend="instance", instance="t2.small")
    kw = dict(model_bytes=int(4e9), batch_bytes=int(1e6))
    with pytest.raises(ValueError, match="larger tier"):
        ex.simulate_instance([1.0], **kw)  # strict by default
    # legacy path (executor.run): fall back to no-memory-model accounting
    rep = ex.simulate_instance([1.0], strict_fit=False, **kw)
    assert rep.num_splits == 1 and rep.wall_time_s == pytest.approx(1.0)


def test_splitting_slows_the_constrained_epoch():
    cfg = InstanceConfig()
    free = ServerlessExecutor(
        backend="instance", instance="t2.large", instance_config=cfg,
    ).simulate_instance(
        [1.0] * 4, model_bytes=int(531e6), batch_bytes=int(160e6),
    )
    tight = ServerlessExecutor(
        backend="instance", instance="t2.small", instance_config=cfg,
    ).simulate_instance(
        [1.0] * 4, model_bytes=int(531e6), batch_bytes=int(160e6),
    )
    assert free.num_splits == 1 and tight.num_splits > 1
    # same measured compute, but the constrained tier pays per-split
    # gradient-accumulation overhead on every batch
    assert tight.wall_time_s > free.wall_time_s
    assert tight.wall_time_s == pytest.approx(
        4.0 * (1.0 + (tight.num_splits - 1) * 0.05)
    )


def test_instance_speedup_scales_with_vcpus():
    assert instance_speedup("t2.small", None) == 1.0  # legacy: no scaling
    assert instance_speedup("t2.medium", 1.0) == EC2_VCPUS["t2.medium"]
    assert instance_speedup("t2.nano", 4.0) == pytest.approx(0.25)  # floor


# ---------------------------------------------------------------------------
# Churn: seeded, survivable, downtime unbilled
# ---------------------------------------------------------------------------

def test_churn_is_seeded_deterministic_and_redos_complete():
    cfg = InstanceConfig(boot_s=5.0, churn_prob=0.4, churn_downtime_s=2.0, seed=3)
    a = InstanceRuntime(cfg, instance="t2.small")
    b = InstanceRuntime(cfg, instance="t2.small")
    ra = [a.run_epoch([1.0] * 6) for _ in range(3)]
    rb = [b.run_epoch([1.0] * 6) for _ in range(3)]
    assert sum(r.churn_drops for r in ra) > 0  # churn actually fired
    assert [r.makespan_s for r in ra] == [r.makespan_s for r in rb]
    assert [r.churn_drops for r in ra] == [r.churn_drops for r in rb]
    for r in ra:
        # every batch completed despite drops
        assert r.compute_s == pytest.approx(6.0)
        # each drop pays detection downtime + a fresh (billed) boot
        assert r.downtime_s == pytest.approx(r.churn_drops * 2.0)


def test_churn_downtime_extends_wall_but_not_the_bill():
    cfg = InstanceConfig(boot_s=0.0, churn_prob=0.5, churn_downtime_s=7.0, seed=1)
    rt = InstanceRuntime(cfg, instance="t2.small")
    res = rt.run_epoch([1.0] * 8)
    assert res.churn_drops > 0
    assert res.makespan_s == pytest.approx(res.billed_s + res.downtime_s)
    cost = rt.price(res)
    assert cost.unbilled_downtime_s == pytest.approx(res.downtime_s)
    assert cost.wall_time_s == pytest.approx(res.makespan_s)
    # the bill covers busy + boot + idle only
    assert cost.cost_per_peer == pytest.approx(
        ec2_cost_per_second("t2.small") * res.billed_s
    )


def test_zero_churn_config_never_drops():
    rt = InstanceRuntime(InstanceConfig(seed=5), instance="t2.small")
    res = rt.run_epoch([0.5] * 10)
    assert res.churn_drops == 0 and res.downtime_s == 0.0


# ---------------------------------------------------------------------------
# Degree-aware wire charging
# ---------------------------------------------------------------------------

def test_wire_charging_is_degree_aware_through_linkmodel():
    link = LinkModel(bandwidth_bps=1e9)
    payload = int(1e9)  # 8 s per transfer at 1 Gb/s
    rt = InstanceRuntime(instance="t2.small")
    res = rt.run_epoch(
        [1.0], upload_bytes=payload, download_bytes=[payload] * 3, link=link,
    )
    assert res.wire_s == pytest.approx(4 * 8.0)  # 1 upload + degree 3 downloads
    assert res.makespan_s == pytest.approx(1.0 + 32.0)
    # wire time is billed (the VM is up, moving bytes)
    assert rt.price(res).cost_per_peer == pytest.approx(
        ec2_cost_per_second("t2.small") * 33.0
    )


def test_wire_bytes_without_link_rejected():
    """Forgetting link= must not silently under-report the instance wall."""
    rt = InstanceRuntime(instance="t2.small")
    with pytest.raises(ValueError, match="LinkModel"):
        rt.run_epoch([1.0], upload_bytes=int(1e6))
    with pytest.raises(ValueError, match="LinkModel"):
        rt.run_epoch([1.0], download_bytes=[int(1e6)])


def test_barrier_wait_is_billed_idle():
    rt = InstanceRuntime(instance="t2.small")
    res = rt.run_epoch([1.0], barrier_wait_s=9.0)
    assert res.idle_s == pytest.approx(9.0)
    assert res.makespan_s == pytest.approx(10.0)
    assert rt.price(res).billed_s == pytest.approx(10.0)


# ---------------------------------------------------------------------------
# CostReport frontier API
# ---------------------------------------------------------------------------

def test_cost_report_speedup_and_multiple_reproduce_paper_headline():
    # the paper's batch-1024 row: 41.2 s serverless vs 258 s instance,
    # $0.0357 vs $0.0067 -> 84% faster at ~5.4x the cost
    s = CostReport("serverless", 41.2, 0.03567)
    i = CostReport("instance", 258.0, 0.00665)
    assert s.speedup_pct_vs(i) == pytest.approx(84.03, abs=0.01)
    assert s.cost_multiple_vs(i) == pytest.approx(5.36, abs=0.01)
    cmp = compare_backends(s, i)
    assert cmp["speedup_pct"] == pytest.approx(s.speedup_pct_vs(i))
    assert cmp["cost_multiple"] == pytest.approx(s.cost_multiple_vs(i))
    assert s.total_usd == pytest.approx(0.03567)  # num_peers defaults to 1
    assert CostReport("s", 1.0, 0.1, num_peers=4).total_usd == pytest.approx(0.4)


def test_pareto_frontier_keeps_only_nondominated_points():
    fast_expensive = CostReport("serverless", 1.0, 10.0)
    slow_cheap = CostReport("instance", 10.0, 1.0)
    dominated = CostReport("instance", 12.0, 2.0)  # slower AND dearer
    middle = CostReport("instance", 5.0, 5.0)
    front = pareto_frontier([dominated, slow_cheap, fast_expensive, middle])
    assert front == [fast_expensive, middle, slow_cheap]
    # a point dominated on one axis with a tie on the other is dropped
    tie = CostReport("instance", 10.0, 5.0)
    assert tie not in pareto_frontier([slow_cheap, tie, fast_expensive])


def test_execution_report_cost_report_roundtrip():
    ex = ServerlessExecutor(backend="instance", instance="t2.medium")
    rep = ex.simulate_instance([1.0, 2.0])
    cr = rep.cost_report(num_peers=3, label="baseline")
    assert cr.backend == "instance" and cr.instance == "t2.medium"
    assert cr.wall_time_s == rep.wall_time_s
    assert cr.cost_usd == rep.cost_usd and cr.num_peers == 3
    assert "t2.medium" in cr.summary()


# ---------------------------------------------------------------------------
# Serverless-vs-instance: the trade-off shape, engine-priced on both sides
# ---------------------------------------------------------------------------

def test_resource_constrained_comparison_has_the_paper_shape():
    """Many batches on a weak tier: serverless >= 90% faster, instance
    cheaper — the 97.34% / 5.4x trade-off, both sides on the engine."""
    per_batch = [3.0] * 32  # 1-vCPU reference seconds
    model_bytes, batch_bytes = int(531e6), int(160e6)
    sex = ServerlessExecutor(instance="t2.small", instance_vcpus=1.0)
    srep = sex.simulate(per_batch, model_bytes=model_bytes, batch_bytes=batch_bytes)
    iex = ServerlessExecutor(
        backend="instance", instance="t2.small",
        instance_config=InstanceConfig(boot_s=40.0),
    )
    irep = iex.simulate_instance(
        per_batch, model_bytes=model_bytes, batch_bytes=batch_bytes,
        reference_vcpus=1.0,
    )
    assert irep.num_splits > 1  # genuinely resource-constrained
    cmp = compare_backends(srep.cost_report(), irep.cost_report())
    assert cmp["speedup_pct"] >= 90.0
    assert cmp["cost_multiple"] > 1.0  # and the instance is cheaper


def test_unknown_tier_rejected():
    with pytest.raises(ValueError, match="known tiers"):
        InstanceRuntime(instance="p5.48xlarge")


# ---------------------------------------------------------------------------
# GPU instance tiers: same runtime machinery, GPU prices/memory/speedups
# ---------------------------------------------------------------------------

def test_gpu_tier_tables_are_consistent():
    assert set(GPU_USD_PER_HOUR) == set(GPU_MEMORY_MB)
    assert set(GPU_USD_PER_HOUR) == set(GPU_SPEEDUP) == set(GPU_BOOT_S)
    for tier in GPU_USD_PER_HOUR:
        assert is_gpu_instance(tier)
        assert tier in INSTANCE_MEMORY_MB  # merged view sees GPU tiers
        assert ec2_cost_per_second(tier) == pytest.approx(
            GPU_USD_PER_HOUR[tier] / 3600.0
        )
        # a GPU runs the reference workload faster than any t2 CPU tier
        assert instance_equivalent_vcpus(tier) > max(EC2_VCPUS.values())
    assert not is_gpu_instance("t2.large")
    assert instance_equivalent_vcpus("t2.large") == EC2_VCPUS["t2.large"]


def test_gpu_tier_splits_against_device_memory():
    # VGG11-scale + large batch fit a 16 GB device comfortably...
    assert instance_splits(int(531e6), int(160e6), "g4dn.xlarge") == 1
    # ...but a model bigger than HBM is refused like any CPU tier
    with pytest.raises(ValueError, match="larger tier"):
        instance_splits(int(9e9), int(1e6), "g4dn.xlarge")


def test_gpu_speedup_scales_reference_times():
    # times measured on the 1-vCPU reference run GPU_SPEEDUP x faster
    assert instance_speedup("p3.2xlarge", 1.0) == GPU_SPEEDUP["p3.2xlarge"]
    assert instance_speedup("p3.2xlarge", None) == 1.0  # legacy convention


def test_gpu_peer_priced_with_boot_and_idle():
    """InstanceRuntime prices a GPU peer end-to-end: boot billed at the
    GPU rate, compute scaled by the GPU speedup, barrier idle billed."""
    boot = GPU_BOOT_S["p3.2xlarge"]
    ex = ServerlessExecutor(
        backend="instance", instance="p3.2xlarge",
        instance_config=InstanceConfig.gpu_default(boot),
    )
    rep = ex.simulate_instance(
        [24.0, 24.0], model_bytes=int(531e6), batch_bytes=int(8e6),
        reference_vcpus=1.0, barrier_wait_s=3.0,
    )
    gpu_s = 48.0 / GPU_SPEEDUP["p3.2xlarge"]  # 2 s of device compute
    assert rep.boot_s == pytest.approx(boot)
    assert rep.wall_time_s == pytest.approx(boot + gpu_s + 3.0)
    assert rep.instance_billed_s == pytest.approx(boot + gpu_s + 3.0)
    assert rep.cost_usd == pytest.approx(
        ec2_cost_per_second("p3.2xlarge") * (boot + gpu_s + 3.0)
    )
    # warm epoch: no boot, pure device compute
    warm = ex.simulate_instance([24.0, 24.0], reference_vcpus=1.0)
    assert warm.boot_s == 0.0
    assert warm.wall_time_s == pytest.approx(gpu_s)


def test_gpu_default_preset_shape():
    cfg = InstanceConfig.gpu_default(90.0)
    assert cfg.boot_s == 90.0
    assert cfg.churn_prob > 0.0  # same interruption shape as aws_default
    assert InstanceConfig.gpu_default().boot_s == 90.0


# ---------------------------------------------------------------------------
# pareto_frontier tie handling (regression): equal-coordinate reports are
# mutually non-dominated — both must survive, under any input order
# ---------------------------------------------------------------------------

def test_pareto_frontier_keeps_equal_coordinate_ties():
    a = CostReport("serverless", 5.0, 2.0, label="lambda-4400")
    b = CostReport("instance", 5.0, 2.0, label="t2.large")
    assert not dominates(a, b) and not dominates(b, a)
    front = pareto_frontier([a, b])
    assert a in front and b in front  # previously one was silently evicted


def test_pareto_frontier_is_permutation_and_duplication_invariant():
    import itertools

    a = CostReport("serverless", 5.0, 2.0, label="x")
    b = CostReport("instance", 5.0, 2.0, label="y")
    fast = CostReport("serverless", 1.0, 9.0, label="fast")
    dom = CostReport("instance", 6.0, 3.0, label="dominated")
    pts = [a, b, fast, dom]
    base = pareto_frontier(pts)
    assert dom not in base and len(base) == 3
    for perm in itertools.permutations(pts):
        assert pareto_frontier(list(perm)) == base  # total-order sort key
    # duplication keeps membership (each copy survives, none evicts another)
    dup = pareto_frontier(pts + pts)
    assert dup == [p for p in base for _ in (0, 1)]


def test_trainer_cost_frontier_is_fresh_and_deterministic():
    """The frontier is a pure function of the measured times: earlier
    account_* calls (warm pools, VM boots, allocation history) must not
    change it, and the instance side prices its configured boot."""
    from repro.configs import get_config, reduced
    from repro.core.p2p import Topology
    from repro.launch.mesh import make_host_mesh
    from repro.optim import sgd
    from repro.optim.schedules import warmup_cosine
    from repro.train import P2PTrainer

    tr = P2PTrainer(
        reduced(get_config("qwen2.5-3b"), vocab_size=64),
        sgd(), Topology(peer_axes=()), make_host_mesh(1, 1),
        warmup_cosine(1e-3, 1, 10),
        backend="instance", instance_config=InstanceConfig(boot_s=40.0),
    )
    per = [0.5] * 4
    a = tr.cost_frontier(per)
    tr.account_instance(per)  # boots the trainer's persistent VM...
    tr.account_serverless(per)  # ...and warms the Lambda pools
    b = tr.cost_frontier(per)  # the frontier must not notice
    assert a["speedup_pct"] == b["speedup_pct"]
    assert a["instance_usd"] == b["instance_usd"]
    assert a["serverless_usd"] == b["serverless_usd"]
    assert a["instance_wall_s"] >= 40.0  # frontier includes the boot
    assert tr.account(per).backend == "instance"  # backend-aware dispatch
