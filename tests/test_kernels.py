"""Per-kernel validation: shape/dtype sweeps, assert_allclose vs ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.flash_attention import flash_attention
from repro.kernels.qsgd import qsgd_dequantize, qsgd_quantize
from repro.kernels.ref import (
    attention_ref,
    qsgd_dequantize_ref,
    qsgd_quantize_ref,
    ssd_scan_ref,
)
from repro.kernels.ssd_scan import ssd_scan_pallas


# ---------------------------------------------------------------------------
# QSGD
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nb", [1, 7, 8, 33])
@pytest.mark.parametrize("bucket", [128, 256, 2048])
@pytest.mark.parametrize("s", [1, 15, 127])
def test_qsgd_quantize_matches_ref(nb, bucket, s):
    key = jax.random.PRNGKey(nb * 1000 + bucket + s)
    x = jax.random.normal(key, (nb, bucket)) * 3.0
    u = jax.random.uniform(jax.random.fold_in(key, 1), (nb, bucket))
    lev_k, nrm_k = qsgd_quantize(x, u, s)
    lev_r, nrm_r = qsgd_quantize_ref(x, u, s)
    np.testing.assert_array_equal(np.asarray(lev_k), np.asarray(lev_r))
    np.testing.assert_allclose(np.asarray(nrm_k), np.asarray(nrm_r), rtol=1e-6)
    dq_k = qsgd_dequantize(lev_k, nrm_k, s)
    dq_r = qsgd_dequantize_ref(lev_r, nrm_r, s)
    np.testing.assert_allclose(np.asarray(dq_k), np.asarray(dq_r), rtol=1e-6)


def test_qsgd_zero_bucket():
    x = jnp.zeros((4, 128))
    u = jnp.full((4, 128), 0.5)
    lev, nrm = qsgd_quantize(x, u, 15)
    assert np.all(np.asarray(lev) == 0)
    dq = qsgd_dequantize(lev, nrm, 15)
    assert np.all(np.asarray(dq) == 0)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize(
    "B,S,H,P,G,N,chunk",
    [
        (1, 32, 2, 16, 1, 8, 16),
        (2, 96, 4, 32, 2, 16, 32),
        (2, 64, 4, 64, 1, 32, 64),  # single chunk
        (1, 80, 8, 32, 4, 16, 32),  # padded last chunk
    ],
)
def test_ssd_kernel_matches_ref(B, S, H, P, G, N, chunk):
    key = jax.random.PRNGKey(B * S + H)
    x = jax.random.normal(key, (B, S, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1), (B, S, H))) * 0.2
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (H,)) * 0.3)
    Bm = jax.random.normal(jax.random.fold_in(key, 3), (B, S, G, N)) * 0.3
    Cm = jax.random.normal(jax.random.fold_in(key, 4), (B, S, G, N)) * 0.3
    y_ref, _ = ssd_scan_ref(x, dt, A, Bm, Cm)
    y_k = ssd_scan_pallas(x, dt, A, Bm, Cm, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref), atol=2e-5, rtol=2e-4)


def test_ssd_kernel_bf16_inputs():
    B, S, H, P, G, N = 1, 64, 2, 32, 1, 16
    key = jax.random.PRNGKey(0)
    x = (jax.random.normal(key, (B, S, H, P)) * 0.5).astype(jnp.bfloat16)
    dt = jax.nn.softplus(jax.random.normal(key, (B, S, H))) * 0.2
    A = -jnp.exp(jnp.zeros((H,)))
    Bm = (jax.random.normal(key, (B, S, G, N)) * 0.3).astype(jnp.bfloat16)
    Cm = (jax.random.normal(key, (B, S, G, N)) * 0.3).astype(jnp.bfloat16)
    y_ref, _ = ssd_scan_ref(x, dt, A, Bm, Cm)
    y_k = ssd_scan_pallas(x, dt, A, Bm, Cm, chunk=32)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref), atol=3e-2, rtol=3e-2)


# ---------------------------------------------------------------------------
# Flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize(
    "B,S,H,K,D,softcap,window,bq,bkv",
    [
        (2, 64, 4, 2, 32, 0.0, 0, 32, 32),
        (1, 128, 4, 4, 64, 50.0, 0, 64, 32),
        (2, 96, 8, 2, 32, 0.0, 32, 32, 32),   # sliding window
        (1, 100, 4, 1, 32, 0.0, 0, 32, 32),   # padded seq (100 % 32 != 0)
        (1, 64, 8, 8, 128, 0.0, 0, 64, 64),   # MHA, lane-sized head_dim
    ],
)
def test_flash_attention_matches_ref(B, S, H, K, D, softcap, window, bq, bkv):
    key = jax.random.PRNGKey(S + H + D)
    q = jax.random.normal(key, (B, S, H, D)) * 0.5
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, K, D)) * 0.5
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, K, D)) * 0.5
    o_k = flash_attention(
        q, k, v, causal=True, softcap=softcap, window=window, block_q=bq, block_kv=bkv
    )
    o_r = attention_ref(q, k, v, causal=True, softcap=softcap, window=window)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r), atol=2e-5, rtol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    B, S, H, K, D = 1, 64, 4, 2, 32
    key = jax.random.PRNGKey(3)
    q = (jax.random.normal(key, (B, S, H, D)) * 0.5).astype(dtype)
    k = (jax.random.normal(jax.random.fold_in(key, 1), (B, S, K, D)) * 0.5).astype(dtype)
    v = (jax.random.normal(jax.random.fold_in(key, 2), (B, S, K, D)) * 0.5).astype(dtype)
    o_k = flash_attention(q, k, v, block_q=32, block_kv=32)
    assert o_k.dtype == dtype
    o_r = attention_ref(q, k, v)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(
        np.asarray(o_k, np.float32), np.asarray(o_r), atol=tol, rtol=tol
    )


# ---------------------------------------------------------------------------
# ops wrappers
# ---------------------------------------------------------------------------

def test_ops_default_interpret_on_cpu():
    assert ops.default_interpret() is True
