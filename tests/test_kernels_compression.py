"""Interpret-mode parity suite for the fused compressed-exchange kernels.

Covers the PR's kernel surface against the ``kernels/ref.py`` oracles:
fused decode-dequantize-reduce (qsgd), topk select+pack and the fused
scatter-accumulate decoder — plus the impl-routing regression (the device
``combine`` must actually take the kernel path when ``impl="kernel"``),
packed-wire-format accounting asserts, and the EF-SGD convergence /
equivalence rails on the host cluster and the 4-device mesh.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compression as C
from repro.core.compression import QSGDConfig
from repro.core.exchange import ExchangeContext, get_exchange
from repro.kernels import ops as kops
from repro.kernels import ref as kref

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


# ---------------------------------------------------------------------------
# fused decode-dequantize-reduce vs the unfused oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("P", [1, 2, 4])
@pytest.mark.parametrize("nb,bucket", [(1, 128), (5, 256), (8, 128), (13, 512)])
@pytest.mark.parametrize("s", [3, 127])
def test_dequant_reduce_matches_unfused_ref(P, nb, bucket, s):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(nb * 1000 + bucket + s), 3)
    lev = jax.random.randint(k1, (P, nb, bucket), -s, s + 1, jnp.int8)
    nrm = jax.random.uniform(k2, (P, nb), jnp.float32, 0.1, 2.0)
    w = jax.random.uniform(k3, (P,), jnp.float32)
    got = kops.qsgd_dequant_reduce(lev, nrm, w, s)
    want = kref.qsgd_dequant_reduce_ref(lev, nrm, w, s)
    assert got.shape == (nb, bucket)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6
    )


def test_dequant_reduce_uniform_weights_is_mean_of_dequant():
    P, nb, bucket, s = 4, 6, 128, 7
    lev = jax.random.randint(jax.random.PRNGKey(0), (P, nb, bucket), -s, s + 1, jnp.int8)
    nrm = jax.random.uniform(jax.random.PRNGKey(1), (P, nb), jnp.float32, 0.1, 1.0)
    w = jnp.full((P,), 1.0 / P, jnp.float32)
    fused = kops.qsgd_dequant_reduce(lev, nrm, w, s)
    unfused = jnp.stack(
        [C.qsgd_dequantize_ref(lev[p], nrm[p], s) for p in range(P)]
    ).mean(axis=0)
    np.testing.assert_allclose(
        np.asarray(fused), np.asarray(unfused), rtol=1e-6, atol=1e-6
    )


def test_compression_dequant_reduce_routes_impl():
    """C.dequant_reduce(impl="kernel") must call the Pallas wrapper."""
    P, nb, bucket, s = 2, 4, 128, 15
    lev = jax.random.randint(jax.random.PRNGKey(2), (P, nb, bucket), -s, s + 1, jnp.int8)
    nrm = jnp.ones((P, nb), jnp.float32)
    w = jnp.full((P,), 0.5, jnp.float32)
    calls = []
    orig = kops.qsgd_dequant_reduce
    kops.qsgd_dequant_reduce = lambda *a, **k: (calls.append(1), orig(*a, **k))[1]
    try:
        out_k = C.dequant_reduce(lev, nrm, w, QSGDConfig(levels=s, impl="kernel"))
        assert calls, "impl='kernel' did not reach the Pallas wrapper"
        out_j = C.dequant_reduce(lev, nrm, w, QSGDConfig(levels=s, impl="jnp"))
        assert len(calls) == 1, "impl='jnp' must NOT take the kernel path"
    finally:
        kops.qsgd_dequant_reduce = orig
    np.testing.assert_allclose(
        np.asarray(out_k), np.asarray(out_j), rtol=1e-6, atol=1e-6
    )


# ---------------------------------------------------------------------------
# topk select+pack / scatter-accumulate vs the oracles
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "n,k",
    [(7, 1), (128, 128), (129, 4), (513, 5), (1000, 10), (4096, 1), (300, 300)],
)
def test_topk_select_pack_matches_lax_top_k(n, k):
    x = jax.random.normal(jax.random.PRNGKey(n * 7 + k), (n,), jnp.float32)
    v, i = kops.topk_select_pack(x, k)
    rv, ri = kref.topk_select_ref(x, k)
    # Same selected index SET (order may differ) and values = x at indices.
    assert set(np.asarray(i).tolist()) == set(np.asarray(ri).tolist())
    np.testing.assert_array_equal(np.asarray(v), np.asarray(x)[np.asarray(i)])
    assert i.dtype == jnp.int32 and v.dtype == jnp.float32


def test_topk_select_pack_exact_k_under_ties():
    # all-equal magnitudes: the two-tier threshold must still emit exactly
    # k unique indices with the tied value
    for x, k in [(jnp.ones((300,)), 7), (jnp.zeros((64,)), 5),
                 (-jnp.ones((200,)) * 2.5, 3)]:
        v, i = kops.topk_select_pack(x, k)
        idx = np.asarray(i).tolist()
        assert len(set(idx)) == k
        np.testing.assert_array_equal(np.asarray(v), np.asarray(x)[idx])


@pytest.mark.parametrize("P,k,n", [(1, 1, 1), (2, 9, 200), (4, 33, 1000)])
def test_topk_scatter_accum_matches_ref(P, k, n):
    vals = jax.random.normal(jax.random.PRNGKey(P), (P, k), jnp.float32)
    idx = jax.random.randint(jax.random.PRNGKey(k), (P, k), 0, n, jnp.int32)
    w = jax.random.uniform(jax.random.PRNGKey(n), (P,), jnp.float32)
    got = kops.topk_scatter_accum(vals, idx, w, n)
    want = kref.topk_scatter_ref(vals, idx, w, n)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)


def test_topk_select_scatter_roundtrip_is_projection():
    """scatter(select(x)) == x masked to its top-k coordinates."""
    n, k = 777, 31
    x = jax.random.normal(jax.random.PRNGKey(5), (n,), jnp.float32)
    v, i = kops.topk_select_pack(x, k)
    dense = kops.topk_scatter_accum(v[None], i[None], jnp.ones((1,)), n)
    rv, ri = kref.topk_select_ref(x, k)
    ref_dense = np.zeros((n,), np.float32)
    ref_dense[np.asarray(ri)] = np.asarray(rv)
    np.testing.assert_allclose(np.asarray(dense), ref_dense, rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# seeded shape sweeps: arbitrary lengths incl. non-multiple-of-bucket sizes
# (deterministic stand-in for the hypothesis property tests — hypothesis is
# an optional dependency here, same as tests/test_compression.py)
# ---------------------------------------------------------------------------

_SWEEP = [
    # (n, bucket, s, P) — n deliberately NOT a multiple of bucket except one
    (1, 128, 3, 1),
    (97, 128, 15, 2),
    (128, 128, 127, 4),
    (200, 256, 3, 3),
    (511, 256, 127, 2),
    (513, 512, 15, 4),
    (700, 512, 3, 1),
]


@pytest.mark.parametrize("n,bucket,s,P", _SWEEP)
def test_fused_decode_matches_host_codec_sweep(n, bucket, s, P):
    """Quantize an arbitrary-length (non-multiple-of-bucket) vector per
    peer, then: fused kernel reduce == mean of per-peer host dequantize."""
    cfg = QSGDConfig(levels=s, bucket=bucket, impl="jnp")
    x = jax.random.normal(jax.random.PRNGKey(n * 31 + bucket + P), (P, n))
    payloads = [
        C.quantize(x[p], jax.random.PRNGKey(p), cfg) for p in range(P)
    ]
    lev = jnp.stack([p["levels"] for p in payloads])  # (P, nb, bucket)
    nrm = jnp.stack([p["norms"] for p in payloads])
    w = jnp.full((P,), 1.0 / P, jnp.float32)
    fused = kops.qsgd_dequant_reduce(lev, nrm, w, s).reshape(-1)[:n]
    unfused = jnp.stack(
        [C.dequantize(p, cfg) for p in payloads]
    ).mean(axis=0)
    np.testing.assert_allclose(
        np.asarray(fused), np.asarray(unfused), rtol=1e-5, atol=1e-5
    )


@pytest.mark.parametrize("n", [2, 13, 128, 129, 500, 900])
@pytest.mark.parametrize("frac", [1e-3, 0.01, 0.1, 1.0])
def test_topk_kernel_selects_same_set_sweep(n, frac):
    k = max(1, min(n, int(round(n * frac))))
    x = jax.random.normal(jax.random.PRNGKey(n), (n,), jnp.float32)
    v, i = kops.topk_select_pack(x, k)
    rv, ri = kref.topk_select_ref(x, k)
    assert set(np.asarray(i).tolist()) == set(np.asarray(ri).tolist())
    assert float(jnp.abs(v).min()) >= float(jnp.abs(rv).min()) - 1e-6


# ---------------------------------------------------------------------------
# satellite 1: device combine must route QSGDConfig.impl / ctx.topk_impl
# ---------------------------------------------------------------------------


def _vmap_combine(proto, ctx, grads, key=None):
    """Run a device combine under vmap-with-axis-name (a cheap stand-in
    for the shard_map manual region: all_gather/axis_index resolve)."""

    def body(g):
        avg, _ = proto.combine(g, ctx, key=key)
        return avg

    return jax.vmap(body, axis_name="data")(grads)


def test_qsgd_device_combine_takes_kernel_path():
    """Regression (PR-7 satellite): combine() ignored QSGDConfig.impl and
    always dequantized through the jnp ref. Assert the Pallas wrappers are
    reached when impl='kernel' — for encode AND the fused decode-reduce."""
    P = 4
    grads = {"w": jax.random.normal(jax.random.PRNGKey(0), (P, 2, 200))}
    proto = get_exchange("qsgd")
    calls = {"quant": 0, "reduce": 0}
    oq, orr = kops.qsgd_quantize, kops.qsgd_dequant_reduce

    def cq(*a, **k):
        calls["quant"] += 1
        return oq(*a, **k)

    def cr(*a, **k):
        calls["reduce"] += 1
        return orr(*a, **k)

    kops.qsgd_quantize, kops.qsgd_dequant_reduce = cq, cr
    try:
        ctx = ExchangeContext(
            axis="data", num_peers=P,
            qsgd=QSGDConfig(levels=7, bucket=128, impl="kernel"),
        )
        out_k = _vmap_combine(proto, ctx, grads, key=jax.random.PRNGKey(3))
        assert calls["quant"] >= 1, "impl='kernel' quantize not routed"
        assert calls["reduce"] >= 1, "impl='kernel' fused decode not routed"
        calls["quant"] = calls["reduce"] = 0
        ctx_j = ExchangeContext(
            axis="data", num_peers=P,
            qsgd=QSGDConfig(levels=7, bucket=128, impl="jnp"),
        )
        out_j = _vmap_combine(proto, ctx_j, grads, key=jax.random.PRNGKey(3))
        assert calls["quant"] == 0 and calls["reduce"] == 0
    finally:
        kops.qsgd_quantize, kops.qsgd_dequant_reduce = oq, orr
    # same key -> identical stochastic rounding -> paths agree to float eps
    np.testing.assert_allclose(
        np.asarray(out_k["w"]), np.asarray(out_j["w"]), rtol=1e-6, atol=1e-6
    )


def test_topk_device_combine_takes_kernel_path():
    P = 2
    grads = {"w": jax.random.normal(jax.random.PRNGKey(1), (P, 300))}
    proto = get_exchange("topk")
    calls = {"sel": 0, "scat": 0}
    osel, oscat = kops.topk_select_pack, kops.topk_scatter_accum

    def cs(*a, **k):
        calls["sel"] += 1
        return osel(*a, **k)

    def cc(*a, **k):
        calls["scat"] += 1
        return oscat(*a, **k)

    kops.topk_select_pack, kops.topk_scatter_accum = cs, cc
    try:
        ctx = ExchangeContext(
            axis="data", num_peers=P, topk_frac=0.05, topk_impl="kernel"
        )
        out_k = _vmap_combine(proto, ctx, grads)
        assert calls["sel"] >= 1 and calls["scat"] >= 1
        calls["sel"] = calls["scat"] = 0
        ctx_j = ExchangeContext(
            axis="data", num_peers=P, topk_frac=0.05, topk_impl="jnp"
        )
        out_j = _vmap_combine(proto, ctx_j, grads)
        assert calls["sel"] == 0 and calls["scat"] == 0
    finally:
        kops.topk_select_pack, kops.topk_scatter_accum = osel, oscat
    np.testing.assert_allclose(
        np.asarray(out_k["w"]), np.asarray(out_j["w"]), rtol=1e-6, atol=1e-6
    )


# ---------------------------------------------------------------------------
# satellite 2: wire accounting == the encoded payload's actual nbytes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [(3, 33), (1000,), (7, 11, 13)])
@pytest.mark.parametrize("impl", ["jnp", "kernel"])
def test_qsgd_wire_bytes_match_encoded_payload(shape, impl):
    grads = {"w": jax.random.normal(jax.random.PRNGKey(0), shape)}
    cfg = QSGDConfig(levels=7, bucket=128, impl=impl)
    ctx = ExchangeContext(num_peers=4, qsgd=cfg)
    proto = get_exchange("qsgd")
    payload, nbytes = proto.host_encode(grads, ctx, key=jax.random.PRNGKey(1))
    # actual packed wire format: int8 level banks + fp32 bucket norms
    actual = int(payload["w"]["levels"].nbytes + payload["w"]["norms"].nbytes)
    assert payload["w"]["levels"].dtype == jnp.int8
    assert payload["w"]["norms"].dtype == jnp.float32
    assert nbytes == actual
    assert proto.wire_bytes_per_edge(grads, ctx) == actual
    # roundtrip: decode reproduces the leaf shape
    dec = proto.host_decode(payload, grads, ctx)
    assert dec["w"].shape == shape


@pytest.mark.parametrize("wire_dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("impl", ["jnp", "kernel"])
def test_topk_wire_bytes_match_encoded_payload(wire_dtype, impl):
    grads = {"w": jax.random.normal(jax.random.PRNGKey(0), (3, 77)),
             "b": jax.random.normal(jax.random.PRNGKey(1), (13,))}
    ctx = ExchangeContext(
        num_peers=4, topk_frac=0.1, topk_impl=impl, wire_dtype=wire_dtype
    )
    proto = get_exchange("topk")
    payload, nbytes = proto.host_encode(grads, ctx)
    # actual packed wire format: wire-dtype values + int32 index pairs
    actual = sum(
        int(p["values"].nbytes + p["idx"].nbytes)
        for p in jax.tree.leaves(
            payload, is_leaf=lambda x: isinstance(x, dict) and "values" in x
        )
    )
    for p in jax.tree.leaves(
        payload, is_leaf=lambda x: isinstance(x, dict) and "values" in x
    ):
        assert p["idx"].dtype == jnp.int32
        assert p["values"].dtype == wire_dtype
    assert nbytes == actual
    assert proto.wire_bytes_per_edge(grads, ctx) == actual
    dec = proto.host_decode(payload, grads, ctx)
    assert dec["w"].shape == (3, 77) and dec["b"].shape == (13,)


def test_qsgd_wire_bytes_le_30pct_of_raw():
    grads = {"w": jnp.zeros((64, 64)), "b": jnp.zeros((100,))}
    raw = sum(x.size * 4 for x in jax.tree.leaves(grads))
    q = get_exchange("qsgd").wire_bytes_per_edge(
        grads, ExchangeContext(num_peers=4, qsgd=QSGDConfig(levels=3, bucket=512))
    )
    t = get_exchange("topk").wire_bytes_per_edge(
        grads, ExchangeContext(num_peers=4, topk_frac=1e-3)
    )
    assert q <= 0.30 * raw
    assert t <= 0.30 * raw


# ---------------------------------------------------------------------------
# EF-SGD: equivalence + convergence rails
# ---------------------------------------------------------------------------


def test_combine_ef_lossless_residual_is_zero():
    """For a lossless protocol the local image IS the gradient, so the
    EF residual stays identically zero (the no-regression rail)."""
    P = 2
    grads = {"w": jax.random.normal(jax.random.PRNGKey(0), (P, 64))}
    proto = get_exchange("allgather_mean")
    ctx = ExchangeContext(axis="data", num_peers=P)

    def body(g):
        avg, local, _ = proto.combine_ef(g, ctx)
        res = jax.tree.map(lambda a, b: a - b, g, local)
        return avg, res

    avg, res = jax.vmap(body, axis_name="data")(grads)
    np.testing.assert_array_equal(np.asarray(res["w"]), 0.0)
    np.testing.assert_allclose(
        np.asarray(avg["w"][0]), np.asarray(grads["w"]).mean(0), rtol=1e-6
    )


def test_combine_ef_qsgd_local_image_is_own_decode():
    P = 2
    s, bucket = 7, 128
    grads = {"w": jax.random.normal(jax.random.PRNGKey(0), (P, 200))}
    cfg = QSGDConfig(levels=s, bucket=bucket)
    proto = get_exchange("qsgd")
    ctx = ExchangeContext(axis="data", num_peers=P, qsgd=cfg)
    key = jax.random.PRNGKey(9)

    def body(g):
        _, local, _ = proto.combine_ef(g, ctx, key=key)
        return local

    local = jax.vmap(body, axis_name="data")(grads)
    # re-derive each peer's decode with the same per-peer folded key
    for r in range(P):
        kr = jax.random.fold_in(key, r)
        (leafkey,) = jax.random.split(kr, 1)
        payload = C.quantize(grads["w"][r], leafkey, cfg)
        np.testing.assert_allclose(
            np.asarray(local["w"][r]),
            np.asarray(C.dequantize(payload, cfg)),
            rtol=1e-6, atol=1e-6,
        )


@pytest.mark.slow
def test_ef_convergence_device_path():
    """EF-SGD retains convergence at the aggressive settings on the
    device exchange path (every contribution compressed — the semantics
    ``build_p2p_train_step`` runs on the mesh), on a seeded least-squares
    problem:

      * top-k frac=1e-3 (k=1 of 512, a contractive but biased
        sparsifier) STALLS without EF and converges >= 10x lower with it;
      * qsgd levels=3 is UNBIASED and converges without EF — which is
        why no EF-beats-no-EF claim exists for qsgd: aggressive qsgd is
        also non-contractive (noise ~ sqrt(bucket)/levels of the input),
        outside EF theory, and EF-qsgd finiteness is covered by the
        multidevice test above.
    """
    P, B, D = 4, 64, 512
    key = jax.random.PRNGKey(0)
    w_true = jax.random.normal(key, (D,)) / jnp.sqrt(D)
    X = jax.random.normal(jax.random.fold_in(key, 1), (P, B, D))
    y = jnp.einsum("pbd,d->pb", X, w_true) + 0.01 * jax.random.normal(
        jax.random.fold_in(key, 2), (P, B)
    )

    def lossf(w):
        return float(jnp.mean((jnp.einsum("pbd,d->pb", X, w) - y) ** 2))

    def train(name, ef, lr, n, **ctx_kw):
        proto = get_exchange(name) if name else None
        ctx = ExchangeContext(axis="data", num_peers=P, **ctx_kw)

        def step(w, e, Xr, yr, k):
            g = Xr.T @ (Xr @ w - yr) / B
            if proto is None:
                return w - lr * jax.lax.pmean(g, "data"), e
            if ef:
                c = g + e
                avg, local, _ = proto.combine_ef(c, ctx, key=k)
                return w - lr * avg, c - local
            avg, _ = proto.combine(g, ctx, key=k)
            return w - lr * avg, e

        vstep = jax.jit(
            jax.vmap(step, in_axes=(0, 0, 0, 0, None), axis_name="data")
        )
        w = jnp.zeros((P, D))
        e = jnp.zeros((P, D))
        for t in range(n):
            w, e = vstep(w, e, X, y, jax.random.fold_in(key, 100 + t))
        return lossf(w[0])

    no_ef = train("topk", False, 0.02, 1500, topk_frac=1e-3)
    with_ef = train("topk", True, 0.02, 1500, topk_frac=1e-3)
    assert no_ef >= 0.1, f"top-k frac=1e-3 should stall without EF: {no_ef}"
    assert with_ef <= no_ef / 10.0, (with_ef, no_ef)

    qsgd_no_ef = train(
        "qsgd", False, 0.1, 300, qsgd=QSGDConfig(levels=3, bucket=512)
    )
    assert qsgd_no_ef <= 1e-3, f"unbiased qsgd should converge: {qsgd_no_ef}"


@pytest.mark.slow
def test_fused_kernel_paths_equivalence_multidevice():
    """Acceptance rail: kernel == jnp combine paths <= 1e-6 on the 4-device
    mesh (interpret mode), and EF threading through build_p2p_train_step
    is a no-op for a lossless protocol."""
    script = textwrap.dedent(
        """
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro import compat
        from repro.core.compression import QSGDConfig
        from repro.core.exchange import ExchangeContext, get_exchange

        mesh = compat.make_mesh((4,), ("data",),
                                axis_types=(compat.AxisType.Auto,))
        g_global = {
            "w": jax.random.normal(jax.random.PRNGKey(0), (4, 6, 33)),
            "b": jax.random.normal(jax.random.PRNGKey(1), (4, 170)),
        }

        def run(name, **ctx_kw):
            proto = get_exchange(name)
            ctx = ExchangeContext(axis="data", num_peers=4, **ctx_kw)

            def body(g):
                per_peer = jax.tree.map(lambda x: x[0], g)
                key = jax.random.PRNGKey(7) if proto.requires_key else None
                avg, _ = proto.combine(per_peer, ctx, key=key)
                return avg

            fn = compat.shard_map(
                body, mesh=mesh,
                in_specs=(jax.tree.map(lambda _: P("data"), g_global),),
                out_specs=jax.tree.map(lambda _: P(), g_global),
                axis_names={"data"}, check_vma=False,
            )
            with compat.set_mesh(mesh):
                return jax.jit(fn)(g_global)

        def maxerr(a, b):
            return max(
                float(jnp.abs(x - y).max())
                for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b))
            )

        # fused Pallas decode path == unfused jnp reference (same rng key
        # -> identical stochastic rounding, so only decode order differs)
        for kw_k, kw_j in [
            (
                {"qsgd": QSGDConfig(levels=3, bucket=128, impl="kernel")},
                {"qsgd": QSGDConfig(levels=3, bucket=128, impl="jnp")},
            ),
            (
                {"qsgd": QSGDConfig(levels=127, bucket=256, impl="kernel")},
                {"qsgd": QSGDConfig(levels=127, bucket=256, impl="jnp")},
            ),
        ]:
            err = maxerr(run("qsgd", **kw_k), run("qsgd", **kw_j))
            assert err <= 1e-6, ("qsgd", err)
            print("qsgd kernel==jnp err", err)

        for frac in (0.05, 1.0):
            err = maxerr(
                run("topk", topk_frac=frac, topk_impl="kernel"),
                run("topk", topk_frac=frac, topk_impl="jnp"),
            )
            assert err <= 1e-6, ("topk", frac, err)
            print("topk kernel==jnp err", frac, err)

        # EF threading through the step builder: lossless protocol ->
        # bit-equal params and an all-zero residual bank
        from repro.core.p2p import Topology, build_p2p_train_step, init_ef
        from repro.core.p2p import TrainState
        from repro.optim import sgd

        opt = sgd(momentum=0.9)
        params = {"w": jax.random.normal(jax.random.PRNGKey(2), (8, 16))}
        batch = {"x": jax.random.normal(jax.random.PRNGKey(3), (8, 16))}

        def loss_fn(p, b):
            l = jnp.mean((b["x"] @ p["w"].T) ** 2)
            return l, l

        def make_state(ef):
            s = TrainState(
                params=params, opt_state=opt.init(params),
                step=jnp.zeros((), jnp.int32), key=jax.random.PRNGKey(0),
            )
            return s.replace(ef=init_ef(params, 4)) if ef else s

        def run_steps(topo, ef):
            step = build_p2p_train_step(
                loss_fn, opt, topo, mesh, lambda s: 0.05
            )
            st = make_state(ef)
            with compat.set_mesh(mesh):
                for _ in range(3):
                    st, _m = jax.jit(step)(st, batch)
            return st

        topo = Topology(peer_axes=("data",), lambda_axis=None,
                        exchange="allgather_mean")
        a = run_steps(topo, ef=False)
        b = run_steps(Topology(peer_axes=("data",), lambda_axis=None,
                               exchange="allgather_mean", ef=True), ef=True)
        assert maxerr(a.params, b.params) == 0.0, "EF must be a lossless no-op"
        assert all(
            float(jnp.abs(x).max()) == 0.0 for x in jax.tree.leaves(b.ef)
        ), "lossless residual must stay zero"

        # EF + qsgd(levels=3, kernel impl) runs end-to-end and stays finite
        topo_q = Topology(
            peer_axes=("data",), lambda_axis=None, exchange="qsgd",
            qsgd=QSGDConfig(levels=3, bucket=128, impl="kernel"), ef=True,
        )
        c = run_steps(topo_q, ef=True)
        assert all(
            bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(c.params)
        )
        assert any(
            float(jnp.abs(x).max()) > 0.0 for x in jax.tree.leaves(c.ef)
        ), "lossy codec must accumulate a residual"
        print("OK")
        """
    )
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = SRC
    r = subprocess.run(
        [sys.executable, "-c", script], env=env, capture_output=True, text=True,
        timeout=600,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout


@pytest.mark.slow
def test_host_cluster_kernel_impl_equivalence():
    """Acceptance rail: host cluster final params, kernel vs jnp impl,
    <= 1e-6 for both codecs."""
    from repro.configs import get_config
    from repro.core import LocalP2PCluster
    from repro.optim import sgd

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "benchmarks"))
    from common import small_mnist

    cfg = get_config("squeezenet1.1")

    def run(**kw):
        cl = LocalP2PCluster(
            cfg, small_mnist(size=128, hw=8), num_peers=4, batch_size=8,
            batches_per_epoch=1, optimizer=sgd(momentum=0.9), lr=0.05,
            sync=True, seed=0, **kw,
        )
        cl.run_epoch_sync(0)
        return cl.peers[0].params

    def maxerr(a, b):
        return max(
            float(jnp.abs(x - y).max())
            for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b))
        )

    q = maxerr(
        run(exchange="qsgd", qsgd=QSGDConfig(levels=7, bucket=256, impl="jnp")),
        run(exchange="qsgd", qsgd=QSGDConfig(levels=7, bucket=256, impl="kernel")),
    )
    assert q <= 1e-6, q
    t = maxerr(
        run(exchange="topk", topk_frac=0.01, topk_impl="jnp"),
        run(exchange="topk", topk_frac=0.01, topk_impl="kernel"),
    )
    assert t <= 1e-6, t
