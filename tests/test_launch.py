"""Launch-layer unit tests that need no devices: sharding policy,
activation rules, shape handling, skip logic."""
import dataclasses

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.configs.base import ShapeConfig
from repro.launch import sharding as SH


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


SINGLE = FakeMesh({"data": 16, "model": 16})
MULTI = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_cfg_for_shape_window_only_for_long():
    from repro.launch.dryrun import cfg_for_shape

    qwen = get_config("qwen2.5-3b")
    assert cfg_for_shape(qwen, SHAPES["decode_32k"]).serve_window == 0
    assert cfg_for_shape(qwen, SHAPES["long_500k"]).serve_window == 4096
    gem = get_config("gemma2-2b")
    assert cfg_for_shape(gem, SHAPES["decode_32k"]).sliding_window == 4096


def test_regime_a_train_rules_pin_batch_over_model():
    cfg = get_config("qwen2.5-3b")
    rules = SH.activation_rules(cfg, SHAPES["train_4k"], SINGLE)
    assert rules["batch"][-1] == "model"
    assert rules["heads"] is None and rules["ff"] is None


def test_regime_b_train_rules_are_tp():
    cfg = get_config("dbrx-132b")
    rules = SH.activation_rules(cfg, SHAPES["train_4k"], SINGLE)
    assert "model" not in (rules["batch"] or ())
    assert rules["heads"] == "model" and rules["ff"] == "model"
    assert rules["experts"] == "model"


def test_decode_rules_shard_cache():
    cfg = get_config("qwen2.5-3b")  # kv=2: heads can't shard 16 ways
    rules = SH.activation_rules(cfg, SHAPES["decode_32k"], SINGLE)
    assert rules["kv_heads"] is None
    assert rules["kv_seq"] == ("model",)
    # long-context single request: spare batch axes join the seq shard
    rules = SH.activation_rules(cfg, SHAPES["long_500k"], SINGLE)
    assert set(rules["kv_seq"]) == {"model", "data"}


def test_expert_fallback_megatron_split():
    granite = get_config("granite-moe-3b-a800m")  # 40 experts % 16 != 0
    s = SH.param_spec(("stack", "0", "ffn", "w_gate"), (32, 40, 1536, 512), granite, SINGLE)
    assert s == P(None, None, None, "model")  # column-parallel on f
    s = SH.param_spec(("stack", "0", "ffn", "w_down"), (32, 40, 512, 1536), granite, SINGLE)
    assert s == P(None, None, "model", None)  # row-parallel on f


def test_embed_single_axis_workaround():
    cfg = get_config("dbrx-132b")  # fsdp arch
    s = SH.param_spec(("embed",), (100352, 6144), cfg, SINGLE)
    assert sum(e is not None for e in s) <= 1  # never 2D-sharded


def test_topology_regimes():
    from repro.launch.dryrun import topology_for

    t = topology_for(get_config("qwen2.5-3b"), SINGLE)
    assert t.peer_axes == ("data",) and t.serverless
    t = topology_for(get_config("qwen2.5-3b"), MULTI)
    assert t.peer_axes == ("pod", "data")
    t = topology_for(get_config("dbrx-132b"), MULTI)
    assert t.peer_axes == ("pod",) and not t.serverless
    t = topology_for(get_config("dbrx-132b"), SINGLE)
    assert t.peer_axes == ()


def test_skip_registry():
    from repro.launch.dryrun import SKIPS

    assert ("whisper-base", "long_500k") in SKIPS


def test_batch_specs_sanitized_for_odd_batches():
    import jax.numpy as jnp
    from jax.sharding import NamedSharding

    # B=1 can't shard over anything; spec must collapse to replicated
    cfg = get_config("qwen2.5-3b")
    shape = ShapeConfig("x", 128, 1, "prefill")

    class M(FakeMesh):
        def __init__(self):
            super().__init__({"data": 16, "model": 16})

    rules = SH.activation_rules(cfg, shape, M())
    assert rules["batch"] is None
