"""HostMailbox coverage — latest-wins register semantics, time-based
visibility, the synchronization barrier across interleaved epochs, and the
>100 MB S3-indirection path (paper §III-B.3)."""
import pytest

from repro.core.mailbox import MESSAGE_CAP_BYTES, HostMailbox


# ---------------------------------------------------------------------------
# Latest-wins register semantics
# ---------------------------------------------------------------------------

def test_latest_wins_replacement_keeps_only_newest():
    mb = HostMailbox(2)
    for i in range(5):
        mb.publish(0, f"g{i}", nbytes=10 + i, time=float(i), epoch=0)
    msg = mb.consume(0)
    assert msg.payload == "g4" and msg.nbytes == 14
    assert mb.stats["publishes"] == 5
    # register, not queue: repeated reads see the same message
    assert mb.consume(0).payload == "g4"
    assert mb.stats["consumes"] == 2


def test_replacement_crosses_epochs():
    mb = HostMailbox(2)
    mb.publish(1, "old", nbytes=8, time=1.0, epoch=0)
    mb.publish(1, "new", nbytes=8, time=9.0, epoch=3)
    msg = mb.consume(1)
    assert msg.payload == "new" and msg.epoch == 3


def test_empty_queue_and_unpublished_peer():
    mb = HostMailbox(3)
    assert mb.consume(2) is None
    assert mb.consume(2, at_time=100.0) is None


# ---------------------------------------------------------------------------
# consume(at_time=...) visibility ordering
# ---------------------------------------------------------------------------

def test_visibility_ordering_follows_publish_time():
    mb = HostMailbox(2)
    mb.publish(0, "early", nbytes=4, time=2.0, epoch=0)
    assert mb.consume(0, at_time=1.0) is None  # not yet on the wire
    assert mb.consume(0, at_time=2.0).payload == "early"  # boundary: visible
    mb.publish(0, "late", nbytes=4, time=7.0, epoch=1)
    # latest-wins replaced the register: a reader at t=3 sees NOTHING, not
    # the old message — exactly the stale-read hazard of async consumption
    assert mb.consume(0, at_time=3.0) is None
    assert mb.consume(0, at_time=7.5).payload == "late"
    assert mb.consume(0, at_time=None).payload == "late"  # sync read: no clock


# ---------------------------------------------------------------------------
# Barrier across interleaved epochs
# ---------------------------------------------------------------------------

def test_barrier_epochs_are_independent_and_interleave():
    mb = HostMailbox(2)
    mb.barrier_signal(0, epoch=0)
    mb.barrier_signal(0, epoch=1)  # peer 0 raced ahead into epoch 1
    assert not mb.barrier_complete(0)
    assert not mb.barrier_complete(1)
    mb.barrier_signal(1, epoch=0)
    assert mb.barrier_complete(0)
    assert not mb.barrier_complete(1)
    mb.barrier_reset(0)  # resetting epoch 0 must not eat epoch-1 signals
    assert not mb.barrier_complete(0)
    mb.barrier_signal(1, epoch=1)
    assert mb.barrier_complete(1)
    mb.barrier_reset(1)
    assert not mb.barrier_complete(1)


def test_barrier_duplicate_signals_do_not_overcount():
    mb = HostMailbox(3)
    mb.barrier_signal(0, epoch=0)
    mb.barrier_signal(0, epoch=0)
    mb.barrier_signal(1, epoch=0)
    assert not mb.barrier_complete(0)  # distinct peers, not raw signal count
    mb.barrier_signal(2, epoch=0)
    assert mb.barrier_complete(0)


# ---------------------------------------------------------------------------
# >100 MB S3-indirection path
# ---------------------------------------------------------------------------

def test_s3_indirection_threshold_and_stats():
    mb = HostMailbox(1)
    mb.publish(0, "fits", nbytes=MESSAGE_CAP_BYTES, time=0.0, epoch=0)
    assert not mb.consume(0).via_s3
    assert mb.stats["s3_indirections"] == 0
    mb.publish(0, "big", nbytes=MESSAGE_CAP_BYTES + 1, time=1.0, epoch=0)
    msg = mb.consume(0)
    assert msg.via_s3 and msg.s3_uuid is not None
    assert mb.stats["s3_indirections"] == 1
    mb.publish(0, "bigger", nbytes=2 * MESSAGE_CAP_BYTES, time=2.0, epoch=1)
    assert mb.stats["s3_indirections"] == 2


def test_download_time_charges_payload_and_s3_round_trip():
    mb = HostMailbox(1, s3_rtt_s=0.05)
    bw = 1e9
    mb.publish(0, "small", nbytes=10_000_000, time=0.0, epoch=0)
    small = mb.consume(0)
    assert mb.download_time_s(small, bw) == pytest.approx(10_000_000 * 8 / bw)
    mb.publish(0, "big", nbytes=MESSAGE_CAP_BYTES + 1, time=1.0, epoch=0)
    big = mb.consume(0)
    expected = (MESSAGE_CAP_BYTES + 1) * 8 / bw + 0.05
    assert mb.download_time_s(big, bw) == pytest.approx(expected)
