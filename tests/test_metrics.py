"""StageMetrics / StageProbe (metrics/resources.py): probe measurement,
nesting, simulated stages, mean/table invariants."""
import time

import pytest

from repro.metrics.resources import StageMetrics, StageProbe, StageRecord


def test_probe_records_elapsed_time():
    m = StageMetrics()
    with m.stage("compute_gradients"):
        time.sleep(0.01)
    recs = m.records["compute_gradients"]
    assert len(recs) == 1
    assert recs[0].seconds >= 0.01
    assert recs[0].mem_mb >= 0.0


def test_probe_nesting_attributes_both_stages():
    m = StageMetrics()
    with m.stage("send_gradients"):
        time.sleep(0.005)
        with m.stage("receive_gradients"):
            time.sleep(0.005)
    outer = m.records["send_gradients"][0]
    inner = m.records["receive_gradients"][0]
    # the inner probe's wall time is contained in the outer's
    assert outer.seconds >= inner.seconds
    assert inner.seconds >= 0.005


def test_probe_swallows_nothing_on_exception():
    m = StageMetrics()
    with pytest.raises(RuntimeError):
        with m.stage("model_update"):
            raise RuntimeError("boom")
    # the record is still written (context manager returns False)
    assert len(m.records["model_update"]) == 1


def test_add_simulated_zero_cpu_memory():
    m = StageMetrics()
    m.add_simulated("cold_start", 2.5)
    m.add_simulated("cold_start", 1.5)
    mean = m.mean("cold_start")
    assert mean.seconds == pytest.approx(2.0)
    assert mean.cpu_percent == 0.0 and mean.mem_mb == 0.0


def test_mean_of_empty_stage_is_zero_record():
    m = StageMetrics()
    mean = m.mean("receive_gradients")
    assert (mean.seconds, mean.cpu_percent, mean.mem_mb, mean.rss_mb) == (
        0.0, 0.0, 0.0, 0.0,
    )


def test_mean_averages_all_fields():
    m = StageMetrics()
    m.add("model_update", StageRecord(1.0, 10.0, 100.0, 200.0))
    m.add("model_update", StageRecord(3.0, 30.0, 300.0, 400.0))
    mean = m.mean("model_update")
    assert mean.seconds == 2.0
    assert mean.cpu_percent == 20.0
    assert mean.mem_mb == 200.0
    assert mean.rss_mb == 300.0


def test_table_covers_all_stages_and_memory_is_max():
    m = StageMetrics()
    m.add("compute_gradients", StageRecord(0.5, 50.0, 10.0, 99.0))
    m.add_simulated("queue_wait", 0.25)
    t = m.table()
    # every Table-I stage plus the engine-simulated ones, measured or not
    assert set(t) == set(StageMetrics.STAGES + StageMetrics.SIM_STAGES)
    row = t["compute_gradients"]
    assert row["time_s"] == 0.5
    assert row["memory_mb"] == 99.0  # max(tracemalloc peak, RSS)
    assert t["queue_wait"]["time_s"] == 0.25
    assert t["model_update"]["time_s"] == 0.0  # unmeasured -> zeros


def test_stage_returns_probe_for_this_metrics():
    m = StageMetrics()
    probe = m.stage("convergence_detection")
    assert isinstance(probe, StageProbe)
    assert probe.metrics is m and probe.stage == "convergence_detection"
