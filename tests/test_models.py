"""Model-semantics tests: decode==forward consistency, MoE dispatch
agreement, layer grouping, SSD chunked==naive, sliding windows."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models
from repro.configs import get_config, reduced
from repro.configs.base import BlockSpec
from repro.models.ssm import ssd_chunked
from repro.models.transformer import layer_grouping
from repro.kernels.ref import ssd_scan_ref


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "gemma2-2b", "mamba2-370m", "zamba2-1.2b"])
def test_decode_matches_forward(arch):
    """Feeding tokens one-by-one through the decode path must reproduce the
    full-sequence forward logits (KV caches / SSM states are correct)."""
    cfg = reduced(get_config(arch))
    params = models.init_model(jax.random.PRNGKey(0), cfg)
    B, S = 2, 12
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    full_logits, _ = models.forward(params, {"tokens": tokens}, cfg)

    state = models.init_decode_state(cfg, B, S + 1)
    dec = []
    for t in range(S):
        logits, state = models.decode_step(params, state, tokens[:, t : t + 1], cfg)
        dec.append(logits)
    dec = jnp.stack(dec, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec, np.float32),
        np.asarray(full_logits, np.float32),
        atol=0.08, rtol=0.08,  # bf16 accumulation differences
    )


def test_decode_matches_forward_rolling_window():
    """Sliding-window rolling cache must agree with windowed full attention."""
    cfg = reduced(get_config("gemma2-2b"))
    import dataclasses

    cfg = dataclasses.replace(cfg, sliding_window=8)  # force rolling (S > window)
    params = models.init_model(jax.random.PRNGKey(0), cfg)
    B, S = 1, 20
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    full_logits, _ = models.forward(params, {"tokens": tokens}, cfg)
    state = models.init_decode_state(cfg, B, S)
    dec = []
    for t in range(S):
        logits, state = models.decode_step(params, state, tokens[:, t : t + 1], cfg)
        dec.append(logits)
    dec = jnp.stack(dec, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec, np.float32), np.asarray(full_logits, np.float32),
        atol=0.08, rtol=0.08,
    )


def test_moe_capacity_matches_dense_at_high_capacity():
    cfg = reduced(get_config("granite-moe-3b-a800m"))
    params = models.init_model(jax.random.PRNGKey(0), cfg)
    B, S = 2, 16
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)}
    from repro.models.layers import moe_apply
    import functools

    l_dense, _ = models.forward(params, batch, cfg, moe_dispatch="dense")
    # capacity path with generous capacity keeps (almost) all tokens
    from repro.models import transformer as T

    x = jax.random.normal(jax.random.PRNGKey(2), (B, S, cfg.d_model), jnp.float32) * 0.3
    moe_params = params["stack"][0]["ffn"]
    one = jax.tree.map(lambda p: p[0], moe_params)
    yd, auxd = moe_apply(one, x, cfg, dispatch="dense")
    yc, auxc = moe_apply(one, x, cfg, dispatch="capacity", capacity_factor=8.0)
    np.testing.assert_allclose(np.asarray(yd), np.asarray(yc), atol=2e-2, rtol=2e-2)
    np.testing.assert_allclose(float(auxd), float(auxc), rtol=1e-5)


def test_layer_grouping_periods():
    assert layer_grouping(get_config("qwen2.5-3b"))[:3][1:] == (36, 0)
    p, n, r = layer_grouping(get_config("gemma2-2b"))
    assert len(p) == 2 and n == 13 and r == 0
    assert p[0].mixer == "attn_local" and p[1].mixer == "attn"
    p, n, r = layer_grouping(get_config("zamba2-1.2b"))
    assert len(p) == 6 and n == 6 and r == 2
    assert p[5].mixer == "shared_attn"


def test_block_specs_families():
    assert all(s.mixer == "mamba" for s in get_config("mamba2-370m").block_specs())
    moe = get_config("dbrx-132b").block_specs()
    assert all(s.ffn == "moe" for s in moe)
    z = get_config("zamba2-1.2b").block_specs()
    assert sum(s.mixer == "shared_attn" for s in z) == 6


def test_ssd_chunked_matches_naive_long():
    B, S, H, P, G, N = 1, 200, 4, 32, 1, 16
    key = jax.random.PRNGKey(5)
    x = jax.random.normal(key, (B, S, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1), (B, S, H))) * 0.2
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (H,)) * 0.3)
    Bm = jax.random.normal(jax.random.fold_in(key, 3), (B, S, G, N)) * 0.3
    Cm = jax.random.normal(jax.random.fold_in(key, 4), (B, S, G, N)) * 0.3
    y_ref, st_ref = ssd_scan_ref(x, dt, A, Bm, Cm)
    y, st = ssd_chunked(x, dt, A, Bm, Cm, chunk=64)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=2e-5, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref), atol=2e-5, rtol=2e-4)


def test_vlm_prefix_changes_text_logits():
    cfg = reduced(get_config("internvl2-26b"))
    params = models.init_model(jax.random.PRNGKey(0), cfg)
    B, S = 1, 8
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    p1 = jax.random.normal(jax.random.PRNGKey(2), (B, cfg.vision_tokens, cfg.d_model))
    p2 = jax.random.normal(jax.random.PRNGKey(3), (B, cfg.vision_tokens, cfg.d_model))
    l1, _ = models.forward(params, {"tokens": tokens, "patches": p1}, cfg)
    l2, _ = models.forward(params, {"tokens": tokens, "patches": p2}, cfg)
    assert l1.shape == (B, S, cfg.vocab_size)
    assert float(jnp.abs(l1 - l2).max()) > 1e-3  # vision prefix attended to


def test_encdec_cross_attention_matters():
    cfg = reduced(get_config("whisper-base"))
    params = models.init_model(jax.random.PRNGKey(0), cfg)
    B, S = 1, 8
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    f1 = jax.random.normal(jax.random.PRNGKey(2), (B, cfg.encoder_seq, cfg.d_model))
    f2 = jax.random.normal(jax.random.PRNGKey(3), (B, cfg.encoder_seq, cfg.d_model))
    l1, _ = models.forward(params, {"tokens": tokens, "frames": f1}, cfg)
    l2, _ = models.forward(params, {"tokens": tokens, "frames": f2}, cfg)
    assert float(jnp.abs(l1 - l2).max()) > 1e-3


def test_causality():
    """Future tokens must not influence past logits."""
    cfg = reduced(get_config("qwen2.5-3b"))
    params = models.init_model(jax.random.PRNGKey(0), cfg)
    B, S = 1, 10
    t1 = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    t2 = t1.at[:, -1].set((t1[:, -1] + 7) % cfg.vocab_size)
    l1, _ = models.forward(params, {"tokens": t1}, cfg)
    l2, _ = models.forward(params, {"tokens": t2}, cfg)
    np.testing.assert_allclose(
        np.asarray(l1[:, :-1]), np.asarray(l2[:, :-1]), atol=1e-5
    )


def test_logit_softcap_bounds():
    cfg = reduced(get_config("gemma2-2b"))
    assert cfg.final_logit_softcap == 30.0
    params = models.init_model(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab_size)
    logits, _ = models.forward(params, {"tokens": tokens}, cfg)
    assert float(jnp.abs(logits).max()) <= 30.0 + 1e-3
