"""P2P exchange semantics. Multi-device collective behaviour runs in a
subprocess (so the 8-device XLA flag never leaks into this process);
host-level Algorithm-1 semantics run in-process via LocalP2PCluster."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import LocalP2PCluster, QSGDConfig
from repro.data import make_dataset
from repro.optim import sgd

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.mark.slow
def test_exchange_modes_equivalent_multidevice():
    """allgather_mean (paper) == psum_mean (optimized) bit-for-bit, and the
    qsgd + async exchanges lower and run — on an 8-device mesh."""
    script = textwrap.dedent(
        """
        import jax, jax.numpy as jnp
        from repro.compat import AxisType, make_mesh, set_mesh
        from repro.configs import get_config, reduced
        from repro.core.p2p import Topology
        from repro.core.compression import QSGDConfig
        from repro.train import build_train_step, init_train_state
        from repro.optim import sgd
        from repro.optim.schedules import constant
        from repro.models.layers import axis_rules

        mesh = make_mesh((4, 2), ("data", "model"), axis_types=(AxisType.Auto,)*2)
        cfg = reduced(get_config("qwen2.5-3b"))
        opt = sgd(momentum=0.9)
        state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
        batch = {"tokens": jnp.zeros((8, 32), jnp.int32),
                 "labels": jnp.ones((8, 32), jnp.int32)}
        rules = {"batch": ("data",), "embed": None, "ff": None, "heads": None,
                 "kv_heads": None, "experts": None, "vocab": None, "kv_seq": None}
        outs = {}
        for mode in ("allgather_mean", "psum_mean", "qsgd"):
            topo = Topology(peer_axes=("data",), lambda_axis="model", exchange=mode,
                            qsgd=QSGDConfig(levels=127, bucket=256))
            step = build_train_step(cfg, opt, topo, mesh, constant(1e-2))
            with set_mesh(mesh):
                with axis_rules(rules):
                    s2, m = jax.jit(step)(state, batch)
            outs[mode] = s2["params"]
            assert bool(jnp.isfinite(m["loss"])), mode
        d = max(float(jnp.abs(a - b).max()) for a, b in zip(
            jax.tree.leaves(outs["allgather_mean"]), jax.tree.leaves(outs["psum_mean"])))
        assert d == 0.0, f"allgather vs psum diff {d}"
        dq = max(float(jnp.abs(a - b).max()) for a, b in zip(
            jax.tree.leaves(outs["allgather_mean"]), jax.tree.leaves(outs["qsgd"])))
        assert 0 < dq < 0.1, f"qsgd should be close but not identical: {dq}"
        print("OK")
        """
    )
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    r = subprocess.run(
        [sys.executable, "-c", script], env=env, capture_output=True, text=True,
        timeout=600,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout


def test_sync_p2p_equals_pooled_sgd():
    """With equal partitions and a sync exchange, P peers stepping together
    must equal single-worker SGD on the pooled batch (Algorithm 1's goal)."""
    cfg = get_config("squeezenet1.1")
    ds = make_dataset("mnist", size=256, image_hw=8, channels=1)
    # 2 peers x 1 batch of 16
    cl2 = LocalP2PCluster(
        cfg, ds, num_peers=2, batch_size=16, batches_per_epoch=1,
        optimizer=sgd(momentum=0.0), lr=0.1, sync=True, seed=3,
    )
    cl2.run_epoch_sync(0)
    # Reference: single peer with both peers' batches
    import jax

    cl1 = LocalP2PCluster(
        cfg, ds, num_peers=2, batch_size=16, batches_per_epoch=1,
        optimizer=sgd(momentum=0.0), lr=0.1, sync=True, seed=3,
    )
    b0 = cl1.peers[0].loader.load(__import__("repro.data", fromlist=["BatchKey"]).BatchKey(0, 0, 0))
    b1 = cl1.peers[1].loader.load(__import__("repro.data", fromlist=["BatchKey"]).BatchKey(1, 0, 0))
    g0, _, _ = cl1._grad(cl1.peers[0].params, jax.tree.map(jnp.asarray, b0))
    g1, _, _ = cl1._grad(cl1.peers[1].params, jax.tree.map(jnp.asarray, b1))
    avg = jax.tree.map(lambda a, b: (a + b) / 2, g0, g1)
    ref_params, _ = cl1._apply(
        cl1.peers[0].params, cl1.peers[0].opt_state, avg, jnp.float32(0.1)
    )
    for a, b in zip(jax.tree.leaves(cl2.peers[0].params), jax.tree.leaves(ref_params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
    # and all peers hold identical models after a sync epoch
    for a, b in zip(
        jax.tree.leaves(cl2.peers[0].params), jax.tree.leaves(cl2.peers[1].params)
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_uses_stale_gradients():
    """Async peers consume what's visible at their clock — peers diverge."""
    cfg = get_config("squeezenet1.1")
    ds = make_dataset("mnist", size=256, image_hw=8, channels=1)
    cl = LocalP2PCluster(
        cfg, ds, num_peers=3, batch_size=8, batches_per_epoch=1,
        optimizer=sgd(momentum=0.0), lr=0.05, sync=False,
        peer_speeds=[1.0, 3.0, 9.0], seed=0,
    )
    cl.run_epoch_async(0)
    cl.run_epoch_async(1)
    p0 = jax.tree.leaves(cl.peers[0].params)
    p2 = jax.tree.leaves(cl.peers[2].params)
    diff = max(float(jnp.abs(a - b).max()) for a, b in zip(p0, p2))
    assert diff > 0  # stale consumption -> models diverge between peers


def test_qsgd_cluster_reduces_wire_bytes():
    cfg = get_config("squeezenet1.1")
    ds = make_dataset("mnist", size=128, image_hw=8, channels=1)
    cl = LocalP2PCluster(
        cfg, ds, num_peers=2, batch_size=8, batches_per_epoch=1,
        optimizer=sgd(momentum=0.9), lr=0.05,
        qsgd=QSGDConfig(levels=127, bucket=512), seed=0,
    )
    cl.run_epoch_sync(0)
    assert cl.peers[0].comm_bytes_sent < cl._model_bytes / 3
