"""Async (staleness-K) P2P exchange in the distributed JAX path —
multi-device semantics run in a subprocess (8 fake devices)."""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.mark.slow
def test_async_mailbox_exchange_multidevice():
    script = textwrap.dedent(
        """
        import jax, jax.numpy as jnp
        from repro.compat import AxisType, make_mesh, set_mesh
        from repro.configs import get_config, reduced
        from repro.core.p2p import Topology, init_mailbox
        from repro.train import build_train_step, init_train_state
        from repro.optim import sgd
        from repro.optim.schedules import constant
        from repro.models.layers import axis_rules

        mesh = make_mesh((4, 2), ("data", "model"), axis_types=(AxisType.Auto,)*2)
        cfg = reduced(get_config("qwen2.5-3b"), num_layers=1, d_model=64, vocab_size=64)
        opt = sgd(momentum=0.0)
        state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, 64),
                 "labels": jax.random.randint(jax.random.PRNGKey(2), (8, 16), 0, 64)}
        rules = {"batch": ("data",), "embed": None, "ff": None, "heads": None,
                 "kv_heads": None, "experts": None, "vocab": None, "kv_seq": None,
                 "seq": None}

        # async topology with a staleness-1 mailbox ring in the train state
        topo = Topology(peer_axes=("data",), lambda_axis="model", exchange="async")
        astate = state.replace(mailbox=init_mailbox(state.params, 4))
        step_a = build_train_step(cfg, opt, topo, mesh, constant(1e-2))

        # sync reference
        topo_s = Topology(peer_axes=("data",), lambda_axis="model", exchange="psum_mean")
        step_s = build_train_step(cfg, opt, topo_s, mesh, constant(1e-2))

        with set_mesh(mesh):
            with axis_rules(rules):
                s1, m1 = jax.jit(step_a)(astate, batch)
                s2, m2 = jax.jit(step_a)(s1, batch)
                ss, ms = jax.jit(step_s)(state, batch)

        # step 1: mailbox was zeros -> effective grad = own/P, so async
        # params differ from sync (which averages fresh gradients)
        d = max(float(jnp.abs(a - b).max()) for a, b in zip(
            jax.tree.leaves(s1["params"]), jax.tree.leaves(ss["params"])))
        assert d > 0, "async step should differ from sync on a cold mailbox"
        # mailbox ring was refreshed with the step's gradients: (K=1, P=4, ...)
        mb = jax.tree.leaves(s1["mailbox"])[0]
        assert mb.shape[:2] == (1, 4), mb.shape
        assert float(jnp.abs(mb).max()) > 0
        assert bool(jnp.isfinite(m2["loss"]))

        # staleness-2: the bank consumed at step t was published at t-2, so
        # after one step the ring's oldest slot is still the zero bank and
        # the fresh bank sits in slot 1
        topo2 = Topology(peer_axes=("data",), lambda_axis="model", exchange="async",
                         staleness=2)
        astate2 = state.replace(mailbox=init_mailbox(state.params, 4, staleness=2))
        step_2 = build_train_step(cfg, opt, topo2, mesh, constant(1e-2))
        with set_mesh(mesh):
            with axis_rules(rules):
                t1, _ = jax.jit(step_2)(astate2, batch)
        ring = jax.tree.leaves(t1["mailbox"])[0]
        assert ring.shape[:2] == (2, 4), ring.shape
        assert float(jnp.abs(ring[0]).max()) == 0.0  # still the cold bank
        assert float(jnp.abs(ring[1]).max()) > 0     # fresh publication
        # step-1 params agree with staleness-1 (both consumed a zero bank)
        dk = max(float(jnp.abs(a - b).max()) for a, b in zip(
            jax.tree.leaves(t1["params"]), jax.tree.leaves(s1["params"])))
        assert dk == 0.0, dk
        print("OK")
        """
    )
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    r = subprocess.run(
        [sys.executable, "-c", script], env=env, capture_output=True, text=True,
        timeout=600,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout
