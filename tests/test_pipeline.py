"""Edge-case + coverage suite for the data pipeline (``repro.data.pipeline``).

Complements test_data_optim.py with the boundary behaviors: epoch
reshuffling vs same-key determinism, drop-remainder arithmetic, short
datasets, preprocessing branches, and out-of-range partitions. The final
test is a coverage *rail*: it replays the whole surface under the stdlib
``trace`` module (no pytest-cov in the container) and fails if line
coverage of pipeline.py drops below 80%.
"""
import importlib
import sys
import trace as stdlib_trace

import numpy as np
import pytest

from repro.data.pipeline import (
    BatchKey,
    DataLoader,
    Dataset,
    Partitioner,
    generate_images,
    generate_tokens,
    make_dataset,
)


def _small(name="mnist", **kw):
    kw.setdefault("size", 64)
    return make_dataset(name, **kw)


def test_batchkey_s3_addressing():
    key = BatchKey(peer=3, epoch=1, index=42)
    assert key.s3_key("mnist") == "mnist/peer=3/epoch=1/batch=00042.npz"


def test_make_dataset_presets_and_overrides():
    ds = make_dataset("cifar", size=128, preprocessing="minmax")
    assert (ds.image_hw, ds.channels, ds.size) == (32, 3, 128)
    assert isinstance(ds, Dataset)
    with pytest.raises(KeyError, match="unknown dataset"):
        make_dataset("imagenet")


def test_same_key_yields_identical_batch_across_loaders():
    # the S3-addressing contract: a batch is a pure function of
    # (dataset seed, BatchKey) — independent loader instances agree
    for name in ("mnist", "lm"):
        ds = _small(name)
        a = DataLoader(Partitioner(ds, 2), 0, 8)
        b = DataLoader(Partitioner(ds, 2), 0, 8)
        key = BatchKey(0, 2, 1)
        ba, bb = a.load(key), b.load(key)
        assert sorted(ba) == sorted(bb)
        for k in ba:
            np.testing.assert_array_equal(ba[k], bb[k])


def test_epochs_reshuffle_but_replay_identically():
    dl = DataLoader(Partitioner(_small(), 2), 0, 8)
    e0 = dl.batch_indices(BatchKey(0, 0, 0))
    e1 = dl.batch_indices(BatchKey(0, 1, 0))
    assert not np.array_equal(e0, e1)  # different epoch => new permutation
    np.testing.assert_array_equal(e0, dl.batch_indices(BatchKey(0, 0, 0)))
    # an epoch is a permutation of the partition: disjoint, exhaustive
    all_idx = np.concatenate(
        [dl.batch_indices(BatchKey(0, 0, i)) for i in range(dl.num_batches)]
    )
    assert len(set(all_idx.tolist())) == len(all_idx) == len(dl.part)


def test_peers_see_disjoint_batches():
    part = Partitioner(_small(), 2)
    d0, d1 = DataLoader(part, 0, 8), DataLoader(part, 1, 8)
    i0 = d0.batch_indices(BatchKey(0, 0, 0))
    i1 = d1.batch_indices(BatchKey(1, 0, 0))
    assert not set(i0.tolist()) & set(i1.tolist())


def test_drop_remainder_batch_arithmetic():
    ds = _small(size=50)  # per-peer partition = 25, batch 8 -> 3 rem 1
    part = Partitioner(ds, 2)
    drop = DataLoader(part, 0, 8, drop_remainder=True)
    keep = DataLoader(part, 0, 8, drop_remainder=False)
    assert drop.num_batches == 3 and keep.num_batches == 4
    batches = list(keep.epoch(0))
    assert [len(b["labels"]) for b in batches] == [8, 8, 8, 1]
    assert all(len(b["labels"]) == 8 for b in drop.epoch(0))


def test_short_dataset_edges():
    ds = _small(size=10)
    part = Partitioner(ds, 3)  # 3 per peer, index 9 dropped by the split
    dl = DataLoader(part, 0, 4, drop_remainder=True)
    assert dl.num_batches == 0 and list(dl.epoch(0)) == []
    dl2 = DataLoader(part, 0, 4, drop_remainder=False)
    assert dl2.num_batches == 1
    (only,) = list(dl2.epoch(0))
    assert len(only["labels"]) == 3


def test_partitioner_out_of_range():
    part = Partitioner(_small(), 2)
    for bad in (-1, 2, 99):
        with pytest.raises(IndexError):
            part.partition(bad)


def test_preprocessing_branches():
    idx = np.arange(32)
    mm, _ = generate_images(_small(preprocessing="minmax"), idx)
    assert mm.min() == pytest.approx(0.0) and mm.max() == pytest.approx(1.0)
    st, _ = generate_images(_small(preprocessing="standardize"), idx)
    assert abs(st.mean()) < 1e-5 and st.std() == pytest.approx(1.0, abs=1e-4)
    raw, _ = generate_images(_small(preprocessing="none"), idx)
    assert raw.std() > 0 and not (0.999 < raw.std() < 1.001)


def test_token_streams_are_aligned_next_token_targets():
    ds = _small("lm", size=32, seq_len=16)
    x, y = generate_tokens(ds, np.arange(4))
    assert x.shape == y.shape == (4, 16)
    assert x.min() >= 0 and y.max() < ds.vocab_size
    np.testing.assert_array_equal(x[:, 1:], y[:, :-1])  # y is x shifted by 1


def test_pipeline_line_coverage_rail():
    """>= 80% line coverage of pipeline.py, measured with stdlib trace.

    Reloads the module under the tracer so module-level lines count too,
    then replays the public surface (both dataset kinds, all preprocessing
    branches, both drop-remainder modes, and the error paths).
    """
    import repro.data.pipeline as pl

    def exercise():
        mod = importlib.reload(pl)
        for name, pre in (("mnist", "minmax"), ("cifar", "standardize"),
                          ("lm", "none")):
            ds = mod.make_dataset(name, size=40, preprocessing=pre,
                                  **({"seq_len": 8} if name == "lm" else {}))
            part = mod.Partitioner(ds, 2, shuffle_seed=1)
            for drop in (True, False):
                dl = mod.DataLoader(part, 0, 7, drop_remainder=drop)
                for batch in dl.epoch(0):
                    assert batch
            dl.load(mod.BatchKey(0, 1, 0))
        mod.BatchKey(0, 0, 0).s3_key("mnist")
        try:
            mod.make_dataset("nope")
        except KeyError:
            pass
        try:
            part.partition(5)
        except IndexError:
            pass

    tracer = stdlib_trace.Trace(count=1, trace=0)
    tracer.runfunc(exercise)
    path = pl.__file__
    executable = set(stdlib_trace._find_executable_linenos(path))
    hit = {
        line
        for (fname, line) in tracer.results().counts
        if fname == path
    }
    cov = len(hit & executable) / len(executable)
    missed = sorted(executable - hit)
    assert cov >= 0.80, f"pipeline.py coverage {cov:.0%} < 80%; missed {missed}"
    # leave a clean module state for the rest of the session
    importlib.reload(sys.modules["repro.data.pipeline"])
