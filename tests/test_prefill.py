"""One-shot prefill-into-cache must agree with token-by-token decode
(attention: exact; SSM: chunked-vs-recurrent tolerance)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models
from repro.configs import get_config, reduced

B, S, G = 2, 12, 4


def _roundtrip(arch, tol, **tweak):
    cfg = reduced(get_config(arch))
    if tweak:
        cfg = dataclasses.replace(cfg, **tweak)
    params = models.init_model(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S + G), 0, cfg.vocab_size)
    total = S + G

    stateA = models.init_decode_state(cfg, B, total)
    for t in range(S):
        la, stateA = models.decode_step(params, stateA, tokens[:, t : t + 1], cfg)
    stateB = models.init_decode_state(cfg, B, total)
    lb, stateB = models.prefill(params, stateB, {"tokens": tokens[:, :S]}, cfg)

    diffs = [float(jnp.abs(la - lb).max())]
    for t in range(G):
        la, stateA = models.decode_step(params, stateA, tokens[:, S + t : S + t + 1], cfg)
        lb, stateB = models.decode_step(params, stateB, tokens[:, S + t : S + t + 1], cfg)
        diffs.append(float(jnp.abs(la - lb).max()))
    assert max(diffs) <= tol, diffs
    assert int(stateB["pos"]) == total


def test_prefill_dense():
    _roundtrip("qwen2.5-3b", 1e-4)


def test_prefill_rolling_window():
    # prompt longer than the window exercises the rolling rewrite
    _roundtrip("gemma2-2b", 1e-4, sliding_window=8)


def test_prefill_ssm():
    _roundtrip("mamba2-370m", 0.05)


def test_prefill_hybrid():
    _roundtrip("zamba2-1.2b", 0.05)


def test_prefill_vlm_matches_forward():
    cfg = reduced(get_config("internvl2-26b"))
    params = models.init_model(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    patches = jax.random.normal(jax.random.PRNGKey(2), (B, cfg.vision_tokens, cfg.d_model))
    batch = {"tokens": tokens, "patches": patches}
    full, _ = models.forward(params, batch, cfg)
    state = models.init_decode_state(cfg, B, cfg.vision_tokens + S + G)
    logits, state = models.prefill(params, state, batch, cfg)
    np.testing.assert_allclose(
        np.asarray(full[:, -1]), np.asarray(logits), atol=1e-3, rtol=1e-3
    )
    assert int(state["pos"]) == cfg.vision_tokens + S


def test_prefill_encdec_matches_forward():
    cfg = reduced(get_config("whisper-base"))
    params = models.init_model(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    frames = jax.random.normal(jax.random.PRNGKey(2), (B, cfg.encoder_seq, cfg.d_model))
    batch = {"tokens": tokens, "frames": frames}
    full, _ = models.forward(params, batch, cfg)
    state = models.init_decode_state(cfg, B, S + G)
    logits, state = models.prefill(params, state, batch, cfg)
    np.testing.assert_allclose(
        np.asarray(full[:, -1]), np.asarray(logits), atol=1e-3, rtol=1e-3
    )
    # cross K/V filled
    assert float(jnp.abs(state["cross"]["k"]).max()) > 0
