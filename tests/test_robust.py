"""Byzantine-robust aggregation: combinators vs numpy references, the
adversary model on the host cluster (poisoned publishes, stale replay,
nonfinite rejection), robust-protocol equivalence rails, and the
ConvergenceDetector NaN regression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import robust as R
from repro.core.convergence import (
    ConvergenceDetector,
    EarlyStopping,
    ReduceLROnPlateau,
)
from repro.core.exchange import ExchangeContext, get_exchange


# ---------------------------------------------------------------------------
# combinators vs numpy references
# ---------------------------------------------------------------------------


def test_masked_trimmed_mean_matches_numpy(rng):
    x = jnp.asarray(rng.normal(size=(7, 5, 3)), jnp.float32)
    full = jnp.ones((7,), bool)
    # f=0: plain mean
    np.testing.assert_allclose(
        np.asarray(R.masked_trimmed_mean(x, full, 0.0)),
        np.asarray(x).mean(0), rtol=1e-6,
    )
    # f=0.2: floor(0.2*7)=1 trimmed from each end, mean of middle 5
    got = np.asarray(R.masked_trimmed_mean(x, full, 0.2))
    ref = np.sort(np.asarray(x), axis=0)[1:-1].mean(0)
    np.testing.assert_allclose(got, ref, rtol=1e-5)


def test_masked_trimmed_mean_sparse_mask(rng):
    x = jnp.asarray(rng.normal(size=(6, 4)), jnp.float32)
    mask = jnp.asarray([True, False, True, True, False, True])
    sub = np.asarray(x)[np.asarray(mask)]
    # k=4 members, floor(0.25*4)=1 from each end
    ref = np.sort(sub, axis=0)[1:-1].mean(0)
    np.testing.assert_allclose(
        np.asarray(R.masked_trimmed_mean(x, mask, 0.25)), ref, rtol=1e-5
    )


def test_trim_clamped_below_half():
    x = jnp.asarray([[0.0], [1.0], [2.0]])
    m = jnp.ones((3,), bool)
    # f=0.45 of k=3 -> floor=1, clamped to (k-1)//2=1: median survives
    np.testing.assert_allclose(
        np.asarray(R.masked_trimmed_mean(x, m, 0.45)), [1.0]
    )
    with pytest.raises(ValueError):
        R.masked_trimmed_mean(x, m, 0.5)


def test_masked_median_matches_numpy(rng):
    for k in (3, 4, 7, 8):  # odd and even member counts
        x = jnp.asarray(rng.normal(size=(k, 6)), jnp.float32)
        got = np.asarray(R.masked_median(x, jnp.ones((k,), bool)))
        np.testing.assert_allclose(got, np.median(np.asarray(x), 0), rtol=1e-5)
    x = jnp.asarray(rng.normal(size=(5, 2)), jnp.float32)
    mask = jnp.asarray([True, True, False, True, False])
    ref = np.median(np.asarray(x)[np.asarray(mask)], 0)
    np.testing.assert_allclose(
        np.asarray(R.masked_median(x, mask)), ref, rtol=1e-5
    )


def test_trimmed_mean_resists_planted_outlier(rng):
    honest = rng.normal(size=(6, 8)).astype(np.float32)
    bank = np.concatenate([honest, 1e6 * np.ones((2, 8), np.float32)])
    m = jnp.ones((8,), bool)
    tm = np.asarray(R.masked_trimmed_mean(jnp.asarray(bank), m, 0.25))
    md = np.asarray(R.masked_median(jnp.asarray(bank), m))
    honest_mean = honest.mean(0)
    # order statistics of 6 N(0,1) samples deviate O(1) from their mean;
    # what matters is the outliers' 1e6 never leaks in
    assert np.abs(tm - honest_mean).max() < 2.5
    assert np.abs(md - honest_mean).max() < 2.5
    # the plain mean is destroyed by the same bank
    assert np.abs(bank.mean(0) - honest_mean).max() > 1e5


def test_krum_scores_and_select(rng):
    flat = jnp.asarray(rng.normal(size=(6, 10)), jnp.float32)
    f = 1
    scores = np.asarray(R.krum_scores(flat, f=f))
    x = np.asarray(flat)
    d2 = ((x[:, None, :] - x[None, :, :]) ** 2).sum(-1)
    np.fill_diagonal(d2, np.inf)
    ref = np.sort(d2, 1)[:, : 6 - f - 2].sum(1)
    np.testing.assert_allclose(scores, ref, rtol=1e-4)
    agg, sel = R.krum_select(flat, m=1, f=f)
    assert int(sel[0]) == int(np.argmin(ref))
    np.testing.assert_allclose(np.asarray(agg), x[int(np.argmin(ref))],
                               rtol=1e-6)
    # multi-Krum: mean of the m lowest-scored rows
    agg2, sel2 = R.krum_select(flat, m=3, f=f)
    np.testing.assert_allclose(
        np.asarray(agg2), x[np.argsort(ref)[:3]].mean(0), rtol=1e-5
    )


def test_krum_excludes_far_attacker(rng):
    honest = rng.normal(size=(5, 16)).astype(np.float32)
    attacker = 100.0 + rng.normal(size=(1, 16)).astype(np.float32)
    flat = jnp.asarray(np.concatenate([honest, attacker]))
    _, sel = R.krum_select(flat, m=1, f=1)
    assert int(sel[0]) != 5  # never the far-away row


def test_krum_validation():
    flat = jnp.zeros((2, 4))
    with pytest.raises(ValueError):
        R.krum_scores(flat)  # P >= 3
    with pytest.raises(ValueError):
        R.krum_scores(jnp.zeros((4, 3)), f=2)  # f <= P - 3


def test_bank_norm_clipping(rng):
    bank = {"w": jnp.asarray(rng.normal(size=(4, 8)), jnp.float32)}
    norms = np.asarray(R.bank_peer_norms(bank))
    ref = np.linalg.norm(np.asarray(bank["w"]), axis=1)
    np.testing.assert_allclose(norms, ref, rtol=1e-5)
    clipped = R.clip_bank_to_norm(bank, 0.5)
    cn = np.asarray(R.bank_peer_norms(clipped))
    assert (cn <= 0.5 + 1e-5).all()


# ---------------------------------------------------------------------------
# AdversarySpec
# ---------------------------------------------------------------------------


def test_adversary_spec_seeded_and_fraction():
    a = R.AdversarySpec(fraction=0.25, seed=3)
    assert a.num_attackers(8) == 2
    assert a.attackers(8) == a.attackers(8)  # deterministic in the seed
    b = R.AdversarySpec(fraction=0.25, seed=4)
    assert set(a.attackers(100)) != set(b.attackers(100))
    m = a.mask(8)
    assert m.dtype == bool and m.sum() == 2
    assert all(a.is_attacker(r, 8) == bool(m[r]) for r in range(8))
    assert R.AdversarySpec(num=3).num_attackers(8) == 3
    assert not R.AdversarySpec().active
    assert "sign_flip" in R.AdversarySpec(fraction=0.5).describe()


def test_adversary_spec_validation():
    with pytest.raises(ValueError):
        R.AdversarySpec(fraction=1.5)
    with pytest.raises(ValueError):
        R.AdversarySpec(attack="meteor")
    with pytest.raises(ValueError):
        R.AdversarySpec(num=-1)


def test_poison_gradients_kinds():
    g = {"w": jnp.ones((3,)), "b": -2.0 * jnp.ones((2,))}
    spec = R.AdversarySpec(fraction=0.5, attack="sign_flip", scale=10.0)
    p = R.poison_gradients(g, spec, jax.random.PRNGKey(0))
    np.testing.assert_allclose(np.asarray(p["w"]), -10.0 * np.ones(3))
    np.testing.assert_allclose(np.asarray(p["b"]), 20.0 * np.ones(2))
    noisy = R.poison_gradients(
        g, R.AdversarySpec(fraction=0.5, attack="scaled_noise", scale=5.0),
        jax.random.PRNGKey(0),
    )
    assert float(jnp.abs(noisy["w"]).max()) > 0  # noise, not the honest g
    with pytest.raises(ValueError, match="stale_replay"):
        R.poison_gradients(
            g, R.AdversarySpec(fraction=0.5, attack="stale_replay"),
            jax.random.PRNGKey(0),
        )


def test_tree_all_finite():
    assert R.tree_all_finite({"a": jnp.ones(3), "b": jnp.zeros(2)})
    assert not R.tree_all_finite({"a": jnp.asarray([1.0, float("nan")])})
    assert not R.tree_all_finite({"a": jnp.asarray([float("inf")])})


# ---------------------------------------------------------------------------
# host cluster: adversary + robust protocols end to end
# ---------------------------------------------------------------------------


def _cluster(**kw):
    from repro.configs import get_config
    from repro.core import LocalP2PCluster
    from repro.data import make_dataset
    from repro.optim import sgd

    base = dict(
        num_peers=4, batch_size=8, batches_per_epoch=2,
        optimizer=sgd(momentum=0.9), lr=0.05, sync=True, seed=0,
    )
    base.update(kw)
    return LocalP2PCluster(
        get_config("squeezenet1.1"),
        make_dataset("mnist", size=128, image_hw=8, channels=1),
        **base,
    )


@pytest.mark.slow
def test_cluster_zero_trim_equivalent_to_mean():
    a = _cluster(exchange="allgather_mean")
    b = _cluster(exchange="trimmed_mean:0")
    a.run(2)
    b.run(2)
    err = max(
        float(jnp.abs(x - y).max())
        for x, y in zip(jax.tree.leaves(a.peers[0].params),
                        jax.tree.leaves(b.peers[0].params))
    )
    assert err <= 1e-6, err


@pytest.mark.slow
def test_cluster_adversary_poisons_wire_not_self():
    adv = R.AdversarySpec(num=1, attack="sign_flip", scale=10.0, seed=1)
    cl = _cluster(exchange="median", adversary=adv)
    cl.run_epoch_sync(0)
    (attacker,) = adv.attackers(4)
    assert cl.mailbox.stats["poisoned_publishes"] == 1
    # the attacker's register holds the poisoned payload, visible to all
    msg = cl.mailbox.consume(attacker)
    honest = (r for r in range(4) if r != attacker)
    assert msg is not None and msg.epoch == 0


@pytest.mark.slow
def test_cluster_stale_replay_ships_previous_epoch():
    adv = R.AdversarySpec(num=1, attack="stale_replay", seed=2)
    cl = _cluster(exchange="allgather_mean", adversary=adv)
    (attacker,) = adv.attackers(4)
    cl.run_epoch_sync(0)
    # epoch 0: no cached payload yet -> honest publish
    assert cl.mailbox.stats["poisoned_publishes"] == 0
    first = cl.mailbox.consume(attacker).payload
    cl.run_epoch_sync(1)
    # epoch 1: the wire carries epoch 0's payload verbatim
    assert cl.mailbox.stats["poisoned_publishes"] == 1
    replayed = cl.mailbox.consume(attacker).payload
    assert all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(jax.tree.leaves(first), jax.tree.leaves(replayed))
    )


@pytest.mark.slow
def test_cluster_rejects_nonfinite_contribution():
    cl = _cluster(exchange="allgather_mean", reject_nonfinite=True)
    grads = {p.rank: None for p in cl.peers}
    for peer in cl.peers:
        g, _, _, _ = cl._compute_peer_gradient(peer, 0)
        grads[peer.rank] = g
    # peer 3 publishes NaNs; everyone else publishes honestly
    bad = jax.tree.map(lambda x: x * jnp.nan, grads[3])
    for peer in cl.peers:
        cl._publish(peer, bad if peer.rank == 3 else grads[peer.rank],
                    0, at_time=0.0)
    gp, _ = cl._consume_all(cl.peers[0], grads[0], at_time=None)
    assert 3 not in gp  # dropped at the trust boundary
    assert set(gp) == {0, 1, 2}
    assert cl.mailbox.stats["rejected_nonfinite"] == 1


def test_cluster_refuses_adversary_on_sharded_protocol():
    with pytest.raises(ValueError, match="whole-gradient"):
        _cluster(exchange="reduce_scatter",
                 adversary=R.AdversarySpec(num=1))


def test_device_path_refuses_stale_replay():
    from repro.core.p2p import Topology, build_p2p_train_step
    from repro.optim import sgd as _sgd
    from repro import compat

    mesh = compat.make_mesh((1,), ("data",))
    with pytest.raises(ValueError, match="host mailbox"):
        build_p2p_train_step(
            lambda p, b: (jnp.float32(0), jnp.float32(0)),
            _sgd(), Topology(peer_axes=("data",)), mesh, lambda s: 0.1,
            adversary=R.AdversarySpec(num=1, attack="stale_replay"),
        )


def test_krum_exchange_refuses_sparse_graph():
    with pytest.raises(ValueError, match="full"):
        _cluster(exchange="krum", graph="ring")


def test_host_combine_fallback_is_none():
    # non-robust protocols keep the legacy mixing path
    proto = get_exchange("allgather_mean")
    assert proto.host_combine({0: {"w": jnp.ones(2)}}, 0,
                              ExchangeContext(num_peers=1)) is None


# ---------------------------------------------------------------------------
# satellite: ConvergenceDetector NaN handling
# ---------------------------------------------------------------------------


def test_plateau_nan_counts_as_bad_epoch():
    p = ReduceLROnPlateau(0.1, mode="min", patience=1)
    p.step(1.0)
    lr0 = p.lr
    p.step(float("nan"))
    p.step(float("nan"))  # patience exceeded -> reduce
    assert p.lr < lr0
    assert p.best == 1.0  # NaN never becomes "best"


def test_plateau_inf_never_improves_even_first():
    p = ReduceLROnPlateau(0.1, mode="max", patience=0)
    p.step(float("-inf"))
    assert p.best is None
    p.step(float("inf"))
    assert p.best is None  # +inf in max mode would be unbeatable
    p.step(0.5)
    assert p.best == 0.5


def test_early_stopping_nan_streak_stops():
    s = EarlyStopping(mode="min", patience=2)
    assert not s.step(1.0)
    assert not s.step(float("nan"))
    assert s.step(float("nan"))  # two bad epochs -> stop
    assert s.best == 1.0


def test_early_stopping_nan_first_metric_not_best():
    s = EarlyStopping(mode="min", patience=3)
    s.step(float("nan"))
    assert s.best is None
    s.step(2.0)
    assert s.best == 2.0


def test_convergence_detector_diverged_run_stops():
    det = ConvergenceDetector(0.1, mode="min", plateau_patience=1,
                              stop_patience=3, max_epochs=100)
    det.step(1.0)
    stopped = False
    for _ in range(4):
        stopped = det.step(float("nan"))
    assert stopped
    assert det.plateau.best == 1.0
