"""Cluster-level runtime behaviour: the engine-backed async epoch
reproduces the legacy heapq loop bit-for-bit, receive-side wire time is
charged, churn is seeded and deterministic, and engine fault accounting
reaches StageMetrics / ExecutionReport through a real cluster epoch."""
import heapq

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import LocalP2PCluster, RuntimeConfig, ServerlessExecutor
from repro.data import make_dataset
from repro.optim import sgd


def _small_cluster(**kw):
    cfg = get_config("squeezenet1.1")
    ds = make_dataset("mnist", size=64, image_hw=8, channels=1)
    base = dict(
        num_peers=3, batch_size=8, batches_per_epoch=1,
        optimizer=sgd(momentum=0.0), lr=0.05, seed=0,
    )
    base.update(kw)
    return LocalP2PCluster(cfg, ds, **base)


def _fake_walls(rank: int, epoch: int) -> float:
    return 0.11 + 0.07 * ((rank * 3 + epoch) % 5)


def _stub_compute(cl):
    """Replace real gradient computation with deterministic walls."""
    zero = jax.tree.map(jnp.zeros_like, cl.peers[0].params)

    def fake(peer, epoch):
        w = _fake_walls(peer.rank, epoch)
        peer.compute_time_s += w
        return zero, 1.0, 0.5, w

    cl._compute_peer_gradient = fake


def test_async_epoch_matches_legacy_heapq_loop_bit_for_bit():
    """Acceptance: engine event order and virtual clocks reproduce the old
    ad-hoc ``heapq`` loop exactly (zero faults, zero wire time)."""
    speeds = [1.0, 2.0, 0.5]
    cl = _small_cluster(
        sync=False, peer_speeds=speeds, network_bandwidth_bps=float("inf"),
    )
    _stub_compute(cl)
    orders = []
    for e in range(4):
        cl.run_epoch_async(e)
        orders.append(list(cl.last_event_order))

    # the legacy loop, verbatim: pop (clock, rank), advance by wall * speed
    clocks = [0.0, 0.0, 0.0]
    for e in range(4):
        events = [(clocks[r], r) for r in range(3)]
        heapq.heapify(events)
        expected = []
        while events:
            _, r = heapq.heappop(events)
            expected.append(r)
            clocks[r] += _fake_walls(r, e) * speeds[r]
        assert orders[e] == expected, f"epoch {e}"
    for peer, c in zip(cl.peers, clocks):
        assert peer.clock == c  # exact float equality, not approx


def test_async_stale_consumption_preserved():
    """Fast peers see nothing from slow peers in epoch 0 — peers diverge."""
    cl = _small_cluster(sync=False, peer_speeds=[1.0, 3.0, 9.0])
    cl.run_epoch_async(0)
    cl.run_epoch_async(1)
    p0 = jax.tree.leaves(cl.peers[0].params)
    p2 = jax.tree.leaves(cl.peers[2].params)
    assert max(float(jnp.abs(a - b).max()) for a, b in zip(p0, p2)) > 0


def test_receive_wire_time_is_charged():
    """Satellite fix: recv_time_s accrues payload download time instead of
    the old hardcoded 0.0."""
    bw = 1e9
    cl = _small_cluster(network_bandwidth_bps=bw, sync=True)
    cl.run_epoch_sync(0)
    for peer in cl.peers:
        assert peer.recv_time_s > 0.0
        # allgather_mean: every peer ships the same dense payload, so the
        # receive side downloads (P-1) copies of what this peer sent
        expected = (cl.num_peers - 1) * peer.comm_bytes_sent * 8 / bw
        assert peer.recv_time_s == pytest.approx(expected)
        assert peer.metrics.mean("receive_gradients").seconds > 0


def test_receive_wire_time_advances_async_clock():
    cl = _small_cluster(sync=False, network_bandwidth_bps=1e9)
    _stub_compute(cl)
    for e in range(2):
        cl.run_epoch_async(e)
    assert any(p.recv_time_s > 0 for p in cl.peers)
    for peer in cl.peers:
        # clock = sum of compute * speed + everything charged to the link's
        # receive side (send wire delays visibility instead of the sender)
        compute = sum(_fake_walls(peer.rank, e) * peer.speed for e in range(2))
        assert peer.clock == pytest.approx(compute + peer.recv_time_s)


def test_churn_is_seeded_deterministic_and_survivable():
    kw = dict(sync=False, churn_prob=0.6, churn_downtime_s=2.0, seed=5)
    a = _small_cluster(**kw)
    b = _small_cluster(**kw)
    for cl in (a, b):
        _stub_compute(cl)
        for e in range(3):
            cl.run_epoch_async(e)
    drops_a = [p.drops for p in a.peers]
    assert sum(drops_a) > 0  # churn actually fired at p=0.6 over 9 steps
    assert drops_a == [p.drops for p in b.peers]
    assert [p.clock for p in a.peers] == [p.clock for p in b.peers]
    assert a.last_event_order == b.last_event_order
    for peer in a.peers:
        if peer.drops:
            assert peer.downtime_s >= peer.drops * 2.0  # rejoin delay charged
        assert peer.steps_done == 3  # dropped peers rejoined and updated

    quiet = _small_cluster(sync=False, seed=5)
    _stub_compute(quiet)
    quiet.run_epoch_async(0)
    assert all(p.drops == 0 for p in quiet.peers)


def test_dropped_peer_is_consumed_stale_by_others():
    """SPIRT-style: while a peer is down, others read its latest-wins
    register from the previous epoch rather than blocking."""
    cl = _small_cluster(sync=False, churn_prob=0.999, churn_downtime_s=50.0, seed=1)
    _stub_compute(cl)
    cl.run_epoch_async(0)
    # everyone eventually published epoch 0 (rejoin happens within-epoch)
    for r in range(cl.num_peers):
        assert cl.mailbox.consume(r) is not None
    assert all(p.drops > 0 for p in cl.peers)
    assert all(p.steps_done == 1 for p in cl.peers)


def test_engine_faults_reach_reports_and_stage_metrics():
    """Cold starts / queue waits / retries flow from the engine through
    ExecutionReport into the Table-I stage metrics of a real epoch."""
    ex = ServerlessExecutor(
        runtime=RuntimeConfig(cold_start_s=1.5, concurrency_limit=1),
    )
    cl = _small_cluster(batches_per_epoch=3, executor=ex, sync=True)
    cl.run_epoch_sync(0)
    rep = cl.peers[0].reports[0]
    # concurrency_limit=1: one container cold-starts, then is serially
    # reused by the queued invocations (AWS-style warm reuse)
    assert rep.num_cold_starts == 1 and rep.cold_start_s == pytest.approx(1.5)
    assert rep.queue_wait_s > 0  # concurrency_limit=1 serialized the fan-out
    assert rep.wall_time_s > rep.cold_start_s  # cold time is inside the wall
    table = cl.peers[0].metrics.table()
    assert table["cold_start"]["time_s"] == pytest.approx(1.5, rel=1e-3)
    assert table["queue_wait"]["time_s"] > 0
    assert "retry" in table and table["retry"]["time_s"] == 0.0


def test_serverless_offload_with_faults_keeps_math_exact():
    """Faults change time and dollars, never gradients (paper's premise)."""
    kw = dict(sync=True, seed=7)
    a = _small_cluster(**kw)
    a.run_epoch_sync(0)
    b = _small_cluster(
        executor=ServerlessExecutor(
            runtime=RuntimeConfig(cold_start_s=2.0, failure_rate=0.3, seed=0),
            allocation="latency",
        ),
        **kw,
    )
    b.run_epoch_sync(0)
    for x, y in zip(
        jax.tree.leaves(a.peers[0].params), jax.tree.leaves(b.peers[0].params)
    ):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
