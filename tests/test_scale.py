"""Scaling-surface tests (PR 9): sparse graphs vs dense oracles, the
batched fanout engine vs the legacy scalar engine, array-backed mailbox
semantics, warm-pool stats, LinkModel edge cases, and the TreePlan /
tree-exchange aggregation structure."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.events import (
    BATCHED_FANOUT_MIN,
    FanoutTimeout,
    LinkModel,
    RuntimeConfig,
    ServerlessRuntime,
)
from repro.core.graph import DENSE_MATERIALIZE_LIMIT, get_graph
from repro.core.mailbox import HostMailbox
from repro.core.tree import TreePlan

GRAPH_SPECS = ("full", "ring", "gossip:3", "hierarchical:4")


# ---------------------------------------------------------------------------
# Sparse overlays vs dense oracles
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("P", [2, 8, 64])
@pytest.mark.parametrize("spec", GRAPH_SPECS)
def test_mixing_row_matches_dense_matrix(spec, P):
    if spec == "gossip:3" and P <= 3:
        pytest.skip("gossip validates k < P")
    g = get_graph(spec, P, seed=0)
    W = np.asarray(g.mixing_matrix())
    for r in range(P):
        # bit-equal, not allclose: both sides assemble the same MH terms
        assert np.array_equal(g.mixing_row(r), W[r]), (spec, P, r)
        assert np.array_equal(
            g.neighbors_array(r), np.flatnonzero(np.asarray(g.adjacency)[r])
        )


@pytest.mark.parametrize("spec", GRAPH_SPECS)
def test_power_iteration_gap_matches_dense_oracle(spec):
    g = get_graph(spec, 64, seed=0)
    dense = g.spectral_gap(method="dense")
    power = g.spectral_gap(method="power")
    # power converges at rate |λ3/λ2|: near-degenerate subdominant pairs
    # (hierarchical at P=64) land ~1e-6 off the eigvalsh oracle
    assert abs(dense - power) <= 5e-6, (spec, dense, power)


def test_mix_apply_matches_dense_matvec():
    rng = np.random.default_rng(0)
    for spec in GRAPH_SPECS:
        g = get_graph(spec, 32, seed=0)
        W = np.asarray(g.mixing_matrix())
        x = rng.standard_normal(32)
        assert np.allclose(g.mix_apply(x), W @ x, atol=1e-12), spec
        X = rng.standard_normal((32, 5))
        assert np.allclose(g.mix_apply(X), W @ X, atol=1e-12), spec


def test_dense_materialization_is_gated():
    P = DENSE_MATERIALIZE_LIMIT + 1
    g = get_graph("ring", P, seed=0)
    with pytest.raises(ValueError, match="DENSE_MATERIALIZE_LIMIT"):
        g.mixing_matrix()
    with pytest.raises(ValueError, match="DENSE_MATERIALIZE_LIMIT"):
        g.adjacency
    # ...while the sparse surface keeps answering
    assert g.degree(0) == 2
    assert np.array_equal(g.neighbors_array(0), [1, P - 1])
    assert abs(float(np.sum(g.mixing_row(0))) - 1.0) < 1e-12
    assert g.is_connected()
    assert 0.0 < g.spectral_gap() < 1.0


def test_full_graph_is_implicit_at_scale():
    # 1e5-peer full mesh: no CSR (1e10 edges), every query is analytic
    g = get_graph("full", 100_000)
    assert g.is_full and g.degree(7) == 99_999
    assert g.spectral_gap() == 1.0
    x = np.arange(100_000, dtype=np.float64)
    assert np.allclose(g.mix_apply(x), x.mean())


# ---------------------------------------------------------------------------
# Batched fanout engine == legacy scalar engine (same seed, same records)
# ---------------------------------------------------------------------------

ENGINE_CONFIGS = {
    "ideal": {},
    "cold": dict(cold_start_s=2.0),
    "capped": dict(concurrency_limit=8),
    "faults": dict(failure_rate=0.2, straggler_prob=0.3),
    "all": dict(
        concurrency_limit=8, cold_start_s=2.0, failure_rate=0.2,
        straggler_prob=0.3,
    ),
}

RECORD_FIELDS = (
    "submit_s", "start_s", "end_s", "exec_s", "download_s", "queue_wait_s",
    "cold_start_s", "cold_starts", "straggler_factor", "attempts",
    "retries", "backoff_s", "failed_s", "billed_s",
)


@pytest.mark.parametrize("name", sorted(ENGINE_CONFIGS))
def test_batched_engine_matches_scalar(name):
    kw = ENGINE_CONFIGS[name]
    results = {}
    for batched in (False, True):
        rt = ServerlessRuntime(RuntimeConfig(seed=3, **kw))
        times = np.random.default_rng(11).uniform(0.5, 1.5, 33)
        # two consecutive fanouts: the second reuses the warm pool
        first = rt.fanout(times, memory_mb=1792, batched=batched)
        second = rt.fanout(times[::-1], memory_mb=1792, batched=batched)
        results[batched] = (first, second, rt.clock, dict(rt.pool.stats))
    for wave in (0, 1):
        a, b = results[False][wave], results[True][wave]
        assert a.makespan_s == pytest.approx(b.makespan_s, abs=1e-9)
        for ra, rb in zip(a.invocations, b.invocations):
            for f in RECORD_FIELDS:
                assert float(getattr(ra, f)) == pytest.approx(
                    float(getattr(rb, f)), abs=1e-9
                ), (name, wave, ra.index, f)
    assert results[False][2] == pytest.approx(results[True][2], abs=1e-9)
    assert results[False][3] == results[True][3]  # pool hits/misses/expired


def test_auto_batching_threshold():
    rt = ServerlessRuntime()
    small = rt.fanout(np.ones(4), memory_mb=1792)
    big = rt.fanout(np.ones(BATCHED_FANOUT_MIN), memory_mb=1792)
    assert len(small.invocations) == 4
    assert len(big.invocations) == BATCHED_FANOUT_MIN
    # both paths end with sorted record indices and absolute-time stamps
    assert [r.index for r in big.invocations] == list(range(BATCHED_FANOUT_MIN))


def test_batched_timeout_raises_like_scalar():
    for batched in (False, True):
        rt = ServerlessRuntime(
            RuntimeConfig(failure_rate=1.0, max_retries=1, seed=0)
        )
        with pytest.raises(FanoutTimeout):
            rt.fanout(
                np.ones(300), memory_mb=1792, timeout_s=0.5, batched=batched
            )


# ---------------------------------------------------------------------------
# Warm-container pool: O(1)-ish acquire + stats micro-assertions
# ---------------------------------------------------------------------------

def test_pool_stats_hits_misses_expired():
    rt = ServerlessRuntime(RuntimeConfig(container_keepalive_s=10.0))
    key = (0, 1792)
    assert rt.pool.acquire(key, at=0.0) is False  # empty pool: miss
    rt.pool.release(key, at=1.0)
    rt.pool.release(key, at=2.0)
    assert rt.pool.acquire(key, at=3.0) is True  # warm hit (LIFO: t=2)
    assert rt.pool.acquire(key, at=20.0) is False  # t=1 expired by 11.0
    assert rt.pool.stats == {"hits": 1, "misses": 2, "expired": 1}


def test_pool_future_release_invisible_until_due():
    rt = ServerlessRuntime()
    key = (0, 1792)
    rt.pool.release(key, at=5.0)  # staged by a batched wave
    assert rt.pool.acquire(key, at=1.0) is False  # not warm *yet*
    assert rt.pool.acquire(key, at=6.0) is True


def test_pool_take_available_batch_claim():
    rt = ServerlessRuntime(RuntimeConfig(container_keepalive_s=100.0))
    key = (0, 1792)
    for t in (1.0, 2.0, 3.0):
        rt.pool.release(key, at=t)
    assert rt.pool.take_available(key, at=4.0, want=5) == 3
    assert rt.pool.stats["hits"] == 3
    assert rt.pool.acquire(key, at=4.0) is False


# ---------------------------------------------------------------------------
# LinkModel edge cases
# ---------------------------------------------------------------------------

def test_link_transfer_edge_cases():
    link = LinkModel(bandwidth_bps=1e9)
    assert link.transfer_s(0) == 0.0
    overhead = LinkModel(bandwidth_bps=1e9, per_message_overhead_s=0.25)
    assert overhead.transfer_s(0) == 0.25  # framing charged even when empty
    assert overhead.transfer_s(10**9 // 8) == pytest.approx(1.25)


def test_download_time_with_raw_bandwidth_and_none_link():
    from repro.core.mailbox import Message

    mb = HostMailbox(2)
    msg = Message(None, 0.0, 0, nbytes=1_000_000)
    # link=None falls back to the raw bandwidth figure (no overhead term)
    assert mb.download_time_s(msg, 1e9) == pytest.approx(0.008)
    link = LinkModel(bandwidth_bps=1e9, per_message_overhead_s=0.1)
    assert mb.download_time_s(msg, link=link) == pytest.approx(0.108)


# ---------------------------------------------------------------------------
# Array-backed mailbox semantics
# ---------------------------------------------------------------------------

def test_mailbox_latest_wins_and_live_counter():
    mb = HostMailbox(4)
    assert mb.live_messages == 0
    mb.publish(1, "a", nbytes=10, time=1.0, epoch=0)
    mb.publish(1, "b", nbytes=20, time=2.0, epoch=0)  # same-epoch replace
    mb.publish(2, "c", nbytes=30, time=1.0, epoch=0, shard=("up",))
    assert mb.live_messages == 2  # registers, not publishes
    assert mb.stats["publishes"] == 3
    assert mb.stats["compacted"] == 1
    msg = mb.consume(1)
    assert msg.payload == "b" and msg.nbytes == 20 and msg.publish_time == 2.0
    assert mb.consume(2) is None  # default shard register is empty
    assert mb.consume(2, shard=("up",)).payload == "c"
    # time-gated visibility
    assert mb.consume(1, at_time=1.5) is None
    assert mb.consume(1, at_time=2.5).payload == "b"


def test_mailbox_barrier_counts_distinct_signals():
    mb = HostMailbox(3)
    mb.barrier_signal(0, epoch=5)
    mb.barrier_signal(0, epoch=5)  # duplicate never over-counts
    mb.barrier_signal(1, epoch=5)
    assert not mb.barrier_complete(5)
    mb.barrier_signal(2, epoch=5)
    assert mb.barrier_complete(5)
    mb.barrier_reset(5)
    assert not mb.barrier_complete(5)
    mb.barrier_reset(5)  # idempotent


# ---------------------------------------------------------------------------
# TreePlan structure
# ---------------------------------------------------------------------------

def test_tree_plan_structure():
    tp = TreePlan(10, 2)
    assert tp.depth == 4
    assert [list(l) for l in tp.levels()] == [[0], [1, 2], [3, 4, 5, 6],
                                              [7, 8, 9]]
    assert tp.parent(0) is None
    for r in range(1, 10):
        assert r in tp.children(tp.parent(r))
        assert tp.child_slot(r) == (r - 1) % 2
    assert tp.num_hubs == 5
    assert tp.level_of(9) == 3


def test_tree_plan_covers_every_rank_once():
    for P, k in [(1, 2), (2, 2), (100, 3), (1000, 4)]:
        tp = TreePlan(P, k)
        seen = [r for lvl in tp.levels() for r in lvl]
        assert sorted(seen) == list(range(P))
        for r in range(P):
            assert len(tp.children(r)) <= k


def test_tree_plan_validates_fanout():
    with pytest.raises(ValueError, match="fanout must be >= 2"):
        TreePlan(8, 1)
    from repro.core.exchange import get_exchange

    with pytest.raises(ValueError, match="fanout must be >= 2"):
        get_exchange("tree:1")
    assert get_exchange("tree:4").fanout == 4
    assert get_exchange("tree").fanout == 2


# ---------------------------------------------------------------------------
# Tree exchange accounting
# ---------------------------------------------------------------------------

def test_tree_wire_accounting_bounded_publish():
    from repro.core.exchange import ExchangeContext, get_exchange

    grads_like = {"w": jnp.zeros((64, 64), jnp.float32)}
    tree = get_exchange("tree")
    dense = get_exchange("allgather_mean")
    for P in (4, 64, 1024):
        ctx = ExchangeContext(num_peers=P)
        buf = tree.wire_bytes_per_edge(grads_like, ctx)
        # a hub publishes <= 2 buffers regardless of P...
        assert tree.host_wire_bytes(grads_like, ctx) == 2 * buf
        # ...total tree traffic is 2(P-1) hop messages...
        assert tree.wire_bytes(grads_like, ctx) == 2 * (P - 1) * buf
        # ...while a dense full-mesh peer's wire grows O(P)
        assert dense.wire_bytes(grads_like, ctx) == pytest.approx(
            dense.wire_bytes_per_edge(grads_like, ctx) * (P - 1)
        )
