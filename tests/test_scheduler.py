"""Heterogeneous fleets + auto-scheduler: property tests for the frontier
math (no frontier point dominated, every non-frontier point dominated,
permutation/duplication invariance), scheduler invariants (deadline/budget
never violated, exhaustive-optimal picks, strict policies raise exactly
when infeasible), single-backend fleet == PR 5 pure-backend accounting
<= 1e-6, and the mixed-fleet (GPU + CPU + serverless in one epoch)
same-seed trace-determinism rail.

The randomized suites run on seeded numpy (always-on, reproducible); the
hypothesis variants add shrinking search when hypothesis is installed.
"""
import numpy as np
import pytest

from repro.analysis.trace import TraceRecorder
from repro.core.cost import (
    CostReport,
    dominates,
    ec2_cost_per_second,
    pareto_frontier,
)
from repro.core.events import InstanceConfig, RuntimeConfig
from repro.core.scheduler import (
    FleetExecutor,
    FleetPlan,
    PeerAssignment,
    Scheduler,
    available_schedulers,
    evaluate_candidates,
    get_scheduler,
    standard_candidates,
)
from repro.core.serverless import ServerlessExecutor

MODEL = int(531e6)
BATCH = int(8e6)


def _random_reports(rng, n, *, grid=True):
    """Random CostReport sets; the coarse grid forces coordinate ties."""
    out = []
    for i in range(n):
        if grid:
            wall = float(rng.integers(1, 6))
            cost = float(rng.integers(1, 6))
        else:
            wall = float(rng.uniform(0.1, 100.0))
            cost = float(rng.uniform(1e-4, 1.0))
        out.append(
            CostReport(
                backend=("serverless", "instance", "fleet")[int(rng.integers(3))],
                wall_time_s=wall,
                cost_usd=cost,
                num_peers=int(rng.integers(1, 5)),
                label=f"r{i}",
            )
        )
    return out


# ---------------------------------------------------------------------------
# Frontier invariants (property suite)
# ---------------------------------------------------------------------------

def _check_frontier_invariants(pts):
    front = pareto_frontier(pts)
    assert front, "a nonempty set always has a nonempty frontier"
    # 1. no frontier point is dominated by ANY input point
    for f in front:
        assert not any(dominates(p, f) for p in pts)
    # 2. every non-frontier point is dominated by some frontier point
    for p in pts:
        if p not in front:
            assert any(dominates(f, p) for f in front)
    # 3. permutation invariance (total-order sort key)
    rng = np.random.default_rng(0)
    for _ in range(3):
        perm = [pts[j] for j in rng.permutation(len(pts))]
        assert pareto_frontier(perm) == front
    # 4. duplication invariance: membership unchanged, copies kept
    dup = pareto_frontier(list(pts) + list(pts))
    assert [p for p in dup if p in front] == dup
    for f in front:
        assert f in dup


@pytest.mark.parametrize("grid", [True, False])
def test_frontier_invariants_randomized(grid):
    rng = np.random.default_rng(7 if grid else 8)
    for trial in range(60):
        pts = _random_reports(rng, int(rng.integers(1, 14)), grid=grid)
        _check_frontier_invariants(pts)


def test_frontier_invariants_hypothesis():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=60, deadline=None)
    @given(
        coords=st.lists(
            st.tuples(st.integers(1, 5), st.integers(1, 5)),
            min_size=1,
            max_size=12,
        )
    )
    def prop(coords):
        pts = [
            CostReport("serverless", float(w), float(c), label=f"h{i}")
            for i, (w, c) in enumerate(coords)
        ]
        _check_frontier_invariants(pts)

    prop()


def test_equal_coordinate_reports_are_mutually_nondominated():
    a = CostReport("serverless", 3.0, 3.0, label="a")
    b = CostReport("instance", 3.0, 3.0, label="b")
    assert not dominates(a, b) and not dominates(b, a)
    assert dominates(CostReport("x", 2.0, 3.0), a)  # faster, same cost
    assert dominates(CostReport("x", 3.0, 2.0), a)  # same wall, cheaper
    front = pareto_frontier([a, b])
    assert len(front) == 2


# ---------------------------------------------------------------------------
# Scheduler invariants (property suite)
# ---------------------------------------------------------------------------

def _check_scheduler_invariants(reports, deadline, budget):
    cheapest = get_scheduler("cheapest_under_deadline")
    fastest = get_scheduler("fastest_under_budget")
    walker = get_scheduler("pareto_walk")

    dl_ok = [r for r in reports if deadline is None or r.wall_time_s <= deadline]
    if dl_ok:
        pick = reports[cheapest.choose(reports, deadline_s=deadline)]
        assert deadline is None or pick.wall_time_s <= deadline  # never violated
        assert pick.total_usd == min(r.total_usd for r in dl_ok)  # exhaustive
    else:
        with pytest.raises(ValueError, match="deadline"):
            cheapest.choose(reports, deadline_s=deadline)

    bg_ok = [r for r in reports if budget is None or r.total_usd <= budget]
    if bg_ok:
        pick = reports[fastest.choose(reports, budget_usd=budget)]
        assert budget is None or pick.total_usd <= budget  # never violated
        assert pick.wall_time_s == min(r.wall_time_s for r in bg_ok)
    else:
        with pytest.raises(ValueError, match="budget"):
            fastest.choose(reports, budget_usd=budget)

    # pareto_walk: best-effort — never raises, never leaves the frontier
    pick = reports[walker.choose(reports, deadline_s=deadline, budget_usd=budget)]
    front = pareto_frontier(reports)
    assert any(
        pick.wall_time_s == f.wall_time_s and pick.cost_usd == f.cost_usd
        for f in front
    )
    if deadline is None and budget is None:
        assert pick.cost_usd == min(f.cost_usd for f in front)


def test_scheduler_invariants_randomized():
    rng = np.random.default_rng(11)
    for trial in range(80):
        reports = _random_reports(rng, int(rng.integers(1, 10)), grid=True)
        deadline = (
            None if rng.random() < 0.25 else float(rng.uniform(0.0, 7.0))
        )
        budget = (
            None if rng.random() < 0.25 else float(rng.uniform(0.0, 25.0))
        )
        _check_scheduler_invariants(reports, deadline, budget)


def test_scheduler_invariants_hypothesis():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=60, deadline=None)
    @given(
        coords=st.lists(
            st.tuples(st.integers(1, 5), st.integers(1, 5), st.integers(1, 4)),
            min_size=1,
            max_size=10,
        ),
        deadline=st.one_of(st.none(), st.floats(0.0, 8.0)),
        budget=st.one_of(st.none(), st.floats(0.0, 30.0)),
    )
    def prop(coords, deadline, budget):
        reports = [
            CostReport(
                "serverless", float(w), float(c), num_peers=p, label=f"h{i}"
            )
            for i, (w, c, p) in enumerate(coords)
        ]
        _check_scheduler_invariants(reports, deadline, budget)

    prop()


def test_scheduler_registry_contract():
    names = available_schedulers()
    assert {"cheapest_under_deadline", "fastest_under_budget",
            "pareto_walk"} <= set(names)
    for n in names:
        s = get_scheduler(n)
        assert isinstance(s, Scheduler) and s.name == n
    with pytest.raises(ValueError, match="registered schedulers"):
        get_scheduler("gradient_descent_on_money")


def test_scheduler_tie_break_is_deterministic():
    # two equal-cost equal-wall candidates: the pick must be stable (first
    # index), not dependent on dict/hash order
    reports = [
        CostReport("serverless", 2.0, 1.0, label="a"),
        CostReport("instance", 2.0, 1.0, label="b"),
    ]
    s = get_scheduler("cheapest_under_deadline")
    assert all(s.choose(reports, deadline_s=5.0) == 0 for _ in range(5))


# ---------------------------------------------------------------------------
# FleetPlan validation
# ---------------------------------------------------------------------------

def test_peer_assignment_validation():
    with pytest.raises(ValueError, match="backend"):
        PeerAssignment("tpu")
    with pytest.raises(ValueError, match="known tiers"):
        PeerAssignment("instance", instance="t9.mega")
    with pytest.raises(ValueError, match="serverless knob"):
        PeerAssignment("instance", instance="t2.large", memory_mb=1024)
    with pytest.raises(ValueError, match="no VM tier"):
        PeerAssignment("serverless", instance="t2.large")
    with pytest.raises(ValueError, match="memory_mb"):
        PeerAssignment("serverless", memory_mb=64)
    assert PeerAssignment("instance", instance="g5.xlarge").is_gpu
    assert not PeerAssignment("serverless").is_gpu


def test_fleet_plan_shape():
    with pytest.raises(ValueError, match="at least one"):
        FleetPlan(())
    plan = FleetPlan.pure("serverless", 3, memory_mb=4400)
    assert plan.num_peers == 3 and plan.is_pure
    mixed = FleetPlan(
        (
            PeerAssignment("instance", instance="p3.2xlarge"),
            PeerAssignment("serverless"),
        ),
        name="m",
    )
    assert not mixed.is_pure
    assert "gpu:p3.2xlarge" in mixed.describe()
    assert len(standard_candidates(4)) >= 6


# ---------------------------------------------------------------------------
# Single-backend fleet == PR 5 pure-backend accounting (<= 1e-6)
# ---------------------------------------------------------------------------

def test_pure_serverless_fleet_matches_pr5_report():
    times = [0.4] * 6
    fx = FleetExecutor(runtime=RuntimeConfig(seed=0))
    fr = fx.run_epoch(
        FleetPlan.pure("serverless", 3),
        [times] * 3,
        model_bytes=MODEL,
        batch_bytes=BATCH,
    )
    pure = (
        ServerlessExecutor(runtime=RuntimeConfig(seed=0))
        .simulate(times, model_bytes=MODEL, batch_bytes=BATCH)
        .cost_report(num_peers=3)
    )
    cr = fr.cost_report()
    assert cr.backend == "serverless"
    assert abs(cr.wall_time_s - pure.wall_time_s) <= 1e-6
    assert abs(cr.cost_usd - pure.cost_usd) <= 1e-6
    assert abs(cr.total_usd - pure.total_usd) <= 1e-6
    assert cr.lambda_memory_mb == pure.lambda_memory_mb


def test_pure_instance_fleet_matches_pr5_report():
    times = [0.7] * 5
    fx = FleetExecutor(instance_config=InstanceConfig())
    fr = fx.run_epoch(
        FleetPlan.pure("instance", 4, instance="t2.xlarge"),
        [times] * 4,
        model_bytes=MODEL,
        batch_bytes=BATCH,
    )
    pure = (
        ServerlessExecutor(
            backend="instance",
            instance="t2.xlarge",
            instance_config=InstanceConfig(),
        )
        .simulate_instance(
            times, model_bytes=MODEL, batch_bytes=BATCH, reference_vcpus=1.0
        )
        .cost_report(num_peers=4)
    )
    cr = fr.cost_report()
    assert cr.backend == "instance" and cr.instance == "t2.xlarge"
    assert abs(cr.wall_time_s - pure.wall_time_s) <= 1e-6
    assert abs(cr.cost_usd - pure.cost_usd) <= 1e-6
    # identical peers: nobody waits at the barrier (float noise only)
    assert all(r.idle_s <= 1e-9 for r in fr.per_peer)


# ---------------------------------------------------------------------------
# Mixed-fleet accounting: wall = max over peers, cost = sum, idle billed
# ---------------------------------------------------------------------------

def test_mixed_fleet_wall_is_max_and_cost_is_sum():
    heavy, light = [24.0, 24.0], [0.3] * 12
    plan = FleetPlan(
        (
            PeerAssignment("instance", instance="p3.2xlarge"),
            PeerAssignment("serverless"),
        )
    )
    fx = FleetExecutor(instance_config=InstanceConfig())  # no boot: warm math
    fr = fx.run_epoch(
        plan, [heavy, light], model_bytes=MODEL, batch_bytes=BATCH
    )
    gpu_rep, sls_rep = fr.per_peer
    assert fr.wall_time_s == pytest.approx(
        max(gpu_rep.wall_time_s, sls_rep.wall_time_s)
    )
    assert fr.total_usd == pytest.approx(gpu_rep.cost_usd + sls_rep.cost_usd)
    assert fr.cost_report().backend == "fleet"
    # GPU ran 48 reference-seconds at 24x
    assert gpu_rep.wall_time_s >= 2.0


def test_instance_peer_bills_barrier_idle_to_fleet_wall():
    # a fast CPU peer waits for a slow serverless peer: the VM's meter runs
    plan = FleetPlan(
        (
            PeerAssignment("instance", instance="t2.xlarge"),
            PeerAssignment("serverless"),
        )
    )
    fx = FleetExecutor(instance_config=InstanceConfig())
    fr = fx.run_epoch(
        plan, [[0.1], [30.0]], model_bytes=MODEL, batch_bytes=BATCH
    )
    cpu_rep, sls_rep = fr.per_peer
    assert fr.wall_time_s == pytest.approx(sls_rep.wall_time_s)
    idle = fr.wall_time_s - (0.1 / 4.0)  # t2.xlarge runs 0.1 ref-s at 4 vCPU
    assert cpu_rep.idle_s == pytest.approx(idle)
    assert cpu_rep.cost_usd == pytest.approx(
        ec2_cost_per_second("t2.xlarge") * fr.wall_time_s
    )


def test_fleet_rejects_mismatched_workload():
    fx = FleetExecutor()
    with pytest.raises(ValueError, match="per-peer batch lists"):
        fx.run_epoch(
            FleetPlan.pure("serverless", 3),
            [[1.0]] * 2,
            model_bytes=MODEL,
            batch_bytes=BATCH,
        )


def test_evaluate_candidates_warm_amortizes_boot():
    plan = FleetPlan.pure("instance", 2, instance="p3.2xlarge")
    cold = evaluate_candidates(
        [plan], [[1.0]] * 2, model_bytes=MODEL, batch_bytes=BATCH, warm=False
    )[0]
    warm = evaluate_candidates(
        [plan], [[1.0]] * 2, model_bytes=MODEL, batch_bytes=BATCH, warm=True
    )[0]
    # first epoch pays the GPU boot; steady state does not
    assert cold.wall_time_s > warm.wall_time_s
    assert warm.wall_time_s == pytest.approx(1.0 / 24.0)


# ---------------------------------------------------------------------------
# Mixed-fleet trace-determinism rail (PR 8): GPU + CPU + serverless in one
# epoch, same seed => bit-identical digests — faults/churn ON
# ---------------------------------------------------------------------------

@pytest.mark.parametrize(
    "runtime,instance_cfg",
    [
        (RuntimeConfig(seed=3), None),  # ideal fleet (GPU boot preset)
        (
            RuntimeConfig.aws_default(),  # cold starts, stragglers, faults
            InstanceConfig(
                boot_s=5.0, churn_prob=0.3, churn_downtime_s=2.0, seed=3
            ),
        ),
    ],
    ids=["ideal", "faulty"],
)
def test_mixed_fleet_same_seed_digest_stability(runtime, instance_cfg):
    plan = FleetPlan(
        (
            PeerAssignment("instance", instance="p3.2xlarge"),
            PeerAssignment("instance", instance="t2.large"),
            PeerAssignment("serverless"),
            PeerAssignment("serverless", memory_mb=4400),
        ),
        name="gpu+cpu+sls",
    )
    workload = [[6.0, 6.0], [1.0] * 4, [0.5] * 8, [0.5] * 8]

    def one_run():
        tr = TraceRecorder()
        fx = FleetExecutor(
            runtime=runtime, instance_config=instance_cfg, tracer=tr
        )
        outs = [
            fx.run_epoch(plan, workload, model_bytes=MODEL, batch_bytes=BATCH)
            for _ in range(2)
        ]
        return tr.digest(), [o.wall_time_s for o in outs], [
            o.total_usd for o in outs
        ]

    d1, walls1, usd1 = one_run()
    d2, walls2, usd2 = one_run()
    assert d1 == d2  # bit-identical event traces
    assert walls1 == walls2 and usd1 == usd2


# ---------------------------------------------------------------------------
# Trainer surface: P2PTrainer(scheduler=...) + schedule_epoch
# ---------------------------------------------------------------------------

def test_trainer_schedule_epoch_picks_under_constraints():
    from repro.configs import get_config, reduced
    from repro.core.p2p import Topology
    from repro.launch.mesh import make_host_mesh
    from repro.optim import sgd
    from repro.optim.schedules import warmup_cosine
    from repro.train import P2PTrainer

    tr = P2PTrainer(
        reduced(get_config("qwen2.5-3b"), vocab_size=64),
        sgd(), Topology(peer_axes=()), make_host_mesh(1, 1),
        warmup_cosine(1e-3, 1, 10),
        scheduler="cheapest_under_deadline",
    )
    workload = [[8.0], [8.0], [0.2] * 8, [0.2] * 8]
    out = tr.schedule_epoch(workload, deadline_s=120.0)
    assert out["plan"].num_peers == 4
    assert out["report"].wall_time_s <= 120.0
    assert len(out["candidates"]) >= 6
    # no scheduler configured -> actionable error
    tr2 = P2PTrainer(
        reduced(get_config("qwen2.5-3b"), vocab_size=64),
        sgd(), Topology(peer_axes=()), make_host_mesh(1, 1),
        warmup_cosine(1e-3, 1, 10),
    )
    with pytest.raises(ValueError, match="scheduler"):
        tr2.schedule_epoch(workload)
